#!/usr/bin/env python
"""Summarize repro.obs run logs (and optional Chrome traces) for humans.

    PYTHONPATH=src python tools/obs_report.py RUNLOG.jsonl [more.jsonl ...] \
        [--trace trace.json]

For each run log: the run configuration, loss trajectory, recorded
per-round theta, the theta-headroom percentiles with their safe
thresholds, the modulo alias sentinel (LOUD warning on any event — it
means Lemma 1's hypothesis failed and decodes wrapped), payload
bits/param, and the host-side phase breakdown from the recorded spans.

Reads anything ``repro.obs.runlog`` writes: trainer runs, ``--log-jsonl``
dryruns, benchmark ``*.runlog.jsonl`` sidecars.  The CI gate lives in
``tools/check_obs.py``; this tool only reports.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import runlog as RL  # noqa: E402
from repro.obs import trace as TR  # noqa: E402


def _pct(values, q):
    if not values:
        return 0.0
    vs = sorted(values)
    return vs[min(int(len(vs) * q), len(vs) - 1)]


def _metric_series(steps, key):
    return [r["metrics"][key] for r in steps
            if isinstance(r.get("metrics"), dict)
            and isinstance(r["metrics"].get(key), (int, float))]


def report_runlog(path: str) -> int:
    """Print one run log's summary; returns the number of schema errors."""
    errors = RL.validate_runlog(path)
    print(f"== {path}")
    if errors:
        for e in errors:
            print(f"  SCHEMA ERROR: {e}")
        return len(errors)
    records = RL.read_runlog(path)
    head = records[0]
    run = head.get("run", {}) or {}
    cfg_bits = [f"tool={head.get('tool')}"]
    for k in ("algo", "wire", "bits", "n_workers", "topology", "backend",
              "theta", "theta_mode", "bench"):
        if k in run:
            cfg_bits.append(f"{k}={run[k]}")
    print("  " + "  ".join(cfg_bits))

    steps = RL.step_records(records)
    if steps:
        losses = _metric_series(steps, "loss")
        thetas = _metric_series(steps, "theta")
        print(f"  steps logged: {len(steps)}"
              + (f"  loss {losses[0]:.4g} -> {losses[-1]:.4g}"
                 if losses else ""))
        if thetas:
            print(f"  theta recorded per round: min={min(thetas):.4g} "
                  f"max={max(thetas):.4g}")
        headroom = _metric_series(steps, "obs_headroom")
        consensus = _metric_series(steps, "obs_consensus_inf")
        if headroom and any(h > 0 for h in headroom):
            # safe threshold: headroom < theta/B = (1-2*delta)/2 < 0.5
            print(f"  theta headroom (consensus/B): "
                  f"p50={_pct(headroom, 0.50):.4g} "
                  f"p95={_pct(headroom, 0.95):.4g} "
                  f"max={max(headroom):.4g}   (safe < 0.5)")
        if consensus and thetas and all(t > 0 for t in thetas):
            ratio = [c / t for c, t in zip(consensus, thetas)]
            print(f"  consensus/theta: p50={_pct(ratio, 0.50):.4g} "
                  f"p95={_pct(ratio, 0.95):.4g} max={max(ratio):.4g}   "
                  f"(safe < 1)")
        bpp = _metric_series(steps, "obs_bits_per_param")
        if bpp:
            print(f"  payload bits/param: {bpp[-1]:.4g}")
        b_slow = _metric_series(steps, "obs_bytes_slow")
        b_fast = _metric_series(steps, "obs_bytes_fast")
        if b_slow and any(v > 0 for v in b_slow):
            line = f"  bytes/round slow axis: {b_slow[-1]:.4g}"
            if b_fast and any(v > 0 for v in b_fast):
                line += (f"  fast axis: {b_fast[-1]:.4g}  "
                         f"(two-tier round: quantized owned-shard gossip "
                         f"vs intra reduce-scatter/all-gather)")
            print(line)
        part = _metric_series(steps, "obs_participation")
        if part and any(v < 1.0 for v in part):
            dropped = _metric_series(steps, "obs_dropped_neighbors")
            line = (f"  participation: mean={sum(part) / len(part):.4g} "
                    f"min={min(part):.4g}")
            if dropped:
                line += (f"  dropped gossip edges/round: "
                         f"max={max(dropped):.4g}")
            print(line + "  (elastic rounds; absent workers mix identity)")
        ef = _metric_series(steps, "obs_ef_residual_l2")
        if ef and any(v > 0 for v in ef):
            print(f"  EF residual l2: first={ef[0]:.4g} last={ef[-1]:.4g} "
                  f"max={max(ef):.4g}")
        warm = _metric_series(steps, "obs_warm")
        if warm and any(v > 0 for v in warm):
            print(f"  warmup rounds in log: "
                  f"{sum(1 for v in warm if v > 0)}/{len(warm)}")
        aliases = RL.alias_events(records)
        if aliases:
            print(f"  *** ALIAS WARNING: {aliases} modulo alias events — "
                  f"theta is undersized, Lemma 1's |x_i - x_j| < theta "
                  f"hypothesis FAILED and decodes wrapped.  Raise theta "
                  f"(or its schedule) before trusting this run. ***")
        elif _metric_series(steps, "obs_alias_count"):
            print("  alias sentinel: 0 events (theta bound held)")

    spans = [r for r in records if r.get("kind") == "span"]
    if spans:
        by_name = {}
        for s in spans:
            by_name.setdefault(s["name"], []).append(s["dur_s"])
        total = sum(sum(v) for v in by_name.values())
        print("  phase breakdown (host spans):")
        for name, durs in sorted(by_name.items(),
                                 key=lambda kv: -sum(kv[1])):
            tot = sum(durs)
            share = 100.0 * tot / total if total else 0.0
            print(f"    {name:<22} {tot:8.3f}s  x{len(durs):<5} "
                  f"{share:5.1f}%")

    events = [r for r in records if r.get("kind") == "event"]
    if events:
        kinds = {}
        for e in events:
            kinds[e["name"]] = kinds.get(e["name"], 0) + 1
        print("  events: " + ", ".join(f"{k} x{v}"
                                       for k, v in sorted(kinds.items())))
    for r in records:
        if r.get("kind") == "result":
            fields = {k: v for k, v in r.items() if k != "kind"}
            print("  result: " + json.dumps(fields))
    return 0


def report_trace(path: str) -> int:
    print(f"== {path}")
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"  UNREADABLE: {e}")
        return 1
    errors = TR.validate_chrome(obj)
    for e in errors:
        print(f"  TRACE ERROR: {e}")
    evs = obj.get("traceEvents", [])
    spans = [e for e in evs if e.get("ph") == "X"]
    pids = sorted({e.get("pid", 0) for e in evs})
    print(f"  {len(evs)} events ({len(spans)} spans) across "
          f"{len(pids)} process(es); open in Perfetto / chrome://tracing")
    by_name = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s.get("dur", 0.0))
    for name, durs in sorted(by_name.items(), key=lambda kv: -sum(kv[1]))[:10]:
        print(f"    {name:<22} {sum(durs)/1e6:8.3f}s  x{len(durs)}")
    return len(errors)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("runlogs", nargs="*", help="runlog JSONL files")
    ap.add_argument("--trace", action="append", default=[],
                    help="Chrome-trace JSON files to summarize")
    args = ap.parse_args(argv)
    if not args.runlogs and not args.trace:
        ap.error("nothing to report: pass runlog files and/or --trace")
    failures = 0
    for path in args.runlogs:
        failures += report_runlog(path)
    for path in args.trace:
        failures += report_trace(path)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
