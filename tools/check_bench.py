#!/usr/bin/env python3
"""Benchmark regression gate: smoke run vs the committed perf trajectory.

CI's ``bench-smoke`` job produces ``BENCH_network_sim.smoke.json`` on every
push; this script compares it against the committed full-run
``BENCH_network_sim.json`` and fails (exit 1) when the simulator's pricing
drifts, so a regression in the contention/link models cannot land silently.

What is compared — smoke runs use a smaller model, so raw round times and
speedups are NOT comparable across the two files.  The invariant that is:
the **marginal wire seconds per byte** each scenario charges,

    slope = (round_s(fp32) - round_s(moniqua-1bit))
            / (bytes(fp32) - bytes(moniqua-1bit))

which cancels the compute term and the model size, leaving the scenario's
effective bandwidth pricing (1/beta for isolated links, the fair-share
rate for contended fabrics).  Checks:

1. every scenario in the smoke table exists in the reference table;
2. per-scenario slope drift <= --tol (default 25% relative);
3. the reference still covers the required contention scenarios and
   carries a positive headline speedup with loss within tolerance.

Usage:  python tools/check_bench.py \\
            [--smoke BENCH_network_sim.smoke.json] \\
            [--ref BENCH_network_sim.json] [--tol 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REQUIRED_SCENARIOS = ("bandwidth-starved", "oversubscribed-tor",
                      "shared-uplink-ring", "calibrated-from-bench")
# every contended scenario must carry a contention-summary row in the
# reference — an empty `contention` list must fail, not pass vacuously
CONTENTION_SCENARIOS = ("oversubscribed-tor", "shared-uplink-ring")
BASE_CODEC, FAST_CODEC = "fp32", "moniqua-1bit"


def wire_slope(table: list, scenario: str) -> float | None:
    """Marginal wire seconds/byte between the fp32 and 1-bit rows."""
    rows = {r["codec"]: r for r in table if r["scenario"] == scenario}
    f, q = rows.get(BASE_CODEC), rows.get(FAST_CODEC)
    if not (f and q):
        return None
    db = f["bytes_per_round"] - q["bytes_per_round"]
    if db <= 0:
        return None
    return (f["mean_round_s"] - q["mean_round_s"]) / db


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke",
                    default=os.path.join(REPO, "BENCH_network_sim.smoke.json"))
    ap.add_argument("--ref",
                    default=os.path.join(REPO, "BENCH_network_sim.json"))
    ap.add_argument("--tol", type=float, default=0.25,
                    help="max relative drift of per-scenario wire slope")
    args = ap.parse_args(argv)

    with open(args.smoke) as f:
        smoke = json.load(f)
    with open(args.ref) as f:
        ref = json.load(f)

    errors: list[str] = []
    ref_scenarios = {r["scenario"] for r in ref["table"]}
    smoke_scenarios = sorted({r["scenario"] for r in smoke["table"]})

    for name in REQUIRED_SCENARIOS:
        if name not in ref_scenarios:
            errors.append(f"reference is missing required scenario {name!r}")

    for name in smoke_scenarios:
        if name not in ref_scenarios:
            errors.append(f"smoke scenario {name!r} missing from reference")
            continue
        s_slope = wire_slope(smoke["table"], name)
        r_slope = wire_slope(ref["table"], name)
        if s_slope is None or r_slope is None:
            errors.append(f"{name}: cannot form {BASE_CODEC} vs {FAST_CODEC} "
                          "wire slope (missing codec rows?)")
            continue
        drift = abs(s_slope - r_slope) / abs(r_slope)
        status = "FAIL" if drift > args.tol else "ok"
        print(f"{name}: wire slope smoke={s_slope:.3e} ref={r_slope:.3e} "
              f"drift={drift:.1%} [{status}]")
        if drift > args.tol:
            errors.append(f"{name}: wire-slope drift {drift:.1%} "
                          f"exceeds {args.tol:.0%}")

    head = ref.get("headline") or {}
    if not head.get("speedup_x") or head["speedup_x"] <= 1.0:
        errors.append("reference headline speedup missing or <= 1.0x")
    elif not head.get("loss_within_tol"):
        errors.append("reference headline reached speedup outside the "
                      "loss tolerance")
    else:
        print(f"headline: {head['scenario']} "
              f"{head['speedup_x']:.2f}x at matched loss [ok]")

    contention = {c["scenario"]: c for c in ref.get("contention", [])}
    for name in CONTENTION_SCENARIOS:
        c = contention.get(name)
        if c is None:
            errors.append(f"{name}: no contention-summary row in the "
                          "reference (speedups unresolvable or scenario "
                          "dropped)")
        elif not c.get("gap_widened"):
            errors.append(f"{name}: fp32-vs-1bit gap did NOT widen over "
                          f"{c['isolated_baseline']}")
        else:
            print(f"contention: {name} {c['speedup_x']:.2f}x vs "
                  f"isolated {c['isolated_speedup_x']:.2f}x [ok]")

    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"bench check OK ({len(smoke_scenarios)} scenarios compared)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
