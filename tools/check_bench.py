#!/usr/bin/env python3
"""Benchmark regression gate: smoke run vs the committed perf trajectory.

CI's ``bench-smoke`` job produces ``BENCH_network_sim.smoke.json`` on every
push; this script compares it against the committed full-run
``BENCH_network_sim.json`` and fails (exit 1) when the simulator's pricing
drifts, so a regression in the contention/link models cannot land silently.

What is compared — smoke runs use a smaller model, so raw round times and
speedups are NOT comparable across the two files.  The invariant that is:
the **marginal wire seconds per byte** each scenario charges,

    slope = (round_s(fp32) - round_s(moniqua-1bit))
            / (bytes(fp32) - bytes(moniqua-1bit))

which cancels the compute term and the model size, leaving the scenario's
effective bandwidth pricing (1/beta for isolated links, the fair-share
rate for contended fabrics).  Checks:

1. every scenario in the smoke table exists in the reference table;
2. per-scenario slope drift <= --tol (default 25% relative);
3. the reference still covers the required contention scenarios and
   carries a positive headline speedup with loss within tolerance.

It also gates the comm-fusion trajectory (``BENCH_comm_fusion.json``, from
``benchmarks/bench_comm_fusion.py``): for every model the smoke run's
bucketed-vs-per-leaf speedup at the 1-bit headline codec must not regress
more than ``--tol`` below the committed reference (one-sided — running
*faster* than the reference never fails), and the committed reference must
still show the >=2x bucketed win on a multi-leaf model.  Raw mix times are
machine-dependent; the speedup is a ratio of two times on the same host,
which is what makes it comparable across machines at all.  Fusion checks
run only when the fusion smoke file exists (``--fusion-smoke``), so the
network-sim gate can run standalone.

It also gates the memory-accounting trajectory
(``BENCH_memory_overhead.json``, from ``benchmarks/bench_memory_overhead``):
the committed reference must show Moniqua-wire rows at **exactly 0.0**
extra memory (the paper's headline systems claim) and every EF-wire row
(``ef_qsgd`` / ``onebit``) at a Theta(nd)-scale residual buffer (>= 4
bytes/param).  Because both the accounting and the ``repro.sim`` round
pricing are deterministic, the smoke run must reproduce the reference
accounting columns *exactly* — any drift means the codec memory/byte model
changed and the committed artifact must be regenerated deliberately.
Memory checks run only when the memory smoke file exists (``--mem-smoke``);
a smoke file without its reference is an error, not a skip.

It also gates the chunk-pipelined round trajectory (``BENCH_overlap.json``,
from ``benchmarks/bench_overlap.py``): every bit-exactness row (the five
wires, pipelined-vs-barrier) must be ``true`` in BOTH files — the pipeline
is a schedule change and any numeric drift is a hard failure — the
committed reference must keep a measurable whole-step win (>= 1.10x) on
at least two multi-chunk model configs, and the smoke run's speedup on
reference-winning cells must not regress more than ``--tol`` below the
(capped) committed win.  Overlap checks run only when the overlap smoke
file exists (``--overlap-smoke``).

It also gates the two-tier hierarchy trajectory (``BENCH_hierarchical.json``,
from ``benchmarks/bench_hierarchical.py``): every trivial-tier bit-exactness
row (five wires x both backends, WireState carries included) must be ``true``
in BOTH files — the tiered round is a schedule/placement change and any
numeric drift against the single-tier reference is a hard failure — every
accounting row must show slow-axis bytes <= (1/n_intra + eps) of the
single-tier bytes (the owned-shard contract; ratios are shape math, so they
hold in smoke and full alike), and the committed reference must carry a
>= 70B-param headline whose two-tier wall-clock-to-target beats single-tier
1-bit on the same oversubscribed fabric.  Hierarchy checks run only when the
hierarchy smoke file exists (``--hier-smoke``).

It also gates the elastic-gossip trajectory (``BENCH_elastic.json``, from
``benchmarks/bench_elastic.py``): every presence=all-ones bit-exactness
row (five wires x both backends x both gossip paths, plus the two-tier
engine, WireState carries included) must be ``true`` in BOTH files — the
elastic mask is a renormalization change and any numeric drift with
nobody absent is a hard failure — every deadline row must show
deadline-dropping beating wait-for-stragglers on wall-clock-to-target
(``speedup_x > 1``) with BOTH runs hitting the matched loss target
(``matched``), and every robustness-sweep row must have converged
(``loss_last < loss_first``).  The sim and the replay are seeded and
deterministic, so these invariants hold in smoke and full runs alike.
Elastic checks run only when the elastic smoke file exists
(``--elastic-smoke``); a smoke file without its reference is an error.

Usage:  python tools/check_bench.py \\
            [--smoke BENCH_network_sim.smoke.json] \\
            [--ref BENCH_network_sim.json] \\
            [--fusion-smoke BENCH_comm_fusion.smoke.json] \\
            [--fusion-ref BENCH_comm_fusion.json] \\
            [--mem-smoke BENCH_memory_overhead.smoke.json] \\
            [--mem-ref BENCH_memory_overhead.json] \\
            [--overlap-smoke BENCH_overlap.smoke.json] \\
            [--overlap-ref BENCH_overlap.json] \\
            [--hier-smoke BENCH_hierarchical.smoke.json] \\
            [--hier-ref BENCH_hierarchical.json] \\
            [--elastic-smoke BENCH_elastic.smoke.json] \\
            [--elastic-ref BENCH_elastic.json] [--tol 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REQUIRED_SCENARIOS = ("bandwidth-starved", "oversubscribed-tor",
                      "shared-uplink-ring", "calibrated-from-bench")
# every contended scenario must carry a contention-summary row in the
# reference — an empty `contention` list must fail, not pass vacuously
CONTENTION_SCENARIOS = ("oversubscribed-tor", "shared-uplink-ring")
BASE_CODEC, FAST_CODEC = "fp32", "moniqua-1bit"


def wire_slope(table: list, scenario: str) -> float | None:
    """Marginal wire seconds/byte between the fp32 and 1-bit rows."""
    rows = {r["codec"]: r for r in table if r["scenario"] == scenario}
    f, q = rows.get(BASE_CODEC), rows.get(FAST_CODEC)
    if not (f and q):
        return None
    db = f["bytes_per_round"] - q["bytes_per_round"]
    if db <= 0:
        return None
    return (f["mean_round_s"] - q["mean_round_s"]) / db


# the fusion gate's headline codec: where per-leaf fixed costs dominate
FUSION_CODEC = "moniqua-1bit"
# the committed reference must keep a >=2x bucketed win on a model with at
# least this many leaves (the multi-leaf regime fusion exists for)
FUSION_MIN_SPEEDUP, FUSION_MIN_LEAVES = 2.0, 16
# every zoo model must appear in smoke AND reference — a shrinking bench
# table must fail, not silently disable the per-model gate
FUSION_REQUIRED_MODELS = ("resnet", "transformer", "mamba2", "moe")


def check_fusion(smoke: dict, ref: dict, tol: float, errors: list) -> None:
    """Per-model bucketed-speedup regression gate for BENCH_comm_fusion.

    Only models the reference shows *winning* from bucketing (speedup
    >= 1) are floor-gated: sub-1x rows are the staging-copy-bound regime
    where per-leaf wins by design, and a ratio of two noisy sub-100ms
    timings routinely drifts >25% run-to-run — gating them makes CI flaky
    without guarding anything fusion promises.  They still must be
    present (coverage check) and are reported for the trajectory.
    """
    def rows(d):
        return {r["model"]: r for r in d["table"]
                if r["codec"] == FUSION_CODEC}

    s_rows, r_rows = rows(smoke), rows(ref)
    for model in FUSION_REQUIRED_MODELS:
        if model not in r_rows:
            errors.append(f"fusion: required model {model!r} missing from "
                          "reference")
        if model not in s_rows:
            errors.append(f"fusion: required model {model!r} missing from "
                          "smoke run")
    for model, s in sorted(s_rows.items()):
        r = r_rows.get(model)
        if r is None:
            errors.append(f"fusion: model {model!r} missing from reference")
            continue
        if r["speedup_x"] < 1.0:
            print(f"fusion: {model} speedup smoke={s['speedup_x']:.2f}x "
                  f"ref={r['speedup_x']:.2f}x [info: per-leaf regime, "
                  "not gated]")
            continue
        # floor against the promised win (capped at FUSION_MIN_SPEEDUP),
        # not the dev host's exact ratio: the speedup's magnitude is
        # host-profile-dependent (dispatch overhead vs copy bandwidth),
        # and the gate exists to catch the bucketed path regressing
        # toward parity, not a faster reference machine
        floor = (1.0 - tol) * min(r["speedup_x"], FUSION_MIN_SPEEDUP)
        status = "FAIL" if s["speedup_x"] < floor else "ok"
        print(f"fusion: {model} speedup smoke={s['speedup_x']:.2f}x "
              f"ref={r['speedup_x']:.2f}x floor={floor:.2f}x [{status}]")
        if s["speedup_x"] < floor:
            errors.append(f"fusion: {model} bucketed speedup regressed "
                          f"{s['speedup_x']:.2f}x < {floor:.2f}x "
                          f"(ref {r['speedup_x']:.2f}x - {tol:.0%})")
    winners = [r for r in r_rows.values()
               if r["n_leaves"] >= FUSION_MIN_LEAVES
               and r["speedup_x"] >= FUSION_MIN_SPEEDUP]
    if not winners:
        errors.append(
            f"fusion reference: no model with >= {FUSION_MIN_LEAVES} leaves "
            f"keeps a >= {FUSION_MIN_SPEEDUP}x bucketed speedup at "
            f"{FUSION_CODEC}")
    else:
        best = max(winners, key=lambda r: r["speedup_x"])
        print(f"fusion headline: {best['model']} {best['speedup_x']:.2f}x "
              f"({best['n_leaves']} leaves) [ok]")


# the memory gate's wire classes: zero-state vs Theta(nd) error feedback
MEM_ZERO_WIRE = "moniqua"
MEM_EF_WIRES = ("ef_qsgd", "onebit")
# accounting columns that must match the reference exactly (deterministic
# shape math + seeded simulator — no tolerance, by design)
MEM_EXACT_COLS = ("extra_memory_bytes", "wire_bytes_per_step")


def check_memory(smoke: dict, ref: dict, errors: list) -> None:
    """BENCH_memory_overhead gate: Moniqua stays at exactly 0 extra bytes,
    EF wires report Theta(nd) residuals, smoke accounting == reference."""
    def key(r):
        return (r["model"], r["algorithm"], r["wire"], r["bits"])

    r_rows = {key(r): r for r in ref["table"]}
    s_rows = {key(r): r for r in smoke["table"]}

    zero = [r for r in ref["table"]
            if r["algorithm"] == "moniqua" and r["wire"] == MEM_ZERO_WIRE]
    if not zero:
        errors.append("memory reference has no moniqua-wire rows")
    for r in zero:
        if r["extra_memory_MB"] != 0.0 or r["extra_memory_bytes"] != 0:
            errors.append(f"memory: moniqua row {key(r)} reports "
                          f"{r['extra_memory_MB']} MB extra — the "
                          "zero-extra-memory headline claim is broken")
    ef = [r for r in ref["table"] if r["wire"] in MEM_EF_WIRES]
    if not ef:
        errors.append("memory reference has no EF-wire rows "
                      f"({'/'.join(MEM_EF_WIRES)})")
    for r in ef:
        if r["extra_memory_bytes"] < 4 * r["params"]:
            errors.append(f"memory: EF row {key(r)} reports "
                          f"{r['extra_memory_bytes']} B < 4*d — not the "
                          "Theta(nd) residual accounting")
    ok_zero = sum(1 for r in zero
                  if r["extra_memory_MB"] == 0.0) == len(zero) and zero
    ok_ef = sum(1 for r in ef
                if r["extra_memory_bytes"] >= 4 * r["params"]) == len(ef) \
        and ef
    if zero and ef:
        print(f"memory: {len(zero)} moniqua rows at 0 extra "
              f"[{'ok' if ok_zero else 'FAIL'}], {len(ef)} EF rows at "
              f"Theta(nd) [{'ok' if ok_ef else 'FAIL'}]")

    for k in sorted(s_rows):
        if k not in r_rows:
            errors.append(f"memory: smoke row {k} missing from reference")
    for k in sorted(r_rows):
        s = s_rows.get(k)
        if s is None:
            errors.append(f"memory: reference row {k} missing from smoke "
                          "run (accounting table shrank)")
            continue
        for col in MEM_EXACT_COLS:
            if s[col] != r_rows[k][col]:
                errors.append(f"memory: {k} {col} drifted "
                              f"{r_rows[k][col]} -> {s[col]} (accounting "
                              "is deterministic; exact match required)")


# the overlap gate: the committed reference must keep a measurable
# pipelined whole-step win on at least this many multi-chunk configs
OVERLAP_MIN_SPEEDUP, OVERLAP_MIN_WINNERS = 1.10, 2
# smoke floors are capped here (host-profile-dependent magnitude, same
# rationale as FUSION_MIN_SPEEDUP)
OVERLAP_CAP = 1.25


def check_overlap(smoke: dict, ref: dict, tol: float, errors: list) -> None:
    """BENCH_overlap gate: pipelined == barrier bitwise (both files, all
    wires), the committed reference keeps >= OVERLAP_MIN_WINNERS configs
    at >= OVERLAP_MIN_SPEEDUP, and smoke speedups on reference-winning
    cells stay within --tol of the (capped) committed win."""
    for tag, d in (("ref", ref), ("smoke", smoke)):
        bad = [r for r in d.get("bitexact", []) if not r["bitexact"]]
        if not d.get("bitexact"):
            errors.append(f"overlap {tag}: no bitexact rows")
        for r in bad:
            errors.append(f"overlap {tag}: {r['model']}/{r['wire']} "
                          f"pipelined round is NOT bit-exact vs barrier")
        if d.get("bitexact") and not bad:
            wires = len({r["wire"] for r in d["bitexact"]})
            print(f"overlap {tag}: {len(d['bitexact'])} bitexact rows "
                  f"({wires} wires) all true [ok]")

    def rows(d):
        return {(r["model"], r["wire"]): r for r in d["table"]}

    s_rows, r_rows = rows(smoke), rows(ref)
    for key, s in sorted(s_rows.items()):
        r = r_rows.get(key)
        if r is None:
            errors.append(f"overlap: smoke cell {key} missing from "
                          "reference")
            continue
        if r["speedup_x"] < 1.0:
            print(f"overlap: {key[0]}/{key[1]} smoke="
                  f"{s['speedup_x']:.2f}x ref={r['speedup_x']:.2f}x "
                  "[info: barrier regime, not gated]")
            continue
        floor = (1.0 - tol) * min(r["speedup_x"], OVERLAP_CAP)
        status = "FAIL" if s["speedup_x"] < floor else "ok"
        print(f"overlap: {key[0]}/{key[1]} smoke={s['speedup_x']:.2f}x "
              f"ref={r['speedup_x']:.2f}x floor={floor:.2f}x [{status}]")
        if s["speedup_x"] < floor:
            errors.append(f"overlap: {key} pipelined speedup regressed "
                          f"{s['speedup_x']:.2f}x < {floor:.2f}x "
                          f"(ref {r['speedup_x']:.2f}x - {tol:.0%})")
    winners = [r for r in r_rows.values()
               if r["chunks"] > 1 and r["speedup_x"] >= OVERLAP_MIN_SPEEDUP]
    if len(winners) < OVERLAP_MIN_WINNERS:
        errors.append(
            f"overlap reference: only {len(winners)} multi-chunk configs "
            f"at >= {OVERLAP_MIN_SPEEDUP}x (need {OVERLAP_MIN_WINNERS})")
    else:
        best = max(winners, key=lambda r: r["speedup_x"])
        print(f"overlap headline: {best['model']}/{best['wire']} "
              f"{best['speedup_x']:.2f}x over {len(winners)} winning "
              "configs [ok]")


# the hierarchy gate: owned-shard slow-axis contract + >= 70B headline
HIER_RATIO_EPS = 1e-3
HIER_MIN_PARAMS = 70e9
# five wires x two backends: a shrinking bit-exactness matrix must fail
HIER_MIN_BITEXACT_ROWS = 10


def check_hierarchical(smoke: dict, ref: dict, tol: float,
                       errors: list) -> None:
    """BENCH_hierarchical gate: trivial-tier rounds bitwise == single-tier
    (both files, all wires/backends incl. WireState), slow-axis bytes
    <= (1/n_intra + eps) of single-tier in every accounting row, and the
    committed reference keeps a >= 70B headline with a two-tier
    wall-clock-to-target win on the contended fabric."""
    for tag, d in (("ref", ref), ("smoke", smoke)):
        rows = d.get("bitexact", [])
        if len(rows) < HIER_MIN_BITEXACT_ROWS:
            errors.append(f"hierarchy {tag}: only {len(rows)} bitexact rows "
                          f"(need >= {HIER_MIN_BITEXACT_ROWS}: five wires "
                          "x two backends)")
        bad = [r for r in rows if not r["bitexact"]]
        for r in bad:
            errors.append(f"hierarchy {tag}: {r['wire']}/{r['backend']} "
                          "trivial-tier round is NOT bit-exact vs "
                          "single-tier")
        if rows and not bad:
            wires = len({r["wire"] for r in rows})
            print(f"hierarchy {tag}: {len(rows)} bitexact rows "
                  f"({wires} wires) all true [ok]")
        for r in d.get("table", []):
            cap = 1.0 / r["n_intra"] + HIER_RATIO_EPS
            status = "FAIL" if r["slow_bytes_ratio"] > cap else "ok"
            print(f"hierarchy {tag}: {r['config']} slow-bytes ratio "
                  f"{r['slow_bytes_ratio']:.4f} cap {cap:.4f} [{status}]")
            if r["slow_bytes_ratio"] > cap:
                errors.append(f"hierarchy {tag}: {r['config']} slow-axis "
                              f"bytes ratio {r['slow_bytes_ratio']:.4f} "
                              f"exceeds 1/n_intra + eps = {cap:.4f} — the "
                              "owned-shard contract is broken")

    head = ref.get("headline") or {}
    if not head:
        errors.append("hierarchy reference has no headline row")
        return
    if head.get("params", 0) < HIER_MIN_PARAMS:
        errors.append(f"hierarchy reference headline is {head.get('params')} "
                      f"params — need >= {HIER_MIN_PARAMS:.0e} (the 70B "
                      "config the README row cites)")
    if not head.get("speedup_x") or head["speedup_x"] <= 1.0:
        errors.append("hierarchy reference headline: two-tier wall-clock-"
                      "to-target does not beat single-tier "
                      f"(speedup_x={head.get('speedup_x')})")
    else:
        print(f"hierarchy headline: {head['config']} "
              f"{head['slow_reduction_x']:.1f}x fewer slow-axis bytes, "
              f"{head['speedup_x']:.2f}x wall-clock-to-target [ok]")


# the elastic gate: five wires x two backends x two paths (20) plus the
# five two-tier rows — a shrinking bit-exactness matrix must fail
ELASTIC_MIN_BITEXACT_ROWS = 25


def check_elastic(smoke: dict, ref: dict, errors: list) -> None:
    """BENCH_elastic gate: presence=all-ones bitwise == plain mix (both
    files, all wires/backends/paths incl. two-tier and WireState),
    deadline-dropping beats wait-for-stragglers at matched loss in every
    deadline row, and every dropout-sweep run converged."""
    for tag, d in (("ref", ref), ("smoke", smoke)):
        rows = d.get("bitexact", [])
        if len(rows) < ELASTIC_MIN_BITEXACT_ROWS:
            errors.append(f"elastic {tag}: only {len(rows)} bitexact rows "
                          f"(need >= {ELASTIC_MIN_BITEXACT_ROWS}: five "
                          "wires x two backends x two paths + two-tier)")
        bad = [r for r in rows if not r["bitexact"]]
        for r in bad:
            errors.append(f"elastic {tag}: {r['wire']}/{r['backend']}/"
                          f"{r['path']} presence=all-ones round is NOT "
                          "bit-exact vs plain mix")
        if rows and not bad:
            wires = len({r["wire"] for r in rows})
            print(f"elastic {tag}: {len(rows)} bitexact rows "
                  f"({wires} wires) all true [ok]")
        for r in d.get("deadline", []):
            ok = r.get("matched") and r.get("speedup_x", 0.0) > 1.0
            status = "ok" if ok else "FAIL"
            print(f"elastic {tag}: {r['scenario']} deadline "
                  f"{r['speedup_x']:.2f}x wall-clock-to-target "
                  f"(participation {r['participation_deadline']:.2f}) "
                  f"[{status}]")
            if not r.get("matched"):
                errors.append(f"elastic {tag}: {r['scenario']} missed the "
                              "matched-loss target (a run never reached "
                              f"{r.get('target_loss')})")
            elif r.get("speedup_x", 0.0) <= 1.0:
                errors.append(f"elastic {tag}: {r['scenario']} deadline-"
                              "dropping does not beat wait-for-stragglers "
                              f"({r.get('speedup_x')}x)")
        if not d.get("deadline"):
            errors.append(f"elastic {tag}: no deadline rows")
        diverged = [r for r in d.get("sweep", [])
                    if not r["loss_last"] < r["loss_first"]]
        for r in diverged:
            errors.append(f"elastic {tag}: sweep run p={r['p']} "
                          f"{r['codec']} diverged ({r['loss_first']} -> "
                          f"{r['loss_last']})")
        if not d.get("sweep"):
            errors.append(f"elastic {tag}: no dropout-sweep rows")
        elif not diverged:
            codecs = len({r["codec"] for r in d["sweep"]})
            ps = len({r["p"] for r in d["sweep"]})
            print(f"elastic {tag}: {len(d['sweep'])} sweep runs "
                  f"({codecs} codecs x {ps} dropout rates) converged [ok]")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke",
                    default=os.path.join(REPO, "BENCH_network_sim.smoke.json"))
    ap.add_argument("--ref",
                    default=os.path.join(REPO, "BENCH_network_sim.json"))
    ap.add_argument("--fusion-smoke",
                    default=os.path.join(REPO,
                                         "BENCH_comm_fusion.smoke.json"))
    ap.add_argument("--fusion-ref",
                    default=os.path.join(REPO, "BENCH_comm_fusion.json"))
    ap.add_argument("--mem-smoke",
                    default=os.path.join(REPO,
                                         "BENCH_memory_overhead.smoke.json"))
    ap.add_argument("--mem-ref",
                    default=os.path.join(REPO, "BENCH_memory_overhead.json"))
    ap.add_argument("--overlap-smoke",
                    default=os.path.join(REPO, "BENCH_overlap.smoke.json"))
    ap.add_argument("--overlap-ref",
                    default=os.path.join(REPO, "BENCH_overlap.json"))
    ap.add_argument("--hier-smoke",
                    default=os.path.join(REPO,
                                         "BENCH_hierarchical.smoke.json"))
    ap.add_argument("--hier-ref",
                    default=os.path.join(REPO, "BENCH_hierarchical.json"))
    ap.add_argument("--elastic-smoke",
                    default=os.path.join(REPO, "BENCH_elastic.smoke.json"))
    ap.add_argument("--elastic-ref",
                    default=os.path.join(REPO, "BENCH_elastic.json"))
    ap.add_argument("--tol", type=float, default=0.25,
                    help="max relative drift of per-scenario wire slope "
                         "and of per-model bucketed speedup")
    args = ap.parse_args(argv)

    with open(args.smoke) as f:
        smoke = json.load(f)
    with open(args.ref) as f:
        ref = json.load(f)

    errors: list[str] = []
    ref_scenarios = {r["scenario"] for r in ref["table"]}
    smoke_scenarios = sorted({r["scenario"] for r in smoke["table"]})

    for name in REQUIRED_SCENARIOS:
        if name not in ref_scenarios:
            errors.append(f"reference is missing required scenario {name!r}")

    for name in smoke_scenarios:
        if name not in ref_scenarios:
            errors.append(f"smoke scenario {name!r} missing from reference")
            continue
        s_slope = wire_slope(smoke["table"], name)
        r_slope = wire_slope(ref["table"], name)
        if s_slope is None or r_slope is None:
            errors.append(f"{name}: cannot form {BASE_CODEC} vs {FAST_CODEC} "
                          "wire slope (missing codec rows?)")
            continue
        drift = abs(s_slope - r_slope) / abs(r_slope)
        status = "FAIL" if drift > args.tol else "ok"
        print(f"{name}: wire slope smoke={s_slope:.3e} ref={r_slope:.3e} "
              f"drift={drift:.1%} [{status}]")
        if drift > args.tol:
            errors.append(f"{name}: wire-slope drift {drift:.1%} "
                          f"exceeds {args.tol:.0%}")

    head = ref.get("headline") or {}
    if not head.get("speedup_x") or head["speedup_x"] <= 1.0:
        errors.append("reference headline speedup missing or <= 1.0x")
    elif not head.get("loss_within_tol"):
        errors.append("reference headline reached speedup outside the "
                      "loss tolerance")
    else:
        print(f"headline: {head['scenario']} "
              f"{head['speedup_x']:.2f}x at matched loss [ok]")

    contention = {c["scenario"]: c for c in ref.get("contention", [])}
    for name in CONTENTION_SCENARIOS:
        c = contention.get(name)
        if c is None:
            errors.append(f"{name}: no contention-summary row in the "
                          "reference (speedups unresolvable or scenario "
                          "dropped)")
        elif not c.get("gap_widened"):
            errors.append(f"{name}: fp32-vs-1bit gap did NOT widen over "
                          f"{c['isolated_baseline']}")
        else:
            print(f"contention: {name} {c['speedup_x']:.2f}x vs "
                  f"isolated {c['isolated_speedup_x']:.2f}x [ok]")

    n_fusion = 0
    if os.path.exists(args.fusion_smoke):
        with open(args.fusion_smoke) as f:
            fusion_smoke = json.load(f)
        if not os.path.exists(args.fusion_ref):
            errors.append(f"fusion smoke exists but reference "
                          f"{args.fusion_ref} is missing")
        else:
            with open(args.fusion_ref) as f:
                fusion_ref = json.load(f)
            check_fusion(fusion_smoke, fusion_ref, args.tol, errors)
            n_fusion = len({r["model"] for r in fusion_smoke["table"]})

    n_mem = 0
    if os.path.exists(args.mem_smoke):
        with open(args.mem_smoke) as f:
            mem_smoke = json.load(f)
        if not os.path.exists(args.mem_ref):
            errors.append(f"memory smoke exists but reference "
                          f"{args.mem_ref} is missing")
        else:
            with open(args.mem_ref) as f:
                mem_ref = json.load(f)
            check_memory(mem_smoke, mem_ref, errors)
            n_mem = len(mem_smoke["table"])

    n_overlap = 0
    if os.path.exists(args.overlap_smoke):
        with open(args.overlap_smoke) as f:
            overlap_smoke = json.load(f)
        if not os.path.exists(args.overlap_ref):
            errors.append(f"overlap smoke exists but reference "
                          f"{args.overlap_ref} is missing")
        else:
            with open(args.overlap_ref) as f:
                overlap_ref = json.load(f)
            check_overlap(overlap_smoke, overlap_ref, args.tol, errors)
            n_overlap = len(overlap_smoke["table"])

    n_hier = 0
    if os.path.exists(args.hier_smoke):
        with open(args.hier_smoke) as f:
            hier_smoke = json.load(f)
        if not os.path.exists(args.hier_ref):
            errors.append(f"hierarchy smoke exists but reference "
                          f"{args.hier_ref} is missing")
        else:
            with open(args.hier_ref) as f:
                hier_ref = json.load(f)
            check_hierarchical(hier_smoke, hier_ref, args.tol, errors)
            n_hier = len(hier_smoke.get("bitexact", []))

    n_elastic = 0
    if os.path.exists(args.elastic_smoke):
        with open(args.elastic_smoke) as f:
            elastic_smoke = json.load(f)
        if not os.path.exists(args.elastic_ref):
            errors.append(f"elastic smoke exists but reference "
                          f"{args.elastic_ref} is missing")
        else:
            with open(args.elastic_ref) as f:
                elastic_ref = json.load(f)
            check_elastic(elastic_smoke, elastic_ref, errors)
            n_elastic = len(elastic_smoke.get("bitexact", []))

    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        print(f"bench check OK ({len(smoke_scenarios)} scenarios, "
              f"{n_fusion} fusion models, {n_mem} memory rows, "
              f"{n_overlap} overlap cells, {n_hier} hierarchy rows, "
              f"{n_elastic} elastic rows compared)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
