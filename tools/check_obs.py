#!/usr/bin/env python
"""CI gate for the observability pipeline: validate run logs, fail on alias.

    PYTHONPATH=src python tools/check_obs.py RUNLOG.jsonl [more.jsonl ...] \
        [--trace trace.json] [--require-telemetry] [--allow-alias]

Exit non-zero if any run log fails ``repro.obs.runlog`` schema validation,
any supplied Chrome trace is structurally invalid, or (unless
``--allow-alias``) any run log records a modulo alias event — an alias in
a smoke run means the theta configuration violates Lemma 1's hypothesis
and the build must not ship it silently.  ``--require-telemetry``
additionally fails logs whose step records carry no ``obs_*`` metrics
(catches a CI job that forgot to turn the flag on).
``--min-participation F`` fails any log whose recorded
``obs_participation`` falls below ``F`` at any step — the elastic-rounds
floor: churn beyond the configured budget must not pass CI silently.

``tools/obs_report.py`` is the human-facing twin; this one only gates.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import runlog as RL  # noqa: E402
from repro.obs import trace as TR  # noqa: E402


def check_runlog(path: str, require_telemetry: bool,
                 allow_alias: bool, min_participation: float = 0.0) -> list:
    errors = RL.validate_runlog(path)
    if errors:
        return errors
    records = RL.read_runlog(path)
    steps = RL.step_records(records)
    if min_participation > 0.0:
        part = [r["metrics"]["obs_participation"] for r in steps
                if isinstance(r.get("metrics"), dict)
                and isinstance(r["metrics"].get("obs_participation"),
                               (int, float))]
        low = [v for v in part if v < min_participation]
        if low:
            errors.append(
                f"{path}: participation fell to {min(low):.4g} "
                f"(floor {min_participation}) in {len(low)} step "
                "record(s) — churn exceeded the elastic budget")
    if require_telemetry:
        has_obs = any(k.startswith("obs_")
                      for r in steps
                      if isinstance(r.get("metrics"), dict)
                      for k in r["metrics"])
        if not has_obs:
            errors.append(f"{path}: --require-telemetry but no obs_* "
                          "metrics in any step record (telemetry flag off?)")
    if not allow_alias:
        aliases = RL.alias_events(records)
        if aliases:
            errors.append(
                f"{path}: {aliases} modulo alias events recorded — theta "
                "is undersized for this run (Lemma 1 hypothesis violated); "
                "a smoke run must be alias-free")
    return errors


def check_trace(path: str) -> list:
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    return [f"{path}: {e}" for e in TR.validate_chrome(obj)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("runlogs", nargs="*", help="runlog JSONL files to gate")
    ap.add_argument("--trace", action="append", default=[],
                    help="Chrome-trace JSON files to validate")
    ap.add_argument("--require-telemetry", action="store_true",
                    help="fail logs with no obs_* step metrics")
    ap.add_argument("--allow-alias", action="store_true",
                    help="do not fail on recorded alias events (for "
                         "deliberately-undersized-theta experiments)")
    ap.add_argument("--min-participation", type=float, default=0.0,
                    help="fail if obs_participation drops below this floor "
                         "at any logged step (0 = no floor)")
    args = ap.parse_args(argv)
    if not args.runlogs and not args.trace:
        ap.error("nothing to check: pass runlog files and/or --trace")
    errors = []
    for path in args.runlogs:
        errors.extend(check_runlog(path, args.require_telemetry,
                                   args.allow_alias,
                                   args.min_participation))
    for path in args.trace:
        errors.extend(check_trace(path))
    for e in errors:
        print(f"check_obs: FAIL: {e}")
    if not errors:
        n = len(args.runlogs) + len(args.trace)
        print(f"check_obs: OK ({n} artifact(s) validated, alias-free)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
