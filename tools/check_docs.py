#!/usr/bin/env python3
"""Docs link/symbol checker: every code path referenced in README.md and
docs/*.md must exist in the repo.

Checked references are inline code spans (`...`) that look like repo paths:

* ``src/repro/comm/engine.py`` — file must exist;
* ``benchmarks/`` — directory must exist;
* ``src/repro/kernels/ops.py::moniqua_encode`` /
  ``tests/test_engine.py::test_x`` — file must exist AND define the symbol
  (its last ``.``-component appears as a word in the file);
* ``BENCH_network_sim.json`` — repo-root benchmark artifacts (the
  ``BENCH_*.json`` perf trajectory) must exist at the repo root, as must
  referenced repo-root support files (``requirements*.txt``).

Run from anywhere:  python tools/check_docs.py   (exit 1 on any dangling
reference; listed one per line).  Wired into CI and tests/test_docs.py.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md")) if os.path.isdir(os.path.join(REPO, "docs")) else ["README.md"]

# a span is a candidate repo path if it starts at a known root or is a
# bare *.py/*.md name; everything else (shell snippets, math, flags) skipped
ROOTS = ("src/", "docs/", "tests/", "benchmarks/", "examples/", "tools/",
         ".github/")
SPAN_RE = re.compile(r"`([^`\n]+)`")
BENCH_RE = re.compile(
    r"^(BENCH_\w+\.json|RUNLOG_\w+\.jsonl|requirements[\w.-]*\.txt)$")

# artifacts the docs promise and CI gates on: these must EXIST in the repo
# even if no markdown span happens to reference them — a deleted trajectory
# file or doc page fails here, not silently at the next bench run
REQUIRED_ARTIFACTS = (
    "docs/codecs.md",
    "docs/simulator.md",
    "docs/kernels.md",
    "docs/observability.md",
    "docs/elasticity.md",
    "BENCH_network_sim.json",
    "BENCH_comm_fusion.json",
    "BENCH_memory_overhead.json",
    "BENCH_overlap.json",
    "BENCH_hierarchical.json",
    "BENCH_elastic.json",
    "RUNLOG_sample.jsonl",
)


def candidate(span: str) -> str | None:
    token = span.strip().split()[0] if span.strip() else ""
    if not token or any(c in token for c in "<>*$(){}="):
        return None
    if token.startswith(ROOTS) or BENCH_RE.match(token):
        return token
    return None


def check_file(md_path: str) -> list[str]:
    errors = []
    text = open(os.path.join(REPO, md_path)).read()
    # markdown hard-wraps can split a span across lines; rejoin before scan
    text = re.sub(r"([^`\n])\n([^`\n])", r"\1 \2", text)
    for span in SPAN_RE.findall(text):
        token = candidate(span)
        if token is None:
            continue
        path, _, symbol = token.partition("::")
        path = path.rstrip("/").rstrip(".,;:")
        full = os.path.join(REPO, path)
        if not os.path.exists(full):
            errors.append(f"{md_path}: `{token}` -> missing path {path}")
            continue
        if symbol and os.path.isfile(full):
            leaf = symbol.strip().split(".")[-1].split("(")[0].strip()
            src = open(full).read()
            if leaf and not re.search(rf"\b{re.escape(leaf)}\b", src):
                errors.append(
                    f"{md_path}: `{token}` -> no symbol {leaf!r} in {path}")
    return errors


def main() -> int:
    errors = []
    for artifact in REQUIRED_ARTIFACTS:
        if not os.path.exists(os.path.join(REPO, artifact)):
            errors.append(f"required artifact missing: {artifact}")
    for md in DOC_FILES:
        if os.path.exists(os.path.join(REPO, md)):
            errors.extend(check_file(md))
    for e in errors:
        print(e)
    if not errors:
        print(f"docs check OK ({len(DOC_FILES)} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
