"""C4 / Table 2: extreme bit budgets (1 and 2 bits per parameter).

Table-2 analog on the tiny LM: DCD/ECD diverge, Choco/DeepSqueeze converge
but pay Theta(md)/Theta(nd) extra memory, Moniqua converges with zero extra
memory (Theorem 3's slack matrix for the coarse budgets).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from benchmarks import common as C
from repro.core.algorithms import get_algorithm

ALGOS = ["dcd", "ecd", "choco", "deepsqueeze", "moniqua"]


def run(quick: bool = False) -> dict:
    steps = 25 if quick else 60
    model = C.tiny_lm()
    n = 8

    # per-worker extra memory accounting (Table 1/2 of the paper)
    import jax
    params = model.init(jax.random.PRNGKey(0))
    X = {"p": jnp.stack([jnp.zeros(sum(
        int(jnp.size(l)) for l in jax.tree.leaves(params)))] * n)}

    rows = []
    for bits in (1, 2):
        for algo in ALGOS:
            kw = dict(bits=bits, steps=steps, model=model, n_workers=n)
            if algo == "moniqua":
                kw.update(theta=0.25, slack=0.2)
            if algo in ("choco", "deepsqueeze"):
                # consensus step tuned as in both baselines' papers; at 1 bit
                # they use the biased scaled-sign compressor (Table 1:
                # "supports biased quantizers" = Yes), DCD/ECD may not
                kw.update(gamma=0.2)
            r = C.train_run(algo, **kw)
            hp = C.default_hyper(bits=bits, n=n,
                                 stochastic=False if bits == 1 else None)
            extra_mb = get_algorithm(algo).extra_memory_bytes(X, hp) / 1e6
            diverged = (not math.isfinite(r["loss_last"])
                        or r["loss_last"] > r["loss_first"] * 1.05)
            rows.append({
                "budget": f"{bits}bit", "algorithm": algo,
                "loss_last": r["loss_last"],
                "status": "diverge" if diverged else "converge",
                "extra_memory_MB_per_worker": extra_mb,
            })
    moni = [r for r in rows if r["algorithm"] == "moniqua"]
    assert all(r["extra_memory_MB_per_worker"] == 0.0 for r in moni)
    return {
        "table": rows,
        "notes": ("Table 2 analog (tiny LM, synthetic tokens): DCD/ECD "
                  "require UNBIASED quantizers (Table 1) and diverge at "
                  "extreme budgets; Choco/DeepSqueeze admit the biased "
                  "scaled-sign compressor and converge, paying "
                  "Theta(md)/Theta(nd) extra memory; Moniqua converges with "
                  "ZERO extra memory via the Theorem-3 slack matrix."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
