"""C2 / Theorem 2 + Corollary 1: Moniqua converges per-iteration at the
D-PSGD rate.  Trains the tiny LM under every algorithm with identical data
and reports the loss trajectory (Fig. 1's per-epoch panel analog).
"""
from __future__ import annotations

from benchmarks import common as C

ALGOS = [("allreduce", 32), ("dpsgd", 32), ("moniqua", 8), ("choco", 8),
         ("deepsqueeze", 8), ("dcd", 8), ("ecd", 8)]


def run(quick: bool = False) -> dict:
    steps = 30 if quick else 80
    model = C.tiny_lm()
    rows, curves = [], {}
    for algo, bits in ALGOS:
        r = C.train_run(algo, bits=min(bits, 8), theta=2.0,
                        gamma=0.3 if algo in ("choco", "deepsqueeze") else 1.0,
                        steps=steps, model=model)
        rows.append({
            "algorithm": algo, "wire_bits": bits,
            "loss_first": r["loss_first"], "loss_last": r["loss_last"],
            "bytes_per_step": r["bytes_per_step"],
        })
        curves[algo] = [(h["step"], h["loss"]) for h in r["history"]]
    fp = next(r for r in rows if r["algorithm"] == "dpsgd")["loss_last"]
    mq = next(r for r in rows if r["algorithm"] == "moniqua")["loss_last"]
    return {
        "table": rows,
        "curves": curves,
        "moniqua_vs_dpsgd_gap": (mq - fp) / fp,
        "notes": ("Identical data/seeds across algorithms; Moniqua's "
                  "final loss is within a few percent of full-precision "
                  "D-PSGD at 1/4 the wire bytes (C2)."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
