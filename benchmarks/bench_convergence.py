"""C2 / Theorem 2 + Corollary 1: Moniqua converges per-iteration at the
D-PSGD rate.  Trains the tiny LM under every algorithm with identical data
and reports the loss trajectory (Fig. 1's per-epoch panel analog).

The sweep includes the error-feedback wire family (``ef_qsgd`` at 4 bits,
``onebit`` post-warmup) riding the same gossip rule, so the convergence
side of the memory-vs-bits trade in ``BENCH_memory_overhead.json`` is
measured on the identical data stream as the zero-memory Moniqua wire.
"""
from __future__ import annotations

from benchmarks import common as C

# (label, algorithm, wire, bits, extra train_run kwargs)
ALGOS = [
    ("allreduce", "allreduce", "moniqua", 32, {}),
    ("dpsgd", "dpsgd", "moniqua", 32, {}),
    ("moniqua", "moniqua", "moniqua", 8, {}),
    ("choco", "choco", "moniqua", 8, {}),
    ("deepsqueeze", "deepsqueeze", "moniqua", 8, {}),
    ("dcd", "dcd", "moniqua", 8, {}),
    ("ecd", "ecd", "moniqua", 8, {}),
    # EF codec family: same moniqua gossip rule, stateful wires; onebit's
    # short warmup keeps most of the measured run in the 1-bit regime
    ("ef_qsgd-4bit", "moniqua", "ef_qsgd", 4, {}),
    ("onebit", "moniqua", "onebit", 1, {"warmup": 8}),
]


def run(quick: bool = False) -> dict:
    steps = 30 if quick else 80
    model = C.tiny_lm()
    rows, curves = [], {}
    for label, algo, wire, bits, kw in ALGOS:
        r = C.train_run(algo, bits=min(bits, 8), theta=2.0, wire=wire,
                        gamma=0.3 if algo in ("choco", "deepsqueeze") else 1.0,
                        steps=steps, model=model, **kw)
        rows.append({
            "algorithm": label, "wire": wire, "wire_bits": bits,
            "loss_first": r["loss_first"], "loss_last": r["loss_last"],
            "bytes_per_step": r["bytes_per_step"],
        })
        curves[label] = [(h["step"], h["loss"]) for h in r["history"]]
    fp = next(r for r in rows if r["algorithm"] == "dpsgd")["loss_last"]
    mq = next(r for r in rows if r["algorithm"] == "moniqua")["loss_last"]
    return {
        "table": rows,
        "curves": curves,
        "moniqua_vs_dpsgd_gap": (mq - fp) / fp,
        "notes": ("Identical data/seeds across algorithms; Moniqua's "
                  "final loss is within a few percent of full-precision "
                  "D-PSGD at 1/4 the wire bytes (C2).  ef_qsgd/onebit "
                  "rows show what the EF wires' Theta(nd) residual "
                  "memory buys in convergence terms."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
