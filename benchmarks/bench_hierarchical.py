"""Two-tier hierarchical gossip: slow-axis bytes and wall-clock-to-target.

Three parts, all machine-independent (shape math + the seeded ``repro.sim``
event engine — reproducible bit-for-bit):

1. **Bit-exactness rows** (always): a tiered round with a *trivial* intra
   tier (``two_tier(n, 1)``) must be bitwise identical to the single-tier
   bucketed round on the inter topology — outputs AND WireState carries —
   for all five wires on both backends, over iterated rounds.  This is the
   correctness contract that lets the tiered engine share the single-tier
   theory (theta bounds, EF residual analysis); ``tools/check_bench.py``
   gates every row on the committed artifact.

2. **Slow-axis accounting** (always): at a >= 70B-param proxy config
   (abstract ``ShapeDtypeStruct`` trees — the engine's layout and byte
   accounting never materialise the model), n=32 workers in nodes of
   n_intra=4, each worker gossips only its *owned shard* on the slow
   inter axis, so slow-axis bytes drop ~n_intra-fold on top of the 1-bit
   Moniqua quantization.  Gate: ``slow_tiered / slow_single <= 1/n_intra
   + eps``.

3. **Wall-clock-to-target** (always): the ``two-tier-tor`` fabric prices
   both rounds' slow-axis flows on the same oversubscribed uplinks
   (contiguous placement — the placement most *favorable* to the flat
   baseline); the intra reduce-scatter/all-gather is priced analytically
   at NIC rate.  Per-uplink bytes per round are nearly equal (the shard
   lanes ship 1/n_intra of the buffer over n_intra-fold more boundary
   crossings), so the win is mixing speed: the inter ring(8) mixes in
   ``t_mix <= log(4n)/(1-rho)`` ~ 25 rounds where the flat ring(32)
   needs ~380.  Headline: two-tier wall-clock-to-target under single-tier
   1-bit on the same fabric.

    PYTHONPATH=src python benchmarks/bench_hierarchical.py          # full
    PYTHONPATH=src python benchmarks/bench_hierarchical.py --smoke  # CI

Writes ``BENCH_hierarchical.json`` at the repo root
(``BENCH_hierarchical.smoke.json`` under ``--smoke``; the smoke proxy is a
small model, so raw byte counts differ — the gated *ratios* do not).
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import argparse
import dataclasses
import json
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.comm.engine import CommEngine, make_wire
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring, two_tier
from repro.sim import events as SE
from repro.sim.scenarios import get_scenario

# -- part 1: trivial-tier bit-exactness -------------------------------------

BITEXACT_N = 8          # inter workers (trivial intra tier of size 1)
BITEXACT_ROUNDS = 3     # iterated so WireState carries propagate
THETA = 2.0
WIRES = [("full", 32), ("moniqua", 2), ("qsgd", 4), ("ef_qsgd", 4),
         ("onebit", 1)]
BACKENDS = ("jnp", "pallas")


def _wire(name: str, bits: int):
    spec = QuantSpec(bits=min(bits, 8), stochastic=1 < bits <= 8)
    return make_wire(name, spec)


def _bitexact_tree(n: int) -> Dict[str, jax.Array]:
    """Multi-leaf, mixed-shape stack so shard/bucket edges get exercised."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    return {
        "a": 0.5 * jax.random.normal(k1, (n, 37), jnp.float32),
        "b": 0.5 * jax.random.normal(k2, (n, 5, 11), jnp.float32),
        "c": 0.5 * jax.random.normal(k3, (n, 3), jnp.float32),
    }


def _trees_equal(x, y) -> bool:
    xs, ys = jax.tree.leaves(x), jax.tree.leaves(y)
    return len(xs) == len(ys) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(xs, ys))


def bitexact_rows() -> List[Dict[str, Any]]:
    rows = []
    X0 = _bitexact_tree(BITEXACT_N)
    keys = jax.random.split(jax.random.PRNGKey(1), BITEXACT_ROUNDS)
    for wire_name, bits in WIRES:
        for backend in BACKENDS:
            single = CommEngine(ring(BITEXACT_N), _wire(wire_name, bits),
                                backend, path="bucketed")
            tiered = CommEngine(two_tier(BITEXACT_N, 1),
                                _wire(wire_name, bits), backend)
            Xs = Xt = X0
            ss = single.init_wire_state(X0) if single.stateful else None
            st = tiered.init_wire_state(X0) if tiered.stateful else None
            ok = True
            for t in range(BITEXACT_ROUNDS):
                rs = single.mix(Xs, theta=THETA, key=keys[t], state=ss)
                rt = tiered.mix(Xt, theta=THETA, key=keys[t], state=st)
                ok = ok and _trees_equal(rs.x, rt.x)
                if single.stateful:
                    ok = ok and _trees_equal(rs.state, rt.state)
                Xs, Xt, ss, st = rs.x, rt.x, rs.state, rt.state
            rows.append({
                "wire": wire_name, "bits": bits, "backend": backend,
                "stateful": single.stateful, "rounds": BITEXACT_ROUNDS,
                "bitexact": bool(ok),
            })
    return rows


# -- parts 2+3: >= 70B proxy accounting + simulated wall-clock --------------

N, N_INTRA = 32, 4
HEADLINE_BITS = 1         # the paper's 1-bit Moniqua wire on the slow axis
SLOW_RATIO_EPS = 1e-3
SIM_SCENARIO = "two-tier-tor"


def proxy_tree(n: int, *, d: int, d_ff: int, vocab: int, layers: int):
    """Abstract llama-style stacked param tree (shapes only, never allocated).

    Layer stacks are scanned (leading ``layers`` dim inside the leaf), so
    the tree stays at 5 leaves regardless of depth.
    """
    S = lambda *shape: jax.ShapeDtypeStruct((n,) + shape, jnp.float32)
    return {
        "embed": S(vocab, d),
        "attn_qkvo": S(layers, 4 * d * d),
        "mlp": S(layers, 3 * d * d_ff),
        "final_norm": S(d),
        "lm_head": S(vocab, d),
    }


# ~80e9 params: llama-70B-class widths (d=8192, ff=28672, vocab=128256, 80L)
FULL_PROXY = dict(d=8192, d_ff=28672, vocab=128256, layers=80)
# smoke proxy: same shape family, ~1.7e8 params — ratios are identical
SMOKE_PROXY = dict(d=1024, d_ff=2816, vocab=32000, layers=8)


def _params(X) -> int:
    return sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(X))


def accounting_and_walltime(proxy: Dict[str, int], label: str,
                            sim_rounds: int) -> Dict[str, Any]:
    X = proxy_tree(N, **proxy)
    d = _params(X)
    wire = _wire("moniqua", HEADLINE_BITS)
    single = CommEngine(ring(N), wire, "jnp", path="bucketed")
    tiered = CommEngine(two_tier(N, N_INTRA), wire, "jnp")

    m_single = len(single.gossip_topo.neighbor_offsets())
    m_tiered = len(tiered.gossip_topo.neighbor_offsets())
    slow_single = single.payload_bytes_per_broadcast(X) * m_single
    slow_tiered = tiered.payload_bytes_per_broadcast(X) * m_tiered
    fast_tiered = tiered.fast_bytes_per_round(X)

    # wall-clock: slow-axis flows through the contended two-tier fabric,
    # intra phase at NIC rate (ICI never touches the uplinks)
    sc = get_scenario(SIM_SCENARIO, n=N)
    sc_flat = dataclasses.replace(sc, topo=ring(N),
                                  name=f"{SIM_SCENARIO}-flat-ring")
    tr_tier = SE.simulate_sync_rounds(
        sc, tiered.payload_bytes_per_broadcast(X), sim_rounds)
    tr_flat = SE.simulate_sync_rounds(
        sc_flat, single.payload_bytes_per_broadcast(X), sim_rounds)
    fast_s = fast_tiered / sc.fabric.nic_Bps
    round_tiered_s = tr_tier.mean_round_seconds + fast_s
    round_single_s = tr_flat.mean_round_seconds

    # rounds to a fixed consensus target: the reversible-chain mixing bound
    # t_mix <= log(4n)/(1-rho) — the quantity Moniqua's Theorem 1 pays per
    # unit of; identical loss target => rounds ratio = t_mix ratio
    rounds_tiered = tiered.topo.t_mix_bound
    rounds_single = single.topo.t_mix_bound

    wall_tiered = round_tiered_s * rounds_tiered
    wall_single = round_single_s * rounds_single
    return {
        "config": label, "params": d, "n": N, "n_intra": N_INTRA,
        "wire": "moniqua", "bits": HEADLINE_BITS,
        "slow_bytes_single": int(slow_single),
        "slow_bytes_tiered": int(slow_tiered),
        "fast_bytes_tiered": int(fast_tiered),
        "slow_bytes_ratio": slow_tiered / slow_single,
        "slow_reduction_x": slow_single / slow_tiered,
        "rho_single": single.topo.rho, "rho_tiered": tiered.topo.rho,
        "rounds_single": rounds_single, "rounds_tiered": rounds_tiered,
        "round_s_single": round_single_s, "round_s_tiered": round_tiered_s,
        "wall_to_target_s_single": wall_single,
        "wall_to_target_s_tiered": wall_tiered,
        "speedup_x": wall_single / wall_tiered,
    }


def _assert_invariants(result: Dict[str, Any], smoke: bool) -> None:
    """The invariants check_bench.py re-verifies on the committed artifact;
    asserted here too so a bad table can never even be written."""
    for r in result["bitexact"]:
        assert r["bitexact"], f"trivial-tier round NOT bit-exact: {r}"
    for r in result["table"]:
        assert r["slow_bytes_ratio"] <= 1.0 / r["n_intra"] + SLOW_RATIO_EPS, r
        assert r["speedup_x"] > 1.0, r
    if not smoke:
        assert result["headline"]["params"] >= 70e9, result["headline"]


def run(quick: bool = False, smoke: bool = False) -> dict:
    proxy, label = ((SMOKE_PROXY, "smoke-proxy") if (quick or smoke)
                    else (FULL_PROXY, "llama70b-proxy"))
    sim_rounds = 2 if (quick or smoke) else 4
    row = accounting_and_walltime(proxy, label, sim_rounds)
    result = {
        "bitexact": bitexact_rows(),
        "table": [row],
        "headline": row,
        "notes": (
            f"two-tier gossip, n={N} in nodes of {N_INTRA} "
            f"(inter ring({N // N_INTRA}) x intra all-to-all): each worker "
            "ships only its owned shard on the slow axis, so slow-axis "
            "bytes shrink ~n_intra-fold on top of 1-bit Moniqua; the "
            f"{SIM_SCENARIO} fabric prices both schedules' uplink "
            "contention (contiguous placement, favorable to the flat "
            "baseline) and rounds-to-target use the log(4n)/(1-rho) "
            "mixing bound — the two-tier win is rho(ring(n/k)) << "
            "rho(ring(n)), not fewer uplink bytes per round."),
    }
    _assert_invariants(result, quick or smoke)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small proxy model + fewer sim rounds (gated "
                         "ratios are model-size-independent)")
    ap.add_argument("--out", default=None,
                    help="output path; defaults to BENCH_hierarchical.json "
                         "at the repo root (.smoke.json under --smoke, so "
                         "a smoke run never clobbers the committed "
                         "trajectory)")
    args = ap.parse_args(argv)
    if args.out is None:
        name = ("BENCH_hierarchical.smoke.json" if args.smoke
                else "BENCH_hierarchical.json")
        args.out = os.path.join(_ROOT, name)
    result = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, default=float)
    ok = sum(1 for r in result["bitexact"] if r["bitexact"])
    print(f"wrote {args.out} ({ok}/{len(result['bitexact'])} bitexact rows, "
          f"{len(result['table'])} accounting rows)")
    print(C.markdown_table(result["table"],
                           ["config", "params", "n_intra",
                            "slow_reduction_x", "rounds_single",
                            "rounds_tiered", "speedup_x"]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
