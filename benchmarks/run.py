"""Benchmark orchestrator — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-speed
    PYTHONPATH=src python -m benchmarks.run --only bench_low_bit

Each bench maps to a paper artifact:

    bench_naive_floor       Theorem 1   (naive quantization floor)
    bench_convergence       Fig. 1 loss panel / Theorem 2 (rate parity)
    bench_walltime          Fig. 1 wall-clock under 4 network configs
    bench_low_bit           Table 2     (1/2-bit budgets + memory)
    bench_memory_overhead   Table 1     (additional memory accounting)
    bench_d2_hetero         Fig. 2a     (D^2 / decentralized data)
    bench_adpsgd            Fig. 2b     (asynchronous gossip)
    bench_bits_bound        Sec. 4      (O(log log n) bits bound)
    bench_network_sim       Fig. 5 analog (repro.sim wall-clock-to-target)
    bench_comm_fusion       per-leaf vs bucketed flat-buffer mix timing
    roofline_table          deliverable g (dry-run roofline terms)

Writes benchmarks/results/<name>.json and a combined markdown report to
benchmarks/results/REPORT.md (consumed by EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
import traceback

from benchmarks import common as C

BENCHES = [
    "bench_naive_floor",
    "bench_convergence",
    "bench_walltime",
    "bench_low_bit",
    "bench_memory_overhead",
    "bench_d2_hetero",
    "bench_adpsgd",
    "bench_bits_bound",
    "bench_network_sim",
    "bench_comm_fusion",
    "roofline_table",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced step counts (CI)")
    ap.add_argument("--only", default=None, help="run one benchmark")
    args = ap.parse_args(argv)

    names = [args.only] if args.only else BENCHES
    report = ["# Benchmark report", ""]
    failures = 0
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            result = mod.run(quick=args.quick)
            result["seconds"] = time.time() - t0
            path = C.save_result(name, result)
            tables = [("table", result.get("table", []))]
            tables += [(k, v) for k, v in result.items()
                       if k != "table" and k.endswith("_table") and v]
            for tname, rows in tables:
                if tname != "table":
                    print(f"-- {tname} --")
                print(C.markdown_table(rows))
            print(f"notes: {result.get('notes','')}")
            print(f"[{name}] done in {result['seconds']:.1f}s -> {path}\n")
            report += [f"## {name}", ""]
            for tname, rows in tables:
                if tname != "table":
                    report += [f"### {tname}", ""]
                report += [C.markdown_table(rows), ""]
            report += [result.get("notes", ""), ""]
        except Exception as e:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            report += [f"## {name}", "", f"FAILED: {e}", ""]
    os.makedirs(C.RESULTS_DIR, exist_ok=True)
    with open(os.path.join(C.RESULTS_DIR, "REPORT.md"), "w") as f:
        f.write("\n".join(report))
    print(f"benchmarks complete; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
