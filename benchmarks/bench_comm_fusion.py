"""Per-round mix cost: per-leaf gossip vs bucketed flat-buffer gossip.

The paper's headline is wall-clock speed, and the mix step is where the
engine spends it.  The per-leaf path pays a fixed cost per pytree leaf —
one encode launch, one decode-reduce launch, one payload roll per offset,
and one pad to the 256x1024 tile grid (so a 64-element norm scale becomes
>=262k elements of codec work).  The bucketed path (``comm/bucket.py``)
pays each of those once per round on one flat buffer.

This benchmark measures that gap on real model-zoo parameter trees
(resnet / transformer / mamba2 / moe) x wire codec x bit width, on the
jitted jnp backend of this host, and records the *dispatch/padding
overhead model* behind it: leaves, real elements, tile-padded elements,
and codec launches per round for both paths.  ``BENCH_comm_fusion.json``
is the committed trajectory; ``tools/check_bench.py`` gates the bucketed
speedup per model against it in CI.

Usage:  python benchmarks/bench_comm_fusion.py [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp

from repro.comm import bucket
from repro.comm.engine import CommEngine, make_wire
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.kernels.moniqua_encode import (DEFAULT_BLOCK_COLS,
                                          DEFAULT_BLOCK_ROWS)

N_WORKERS = 8

# (label, wire, bits): the fusion-relevant slice of the codec matrix — the
# 1-bit headline (fixed costs dominate tiny payloads), the 8-bit midpoint,
# the scale+codes comparison, and the raw wire.
CODECS = [
    ("moniqua-1bit", "moniqua", 1),
    ("moniqua-8bit", "moniqua", 8),
    ("qsgd-8bit", "qsgd", 8),
    ("fp32", "full", 32),
]


# ---------------------------------------------------------------------------
# Model zoo parameter trees (single replica; stacked to [n, ...] below).
# ---------------------------------------------------------------------------

def _zoo():
    from repro.configs import get_config
    from repro.models import resnet as R
    from repro.models.model_factory import build_model

    def resnet(key):
        return R.init_resnet(key, depth=20, width=16)

    def transformer(key):
        return build_model(get_config("llama3.2-3b").reduced()).init(key)

    def mamba2(key):
        # zamba2 reduced: a stack of mamba2 blocks + one shared attention
        return build_model(get_config("zamba2-1.2b").reduced()).init(key)

    def moe(key):
        return build_model(get_config("dbrx-132b").reduced()).init(key)

    return [("resnet", resnet), ("transformer", transformer),
            ("mamba2", mamba2), ("moe", moe)]


def _stack(params, n=N_WORKERS):
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), params)


# ---------------------------------------------------------------------------
# The static overhead model: what each path launches and pads.
# ---------------------------------------------------------------------------

def _tile_padded(elems: int) -> int:
    """Elements after padding to the Pallas encode tile grid (ops.py)."""
    rows = -(-elems // DEFAULT_BLOCK_COLS)
    return -(-rows // DEFAULT_BLOCK_ROWS) * DEFAULT_BLOCK_ROWS \
        * DEFAULT_BLOCK_COLS


def overhead_model(X, vpb: int) -> dict:
    """Launch and padding accounting for one Moniqua round on ``X``."""
    leaves = jax.tree.leaves(X)
    layout = bucket.layout_of(X, vpb)
    per_leaf_padded = 0
    for s in layout.slots:
        per_leaf_padded += _tile_padded(s.padded_size)
    elems = layout.total_elems
    bucketed_padded = _tile_padded(layout.padded_elems)
    return {
        "n_leaves": len(leaves),
        "elems_per_worker": elems,
        "tile_padded_elems_per_leaf_path": per_leaf_padded,
        "tile_padded_elems_bucketed": bucketed_padded,
        "pad_overhead_per_leaf_x": per_leaf_padded / elems,
        "pad_overhead_bucketed_x": bucketed_padded / elems,
        # encode + decode-reduce per leaf (rolls excluded) vs one of each
        "codec_launches_per_leaf_path": 2 * len(leaves),
        "codec_launches_bucketed": 2,
    }


# ---------------------------------------------------------------------------
# Timing.
# ---------------------------------------------------------------------------

def _time_pair(eng_leaf: CommEngine, eng_bucket: CommEngine, X,
               needs_theta: bool, reps: int) -> tuple[float, float]:
    """Per-round mix time for the per-leaf and bucketed engines.

    The two paths are timed *interleaved*, rep by rep, so scheduler drift
    and frequency scaling hit both equally, and the estimate is the min
    over reps — the speedup the CI gate compares is a ratio of two
    same-host times, and contention noise only ever inflates a sample, so
    the min is the stable estimator of the uncontended round.
    """
    key = jax.random.PRNGKey(0)

    def jit_mix(eng):
        if needs_theta:
            f = jax.jit(lambda x, k: eng.mix(x, theta=2.0, key=k).x)
        else:
            f = jax.jit(lambda x, k: eng.mix(x, key=k).x)
        jax.block_until_ready(f(X, key))        # compile + warm up
        return f

    mixes = (jit_mix(eng_leaf), jit_mix(eng_bucket))
    times = ([], [])
    for _ in range(reps):
        for mix, acc in zip(mixes, times):
            t0 = time.perf_counter()
            jax.block_until_ready(mix(X, key))
            acc.append(time.perf_counter() - t0)
    return min(times[0]), min(times[1])


def run(quick: bool = False) -> dict:
    reps = 5 if quick else 10
    topo = ring(N_WORKERS)
    table, overhead = [], []
    for model_name, init in _zoo():
        X = _stack(init(jax.random.PRNGKey(0)))
        n_leaves = len(jax.tree.leaves(X))
        d = bucket.layout_of(X, 1).total_elems
        overhead.append({"model": model_name,
                         **overhead_model(X, vpb=8)})   # 1-bit grid
        for label, wire, bits in CODECS:
            spec = QuantSpec(bits=min(bits, 8), stochastic=1 < bits <= 8)
            codec = make_wire(wire, spec)
            eng_l = CommEngine(topo, codec, backend="jnp", path="per_leaf")
            eng_b = CommEngine(topo, codec, backend="jnp", path="bucketed")
            needs_theta = wire == "moniqua"
            t_leaf, t_bucket = _time_pair(eng_l, eng_b, X, needs_theta,
                                          reps)
            table.append({
                "model": model_name, "codec": label, "bits": bits,
                "n_leaves": n_leaves, "params_per_worker": d,
                "mix_ms_per_leaf": t_leaf * 1e3,
                "mix_ms_bucketed": t_bucket * 1e3,
                "speedup_x": t_leaf / t_bucket,
                "wire_bytes_per_leaf": eng_l.bytes_per_round(X),
                "wire_bytes_bucketed": eng_b.bytes_per_round(X),
            })

    one_bit = [r for r in table if r["codec"] == "moniqua-1bit"]
    head = max(one_bit, key=lambda r: r["speedup_x"])
    return {
        "table": table,
        "overhead": overhead,
        "headline": {"model": head["model"], "codec": head["codec"],
                     "speedup_x": head["speedup_x"],
                     "mix_ms_per_leaf": head["mix_ms_per_leaf"],
                     "mix_ms_bucketed": head["mix_ms_bucketed"]},
        "backend": "jnp (jitted, this host)",
        "n_workers": N_WORKERS,
        "reps": reps,
        "notes": (
            "Measured per-round CommEngine.mix time, per-leaf vs bucketed "
            "flat-buffer gossip (comm/bucket.py), ring n=8, jitted jnp "
            "backend; the two paths are timed interleaved rep-by-rep and "
            "each reported time is the min over reps (contention noise "
            "only inflates samples). "
            "The 'overhead' section is the static model of why "
            "fusion wins: the per-leaf path pads EVERY leaf to the 256x1024 "
            "Pallas tile grid (min 262,144 elements per launch), so models "
            "with dozens of sub-262k leaves do pad_overhead_per_leaf_x "
            "times the real codec work, plus 2*n_leaves kernel dispatches "
            "per round; the bucketed path pads once and dispatches twice. "
            "Wire bytes match the per-leaf sum for Moniqua by construction "
            "(vpb row alignment) and for qsgd too: the bucketed path keeps "
            "one max-norm scale per tensor (segment slices of the flat "
            "buffer), not one whole-model scale."),
    }


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer reps; write BENCH_comm_fusion.smoke.json")
    args = ap.parse_args()
    out = run(quick=args.smoke)
    name = "BENCH_comm_fusion.smoke.json" if args.smoke \
        else "BENCH_comm_fusion.json"
    path = os.path.join(_ROOT, name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(json.dumps(out["headline"], indent=2, default=float))
    print(f"wrote {path}")
