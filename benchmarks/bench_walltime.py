"""C10 / Figure 1: wall-clock-to-target under four network configurations.

We cannot shape real links in this container (DESIGN §2 change #2); instead
each algorithm's measured per-step wire bytes and message count feed an
analytic network model (bandwidth + latency), plus a local-overhead term for
replica updates / error tracking.  Reported: seconds per step and the
projected time to reach the D-PSGD target loss, per network config.

Part 2 sweeps the *wire codec* through ``CommEngine`` — fp32 / Moniqua at
8/4/1 bits / QSGD-style scale+codes — on the same ResNet20-size payload:
measured on-device mix time (jitted, CPU) + exact payload bytes + projected
step time on each network.  This is the codec-swap surface the engine makes
a one-line change.
"""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.configs import get_config
from repro.core.algorithms import get_algorithm

# per-step message count per worker: one per neighbor (2 on a ring), except
# AllReduce which does 2 log2(n) phases of the ring-allreduce
MSGS = {"allreduce": 6.0, "dpsgd": 2.0, "naive": 2.0, "moniqua": 2.0,
        "choco": 2.0, "deepsqueeze": 2.0, "dcd": 2.0, "ecd": 2.0}

ALGOS = ["allreduce", "dpsgd", "moniqua", "choco", "deepsqueeze", "dcd",
         "ecd"]

N_WORKERS = 8
D_PARAMS = 272_474                      # ResNet20 parameter count


def _algorithm_rows(X, grad_seconds: float):
    rows = []
    for algo_name in ALGOS:
        algo = get_algorithm(algo_name)
        hp = C.default_hyper(bits=8, n=N_WORKERS)
        wire = algo.bytes_per_step(X, hp)
        local = (C.LOCAL_OVERHEAD_COPIES[algo_name] * D_PARAMS * 4
                 / C.HOST_COPY_BW)
        row = {"algorithm": algo_name, "wire_bytes_per_step": wire,
               "extra_local_s": local}
        for net in C.NETWORKS:
            comm = net.step_comm_seconds(wire, MSGS[algo_name])
            row[f"s/step {net.name}"] = grad_seconds + local + comm
        rows.append(row)
    return rows


def _codec_rows(X, grad_seconds: float, quick: bool):
    """Sweep wire codecs through CommEngine on the same payload.

    Each network column appears twice: the closed-form analytic model
    (``measured`` mix time + bytes/bandwidth + 2 messages * latency) and
    the ``repro.sim`` event-engine prediction for the same bytes (sender
    NIC serialization, latencies overlapped).  The sim is slightly
    cheaper per step by ~1 message latency — the overlap the closed form
    cannot express; agreement within that margin is the predicted-vs-
    measured check.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.topology import ring
    from repro.sim import events as SE
    from repro.sim import scenarios as SC

    rows = []
    reps = 2 if quick else 5
    topo = ring(N_WORKERS)
    m = len(topo.neighbor_offsets())
    for label, wire, bits in C.ENGINE_CODECS:
        eng = C.build_engine(wire, bits, n=N_WORKERS)
        wire_bytes = eng.bytes_per_round(X)
        key = jax.random.PRNGKey(0)
        mix = jax.jit(lambda x, k: eng.mix(x, theta=2.0, key=k).x)
        out = mix(X, key)                       # compile + warm up
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(mix(X, key))
        mix_s = (time.time() - t0) / reps
        row = {"codec": label, "wire_bytes_per_step": wire_bytes,
               "mix_ms_measured": mix_s * 1e3,
               "vs_fp32_bytes": wire_bytes / (D_PARAMS * 4 * 2)}
        for net in C.NETWORKS:
            comm = net.step_comm_seconds(wire_bytes, 2.0)
            row[f"s/step {net.name}"] = grad_seconds + mix_s + comm
            sc = SC.scenario_from_netconfig(net.name, net.bandwidth_bps,
                                            net.latency_s, topo,
                                            compute_s=grad_seconds + mix_s)
            trace = SE.simulate_sync_rounds(sc, wire_bytes // m,
                                            num_rounds=3)
            row[f"sim s/step {net.name}"] = trace.mean_round_seconds
        slow = C.NETWORKS[-1]
        row["sim_vs_analytic"] = (row[f"sim s/step {slow.name}"]
                                  / row[f"s/step {slow.name}"])
        # contended column: same bytes on the oversubscribed-ToR fabric,
        # where concurrent payloads share uplink bandwidth (water-filling)
        sc_tor = SC.get_scenario("oversubscribed-tor", n=N_WORKERS,
                                 compute_s=grad_seconds + mix_s)
        tor = SE.simulate_sync_rounds(sc_tor, wire_bytes // m, num_rounds=3)
        row["sim s/step oversubscribed-tor"] = tor.mean_round_seconds
        rows.append(row)
    return rows


def _calibration_check(codec_rows, grad_seconds: float):
    """Fit alpha/beta back out of this run's own codec table.

    The codec sweep measured 6 payload sizes on each analytic network —
    exactly the probes ``repro.sim.calibrate`` fits.  Fitting the slowest
    network's column must recover its bandwidth (beta = bps/8) and
    two-message latency (alpha = 2 * latency_s): the self-consistency
    check that the calibrated mode reproduces the constants it probed.
    """
    from repro.sim import calibrate as CAL

    net = C.NETWORKS[-1]
    fit = CAL.calibrate_from_walltime({"codec_table": codec_rows}, net.name,
                                      compute_s=grad_seconds)
    true_beta = net.bandwidth_bps / 8.0
    true_alpha = 2.0 * net.latency_s
    return {
        "network": net.name,
        "alpha_fit_s": fit.alpha_s, "alpha_true_s": true_alpha,
        "beta_fit_Bps": fit.beta_Bps, "beta_true_Bps": true_beta,
        "alpha_rel_err": abs(fit.alpha_s - true_alpha) / true_alpha,
        "beta_rel_err": abs(fit.beta_Bps - true_beta) / true_beta,
        "r2": fit.r2,
    }


def run(quick: bool = False) -> dict:
    # ResNet20-scale model: 0.27M params (the paper's Fig. 1 workload)
    import jax.numpy as jnp
    X = {"w": jnp.zeros((N_WORKERS, D_PARAMS), jnp.float32)}
    grad_seconds = 0.05                     # P100 fwd+bwd estimate @bs128

    rows = _algorithm_rows(X, grad_seconds)
    codec_rows = _codec_rows(X, grad_seconds, quick)

    # ranking on the slowest network: Moniqua must beat every baseline
    slow = f"s/step {C.NETWORKS[-1].name}"
    fastest = min(rows, key=lambda r: r[slow])
    fastest_codec = min(codec_rows, key=lambda r: r[slow])
    return {
        "table": rows,
        "codec_table": codec_rows,
        "calibration": _calibration_check(codec_rows, grad_seconds),
        "fastest_on_slow_net": fastest["algorithm"],
        "fastest_codec_on_slow_net": fastest_codec["codec"],
        "notes": ("Analytic network model (DESIGN §2 change #2): "
                  "step time = grad + local overhead + bytes/bandwidth + "
                  "messages*latency, ResNet20-size payloads, ring n=8, "
                  "8-bit budget. Reproduces Fig. 1's ordering: quantized "
                  "algorithms split from full precision as bandwidth drops, "
                  "AllReduce degrades worst with latency, and Moniqua leads "
                  "since it pays no replica/error-tracking overhead. "
                  "codec_table sweeps the CommEngine wire codec (fp32 / "
                  "Moniqua 8/4/1-bit / QSGD 8/4-bit) with measured jitted "
                  "mix time on this host; Moniqua 1-bit ships 1/32 of the "
                  "fp32 bytes with no per-tensor scale overhead. The 'sim "
                  "s/step' columns are the repro.sim event-engine "
                  "predictions for the same bytes (sender NIC "
                  "serialization with overlapped latency); "
                  "sim_vs_analytic ~ 1 on the slowest network is the "
                  "predicted-vs-measured agreement check. The "
                  "'sim s/step oversubscribed-tor' column prices the same "
                  "bytes on a contended ToR fabric (repro.sim.contention); "
                  "'calibration' fits alpha/beta back out of this run's "
                  "own probes via repro.sim.calibrate and reports the "
                  "relative recovery error."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
