"""C10 / Figure 1: wall-clock-to-target under four network configurations.

We cannot shape real links in this container (DESIGN §2 change #2); instead
each algorithm's measured per-step wire bytes and message count feed an
analytic network model (bandwidth + latency), plus a local-overhead term for
replica updates / error tracking.  Reported: seconds per step and the
projected time to reach the D-PSGD target loss, per network config.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.configs import get_config
from repro.core.algorithms import get_algorithm

# per-step message count per worker: one per neighbor (2 on a ring), except
# AllReduce which does 2 log2(n) phases of the ring-allreduce
MSGS = {"allreduce": 6.0, "dpsgd": 2.0, "naive": 2.0, "moniqua": 2.0,
        "choco": 2.0, "deepsqueeze": 2.0, "dcd": 2.0, "ecd": 2.0}

ALGOS = ["allreduce", "dpsgd", "moniqua", "choco", "deepsqueeze", "dcd",
         "ecd"]


def run(quick: bool = False) -> dict:
    # ResNet20-scale model: 0.27M params (the paper's Fig. 1 workload)
    import jax.numpy as jnp
    n = 8
    d_params = 272_474                      # ResNet20 parameter count
    X = {"w": jnp.zeros((n, d_params), jnp.float32)}
    grad_seconds = 0.05                     # P100 fwd+bwd estimate @bs128

    rows = []
    for algo_name in ALGOS:
        algo = get_algorithm(algo_name)
        hp = C.default_hyper(bits=8, n=n)
        wire = algo.bytes_per_step(X, hp)
        local = (C.LOCAL_OVERHEAD_COPIES[algo_name] * d_params * 4
                 / C.HOST_COPY_BW)
        row = {"algorithm": algo_name, "wire_bytes_per_step": wire,
               "extra_local_s": local}
        for net in C.NETWORKS:
            comm = net.step_comm_seconds(wire, MSGS[algo_name])
            row[f"s/step {net.name}"] = grad_seconds + local + comm
        rows.append(row)

    # ranking on the slowest network: Moniqua must beat every baseline
    slow = f"s/step {C.NETWORKS[-1].name}"
    fastest = min(rows, key=lambda r: r[slow])
    return {
        "table": rows,
        "fastest_on_slow_net": fastest["algorithm"],
        "notes": ("Analytic network model (DESIGN §2 change #2): "
                  "step time = grad + local overhead + bytes/bandwidth + "
                  "messages*latency, ResNet20-size payloads, ring n=8, "
                  "8-bit budget. Reproduces Fig. 1's ordering: quantized "
                  "algorithms split from full precision as bandwidth drops, "
                  "AllReduce degrades worst with latency, and Moniqua leads "
                  "since it pays no replica/error-tracking overhead."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
