"""Elastic gossip: deadline rounds, partial participation, fault injection.

Three claims, each a gated artifact section (``tools/check_bench.py``):

1. ``bitexact`` — ``mix(presence=all-ones)`` must be *bitwise* identical
   to plain ``mix()`` for every wire x backend x gossip path, and for the
   two-tier engine, over iterated rounds *including* the EF WireState
   carries: the elastic code path costs exactly nothing when nobody is
   absent.
2. ``deadline`` — on ``straggler-longtail`` and on ``churn-ring`` with a
   heavy-tail compute term composed in, deadline-dropping reaches the
   same loss target in strictly less wall clock than waiting for
   stragglers.  The event sim prices the rounds (barrier vs deadline)
   and records the realized per-round participation masks; the *real*
   CommEngine then replays those masks (``mix(presence=...)``) on a
   decentralized quadratic, so rounds-to-target reflects exactly the
   mixing the elastic run would have done.  "Matched loss" means both
   runs hit the *same* absolute target (5% of the shared initial loss).
3. ``sweep`` — tiny-LM loss vs dropout rate p for moniqua-1bit vs fp32
   through the full trainer (``AlgoHyper.presence``): the paper's 1-bit
   wire must degrade gracefully alongside the fp32 baseline as workers
   drop out (the robustness margin).

Outputs ``BENCH_elastic.json`` (committed, full run) and
``BENCH_elastic.smoke.json`` (CI smoke; never clobbers the committed
artifact).
"""
from __future__ import annotations

import dataclasses as dc
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.comm.engine import CommEngine, make_wire
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring, two_tier
from repro.sim import events as SE
from repro.sim.network import STREAM_OUTAGE, sim_uniform
from repro.sim.scenarios import get_scenario

# every codec the engine can put on the wire; bits picks the QuantSpec
WIRES = [("full", 32), ("moniqua", 2), ("qsgd", 4),
         ("ef_qsgd", 4), ("onebit", 1)]
BACKENDS = ("jnp", "pallas")
PATHS = ("bucketed", "per_leaf")
N = 8
THETA = 4.0          # bitexact trees are O(0.1): ample Lemma-1 headroom
ROUNDS_ITER = 3      # iterated rounds so WireState carries are exercised

TARGET_FRAC = 0.05   # "matched loss" target: 5% of the shared initial loss
REPLAY_D = 16
REPLAY_LR = 0.2
REPLAY_THETA = 16.0  # replay iterates start ~N(0,1)-spread: theta >> diam


def _engine(wname: str, bits: int, backend: str = "jnp",
            path: str = "bucketed", topo=None) -> CommEngine:
    spec = QuantSpec(bits=min(bits, 8), stochastic=1 < bits <= 8)
    # warmup=1: round 1 is the fp32 warmup, rounds 2..k hit the real
    # 1-bit + error-feedback path (the state we must compare)
    return CommEngine(topo if topo is not None else ring(N),
                      make_wire(wname, spec, warmup=1), backend, path=path)


def _tree(n: int, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": 0.1 * jax.random.normal(k1, (n, 4, 3)),
            "b": 0.1 * jax.random.normal(k2, (n, 5)),
            "s": {"m": 0.1 * jax.random.normal(k3, (n, 2, 2, 2))}}


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def _iterated(eng: CommEngine, X0, presence):
    X = X0
    state = eng.init_wire_state(X0) if eng.stateful else None
    for r in range(ROUNDS_ITER):
        res = eng.mix(X, theta=THETA, key=jax.random.PRNGKey(100 + r),
                      state=state, presence=presence)
        X = res.x
        if eng.stateful:
            state = res.state
    return X, (state if state is not None else {})


def bitexact_rows() -> list:
    """presence=all-ones vs plain mix, bitwise, for every engine build."""
    X0 = _tree(N, jax.random.PRNGKey(7))
    rows = []
    for wname, bits in WIRES:
        for backend in BACKENDS:
            for path in PATHS:
                eng = _engine(wname, bits, backend, path)
                xa, sa = _iterated(eng, X0, None)
                xb, sb = _iterated(eng, X0, (1,) * N)
                rows.append({
                    "wire": wname, "backend": backend, "path": path,
                    "bitexact": bool(_trees_equal(xa, xb)
                                     and _trees_equal(sa, sb))})
        # two-tier engine: presence is a per-NODE mask (n_inter entries)
        eng = _engine(wname, bits, "jnp", "bucketed", topo=two_tier(N, 2))
        xa, sa = _iterated(eng, X0, None)
        xb, sb = _iterated(eng, X0, (1,) * (N // 2))
        rows.append({"wire": wname, "backend": "jnp", "path": "tiered",
                     "bitexact": bool(_trees_equal(xa, xb)
                                      and _trees_equal(sa, sb))})
    return rows


# ---------------------------------------------------------------------------
# Part 2: deadline-dropping vs wait-for-stragglers, wall clock to target.
# ---------------------------------------------------------------------------

def _quadratic_replay(masks, rounds: int, *, seed: int,
                      wire: str = "moniqua", bits: int = 8) -> list:
    """Decentralized quadratic driven through the real engine.

    Worker i descends 0.5||x_i - c_i||^2 then gossips; ``masks`` are the
    sim's realized per-round participation masks (empty/None entries mean
    everyone up).  Returns ``losses`` with ``losses[0]`` the pre-round
    loss and ``losses[k+1]`` the mean distance-to-global-optimum after
    round k.
    """
    n, d = N, REPLAY_D
    eng = C.build_engine(wire, bits, n=n)
    key = jax.random.PRNGKey(seed)
    kc, kx = jax.random.split(key)
    c = jax.random.normal(kc, (n, d))
    X = {"x": c + 1.5 * jax.random.normal(kx, (n, d))}
    cbar = jnp.mean(c, axis=0)
    state = eng.init_wire_state(X) if eng.stateful else None

    def loss_of(Xd) -> float:
        return float(0.5 * jnp.mean(jnp.sum((Xd["x"] - cbar) ** 2, -1)))

    losses = [loss_of(X)]
    for k in range(rounds):
        X = {"x": X["x"] - REPLAY_LR * (X["x"] - c)}
        pres = tuple(masks[k]) if masks and k < len(masks) else None
        res = eng.mix(X, theta=REPLAY_THETA, key=jax.random.fold_in(key, k),
                      state=state, presence=pres)
        X = res.x
        if eng.stateful:
            state = res.state
        losses.append(loss_of(X))
    return losses


def _wall_to_target(losses, round_seconds, target):
    for k in range(len(round_seconds)):
        if losses[k + 1] <= target:
            return sum(round_seconds[:k + 1]), k + 1
    return None, None


def _deadline_row(sc, deadline_s: float, rounds: int, seed: int) -> dict:
    payload = 4 * REPLAY_D  # fp32 replay vector per neighbor message
    tw = SE.simulate_sync_rounds(sc, payload, rounds)
    td = SE.simulate_sync_rounds(sc.with_deadline(deadline_s), payload,
                                 rounds)
    lw = _quadratic_replay(tw.presence, rounds, seed=seed)
    ld = _quadratic_replay(td.presence, rounds, seed=seed)
    target = TARGET_FRAC * lw[0]
    ww, rw = _wall_to_target(lw, tw.round_seconds, target)
    wd, rd = _wall_to_target(ld, td.round_seconds, target)
    return {
        "scenario": sc.name, "deadline_s": deadline_s, "rounds": rounds,
        "participation_wait": tw.participation_mean,
        "participation_deadline": td.participation_mean,
        "target_loss": target,
        "rounds_to_target_wait": rw, "rounds_to_target_deadline": rd,
        "wall_to_target_wait_s": ww, "wall_to_target_deadline_s": wd,
        "loss_final_wait": lw[-1], "loss_final_deadline": ld[-1],
        "matched": bool(rw is not None and rd is not None),
        "speedup_x": (ww / wd) if (ww and wd) else 0.0,
        "fingerprint_deadline": td.fingerprint(),
    }


def deadline_rows(rounds: int) -> list:
    # one chronically-slow heavy-tail worker: the paper's straggler regime
    strag = get_scenario("straggler-longtail", n=N, seed=1)
    # deadline admits worker 0's 4x base (0.2s) only when its Pareto term
    # is quiet — it still mixes occasionally, so no consensus floor
    row_a = _deadline_row(strag, 5.0 * strag.compute.base_s, rounds, seed=2)
    # churn + a heavy tail on EVERY worker: crash-restart decides presence,
    # the deadline decides who of the survivors makes the barrier
    churn = get_scenario("churn-ring", n=N, seed=11)
    churn = dc.replace(churn, compute=dc.replace(
        churn.compute, tail="pareto", tail_scale=1.0, pareto_shape=1.5))
    row_b = _deadline_row(churn, 2.4 * churn.compute.base_s, rounds, seed=3)
    return [row_a, row_b]


# ---------------------------------------------------------------------------
# Part 3: robustness margin — tiny-LM loss vs dropout rate p.
# ---------------------------------------------------------------------------

def _dropout_mask(n: int, p: float, seed: int = 0):
    """Deterministic worker mask with ~p*n absent (counter-hash draws)."""
    k = int(round(p * n))
    if k == 0:
        return None
    order = sorted(range(n),
                   key=lambda i: sim_uniform(seed, STREAM_OUTAGE, 0x5EEB, i))
    absent = set(order[:k])
    return tuple(0 if i in absent else 1 for i in range(n))


def sweep_rows(steps: int) -> list:
    model = C.tiny_lm()
    rows = []
    for p in (0.0, 0.125, 0.25, 0.375):
        presence = _dropout_mask(N, p, seed=17)
        for label, kw in (
                ("fp32", dict(algo="dpsgd", wire="full", bits=8)),
                ("moniqua-1bit", dict(algo="moniqua", wire="moniqua",
                                      bits=1, theta=0.25, slack=0.2))):
            r = C.train_run(steps=steps, model=model, n_workers=N,
                            presence=presence, lr=0.3, seed=0, **kw)
            rows.append({
                "p": p, "codec": label,
                "absent_workers": (0 if presence is None
                                   else N - sum(presence)),
                "loss_first": r["loss_first"], "loss_last": r["loss_last"],
            })
    # robustness margin: degradation vs the same codec's p=0 run
    base = {r["codec"]: r["loss_last"] for r in rows if r["p"] == 0.0}
    for r in rows:
        r["degradation"] = r["loss_last"] - base[r["codec"]]
    return rows


# ---------------------------------------------------------------------------


def _assert_invariants(result: dict, smoke: bool) -> None:
    bad = [r for r in result["bitexact"] if not r["bitexact"]]
    assert not bad, f"presence=all-ones not bit-exact: {bad}"
    for r in result["deadline"]:
        assert r["matched"], (
            f"{r['scenario']}: a run missed the matched-loss target "
            f"{r['target_loss']:.4g} (wait={r['loss_final_wait']:.4g}, "
            f"deadline={r['loss_final_deadline']:.4g})")
        assert r["speedup_x"] > 1.0, (
            f"{r['scenario']}: deadline-dropping did not beat "
            f"wait-for-stragglers ({r['speedup_x']:.3g}x)")
    for r in result["sweep"]:
        assert r["loss_last"] < r["loss_first"], (
            f"sweep run diverged: {r}")


def run(quick: bool = False, smoke: bool = False) -> dict:
    quick = quick or smoke
    sim_rounds = 90 if quick else 240
    lm_steps = 16 if quick else 40
    result = {
        "bitexact": bitexact_rows(),
        "deadline": deadline_rows(sim_rounds),
        "sweep": sweep_rows(lm_steps),
        "headline": None,
        "notes": (
            "Elastic gossip: (1) presence=all-ones is bitwise identical "
            "to plain mix for every wire/backend/path incl. two-tier and "
            "EF WireState carries; (2) deadline-dropped rounds replayed "
            "through the real engine with the sim's realized presence "
            "masks reach the same loss target in less wall clock than "
            "waiting for stragglers; (3) moniqua-1bit degrades gracefully "
            "with dropout rate p alongside fp32 (full trainer runs)."),
    }
    result["headline"] = {
        "scenario": result["deadline"][0]["scenario"],
        "speedup_x": result["deadline"][0]["speedup_x"],
        "participation_deadline":
            result["deadline"][0]["participation_deadline"],
        "bitexact_rows": len(result["bitexact"]),
    }
    _assert_invariants(result, smoke)
    return result


def main(argv=None) -> int:
    import argparse
    import json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced rounds/steps; writes the .smoke artifact")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    out = args.out or os.path.join(
        _ROOT, "BENCH_elastic.smoke.json" if args.smoke
        else "BENCH_elastic.json")
    result = run(quick=args.quick, smoke=args.smoke)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, default=float)
        f.write("\n")
    print(f"wrote {out}")
    print(C.markdown_table(result["deadline"],
                           cols=["scenario", "deadline_s",
                                 "participation_deadline",
                                 "wall_to_target_wait_s",
                                 "wall_to_target_deadline_s", "speedup_x"]))
    print(C.markdown_table(result["sweep"],
                           cols=["p", "codec", "loss_last", "degradation"]))
    n_ok = sum(1 for r in result["bitexact"] if r["bitexact"])
    print(f"bitexact: {n_ok}/{len(result['bitexact'])} rows identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
