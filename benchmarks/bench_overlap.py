"""Whole-step wall-clock: barrier gossip round vs chunk-pipelined round.

PR-4 fused the round into one flat bucket; this benchmark measures the
next lever: splitting that bucket into K slot-aligned chunks and running
the staged ``RoundPlan`` pipeline (encode t / permute t-1 / decode-reduce
t-2, comm/engine.py).  On a mesh the win is overlap — chunk t's
collective-permute hides behind t+1's encode; on this CPU host the same
restructuring still wins wall-clock because each ~(D/K)-sized chunk stays
cache-resident across its three phases instead of streaming the whole
multi-MB buffer through memory three times per round.

Measured: the **whole jitted train step** (fwd + bwd + optimizer + gossip,
``train/train_step.py``) on reduced model-zoo configs, ``chunks=1`` vs
``chunks=K``, interleaved rep-by-rep with min-over-reps (contention noise
only inflates samples).  The pipeline is only worth shipping if the round
it produces is the same round — so the table also records the bit-exact
booleans for ALL five wires (outputs and, for the EF wires, the post-round
WireState), ``chunks=1`` vs ``chunks=K``, which ``tools/check_bench.py``
gates alongside the speedups.

``BENCH_overlap.json`` is the committed trajectory; CI's bench-smoke job
writes ``BENCH_overlap.smoke.json`` and the gate compares the two.

Usage:  python benchmarks/bench_overlap.py [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.engine import CommEngine, make_wire
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig

N_WORKERS = 8
CHUNKS = 4          # the pipelined K; tuned on this host (K=2..8 sweep)
SHAPE = InputShape("bench", seq_len=32, global_batch=8, kind="train")

# whole-step timing: the two quantized wires whose codec work dominates
# the round (the fp32 wire is a memcpy-bound roll — nothing to pipeline)
TIMED_WIRES = [("moniqua-8bit", "moniqua", 8), ("qsgd-8bit", "qsgd", 8)]

# bit-exactness is checked for the FULL wire family
BITEXACT_WIRES = [("full", 32), ("moniqua", 8), ("qsgd", 8),
                  ("ef_qsgd", 4), ("onebit", 1)]


def _zoo():
    return [("transformer", "llama3.2-3b"), ("mamba2", "zamba2-1.2b"),
            ("moe", "dbrx-132b")]


def _stack(params, n=N_WORKERS):
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), params)


# ---------------------------------------------------------------------------
# Whole-step timing.
# ---------------------------------------------------------------------------

def _trainer(model, wire, chunks):
    tc = TrainerConfig(algo="moniqua", wire=wire, n_workers=N_WORKERS,
                       bits=8, theta=2.0, steps=1, comm_path="bucketed",
                       chunks=chunks)
    return Trainer(model, SHAPE, tc)


def _time_step_pair(model, wire, reps):
    """Min-over-reps whole-step seconds, barrier (K=1) vs pipelined (K=K),
    interleaved rep by rep so host drift hits both equally."""
    trs = (_trainer(model, wire, 1), _trainer(model, wire, CHUNKS))
    batch = trs[0].pipeline.worker_batch(0)
    for tr in trs:                       # compile + warm up (donated state)
        out, _ = tr.jstep(tr.init_state(), batch)
        jax.block_until_ready(out["params"])
    times = ([], [])
    for _ in range(reps):
        for tr, acc in zip(trs, times):
            state = tr.init_state()      # fresh: jstep donates its input
            t0 = time.perf_counter()
            out, _ = tr.jstep(state, batch)
            jax.block_until_ready(out["params"])
            acc.append(time.perf_counter() - t0)
    return min(times[0]), min(times[1])


# ---------------------------------------------------------------------------
# Bit-exactness: chunks=K is the same round as chunks=1, every wire.
# ---------------------------------------------------------------------------

def _bitexact_row(model_name, X):
    rows = []
    for wire, bits in BITEXACT_WIRES:
        spec = QuantSpec(bits=min(bits, 8), stochastic=1 < bits <= 8)
        codec = (make_wire(wire, spec, warmup=1)
                 if wire in ("ef_qsgd", "onebit") else make_wire(wire, spec))
        a = CommEngine(ring(N_WORKERS), codec, backend="jnp",
                       path="bucketed", chunks=1)
        b = CommEngine(ring(N_WORKERS), codec, backend="jnp",
                       path="bucketed", chunks=CHUNKS)
        kw = {"theta": 2.0, "key": jax.random.PRNGKey(0)}
        if wire == "full":
            kw = {}
        elif wire != "moniqua":
            kw.pop("theta")
        sa = a.init_wire_state(X) if a.stateful else None
        ra = a.mix(X, state=sa, **kw)
        rb = b.mix(X, state=sa, **kw)
        ok = all(bool(jnp.all(la == lb)) for la, lb in
                 zip(jax.tree.leaves(ra.x), jax.tree.leaves(rb.x)))
        if a.stateful:
            ok = ok and all(bool(jnp.all(la == lb)) for la, lb in
                            zip(jax.tree.leaves(ra.state["residual"]),
                                jax.tree.leaves(rb.state["residual"])))
        rows.append({"model": model_name, "wire": wire, "bits": bits,
                     "chunks": CHUNKS, "bitexact": bool(ok)})
    return rows


# ---------------------------------------------------------------------------

def run(quick: bool = False) -> dict:
    reps = 2 if quick else 5
    zoo = _zoo()[:1] if quick else _zoo()
    table, bitexact = [], []
    for model_name, cfg_name in zoo:
        model = build_model(get_config(cfg_name).reduced())
        X = _stack(model.init(jax.random.PRNGKey(0)))
        d = sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(X))
        eng = CommEngine(ring(N_WORKERS), make_wire("moniqua"),
                         backend="jnp", path="bucketed")
        layout = eng.layout(X)
        n_chunks = len(layout.chunks(CHUNKS))
        for label, wire, bits in TIMED_WIRES:
            t_barrier, t_pipe = _time_step_pair(model, wire, reps)
            table.append({
                "model": model_name, "wire": label, "chunks": n_chunks,
                "params_per_worker": d,
                "n_slots": len(layout.slots),
                "step_ms_barrier": t_barrier * 1e3,
                "step_ms_pipelined": t_pipe * 1e3,
                "speedup_x": t_barrier / t_pipe,
            })
        bitexact.extend(_bitexact_row(model_name, X))

    all_exact = all(r["bitexact"] for r in bitexact)
    head = max(table, key=lambda r: r["speedup_x"])
    return {
        "table": table,
        "bitexact": bitexact,
        "all_bitexact": all_exact,
        "headline": {"model": head["model"], "wire": head["wire"],
                     "chunks": head["chunks"],
                     "speedup_x": head["speedup_x"],
                     "step_ms_barrier": head["step_ms_barrier"],
                     "step_ms_pipelined": head["step_ms_pipelined"]},
        "backend": "jnp (jitted, this host)",
        "n_workers": N_WORKERS,
        "chunks": CHUNKS,
        "reps": reps,
        "notes": (
            "Whole jitted train-step wall-clock (fwd+bwd+optimizer+gossip, "
            "train/train_step.py make_train_step via the Trainer), ring "
            "n=8, reduced model-zoo configs, barrier round (chunks=1) vs "
            "the staged RoundPlan pipeline (chunks=4, comm/engine.py); "
            "paths timed interleaved rep-by-rep, min over reps.  The "
            "pipelined round does identical work in K slot-aligned "
            "windows (encode t / permute t-1 / decode-reduce t-2); on a "
            "mesh the permute overlaps neighboring chunks' codec phases, "
            "and on this CPU host the chunk-sized working set stays "
            "cache-resident across its three phases, which is where the "
            "measured win comes from.  'bitexact' rows verify chunks=4 "
            "against chunks=1 bitwise for all five wires (outputs + EF "
            "WireState) — the pipeline is a schedule change, not a "
            "numerics change."),
    }


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fewer reps, first zoo model only; write "
                         "BENCH_overlap.smoke.json")
    args = ap.parse_args()
    out = run(quick=args.smoke)
    name = "BENCH_overlap.smoke.json" if args.smoke else "BENCH_overlap.json"
    path = os.path.join(_ROOT, name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(json.dumps(out["headline"], indent=2, default=float))
    print(f"wrote {path}")
