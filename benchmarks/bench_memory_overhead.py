"""C8 / Tables 1-2 memory column, extended with the error-feedback wires:
the paper-relevant tradeoff *triangle* — bits/param vs extra memory vs
steps-to-target — with real codec implementations behind every row.

Two parts:

1. **Accounting table** (always, machine-independent): per algorithm/wire
   at ResNet20 / ResNet110 parameter counts, the per-worker extra memory
   (``Algorithm.extra_memory_bytes``, which for the EF wires is the live
   ``CommEngine.wire_state_bytes`` residual accounting), the wire bytes per
   step, bits/param on the wire, and the simulated seconds per gossip round
   on the bandwidth-starved scenario (``repro.sim`` is seeded and
   deterministic, so these numbers are reproducible bit-for-bit).  Moniqua
   must land at exactly 0 extra bytes — the headline systems claim — while
   EF-QSGD / onebit pay a Theta(nd) residual buffer; ``tools/check_bench.py``
   gates both invariants on the committed ``BENCH_memory_overhead.json``.

2. **Convergence triangle** (full run only): one tiny-LM training run per
   codec family through the real ``CommEngine`` wires, reporting steps to
   reach the fp32 target loss — the third axis that shows what the EF
   wires buy (or don't) for their memory.

    PYTHONPATH=src python benchmarks/bench_memory_overhead.py          # full
    PYTHONPATH=src python benchmarks/bench_memory_overhead.py --smoke  # CI

Writes ``BENCH_memory_overhead.json`` at the repo root
(``BENCH_memory_overhead.smoke.json`` under ``--smoke``) and, under
``benchmarks.run``, the usual ``benchmarks/results`` copy.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import argparse
import json
from typing import Any, Dict, List

import jax.numpy as jnp

from benchmarks import common as C
from repro.core.algorithms import get_algorithm
from repro.sim import events as SE
from repro.sim.scenarios import get_scenario

PARAMS = {"resnet20": 272_474, "resnet110": 1_727_962}
N = 8

# (algorithm, wire, bits): Table 1/2's zoo plus the EF codec family riding
# the same gossip rule (``moniqua`` algorithm routes through whichever wire
# ``AlgoHyper.wire`` selects).  dpsgd ships fp32; its bits column reports
# the wire width, not a QuantSpec.
CONFIGS = [
    ("dpsgd", "full", 32),
    ("dcd", "moniqua", 8),
    ("ecd", "moniqua", 8),
    ("choco", "moniqua", 8),
    ("deepsqueeze", "moniqua", 8),
    ("moniqua", "moniqua", 8),
    ("moniqua", "moniqua", 1),
    ("moniqua", "ef_qsgd", 8),
    ("moniqua", "ef_qsgd", 4),
    ("moniqua", "onebit", 1),
]

# the scenario where bytes dominate the round — the regime that makes the
# memory-for-bandwidth trade visible in wall-clock terms
SIM_SCENARIO = "bandwidth-starved"
SIM_ROUNDS = 3


def accounting_table() -> List[Dict[str, Any]]:
    rows = []
    for model_name, d in PARAMS.items():
        X = {"w": jnp.zeros((N, d), jnp.float32)}
        for algo_name, wire, bits in CONFIGS:
            hp = C.default_hyper(bits=min(bits, 8), n=N, wire=wire,
                                 stochastic=False)
            algo = get_algorithm(algo_name)
            extra = algo.extra_memory_bytes(X, hp)
            wire_bytes = algo.bytes_per_step(X, hp)
            m = len(hp.topo.neighbor_offsets())
            sc = get_scenario(SIM_SCENARIO, n=N)
            trace = SE.simulate_sync_rounds(sc, wire_bytes // m, SIM_ROUNDS)
            rows.append({
                "model": model_name, "params": d,
                "algorithm": algo_name, "wire": wire, "bits": bits,
                "extra_memory_bytes": int(extra),
                "extra_memory_MB": extra / 1e6,
                "wire_bytes_per_step": int(wire_bytes),
                "bits_per_param": wire_bytes * 8.0 / m / d,
                "sim_round_s": trace.mean_round_seconds,
            })
    _assert_invariants(rows)
    return rows


def _assert_invariants(rows: List[Dict[str, Any]]) -> None:
    """The invariants check_bench.py re-verifies on the committed artifact;
    asserted here too so a bad table can never even be written."""
    for r in rows:
        if r["wire"] == "moniqua":
            assert r["algorithm"] != "moniqua" or r["extra_memory_MB"] == 0.0
        if r["wire"] in ("ef_qsgd", "onebit"):
            # Theta(nd): one f32 residual per parameter per worker
            assert r["extra_memory_bytes"] >= 4 * r["params"], r


def triangle_rows(steps: int = 60) -> List[Dict[str, Any]]:
    """Steps-to-target per codec family: the convergence corner of the
    triangle, measured with real training runs (not assumed)."""
    runs = [
        ("dpsgd", "full", 32, {}),
        ("moniqua", "moniqua", 8, {}),
        ("moniqua", "ef_qsgd", 4, {}),
        # short warmup so the 1-bit phase dominates the measured run
        ("moniqua", "onebit", 1, {"warmup": 8}),
    ]
    results = []
    for algo, wire, bits, kw in runs:
        out = C.train_run(algo, bits=min(bits, 8), wire=wire, steps=steps,
                          log_every=1, **kw)
        results.append((algo, wire, bits, out))
    target = 1.05 * results[0][3]["loss_last"]   # fp32 final loss + 5%
    rows = []
    for algo, wire, bits, out in results:
        steps_to = next((h["step"] for h in out["history"]
                         if h["loss"] <= target), None)
        rows.append({
            "algorithm": algo, "wire": wire, "bits": bits,
            "loss_last": out["loss_last"],
            "steps_to_target": steps_to,
            "bytes_per_step": out["bytes_per_step"],
        })
    return rows


def run(quick: bool = False, smoke: bool = False) -> dict:
    result = {
        "table": accounting_table(),
        "notes": (
            "Table 1/2 memory accounting + EF codec family, ring n=8 "
            "(2 neighbors): replica schemes (Choco/DCD/ECD) pay (deg+1) "
            "model copies = Theta(md) graph-wide; DeepSqueeze and the EF "
            "wires (ef_qsgd, onebit) one error buffer = Theta(nd); Moniqua "
            "exactly 0 — the paper's headline systems property.  "
            "sim_round_s prices each wire's exact bytes on the "
            f"{SIM_SCENARIO} scenario (deterministic simulator)."),
    }
    if not (quick or smoke):
        result["triangle"] = triangle_rows()
        result["triangle_notes"] = (
            "steps to reach 1.05x the fp32 final loss on the tiny-LM bench "
            "(real CommEngine wires; onebit uses warmup=8 of 60 steps)")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="accounting table only (fast, machine-independent)")
    ap.add_argument("--out", default=None,
                    help="output path; defaults to BENCH_memory_overhead"
                         ".json at the repo root (.smoke.json under "
                         "--smoke, so a smoke run never clobbers the "
                         "committed trajectory)")
    args = ap.parse_args(argv)
    if args.out is None:
        name = ("BENCH_memory_overhead.smoke.json" if args.smoke
                else "BENCH_memory_overhead.json")
        args.out = os.path.join(_ROOT, name)
    result = run(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, default=float)
    print(f"wrote {args.out} ({len(result['table'])} accounting rows"
          + (f", {len(result['triangle'])} triangle rows" if "triangle"
             in result else "") + ")")
    print(C.markdown_table(result["table"],
                           ["model", "algorithm", "wire", "bits",
                            "extra_memory_MB", "bits_per_param",
                            "sim_round_s"]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
