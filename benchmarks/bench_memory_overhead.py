"""C8 / Tables 1-2 memory column: additional memory per algorithm at
ResNet20 / ResNet110 scale (the paper's accounting: conceptual replicas /
error buffers vs full-precision D-PSGD).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common as C
from repro.core.algorithms import get_algorithm

PARAMS = {"resnet20": 272_474, "resnet110": 1_727_962}
ALGOS = ["dpsgd", "dcd", "ecd", "choco", "deepsqueeze", "moniqua"]
N = 8


def run(quick: bool = False) -> dict:
    rows = []
    for model_name, d in PARAMS.items():
        X = {"w": jnp.zeros((N, d), jnp.float32)}
        hp = C.default_hyper(bits=8, n=N)
        for algo in ALGOS:
            a = get_algorithm(algo)
            rows.append({
                "model": model_name, "algorithm": algo,
                "extra_memory_MB": a.extra_memory_bytes(X, hp) / 1e6,
                "wire_bytes_per_step": a.bytes_per_step(X, hp),
            })
    moni = [r for r in rows if r["algorithm"] == "moniqua"]
    assert all(r["extra_memory_MB"] == 0.0 for r in moni)
    return {
        "table": rows,
        "notes": ("Table 1/2 memory accounting, ring n=8 (2 neighbors): "
                  "replica schemes (Choco/DCD/ECD) pay (deg+1) model copies "
                  "= Theta(md) graph-wide; DeepSqueeze one error buffer = "
                  "Theta(nd); Moniqua exactly 0 — the paper's headline "
                  "systems property."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
