"""Render the §Dry-run / §Roofline tables from dryrun_results.jsonl.

Reads the JSONL emitted by ``repro.launch.dryrun --out`` and produces the
EXPERIMENTS.md tables: per (arch x shape x mesh) the three roofline terms,
dominant bottleneck, MODEL_FLOPS ratio, and the collective schedule.
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from typing import Dict, List

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _newest(*names: str) -> str:
    """Prefer the post-optimization artifact when it exists and is complete
    (…2.jsonl written by the final re-sweep), else the baseline file."""
    for n in names:
        p = os.path.join(_ROOT, n)
        if os.path.exists(p):
            return p
    return os.path.join(_ROOT, names[-1])


DEFAULT_PATH = _newest("dryrun_results2.jsonl", "dryrun_results.jsonl")
CALIBRATED_PATH = _newest("calibrated2.jsonl", "calibrated.jsonl")


def load(path: str = DEFAULT_PATH,
         calibrated_path: str = CALIBRATED_PATH) -> List[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            if line.strip():
                rows.append(json.loads(line))
    # keep the LAST result per (arch, shape, mesh) — re-runs override
    seen: "OrderedDict[tuple, dict]" = OrderedDict()
    for r in rows:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    # merge depth-probe calibration (scan-undercount fix; launch/calibrate.py)
    if calibrated_path and os.path.exists(calibrated_path):
        with open(calibrated_path) as f:
            for line in f:
                if not line.strip():
                    continue
                c = json.loads(line)
                key = (c["arch"], c["shape"], c["mesh"])
                if c.get("status") == "ok" and key in seen:
                    seen[key]["roofline_calibrated"] = \
                        c["roofline_calibrated"]
                    seen[key]["collectives_calibrated"] = \
                        c["collectives_calibrated"]
    return list(seen.values())


def roofline_rows(results: List[dict], mesh: str = "16x16") -> List[dict]:
    out = []
    for r in results:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": "skipped", "note": r["error"][:60]})
            continue
        if r["status"] != "ok":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": "ERROR"})
            continue
        calibrated = "roofline_calibrated" in r
        rf = r.get("roofline_calibrated", r["roofline"])
        out.append({
            "arch": r["arch"], "shape": r["shape"],
            "status": "ok" + ("*" if calibrated else ""),
            "compute_ms": rf["compute_s"] * 1e3,
            "memory_ms": rf["memory_s"] * 1e3,
            "collective_ms": rf["collective_s"] * 1e3,
            "dominant": rf["dominant"],
            "useful_ratio": rf["useful_ratio"],
            "mfu_bound": rf["mfu_upper_bound"],
            "hbm_gb": r["memory"]["peak_estimate_gb"],
        })
    return out


def collective_rows(results: List[dict], mesh: str = "16x16") -> List[dict]:
    out = []
    for r in results:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        out.append({"arch": r["arch"], "shape": r["shape"],
                    "collectives": r["collectives"]["summary"]})
    return out


def run(quick: bool = False, path: str = DEFAULT_PATH) -> dict:
    if not os.path.exists(path):
        return {"table": [], "notes": f"no dry-run results at {path}; "
                "run `python -m repro.launch.dryrun --both-meshes --out "
                "dryrun_results.jsonl` first"}
    results = load(path)
    ok = sum(1 for r in results if r["status"] == "ok")
    skipped = sum(1 for r in results if r["status"] == "skipped")
    err = sum(1 for r in results if r["status"] == "error")
    return {
        "table": roofline_rows(results),
        "collectives": collective_rows(results),
        "multi_pod": roofline_rows(results, mesh="2x16x16"),
        "counts": {"ok": ok, "skipped": skipped, "error": err,
                   "total": len(results)},
        "notes": (f"{ok} ok / {skipped} skipped / {err} errors of "
                  f"{len(results)} (arch x shape x mesh) combinations; "
                  "terms in ms/step/chip at v5e constants "
                  "(197 TF bf16, 819 GB/s HBM, 50 GB/s ICI)."),
    }


if __name__ == "__main__":
    import json as _json
    print(_json.dumps(run(), indent=2, default=float))
