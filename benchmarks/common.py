"""Shared benchmark utilities: tiny-model trainer runs, quadratic runner,
network cost model, and result I/O.

Every ``bench_*`` module exposes ``run(quick: bool) -> dict`` returning a
JSON-serialisable result with a ``table`` (list of row dicts) and ``notes``.
``benchmarks.run`` orchestrates them and renders markdown for EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.algorithms import AlgoHyper, get_algorithm
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.data.synthetic import quadratic_grad
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_result(name: str, result: Dict[str, Any]) -> str:
    """Write the benchmark result JSON plus a schema-versioned run log.

    The sibling ``<name>.runlog.jsonl`` re-emits the result through
    ``repro.obs.runlog`` (header + one ``event`` per table row + a final
    ``result``) so benchmark outputs flow through the same
    ``tools/obs_report.py`` / ``tools/check_obs.py`` pipeline as trainer
    and dryrun logs.
    """
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2, default=float)
    from repro.obs.runlog import RunLogWriter
    with RunLogWriter(os.path.join(RESULTS_DIR, f"{name}.runlog.jsonl"),
                      run={"bench": name}, tool="benchmark") as w:
        for row in result.get("table", []) or []:
            if isinstance(row, dict):
                w.event("bench_row", row)
        w.result(bench=name, rows=len(result.get("table", []) or []),
                 notes=str(result.get("notes", ""))[:500])
    return path


def markdown_table(rows: List[Dict[str, Any]], cols: Optional[List[str]] = None
                   ) -> str:
    if not rows:
        return "(empty)"
    cols = cols or list(rows[0].keys())
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(fmt(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Tiny LM used by the convergence benchmarks (fast on 1 CPU core).
# ---------------------------------------------------------------------------

TINY_SHAPE = InputShape("bench", seq_len=32, global_batch=16, kind="train")


def tiny_lm(d_model=64, layers=2, vocab=128):
    import dataclasses as dc
    cfg = get_config("llama3.2-3b").reduced()
    cfg = dc.replace(cfg, num_layers=layers, d_model=d_model, num_heads=2,
                     num_kv_heads=2, head_dim=d_model // 2, d_ff=2 * d_model,
                     vocab_size=vocab)
    return build_model(cfg)


def train_run(algo: str, *, bits=8, theta=2.0, slack=1.0, gamma=1.0,
              steps=60, lr=0.3, n_workers=8, seed=0, model=None,
              shape=TINY_SHAPE, wire="moniqua", topology="ring",
              warmup=16, log_every=None, telemetry=False,
              log_jsonl=None, presence=None) -> Dict[str, Any]:
    model = model or tiny_lm()
    tc = TrainerConfig(algo=algo, topology=topology, n_workers=n_workers,
                       bits=bits, theta=theta,
                       slack=slack, gamma=gamma, lr=lr, steps=steps,
                       log_every=log_every or max(steps // 10, 1),
                       momentum=0.0, weight_decay=0.0, seed=seed, wire=wire,
                       warmup=warmup, telemetry=telemetry,
                       log_jsonl=log_jsonl, presence=presence)
    t0 = time.time()
    out = Trainer(model, shape, tc).run()
    hp = out["state"], out["history"]
    return {
        "algo": algo, "bits": bits,
        "loss_first": out["history"][0]["loss"],
        "loss_last": out["history"][-1]["loss"],
        "history": out["history"],
        "bytes_per_step": out["bytes_per_step"],
        "seconds": time.time() - t0,
    }


# ---------------------------------------------------------------------------
# Theorem-1 quadratic runner (shared by floor/convergence benches).
# ---------------------------------------------------------------------------

def quadratic_run(algo_name: str, hp: AlgoHyper, *, n=8, d=32, steps=800,
                  alpha0=0.05, sigma=0.05, seed=0, trace_every=20):
    algo = get_algorithm(algo_name)
    opt = hp.naive_delta / 2.0
    X = jnp.zeros((n, d))
    extra = algo.init(X, hp)
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def step(X, extra, k, key):
        key, kg, ka = jax.random.split(key, 3)
        gkeys = jax.random.split(kg, n)
        g = jax.vmap(lambda x, kk: quadratic_grad(
            x, hp.naive_delta, kk, sigma))(X, gkeys)
        alpha = alpha0 / (1.0 + 0.01 * k)
        Xn, extran = algo.step(X, extra, g, alpha, k, ka, hp)
        return Xn, extran, key

    trace = []
    for k in range(steps):
        X, extra, key = step(X, extra, jnp.asarray(k), key)
        if k % trace_every == 0 or k == steps - 1:
            g2 = float(jnp.mean(jnp.sum((X - opt) ** 2, axis=1)))
            trace.append({"step": k, "grad_sq": g2})
    return {"trace": trace, "final_grad_sq": trace[-1]["grad_sq"],
            "X": np.asarray(X)}


def default_hyper(bits=8, theta=2.0, n=8, naive_delta=0.2, slack=1.0,
                  gamma=1.0, stochastic=None, wire="moniqua", backend="jnp"):
    topo = ring(n)
    if slack < 1.0:
        topo = topo.slack(slack)
    stochastic = (bits > 1) if stochastic is None else stochastic
    return AlgoHyper(topo=topo,
                     codec=MoniquaCodec(QuantSpec(bits=bits,
                                                  stochastic=stochastic)),
                     theta=theta, gamma=gamma, naive_delta=naive_delta,
                     wire=wire, backend=backend)


# ---------------------------------------------------------------------------
# CommEngine codec sweep (bench_walltime and friends).
# ---------------------------------------------------------------------------

# (label, wire, bits): every codec CommEngine can put on the wire, from the
# fp32 baseline down to the paper's 1-bit headline configuration.
ENGINE_CODECS = [
    ("fp32", "full", 32),
    ("moniqua-8bit", "moniqua", 8),
    ("moniqua-4bit", "moniqua", 4),
    ("moniqua-1bit", "moniqua", 1),
    ("qsgd-8bit", "qsgd", 8),
    ("qsgd-4bit", "qsgd", 4),
]


def build_engine(wire: str, bits: int, n: int = 8, backend: str = "jnp",
                 path: str = "bucketed", topo=None):
    """One-liner CommEngine factory for benchmark sweeps."""
    from repro.comm.engine import CommEngine, make_wire
    spec = QuantSpec(bits=min(bits, 8), stochastic=1 < bits <= 8)
    return CommEngine(ring(n) if topo is None else topo,
                      make_wire(wire, spec), backend, path=path)


# ---------------------------------------------------------------------------
# Network cost model (Fig. 1's four configurations).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    name: str
    bandwidth_bps: float       # per-link
    latency_s: float           # per message

    def step_comm_seconds(self, bytes_sent: int, n_messages: int) -> float:
        return bytes_sent * 8.0 / self.bandwidth_bps \
            + n_messages * self.latency_s


# Fig. 1: (a) 10Gbps/0.15ms, (b) 1Gbps/0.15ms, (c) 1Gbps/5ms, (d) 100Mbps/5ms
NETWORKS = [
    NetworkConfig("10Gbps-0.15ms", 10e9, 0.15e-3),
    NetworkConfig("1Gbps-0.15ms", 1e9, 0.15e-3),
    NetworkConfig("1Gbps-5ms", 1e9, 5e-3),
    NetworkConfig("100Mbps-5ms", 100e6, 5e-3),
]

# Extra local work per step (replica updates / error tracking), relative to
# the cost of one model copy in memory bandwidth terms; calibrated from the
# paper's observation that quantized baselines pay a constant compute delay.
LOCAL_OVERHEAD_COPIES = {
    "allreduce": 0.0, "dpsgd": 0.0, "naive": 1.0, "moniqua": 2.0,
    "choco": 4.0, "deepsqueeze": 3.0, "dcd": 4.0, "ecd": 5.0,
    "d2": 2.0, "moniqua_d2": 3.0,
}
HOST_COPY_BW = 10e9   # bytes/s a 2-core GCP worker moves through memory
