"""C1 / Theorem 1: naive quantization stalls at the gradient-norm floor
``phi^2 delta^2 / (8 (1 + phi^2))`` per coordinate on the quadratic
``f(x) = ||x - delta 1/2||^2 / 2``; Moniqua (same bit budget) converges.
"""
from __future__ import annotations

from benchmarks import common as C


def run(quick: bool = False) -> dict:
    steps = 400 if quick else 1200
    n, d, delta = 8, 32, 0.2
    topo_phi = C.ring(n).phi
    floor_per_coord = topo_phi ** 2 * delta ** 2 / (8 * (1 + topo_phi ** 2))
    floor = floor_per_coord * d

    rows = []
    for algo, hp in [
        ("naive", C.default_hyper(naive_delta=delta)),
        ("dpsgd", C.default_hyper(naive_delta=delta)),
        ("moniqua", C.default_hyper(theta=0.5, naive_delta=delta)),
    ]:
        res = C.quadratic_run(algo, hp, n=n, d=d, steps=steps)
        rows.append({
            "algorithm": algo,
            "final_grad_sq": res["final_grad_sq"],
            "theorem1_floor": floor,
            "beats_floor": bool(res["final_grad_sq"] < floor),
        })
    return {
        "table": rows,
        "notes": (f"Theorem-1 quadratic, n={n} ring, d={d}, "
                  f"quantizer pitch delta={delta}; floor = "
                  f"phi^2 delta^2 d / (8(1+phi^2)) = {floor:.4g}. "
                  "Naive must stay above the floor; Moniqua (8-bit, theta=0.5)"
                  " and full-precision D-PSGD drop below it."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
