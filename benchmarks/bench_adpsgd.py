"""C6 / Figure 2b: Moniqua on AD-PSGD (asynchronous gossip, Theorem 5).

Runs the single-worker-update analysis model (DESIGN §2: asynchrony as
simulation) with stale gradients tau_k <= T and pairwise gossip W_k, plain
vs modulo-quantized, plus the projected wall-clock per update from the
network model (the quantized variant ships 1/4 of the bytes and AD-PSGD has
no synchronization barrier).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.adpsgd import ADPSGDConfig, run as adpsgd_run
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.data.synthetic import quadratic_grad

N, D = 6, 32
DELTA = 0.2


def run(quick: bool = False) -> dict:
    iters = 600 if quick else 2000
    x0 = jnp.zeros((N, D))
    grad = lambda x, i, key: quadratic_grad(x, DELTA, key, sigma=0.05)

    rows = []
    for name, quantized, bits in [("ad-psgd", False, 32),
                                  ("moniqua-adpsgd", True, 8)]:
        cfg = ADPSGDConfig(topo=ring(N),
                           codec=MoniquaCodec(QuantSpec(bits=bits if quantized
                                                        else 8)),
                           theta=0.5, max_delay=4, quantized=quantized)
        Xf, trace = adpsgd_run(x0, grad, 0.05, iters, cfg,
                               jax.random.PRNGKey(0))
        err = float(np.mean((np.asarray(trace[-1]) - DELTA / 2) ** 2))
        wire = D * bits // 8            # bytes per pairwise exchange
        net = C.NETWORKS[1]             # 1 Gbps / 0.15 ms
        rows.append({
            "algorithm": name, "final_err": err,
            "bytes_per_update": wire,
            "s_per_update_1Gbps": net.step_comm_seconds(wire, 1),
            "finite": bool(np.isfinite(np.asarray(Xf)).all()),
        })
    return {
        "table": rows,
        "notes": ("AD-PSGD analysis model (stale grads tau<=4, random pair "
                  "gossip): Moniqua variant reaches the same error at 1/4 "
                  "the bytes per update — Fig. 2b's 'communication reduced' "
                  "claim. No global barrier in either variant."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
