"""C5 / Figure 2a: decentralized (heterogeneous) data — Moniqua on D^2.

Each worker optimises its own quadratic f_i(x) = ||x - c_i||^2/2 with worker
optima c_i spread wide (outer variance zeta^2 large — the 1-label-per-worker
CIFAR split analog).  With a constant step size, D-PSGD's stationary error
carries an alpha^2 zeta^2 / (1-rho)^2 floor; D^2 cancels it, and Moniqua-D^2
matches D^2 while sending quantized payloads (Theorem 4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.algorithms import get_algorithm

N, D = 8, 32
SPREAD = 5.0          # ||c_i - mean c|| scale: the outer variance
ALPHA = 0.1           # constant step size (the regime D^2 targets)


def _run(algo_name: str, steps: int, seed=0):
    algo = get_algorithm(algo_name)
    # lazier ring: D^2 requires lambda_n > -1/3
    hp = C.default_hyper(theta=2.0, n=N, slack=0.75)
    key = jax.random.PRNGKey(seed)
    c = SPREAD * jax.random.normal(key, (N, D))      # worker optima
    c_bar = jnp.mean(c, axis=0)
    X = jnp.zeros((N, D))
    extra = algo.init(X, hp)

    @jax.jit
    def step(X, extra, k, kk):
        kk, kg, ka = jax.random.split(kk, 3)
        noise = 0.05 * jax.random.normal(kg, (N, D))
        g = X - c + noise                            # grad f_i at x_i
        Xn, extran = algo.step(X, extra, g, ALPHA, k, ka, hp)
        return Xn, extran, kk

    for k in range(steps):
        X, extra, key = step(X, extra, jnp.asarray(k), key)
    # the paper's failure mode is at the LOCAL models: with constant alpha
    # and high outer variance, D-PSGD workers are dragged toward their own
    # optima; the per-worker gradient of the GLOBAL objective stays large.
    per_worker_err = float(jnp.mean(jnp.sum((X - c_bar) ** 2, axis=1)))
    mean_err = float(jnp.sum((jnp.mean(X, 0) - c_bar) ** 2))
    worker_gap = float(jnp.max(jnp.abs(X - jnp.mean(X, 0, keepdims=True))))
    return per_worker_err, mean_err, worker_gap


def run(quick: bool = False) -> dict:
    steps = 300 if quick else 1000
    rows = []
    for algo in ("dpsgd", "d2", "moniqua_d2"):
        werr, merr, gap = _run(algo, steps)
        rows.append({"algorithm": algo, "per_worker_grad_sq": werr,
                     "mean_model_err": merr, "consensus_gap": gap})
    e = {r["algorithm"]: r["per_worker_grad_sq"] for r in rows}
    return {
        "table": rows,
        "dpsgd_over_d2": e["dpsgd"] / max(e["d2"], 1e-12),
        "notes": (f"Heterogeneous quadratics (outer variance ~ {SPREAD}^2), "
                  f"constant alpha={ALPHA}: D-PSGD's LOCAL models stall at "
                  "the alpha^2 zeta^2 consensus floor (per-worker global-"
                  "objective gradient stays large), D^2 cancels the outer-"
                  "variance term, and Moniqua-D^2 matches D^2 at 1/4 wire "
                  "bytes (Fig. 2a / Theorem 4)."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
