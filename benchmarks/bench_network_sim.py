"""Simulated wall-clock-to-target-loss: scenario x codec (repro.sim).

The wall-clock deliverable the paper claims ("converges faster with
respect to wall clock time") needs two ingredients the repo now has:

1. a *real* loss-vs-step trajectory per wire codec — one tiny-LM training
   run per codec through ``CommEngine`` (fp32 / Moniqua / QSGD), so the
   convergence side is measured, not assumed;
2. a *simulated* seconds-per-round per scenario — the codec's exact wire
   bytes priced by the event-driven simulator (``repro.sim``) under each
   named scenario's link and compute models.

Composing them maps every logged loss point to a simulated wall clock, so
"time to reach the fp32 target loss" is comparable across codecs on the
same network.  A second table replays asynchronous AD-PSGD through
``CommEngine.pair_average`` edge by edge on the straggler scenario —
wall clock and gradient staleness from the same event loop.

    PYTHONPATH=src python benchmarks/bench_network_sim.py           # full
    PYTHONPATH=src python benchmarks/bench_network_sim.py --smoke   # CI

Writes ``BENCH_network_sim.json`` at the repo root (the perf-trajectory
artifact CI uploads) and, under ``benchmarks.run``, the usual
``benchmarks/results/bench_network_sim.json``.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import argparse
import json
from typing import Any, Dict, List, Optional

from benchmarks import common as C

# (label, trainer algo, train_run kwargs) — fp32 is D-PSGD's exact gossip;
# the quantized rows swap the CommEngine wire under the same update rule.
# The 1-bit row uses the Table-2 configuration (theta=0.25 + Theorem-3
# slack) that bench_low_bit shows converging at that budget.
CODECS = [
    ("fp32", "dpsgd", {}),
    ("moniqua-1bit", "moniqua",
     dict(wire="moniqua", bits=1, theta=0.25, slack=0.2)),
    ("moniqua-8bit", "moniqua", dict(wire="moniqua", bits=8)),
    ("qsgd-8bit", "moniqua", dict(wire="qsgd", bits=8)),
]

SCENARIOS = ["lan-10gbe-ring", "lan-1gbe-ring", "wan-exponential",
             "straggler-longtail", "bandwidth-starved", "oversubscribed-tor",
             "shared-uplink-ring", "calibrated-from-bench"]
SMOKE_SCENARIOS = ["lan-10gbe-ring", "bandwidth-starved",
                   "oversubscribed-tor"]
SMOKE_CODECS = [c for c in CODECS if c[0] != "moniqua-8bit"]

# the isolated-link twin of each contended scenario — identical NICs,
# alpha, jitter and compute, no shared fabric — so the gap comparison
# isolates contention and nothing else
CONTENTION_BASELINE = {"oversubscribed-tor": "lan-10gbe-ring",
                       "shared-uplink-ring": "lan-1gbe-ring"}

N_WORKERS = 8
TARGET_TOL = 0.05       # target = fp32 final loss * (1 + tol)


def _wallclock_at_step(cum_seconds: List[float], step: int) -> float:
    return cum_seconds[min(step, len(cum_seconds) - 1)]


def _steps_to_target(history: List[Dict], target: float) -> Optional[int]:
    for h in history:
        if h["loss"] <= target:
            return int(h["step"])
    return None


def _async_rows(steps: int) -> List[Dict[str, Any]]:
    """AD-PSGD replay on the straggler scenario: quantized vs exact wire."""
    import jax
    import jax.numpy as jnp

    from repro.comm.engine import CommEngine, FullPrecisionWire, MoniquaWire
    from repro.core.quantizers import QuantSpec
    from repro.core.topology import ring
    from repro.sim import events as SE
    from repro.sim import scenarios as SC

    sc = SC.get_scenario("straggler-longtail", n=N_WORKERS, compute_s=0.01)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (N_WORKERS, 64)) * 0.2

    def grad_fn(x, i, key):        # quadratic f = ||x||^2/2 + noise
        return x + 0.02 * jax.random.normal(key, x.shape)

    rows = []
    for label, codec in [
            ("fp32", FullPrecisionWire()),
            ("moniqua-8bit", MoniquaWire(QuantSpec(bits=8)))]:
        eng = CommEngine(ring(N_WORKERS), codec, backend="jnp")
        out = SE.replay_adpsgd(sc, eng, x0, grad_fn, alpha=0.05,
                               num_updates=steps, theta=2.0)
        tr = out["trace"]
        rows.append({
            "wire": label,
            "updates": tr.count(SE.UPDATE),
            "wall_s": tr.total_seconds,
            "s_per_update": tr.total_seconds / max(tr.count(SE.UPDATE), 1),
            "bytes_on_wire": tr.bytes_on_wire,
            "staleness_mean": tr.staleness_mean,
            "staleness_max": tr.staleness_max,
            "consensus_sq": out["consensus_sq"],
            "mean_abs_x": float(jnp.mean(jnp.abs(out["X"]))),
        })
    return rows


def run(quick: bool = False, smoke: bool = False) -> dict:
    from repro.sim import events as SE
    from repro.sim import scenarios as SC

    scenarios = SMOKE_SCENARIOS if smoke else SCENARIOS
    codecs = SMOKE_CODECS if smoke else CODECS
    steps = 24 if smoke else (40 if quick else 80)
    model = (C.tiny_lm(d_model=32, layers=1, vocab=64) if smoke
             else C.tiny_lm())

    # 1. one training run per (scenario topology x codec): the convergence
    # trajectory gossips on the SAME graph the simulator prices, so bytes,
    # round times, and loss curves are internally consistent per row
    scen_objs = {name: SC.get_scenario(name, n=N_WORKERS)
                 for name in scenarios}
    runs: Dict[str, Dict[str, Dict[str, Any]]] = {}
    for topo_name in sorted({sc.topo.name for sc in scen_objs.values()}):
        runs[topo_name] = {}
        for label, algo, kw in codecs:
            runs[topo_name][label] = C.train_run(
                algo, steps=steps, n_workers=N_WORKERS, model=model,
                topology=topo_name, log_every=max(steps // 20, 1), **kw)

    # target per topology: the fp32 baseline's final loss on that graph
    targets = {t: runs[t]["fp32"]["loss_last"] * (1.0 + TARGET_TOL)
               for t in runs}

    # 2. price every codec's bytes on every scenario
    table: List[Dict[str, Any]] = []
    for scen_name in scenarios:
        sc = scen_objs[scen_name]
        topo_name = sc.topo.name
        m_neighbors = len(sc.topo.neighbor_offsets())
        target = targets[topo_name]
        for label, algo, kw in codecs:
            r = runs[topo_name][label]
            bytes_per_neighbor = r["bytes_per_step"] // m_neighbors
            trace = SE.simulate_sync_rounds(sc, bytes_per_neighbor, steps)
            cum = trace.cumulative_seconds()
            st = _steps_to_target(r["history"], target)
            row = {
                "scenario": scen_name,
                "codec": label,
                "bytes_per_round": r["bytes_per_step"],
                "mean_round_s": trace.mean_round_seconds,
                "final_loss": r["loss_last"],
                "loss_vs_fp32": (r["loss_last"]
                                 / runs[topo_name]["fp32"]["loss_last"]),
                "steps_to_target": st,
                "wallclock_to_target_s": (None if st is None
                                          else _wallclock_at_step(cum, st)),
                "sim_total_s": trace.total_seconds,
            }
            table.append(row)

    # 3. headline check: bandwidth-starved, Moniqua 1-bit vs fp32
    headline: Dict[str, Any] = {}
    bw = [r for r in table if r["scenario"] == "bandwidth-starved"]
    if bw:
        f = next(r for r in bw if r["codec"] == "fp32")
        q = next(r for r in bw if r["codec"] == "moniqua-1bit")
        bw_target = targets[scen_objs["bandwidth-starved"].topo.name]
        if f["wallclock_to_target_s"] and q["wallclock_to_target_s"]:
            headline = {
                "scenario": "bandwidth-starved",
                "fp32_to_target_s": f["wallclock_to_target_s"],
                "moniqua_1bit_to_target_s": q["wallclock_to_target_s"],
                "speedup_x": (f["wallclock_to_target_s"]
                              / q["wallclock_to_target_s"]),
                "loss_within_tol": q["final_loss"] <= bw_target,
            }

    # 4. contention summary: the fp32-vs-1bit gap must WIDEN when the same
    # NICs share an oversubscribed fabric (the claim the CI gate guards)
    def _speedup(scen: str) -> Optional[float]:
        rows = {r["codec"]: r for r in table if r["scenario"] == scen}
        f, q = rows.get("fp32"), rows.get("moniqua-1bit")
        if not (f and q and f["wallclock_to_target_s"]
                and q["wallclock_to_target_s"]):
            return None
        return f["wallclock_to_target_s"] / q["wallclock_to_target_s"]

    contention = []
    for scen, base in CONTENTION_BASELINE.items():
        if scen not in scenarios or base not in scenarios:
            continue
        s_c, s_b = _speedup(scen), _speedup(base)
        if s_c is None or s_b is None:
            continue
        contention.append({
            "scenario": scen, "isolated_baseline": base,
            "speedup_x": s_c, "isolated_speedup_x": s_b,
            "gap_widened": s_c > s_b,
        })

    async_rows = _async_rows(steps=60 if smoke else 200)

    return {
        "table": table,
        "async_table": async_rows,
        "target_loss": targets,
        "headline": headline,
        "contention": contention,
        "notes": (
            "Wall-clock-to-target-loss per (scenario x codec): loss "
            "trajectories are measured tiny-LM training runs through "
            "CommEngine (one per scenario-topology x wire codec, gossiping "
            "on the same graph the simulator prices), wall clock is the "
            "event-driven repro.sim prediction for those exact wire bytes "
            "under each scenario's alpha-beta links and compute model. "
            "Target = fp32 final loss * 1.05. On bandwidth-starved links "
            "the fp32 payload dominates the round so Moniqua 1-bit wins "
            "wall clock at matched loss; on the 10GbE LAN all codecs tie "
            "(compute-bound) — the codec only pays off when the network "
            "is the bottleneck, which is the paper's Fig. 1 story. "
            "async_table replays AD-PSGD through CommEngine.pair_average "
            "on the straggler scenario: same event loop yields wall clock, "
            "bytes, and gradient staleness. The contention rows compare "
            "each contended-fabric scenario (shared ToR uplinks / shared "
            "medium, priced by the water-filling fluid solver in "
            "repro.sim.contention) against its isolated-link twin: "
            "concurrent fp32 payloads slow each other down, so the "
            "fp32-vs-1bit gap widens beyond what isolated links predict. "
            "calibrated-from-bench prices links an alpha-beta least-"
            "squares fit produced (repro.sim.calibrate), not datasheet "
            "constants."),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model, 2 scenarios, 3 codecs (CI)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="output path; defaults to BENCH_network_sim.json "
                         "at the repo root (BENCH_network_sim.smoke.json "
                         "under --smoke, so a smoke run never clobbers the "
                         "committed full-run trajectory)")
    args = ap.parse_args(argv)
    if args.out is None:
        name = ("BENCH_network_sim.smoke.json" if args.smoke
                else "BENCH_network_sim.json")
        args.out = os.path.join(_ROOT, name)
    result = run(quick=args.quick, smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, default=float)
    print(C.markdown_table(result["table"]))
    print("-- async_table --")
    print(C.markdown_table(result["async_table"]))
    if result["contention"]:
        print("-- contention --")
        print(C.markdown_table(result["contention"]))
    print(f"headline: {result['headline']}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
