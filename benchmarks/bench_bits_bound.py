"""C7 / Sec. 4 'Bound on the Bits': B <= ceil(log2(4 log2(16n)/(1-rho) + 3)),
independent of model dimension d and growing O(log log n).

Empirical leg: run Moniqua at the theory-prescribed (delta, theta) for a
ring of 8 and confirm convergence at that bit width.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import theta as TH
from repro.core.quantizers import bits_for_delta
from repro.core.topology import exponential, ring


def run(quick: bool = False) -> dict:
    rows = []
    for n in (4, 8, 16, 64, 256, 1024, 4096):
        r_ring, r_exp = ring(n), exponential(n)
        rows.append({
            "n": n,
            "ring_rho": r_ring.rho,
            "ring_bits_bound": TH.bits_bound(n, r_ring.rho),
            "exp_rho": r_exp.rho,
            "exp_bits_bound": TH.bits_bound(n, r_exp.rho),
        })

    # empirical: theory-prescribed delta for ring(8) -> bits -> converge?
    n = 8
    topo = ring(n)
    delta = TH.delta_dpsgd(n, topo.rho)
    bits = min(bits_for_delta(delta), 8)
    steps = 300 if quick else 800
    hp = C.default_hyper(bits=bits, theta=0.5, n=n)
    res = C.quadratic_run("moniqua", hp, n=n, steps=steps)

    # dimension independence: same bits bound regardless of d (definitional,
    # but the empirical error at two d's shows no dimension blow-up)
    res_d4 = C.quadratic_run("moniqua", hp, n=n, d=4, steps=steps)

    return {
        "table": rows,
        "theory_delta_ring8": delta,
        "bits_used_ring8": bits,
        "final_grad_sq_d32": res["final_grad_sq"],
        "final_grad_sq_d4": res_d4["final_grad_sq"] * 8,  # per-coord scaled
        "notes": ("The bound is O(log log n) at FIXED rho (Sec. 4); on a "
                  "ring rho itself degrades as 1 - O(1/n^2), so the bound "
                  "grows ~log n there — the exponential graph keeps rho "
                  "bounded away from 1 and shows the flat O(log log n) "
                  "behaviour (9 bits at n=4096). Empirically the theory-"
                  "prescribed width converges on ring(8). Bound is "
                  "d-independent by construction."),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(quick=True), indent=2, default=float))
