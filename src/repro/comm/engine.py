"""CommEngine: one pluggable communication engine for decentralized SGD.

Every decentralized algorithm in this repo reduces its communication to the
same primitive — *one gossip round*: encode the local model, circulate the
payload along the topology (``jnp.roll`` on the stacked worker axis, which is
one ``collective-permute`` on the production mesh), decode each neighbor
against the local reference, and accumulate the weighted consensus step

    X_{k+1/2}[i] = x_i + sum_{o != 0} w_o * (xhat_{i+o} - xhat_self)     (*)

``CommEngine`` owns that round end-to-end and exposes the three seams the
paper's algorithm zoo (and every future scaling PR) plugs into:

* **codec** — what rides on the wire: ``FullPrecisionWire`` (D-PSGD baseline;
  (*) then collapses to the circulant ``X W``), ``MoniquaWire`` (Algorithm 1's
  bit-packed modulo residue, no scales, no extra state), or ``QSGDWire``
  (Alistarh et al. 2017 scale+codes, the obvious external comparison).
* **topology** — any circulant :class:`~repro.core.topology.Topology`; the
  weights are static so they compile into the mixing (and into the fused
  kernel's unrolled reduction).
* **backend** — ``"jnp"`` lowers everywhere (pure jnp, used by the CPU
  convergence experiments), ``"pallas"`` uses the fused TPU kernels
  (``kernels/moniqua_encode.py`` + ``kernels/moniqua_decode_reduce.py``),
  ``"auto"`` picks Pallas on TPU.  Both Moniqua backends draw stochastic
  rounding from the same counter-based hash of (seed, element index), so they
  agree **bit-exactly** in interpret mode — the parity contract
  ``tests/test_engine.py`` enforces.

Why the fused backend matters: the legacy path
(``comm/gossip.py::moniqua_gossip``) decodes every neighbor payload into a
full f32 model copy before reducing — ``m`` extra HBM materializations per
round.  The fused decode-reduce kernel unpacks all payloads, applies the
modulo recovery and accumulates the weighted delta in VMEM, writing the mixed
result once (HBM-traffic model in ``docs/kernels.md``).

Bytes accounting is trace-time bookkeeping: ``mix(..., ledger=...)`` records
payload-bytes-per-worker into a :class:`~repro.comm.gossip.BytesLedger`, and
``bytes_per_round`` returns the same number without running anything — the
input to the analytic network model in ``benchmarks/``.

Sharded meshes: the Moniqua backends tile each worker's slice separately
(``kernels/ops.py`` stacked wrappers vmap the tile layout over the worker
axis), so the only cross-worker traffic in a round is the packed
collective-permute of the payload, and — because every worker hashes the
same (seed, element) pairs — stochastic rounding uses Supp.-C shared
randomness exactly: identical models encode to identical payloads on
every worker.

Bucketing: by default the engine does not gossip leaf by leaf.  A cached
:class:`~repro.comm.bucket.BucketLayout` flattens the whole stacked pytree
into one contiguous per-worker buffer, so a round is one encode launch,
one packed roll per offset (the whole-model collective-permute), one fused
decode-reduce, and one scatter back to leaves — the per-leaf fixed costs
(kernel dispatch and, above all, the 256x1024 tile-grid pad that turns a
64-element bias into 262k elements of codec work) are paid once per round
instead of once per leaf.  ``bucketed=False`` keeps the per-leaf path as
the parity reference; ``benchmarks/bench_comm_fusion.py`` measures the
gap and commits it to ``BENCH_comm_fusion.json``.

Wall-clock prediction: the byte counts this engine produces feed the
event-driven simulator (``repro.sim``), which prices them under explicit
link/compute models per named scenario — see ``docs/simulator.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import bucket, gossip
from repro.comm.gossip import BytesLedger
from repro.core import modulo
from repro.core.quantizers import (QuantSpec, packed_last_dim, qsgd_decode,
                                   qsgd_decode_segmented, qsgd_encode,
                                   qsgd_encode_segmented, qsgd_payload_bytes)
from repro.core.topology import Topology
from repro.kernels import ops as kops
from repro.kernels import ref as kref

PyTree = Any

WIRES = ("full", "moniqua", "qsgd")
BACKENDS = ("auto", "jnp", "pallas")


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


# ---------------------------------------------------------------------------
# Wire codecs: what one worker broadcasts per round.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FullPrecisionWire:
    """Identity codec: the raw model rides the wire (D-PSGD / D2 baseline)."""
    name = "full"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        return int(np.prod(shape, dtype=np.int64)) * itemsize


@dataclasses.dataclass(frozen=True)
class MoniquaWire:
    """Algorithm 1's packed modulo residue: ``bits/8`` bytes/param, no scales."""
    spec: QuantSpec = QuantSpec()
    name = "moniqua"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        if not shape:
            return 1
        inner = int(np.prod(shape[:-1], dtype=np.int64))
        return inner * packed_last_dim(shape[-1], self.spec.bits)


@dataclasses.dataclass(frozen=True)
class QSGDWire:
    """Scale+codes codec: packed codes + one f32 max-norm scale per tensor."""
    spec: QuantSpec = QuantSpec()
    name = "qsgd"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        return qsgd_payload_bytes(shape, self.spec.bits)


def make_wire(name: str, spec: Optional[QuantSpec] = None):
    spec = spec or QuantSpec()
    if name == "full":
        return FullPrecisionWire()
    if name == "moniqua":
        return MoniquaWire(spec)
    if name == "qsgd":
        return QSGDWire(spec)
    raise ValueError(f"unknown wire codec {name!r}; one of {WIRES}")


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

def _leaf_seed(base_seed: jax.Array, leaf_idx: int) -> jax.Array:
    """Distinct deterministic hash seed per pytree leaf (both backends)."""
    return jnp.asarray(base_seed, jnp.uint32) ^ jnp.uint32(
        (leaf_idx * 0x9E3779B1) & 0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class CommEngine:
    """One gossip round, end-to-end: codec x topology x backend + accounting.

    Static (hashable) configuration only — per-round dynamics (``theta``, the
    PRNG key, the ledger) are call arguments, so an engine can be constructed
    freely inside a jitted step function.

    ``bucketed`` (default) flattens the whole stacked pytree into one
    contiguous per-worker staging buffer (``comm/bucket.py``) so a round
    costs **one** encode launch, **one** packed payload roll per offset,
    and **one** fused decode-reduce — instead of that trio per leaf, each
    with its own pad to the 256x1024 tile grid.  The per-leaf path stays
    behind ``bucketed=False`` as the parity reference; both draw the same
    stochastic-rounding uniforms per element (global counter indices), so
    they are bit-exact against each other for the Moniqua wire.
    """
    topo: Topology
    codec: Any = dataclasses.field(default_factory=MoniquaWire)
    backend: str = "auto"
    bucketed: bool = True

    # -- the tentpole primitive --------------------------------------------
    def mix(self, X: PyTree, theta=None, key: Optional[jax.Array] = None,
            ledger: Optional[BytesLedger] = None) -> PyTree:
        """One gossip round on stacked models (leaves ``[n, ...]``).

        Returns ``X_{k+1/2}``; with the full-precision codec this is exactly
        the circulant ``X W`` of ``gossip.mix``.  ``ledger`` (if given) is
        credited at trace time with payload-bytes * n_neighbors per round.
        """
        offsets = self.topo.neighbor_offsets()
        if not offsets:                      # single worker: nothing on wire
            return X
        if not jax.tree.leaves(X):           # empty pytree: nothing to mix
            return X
        if ledger is not None:
            self._record(X, ledger)
        if self.codec.name == "moniqua" and theta is None:
            raise ValueError("MoniquaWire needs the a-priori bound theta")
        if self.bucketed:
            return self._mix_bucketed(X, theta, key)
        if self.codec.name == "full":
            return gossip.mix(X, self.topo)
        backend = resolve_backend(self.backend)
        self._require_key(key)
        base_seed = kops._key_to_seed(key)
        leaves, td = jax.tree.flatten(X)
        if self.codec.name == "moniqua":
            # global counter indices: leaf i's elements hash
            # (seed, layout.offset_i + e), the SAME pairs the bucketed
            # one-shot encode hashes — the bucketed-vs-per-leaf parity
            layout = self.layout(X)
            out = [self._mix_leaf(l, theta, base_seed, backend,
                                  idx_base=layout.offsets[i])
                   for i, l in enumerate(leaves)]
        else:
            out = [self._mix_leaf(l, theta, _leaf_seed(base_seed, i), backend)
                   for i, l in enumerate(leaves)]
        return jax.tree.unflatten(td, out)

    # -- bucketed round: one encode, one roll per offset, one reduce -------
    def _mix_bucketed(self, X: PyTree, theta,
                      key: Optional[jax.Array]) -> PyTree:
        offsets = self.topo.neighbor_offsets()
        weights = self._neighbor_weights()
        layout = self.layout(X)
        if self.codec.name == "full" and not layout.uniform_dtype:
            # mixed-dtype raw wire: f32 staging would change the mixing
            # arithmetic (bf16 rolls accumulate in bf16 per leaf), breaking
            # the `mix == gossip.mix` contract — and the full wire has no
            # per-leaf encode/pad cost to amortize, so fall back per leaf
            return gossip.mix(X, self.topo)
        flat = layout.flatten(X)             # [n, D] staging buffer
        if self.codec.name == "full":
            return layout.unflatten(gossip.mix(flat, self.topo))
        backend = resolve_backend(self.backend)
        self._require_key(key)
        seed = kops._key_to_seed(key)
        spec = self.codec.spec
        if self.codec.name == "moniqua":
            B = modulo.b_theta(theta, spec.delta)
            packed = kops.moniqua_encode_stacked(flat, B, spec, seed,
                                                 backend=backend)
            p_nbrs = jnp.stack([gossip._roll(packed, o) for o in offsets])
            out = kops.moniqua_decode_reduce_stacked(packed, p_nbrs, flat, B,
                                                     weights, spec,
                                                     backend=backend)
            return layout.unflatten(out)
        # qsgd on the flat buffer, with per-tensor scale granularity kept
        # (segment slices of the bucket); one decode per neighbor replaces
        # the per-leaf qsgd_decode copies
        seg = layout.segment_sizes
        packed, scales = qsgd_encode_segmented(flat, spec, seed, seg)
        xq_self = qsgd_decode_segmented(packed, scales, spec, seg)
        acc = None
        for o, w in zip(offsets, weights):
            xq_j = qsgd_decode_segmented(gossip._roll(packed, o),
                                         gossip._roll(scales, o), spec, seg)
            t = (xq_j - xq_self) * w
            acc = t if acc is None else acc + t
        out = (flat.astype(jnp.float32) + acc).astype(flat.dtype)
        return layout.unflatten(out)

    def _mix_leaf(self, x: jax.Array, theta, seed: jax.Array,
                  backend: str, idx_base=0) -> jax.Array:
        if x.ndim == 1:      # scalar-per-worker leaf: give it a unit last axis
            return self._mix_leaf(x[:, None], theta, seed, backend,
                                  idx_base)[:, 0]
        offsets = self.topo.neighbor_offsets()
        weights = self._neighbor_weights()
        if self.codec.name == "moniqua":
            spec = self.codec.spec
            B = modulo.b_theta(theta, spec.delta)
            # per-worker tiling: each worker's slice is encoded/decoded in
            # its own tile grid (kops stacked wrappers), so only the packed
            # payload roll crosses the worker axis and all workers share
            # one rounding-uniform stream per element (Supp. C)
            packed = kops.moniqua_encode_stacked(x, B, spec, seed,
                                                 backend=backend,
                                                 idx_base=idx_base)
            p_nbrs = jnp.stack([gossip._roll(packed, o) for o in offsets])
            return kops.moniqua_decode_reduce_stacked(packed, p_nbrs, x, B,
                                                      weights, spec,
                                                      backend=backend)
        # qsgd: reference-free decode; each worker ships (codes, own scale)
        spec = self.codec.spec
        packed, scale = qsgd_encode(x, spec, seed)
        xq_self = qsgd_decode(packed, scale, spec, x.shape[-1])
        acc = None
        for o, w in zip(offsets, weights):
            xq_j = qsgd_decode(gossip._roll(packed, o),
                               gossip._roll(scale, o), spec, x.shape[-1])
            t = (xq_j - xq_self) * w
            acc = t if acc is None else acc + t
        return (x.astype(jnp.float32) + acc).astype(x.dtype)

    # -- layout plumbing ---------------------------------------------------
    def _align(self) -> int:
        """Row alignment of the flat buffer: values-per-byte for packed
        codecs (keeps per-leaf byte boundaries), 1 for the raw wire."""
        spec = getattr(self.codec, "spec", None)
        return spec.values_per_byte if spec is not None else 1

    def layout(self, X: PyTree) -> bucket.BucketLayout:
        """The (memoized) flat-buffer layout this engine uses for ``X``.

        Accepts abstract ``ShapeDtypeStruct`` trees, so callers (trainer,
        dryrun) can build the layout once outside jit; traced rounds then
        hit the cache with the identical static description.
        """
        return bucket.layout_of(X, self._align())

    def _neighbor_weights(self) -> Tuple[float, ...]:
        return tuple(w for o, w in zip(self.topo.offsets, self.topo.weights)
                     if o % self.topo.n != 0)

    def _require_key(self, key) -> None:
        """Stochastic rounding without a key would silently reuse seed 0
        every round, losing the across-step unbiasedness the convergence
        argument needs — fail loudly instead (matches the legacy path)."""
        spec = getattr(self.codec, "spec", None)
        if key is None and spec is not None and spec.stochastic:
            raise ValueError(
                f"{self.codec.name} wire with stochastic rounding needs a "
                "PRNG key (pass key=, or use a nearest-rounding QuantSpec)")

    # -- AD-PSGD's primitive: one edge exchange ----------------------------
    def pair_average(self, xi: jax.Array, xj: jax.Array, theta=None,
                     key: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
        """One gossip on edge (i, j) with the pair-averaging ``W_k``.

        Quantized codecs exchange payloads and decode against each endpoint's
        own model (Algorithm 3 lines 4-7); both endpoints encode under the
        same seed (shared randomness).  Simulator-scale API: always pure-jnp
        (AD-PSGD runs under ``lax.scan`` on host devices).
        """
        if self.codec.name == "full":
            avg = 0.5 * (xi + xj)
            return avg, avg
        self._require_key(key)
        seed = kops._key_to_seed(key)
        if self.codec.name == "moniqua":
            spec = self.codec.spec
            B = modulo.b_theta(theta, spec.delta)
            pi = kops.moniqua_encode_jnp(xi, B, spec, seed)
            pj = kops.moniqua_encode_jnp(xj, B, spec, seed)
            n_last = xi.shape[-1]

            def val(p):
                return kref.value_ref(p, B, spec.bits)[..., :n_last]

            xj_at_i = modulo.recover(val(pj), xi, B)
            xi_at_j = modulo.recover(val(pi), xj, B)
            xi_self = modulo.local_bias(val(pi), xi, B)
            xj_self = modulo.local_bias(val(pj), xj, B)
            return (xi + 0.5 * (xj_at_i - xi_self),
                    xj + 0.5 * (xi_at_j - xj_self))
        spec = self.codec.spec
        pi, si = qsgd_encode(xi, spec, seed, worker_axis=False)
        pj, sj = qsgd_encode(xj, spec, seed, worker_axis=False)
        qi = qsgd_decode(pi, si, spec, xi.shape[-1])
        qj = qsgd_decode(pj, sj, spec, xj.shape[-1])
        return xi + 0.5 * (qj - qi), xj + 0.5 * (qi - qj)

    # -- gossip building blocks shared by the algorithm zoo ----------------
    def neighbor_sum(self, X: PyTree, transform) -> PyTree:
        """``sum_{o != 0} w_o * transform(roll(X, -o), o)`` leaf-wise."""
        return gossip.neighbor_sum(X, self.topo, transform)

    def self_weight(self) -> float:
        return gossip.self_weight(self.topo)

    # -- accounting --------------------------------------------------------
    def payload_bytes_per_broadcast(self, X: PyTree) -> int:
        """Bytes one worker ships to ONE neighbor per round.

        Bucketed rounds roll the packed flat buffer plus, for qsgd, the
        per-tensor scale vector; per-leaf rounds roll each leaf's payload.
        The vpb row alignment makes the bucketed Moniqua payload equal the
        per-leaf sum exactly — the tile-grid pad is sliced off before the
        roll and never rides the wire — and bucketed qsgd keeps one
        4-byte scale per tensor, so its bytes match the per-leaf sum too.
        A mixed-dtype tree on the ``full`` wire mixes per leaf (f32
        staging would change the arithmetic), so its bytes are the
        per-leaf sum as well.
        """
        if not jax.tree.leaves(X):
            return 0
        if self.bucketed:
            layout = self.layout(X)
            if self.codec.name == "full":
                if not layout.uniform_dtype:   # per-leaf fallback path
                    return sum(self.codec.payload_bytes(
                        leaf.shape[1:], leaf.dtype.itemsize)
                        for leaf in jax.tree.leaves(X))
                return layout.total_elems * jnp.dtype(
                    layout.stage_dtype).itemsize
            spec = self.codec.spec
            nbytes = layout.padded_elems // spec.values_per_byte
            if self.codec.name == "qsgd":
                nbytes += 4 * layout.num_leaves
            return nbytes
        return sum(self.codec.payload_bytes(leaf.shape[1:],
                                            leaf.dtype.itemsize)
                   for leaf in jax.tree.leaves(X))

    def bytes_per_round(self, X: PyTree) -> int:
        """Payload bytes *sent* per worker per gossip round (all leaves)."""
        m = len(self.topo.neighbor_offsets())
        return self.payload_bytes_per_broadcast(X) * m

    def _record(self, X: PyTree, ledger: BytesLedger) -> None:
        ledger.add(self.payload_bytes_per_broadcast(X),
                   len(self.topo.neighbor_offsets()))
