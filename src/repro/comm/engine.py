"""CommEngine: one pluggable communication engine for decentralized SGD.

Every decentralized algorithm in this repo reduces its communication to the
same primitive — *one gossip round*: encode the local model, circulate the
payload along the topology (``jnp.roll`` on the stacked worker axis, which is
one ``collective-permute`` on the production mesh), decode each neighbor
against the local reference, and accumulate the weighted consensus step

    X_{k+1/2}[i] = x_i + sum_{o != 0} w_o * (xhat_{i+o} - xhat_self)     (*)

``CommEngine`` owns that round end-to-end and exposes the three seams the
paper's algorithm zoo (and every future scaling PR) plugs into:

* **codec** — what rides on the wire: ``FullPrecisionWire`` (D-PSGD baseline;
  (*) then collapses to the circulant ``X W``), ``MoniquaWire`` (Algorithm 1's
  bit-packed modulo residue, no scales, no extra state), ``QSGDWire``
  (Alistarh et al. 2017 scale+codes, the obvious external comparison), or
  the *stateful* error-feedback family — ``EFQSGDWire`` and ``OneBitWire``
  (1-bit Adam-style warmup + sign codes) — which carry a per-worker
  ``WireState`` pytree (EF residual + warmup counter) as an explicit
  jit-safe carry through ``mix``/``pair_average``; see ``docs/codecs.md``.
* **topology** — any circulant :class:`~repro.core.topology.Topology`; the
  weights are static so they compile into the mixing (and into the fused
  kernel's unrolled reduction).
* **backend** — ``"jnp"`` lowers everywhere (pure jnp, used by the CPU
  convergence experiments), ``"pallas"`` uses the fused TPU kernels
  (``kernels/moniqua_encode.py`` + ``kernels/moniqua_decode_reduce.py``),
  ``"auto"`` picks Pallas on TPU.  Both Moniqua backends draw stochastic
  rounding from the same counter-based hash of (seed, element index), so they
  agree **bit-exactly** in interpret mode — the parity contract
  ``tests/test_engine.py`` enforces.

Why the fused backend matters: the legacy path
(``comm/gossip.py::moniqua_gossip``) decodes every neighbor payload into a
full f32 model copy before reducing — ``m`` extra HBM materializations per
round.  The fused decode-reduce kernel unpacks all payloads, applies the
modulo recovery and accumulates the weighted delta in VMEM, writing the mixed
result once (HBM-traffic model in ``docs/kernels.md``).

Bytes accounting is trace-time bookkeeping: ``mix(..., ledger=...)`` records
payload-bytes-per-worker into a :class:`~repro.comm.gossip.BytesLedger`, and
``bytes_per_round`` returns the same number without running anything — the
input to the analytic network model in ``benchmarks/``.

Sharded meshes: the Moniqua backends tile each worker's slice separately
(``kernels/ops.py`` stacked wrappers vmap the tile layout over the worker
axis), so the only cross-worker traffic in a round is the packed
collective-permute of the payload, and — because every worker hashes the
same (seed, element) pairs — stochastic rounding uses Supp.-C shared
randomness exactly: identical models encode to identical payloads on
every worker.

Bucketing: by default the engine does not gossip leaf by leaf.  A cached
:class:`~repro.comm.bucket.BucketLayout` flattens the whole stacked pytree
into one contiguous per-worker buffer, so a round is one encode launch,
one packed roll per offset (the whole-model collective-permute), one fused
decode-reduce, and one scatter back to leaves — the per-leaf fixed costs
(kernel dispatch and, above all, the 256x1024 tile-grid pad that turns a
64-element bias into 262k elements of codec work) are paid once per round
instead of once per leaf.  ``bucketed=False`` keeps the per-leaf path as
the parity reference; ``benchmarks/bench_comm_fusion.py`` measures the
gap and commits it to ``BENCH_comm_fusion.json``.

Wall-clock prediction: the byte counts this engine produces feed the
event-driven simulator (``repro.sim``), which prices them under explicit
link/compute models per named scenario — see ``docs/simulator.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import bucket, gossip
from repro.comm.gossip import BytesLedger
from repro.core import modulo
from repro.core.quantizers import (QuantSpec, ef_qsgd_encode_segmented,
                                   onebit_decode_segmented,
                                   onebit_encode_segmented,
                                   onebit_payload_bytes, packed_last_dim,
                                   qsgd_decode, qsgd_decode_segmented,
                                   qsgd_encode, qsgd_encode_segmented,
                                   qsgd_payload_bytes)
from repro.core.topology import Topology
from repro.kernels import ops as kops
from repro.kernels import ref as kref

PyTree = Any

WIRES = ("full", "moniqua", "qsgd", "ef_qsgd", "onebit")
BACKENDS = ("auto", "jnp", "pallas")


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


# ---------------------------------------------------------------------------
# Wire codecs: what one worker broadcasts per round.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FullPrecisionWire:
    """Identity codec: the raw model rides the wire (D-PSGD / D2 baseline)."""
    name = "full"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        return int(np.prod(shape, dtype=np.int64)) * itemsize


@dataclasses.dataclass(frozen=True)
class MoniquaWire:
    """Algorithm 1's packed modulo residue: ``bits/8`` bytes/param, no scales."""
    spec: QuantSpec = QuantSpec()
    name = "moniqua"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        if not shape:
            return 1
        inner = int(np.prod(shape[:-1], dtype=np.int64))
        return inner * packed_last_dim(shape[-1], self.spec.bits)


@dataclasses.dataclass(frozen=True)
class QSGDWire:
    """Scale+codes codec: packed codes + one f32 max-norm scale per tensor."""
    spec: QuantSpec = QuantSpec()
    name = "qsgd"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        return qsgd_payload_bytes(shape, self.spec.bits)


@dataclasses.dataclass(frozen=True)
class EFQSGDWire:
    """Error-feedback QSGD (Tang et al. 2019 style): quantize ``x + residual``
    with the scale+codes wire, keep ``residual' = x + residual - decode(sent)``
    per worker.  Stateful: pays one f32 residual buffer per worker (Θ(nd)
    graph-wide) — the memory axis ``BENCH_memory_overhead.json`` prices
    against Moniqua's zero-extra-state wire."""
    spec: QuantSpec = dataclasses.field(default_factory=QuantSpec)
    name = "ef_qsgd"
    stateful = True

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        return qsgd_payload_bytes(shape, self.spec.bits)


@dataclasses.dataclass(frozen=True)
class OneBitWire:
    """1-bit Adam-style compressed wire: full-precision gossip for the first
    ``warmup`` rounds, then 1-bit sign codes of the compensated value (with
    per-segment cluster-mean levels) and an error-feedback residual.  The
    carried step counter is the ``need_reset``-style hook: crossing it flips
    the round's codec inside the jitted step (a ``jnp.where`` select — see
    ``_ef_flat_round``), and checkpointing the counter resumes the schedule
    bit-identically."""
    spec: QuantSpec = dataclasses.field(
        default_factory=lambda: QuantSpec(bits=1, stochastic=False))
    warmup: int = 16
    name = "onebit"
    stateful = True

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        """Steady-state (post-warmup) bytes; warmup rounds ship f32
        (``warmup_payload_bytes``) — accounting reports the steady state."""
        return onebit_payload_bytes(shape)

    def warmup_payload_bytes(self, shape: Tuple[int, ...],
                             itemsize: int = 4) -> int:
        return int(np.prod(shape, dtype=np.int64)) * 4 if shape else 4


def make_wire(name: str, spec: Optional[QuantSpec] = None, warmup: int = 16):
    spec = spec or QuantSpec()
    if name == "full":
        return FullPrecisionWire()
    if name == "moniqua":
        return MoniquaWire(spec)
    if name == "qsgd":
        return QSGDWire(spec)
    if name == "ef_qsgd":
        return EFQSGDWire(spec)
    if name == "onebit":
        # the sign path is 1 bit by construction; keep the caller's
        # stochastic/nearest choice but pin the packable width
        return OneBitWire(dataclasses.replace(spec, bits=1), warmup=warmup)
    raise ValueError(f"unknown wire codec {name!r}; one of {WIRES}")


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

def _leaf_seed(base_seed: jax.Array, leaf_idx: int) -> jax.Array:
    """Distinct deterministic hash seed per pytree leaf (both backends)."""
    return jnp.asarray(base_seed, jnp.uint32) ^ jnp.uint32(
        (leaf_idx * 0x9E3779B1) & 0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class CommEngine:
    """One gossip round, end-to-end: codec x topology x backend + accounting.

    Static (hashable) configuration only — per-round dynamics (``theta``, the
    PRNG key, the ledger) are call arguments, so an engine can be constructed
    freely inside a jitted step function.

    ``bucketed`` (default) flattens the whole stacked pytree into one
    contiguous per-worker staging buffer (``comm/bucket.py``) so a round
    costs **one** encode launch, **one** packed payload roll per offset,
    and **one** fused decode-reduce — instead of that trio per leaf, each
    with its own pad to the 256x1024 tile grid.  The per-leaf path stays
    behind ``bucketed=False`` as the parity reference; both draw the same
    stochastic-rounding uniforms per element (global counter indices), so
    they are bit-exact against each other for the Moniqua wire.

    ``telemetry`` (static, default off) makes ``mix`` additionally return a
    round-health dict (``repro.obs.metrics``): consensus inf-distance and
    theta headroom, the modulo alias sentinel, EF residual norm, warmup
    indicator, payload bits/param.  Stateless wires then return
    ``(X, health)``, stateful ones ``(X, state, health)``.  The telemetry
    is purely observational — computed from the round's own flat buffer /
    payload / state with pure jnp, feeding nothing back into the mix — so
    the mixed output (and payload and WireState) is bit-exact with the
    flag on or off, and the health values themselves are identical across
    backends and gossip paths (always evaluated on the canonical flat
    buffer with the jnp reference encode, which is bitwise equal to the
    Pallas and per-leaf payloads by the parity contracts).  When off, the
    flag is a Python-level branch: the telemetry graph is never traced,
    hence dead-code-free under jit.
    """
    topo: Topology
    codec: Any = dataclasses.field(default_factory=MoniquaWire)
    backend: str = "auto"
    bucketed: bool = True
    telemetry: bool = False

    # -- persistent per-worker codec state (WireState) ---------------------
    @property
    def stateful(self) -> bool:
        """True for wires carrying per-worker state (EF residuals) across
        rounds; their ``mix`` takes a ``state`` carry and returns
        ``(X, new_state)`` — thread it like ``theta``, checkpoint it like
        params (``checkpoint/ckpt.py`` serializes it inside trainer state)."""
        return bool(getattr(self.codec, "stateful", False))

    def init_wire_state(self, X: PyTree) -> dict:
        """Fresh ``WireState`` for a stacked pytree (``{}`` for stateless
        wires).  Accepts abstract ``ShapeDtypeStruct`` trees — only shapes
        are read, so trainers can build it under ``jax.eval_shape``.

        The residual lives in the *flat bucket domain* ``[n, padded_elems]``
        (one f32 per row-aligned element): both the bucketed and the
        per-leaf gossip paths read and write the same canonical buffer,
        which is what lets them produce bit-identical post-round state.
        """
        if not self.stateful:
            return {}
        layout = self.layout(X)
        return {"residual": jnp.zeros((layout.n_workers,
                                       layout.padded_elems), jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def wire_state_bytes(self, X: PyTree) -> int:
        """Per-worker bytes of persistent codec state (Tables 1-2 memory
        column): 0 for full/moniqua/qsgd, residual + counter for EF wires."""
        if not self.stateful or not jax.tree.leaves(X):
            return 0
        return self.layout(X).padded_elems * 4 + 4

    # -- the tentpole primitive --------------------------------------------
    def mix(self, X: PyTree, theta=None, key: Optional[jax.Array] = None,
            ledger: Optional[BytesLedger] = None,
            state: Optional[dict] = None) -> PyTree:
        """One gossip round on stacked models (leaves ``[n, ...]``).

        Returns ``X_{k+1/2}``; with the full-precision codec this is exactly
        the circulant ``X W`` of ``gossip.mix``.  ``ledger`` (if given) is
        credited at trace time with payload-bytes * n_neighbors per round.

        Stateful wires (``self.stateful``) additionally require the
        ``state`` carry from :meth:`init_wire_state` and return
        ``(X_{k+1/2}, new_state)`` — an explicit jit-safe carry, exactly
        like ``theta``.

        With ``telemetry=True`` a round-health dict rides along as the
        final element of the return: ``(X, health)`` stateless,
        ``(X, state, health)`` stateful (see the class docstring).
        """
        if self.stateful:
            if not isinstance(state, dict) or "residual" not in state:
                raise ValueError(
                    f"{self.codec.name} wire is stateful: pass "
                    "state=engine.init_wire_state(X) and thread the "
                    "returned (X, state) carry across rounds")
            offsets = self.topo.neighbor_offsets()
            if not offsets or not jax.tree.leaves(X):
                if self.telemetry:           # nothing on the wire
                    from repro.obs import metrics as obs_metrics
                    return X, state, obs_metrics.round_health_zero()
                return X, state
            if ledger is not None:
                self._record(X, ledger)
            Xm, new_state = self._mix_stateful(X, state, key)
            if self.telemetry:
                return Xm, new_state, self._round_health(X, theta, key,
                                                         new_state)
            return Xm, new_state
        offsets = self.topo.neighbor_offsets()
        if not offsets or not jax.tree.leaves(X):
            # single worker or empty pytree: nothing on the wire
            if self.telemetry:
                from repro.obs import metrics as obs_metrics
                return X, obs_metrics.round_health_zero()
            return X
        if ledger is not None:
            self._record(X, ledger)
        if self.codec.name == "moniqua" and theta is None:
            raise ValueError("MoniquaWire needs the a-priori bound theta")
        if self.bucketed:
            Xm = self._mix_bucketed(X, theta, key)
        elif self.codec.name == "full":
            Xm = gossip.mix(X, self.topo)
        else:
            backend = resolve_backend(self.backend)
            self._require_key(key)
            base_seed = kops._key_to_seed(key)
            leaves, td = jax.tree.flatten(X)
            if self.codec.name == "moniqua":
                # global counter indices: leaf i's elements hash
                # (seed, layout.offset_i + e), the SAME pairs the bucketed
                # one-shot encode hashes — the bucketed-vs-per-leaf parity
                layout = self.layout(X)
                out = [self._mix_leaf(l, theta, base_seed, backend,
                                      idx_base=layout.offsets[i])
                       for i, l in enumerate(leaves)]
            else:
                out = [self._mix_leaf(l, theta, _leaf_seed(base_seed, i),
                                      backend)
                       for i, l in enumerate(leaves)]
            Xm = jax.tree.unflatten(td, out)
        if self.telemetry:
            return Xm, self._round_health(X, theta, key, None)
        return Xm

    # -- round health (telemetry=True) -------------------------------------
    def _round_health(self, X: PyTree, theta, key: Optional[jax.Array],
                      new_state: Optional[dict]) -> dict:
        """Health counters for the round just mixed (``repro.obs.metrics``).

        Always evaluated on the canonical flat bucket buffer with pure-jnp
        math, so the values are identical whichever backend or gossip path
        produced the mix: the per-leaf payloads concatenate to the bucketed
        one bitwise (PR-4 parity), and the jnp reference encode equals the
        Pallas kernel bitwise (PR-1 parity).  On the bucketed moniqua path
        the sentinel's re-encode duplicates the round's own encode
        subgraph, which XLA CSEs away; elsewhere telemetry pays one extra
        encode per round — acceptable for an opt-in diagnostics flag.
        """
        from repro.obs import metrics as obs_metrics
        with jax.named_scope("comm.telemetry"):
            layout = self.layout(X)
            flat = layout.flatten(X)
            offsets = self.topo.neighbor_offsets()
            h = obs_metrics.round_health_zero()
            h["consensus_inf"] = obs_metrics.consensus_inf(flat, offsets)
            h["bits_per_param"] = jnp.float32(
                8.0 * self.payload_bytes_per_broadcast(X)
                / max(layout.total_elems, 1))
            if self.codec.name == "moniqua" and theta is not None:
                spec = self.codec.spec
                theta = jnp.asarray(theta, jnp.float32)
                B = modulo.b_theta(theta, spec.delta)
                h["headroom"] = h["consensus_inf"] / B
                if spec.delta < 0.25:    # sentinel pinned to 0 otherwise
                    seed = kops._key_to_seed(key)
                    packed = kops.moniqua_encode_stacked(flat, B, spec,
                                                         seed, backend="jnp")
                    h["alias_count"] = obs_metrics.moniqua_alias_count(
                        packed, flat, B, theta, spec, offsets)
            if new_state is not None:
                h["ef_residual_l2"] = jnp.sqrt(jnp.sum(
                    jnp.square(new_state["residual"].astype(jnp.float32))))
                if self.codec.name == "onebit":
                    # the counter was already bumped: -1 recovers the flag
                    # the round just executed under
                    h["warm"] = (new_state["step"] - 1
                                 < self.codec.warmup).astype(jnp.float32)
            return h

    # -- bucketed round: one encode, one roll per offset, one reduce -------
    def _mix_bucketed(self, X: PyTree, theta,
                      key: Optional[jax.Array]) -> PyTree:
        offsets = self.topo.neighbor_offsets()
        weights = self._neighbor_weights()
        layout = self.layout(X)
        if self.codec.name == "full" and not layout.uniform_dtype:
            # mixed-dtype raw wire: f32 staging would change the mixing
            # arithmetic (bf16 rolls accumulate in bf16 per leaf), breaking
            # the `mix == gossip.mix` contract — and the full wire has no
            # per-leaf encode/pad cost to amortize, so fall back per leaf
            return gossip.mix(X, self.topo)
        flat = layout.flatten(X)             # [n, D] staging buffer
        if self.codec.name == "full":
            return layout.unflatten(gossip.mix(flat, self.topo))
        backend = resolve_backend(self.backend)
        self._require_key(key)
        seed = kops._key_to_seed(key)
        spec = self.codec.spec
        if self.codec.name == "moniqua":
            B = modulo.b_theta(theta, spec.delta)
            with jax.named_scope("comm.encode"):
                packed = kops.moniqua_encode_stacked(flat, B, spec, seed,
                                                     backend=backend)
            with jax.named_scope("comm.permute"):
                p_nbrs = jnp.stack([gossip._roll(packed, o)
                                    for o in offsets])
            with jax.named_scope("comm.decode_reduce"):
                out = kops.moniqua_decode_reduce_stacked(packed, p_nbrs,
                                                         flat, B, weights,
                                                         spec,
                                                         backend=backend)
            return layout.unflatten(out)
        # qsgd on the flat buffer, with per-tensor scale granularity kept
        # (segment slices of the bucket); one decode per neighbor replaces
        # the per-leaf qsgd_decode copies
        seg = layout.segment_sizes
        with jax.named_scope("comm.encode"):
            packed, scales = qsgd_encode_segmented(flat, spec, seed, seg)
        with jax.named_scope("comm.decode_reduce"):
            xq_self = qsgd_decode_segmented(packed, scales, spec, seg)
            acc = None
            for o, w in zip(offsets, weights):
                with jax.named_scope("comm.permute"):
                    p_o = gossip._roll(packed, o)
                    s_o = gossip._roll(scales, o)
                xq_j = qsgd_decode_segmented(p_o, s_o, spec, seg)
                t = (xq_j - xq_self) * w
                acc = t if acc is None else acc + t
            out = (flat.astype(jnp.float32) + acc).astype(flat.dtype)
        return layout.unflatten(out)

    # -- stateful wires: error-feedback rounds on the flat bucket ----------
    def _mix_stateful(self, X: PyTree, state: dict,
                      key: Optional[jax.Array]
                      ) -> Tuple[PyTree, dict]:
        """One EF gossip round; returns ``(X_{k+1/2}, new WireState)``.

        Both the bucketed and the per-leaf paths run the same per-segment
        math on the canonical flat residual buffer: the bucketed round does
        it in one segmented launch over ``[n, D]``, the per-leaf round one
        leaf segment at a time (each leaf's payload rolled separately).
        Same per-segment scales, same row-position rounding uniforms
        (``idx_base`` = the segment's bucket offset), same accumulation
        order — so outputs, payload bits, AND post-round state agree
        bitwise (the ``tests/test_engine.py`` stateful contracts).

        EF math runs in f32 on both backends (no Pallas kernel for the EF
        wires yet; ``resolve_backend`` still validates the name so the
        engine surface stays uniform).
        """
        resolve_backend(self.backend)
        self._require_key(key)
        seed = kops._key_to_seed(key)
        layout = self.layout(X)
        flat = layout.flatten(X).astype(jnp.float32)
        residual, step = state["residual"], state["step"]
        if self.bucketed:
            out, res = self._ef_flat_round(flat, residual,
                                           layout.segment_sizes, 0, seed,
                                           step)
        else:
            out = jnp.zeros_like(flat)
            res = jnp.zeros_like(residual)
            for s in layout.slots:
                vi = jax.lax.slice_in_dim(flat, s.offset,
                                          s.offset + s.padded_size, axis=1)
                ri = jax.lax.slice_in_dim(residual, s.offset,
                                          s.offset + s.padded_size, axis=1)
                oi, rn = self._ef_flat_round(vi, ri, (s.padded_size,),
                                             s.offset, seed, step)
                out = jax.lax.dynamic_update_slice(out, oi, (0, s.offset))
                res = jax.lax.dynamic_update_slice(res, rn, (0, s.offset))
        new_state = {"residual": res, "step": step + jnp.int32(1)}
        return layout.unflatten(out.astype(layout.stage_dtype)), new_state

    def _ef_flat_round(self, v_base: jax.Array, residual: jax.Array,
                       segments: Tuple[int, ...], idx_base: int,
                       seed: jax.Array, step: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
        """EF round on one flat f32 buffer slice: encode ``v = x + r``,
        gossip the codes, mix ``x + sum w_o (decode_j - decode_self)``,
        keep ``r' = v - decode_self``."""
        offsets = self.topo.neighbor_offsets()
        weights = self._neighbor_weights()
        spec = self.codec.spec

        def reduce(d_self, decode_neighbor):
            acc = None
            for o, w in zip(offsets, weights):
                t = (decode_neighbor(o) - d_self) * w
                acc = t if acc is None else acc + t
            return v_base + acc

        if self.codec.name == "ef_qsgd":
            v = v_base + residual
            with jax.named_scope("comm.encode"):
                packed, scales = ef_qsgd_encode_segmented(v, spec, seed,
                                                          segments, idx_base)
            with jax.named_scope("comm.decode_reduce"):
                d_self = qsgd_decode_segmented(packed, scales, spec,
                                               segments)
                out = reduce(d_self, lambda o: qsgd_decode_segmented(
                    gossip._roll(packed, o), gossip._roll(scales, o), spec,
                    segments))
            return out, v - d_self

        # onebit: fp32 gossip during warmup, 1-bit sign codes + EF after.
        # The step counter is the need_reset-style switch.  Selected with
        # jnp.where, NOT lax.cond: cond branch bodies are optimized as
        # separate XLA computations whose fusion/FMA choices depend on the
        # buffer width, which breaks the bucketed-vs-per-leaf bitwise
        # contract at the ulp level.  Both value streams are cheap
        # elementwise math next to the communication, so computing both and
        # selecting is the right trade.
        warm_p = step < self.codec.warmup
        out_warm = gossip.mix(v_base, self.topo)
        v = v_base + residual
        packed, lo, hi = onebit_encode_segmented(v, seed, segments, idx_base,
                                                 spec.stochastic)
        d_self = onebit_decode_segmented(packed, lo, hi, segments)
        out_q = reduce(d_self, lambda o: onebit_decode_segmented(
            gossip._roll(packed, o), gossip._roll(lo, o),
            gossip._roll(hi, o), segments))
        return (jnp.where(warm_p, out_warm, out_q),
                jnp.where(warm_p, residual, v - d_self))

    def _mix_leaf(self, x: jax.Array, theta, seed: jax.Array,
                  backend: str, idx_base=0) -> jax.Array:
        if x.ndim == 1:      # scalar-per-worker leaf: give it a unit last axis
            return self._mix_leaf(x[:, None], theta, seed, backend,
                                  idx_base)[:, 0]
        offsets = self.topo.neighbor_offsets()
        weights = self._neighbor_weights()
        if self.codec.name == "moniqua":
            spec = self.codec.spec
            B = modulo.b_theta(theta, spec.delta)
            # per-worker tiling: each worker's slice is encoded/decoded in
            # its own tile grid (kops stacked wrappers), so only the packed
            # payload roll crosses the worker axis and all workers share
            # one rounding-uniform stream per element (Supp. C)
            packed = kops.moniqua_encode_stacked(x, B, spec, seed,
                                                 backend=backend,
                                                 idx_base=idx_base)
            p_nbrs = jnp.stack([gossip._roll(packed, o) for o in offsets])
            return kops.moniqua_decode_reduce_stacked(packed, p_nbrs, x, B,
                                                      weights, spec,
                                                      backend=backend)
        # qsgd: reference-free decode; each worker ships (codes, own scale)
        spec = self.codec.spec
        packed, scale = qsgd_encode(x, spec, seed)
        xq_self = qsgd_decode(packed, scale, spec, x.shape[-1])
        acc = None
        for o, w in zip(offsets, weights):
            xq_j = qsgd_decode(gossip._roll(packed, o),
                               gossip._roll(scale, o), spec, x.shape[-1])
            t = (xq_j - xq_self) * w
            acc = t if acc is None else acc + t
        return (x.astype(jnp.float32) + acc).astype(x.dtype)

    # -- layout plumbing ---------------------------------------------------
    def _align(self) -> int:
        """Row alignment of the flat buffer: values-per-byte for packed
        codecs (keeps per-leaf byte boundaries), 1 for the raw wire."""
        spec = getattr(self.codec, "spec", None)
        return spec.values_per_byte if spec is not None else 1

    def layout(self, X: PyTree) -> bucket.BucketLayout:
        """The (memoized) flat-buffer layout this engine uses for ``X``.

        Accepts abstract ``ShapeDtypeStruct`` trees, so callers (trainer,
        dryrun) can build the layout once outside jit; traced rounds then
        hit the cache with the identical static description.
        """
        return bucket.layout_of(X, self._align())

    def _neighbor_weights(self) -> Tuple[float, ...]:
        return tuple(w for o, w in zip(self.topo.offsets, self.topo.weights)
                     if o % self.topo.n != 0)

    def _require_key(self, key) -> None:
        """Stochastic rounding without a key would silently reuse seed 0
        every round, losing the across-step unbiasedness the convergence
        argument needs — fail loudly instead (matches the legacy path)."""
        spec = getattr(self.codec, "spec", None)
        if key is None and spec is not None and spec.stochastic:
            raise ValueError(
                f"{self.codec.name} wire with stochastic rounding needs a "
                "PRNG key (pass key=, or use a nearest-rounding QuantSpec)")

    # -- AD-PSGD's primitive: one edge exchange ----------------------------
    def init_edge_state(self, x: jax.Array) -> dict:
        """Per-endpoint ``WireState`` for :meth:`pair_average` (AD-PSGD
        edges): the EF residual lives in the padded flat domain of one
        model copy, plus the warmup step counter.  ``{}`` for stateless
        wires.  Accepts abstract shapes."""
        if not self.stateful:
            return {}
        vpb = self.codec.spec.values_per_byte
        size = int(np.prod(x.shape, dtype=np.int64))
        padded = -(-size // vpb) * vpb
        return {"residual": jnp.zeros((padded,), jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def pair_average(self, xi: jax.Array, xj: jax.Array, theta=None,
                     key: Optional[jax.Array] = None,
                     state_i: Optional[dict] = None,
                     state_j: Optional[dict] = None
                     ) -> Tuple[jax.Array, ...]:
        """One gossip on edge (i, j) with the pair-averaging ``W_k``.

        Quantized codecs exchange payloads and decode against each endpoint's
        own model (Algorithm 3 lines 4-7); both endpoints encode under the
        same seed (shared randomness).  Simulator-scale API: always pure-jnp
        (AD-PSGD runs under ``lax.scan`` on host devices).

        Stateful wires additionally require per-endpoint ``state_i`` /
        ``state_j`` carries from :meth:`init_edge_state` and return a
        4-tuple ``(xi', xj', state_i', state_j')``.
        """
        if self.stateful:
            return self._pair_average_stateful(xi, xj, key, state_i, state_j)
        if self.codec.name == "full":
            avg = 0.5 * (xi + xj)
            return avg, avg
        self._require_key(key)
        seed = kops._key_to_seed(key)
        if self.codec.name == "moniqua":
            spec = self.codec.spec
            B = modulo.b_theta(theta, spec.delta)
            pi = kops.moniqua_encode_jnp(xi, B, spec, seed)
            pj = kops.moniqua_encode_jnp(xj, B, spec, seed)
            n_last = xi.shape[-1]

            def val(p):
                return kref.value_ref(p, B, spec.bits)[..., :n_last]

            xj_at_i = modulo.recover(val(pj), xi, B)
            xi_at_j = modulo.recover(val(pi), xj, B)
            xi_self = modulo.local_bias(val(pi), xi, B)
            xj_self = modulo.local_bias(val(pj), xj, B)
            return (xi + 0.5 * (xj_at_i - xi_self),
                    xj + 0.5 * (xi_at_j - xj_self))
        spec = self.codec.spec
        pi, si = qsgd_encode(xi, spec, seed, worker_axis=False)
        pj, sj = qsgd_encode(xj, spec, seed, worker_axis=False)
        qi = qsgd_decode(pi, si, spec, xi.shape[-1])
        qj = qsgd_decode(pj, sj, spec, xj.shape[-1])
        return xi + 0.5 * (qj - qi), xj + 0.5 * (qi - qj)

    def _pair_average_stateful(self, xi: jax.Array, xj: jax.Array,
                               key: Optional[jax.Array],
                               state_i: Optional[dict],
                               state_j: Optional[dict]
                               ) -> Tuple[jax.Array, jax.Array, dict, dict]:
        """EF edge exchange: each endpoint compensates with its own residual,
        ships codes of ``x + r``, and keeps ``r' = x + r - decode(sent)``."""
        for s in (state_i, state_j):
            if not isinstance(s, dict) or "residual" not in s:
                raise ValueError(
                    f"{self.codec.name} wire is stateful: pass state_i/"
                    "state_j=engine.init_edge_state(x) and thread the "
                    "returned (xi, xj, state_i, state_j) across edges")
        self._require_key(key)
        seed = kops._key_to_seed(key)
        spec = self.codec.spec
        size = int(np.prod(xi.shape, dtype=np.int64))
        padded = state_i["residual"].shape[0]
        seg = (padded,)

        def flat(x):
            f = jnp.ravel(x).astype(jnp.float32)
            return jnp.pad(f, (0, padded - size))[None, :]

        def unflat(f, like):
            return f[0, :size].reshape(like.shape).astype(like.dtype)

        fi, fj = flat(xi), flat(xj)
        vi = fi + state_i["residual"][None, :]
        vj = fj + state_j["residual"][None, :]

        if self.codec.name == "ef_qsgd":
            pi, si = ef_qsgd_encode_segmented(vi, spec, seed, seg)
            pj, sj = ef_qsgd_encode_segmented(vj, spec, seed, seg)
            di = qsgd_decode_segmented(pi, si, spec, seg)
            dj = qsgd_decode_segmented(pj, sj, spec, seg)
            oi, oj = fi + 0.5 * (dj - di), fj + 0.5 * (di - dj)
            ri, rj = vi - di, vj - dj
        else:
            # onebit: a mixed pair stays full-precision — the earlier of
            # the two counters decides warm-vs-quantized.  where-select
            # (not lax.cond) for the same bitwise-contract reason as the
            # gossip round.
            warm_p = jnp.minimum(state_i["step"],
                                 state_j["step"]) < self.codec.warmup
            avg = 0.5 * (fi + fj)
            pi, loi, hii = onebit_encode_segmented(vi, seed, seg, 0,
                                                   spec.stochastic)
            pj, loj, hij = onebit_encode_segmented(vj, seed, seg, 0,
                                                   spec.stochastic)
            di = onebit_decode_segmented(pi, loi, hii, seg)
            dj = onebit_decode_segmented(pj, loj, hij, seg)
            oi = jnp.where(warm_p, avg, fi + 0.5 * (dj - di))
            oj = jnp.where(warm_p, avg, fj + 0.5 * (di - dj))
            ri = jnp.where(warm_p, state_i["residual"][None, :], vi - di)
            rj = jnp.where(warm_p, state_j["residual"][None, :], vj - dj)
        return (unflat(oi, xi), unflat(oj, xj),
                {"residual": ri[0], "step": state_i["step"] + jnp.int32(1)},
                {"residual": rj[0], "step": state_j["step"] + jnp.int32(1)})

    def pair_health(self, xi: jax.Array, xj: jax.Array, theta=None,
                    key: Optional[jax.Array] = None) -> dict:
        """Round health of one :meth:`pair_average` edge exchange.

        Observational twin of ``mix``'s telemetry for the AD-PSGD
        primitive: consensus distance of the endpoints, plus (Moniqua) the
        theta headroom and both-direction alias sentinel on payloads
        re-encoded under the exchange seed — bit-identical to what
        ``pair_average`` ships.  Call on the *pre-exchange* endpoints.
        """
        from repro.obs import metrics as obs_metrics
        with jax.named_scope("comm.telemetry"):
            spec = (self.codec.spec
                    if self.codec.name == "moniqua" else None)
            h = obs_metrics.pair_health(
                xi, xj, theta=theta, spec=spec,
                seed=kops._key_to_seed(key) if spec is not None else None)
            if spec is None:
                bits = getattr(getattr(self.codec, "spec", None), "bits",
                               32)
                h["bits_per_param"] = jnp.float32(
                    32.0 if self.codec.name == "full" else float(bits))
            return h

    # -- gossip building blocks shared by the algorithm zoo ----------------
    def neighbor_sum(self, X: PyTree, transform) -> PyTree:
        """``sum_{o != 0} w_o * transform(roll(X, -o), o)`` leaf-wise."""
        return gossip.neighbor_sum(X, self.topo, transform)

    def self_weight(self) -> float:
        return gossip.self_weight(self.topo)

    # -- accounting --------------------------------------------------------
    def payload_bytes_per_broadcast(self, X: PyTree) -> int:
        """Bytes one worker ships to ONE neighbor per round.

        Bucketed rounds roll the packed flat buffer plus, for qsgd, the
        per-tensor scale vector; per-leaf rounds roll each leaf's payload.
        The vpb row alignment makes the bucketed Moniqua payload equal the
        per-leaf sum exactly — the tile-grid pad is sliced off before the
        roll and never rides the wire — and bucketed qsgd keeps one
        4-byte scale per tensor, so its bytes match the per-leaf sum too.
        A mixed-dtype tree on the ``full`` wire mixes per leaf (f32
        staging would change the arithmetic), so its bytes are the
        per-leaf sum as well.
        """
        if not jax.tree.leaves(X):
            return 0
        if self.stateful:
            # EF wires gossip packed flat segments on BOTH paths (the
            # per-leaf round slices the same canonical bucket buffer), so
            # the accounting is layout-based either way: packed codes plus
            # per-segment scale words (one f32 for ef_qsgd, a lo/hi level
            # pair for onebit).  onebit warmup rounds ship f32
            # (``warmup_payload_bytes``); steady state is what's reported.
            layout = self.layout(X)
            nbytes = layout.padded_elems // self.codec.spec.values_per_byte
            nbytes += (4 if self.codec.name == "ef_qsgd"
                       else 8) * layout.num_leaves
            return nbytes
        if self.bucketed:
            layout = self.layout(X)
            if self.codec.name == "full":
                if not layout.uniform_dtype:   # per-leaf fallback path
                    return sum(self.codec.payload_bytes(
                        leaf.shape[1:], leaf.dtype.itemsize)
                        for leaf in jax.tree.leaves(X))
                return layout.total_elems * jnp.dtype(
                    layout.stage_dtype).itemsize
            spec = self.codec.spec
            nbytes = layout.padded_elems // spec.values_per_byte
            if self.codec.name == "qsgd":
                nbytes += 4 * layout.num_leaves
            return nbytes
        return sum(self.codec.payload_bytes(leaf.shape[1:],
                                            leaf.dtype.itemsize)
                   for leaf in jax.tree.leaves(X))

    def bytes_per_round(self, X: PyTree) -> int:
        """Payload bytes *sent* per worker per gossip round (all leaves)."""
        m = len(self.topo.neighbor_offsets())
        return self.payload_bytes_per_broadcast(X) * m

    def _record(self, X: PyTree, ledger: BytesLedger) -> None:
        ledger.add(self.payload_bytes_per_broadcast(X),
                   len(self.topo.neighbor_offsets()))
