"""CommEngine: one pluggable communication engine for decentralized SGD.

Every decentralized algorithm in this repo reduces its communication to the
same primitive — *one gossip round*: encode the local model, circulate the
payload along the topology (``jnp.roll`` on the stacked worker axis, which is
one ``collective-permute`` on the production mesh), decode each neighbor
against the local reference, and accumulate the weighted consensus step

    X_{k+1/2}[i] = x_i + sum_{o != 0} w_o * (xhat_{i+o} - xhat_self)     (*)

``CommEngine`` owns that round end-to-end and exposes the three seams the
paper's algorithm zoo (and every future scaling PR) plugs into:

* **codec** — what rides on the wire: ``FullPrecisionWire`` (D-PSGD baseline;
  (*) then collapses to the circulant ``X W``), ``MoniquaWire`` (Algorithm 1's
  bit-packed modulo residue, no scales, no extra state), ``QSGDWire``
  (Alistarh et al. 2017 scale+codes, the obvious external comparison), or
  the *stateful* error-feedback family — ``EFQSGDWire`` and ``OneBitWire``
  (1-bit Adam-style warmup + sign codes) — which carry a per-worker
  ``WireState`` pytree (EF residual + warmup counter) as an explicit
  jit-safe carry through ``mix``/``pair_average``; see ``docs/codecs.md``.
* **topology** — any circulant :class:`~repro.core.topology.Topology`; the
  weights are static so they compile into the mixing (and into the fused
  kernel's unrolled reduction).
* **backend** — ``"jnp"`` lowers everywhere (pure jnp, used by the CPU
  convergence experiments), ``"pallas"`` uses the fused TPU kernels
  (``kernels/moniqua_encode.py`` + ``kernels/moniqua_decode_reduce.py``),
  ``"auto"`` picks Pallas on TPU.  Both Moniqua backends draw stochastic
  rounding from the same counter-based hash of (seed, element index), so they
  agree **bit-exactly** in interpret mode — the parity contract
  ``tests/test_engine.py`` enforces.

Results are uniform: ``mix`` always returns a :class:`MixResult`
(``x``, ``state``, ``health`` — ``state == {}`` for stateless wires,
``health is None`` with telemetry off) and ``pair_average`` a
:class:`PairResult`; callers use attribute access, never tuple-arity
branching.

Gossip path (``path=``): ``"bucketed"`` flattens the whole stacked pytree
into one contiguous per-worker staging buffer (``comm/bucket.py``) so a
round is one encode launch, one packed roll per offset, one fused
decode-reduce, and one scatter back to leaves; ``"per_leaf"`` keeps the
leaf-by-leaf round as the parity reference; ``"auto"`` (default) picks per
(layout, codec) from a memoized crossover table seeded by the committed
``BENCH_comm_fusion.json`` — bucketing wins exactly when the per-leaf
tile-grid pad amplification (dozens of sub-tile biases each padded to
256x1024) dwarfs the bucketed single pad, which the committed data shows
only for many-small-leaf models on the Moniqua wire.  Stateful (EF) wires
always bucket: their canonical residual lives in the flat domain.

Staged rounds: ``round_plan(X)`` returns a :class:`RoundPlan` exposing one
gossip round as three separable phases per chunk — ``encode_chunk(i)``,
``permute(i)``, ``decode_reduce(i)`` — over the ``BucketLayout.chunks(K)``
partition (slot-aligned, so per-tensor scales never straddle a chunk).
``RoundPlan.run()`` software-pipelines them in the skewed order
encode(t) / permute(t-1) / decode-reduce(t-2), so chunk t's
collective-permute is issued while t+1 encodes and t-1 reduces — the
ROADMAP's overlap item.  Because every codec hashes *global* element
indices (``idx_base`` = chunk offset) and chunk boundaries stay on
values-per-byte segment boundaries, the pipelined round is **bit-exact**
against the barrier round (``chunks=1``) for every wire — outputs, payload
bits, and post-round WireState (``tests/test_overlap.py``).

Step-level overlap: ``mix_stale`` (stateless Moniqua only) applies the
*previous* round's payloads to the current model and immediately encodes
the result for the next round, carrying ``(packed, ref, B, valid)`` across
steps — one-round-stale mixing, so the decode-reduce of round k can hide
behind the forward pass of step k+1.  Staleness-tolerance for decentralized
SGD with quantized updates (PAPERS.md) covers this delay-1 schedule.

Bytes accounting is trace-time bookkeeping: ``mix(..., ledger=...)`` records
payload-bytes-per-worker into a :class:`~repro.comm.gossip.BytesLedger`, and
``bytes_per_round`` returns the same number without running anything — the
input to the analytic network model in ``benchmarks/``.  Payload bytes are
path-independent (the vpb row alignment makes the bucketed payload equal
the per-leaf sum exactly), so ``path="auto"`` never changes the ledger.

Sharded meshes: the Moniqua backends tile each worker's slice separately
(``kernels/ops.py`` stacked wrappers vmap the tile layout over the worker
axis), so the only cross-worker traffic in a round is the packed
collective-permute of the payload, and — because every worker hashes the
same (seed, element) pairs — stochastic rounding uses Supp.-C shared
randomness exactly: identical models encode to identical payloads on
every worker.

Elastic rounds (``presence=``): ``mix``/``mix_stale``/``pair_average``
accept a per-worker presence mask.  A dead edge (either endpoint absent)
contributes *identity* — the receiving worker keeps its own value in that
edge's weight, which is exactly the renormalized doubly-stochastic
``Topology.with_presence`` matrix applied in the quantized-difference
domain — and an absent worker's model AND its EF ``WireState`` residual
pass through a missed round untouched.  The mask is normalized host-side:
``presence=None`` or all-ones takes *literally today's code path*, so the
full-presence round is bit-exact by construction for every wire, backend,
path, and tier (``tests/test_elastic.py``); each distinct partial mask is
a separate trace (documented recompile — elastic benches run eager).
Tiered engines take a per-NODE mask (length ``n_inter``): an absent node
keeps its intra-tier average but drops out of the inter-shard gossip — the
"uplink partition" failure mode.  See ``docs/elasticity.md``.

Wall-clock prediction: the byte counts this engine produces feed the
event-driven simulator (``repro.sim``), which prices them under explicit
link/compute models per named scenario — see ``docs/simulator.md``.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import bucket, gossip
from repro.comm.gossip import BytesLedger
from repro.core import modulo
from repro.core.quantizers import (QuantSpec, ef_qsgd_encode_segmented,
                                   onebit_decode_segmented,
                                   onebit_encode_segmented,
                                   onebit_payload_bytes, packed_last_dim,
                                   qsgd_decode, qsgd_decode_segmented,
                                   qsgd_encode, qsgd_encode_segmented,
                                   qsgd_payload_bytes)
from repro.core.topology import (HierarchicalTopology, Topology,
                                 normalize_mask)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.moniqua_encode import (DEFAULT_BLOCK_COLS,
                                          DEFAULT_BLOCK_ROWS)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

PyTree = Any

WIRES = ("full", "moniqua", "qsgd", "ef_qsgd", "onebit")
BACKENDS = ("auto", "jnp", "pallas")
PATHS = ("bucketed", "per_leaf", "auto")


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


# ---------------------------------------------------------------------------
# Uniform round results.
# ---------------------------------------------------------------------------

class MixResult(NamedTuple):
    """What one gossip round returns — always the same three fields.

    ``x`` is the mixed model ``X_{k+1/2}``; ``state`` is the post-round
    WireState carry (``{}`` for stateless wires — thread it back into the
    next ``mix`` for EF wires, or the gossip carry for ``mix_stale``);
    ``health`` is the round-health dict (``None`` unless the engine was
    built with ``telemetry=True``).  Use attribute access: the fields are
    uniform precisely so call sites never branch on arity again.
    """
    x: Any
    state: dict = {}
    health: Optional[dict] = None


class PairResult(NamedTuple):
    """What one ``pair_average`` edge exchange returns (AD-PSGD primitive):
    both updated endpoints plus their post-exchange WireState carries
    (``{}`` for stateless wires)."""
    xi: Any
    xj: Any
    state_i: dict = {}
    state_j: dict = {}


# ---------------------------------------------------------------------------
# Wire codecs: what one worker broadcasts per round.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FullPrecisionWire:
    """Identity codec: the raw model rides the wire (D-PSGD / D2 baseline)."""
    name = "full"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        return int(np.prod(shape, dtype=np.int64)) * itemsize


@dataclasses.dataclass(frozen=True)
class MoniquaWire:
    """Algorithm 1's packed modulo residue: ``bits/8`` bytes/param, no scales."""
    spec: QuantSpec = QuantSpec()
    name = "moniqua"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        if not shape:
            return 1
        inner = int(np.prod(shape[:-1], dtype=np.int64))
        return inner * packed_last_dim(shape[-1], self.spec.bits)


@dataclasses.dataclass(frozen=True)
class QSGDWire:
    """Scale+codes codec: packed codes + one f32 max-norm scale per tensor."""
    spec: QuantSpec = QuantSpec()
    name = "qsgd"

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        return qsgd_payload_bytes(shape, self.spec.bits)


@dataclasses.dataclass(frozen=True)
class EFQSGDWire:
    """Error-feedback QSGD (Tang et al. 2019 style): quantize ``x + residual``
    with the scale+codes wire, keep ``residual' = x + residual - decode(sent)``
    per worker.  Stateful: pays one f32 residual buffer per worker (Θ(nd)
    graph-wide) — the memory axis ``BENCH_memory_overhead.json`` prices
    against Moniqua's zero-extra-state wire."""
    spec: QuantSpec = dataclasses.field(default_factory=QuantSpec)
    name = "ef_qsgd"
    stateful = True

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        return qsgd_payload_bytes(shape, self.spec.bits)


@dataclasses.dataclass(frozen=True)
class OneBitWire:
    """1-bit Adam-style compressed wire: full-precision gossip for the first
    ``warmup`` rounds, then 1-bit sign codes of the compensated value (with
    per-segment cluster-mean levels) and an error-feedback residual.  The
    carried step counter is the ``need_reset``-style hook: crossing it flips
    the round's codec inside the jitted step (a ``jnp.where`` select — see
    ``RoundPlan.decode_reduce``), and checkpointing the counter resumes the
    schedule bit-identically."""
    spec: QuantSpec = dataclasses.field(
        default_factory=lambda: QuantSpec(bits=1, stochastic=False))
    warmup: int = 16
    name = "onebit"
    stateful = True

    def payload_bytes(self, shape: Tuple[int, ...], itemsize: int = 4) -> int:
        """Steady-state (post-warmup) bytes; warmup rounds ship f32
        (``warmup_payload_bytes``) — accounting reports the steady state."""
        return onebit_payload_bytes(shape)

    def warmup_payload_bytes(self, shape: Tuple[int, ...],
                             itemsize: int = 4) -> int:
        return int(np.prod(shape, dtype=np.int64)) * 4 if shape else 4


def make_wire(name: str, spec: Optional[QuantSpec] = None, warmup: int = 16):
    spec = spec or QuantSpec()
    if name == "full":
        return FullPrecisionWire()
    if name == "moniqua":
        return MoniquaWire(spec)
    if name == "qsgd":
        return QSGDWire(spec)
    if name == "ef_qsgd":
        return EFQSGDWire(spec)
    if name == "onebit":
        # the sign path is 1 bit by construction; keep the caller's
        # stochastic/nearest choice but pin the packable width
        return OneBitWire(dataclasses.replace(spec, bits=1), warmup=warmup)
    raise ValueError(f"unknown wire codec {name!r}; one of {WIRES}")


# ---------------------------------------------------------------------------
# Auto path selection: per-(layout, codec) crossover from committed bench data.
# ---------------------------------------------------------------------------

def _tile_padded(elems: int) -> int:
    """Elements after padding a flat segment to the Pallas encode tile grid
    (same accounting as ``benchmarks/bench_comm_fusion.py``)."""
    rows = -(-elems // DEFAULT_BLOCK_COLS)
    return -(-rows // DEFAULT_BLOCK_ROWS) * DEFAULT_BLOCK_ROWS \
        * DEFAULT_BLOCK_COLS


# measured crossover when BENCH_comm_fusion.json is absent (derived from the
# same committed data: moniqua buckets win only where per-leaf tile padding
# amplifies ~30x over bucketed; qsgd/full buckets lose on every measured model)
_FALLBACK_CROSSOVER = {"moniqua": 9.8, "qsgd": float("inf"),
                       "full": float("inf")}


@functools.lru_cache(maxsize=1)
def _crossover_table() -> Dict[str, float]:
    """Per-wire pad-amplification threshold above which bucketing wins.

    Seeded from the committed ``BENCH_comm_fusion.json``: each measured
    model has a pad-amplification ratio (per-leaf tile-padded elements /
    bucketed tile-padded elements) and a bucketed-vs-per-leaf speedup per
    codec.  The threshold is the geometric mean of the worst winning and
    best losing ratio — ``inf`` when bucketing never won, ``1.0`` when it
    never lost.  Falls back to the hardcoded equivalents when the file is
    missing (fresh checkout before benches ran).
    """
    try:
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        with open(os.path.join(root, "BENCH_comm_fusion.json")) as f:
            data = json.load(f)
        ratios = {o["model"]: (o["tile_padded_elems_per_leaf_path"]
                               / o["tile_padded_elems_bucketed"])
                  for o in data["overhead"]}
        wire_of = {"moniqua-1bit": "moniqua", "moniqua-8bit": "moniqua",
                   "qsgd-8bit": "qsgd", "fp32": "full"}
        wins: Dict[str, list] = {}
        losses: Dict[str, list] = {}
        for row in data["table"]:
            wire = wire_of.get(row["codec"])
            if wire is None or row["model"] not in ratios:
                continue
            side = wins if row["speedup_x"] >= 1.0 else losses
            side.setdefault(wire, []).append(ratios[row["model"]])
        table = dict(_FALLBACK_CROSSOVER)
        for wire in ("moniqua", "qsgd", "full"):
            w, l = wins.get(wire), losses.get(wire)
            if not w:
                table[wire] = float("inf")
            elif not l:
                table[wire] = 1.0
            else:
                table[wire] = math.sqrt(max(l) * min(w))
        return table
    except Exception:
        return dict(_FALLBACK_CROSSOVER)


@functools.lru_cache(maxsize=4096)
def _auto_bucketed_slots(slots: Tuple[bucket.LeafSlot, ...],
                         padded_elems: int, codec_name: str) -> bool:
    """``path="auto"`` decision for one contiguous slot window: bucket
    exactly when the window's per-leaf pad amplification clears the
    measured crossover for the wire.  Operates on a slot census (not a
    whole layout) so a *shard* of the buffer resolves on its own leaves —
    a shard holding two fused embedding slabs should not inherit the
    bucketing verdict of the whole model's bias census."""
    per_leaf = sum(_tile_padded(s.padded_size) for s in slots)
    ratio = per_leaf / max(_tile_padded(padded_elems), 1)
    return ratio >= _crossover_table().get(codec_name, float("inf"))


def _auto_bucketed(layout: bucket.BucketLayout, codec_name: str) -> bool:
    return _auto_bucketed_slots(layout.slots, layout.padded_elems,
                                codec_name)


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

def _leaf_seed(base_seed: jax.Array, leaf_idx: int) -> jax.Array:
    """Distinct deterministic hash seed per pytree leaf (both backends)."""
    return jnp.asarray(base_seed, jnp.uint32) ^ jnp.uint32(
        (leaf_idx * 0x9E3779B1) & 0xFFFFFFFF)


def _neighbor_weights_of(topo: Topology) -> Tuple[float, ...]:
    return tuple(w for o, w in zip(topo.offsets, topo.weights)
                 if o % topo.n != 0)


# ---------------------------------------------------------------------------
# Elastic rounds: presence masks.
# ---------------------------------------------------------------------------

def _normalize_presence(presence, n: int) -> Optional[Tuple[int, ...]]:
    """Host-side presence normalization: ``None`` or all-ones collapses to
    ``None`` — the caller then takes literally today's (unmasked) code
    path, which is the whole full-presence bit-exactness argument.  A
    partial mask comes back as a static 0/1 tuple (it compiles into the
    trace; distinct masks retrace)."""
    if presence is None:
        return None
    vals = normalize_mask(presence, n)
    if all(vals):
        return None
    return vals


def _alive_cols(presence: Tuple[int, ...], offset: int,
                ndim: int = 2) -> jax.Array:
    """Bool ``[n, 1, ..]`` mask: worker ``i`` True iff both endpoints of
    its edge to ``i + offset`` showed up (``_roll`` indexing: row ``i``
    of ``_roll(x, o)`` is ``x[i + o]``)."""
    pb = jnp.asarray(presence, jnp.bool_)
    pb = pb.reshape((-1,) + (1,) * (ndim - 1))
    return jnp.logical_and(pb, gossip._roll(pb, offset))


def _present_cols(presence: Tuple[int, ...], ndim: int = 2) -> jax.Array:
    pb = jnp.asarray(presence, jnp.bool_)
    return pb.reshape((-1,) + (1,) * (ndim - 1))


def _masked_circulant(x: jax.Array, topo: Topology,
                      presence: Tuple[int, ...]) -> jax.Array:
    """Full-precision elastic mix on one stacked leaf: identity plus the
    weighted diffs of the edges that survived the mask — the
    ``with_presence`` matrix applied without materializing it."""
    f = x.astype(jnp.float32)
    acc = None
    for o, w in zip(topo.offsets, topo.weights):
        if o % topo.n == 0:
            continue
        alive = _alive_cols(presence, o, x.ndim)
        t = jnp.where(alive, gossip._roll(f, o) - f, 0.0) * w
        acc = t if acc is None else acc + t
    if acc is None:
        return x
    return (f + acc).astype(x.dtype)


def _dropped_edge_count(presence: Tuple[int, ...], topo: Topology) -> int:
    """Directed gossip edges the mask killed (health counter; static)."""
    n = topo.n
    return sum(1
               for o in topo.neighbor_offsets()
               for i in range(n)
               if not (presence[i] and presence[(i + o) % n]))


@dataclasses.dataclass
class RoundPlan:
    """One gossip round, staged: per-chunk encode / permute / decode-reduce.

    Built by :meth:`CommEngine.round_plan`.  The three phase methods are
    separable and chunk-indexed so a caller (or :meth:`run`) can interleave
    them; each is bit-exact per chunk against the barrier round's math on
    the same window because

    * chunk windows cover whole leaf slots (``BucketLayout.chunks``), so
      per-tensor codec statistics (qsgd scales, onebit lo/hi levels) see
      exactly the segments the whole-buffer round sees;
    * encode kernels hash *global* element indices (``idx_base`` = the
      chunk's buffer offset; qsgd additionally strides its worker axis by
      the whole-buffer width), so every element draws the same rounding
      uniform regardless of chunking;
    * chunk offsets are values-per-byte aligned, so the chunk payloads are
      byte-exact windows of the whole-buffer payload;
    * the decode-reduce accumulation order per element is identical.

    ``run()`` executes the software pipeline: at tick t it issues
    encode(t), permute(t-1), decode_reduce(t-2) — so the permute of chunk
    t-1 (the round's only cross-worker traffic) is in flight between the
    codec work of its neighbors.  With ``chunks=1`` the skew degenerates to
    the barrier round (encode, permute, reduce back-to-back) — the parity
    reference ``tests/test_overlap.py`` pins.
    """
    engine: "CommEngine"
    layout: bucket.BucketLayout
    chunks: Tuple[bucket.BucketChunk, ...]
    flat: jax.Array
    backend: str
    theta: Any = None
    B: Any = None
    seed: Optional[jax.Array] = None
    residual: Optional[jax.Array] = None
    step: Optional[jax.Array] = None
    # shard plans (TieredPlan stage B): ``flat`` is the owned-shard window
    # of the buffer starting at element ``base``, and the gossip runs on
    # ``topo`` (the inter tier) instead of the engine's topology.  Chunk
    # offsets stay *global* — they are the encode kernels' idx_base — so
    # windows are sliced at ``c.offset - base``.  Defaults reproduce the
    # single-tier whole-buffer round exactly.
    base: int = 0
    topo: Optional[Topology] = None
    # elastic rounds: normalized partial presence mask over the plan's
    # worker axis (None = everyone present = exactly the unmasked math).
    # Encode and permute are unchanged — presence only gates which decoded
    # neighbor diffs enter the reduction (a dead edge contributes identity)
    # and, for EF wires, which rows update their residual.
    presence: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.topo is None:
            self.topo = self.engine.gossip_topo

    @property
    def num_chunks(self) -> int:
        return len(self.chunks)

    def _win(self, arr: jax.Array, c: bucket.BucketChunk) -> jax.Array:
        off = c.offset - self.base
        return jax.lax.slice_in_dim(arr, off, off + c.size, axis=1)

    # -- phase 1: encode one chunk -----------------------------------------
    def encode_chunk(self, i: int) -> Tuple[jax.Array, ...]:
        """Encode chunk ``i`` of the staging buffer; returns the wire-specific
        payload tuple (plus, for EF wires, the compensated value ``v`` that
        the decode-reduce phase needs to close the residual)."""
        c = self.chunks[i]
        eng = self.engine
        name = eng.codec.name
        with obs_trace.chunk_phase("comm.encode", i, self.num_chunks):
            if name == "full":
                return (self._win(self.flat, c),)
            if name == "moniqua":
                return (kops.moniqua_encode_chunk(
                    self.flat, c.offset - self.base, c.size, self.B,
                    eng.codec.spec, self.seed, backend=self.backend,
                    idx_base=c.offset),)
            if name == "qsgd":
                packed, scales = qsgd_encode_segmented(
                    self._win(self.flat, c), eng.codec.spec, self.seed,
                    c.segment_sizes, idx_base=c.offset,
                    idx_stride=self.layout.padded_elems)
                return (packed, scales)
            # EF wires: compensate with the residual window before encoding
            v = self._win(self.flat, c) + self._win(self.residual, c)
            if name == "ef_qsgd":
                packed, scales = ef_qsgd_encode_segmented(
                    v, eng.codec.spec, self.seed, c.segment_sizes, c.offset)
                return (packed, scales, v)
            packed, lo, hi = onebit_encode_segmented(
                v, self.seed, c.segment_sizes, c.offset,
                eng.codec.spec.stochastic)
            return (packed, lo, hi, v)

    # -- phase 2: circulate one chunk's payload ----------------------------
    def permute(self, i: int, enc: Tuple[jax.Array, ...]):
        """Roll chunk ``i``'s payload along the worker axis — the round's
        only cross-worker traffic (one collective-permute per offset on a
        mesh).  The EF wires' local ``v`` never rides the wire."""
        eng = self.engine
        name = eng.codec.name
        with obs_trace.chunk_phase("comm.permute", i, self.num_chunks):
            if name == "full":
                # the raw wire reduces over ALL offsets (self included, where
                # _roll no-ops) — exactly gossip.mix's circulant
                return tuple(gossip._roll(enc[0], o)
                             for o in self.topo.offsets)
            offsets = self.topo.neighbor_offsets()
            if name == "moniqua":
                return jnp.stack([gossip._roll(enc[0], o) for o in offsets])
            n_payload = 2 if name in ("qsgd", "ef_qsgd") else 3
            return tuple(tuple(gossip._roll(p, o) for p in enc[:n_payload])
                         for o in offsets)

    # -- phase 3: decode neighbors, accumulate the consensus step ----------
    def decode_reduce(self, i: int, enc: Tuple[jax.Array, ...], nbrs):
        """Decode chunk ``i``'s circulated payloads against the local window
        and apply (*) on it.  Stateless wires return the mixed window;
        stateful (EF) wires return ``(mixed window, new residual window)``.
        """
        c = self.chunks[i]
        eng = self.engine
        name = eng.codec.name
        spec = getattr(eng.codec, "spec", None)
        seg = c.segment_sizes
        p = self.presence

        def gate(o, t):
            # elastic: a dead edge's decoded diff never enters the
            # reduction — the receiver keeps its own value in that weight
            return t if p is None else jnp.where(_alive_cols(p, o), t, 0.0)

        with obs_trace.chunk_phase("comm.decode_reduce", i, self.num_chunks):
            if name == "full":
                if p is None:
                    out = None
                    for w, r in zip(self.topo.weights, nbrs):
                        t = r * w
                        out = t if out is None else out + t
                    return out.astype(enc[0].dtype)
                # masked raw wire: identity plus the gated neighbor diffs
                # (NOT a re-weighted sum of windows — summing w_o-scaled
                # copies of the local window would put an absent row one
                # ulp off identity)
                win = enc[0]
                f = win.astype(jnp.float32)
                out = f
                for o, w, r in zip(self.topo.offsets, self.topo.weights,
                                   nbrs):
                    if o % self.topo.n == 0:
                        continue
                    out = out + jnp.where(_alive_cols(p, o),
                                          r.astype(jnp.float32) - f,
                                          0.0) * w
                return out.astype(win.dtype)
            offsets = self.topo.neighbor_offsets()
            weights = _neighbor_weights_of(self.topo)
            if name == "moniqua":
                if p is None:
                    return kops.moniqua_decode_reduce_chunk(
                        enc[0], nbrs, self.flat, c.offset - self.base,
                        c.size, self.B, weights, spec,
                        backend=self.backend)
                # masked: one fused decode-reduce per surviving offset
                # (single weight), recombined as win + sum of gated diffs
                win = self._win(self.flat, c).astype(jnp.float32)
                out = win
                for k, (o, w) in enumerate(zip(offsets, weights)):
                    mixed_o = kops.moniqua_decode_reduce_chunk(
                        enc[0], nbrs[k:k + 1], self.flat,
                        c.offset - self.base, c.size, self.B, (w,), spec,
                        backend=self.backend)
                    out = out + gate(o, mixed_o.astype(jnp.float32) - win)
                return out.astype(self._win(self.flat, c).dtype)
            if name == "qsgd":
                win = self._win(self.flat, c)
                packed, scales = enc
                d_self = qsgd_decode_segmented(packed, scales, spec, seg)
                acc = None
                for (p_o, s_o), o, w in zip(nbrs, offsets, weights):
                    t = gate(o, qsgd_decode_segmented(p_o, s_o, spec, seg)
                             - d_self) * w
                    acc = t if acc is None else acc + t
                return (win.astype(jnp.float32) + acc).astype(win.dtype)
            if name == "ef_qsgd":
                win = self._win(self.flat, c)
                packed, scales, v = enc
                d_self = qsgd_decode_segmented(packed, scales, spec, seg)
                acc = None
                for (p_o, s_o), o, w in zip(nbrs, offsets, weights):
                    t = gate(o, qsgd_decode_segmented(p_o, s_o, spec, seg)
                             - d_self) * w
                    acc = t if acc is None else acc + t
                out, res = win + acc, v - d_self
                if p is not None:
                    # an absent worker's model and EF residual pass
                    # through the missed round untouched
                    here = _present_cols(p)
                    rwin = self._win(self.residual, c)
                    out = jnp.where(here, out, win)
                    res = jnp.where(here, res, rwin)
                return out, res
            # onebit: fp32 gossip during warmup, sign codes + EF after; the
            # warm/quantized switch is a jnp.where select, NOT lax.cond —
            # cond bodies compile as separate XLA computations whose fusion
            # choices depend on buffer width, breaking the chunked-vs-
            # barrier bitwise contract at the ulp level.
            win = self._win(self.flat, c)
            rwin = self._win(self.residual, c)
            packed, lo, hi, v = enc
            warm_p = self.step < eng.codec.warmup
            out_warm = (gossip.mix(win, self.topo) if p is None
                        else _masked_circulant(win, self.topo, p))
            d_self = onebit_decode_segmented(packed, lo, hi, seg)
            acc = None
            for (p_o, lo_o, hi_o), o, w in zip(nbrs, offsets, weights):
                t = gate(o, onebit_decode_segmented(p_o, lo_o, hi_o, seg)
                         - d_self) * w
                acc = t if acc is None else acc + t
            out = jnp.where(warm_p, out_warm, win + acc)
            res = jnp.where(warm_p, rwin, v - d_self)
            if p is not None:
                here = _present_cols(p)
                out = jnp.where(here, out, win)
                res = jnp.where(here, res, rwin)
            return out, res

    # -- the software pipeline ---------------------------------------------
    def run(self):
        """Execute the full round through the skewed pipeline.

        Returns the mixed flat buffer (stateless wires) or
        ``(mixed flat buffer, new flat residual)`` (stateful wires).  With
        one chunk this is exactly the barrier round.
        """
        K = self.num_chunks
        stateful = self.engine.stateful
        enc: Dict[int, Any] = {}
        nbr: Dict[int, Any] = {}
        outs: list = [None] * K
        ress: list = [None] * K
        for t in range(K + 2):
            if t < K:
                enc[t] = self.encode_chunk(t)
            if 0 <= t - 1 < K:
                nbr[t - 1] = self.permute(t - 1, enc[t - 1])
            if 0 <= t - 2 < K:
                r = self.decode_reduce(t - 2, enc.pop(t - 2), nbr.pop(t - 2))
                if stateful:
                    outs[t - 2], ress[t - 2] = r
                else:
                    outs[t - 2] = r
        out = outs[0] if K == 1 else jnp.concatenate(outs, axis=1)
        if stateful:
            res = ress[0] if K == 1 else jnp.concatenate(ress, axis=1)
            return out, res
        return out


@dataclasses.dataclass
class TieredPlan:
    """One two-tier gossip round on the flat bucket (hierarchical engines).

    Three stages on the ``[n, D]`` staging buffer viewed as
    ``[n_inter, n_intra, D]`` (worker ``w = g * n_intra + j``):

    1. **Intra reduce** (fast axis, full precision): the intra tier's
       circulant mix along the node axis — with the default fully-connected
       intra tier this is exactly the node mean, i.e. the reduce phase of a
       reduce-scatter.  Skipped at the *Python* level when ``n_intra == 1``
       (no multiply-by-1.0 rides into the graph), which is the whole
       trivial-tier bit-exactness argument.
    2. **Inter shard gossip** (slow axis, quantized): worker ``j`` owns the
       slot-aligned shard window ``layout.shard(n_intra, j)`` and gossips
       *only that window* across nodes on the inter topology — one
       :class:`RoundPlan` per shard with ``base`` = the shard offset and
       ``topo`` = the inter tier, so the encode hashes global element
       indices and every RoundPlan guarantee (chunk pipelining, per-tensor
       scales, WireState math) carries over unchanged.  Each shard plan
       sub-chunks its own slots (``BucketChunk.chunks``): ``chunks=K``
       pipelining composes per shard, and a shard whose *own* leaf census
       resolves ``path="auto"`` to per-leaf degenerates to slot-granular
       chunks (per-leaf on a flat window == one chunk per slot).
    3. **All-gather** (fast axis): the mixed shards concatenate back to the
       full buffer and broadcast across the intra axis — every worker in a
       node leaves the round with the same model, like D-PSGD after an
       exact node-local average.

    With ``n_intra == 1`` stages 1 and 3 are identity reshapes and stage 2
    is one whole-buffer RoundPlan on the inter topology — byte- and
    bit-identical to the single-tier staged round (``tests/
    test_hierarchical.py`` pins this for all five wires, both backends,
    WireState carries included).

    Stateful (EF) wires keep their residual in the *owned-shard domain*:
    one ``[n_inter, padded_elems]`` f32 buffer — row ``g``, window ``j``
    is worker ``(g, j)``'s residual for the shard it encodes — i.e.
    ``n_intra``-fold smaller than the single-tier ``[n, padded_elems]``
    state, which is the memory half of the hierarchy headline.
    """
    engine: "CommEngine"
    layout: bucket.BucketLayout
    flat: jax.Array                    # [n, D] staging buffer
    backend: str
    chunks: int = 1                    # per-shard sub-chunk count K
    theta: Any = None
    B: Any = None
    seed: Optional[jax.Array] = None
    residual: Optional[jax.Array] = None   # [n_inter, D] owned-shard EF state
    step: Optional[jax.Array] = None
    # elastic rounds: per-NODE presence over the inter tier (length
    # n_inter).  An absent node keeps its intra average but drops out of
    # the inter shard gossip — the "uplink partition" failure mode; its
    # owned-shard residual rows pass through untouched.
    presence: Optional[Tuple[int, ...]] = None

    @property
    def topo(self) -> HierarchicalTopology:
        return self.engine.topo

    def intra_reduce(self) -> jax.Array:
        """Stage 1: the intra tier's circulant mix along the node axis;
        returns ``[n_inter, n_intra, D]``.  Pure reshape when trivial."""
        intra = self.topo.intra
        g, k = self.topo.n_inter, self.topo.n_intra
        stage = self.flat.reshape(g, k, self.flat.shape[-1])
        if k == 1:
            return stage
        with obs_trace.named_phase("comm.intra_reduce"):
            out = None
            for o, w in zip(intra.offsets, intra.weights):
                t = (jnp.roll(stage, -o, axis=1) if o % k else stage) * w
                out = t if out is None else out + t
            return out.astype(stage.dtype)

    def shard_plan(self, j: int, z: jax.Array) -> RoundPlan:
        """Stage 2 for shard ``j``: the owner rows' window as a RoundPlan
        over ``n_inter`` node-workers on the inter topology."""
        shard = self.layout.shard(self.topo.n_intra, j)
        k = self.chunks
        if not self.engine._shard_bucketed(shard):
            # this shard's own census says per-leaf: slot-granular chunks
            k = max(k, len(shard.slots))
        zj = jax.lax.slice_in_dim(z[:, j, :], shard.offset,
                                  shard.offset + shard.size, axis=1)
        res = None
        if self.residual is not None:
            res = jax.lax.slice_in_dim(self.residual, shard.offset,
                                       shard.offset + shard.size, axis=1)
        return RoundPlan(engine=self.engine, layout=self.layout,
                         chunks=shard.chunks(k), flat=zj,
                         backend=self.backend, theta=self.theta, B=self.B,
                         seed=self.seed, residual=res, step=self.step,
                         base=shard.offset, topo=self.topo.inter,
                         presence=self.presence)

    def run(self):
        """Execute the tiered round.  Returns the mixed ``[n, D]`` buffer
        (stateless wires) or ``(mixed buffer, new [n_inter, D] residual)``
        (stateful wires)."""
        eng = self.engine
        g, k = self.topo.n_inter, self.topo.n_intra
        stateful = eng.stateful
        z = self.intra_reduce()
        if not self.topo.inter.neighbor_offsets():
            # single node: the round is the intra average alone
            out = z
            res = self.residual
        else:
            outs, ress = [], []
            for j in range(k):
                if self.layout.shard(k, j).size == 0:
                    continue        # more workers than slots: empty window
                plan = self.shard_plan(j, z)
                r = plan.run()
                if stateful:
                    outs.append(r[0])
                    ress.append(r[1])
                else:
                    outs.append(r)
            # stage 3a: concatenate the mixed shards (they cover [0, D)
            # slot-aligned, in order) back into the full node buffer
            full = outs[0] if len(outs) == 1 else jnp.concatenate(outs,
                                                                  axis=1)
            out = full[:, None, :]
            res = None
            if stateful:
                res = (ress[0] if len(ress) == 1
                       else jnp.concatenate(ress, axis=1))
        # stage 3b: all-gather — broadcast each node's mixed model across
        # the intra axis (identity reshape when n_intra == 1)
        D = self.flat.shape[-1]
        out = jnp.broadcast_to(out, (g, k, D)).reshape(g * k, D)
        if stateful:
            return out, res
        return out


@dataclasses.dataclass(frozen=True)
class CommEngine:
    """One gossip round, end-to-end: codec x topology x backend + accounting.

    Static (hashable) configuration only — per-round dynamics (``theta``, the
    PRNG key, the ledger, WireState) are call arguments, so an engine can be
    constructed freely inside a jitted step function.

    ``path`` selects the gossip data path: ``"bucketed"`` stages the whole
    stacked pytree in one flat buffer (one encode launch, one packed roll
    per offset, one fused decode-reduce), ``"per_leaf"`` gossips leaf by
    leaf (the parity reference), and ``"auto"`` (default) picks per
    (layout, codec) from the measured crossover table (module docstring).
    Both paths draw the same stochastic-rounding uniforms per element
    (global counter indices), so they are bit-exact against each other for
    the Moniqua wire.

    ``topo`` may be a :class:`~repro.core.topology.HierarchicalTopology`,
    which turns every ``mix`` into a two-tier round (:class:`TieredPlan`):
    full-precision reduce-scatter/all-gather on the fast intra-node axis,
    quantized gossip of each worker's owned shard on the slow inter-node
    axis.  Tiered rounds always run in the staged flat-bucket domain
    (``path`` then governs per-*shard* launch granularity via the shard's
    own leaf census), and with a trivial intra tier (``n_intra == 1``)
    they are bit-exact against the single-tier bucketed round on the
    inter topology — payloads, outputs, and WireState.

    ``chunks`` sets the default chunk count for the staged round
    (``round_plan``): the bucketed flat buffer is split into that many
    slot-aligned windows and the phases software-pipelined.  ``chunks=1``
    is the barrier round; any K is bit-exact against it.

    ``telemetry`` (static, default off) attaches a round-health dict
    (``repro.obs.metrics``) to the returned :class:`MixResult`: consensus
    inf-distance and theta headroom, the modulo alias sentinel, EF residual
    norm, warmup indicator, payload bits/param.  The telemetry is purely
    observational — computed from the round's own flat buffer / payload /
    state with pure jnp, feeding nothing back into the mix — so the mixed
    output (and payload and WireState) is bit-exact with the flag on or
    off, and the health values themselves are identical across backends,
    gossip paths, and chunk counts (always evaluated on the canonical flat
    buffer with the jnp reference encode, which is bitwise equal to the
    Pallas and per-leaf payloads by the parity contracts).  When off, the
    flag is a Python-level branch: the telemetry graph is never traced,
    hence dead-code-free under jit.
    """
    topo: Any                     # Topology | HierarchicalTopology
    codec: Any = dataclasses.field(default_factory=MoniquaWire)
    backend: str = "auto"
    path: str = "auto"
    chunks: int = 1
    telemetry: bool = False

    def __post_init__(self) -> None:
        if self.path not in PATHS:
            raise ValueError(f"unknown path {self.path!r}; one of {PATHS}")
        if int(self.chunks) < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")

    # -- hierarchy plumbing ------------------------------------------------
    @property
    def tiered(self) -> bool:
        """True when the topology is two-tier (every mix is a TieredPlan)."""
        return isinstance(self.topo, HierarchicalTopology)

    @property
    def gossip_topo(self) -> Topology:
        """The tier whose edges carry *quantized* payloads: the inter tier
        of a hierarchy, or the whole (flat) topology."""
        return self.topo.inter if self.tiered else self.topo

    # -- persistent per-worker codec state (WireState) ---------------------
    @property
    def stateful(self) -> bool:
        """True for wires carrying per-worker state (EF residuals) across
        rounds; their ``mix`` takes a ``state`` carry and the returned
        ``MixResult.state`` must be threaded into the next round — like
        ``theta``, and checkpointed like params (``checkpoint/ckpt.py``
        serializes it inside trainer state)."""
        return bool(getattr(self.codec, "stateful", False))

    def init_wire_state(self, X: PyTree) -> dict:
        """Fresh ``WireState`` for a stacked pytree (``{}`` for stateless
        wires).  Accepts abstract ``ShapeDtypeStruct`` trees — only shapes
        are read, so trainers can build it under ``jax.eval_shape``.

        The residual lives in the *flat bucket domain* ``[n, padded_elems]``
        (one f32 per row-aligned element): both the bucketed and the
        per-leaf gossip paths read and write the same canonical buffer,
        which is what lets them produce bit-identical post-round state.

        Tiered engines shard the residual into the owned-shard domain:
        one ``[n_inter, padded_elems]`` buffer where row ``g``, window
        ``j`` is worker ``(g, j)``'s residual for the shard it encodes —
        ``n_intra``-fold smaller than the single-tier state (and identical
        to it when the intra tier is trivial).
        """
        if not self.stateful:
            return {}
        layout = self.layout(X)
        rows = (self.topo.n_inter if self.tiered else layout.n_workers)
        return {"residual": jnp.zeros((rows, layout.padded_elems),
                                      jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def wire_state_bytes(self, X: PyTree) -> int:
        """Per-worker bytes of persistent codec state (Tables 1-2 memory
        column): 0 for full/moniqua/qsgd, residual + counter for EF wires.
        Tiered engines only persist each worker's owned shard, so the
        per-worker residual shrinks ``n_intra``-fold (reported as the
        exact per-worker average; shard windows are slot-aligned)."""
        if not self.stateful or not jax.tree.leaves(X):
            return 0
        elems = self.layout(X).padded_elems
        if self.tiered:
            elems = -(-elems // self.topo.n_intra)
        return elems * 4 + 4

    # -- gossip path resolution --------------------------------------------
    def resolved_path(self, X: PyTree,
                      shard: Optional[bucket.BucketChunk] = None) -> str:
        """The concrete path (``"bucketed"``/``"per_leaf"``) this engine
        takes for ``X``: the configured one, or — under ``"auto"`` — the
        measured per-(layout, codec) crossover.  Stateful wires always
        bucket (their canonical residual lives in the flat domain).

        With ``shard`` (a :meth:`~repro.comm.bucket.BucketLayout.shard`
        window), ``"auto"`` resolves on the *shard's own leaf census*, not
        the whole model's: a tiered round only encodes the window a worker
        owns, so the pad-amplification that decides bucketing must be the
        window's.  On a tiered engine ``"per_leaf"`` means slot-granular
        launches over the shard window (one chunk per slot).
        """
        if self.path != "auto":
            return self.path
        if self.stateful:
            return "bucketed"
        if shard is not None:
            return ("bucketed" if _auto_bucketed_slots(
                shard.slots, max(shard.size, 1), self.codec.name)
                else "per_leaf")
        layout = self.layout(X)
        return ("bucketed" if _auto_bucketed(layout, self.codec.name)
                else "per_leaf")

    def _use_bucketed(self, X: PyTree) -> bool:
        return self.resolved_path(X) == "bucketed"

    def _shard_bucketed(self, shard: bucket.BucketChunk) -> bool:
        return self.resolved_path(None, shard=shard) == "bucketed"

    # -- the staged round --------------------------------------------------
    def round_plan(self, X: PyTree, theta=None,
                   key: Optional[jax.Array] = None,
                   state: Optional[dict] = None,
                   chunks: Optional[int] = None,
                   presence=None) -> RoundPlan:
        """Stage one gossip round on the flat bucket: returns a
        :class:`RoundPlan` whose ``encode_chunk``/``permute``/
        ``decode_reduce`` phases the caller can interleave (or just
        ``run()``).  ``chunks`` overrides the engine default K.

        The plan always works in the bucketed flat domain; a mixed-dtype
        tree on the raw wire has no bucketed round (f32 staging would
        change the mixing arithmetic) and raises here — ``mix`` handles
        that case by falling back to the per-leaf circulant.

        Tiered engines stage per owned shard instead (one RoundPlan per
        shard inside :class:`TieredPlan`); use :meth:`tiered_plan` / ``mix``.
        """
        if self.tiered:
            raise ValueError(
                "a tiered engine stages per owned shard; use "
                "tiered_plan()/mix() instead of round_plan()")
        layout = self.layout(X)
        if self.codec.name == "full" and not layout.uniform_dtype:
            raise ValueError(
                "no staged round for a mixed-dtype tree on the full wire "
                "(f32 staging would change the mixing arithmetic); "
                "use mix(), which falls back to the per-leaf circulant")
        if self.stateful:
            self._check_wire_state(state)
        k = self.chunks if chunks is None else int(chunks)
        backend = resolve_backend(self.backend)
        flat = layout.flatten(X)
        B = None
        seed = None
        residual = None
        step = None
        if self.codec.name != "full":
            self._require_key(key)
            seed = kops._key_to_seed(key)
        if self.codec.name == "moniqua":
            if theta is None:
                raise ValueError("MoniquaWire needs the a-priori bound theta")
            B = modulo.b_theta(theta, self.codec.spec.delta)
        if self.stateful:
            flat = flat.astype(jnp.float32)
            residual, step = state["residual"], state["step"]
        return RoundPlan(engine=self, layout=layout, chunks=layout.chunks(k),
                         flat=flat, backend=backend, theta=theta, B=B,
                         seed=seed, residual=residual, step=step,
                         presence=_normalize_presence(presence,
                                                      self.gossip_topo.n))

    def tiered_plan(self, X: PyTree, theta=None,
                    key: Optional[jax.Array] = None,
                    state: Optional[dict] = None,
                    chunks: Optional[int] = None,
                    presence=None) -> TieredPlan:
        """Stage one two-tier round (hierarchical engines): intra reduce,
        per-shard inter gossip, all-gather.  ``chunks`` is the per-shard
        sub-chunk count K (pipelined inside each shard's RoundPlan).
        """
        if not self.tiered:
            raise ValueError("tiered_plan needs a HierarchicalTopology "
                             "engine; use round_plan() on flat topologies")
        layout = self.layout(X)
        if self.codec.name == "full" and not layout.uniform_dtype:
            raise ValueError(
                "no tiered round for a mixed-dtype tree on the full wire "
                "(f32 staging would change the mixing arithmetic); stage "
                "the tree in one dtype or use a flat topology")
        if self.stateful:
            self._check_wire_state(state)
        k = self.chunks if chunks is None else int(chunks)
        backend = resolve_backend(self.backend)
        flat = layout.flatten(X)
        B = None
        seed = None
        residual = None
        step = None
        if self.codec.name != "full":
            self._require_key(key)
            seed = kops._key_to_seed(key)
        if self.codec.name == "moniqua":
            if theta is None:
                raise ValueError("MoniquaWire needs the a-priori bound theta")
            B = modulo.b_theta(theta, self.codec.spec.delta)
        if self.stateful:
            flat = flat.astype(jnp.float32)
            residual, step = state["residual"], state["step"]
        return TieredPlan(engine=self, layout=layout, flat=flat,
                          backend=backend, chunks=max(k, 1), theta=theta,
                          B=B, seed=seed, residual=residual, step=step,
                          presence=_normalize_presence(presence,
                                                       self.topo.n_inter))

    # -- the tentpole primitive --------------------------------------------
    def mix(self, X: PyTree, theta=None, key: Optional[jax.Array] = None,
            ledger: Optional[BytesLedger] = None,
            state: Optional[dict] = None, presence=None) -> MixResult:
        """One gossip round on stacked models (leaves ``[n, ...]``).

        Returns a :class:`MixResult`: ``.x`` is ``X_{k+1/2}`` (with the
        full-precision codec exactly the circulant ``X W`` of
        ``gossip.mix``), ``.state`` the post-round WireState (``{}`` for
        stateless wires; stateful wires require the ``state`` carry from
        :meth:`init_wire_state` and the caller must thread ``.state`` into
        the next round), ``.health`` the round-health dict when the engine
        has ``telemetry=True`` (else ``None``).  ``ledger`` (if given) is
        credited at trace time with payload-bytes * n_neighbors per round.

        ``presence`` (elastic rounds): per-worker 0/1 mask — per NODE
        (length ``n_inter``) on tiered engines.  Dead edges contribute
        identity (module docstring); ``None``/all-ones is bit-exact
        today's round.
        """
        if self.stateful:
            self._check_wire_state(state)
        if self.tiered:
            return self._mix_tiered(X, theta, key, ledger, state, presence)
        presence = _normalize_presence(presence, self.topo.n)
        offsets = self.topo.neighbor_offsets()
        if not offsets or not jax.tree.leaves(X):
            # single worker or empty pytree: nothing on the wire
            return self._empty_round(X, state)
        if ledger is not None:
            self._record(X, ledger)
        if self.codec.name == "moniqua" and theta is None:
            raise ValueError("MoniquaWire needs the a-priori bound theta")
        if self.stateful:
            Xm, new_state = self._mix_stateful(X, state, key, presence)
            health = (self._round_health(X, theta, key, new_state, presence)
                      if self.telemetry else None)
            return MixResult(Xm, new_state, health)
        layout = self.layout(X)
        full_mixed_dtype = (self.codec.name == "full"
                            and not layout.uniform_dtype)
        if self._use_bucketed(X) and not full_mixed_dtype:
            Xm = layout.unflatten(
                self.round_plan(X, theta=theta, key=key,
                                presence=presence).run())
        elif self.codec.name == "full":
            if presence is None:
                Xm = gossip.mix(X, self.topo)
            else:
                Xm = jax.tree.map(
                    lambda l: _masked_circulant(l, self.topo, presence), X)
        else:
            backend = resolve_backend(self.backend)
            self._require_key(key)
            base_seed = kops._key_to_seed(key)
            leaves, td = jax.tree.flatten(X)
            if self.codec.name == "moniqua":
                # global counter indices: leaf i's elements hash
                # (seed, layout.offset_i + e), the SAME pairs the bucketed
                # one-shot encode hashes — the bucketed-vs-per-leaf parity
                out = [self._mix_leaf(l, theta, base_seed, backend,
                                      idx_base=layout.offsets[i],
                                      presence=presence)
                       for i, l in enumerate(leaves)]
            else:
                out = [self._mix_leaf(l, theta, _leaf_seed(base_seed, i),
                                      backend, presence=presence)
                       for i, l in enumerate(leaves)]
            Xm = jax.tree.unflatten(td, out)
        health = (self._round_health(X, theta, key, None, presence)
                  if self.telemetry else None)
        return MixResult(Xm, {}, health)

    def _mix_tiered(self, X: PyTree, theta, key: Optional[jax.Array],
                    ledger: Optional[BytesLedger],
                    state: Optional[dict], presence=None) -> MixResult:
        """Tiered engines' round: stage and run a :class:`TieredPlan`.

        Tiered rounds always stage through the flat bucket — the intra
        reduce-scatter/all-gather is a whole-buffer operation, so there is
        no per-leaf variant to resolve to (``path`` only affects how stage
        2 sub-chunks each shard).
        """
        if not jax.tree.leaves(X) or self.topo.n == 1:
            return self._empty_round(X, state)
        if self.codec.name == "moniqua" and theta is None:
            raise ValueError("MoniquaWire needs the a-priori bound theta")
        if ledger is not None:
            self._record(X, ledger)
        plan = self.tiered_plan(X, theta=theta, key=key, state=state,
                                presence=presence)
        layout = plan.layout
        if self.stateful:
            out, res = plan.run()
            new_state = {"residual": res, "step": state["step"] + 1}
            Xm = layout.unflatten(out.astype(layout.stage_dtype))
            health = (self._round_health(X, theta, key, new_state,
                                         plan.presence)
                      if self.telemetry else None)
            return MixResult(Xm, new_state, health)
        Xm = layout.unflatten(plan.run())
        health = (self._round_health(X, theta, key, None, plan.presence)
                  if self.telemetry else None)
        return MixResult(Xm, {}, health)

    def _empty_round(self, X: PyTree, state: Optional[dict]) -> MixResult:
        """Degenerate round (single worker / empty pytree): same MixResult
        shape as the main path, nothing on the wire."""
        health = obs_metrics.round_health_zero() if self.telemetry else None
        carry = state if (state is not None) else {}
        return MixResult(X, carry, health)

    def _check_wire_state(self, state: Optional[dict]) -> None:
        if not isinstance(state, dict) or "residual" not in state:
            raise ValueError(
                f"{self.codec.name} wire is stateful: pass "
                "state=engine.init_wire_state(X) and thread the returned "
                "MixResult.state carry across rounds")

    # -- step-level overlap: one-round-stale mixing ------------------------
    def init_gossip_carry(self, X: PyTree) -> dict:
        """Fresh carry for :meth:`mix_stale` (stateless Moniqua only).

        Holds the payload of the *previous* round — the packed residue, the
        reference buffer it was encoded from, the modulo base ``B`` it was
        encoded under, and a validity flag (the first round has nothing to
        decode).  Accepts abstract shapes (build under ``eval_shape``).
        """
        if self.stateful or self.codec.name != "moniqua":
            raise ValueError(
                "one-round-stale overlap needs the stateless moniqua wire "
                f"(got {self.codec.name!r})")
        if self.tiered:
            raise ValueError(
                "one-round-stale overlap is single-tier only: a tiered "
                "round's payloads are per owned shard, not whole-buffer")
        layout = self.layout(X)
        vpb = self.codec.spec.values_per_byte
        return {"packed": jnp.zeros((layout.n_workers,
                                     layout.padded_elems // vpb), jnp.uint8),
                "ref": jnp.zeros((layout.n_workers, layout.padded_elems),
                                 jnp.float32),
                "B": jnp.zeros((), jnp.float32),
                "valid": jnp.zeros((), jnp.bool_)}

    def mix_stale(self, X: PyTree, carry: dict, theta=None,
                  key: Optional[jax.Array] = None,
                  ledger: Optional[BytesLedger] = None,
                  presence=None) -> MixResult:
        """One-round-stale gossip: apply the PREVIOUS round's payloads to
        this round's model, then encode the mixed result for the next round.

        The returned ``MixResult.state`` is the new carry (thread it like
        WireState).  Step k's model moves by the consensus delta computed
        from round k-1's payloads — decoded against the *reference they
        were encoded from*, under the *B they were encoded under* — so a
        trainer can issue the next forward pass while the previous round's
        decode-reduce is still in flight.  Delay-1 staleness is covered by
        the asynchronous-decentralized-SGD analyses in PAPERS.md; the first
        round (``valid`` unset) applies no delta.

        ``presence`` (elastic): this round's mask gates which of last
        round's payloads are applied — a dead edge's delta is dropped
        (identity), an absent worker applies nothing.  Everyone still
        re-encodes (an absent worker's payload is masked by the round in
        which it is absent, not the round after).
        """
        if self.stateful or self.codec.name != "moniqua":
            raise ValueError(
                "mix_stale needs the stateless moniqua wire "
                f"(got {self.codec.name!r})")
        if self.tiered:
            raise ValueError(
                "mix_stale is single-tier only: a tiered round's payloads "
                "are per owned shard, not whole-buffer")
        if not isinstance(carry, dict) or "packed" not in carry:
            raise ValueError(
                "pass carry=engine.init_gossip_carry(X) and thread the "
                "returned MixResult.state across steps")
        offsets = self.topo.neighbor_offsets()
        if not offsets or not jax.tree.leaves(X):
            return self._empty_round(X, carry)
        if theta is None:
            raise ValueError("MoniquaWire needs the a-priori bound theta")
        if ledger is not None:
            self._record(X, ledger)
        presence = _normalize_presence(presence, self.topo.n)
        backend = resolve_backend(self.backend)
        self._require_key(key)
        seed = kops._key_to_seed(key)
        spec = self.codec.spec
        layout = self.layout(X)
        weights = self._neighbor_weights()
        flat = layout.flatten(X).astype(jnp.float32)
        # decode round k-1 against its own reference/B, apply the delta late
        with obs_trace.named_phase("comm.decode_reduce"):
            p_nbrs = jnp.stack([gossip._roll(carry["packed"], o)
                                for o in offsets])
            if presence is None:
                mixed_ref = kops.moniqua_decode_reduce_stacked(
                    carry["packed"], p_nbrs, carry["ref"], carry["B"],
                    weights, spec, backend=backend)
                delta = mixed_ref - carry["ref"]
            else:
                # elastic: gate each offset's decoded diff by the edge's
                # survival this round; absent rows apply no delta at all
                delta = jnp.zeros_like(carry["ref"])
                for k, (o, w) in enumerate(zip(offsets, weights)):
                    mixed_o = kops.moniqua_decode_reduce_stacked(
                        carry["packed"], p_nbrs[k:k + 1], carry["ref"],
                        carry["B"], (w,), spec, backend=backend)
                    delta = delta + jnp.where(
                        _alive_cols(presence, o),
                        mixed_o - carry["ref"], 0.0)
                delta = jnp.where(_present_cols(presence), delta, 0.0)
            out = flat + jnp.where(carry["valid"], delta, 0.0)
        # encode round k from the post-mix model, for consumption at k+1
        B = modulo.b_theta(theta, spec.delta)
        with obs_trace.named_phase("comm.encode"):
            packed = kops.moniqua_encode_stacked(out, B, spec, seed,
                                                 backend=backend)
        new_carry = {"packed": packed, "ref": out,
                     "B": jnp.asarray(B, jnp.float32),
                     "valid": jnp.ones((), jnp.bool_)}
        Xm = layout.unflatten(out.astype(layout.stage_dtype))
        health = (self._round_health(X, theta, key, None, presence)
                  if self.telemetry else None)
        return MixResult(Xm, new_carry, health)

    # -- round health (telemetry=True) -------------------------------------
    def _round_health(self, X: PyTree, theta, key: Optional[jax.Array],
                      new_state: Optional[dict],
                      presence: Optional[Tuple[int, ...]] = None) -> dict:
        """Health counters for the round just mixed (``repro.obs.metrics``).

        Always evaluated on the canonical flat bucket buffer with pure-jnp
        math, so the values are identical whichever backend, gossip path,
        or chunk count produced the mix: the per-leaf/chunked payloads
        concatenate to the bucketed one bitwise (PR-4 parity), and the jnp
        reference encode equals the Pallas kernel bitwise (PR-1 parity).
        On the bucketed moniqua path the sentinel's re-encode duplicates
        the round's own encode subgraph, which XLA CSEs away; elsewhere
        telemetry pays one extra encode per round — acceptable for an
        opt-in diagnostics flag.
        """
        with jax.named_scope("comm.telemetry"):
            layout = self.layout(X)
            flat = layout.flatten(X)
            offsets = self.topo.neighbor_offsets()
            h = obs_metrics.round_health_zero()
            h["consensus_inf"] = obs_metrics.consensus_inf(flat, offsets)
            h["bits_per_param"] = jnp.float32(
                8.0 * self.payload_bytes_per_broadcast(X)
                / max(layout.total_elems, 1))
            m = len(self.gossip_topo.neighbor_offsets())
            h["bytes_slow"] = jnp.float32(
                self.payload_bytes_per_broadcast(X) * m)
            h["bytes_fast"] = jnp.float32(self.fast_bytes_per_round(X))
            if presence is not None:
                # presence is a normalized static mask (partial by
                # construction: all-ones collapsed to None upstream)
                h["participation"] = jnp.float32(
                    sum(presence) / len(presence))
                h["dropped_neighbors"] = jnp.int32(
                    _dropped_edge_count(presence, self.gossip_topo))
            if self.codec.name == "moniqua" and theta is not None:
                spec = self.codec.spec
                theta = jnp.asarray(theta, jnp.float32)
                B = modulo.b_theta(theta, spec.delta)
                h["headroom"] = h["consensus_inf"] / B
                # tiered rounds encode per owned shard, so a whole-buffer
                # re-encode would not be bit-identical to the payloads the
                # round actually shipped: pin the sentinel to 0 instead of
                # reporting a number that doesn't describe the wire.
                if spec.delta < 0.25 and not self.tiered:
                    seed = kops._key_to_seed(key)
                    packed = kops.moniqua_encode_stacked(flat, B, spec,
                                                         seed, backend="jnp")
                    h["alias_count"] = obs_metrics.moniqua_alias_count(
                        packed, flat, B, theta, spec, offsets)
            if new_state is not None:
                h["ef_residual_l2"] = jnp.sqrt(jnp.sum(
                    jnp.square(new_state["residual"].astype(jnp.float32))))
                if self.codec.name == "onebit":
                    # the counter was already bumped: -1 recovers the flag
                    # the round just executed under
                    h["warm"] = (new_state["step"] - 1
                                 < self.codec.warmup).astype(jnp.float32)
            return h

    # -- stateful wires: error-feedback rounds on the flat bucket ----------
    def _mix_stateful(self, X: PyTree, state: dict,
                      key: Optional[jax.Array],
                      presence: Optional[Tuple[int, ...]] = None
                      ) -> Tuple[PyTree, dict]:
        """One EF gossip round; returns ``(X_{k+1/2}, new WireState)``.

        Both the bucketed (staged-plan) and the per-leaf paths run the same
        per-segment math on the canonical flat residual buffer: the
        bucketed round does it chunk by chunk over ``[n, D]`` (one
        segmented launch per chunk), the per-leaf round one leaf segment at
        a time (each leaf's payload rolled separately).  Same per-segment
        scales, same row-position rounding uniforms (``idx_base`` = the
        segment's bucket offset), same accumulation order — so outputs,
        payload bits, AND post-round state agree bitwise (the
        ``tests/test_engine.py`` stateful contracts).

        EF math runs in f32 on both backends (no Pallas kernel for the EF
        wires yet; ``resolve_backend`` still validates the name so the
        engine surface stays uniform).
        """
        layout = self.layout(X)
        if self._use_bucketed(X):
            out, res = self.round_plan(X, key=key, state=state,
                                       presence=presence).run()
        else:
            resolve_backend(self.backend)
            self._require_key(key)
            seed = kops._key_to_seed(key)
            flat = layout.flatten(X).astype(jnp.float32)
            residual, step = state["residual"], state["step"]
            out = jnp.zeros_like(flat)
            res = jnp.zeros_like(residual)
            for s in layout.slots:
                vi = jax.lax.slice_in_dim(flat, s.offset,
                                          s.offset + s.padded_size, axis=1)
                ri = jax.lax.slice_in_dim(residual, s.offset,
                                          s.offset + s.padded_size, axis=1)
                oi, rn = self._ef_flat_round(vi, ri, (s.padded_size,),
                                             s.offset, seed, step,
                                             presence)
                out = jax.lax.dynamic_update_slice(out, oi, (0, s.offset))
                res = jax.lax.dynamic_update_slice(res, rn, (0, s.offset))
        new_state = {"residual": res,
                     "step": state["step"] + jnp.int32(1)}
        return layout.unflatten(out.astype(layout.stage_dtype)), new_state

    def _ef_flat_round(self, v_base: jax.Array, residual: jax.Array,
                       segments: Tuple[int, ...], idx_base: int,
                       seed: jax.Array, step: jax.Array,
                       presence: Optional[Tuple[int, ...]] = None
                       ) -> Tuple[jax.Array, jax.Array]:
        """EF round on one flat f32 buffer slice (the per-leaf path): encode
        ``v = x + r``, gossip the codes, mix
        ``x + sum w_o (decode_j - decode_self)``, keep
        ``r' = v - decode_self``.  The bucketed path runs the identical
        math through ``RoundPlan`` phases.  ``presence`` gates dead edges
        to identity and carries absent rows' residuals untouched."""
        offsets = self.topo.neighbor_offsets()
        weights = self._neighbor_weights()
        spec = self.codec.spec

        def reduce(d_self, decode_neighbor):
            acc = None
            for o, w in zip(offsets, weights):
                t = decode_neighbor(o) - d_self
                if presence is not None:
                    t = jnp.where(_alive_cols(presence, o), t, 0.0)
                t = t * w
                acc = t if acc is None else acc + t
            return v_base + acc

        def mask_absent(out, res):
            if presence is None:
                return out, res
            here = _present_cols(presence)
            return (jnp.where(here, out, v_base),
                    jnp.where(here, res, residual))

        if self.codec.name == "ef_qsgd":
            v = v_base + residual
            with jax.named_scope("comm.encode"):
                packed, scales = ef_qsgd_encode_segmented(v, spec, seed,
                                                          segments, idx_base)
            with jax.named_scope("comm.decode_reduce"):
                d_self = qsgd_decode_segmented(packed, scales, spec,
                                               segments)
                out = reduce(d_self, lambda o: qsgd_decode_segmented(
                    gossip._roll(packed, o), gossip._roll(scales, o), spec,
                    segments))
            return mask_absent(out, v - d_self)

        # onebit: fp32 gossip during warmup, 1-bit sign codes + EF after.
        # The step counter is the need_reset-style switch.  Selected with
        # jnp.where, NOT lax.cond: cond branch bodies are optimized as
        # separate XLA computations whose fusion/FMA choices depend on the
        # buffer width, which breaks the bucketed-vs-per-leaf bitwise
        # contract at the ulp level.  Both value streams are cheap
        # elementwise math next to the communication, so computing both and
        # selecting is the right trade.
        warm_p = step < self.codec.warmup
        out_warm = (gossip.mix(v_base, self.topo) if presence is None
                    else _masked_circulant(v_base, self.topo, presence))
        v = v_base + residual
        packed, lo, hi = onebit_encode_segmented(v, seed, segments, idx_base,
                                                 spec.stochastic)
        d_self = onebit_decode_segmented(packed, lo, hi, segments)
        out_q = reduce(d_self, lambda o: onebit_decode_segmented(
            gossip._roll(packed, o), gossip._roll(lo, o),
            gossip._roll(hi, o), segments))
        return mask_absent(jnp.where(warm_p, out_warm, out_q),
                           jnp.where(warm_p, residual, v - d_self))

    def _mix_leaf(self, x: jax.Array, theta, seed: jax.Array,
                  backend: str, idx_base=0,
                  presence: Optional[Tuple[int, ...]] = None) -> jax.Array:
        if x.ndim == 1:      # scalar-per-worker leaf: give it a unit last axis
            return self._mix_leaf(x[:, None], theta, seed, backend,
                                  idx_base, presence)[:, 0]
        offsets = self.topo.neighbor_offsets()
        weights = self._neighbor_weights()
        if self.codec.name == "moniqua":
            spec = self.codec.spec
            B = modulo.b_theta(theta, spec.delta)
            # per-worker tiling: each worker's slice is encoded/decoded in
            # its own tile grid (kops stacked wrappers), so only the packed
            # payload roll crosses the worker axis and all workers share
            # one rounding-uniform stream per element (Supp. C)
            packed = kops.moniqua_encode_stacked(x, B, spec, seed,
                                                 backend=backend,
                                                 idx_base=idx_base)
            p_nbrs = jnp.stack([gossip._roll(packed, o) for o in offsets])
            if presence is None:
                return kops.moniqua_decode_reduce_stacked(
                    packed, p_nbrs, x, B, weights, spec, backend=backend)
            # elastic: fused decode-reduce per surviving offset, gated
            f = x.astype(jnp.float32)
            out = f
            for k, (o, w) in enumerate(zip(offsets, weights)):
                mixed_o = kops.moniqua_decode_reduce_stacked(
                    packed, p_nbrs[k:k + 1], x, B, (w,), spec,
                    backend=backend)
                out = out + jnp.where(_alive_cols(presence, o, x.ndim),
                                      mixed_o.astype(jnp.float32) - f, 0.0)
            return out.astype(x.dtype)
        # qsgd: reference-free decode; each worker ships (codes, own scale)
        spec = self.codec.spec
        packed, scale = qsgd_encode(x, spec, seed)
        xq_self = qsgd_decode(packed, scale, spec, x.shape[-1])
        acc = None
        for o, w in zip(offsets, weights):
            xq_j = qsgd_decode(gossip._roll(packed, o),
                               gossip._roll(scale, o), spec, x.shape[-1])
            t = xq_j - xq_self
            if presence is not None:
                t = jnp.where(_alive_cols(presence, o, x.ndim), t, 0.0)
            t = t * w
            acc = t if acc is None else acc + t
        return (x.astype(jnp.float32) + acc).astype(x.dtype)

    # -- layout plumbing ---------------------------------------------------
    def _align(self) -> int:
        """Row alignment of the flat buffer: values-per-byte for packed
        codecs (keeps per-leaf byte boundaries), 1 for the raw wire."""
        spec = getattr(self.codec, "spec", None)
        return spec.values_per_byte if spec is not None else 1

    def layout(self, X: PyTree) -> bucket.BucketLayout:
        """The (memoized) flat-buffer layout this engine uses for ``X``.

        Accepts abstract ``ShapeDtypeStruct`` trees, so callers (trainer,
        dryrun) can build the layout once outside jit; traced rounds then
        hit the cache with the identical static description.
        """
        return bucket.layout_of(X, self._align())

    def _neighbor_weights(self) -> Tuple[float, ...]:
        return _neighbor_weights_of(self.gossip_topo)

    def _require_key(self, key) -> None:
        """Stochastic rounding without a key would silently reuse seed 0
        every round, losing the across-step unbiasedness the convergence
        argument needs — fail loudly instead (matches the legacy path)."""
        spec = getattr(self.codec, "spec", None)
        if key is None and spec is not None and spec.stochastic:
            raise ValueError(
                f"{self.codec.name} wire with stochastic rounding needs a "
                "PRNG key (pass key=, or use a nearest-rounding QuantSpec)")

    # -- AD-PSGD's primitive: one edge exchange ----------------------------
    def init_edge_state(self, x: jax.Array) -> dict:
        """Per-endpoint ``WireState`` for :meth:`pair_average` (AD-PSGD
        edges): the EF residual lives in the padded flat domain of one
        model copy, plus the warmup step counter.  ``{}`` for stateless
        wires.  Accepts abstract shapes."""
        if not self.stateful:
            return {}
        vpb = self.codec.spec.values_per_byte
        size = int(np.prod(x.shape, dtype=np.int64))
        padded = -(-size // vpb) * vpb
        return {"residual": jnp.zeros((padded,), jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def pair_average(self, xi: jax.Array, xj: jax.Array, theta=None,
                     key: Optional[jax.Array] = None,
                     state_i: Optional[dict] = None,
                     state_j: Optional[dict] = None,
                     presence=None) -> PairResult:
        """One gossip on edge (i, j) with the pair-averaging ``W_k``.

        Quantized codecs exchange payloads and decode against each endpoint's
        own model (Algorithm 3 lines 4-7); both endpoints encode under the
        same seed (shared randomness).  Simulator-scale API: always pure-jnp
        (AD-PSGD runs under ``lax.scan`` on host devices).

        Returns a :class:`PairResult`; stateful wires additionally require
        per-endpoint ``state_i`` / ``state_j`` carries from
        :meth:`init_edge_state` and fill ``.state_i`` / ``.state_j`` with
        the post-exchange carries (``{}`` for stateless wires).

        ``presence`` (elastic): a 2-mask ``(p_i, p_j)``.  If either
        endpoint is absent — or the message between them was dropped —
        the exchange is the *identity*: both models come back untouched
        and EF carries (step counters included) do not advance, exactly
        as if the edge had never fired.  ``sim.events.replay_adpsgd``
        routes fault-dropped exchanges through this, so the fault replay
        exercises the real engine API.
        """
        presence = _normalize_presence(presence, 2)
        if presence is not None:
            # at least one endpoint missing: identity exchange
            return PairResult(xi, xj,
                              state_i if self.stateful else {},
                              state_j if self.stateful else {})
        if self.stateful:
            return self._pair_average_stateful(xi, xj, key, state_i, state_j)
        if self.codec.name == "full":
            avg = 0.5 * (xi + xj)
            return PairResult(avg, avg)
        self._require_key(key)
        seed = kops._key_to_seed(key)
        if self.codec.name == "moniqua":
            spec = self.codec.spec
            B = modulo.b_theta(theta, spec.delta)
            pi = kops.moniqua_encode_jnp(xi, B, spec, seed)
            pj = kops.moniqua_encode_jnp(xj, B, spec, seed)
            n_last = xi.shape[-1]

            def val(p):
                return kref.value_ref(p, B, spec.bits)[..., :n_last]

            xj_at_i = modulo.recover(val(pj), xi, B)
            xi_at_j = modulo.recover(val(pi), xj, B)
            xi_self = modulo.local_bias(val(pi), xi, B)
            xj_self = modulo.local_bias(val(pj), xj, B)
            return PairResult(xi + 0.5 * (xj_at_i - xi_self),
                              xj + 0.5 * (xi_at_j - xj_self))
        spec = self.codec.spec
        pi, si = qsgd_encode(xi, spec, seed, worker_axis=False)
        pj, sj = qsgd_encode(xj, spec, seed, worker_axis=False)
        qi = qsgd_decode(pi, si, spec, xi.shape[-1])
        qj = qsgd_decode(pj, sj, spec, xj.shape[-1])
        return PairResult(xi + 0.5 * (qj - qi), xj + 0.5 * (qi - qj))

    def _pair_average_stateful(self, xi: jax.Array, xj: jax.Array,
                               key: Optional[jax.Array],
                               state_i: Optional[dict],
                               state_j: Optional[dict]) -> PairResult:
        """EF edge exchange: each endpoint compensates with its own residual,
        ships codes of ``x + r``, and keeps ``r' = x + r - decode(sent)``."""
        for s in (state_i, state_j):
            if not isinstance(s, dict) or "residual" not in s:
                raise ValueError(
                    f"{self.codec.name} wire is stateful: pass state_i/"
                    "state_j=engine.init_edge_state(x) and thread the "
                    "returned PairResult.state_i/.state_j across edges")
        self._require_key(key)
        seed = kops._key_to_seed(key)
        spec = self.codec.spec
        size = int(np.prod(xi.shape, dtype=np.int64))
        padded = state_i["residual"].shape[0]
        seg = (padded,)

        def flat(x):
            f = jnp.ravel(x).astype(jnp.float32)
            return jnp.pad(f, (0, padded - size))[None, :]

        def unflat(f, like):
            return f[0, :size].reshape(like.shape).astype(like.dtype)

        fi, fj = flat(xi), flat(xj)
        vi = fi + state_i["residual"][None, :]
        vj = fj + state_j["residual"][None, :]

        if self.codec.name == "ef_qsgd":
            pi, si = ef_qsgd_encode_segmented(vi, spec, seed, seg)
            pj, sj = ef_qsgd_encode_segmented(vj, spec, seed, seg)
            di = qsgd_decode_segmented(pi, si, spec, seg)
            dj = qsgd_decode_segmented(pj, sj, spec, seg)
            oi, oj = fi + 0.5 * (dj - di), fj + 0.5 * (di - dj)
            ri, rj = vi - di, vj - dj
        else:
            # onebit: a mixed pair stays full-precision — the earlier of
            # the two counters decides warm-vs-quantized.  where-select
            # (not lax.cond) for the same bitwise-contract reason as the
            # gossip round.
            warm_p = jnp.minimum(state_i["step"],
                                 state_j["step"]) < self.codec.warmup
            avg = 0.5 * (fi + fj)
            pi, loi, hii = onebit_encode_segmented(vi, seed, seg, 0,
                                                   spec.stochastic)
            pj, loj, hij = onebit_encode_segmented(vj, seed, seg, 0,
                                                   spec.stochastic)
            di = onebit_decode_segmented(pi, loi, hii, seg)
            dj = onebit_decode_segmented(pj, loj, hij, seg)
            oi = jnp.where(warm_p, avg, fi + 0.5 * (dj - di))
            oj = jnp.where(warm_p, avg, fj + 0.5 * (di - dj))
            ri = jnp.where(warm_p, state_i["residual"][None, :], vi - di)
            rj = jnp.where(warm_p, state_j["residual"][None, :], vj - dj)
        return PairResult(
            unflat(oi, xi), unflat(oj, xj),
            {"residual": ri[0], "step": state_i["step"] + jnp.int32(1)},
            {"residual": rj[0], "step": state_j["step"] + jnp.int32(1)})

    def pair_health(self, xi: jax.Array, xj: jax.Array, theta=None,
                    key: Optional[jax.Array] = None) -> dict:
        """Round health of one :meth:`pair_average` edge exchange.

        Observational twin of ``mix``'s telemetry for the AD-PSGD
        primitive: consensus distance of the endpoints, plus (Moniqua) the
        theta headroom and both-direction alias sentinel on payloads
        re-encoded under the exchange seed — bit-identical to what
        ``pair_average`` ships.  Call on the *pre-exchange* endpoints.
        """
        with jax.named_scope("comm.telemetry"):
            spec = (self.codec.spec
                    if self.codec.name == "moniqua" else None)
            h = obs_metrics.pair_health(
                xi, xj, theta=theta, spec=spec,
                seed=kops._key_to_seed(key) if spec is not None else None)
            if spec is None:
                bits = getattr(getattr(self.codec, "spec", None), "bits",
                               32)
                h["bits_per_param"] = jnp.float32(
                    32.0 if self.codec.name == "full" else float(bits))
            return h

    # -- gossip building blocks shared by the algorithm zoo ----------------
    def neighbor_sum(self, X: PyTree, transform) -> PyTree:
        """``sum_{o != 0} w_o * transform(roll(X, -o), o)`` leaf-wise.

        Flat-topology primitive (replica-mixing baselines); tiered
        engines have no single circulant to roll on."""
        if self.tiered:
            raise ValueError(
                "neighbor_sum needs a flat circulant topology; the "
                "replica-mixing baselines do not support tiers")
        return gossip.neighbor_sum(X, self.topo, transform)

    def self_weight(self) -> float:
        if self.tiered:
            raise ValueError(
                "self_weight needs a flat circulant topology; the "
                "replica-mixing baselines do not support tiers")
        return gossip.self_weight(self.topo)

    # -- accounting --------------------------------------------------------
    def payload_bytes_per_broadcast(self, X: PyTree) -> int:
        """Bytes one worker ships to ONE neighbor per round.

        Bucketed rounds roll the packed flat buffer plus, for qsgd, the
        per-tensor scale vector; per-leaf rounds roll each leaf's payload.
        The vpb row alignment makes the bucketed Moniqua payload equal the
        per-leaf sum exactly — the tile-grid pad is sliced off before the
        roll and never rides the wire — and bucketed qsgd keeps one
        4-byte scale per tensor, so its bytes match the per-leaf sum too.
        A mixed-dtype tree on the ``full`` wire mixes per leaf (f32
        staging would change the arithmetic), so its bytes are the
        per-leaf sum as well.  Because the paths agree byte for byte,
        ``path="auto"`` resolution never changes this number.

        Tiered engines: each worker broadcasts only its *owned shard* on
        the slow axis.  The per-shard payloads sum to the whole-buffer
        staged payload exactly (``padded_elems // vpb`` and ``num_leaves``
        both distribute over slot-aligned shards), so one shard is a
        ceil'd ``n_intra``-th of the single-tier number — the ~n_intra-fold
        slow-axis reduction the hierarchy headline claims.
        """
        if not jax.tree.leaves(X):
            return 0
        if self.tiered:
            return -(-self._staged_payload_bytes(self.layout(X))
                     // self.topo.n_intra)
        if self.stateful:
            # EF wires gossip packed flat segments on BOTH paths (the
            # per-leaf round slices the same canonical bucket buffer), so
            # the accounting is layout-based either way.  onebit warmup
            # rounds ship f32 (``warmup_payload_bytes``); steady state is
            # what's reported.
            return self._staged_payload_bytes(self.layout(X))
        if self._use_bucketed(X):
            layout = self.layout(X)
            if self.codec.name == "full" and not layout.uniform_dtype:
                # per-leaf fallback path
                return sum(self.codec.payload_bytes(
                    leaf.shape[1:], leaf.dtype.itemsize)
                    for leaf in jax.tree.leaves(X))
            return self._staged_payload_bytes(layout)
        return sum(self.codec.payload_bytes(leaf.shape[1:],
                                            leaf.dtype.itemsize)
                   for leaf in jax.tree.leaves(X))

    def _staged_payload_bytes(self, layout: bucket.BucketLayout) -> int:
        """Whole-buffer payload on the staged (bucketed) path: packed codes
        plus per-segment scale words (one f32 for qsgd/ef_qsgd, a lo/hi
        level pair for onebit)."""
        if self.codec.name == "full":
            return layout.total_elems * jnp.dtype(
                layout.stage_dtype).itemsize
        spec = self.codec.spec
        nbytes = layout.padded_elems // spec.values_per_byte
        if self.codec.name in ("qsgd", "ef_qsgd"):
            nbytes += 4 * layout.num_leaves
        elif self.codec.name == "onebit":
            nbytes += 8 * layout.num_leaves
        return nbytes

    def fast_bytes_per_round(self, X: PyTree) -> int:
        """Fast-axis (intra) bytes one worker sends per tiered round:
        reduce-scatter plus all-gather of the staging buffer, i.e.
        ``2 * (n_intra - 1) / n_intra`` of it in the staging dtype (f32
        for EF wires, which stage in f32).  0 for single-tier engines
        and for a trivial intra tier.
        """
        if not self.tiered or not jax.tree.leaves(X):
            return 0
        k = self.topo.n_intra
        if k == 1:
            return 0
        layout = self.layout(X)
        itemsize = (4 if self.stateful
                    else jnp.dtype(layout.stage_dtype).itemsize)
        return 2 * itemsize * layout.padded_elems * (k - 1) // k

    def bytes_per_round(self, X: PyTree) -> int:
        """Payload bytes *sent* per worker per gossip round (all leaves).

        Tiered engines: the fast-axis reduce-scatter/all-gather bytes plus
        one owned-shard broadcast per *inter* neighbor on the slow axis.
        """
        m = len(self.gossip_topo.neighbor_offsets())
        return (self.fast_bytes_per_round(X)
                + self.payload_bytes_per_broadcast(X) * m)

    def _record(self, X: PyTree, ledger: BytesLedger) -> None:
        ledger.add(self.payload_bytes_per_broadcast(X),
                   len(self.gossip_topo.neighbor_offsets()), tier="slow")
        fast = self.fast_bytes_per_round(X)
        if fast:
            ledger.add(fast, 1, tier="fast")
