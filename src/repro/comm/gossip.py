"""Circulant gossip over a stacked worker axis.

All decentralized state is carried as pytrees whose leaves have a leading
worker dimension ``[n, ...]``.  On the production mesh that dimension is
sharded over the worker mesh axis (``data``, or ``pod`` x ``data``), so every
``jnp.roll(leaf, -o, axis=0)`` lowers to exactly one ``collective-permute``
whose operand is whatever we roll — for Moniqua, the **bit-packed uint8
payload**, which is how the paper's bandwidth saving becomes a measurable
reduction of the roofline collective term.

Weighted circulant mixing implements ``X W`` for circulant ``W``:

    (X W)[i] = sum_o  w_o * X[(i + o) mod n]  = sum_o w_o * roll(X, -o)[i]

``gossip_*`` functions operate leaf-wise over pytrees and return a
``BytesLedger`` recording bytes-on-wire per step per worker (used by the
wall-clock network model in benchmarks/).

Layering: this module is the roll-gossip *primitive* layer.  Algorithms
route their rounds through ``repro.comm.engine.CommEngine`` (codec x
topology x backend orchestration); ``moniqua_gossip`` below is the legacy
unfused reference round — it materialises one f32 model copy per neighbor,
which the engine's fused decode-reduce path avoids (docs/kernels.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moniqua import MoniquaCodec
from repro.core.topology import Topology

PyTree = Any


@dataclasses.dataclass
class BytesLedger:
    """Bytes sent per worker per gossip round (payload only, excl. headers).

    Split per tier: ``bytes_slow`` is the gossip-link traffic (the only
    tier a single-tier round has — quantization's target), ``bytes_fast``
    the intra-node reduce-scatter/all-gather traffic of tiered rounds.
    ``bytes_per_worker`` stays the total, so single-tier callers that
    only read the scalar see the same number as before.
    """
    bytes_per_worker: int = 0
    bytes_fast: int = 0
    bytes_slow: int = 0

    def add(self, nbytes: int, n_sends: int, tier: str = "slow") -> None:
        if tier not in ("fast", "slow"):
            raise ValueError(f"unknown tier {tier!r}")
        total = nbytes * n_sends
        self.bytes_per_worker += total
        if tier == "fast":
            self.bytes_fast += total
        else:
            self.bytes_slow += total


def _roll(leaf: jax.Array, offset: int) -> jax.Array:
    return jnp.roll(leaf, -offset, axis=0) if offset % leaf.shape[0] else leaf


def mix(X: PyTree, topo: Topology) -> PyTree:
    """Full-precision circulant mixing ``X W`` (D-PSGD line 'communicate')."""
    def mix_leaf(x):
        out = None
        for o, w in zip(topo.offsets, topo.weights):
            t = _roll(x, o) * w
            out = t if out is None else out + t
        return out.astype(x.dtype)
    return jax.tree.map(mix_leaf, X)


def neighbor_sum(X: PyTree, topo: Topology,
                 transform: Callable[[jax.Array, int], jax.Array]) -> PyTree:
    """``sum_{o != 0} w_o * transform(roll(X, -o), o)`` leaf-wise."""
    def f(x):
        out = None
        for o, w in zip(topo.offsets, topo.weights):
            if o % topo.n == 0:
                continue
            t = transform(_roll(x, o), o) * w
            out = t if out is None else out + t
        return out
    return jax.tree.map(f, X)


def self_weight(topo: Topology) -> float:
    return sum(w for o, w in zip(topo.offsets, topo.weights) if o % topo.n == 0)


def moniqua_gossip(
    X: PyTree,
    topo: Topology,
    codec: MoniquaCodec,
    theta,
    key: Optional[jax.Array] = None,
    ledger: Optional[BytesLedger] = None,
) -> PyTree:
    """Algorithm 1 lines 3-6: one Moniqua gossip round on stacked models.

    Every worker broadcasts one payload (its packed residue); with shared
    randomness one PRNG key serves all workers.  Returns ``X_{k+1/2}``.
    """
    n_neighbors = len(topo.neighbor_offsets())
    if n_neighbors == 0:          # single worker (hierarchical single-pod)
        return X

    def gossip_leaf(x, leaf_key):
        packed = codec.encode(x, theta, leaf_key)           # [n, ...packed]
        x_hat_self = codec.decode_self(packed, x, theta)    # line 4
        acc = None
        for o, w in zip(topo.offsets, topo.weights):
            if o % topo.n == 0:
                continue
            remote = _roll(packed, o)                        # the quantized collective
            x_hat_j = codec.decode(remote, x, theta)         # line 5 (y = local x)
            d = (x_hat_j - x_hat_self) * w
            acc = d if acc is None else acc + d
        if ledger is not None:
            ledger.add(codec.payload_bytes(x.shape[1:]), n_neighbors)
        out = x.astype(jnp.float32) + acc                    # line 6
        return out.astype(x.dtype)

    leaves, treedef = jax.tree.flatten(X)
    keys = ([None] * len(leaves) if key is None
            else list(jax.random.split(key, len(leaves))))
    return jax.tree.unflatten(treedef, [gossip_leaf(l, k) for l, k in zip(leaves, keys)])


def payload_bytes_tree(X: PyTree, codec: MoniquaCodec) -> int:
    """Total packed bytes for one broadcast of every leaf (per worker)."""
    total = 0
    for leaf in jax.tree.leaves(X):
        total += codec.payload_bytes(leaf.shape[1:])
    return total


def dtype_bytes_tree(X: PyTree) -> int:
    """Full-precision bytes per broadcast (per worker) — the D-PSGD baseline."""
    total = 0
    for leaf in jax.tree.leaves(X):
        total += int(np.prod(leaf.shape[1:], dtype=np.int64)) * leaf.dtype.itemsize
    return total
