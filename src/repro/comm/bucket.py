"""Bucketed flat-buffer gossip: one staging buffer for the whole model.

The per-leaf gossip round in ``CommEngine.mix`` pays a fixed cost per pytree
leaf: one encode launch, one decode-reduce launch, one payload roll per
offset, and — dominating everything for small leaves — one pad to the
256x1024 tile grid, which turns a 64-element bias into >=262k elements of
codec work.  A ResNet/transformer has dozens of sub-262k leaves (biases,
norms, scales), so dispatch + padding overhead swamps the tiny payloads a
1-bit wire actually ships.  This is the classic tensor-fusion observation
(Bagua's ``BaguaBucket``, Horovod's fusion buffer): flatten everything into
one contiguous buffer, pay the fixed costs once.

:class:`BucketLayout` is that buffer's static description.  Built once per
(treedef, leaf shapes/dtypes, alignment) — :func:`layout_of` memoizes — it
flattens a stacked ``[n, ...]`` pytree into one ``[n, D]`` staging buffer
and scatters the mixed result back.  Two invariants make the bucketed round
*bit-exact* against the per-leaf path (the contract
``tests/test_engine.py`` enforces):

1. **Per-leaf vpb row alignment.**  Each leaf's segment is the leaf
   flattened with its last axis zero-padded to the values-per-byte
   boundary — exactly the padding ``kernels/ops.py::_encode_layout``
   applies per leaf — so byte boundaries in the packed flat payload line
   up with the per-leaf payloads and the concatenation of per-leaf
   payload bytes IS the bucketed payload, bit for bit.
2. **Global element indexing.**  Element ``e`` of leaf ``i`` occupies flat
   position ``offset_i + e`` (row-padded positions), and the per-leaf path
   passes ``offset_i`` as the encode kernels' ``idx_base`` — both paths
   hash the same ``(seed, global_index)`` pair per element, so stochastic
   rounding draws identical uniforms (Supp.-C shared randomness is
   preserved: the worker axis never enters the index).

The single pad to the Pallas tile grid happens once, on the flat buffer,
inside ``kernels/ops.py`` — and is sliced off again before the payload
rolls, so tile padding never rides the wire and the bucketed Moniqua
payload bytes equal the per-leaf sum exactly.

Staging dtype: leaves sharing one floating dtype stage natively (a uniform
bf16 tree ships bf16 on the full-precision wire); mixed-dtype trees stage
in f32.  Widening casts are exact, so the quantized codecs stay bit-exact
either way; the full-precision wire, whose *mixing arithmetic* would
change under f32 staging, falls back to the per-leaf circulant mix on
mixed-dtype trees (``CommEngine._mix_bucketed``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Static placement of one stacked leaf inside the flat buffer."""
    shape: Tuple[int, ...]   # per-worker shape (leaf.shape[1:])
    dtype: Any               # original leaf dtype (restored on scatter)
    rows: int                # prod(shape[:-1]); 1 for scalar-per-worker
    last: int                # shape[-1]; 1 for scalar-per-worker
    last_padded: int         # last rounded up to the alignment
    size: int                # rows * last (real elements)
    padded_size: int         # rows * last_padded (elements in the buffer)
    offset: int              # element offset of this segment in the buffer


@dataclasses.dataclass(frozen=True)
class BucketChunk:
    """One contiguous window of the flat buffer, covering whole leaf slots.

    The staged-round pipeline (``CommEngine.round_plan``) encodes, permutes
    and decode-reduces one chunk at a time.  Chunk boundaries always fall on
    slot boundaries, so per-tensor codec statistics (qsgd's max-norm scale,
    onebit's lo/hi levels) never straddle a chunk, and — because every
    ``padded_size`` is a multiple of the layout alignment — chunk offsets
    stay on the values-per-byte packing boundary.
    """
    index: int               # position in the chunk sequence
    offset: int              # element offset of the window in the buffer
    size: int                # padded elements in the window
    slots: Tuple[LeafSlot, ...]   # the (contiguous) slots covered

    @property
    def segment_sizes(self) -> Tuple[int, ...]:
        """Per-tensor segment lengths inside this chunk (cf.
        ``BucketLayout.segment_sizes``, restricted to the window)."""
        return tuple(s.padded_size for s in self.slots)

    def chunks(self, k: int) -> Tuple["BucketChunk", ...]:
        """Sub-partition this window into (at most) ``k`` slot-aligned
        chunks — the same greedy sweep :meth:`BucketLayout.chunks` uses, so
        a shard window composes with the staged round's ``chunks=K``
        pipelining.  Offsets stay *global* buffer offsets (they are the
        encode kernels' ``idx_base``).  An empty window yields no chunks.
        """
        if not self.slots:
            return ()
        return _partition_slots(self.slots, max(int(k), 1))


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Cached flat-buffer layout for one stacked pytree structure.

    ``flatten``/``unflatten`` are pure jnp and safe inside jit; everything
    else is static Python computed once per structure (``layout_of``).
    """
    treedef: Any
    slots: Tuple[LeafSlot, ...]
    n_workers: int
    align: int               # values-per-byte row alignment (1 = none)
    stage_dtype: Any         # staging dtype of the flat buffer

    @property
    def num_leaves(self) -> int:
        return len(self.slots)

    @property
    def total_elems(self) -> int:
        """Real elements per worker (no padding)."""
        return sum(s.size for s in self.slots)

    @property
    def padded_elems(self) -> int:
        """Flat-buffer elements per worker (row padding included)."""
        return sum(s.padded_size for s in self.slots)

    @property
    def offsets(self) -> Tuple[int, ...]:
        """Per-leaf element offsets — the encode kernels' ``idx_base``."""
        return tuple(s.offset for s in self.slots)

    @property
    def uniform_dtype(self) -> bool:
        """True when every leaf already has the staging dtype — i.e. the
        flat buffer is a pure relayout with no widening casts."""
        return all(s.dtype == jnp.dtype(self.stage_dtype)
                   for s in self.slots)

    @property
    def segment_sizes(self) -> Tuple[int, ...]:
        """Per-leaf contiguous segment lengths (row padding included) —
        the static description codecs with per-tensor statistics (qsgd's
        max-norm scale) use to stay per-tensor on the flat buffer."""
        return tuple(s.padded_size for s in self.slots)

    def chunks(self, k: int) -> Tuple[BucketChunk, ...]:
        """Partition the buffer into (at most) ``k`` contiguous chunks.

        Deterministic static partition, balanced by padded element count
        with a greedy sweep: each chunk accumulates whole slots until it
        reaches the remaining-average target.  ``k`` is clamped to
        ``num_leaves`` (a chunk never splits a slot, so per-tensor scale
        segments stay intact) and to >= 1.  ``chunks(1)`` is the whole
        buffer — the barrier round — and the concatenation of chunk
        windows always covers ``[0, padded_elems)`` exactly, in order.
        """
        return _chunks_of(self, max(int(k), 1))

    def shard(self, axis_size: int, axis_index: int) -> BucketChunk:
        """The slot-aligned shard window worker ``axis_index`` of an
        ``axis_size``-way intra axis *owns* in the flat buffer.

        Shards partition ``[0, padded_elems)`` exactly, in order, on slot
        boundaries (per-tensor codec statistics never straddle a shard) and
        balanced by padded element count — the same greedy sweep as
        :meth:`chunks`, but with a fixed shard count: when the tree has
        fewer slots than ``axis_size``, trailing shards are *empty*
        (zero-size windows at the buffer end) rather than the count being
        clamped, so every worker of the intra axis has a well-defined
        (possibly trivial) window.  ``shard(1, 0)`` is the whole buffer —
        the single-tier reference window.
        """
        if axis_size < 1:
            raise ValueError(f"axis_size must be >= 1, got {axis_size}")
        if not 0 <= axis_index < axis_size:
            raise ValueError(
                f"axis_index {axis_index} out of range for "
                f"axis_size {axis_size}")
        return _shards_of(self, int(axis_size))[axis_index]

    # -- the two jit-safe data movers --------------------------------------
    def flatten(self, X: PyTree) -> jax.Array:
        """Stacked pytree -> one ``[n, padded_elems]`` staging buffer.

        Writes each segment into a preallocated buffer with
        ``dynamic_update_slice`` rather than ``jnp.concatenate``: XLA's CPU
        concat emitter falls off the memcpy path when its operands are
        fused reshapes (measured ~14x slower on a 61-leaf ResNet tree),
        while consecutive in-place DUS fusions stay at copy speed.
        """
        leaves = self.treedef.flatten_up_to(X)
        buf = jnp.zeros((self.n_workers, self.padded_elems),
                        self.stage_dtype)
        for leaf, s in zip(leaves, self.slots):
            seg = jnp.reshape(leaf, (self.n_workers, s.rows, s.last))
            seg = seg.astype(self.stage_dtype)
            if s.last_padded != s.last:
                seg = jnp.pad(seg, ((0, 0), (0, 0),
                                    (0, s.last_padded - s.last)))
            buf = jax.lax.dynamic_update_slice(
                buf, seg.reshape(self.n_workers, s.padded_size),
                (0, s.offset))
        return buf

    def unflatten(self, flat: jax.Array) -> PyTree:
        """Inverse of :func:`flatten`: slice segments, drop row padding,
        restore each leaf's shape and dtype."""
        out = []
        for s in self.slots:
            seg = jax.lax.slice_in_dim(flat, s.offset, s.offset + s.padded_size,
                                       axis=1)
            if s.last_padded != s.last:
                seg = seg.reshape(self.n_workers, s.rows, s.last_padded)
                seg = seg[..., :s.last]
            out.append(seg.reshape((self.n_workers,) + s.shape)
                       .astype(s.dtype))
        return self.treedef.unflatten(out)


@functools.lru_cache(maxsize=1024)
def _partition_slots(slots: Tuple[LeafSlot, ...],
                     k: int) -> Tuple[BucketChunk, ...]:
    """Greedy slot-aligned partition of a contiguous slot window into
    ``min(k, len(slots))`` balanced chunks (memoized: slots are frozen/
    hashable, so a jitted round re-tracing with the same window reuses the
    same static chunk descriptors).  Shared by whole-layout chunking
    (``BucketLayout.chunks``), shard windows (``BucketLayout.shard``), and
    shard sub-chunking (``BucketChunk.chunks``)."""
    k = min(k, len(slots))
    chunks, start = [], 0
    remaining = sum(s.padded_size for s in slots)
    for i in range(k):
        target = remaining / (k - i)
        end, acc = start, 0
        # take slots until the chunk reaches the remaining-average target;
        # every chunk takes at least one slot so all k chunks are non-empty
        while end < len(slots) and (end == start or acc < target):
            nxt = acc + slots[end].padded_size
            # stop before overshooting past the target by more than the
            # undershoot — keeps chunk sizes balanced around the target
            if end > start and nxt - target > target - acc:
                break
            acc = nxt
            end += 1
        # leave enough slots for the chunks still to come
        end = min(end, len(slots) - (k - i - 1))
        end = max(end, start + 1)
        window = slots[start:end]
        chunks.append(BucketChunk(index=i, offset=window[0].offset,
                                  size=sum(s.padded_size for s in window),
                                  slots=tuple(window)))
        remaining -= chunks[-1].size
        start = end
    return tuple(chunks)


def _chunks_of(layout: "BucketLayout", k: int) -> Tuple[BucketChunk, ...]:
    return _partition_slots(layout.slots, k)


@functools.lru_cache(maxsize=1024)
def _shards_of(layout: "BucketLayout",
               axis_size: int) -> Tuple[BucketChunk, ...]:
    """Exactly ``axis_size`` shard windows covering the buffer in order.

    The first ``min(axis_size, num_leaves)`` are the greedy balanced
    partition; any remainder (more workers than slots) are empty windows
    pinned to the buffer end so indexing stays total.
    """
    real = _partition_slots(layout.slots, axis_size)
    if len(real) == axis_size:
        return real
    end = layout.padded_elems
    empties = tuple(BucketChunk(index=i, offset=end, size=0, slots=())
                    for i in range(len(real), axis_size))
    return real + empties


def _common_stage_dtype(dtypes) -> Any:
    """One shared inexact dtype stages natively; anything mixed -> f32."""
    uniq = {jnp.dtype(d) for d in dtypes}
    if len(uniq) == 1:
        d = uniq.pop()
        if jnp.issubdtype(d, jnp.inexact):
            return d
    return jnp.dtype(jnp.float32)


@functools.lru_cache(maxsize=256)
def _build(treedef, descs: Tuple[Tuple[Tuple[int, ...], Any], ...],
           align: int) -> BucketLayout:
    if align < 1:
        raise ValueError(f"alignment must be >= 1, got {align}")
    if not descs:
        raise ValueError("cannot bucket an empty pytree")
    n = descs[0][0][0] if descs[0][0] else 0
    slots = []
    offset = 0
    for shape, dtype in descs:
        if not shape or shape[0] != n:
            raise ValueError(
                f"stacked leaves need a shared worker axis: {shape} vs n={n}")
        inner = shape[1:]
        last = inner[-1] if inner else 1
        rows = int(np.prod(inner[:-1], dtype=np.int64)) if inner else 1
        last_p = -(-last // align) * align
        slots.append(LeafSlot(shape=inner, dtype=jnp.dtype(dtype), rows=rows,
                              last=last, last_padded=last_p,
                              size=rows * last,
                              padded_size=rows * last_p, offset=offset))
        offset += rows * last_p
    return BucketLayout(treedef=treedef, slots=tuple(slots), n_workers=n,
                        align=align,
                        stage_dtype=_common_stage_dtype(d for _, d in descs))


def layout_of(X: PyTree, align: int = 1) -> BucketLayout:
    """The (memoized) flat-buffer layout for a stacked pytree.

    ``X`` may hold concrete arrays or ``ShapeDtypeStruct``s — only shapes
    and dtypes are read, so a trainer can warm the cache from its abstract
    state before jit and every traced round reuses the same layout object.
    """
    leaves, treedef = jax.tree.flatten(X)
    descs = tuple((tuple(l.shape), jnp.dtype(l.dtype)) for l in leaves)
    return _build(treedef, descs, int(align))
