"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all *per chip per step, in seconds*:

    compute    = HLO_FLOPs / peak_FLOPs
    memory     = HLO_bytes / HBM_bw
    collective = collective_bytes / link_bw

``compiled.cost_analysis()`` reports per-device FLOPs / bytes-accessed of the
post-SPMD module (verified empirically), so no further division by chip count
is needed.  ``collective_bytes`` is not in cost_analysis: we parse the
post-partitioning HLO text and sum the *operand* bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.  Operand sizes
are derived from the result shape and the replica-group size:

    all-reduce / all-to-all / collective-permute:  operand == result
    all-gather:     operand == result / group_size
    reduce-scatter: operand == result * group_size

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM; the network
term prices bytes on the shared ``repro.sim.network.TPU_V5E_ICI`` link
model (alpha-beta with alpha = 0: the roofline charges pure bandwidth,
per-message latency belongs to the event simulator in ``repro.sim``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sim.network import TPU_V5E_ICI

HW = {
    "peak_flops": 197e12,             # bf16 / chip
    "hbm_bw": 819e9,                  # B/s
    "ici_bw": TPU_V5E_ICI.beta_Bps,   # B/s per link (sim.network model)
}


def hw_with_ici(ici) -> Dict[str, float]:
    """HW table with a calibrated interconnect bandwidth.

    ``ici`` is a :class:`repro.sim.network.LinkModel` (e.g. the output of
    a ``sim/calibrate.py`` fit on measured collective times) or a plain
    bytes/s float.  Pass the result as ``roofline_from_compiled(..., hw=)``
    to price the collective term on measured rather than datasheet
    bandwidth — the ICI constant is a fit input, not a hardcode.
    """
    beta = getattr(ici, "beta_Bps", None)
    if beta is None:
        beta = float(ici)
    if beta <= 0:
        raise ValueError(f"ici bandwidth must be positive, got {beta}")
    return dict(HW, ici_bw=beta)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string like 'bf16[16,256]{1,0}' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z0-9\-]+)\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    def summary(self) -> str:
        parts = [f"{op}x{self.counts[op]}:{self.bytes_by_op[op]/1e6:.1f}MB"
                 for op in sorted(self.counts)]
        return " ".join(parts) if parts else "none"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    by: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):   # e.g. all-gather-start
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue                                 # counted at -start
        result = _shape_bytes(shape_str)
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
        if base == "all-gather":
            operand = result // max(g, 1)
        elif base == "reduce-scatter":
            operand = result * max(g, 1)
        else:
            operand = result
        counts[base] = counts.get(base, 0) + 1
        by[base] = by.get(base, 0) + operand
    return CollectiveStats(counts, by)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    collective_bytes: float      # per device
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float           # 6 N D (useful math, global)
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat/redundancy waste detector)."""
        total_hlo = self.flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """Model-FLOPs utilisation if the step ran at the roofline bound."""
        denom = self.bound_s * self.chips * HW["peak_flops"]
        return self.model_flops / denom if denom else 0.0


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jax versions.

    jax 0.4.x returns a list with one dict per computation; newer jax
    returns the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def roofline_from_compiled(compiled, model_flops: float, chips: int,
                           hw: Dict[str, float] = HW) -> Roofline:
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(
        flops=flops,
        bytes_accessed=nbytes,
        collective_bytes=float(stats.total_bytes),
        compute_s=flops / hw["peak_flops"],
        memory_s=nbytes / hw["hbm_bw"],
        collective_s=stats.total_bytes / hw["ici_bw"],
        model_flops=model_flops,
        chips=chips,
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) or 6 N_active D (MoE); D = tokens/step.

    Audio (enc-dec) processes enc_len + dec_len tokens per example, not
    ``seq_len`` (the raw audio length) — mirrors Model.batch_spec.
    """
    n = cfg.active_param_count()
    if cfg.family == "audio":
        enc_len = shape.seq_len // cfg.encoder_downsample
        dec_len = min(cfg.decoder_len_cap, max(shape.seq_len // 8, 16))
        tokens_per_ex = enc_len + dec_len
    else:
        tokens_per_ex = shape.seq_len
    if shape.kind == "train":
        tokens = shape.global_batch * tokens_per_ex
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * tokens_per_ex
        return 2.0 * n * tokens       # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
