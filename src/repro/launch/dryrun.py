import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  Test hook (used by tests/test_dryrun_small.py):
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
on the production mesh, with ShapeDtypeStruct inputs (no allocation), and
extract the roofline terms (analysis/roofline.py) from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                     # all 40 x 2
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape decode_32k --multi-pod --algo moniqua --bits 8
    ... --out results.json   (incremental append; safe to re-run)

Exit code is non-zero if any requested combination fails to compile.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as RL
from repro.configs import assigned_archs, get_config
from repro.configs.base import ArchConfig, InputShape, get_input_shape
from repro.core.algorithms import AlgoHyper, get_algorithm
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.theta import ThetaSchedule
from repro.core.topology import ring
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               mesh_context, mesh_shape_dict)
from repro.models.model_factory import build_model
from repro.models.sharding import ShardingRules
from repro.optim.sgd import SGDConfig
from repro.train import serve_step as SS
from repro.train import train_step as TS


def skip_reason(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    if cfg.name == "whisper-base" and shape.name == "long_500k":
        return ("full-attention encoder-decoder is quadratic; no sub-quadratic "
                "variant implemented (DESIGN.md §5)")
    return None


def input_specs(model, shape: InputShape, n_workers: int, stacked: bool):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    spec = model.batch_spec(shape)
    out = {}
    for name, (shp, dt) in spec.items():
        if stacked:
            assert shp[0] % n_workers == 0, (shp, n_workers)
            shp = (n_workers, shp[0] // n_workers) + tuple(shp[1:])
        out[name] = jax.ShapeDtypeStruct(shp, dt)
    return out


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    status: str
    seconds: float = 0.0
    error: str = ""
    memory: Dict[str, float] = dataclasses.field(default_factory=dict)
    roofline: Dict[str, Any] = dataclasses.field(default_factory=dict)
    collectives: Dict[str, Any] = dataclasses.field(default_factory=dict)
    sim: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def row(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               mesh=None, algo: str = "moniqua", bits: int = 8,
               wire: str = "moniqua", comm_backend: str = "auto",
               comm_path: str = "auto", chunks: int = 1,
               tiers: int = 1, telemetry: bool = False,
               scenario: Optional[str] = None,
               verbose: bool = True, override: Optional[dict] = None,
               rec=None) -> DryrunResult:
    """One (arch x shape x mesh) lower+compile.  ``rec`` (a
    ``repro.obs.trace.SpanRecorder``) gets lower/compile phase spans;
    ``telemetry`` threads the obs flag into the train step being lowered,
    so the compiled artifact is the instrumented one."""
    import contextlib
    cfg = get_config(arch)
    if override:
        cfg = dataclasses.replace(cfg, **override)
    shape = get_input_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    reason = skip_reason(cfg, shape)
    if reason:
        return DryrunResult(arch, shape_name, mesh_name, "skipped",
                            error=reason)
    t0 = time.time()

    def span(name):
        if rec is None:
            return contextlib.nullcontext()
        return rec.span(name, tid=f"{arch}/{shape_name}", mesh=mesh_name)

    try:
        mesh = mesh or make_production_mesh(multi_pod=multi_pod)
        ms = mesh_shape_dict(mesh)
        mesh_name = "x".join(str(v) for v in mesh.devices.shape)
        chips = 1
        for v in ms.values():
            chips *= v
        rules = ShardingRules(cfg.dist_mode, multi_pod="pod" in ms)
        model = build_model(cfg)
        n_workers = TS.n_workers_for(cfg, rules, ms)

        from repro.models import sharding as SH
        with mesh_context(mesh), SH.constraint_context(rules, ms):
            with span("dryrun.lower"):
                if shape.kind == "train":
                    lowered = _lower_train(model, shape, mesh, ms, rules,
                                           n_workers, algo, bits, wire,
                                           comm_backend, comm_path, chunks,
                                           tiers, telemetry)
                elif shape.kind == "prefill":
                    lowered = _lower_prefill(model, shape, mesh, ms, rules)
                else:
                    lowered = _lower_decode(model, shape, mesh, ms, rules)
            with span("dryrun.compile"):
                compiled = lowered.compile()
        mem = compiled.memory_analysis()
        print(f"[{arch} x {shape_name} x {mesh_name}] memory_analysis:",
              mem)
        ca = RL.cost_analysis_dict(compiled)
        print(f"[{arch} x {shape_name} x {mesh_name}] cost_analysis: "
              f"flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        roof = RL.roofline_from_compiled(
            compiled, RL.model_flops_for(cfg, shape), chips)
        stats = RL.parse_collectives(compiled.as_text())
        sim_pred: Dict[str, Any] = {}
        if scenario and shape.kind == "train":
            hp = _hyper(cfg, n_workers, algo, bits, wire, comm_backend,
                        comm_path, chunks, tiers, telemetry)
            with span("dryrun.sim"):
                sim_pred = _sim_predict(scenario, model, hp, n_workers,
                                        roof)
            if verbose:
                print(f"[{arch} x {shape_name} x {mesh_name}] sim "
                      f"{scenario}: round="
                      f"{sim_pred['predicted_round_s']*1e3:.3f}ms "
                      f"({sim_pred['network_overhead_x']:.2f}x roofline "
                      f"bound)")
        res = DryrunResult(
            arch, shape_name, mesh_name, "ok", seconds=time.time() - t0,
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_gb": (mem.argument_size_in_bytes
                                     + mem.output_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     - mem.alias_size_in_bytes) / 1e9,
            },
            roofline={
                "flops_per_chip": roof.flops,
                "bytes_per_chip": roof.bytes_accessed,
                "collective_bytes_per_chip": roof.collective_bytes,
                "compute_s": roof.compute_s,
                "memory_s": roof.memory_s,
                "collective_s": roof.collective_s,
                "dominant": roof.dominant,
                "bound_s": roof.bound_s,
                "model_flops": roof.model_flops,
                "useful_ratio": roof.useful_ratio,
                "mfu_upper_bound": roof.mfu_upper_bound,
            },
            collectives={"counts": stats.counts,
                         "bytes": stats.bytes_by_op,
                         "summary": stats.summary()},
            sim=sim_pred,
        )
        if verbose:
            r = res.roofline
            print(f"[{arch} x {shape_name} x {mesh_name}] OK in "
                  f"{res.seconds:.1f}s  dominant={r['dominant']} "
                  f"compute={r['compute_s']*1e3:.3f}ms "
                  f"memory={r['memory_s']*1e3:.3f}ms "
                  f"collective={r['collective_s']*1e3:.3f}ms  "
                  f"colls: {res.collectives['summary']}")
        return res
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        tb = traceback.format_exc(limit=20)
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] FAIL: {e}")
        return DryrunResult(arch, shape_name, mesh_name, "error",
                            seconds=time.time() - t0, error=f"{e}\n{tb}")


def _hyper(cfg, n_workers, algo, bits, wire="moniqua", comm_backend="auto",
           comm_path="auto", chunks=1, tiers=1, telemetry=False):
    topo = ring(n_workers)
    spec = QuantSpec(bits=bits, stochastic=bits > 1)
    return AlgoHyper(topo=topo, codec=MoniquaCodec(spec), theta=2.0,
                     wire=wire, backend=comm_backend, path=comm_path,
                     chunks=chunks, tiers=tiers, telemetry=telemetry)


def _sim_predict(scenario_name: str, model, hp, n_workers: int, roof):
    """Price one gossip round of this config on a named sim scenario.

    Compute time per round = the roofline bound of the compiled step (the
    best the chips can do); network time = the engine's wire bytes under
    the scenario's link model.  The ratio says how much the scenario's
    network inflates the step beyond the hardware bound.
    """
    from repro.sim import events as SE
    from repro.sim import scenarios as SC

    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    X_ab = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((n_workers,) + a.shape, a.dtype),
        params)
    eng = hp.engine()
    bytes_round = eng.bytes_per_round(X_ab)
    compute_s = max(roof.bound_s, 1e-9)
    sc = SC.get_scenario(scenario_name, n=n_workers, compute_s=compute_s)
    trace = SE.simulate_sync_rounds(sc, eng.payload_bytes_per_broadcast(X_ab),
                                    num_rounds=25)
    return {
        "scenario": sc.name,
        "bytes_per_round": bytes_round,
        "predicted_round_s": trace.mean_round_seconds,
        "roofline_bound_s": roof.bound_s,
        "network_overhead_x": trace.mean_round_seconds / compute_s,
    }


def _lower_train(model, shape, mesh, ms, rules, n_workers, algo_name, bits,
                 wire="moniqua", comm_backend="auto", comm_path="auto",
                 chunks=1, tiers=1, telemetry=False):
    algo = get_algorithm(algo_name)
    hp = _hyper(model.cfg, n_workers, algo_name, bits, wire, comm_backend,
                comm_path, chunks, tiers, telemetry)
    tcfg = TS.TrainStepConfig(algo=algo_name, sgd=SGDConfig(), lr=0.1,
                              theta=ThetaSchedule(mode="constant", value=2.0))
    step = TS.make_train_step(model, hp, tcfg)
    state_ab = TS.abstract_state(model, algo, hp, n_workers)
    batch_ab = input_specs(model, shape, n_workers, stacked=True)
    state_sh = _named(mesh, TS.state_pspecs(model, algo, hp, rules, ms,
                                            n_workers))
    batch_sh = _named(mesh, TS.batch_pspecs(batch_ab, rules, ms, stacked=True))
    jf = jax.jit(step, in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, None), donate_argnums=(0,))
    return jf.lower(state_ab, batch_ab)


def _lower_prefill(model, shape, mesh, ms, rules):
    pstep = SS.make_prefill_step(model)
    params_ab = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch_ab = input_specs(model, shape, 1, stacked=False)
    params_sh = _named(mesh, TS.params_pspecs(model, rules, ms,
                                              stacked=False))
    batch_sh = _named(mesh, TS.batch_pspecs(batch_ab, rules, ms,
                                            stacked=False))
    jf = jax.jit(pstep, in_shardings=(params_sh, batch_sh))
    return jf.lower(params_ab, batch_ab)


def _lower_decode(model, shape, mesh, ms, rules):
    sstep = SS.make_serve_step(model)
    params_ab = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_ab = SS.abstract_cache(model, shape)
    tok_ab = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    params_sh = _named(mesh, TS.params_pspecs(model, rules, ms,
                                              stacked=False))
    cache_sh = _named(mesh, SS.cache_pspecs(model, shape, rules, ms))
    from repro.models.sharding import safe_pspec
    tok_sh = NamedSharding(mesh, safe_pspec(tok_ab.shape,
                                            rules.pspec("global_batch", None),
                                            ms))
    jf = jax.jit(sstep, in_shardings=(params_sh, cache_sh, tok_sh),
                 out_shardings=(None, cache_sh), donate_argnums=(1,))
    return jf.lower(params_ab, cache_ab, tok_ab)


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--algo", default="moniqua")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--wire", default="moniqua",
                    choices=["moniqua", "qsgd", "full"],
                    help="CommEngine wire codec for quantized gossip")
    ap.add_argument("--comm-backend", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="CommEngine backend")
    ap.add_argument("--comm-path", default="auto",
                    choices=["bucketed", "per_leaf", "auto"],
                    help="CommEngine gossip path: bucketed flat buffer, "
                         "per-leaf mixing, or the memoized auto crossover")
    ap.add_argument("--chunks", type=int, default=1,
                    help="staged-round chunk count for the pipelined "
                         "gossip round (1 = barrier round)")
    ap.add_argument("--tiers", type=int, default=1,
                    help="two-tier hierarchical gossip: workers per node "
                         "(1 = flat single-tier; k>1 puts the named "
                         "topology across n/k nodes with a full-precision "
                         "reduce inside each)")
    ap.add_argument("--scenario", default=None,
                    help="repro.sim scenario name (incl. contended fabrics "
                         "like oversubscribed-tor / shared-uplink-ring and "
                         "calibrated-from-bench): price one gossip round "
                         "of each train config on this simulated network "
                         "(see repro/sim/scenarios.py)")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--telemetry", action="store_true",
                    help="thread AlgoHyper.telemetry into the lowered train "
                         "step (obs_* round-health metrics compile in)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of the lower/compile "
                         "phase spans (open in Perfetto)")
    ap.add_argument("--log-jsonl", default=None, metavar="PATH",
                    help="write a repro.obs.runlog JSONL: one event per "
                         "combination + phase spans + final result")
    ap.add_argument("--host-mesh", default=None, metavar="DxM[:pod=P]",
                    help="use a small host mesh 'DATAxMODEL' (optionally "
                         "'PODxDATAxMODEL') instead of the 256-chip "
                         "production mesh; pair with REPRO_DRYRUN_DEVICES "
                         "so enough forced host devices exist (CI smoke)")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink every arch to a tiny layer stack before "
                         "lowering (CI-scale smoke; same mesh/sharding "
                         "logic, minutes instead of hours)")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else assigned_archs()
    shapes = [args.shape] if args.shape else list(
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    override = None
    if args.reduced:
        override = dict(num_layers=2, d_model=256, num_heads=4,
                        num_kv_heads=2, head_dim=64, d_ff=512,
                        vocab_size=512, remat=False)

    rec = writer = None
    if args.trace or args.log_jsonl:
        from repro.obs.trace import SpanRecorder
        rec = SpanRecorder()
    if args.log_jsonl:
        from repro.obs.runlog import RunLogWriter
        writer = RunLogWriter(args.log_jsonl, run=vars(args), tool="dryrun")

    failures = 0
    try:
        for mp in meshes:
            if args.host_mesh:
                dims = [int(x) for x in args.host_mesh.lower().split("x")]
                if len(dims) == 3:
                    mesh = make_host_mesh(data=dims[1], model=dims[2],
                                          pod=dims[0])
                    mp = True
                else:
                    mesh = make_host_mesh(data=dims[0], model=dims[1])
                    mp = False
            else:
                mesh = make_production_mesh(multi_pod=mp)
            for arch in archs:
                for shape in shapes:
                    res = dryrun_one(arch, shape, multi_pod=mp, mesh=mesh,
                                     algo=args.algo, bits=args.bits,
                                     wire=args.wire,
                                     comm_backend=args.comm_backend,
                                     comm_path=args.comm_path,
                                     chunks=args.chunks, tiers=args.tiers,
                                     telemetry=args.telemetry,
                                     scenario=args.scenario,
                                     override=override, rec=rec)
                    if res.status == "error":
                        failures += 1
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(res.row()) + "\n")
                    if writer is not None:
                        writer.event("dryrun", {
                            "arch": res.arch, "shape": res.shape,
                            "mesh": res.mesh, "status": res.status,
                            "seconds": res.seconds,
                            "peak_estimate_gb":
                                res.memory.get("peak_estimate_gb")})
        if writer is not None:
            writer.spans_from(rec)
            writer.result(failures=failures,
                          combinations=len(meshes) * len(archs) * len(shapes))
        if rec is not None and args.trace:
            rec.save(args.trace, process_name="dryrun")
    finally:
        if writer is not None:
            writer.close()
    print(f"dry-run complete; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
