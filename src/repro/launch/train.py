"""Training launcher.

CPU-scale (default): reduced config, workers as an array axis —
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --algo moniqua --workers 8 --bits 8 --steps 50

Production mesh (requires a real fleet or forced host devices) —
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-20b \
        --mesh production --shape train_4k --full-size
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--algo", default="moniqua",
                    help="allreduce|dpsgd|naive|moniqua|choco|deepsqueeze|"
                         "dcd|ecd|d2|moniqua_d2")
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--theta", type=float, default=2.0)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--mesh", choices=["cpu", "production"], default="cpu")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--shape", default=None,
                    help="assigned input shape name (production mesh)")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full published config (default: reduced)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.configs.base import InputShape, get_input_shape
    from repro.models.model_factory import build_model
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    model = build_model(cfg)

    mesh = rules = None
    if args.mesh == "production":
        from repro.launch.mesh import make_production_mesh
        from repro.models.sharding import ShardingRules
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = ShardingRules(cfg.dist_mode, multi_pod=args.multi_pod)
        shape = get_input_shape(args.shape or "train_4k")
    else:
        shape = InputShape("cli", args.seq, args.batch, "train")

    tc = TrainerConfig(algo=args.algo, topology=args.topology,
                       n_workers=args.workers, bits=args.bits,
                       theta=args.theta, gamma=args.gamma, lr=args.lr,
                       steps=args.steps, log_every=args.log_every,
                       seed=args.seed, checkpoint_path=args.checkpoint,
                       checkpoint_every=0 if not args.checkpoint else 50)
    trainer = Trainer(model, shape, tc, mesh=mesh, rules=rules)

    def log(k, m):
        print(f"step {k:5d}  loss {m['loss']:.4f}  alpha {m['alpha']:.4g}  "
              f"theta {m['theta']:.3g}  g_inf {m['g_inf']:.3g}")

    out = trainer.run(callback=log)
    print(f"bytes/step/worker = {out['bytes_per_step']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
