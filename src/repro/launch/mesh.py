"""Production mesh factories.

Functions, not module-level constants: importing this module never touches
jax device state (required for smoke tests that must see 1 device).

Version compatibility: ``AxisType`` / ``axis_types=`` and the ambient-mesh
setter ``jax.set_mesh`` only exist in newer jax releases.  ``_make_mesh`` and
``mesh_context`` paper over both so the same call sites run on the pinned
jax (0.4.x: ``Mesh`` is its own context manager, meshes are untyped) and on
current jax (explicit ``AxisType.Auto`` axes, ``jax.set_mesh``).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: typed mesh axes
    from jax.sharding import AxisType
except ImportError:  # pinned jax 0.4.x: untyped meshes only
    AxisType = None


def _make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions.

    Newer jax wants ``jax.set_mesh(mesh)``; on 0.4.x the ``Mesh`` object is
    itself a context manager with the same scoping semantics.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Target fleet: TPU v5e, 16x16 = 256 chips/pod; 2 pods multi-pod.

    Axes: ``data`` (decentralized workers / FSDP), ``model`` (tensor
    parallel), plus ``pod`` across pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_two_tier_mesh(inter: int = 8, intra: int = 4, model: int = 8):
    """Two-tier gossip fleet: the decentralized worker dimension split into
    a fast ``intra`` axis (ICI inside a node) and a slow ``inter`` axis
    (the oversubscribed cross-node fabric).  Worker ``w = g * intra + j``
    — the intra index varies fastest, matching ``HierarchicalTopology``'s
    flat worker ordering and the engine's ``reshape(n_inter, n_intra)``
    staging view, so the TieredPlan's intra reduce lowers to collectives
    on the ``intra`` axis and the shard gossip to collective-permutes on
    ``inter``.
    """
    return _make_mesh((inter, intra, model), ("inter", "intra", "model"))


def make_host_mesh(data: int = 4, model: int = 2, pod: int = 0):
    """Small mesh for subprocess tests (requires forced host devices)."""
    if pod:
        return _make_mesh((pod, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
