"""Production mesh factories.

Functions, not module-level constants: importing this module never touches
jax device state (required for smoke tests that must see 1 device).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Target fleet: TPU v5e, 16x16 = 256 chips/pod; 2 pods multi-pod.

    Axes: ``data`` (decentralized workers / FSDP), ``model`` (tensor
    parallel), plus ``pod`` across pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 4, model: int = 2, pod: int = 0):
    """Small mesh for subprocess tests (requires forced host devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
