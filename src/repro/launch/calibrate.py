import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Depth-probe roofline calibration (see DESIGN.md §Roofline-calibration).

XLA's ``cost_analysis`` counts a ``while`` body exactly ONCE, so any model
whose layer stack is a ``lax.scan`` (dense / moe / vlm / audio here) reports
flops / bytes / collective-bytes for a single layer.  This pass lowers two
UNROLLED shallow probes (1 and 2 layers, ``unroll_layers=True``) per
(arch x shape) on the single-pod mesh and extrapolates

    cost(L) = c1 + (L - 1) * (c2 - c1)

which is exact for homogeneous stacks (embedding/head live in the intercept).
ssm / hybrid stacks are Python loops (fully counted); their residual
undercount is the element-wise inter-chunk scan bodies only — documented,
not corrected.

Usage:
    PYTHONPATH=src python -m repro.launch.calibrate --out calibrated.jsonl
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro.analysis import roofline as RL
from repro.configs import assigned_archs, get_config
from repro.configs.base import get_input_shape
from repro.launch import dryrun as DR
from repro.launch.mesh import (make_production_mesh, mesh_context,
                               mesh_shape_dict)
from repro.models.model_factory import build_model
from repro.models.sharding import ShardingRules
from repro.train import train_step as TS

SCANNED_FAMILIES = ("dense", "moe", "vlm", "audio")


def _probe(arch: str, shape, mesh, ms, depth: int, algo: str, bits: int):
    cfg = get_config(arch)
    ov: Dict = dict(num_layers=depth, unroll_layers=True)
    if cfg.family == "audio":
        ov["encoder_layers"] = depth
    cfg = dataclasses.replace(cfg, **ov)
    rules = ShardingRules(cfg.dist_mode, multi_pod="pod" in ms)
    model = build_model(cfg)
    n_workers = TS.n_workers_for(cfg, rules, ms)
    from repro.models import sharding as SH
    with mesh_context(mesh), SH.constraint_context(rules, ms):
        if shape.kind == "train":
            lowered = DR._lower_train(model, shape, mesh, ms, rules,
                                      n_workers, algo, bits)
        elif shape.kind == "prefill":
            lowered = DR._lower_prefill(model, shape, mesh, ms, rules)
        else:
            lowered = DR._lower_decode(model, shape, mesh, ms, rules)
        compiled = lowered.compile()
    ca = RL.cost_analysis_dict(compiled)
    stats = RL.parse_collectives(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), stats)


def _extrapolate(c1: float, c2: float, L: int) -> float:
    return max(c1 + (L - 1) * (c2 - c1), 0.0)


def calibrate_one(arch: str, shape_name: str, mesh, ms, *,
                  algo: str = "moniqua", bits: int = 8) -> dict:
    cfg = get_config(arch)
    shape = get_input_shape(shape_name)
    if DR.skip_reason(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": "16x16",
                "status": "skipped"}
    if cfg.family not in SCANNED_FAMILIES:
        return {"arch": arch, "shape": shape_name, "mesh": "16x16",
                "status": "not-scanned"}
    t0 = time.time()
    try:
        f1, b1, s1 = _probe(arch, shape, mesh, ms, 1, algo, bits)
        f2, b2, s2 = _probe(arch, shape, mesh, ms, 2, algo, bits)
        L = cfg.num_layers
        flops = _extrapolate(f1, f2, L)
        nbytes = _extrapolate(b1, b2, L)
        coll_bytes: Dict[str, float] = {}
        coll_counts: Dict[str, float] = {}
        for op in set(s1.bytes_by_op) | set(s2.bytes_by_op):
            coll_bytes[op] = _extrapolate(s1.bytes_by_op.get(op, 0),
                                          s2.bytes_by_op.get(op, 0), L)
            coll_counts[op] = _extrapolate(s1.counts.get(op, 0),
                                           s2.counts.get(op, 0), L)
        total_coll = sum(coll_bytes.values())
        chips = 1
        for v in ms.values():
            chips *= v
        roof = RL.Roofline(
            flops=flops, bytes_accessed=nbytes, collective_bytes=total_coll,
            compute_s=flops / RL.HW["peak_flops"],
            memory_s=nbytes / RL.HW["hbm_bw"],
            collective_s=total_coll / RL.HW["ici_bw"],
            model_flops=RL.model_flops_for(cfg, shape), chips=chips)
        row = {
            "arch": arch, "shape": shape_name, "mesh": "16x16",
            "status": "ok", "seconds": time.time() - t0,
            "probe": {"L1": {"flops": f1, "bytes": b1},
                      "L2": {"flops": f2, "bytes": b2},
                      "num_layers": L},
            "roofline_calibrated": {
                "flops_per_chip": roof.flops,
                "bytes_per_chip": roof.bytes_accessed,
                "collective_bytes_per_chip": roof.collective_bytes,
                "compute_s": roof.compute_s,
                "memory_s": roof.memory_s,
                "collective_s": roof.collective_s,
                "dominant": roof.dominant,
                "bound_s": roof.bound_s,
                "model_flops": roof.model_flops,
                "useful_ratio": roof.useful_ratio,
                "mfu_upper_bound": roof.mfu_upper_bound,
            },
            "collectives_calibrated": {"counts": coll_counts,
                                       "bytes": coll_bytes},
        }
        r = row["roofline_calibrated"]
        print(f"[{arch} x {shape_name}] calibrated in {row['seconds']:.0f}s "
              f"dominant={r['dominant']} compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"useful={r['useful_ratio']:.3f} mfu<= {r['mfu_upper_bound']:.3f}")
        return row
    except Exception as e:  # noqa: BLE001
        print(f"[{arch} x {shape_name}] calibration FAIL: {e}")
        return {"arch": arch, "shape": shape_name, "mesh": "16x16",
                "status": "error",
                "error": f"{e}\n{traceback.format_exc(limit=10)}"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--algo", default="moniqua")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=False)
    ms = mesh_shape_dict(mesh)
    archs = [args.arch] if args.arch else assigned_archs()
    shapes = ([args.shape] if args.shape else
              ["train_4k", "prefill_32k", "decode_32k", "long_500k"])
    failures = 0
    for arch in archs:
        for shape in shapes:
            row = calibrate_one(arch, shape, mesh, ms, algo=args.algo,
                                bits=args.bits)
            if row["status"] == "error":
                failures += 1
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(row) + "\n")
    print(f"calibration complete; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
