"""Checkpointing: flat-path .npz of any pytree + metadata sidecar.

Arrays are gathered to host (fine at experiment scale; for the production
mesh a per-shard variant would write one file per addressable-device slice —
the path layout already encodes that extension point).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "|"


def _is_typed_key(leaf: Any) -> bool:
    dtype = getattr(leaf, "dtype", None)
    return dtype is not None and jax.dtypes.issubdtype(dtype,
                                                       jax.dtypes.prng_key)


def _flatten_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(f"#{p.idx}")
            else:
                parts.append(str(p))
        if _is_typed_key(leaf):
            # new-style typed PRNG keys carry an opaque extended dtype numpy
            # cannot hold; persist the raw uint32 key data (restore() wraps
            # it back).  Legacy uint32[2] keys pass through as plain arrays.
            leaf = jax.random.key_data(leaf)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # numpy cannot serialise ml_dtypes (bf16, fp8): upcast losslessly;
            # restore() casts back to the dtype of ``like``.
            arr = arr.astype(np.float32)
        out[_SEP.join(parts)] = arr
    return out


def save(path: str, tree: PyTree, meta: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_paths(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    with open(re.sub(r"\.npz$", "", path) + ".meta.json", "w") as f:
        json.dump(meta or {}, f, indent=2, default=str)


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure (and dtypes) of ``like``.

    Typed PRNG-key leaves in ``like`` are re-wrapped from their saved raw
    key data under the same PRNG impl, so full trainer states (which carry
    the step key) round-trip bit-identically alongside plain arrays."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat = _flatten_paths(jax.tree.map(lambda a: np.zeros((), np.int8), like))
    leaves, treedef = jax.tree.flatten(like)
    keys = list(flat.keys())
    assert len(keys) == len(leaves), (len(keys), len(leaves))

    def back(k: str, l: Any):
        if _is_typed_key(l):
            return jax.random.wrap_key_data(jnp.asarray(npz[k]),
                                            impl=jax.random.key_impl(l))
        return jnp.asarray(npz[k]).astype(l.dtype)

    restored = [back(k, l) for k, l in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, restored)


def load_meta(path: str) -> Dict:
    with open(re.sub(r"\.npz$", "", path) + ".meta.json") as f:
        return json.load(f)
