"""SGD with momentum + weight decay (the paper's optimizer) and LR schedules.

The decentralized algorithms (core/algorithms.py) consume a *direction* ``d``
and apply ``x <- gossip(x) - alpha d``; this module turns raw gradients into
that direction (heavy-ball momentum, decoupled weight decay) so the optimizer
is uniform across all update rules, and tracks the running ``||g||_inf`` used
by the theory-mode theta schedule (Theorem 2; "first method" of Sec. 6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    momentum: float = 0.9
    weight_decay: float = 5e-4           # paper Sec. 6 hyper-parameters
    nesterov: bool = False


def init_momentum(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def direction(cfg: SGDConfig, grads: PyTree, params: PyTree,
              mom: PyTree) -> Tuple[PyTree, PyTree, jax.Array]:
    """Returns (direction, new momentum, ||g||_inf over the whole tree)."""
    g_inf = jnp.zeros((), jnp.float32)
    for g in jax.tree.leaves(grads):
        g_inf = jnp.maximum(g_inf, jnp.max(jnp.abs(g.astype(jnp.float32))))

    def upd(g, p, m):
        gf = g.astype(jnp.float32) + cfg.weight_decay * p.astype(jnp.float32)
        mn = cfg.momentum * m + gf
        d = (gf + cfg.momentum * mn) if cfg.nesterov else mn
        return d, mn

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(mom)
    ds, ms = [], []
    for g, p, m in zip(flat_g, flat_p, flat_m):
        d, mn = upd(g, p, m)
        ds.append(d)
        ms.append(mn)
    return (jax.tree.unflatten(treedef, ds),
            jax.tree.unflatten(treedef, ms), g_inf)


# ---------------------------------------------------------------------------
# Step-size schedules.  All satisfy the paper's two-constant condition
# alpha_k / alpha_{k+t} <= C_alpha eta^t (Theorem 2).
# ---------------------------------------------------------------------------

def constant(lr: float) -> Callable[[int], float]:
    return lambda k: lr


def step_decay(lr: float, boundaries, factor=0.1) -> Callable[[int], float]:
    """Paper Sec. 6: decay by 0.1 at given steps (epochs 250/280 there)."""
    bs = tuple(boundaries)

    def f(k):
        mult = 1.0
        for b in bs:
            mult = jnp.where(k >= b, mult * factor, mult)
        return lr * mult
    return f


def cosine(lr: float, total_steps: int, floor: float = 0.0):
    def f(k):
        t = jnp.clip(k / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (lr - floor) * (1.0 + jnp.cos(jnp.pi * t))
    return f


def theorem_lr(K: int, n: int, sigma: float = 1.0, zeta: float = 1.0,
               L: float = 2.0) -> float:
    """Corollary 1: alpha = 1 / (zeta^(2/3) K^(1/3) + sigma sqrt(K/n) + 2L)."""
    import math
    return 1.0 / (zeta ** (2 / 3) * K ** (1 / 3)
                  + sigma * math.sqrt(K / n) + 2 * L)
