"""Sharded input pipeline.

Produces *global* batches laid out for the trainer: decentralized training
wants ``[n_workers, local_batch, ...]`` with the worker dim sharded over the
worker mesh axes.  Generation is deterministic in (seed, step) so every host
of a multi-pod job computes the same logical batch and ``jax.device_put`` with
a NamedSharding slices out only the rows its addressable devices own.

For the assigned-architecture workloads batches are synthetic token/embedding
tensors matching ``Model.batch_spec`` (DESIGN §2: no datasets offline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models.model_factory import Model


@dataclasses.dataclass
class SyntheticLMPipeline:
    """Batch factory for one (model, shape, n_workers) combination."""
    model: Model
    shape: InputShape
    n_workers: int
    seed: int = 0

    def global_batch(self, step: int) -> Dict[str, jax.Array]:
        """Unstacked [GB, ...] batch; cheap uniform tokens + gaussian embeds."""
        spec = self.model.batch_spec(self.shape)
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        out = {}
        for name, (shp, dt) in spec.items():
            key, k = jax.random.split(key)
            if jnp.issubdtype(dt, jnp.integer):
                hi = self.model.cfg.vocab_size
                arr = jax.random.randint(k, shp, 0, hi, dtype=jnp.int32)
            else:
                arr = jax.random.normal(k, shp, dtype=jnp.float32).astype(dt)
            out[name] = arr
        return out

    def worker_batch(self, step: int) -> Dict[str, jax.Array]:
        """Stacked [n, GB/n, ...] layout for the decentralized trainer."""
        gb = self.global_batch(step)
        n = self.n_workers
        def stack(a):
            assert a.shape[0] % n == 0, (a.shape, n)
            return a.reshape(n, a.shape[0] // n, *a.shape[1:])
        return {k: stack(v) for k, v in gb.items()}
