"""Synthetic data generators (offline container: no datasets ship; DESIGN §2).

Three generators:

* ``TokenTask`` — learnable LM data: a fixed random bigram teacher produces
  token streams; the model can drive loss well below the uniform entropy, so
  convergence comparisons between algorithms are meaningful.
* ``cifar_like`` — class-conditional Gaussian images (32x32x3, 10 classes),
  the stand-in for CIFAR10 in the paper-faithful ResNet experiments.
  ``heterogeneous=True`` reproduces the D² setting: worker i draws ONLY class
  i (mod 10) — maximal outer variance (paper Fig. 2a).
* ``quadratic`` — the Theorem 1 objective ``f(x) = ||x - delta 1/2||^2 / 2``
  with additive gradient noise.

All generators are pure functions of (seed, step) — deterministic, resumable,
and identical across hosts, which is what a sharded multi-pod input pipeline
needs (each host slices its worker rows from the same logical batch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenTask:
    vocab_size: int
    seed: int = 0

    def _teacher(self) -> jax.Array:
        """Row-stochastic bigram transition logits (fixed by seed)."""
        key = jax.random.PRNGKey(self.seed)
        return jax.random.normal(key, (self.vocab_size, self.vocab_size)) * 2.0

    def batch(self, step: int, batch: int, seq: int) -> Dict[str, jax.Array]:
        logits = self._teacher()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)

        def one_seq(k):
            k0, ks = jax.random.split(k)
            t0 = jax.random.randint(k0, (), 0, self.vocab_size)

            def body(tok, kk):
                nxt = jax.random.categorical(kk, logits[tok])
                return nxt, nxt
            _, toks = jax.lax.scan(body, t0, jax.random.split(ks, seq))
            return jnp.concatenate([t0[None], toks[:-1]]), toks

        keys = jax.random.split(key, batch)
        tokens, labels = jax.vmap(one_seq)(keys)
        return {"tokens": tokens.astype(jnp.int32),
                "labels": labels.astype(jnp.int32)}


def cifar_like(step: int, batch: int, *, num_classes: int = 10, seed: int = 0,
               worker: int | None = None, heterogeneous: bool = False
               ) -> Dict[str, jax.Array]:
    """Class-conditional Gaussian 'images'.  Deterministic in (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    if worker is not None:
        key = jax.random.fold_in(key, worker)
    k_lbl, k_img, k_mu = jax.random.split(key, 3)
    # fixed class means (seed only — same teacher everywhere)
    mus = jax.random.normal(jax.random.PRNGKey(seed + 777),
                            (num_classes, 8)) * 2.0
    if heterogeneous and worker is not None:
        labels = jnp.full((batch,), worker % num_classes, jnp.int32)
    else:
        labels = jax.random.randint(k_lbl, (batch,), 0, num_classes)
    # low-rank class signal embedded in noise
    basis = jax.random.normal(jax.random.PRNGKey(seed + 778),
                              (8, 32 * 32 * 3)) / 8.0
    signal = (mus[labels] @ basis).reshape(batch, 32, 32, 3)
    noise = jax.random.normal(k_img, (batch, 32, 32, 3)) * 0.5
    return {"images": signal + noise, "labels": labels}


def quadratic_grad(x: jax.Array, delta: float, key, sigma: float = 0.1
                   ) -> jax.Array:
    """Stochastic gradient of the Theorem-1 quadratic at x."""
    opt = delta / 2.0
    return x - opt + sigma * jax.random.normal(key, x.shape)
