"""Phase tracing: host-side spans + Chrome-trace JSON export.

Two timing domains, one file format:

* **Inside jit** — the engine wraps encode / permute / decode-reduce in
  ``jax.named_scope("comm.encode" | "comm.permute" | "comm.decode_reduce"
  | "comm.telemetry")`` so the phases are attributed in XLA/profiler
  output; :func:`trace_annotation` adds a ``jax.profiler``
  TraceAnnotation when the host-side profiler is active.
* **On the host** — :class:`SpanRecorder` is a zero-dependency span
  recorder (``with rec.span("step", tid="train"): ...``) whose events
  export to the Chrome trace event format (``ph: "X"`` complete events,
  microsecond timestamps) that Perfetto / ``chrome://tracing`` open
  directly.

:func:`sim_trace_to_chrome` renders a ``repro.sim`` event timeline
(:class:`~repro.sim.events.SimTrace`) in the same format: one track per
worker (plus a barrier track for sync rounds), each event drawn as a span
from the worker's previous event to its timestamp.  Because measured runs
and sim predictions use distinct ``pid``s, :func:`merge_chrome_traces`
puts them side by side in one Perfetto view — the comparison the ROADMAP's
overlap work needs.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, Iterable, List, Optional

TRACE_SCHEMA = "repro.obs.trace/v1"

# the named_scope labels CommEngine.mix uses for the phases of one round
COMM_PHASES = ("comm.encode", "comm.permute", "comm.decode_reduce",
               "comm.telemetry")


def named_phase(name: str):
    """``jax.named_scope`` for a gossip phase (compile-time metadata only —
    zero runtime cost, and no effect on the lowered math)."""
    import jax
    return jax.named_scope(name)


def chunk_phase(phase: str, chunk: Optional[int] = None,
                total: Optional[int] = None):
    """Named scope for one phase of one *chunk* of a staged gossip round.

    A chunk-pipelined round (``CommEngine.round_plan``) runs each phase K
    times; labelling the scopes ``comm.encode/chunk03of08`` keeps the base
    ``COMM_PHASES`` name as a prefix (existing phase-based tooling still
    aggregates by prefix) while the profiler timeline shows the skewed
    encode(i+1)/permute(i)/decode(i-1) ladder span by span.  A barrier
    round (``chunk=None`` or a single chunk) keeps the plain phase label.
    """
    if chunk is None or (total or 0) <= 1:
        return named_phase(phase)
    suffix = (f"chunk{chunk:02d}of{total:02d}" if total is not None
              else f"chunk{chunk:02d}")
    return named_phase(f"{phase}/{suffix}")


def trace_annotation(name: str):
    """``jax.profiler.TraceAnnotation`` when available (host-side; shows up
    in profiler timelines), otherwise a no-op context."""
    try:
        import jax
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover - profiler not available
        return contextlib.nullcontext()


# ---------------------------------------------------------------------------
# Host-side span recorder.
# ---------------------------------------------------------------------------

class SpanRecorder:
    """Lightweight wall-clock span recorder (``time.perf_counter`` based).

    Spans are dicts ``{name, t0_s, dur_s, tid, args}`` with times relative
    to the recorder's creation; ``to_chrome`` / ``save`` export them as a
    Chrome trace, and ``repro.obs.runlog.RunLogWriter.spans_from`` copies
    them into a JSONL run log for ``tools/obs_report.py``'s phase
    breakdown.
    """

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    @contextlib.contextmanager
    def span(self, name: str, tid: str = "host", **args):
        t0 = self.now()
        try:
            yield
        finally:
            self.events.append({"name": name, "t0_s": t0,
                                "dur_s": self.now() - t0, "tid": tid,
                                "args": dict(args)})

    def instant(self, name: str, tid: str = "host", **args) -> None:
        self.events.append({"name": name, "t0_s": self.now(), "dur_s": 0.0,
                            "tid": tid, "args": dict(args),
                            "instant": True})

    def to_chrome(self, pid: int = 0, process_name: str = "measured"
                  ) -> Dict[str, Any]:
        return chrome_trace(self.events, pid=pid, process_name=process_name)

    def save(self, path: str, pid: int = 0,
             process_name: str = "measured") -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(pid, process_name), f)
        return path


# ---------------------------------------------------------------------------
# Chrome trace event format.
# ---------------------------------------------------------------------------

def chrome_trace(spans: Iterable[Dict[str, Any]], pid: int = 0,
                 process_name: str = "measured") -> Dict[str, Any]:
    """Span dicts -> Chrome trace JSON (object form, ``traceEvents`` list).

    Times are seconds in; the Chrome format wants microseconds.  Spans with
    ``instant: True`` (or zero duration) become ``ph: "i"`` instant
    events; everything else is a ``ph: "X"`` complete event.
    """
    events: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": process_name}}]
    tids: Dict[str, int] = {}
    for s in spans:
        tid = tids.setdefault(str(s.get("tid", "host")), len(tids))
        ev: Dict[str, Any] = {"name": str(s["name"]), "pid": pid, "tid": tid,
                              "ts": float(s["t0_s"]) * 1e6,
                              "args": dict(s.get("args") or {})}
        if s.get("instant") or float(s.get("dur_s") or 0.0) <= 0.0:
            ev.update(ph="i", s="t")
        else:
            ev.update(ph="X", dur=float(s["dur_s"]) * 1e6)
        events.append(ev)
    for name, tid in tids.items():
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA}}


def merge_chrome_traces(traces: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Concatenate trace objects (keep their distinct pids) into one view."""
    events: List[Dict[str, Any]] = []
    for t in traces:
        events.extend(t.get("traceEvents", []))
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA}}


def validate_chrome(obj: Any) -> List[str]:
    """Structural check of a Chrome trace object; returns error strings."""
    errors: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["not a Chrome trace object (missing traceEvents)"]
    for i, ev in enumerate(obj["traceEvents"]):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            errors.append(f"event {i}: missing ph/name")
            continue
        if ev["ph"] in ("X", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                errors.append(f"event {i} ({ev['name']}): bad ts {ts!r}")
            if ev["ph"] == "X":
                dur = ev.get("dur")
                if not isinstance(dur, (int, float)) or dur < 0:
                    errors.append(
                        f"event {i} ({ev['name']}): bad dur {dur!r}")
    return errors


def save_chrome_trace(obj: Dict[str, Any], path: str) -> str:
    with open(path, "w") as f:
        json.dump(obj, f)
    return path


# ---------------------------------------------------------------------------
# Simulator timelines in the same format.
# ---------------------------------------------------------------------------

def sim_trace_to_chrome(trace, pid: int = 1, process_name: str = "sim"
                        ) -> Dict[str, Any]:
    """Render a :class:`~repro.sim.events.SimTrace` as a Chrome trace.

    Track layout: one tid per worker, plus a ``barrier`` track for the
    sync-round events (``worker == -1``).  Each event becomes a span from
    the track's previous event time to the event's timestamp — compute
    spans start at the worker's last round/update, transfer spans show the
    sender's NIC serialization, round spans the barrier wait.  Zero-length
    events render as instants.  ``args`` carry peer/step/nbytes so
    Perfetto's selection panel shows the payload.
    """
    spans: List[Dict[str, Any]] = []
    cursor: Dict[str, float] = {}
    for e in sorted(trace.events, key=lambda e: (e.t, e.kind, e.worker)):
        tid = "barrier" if e.worker < 0 else f"worker {e.worker}"
        t0 = cursor.get(tid, 0.0)
        dur = max(e.t - t0, 0.0)
        args: Dict[str, Any] = {"step": e.step}
        if e.peer >= 0:
            args["peer"] = e.peer
        if e.nbytes:
            args["nbytes"] = e.nbytes
        spans.append({"name": e.kind, "t0_s": min(t0, e.t), "dur_s": dur,
                      "tid": tid, "args": args, "instant": dur <= 0.0})
        cursor[tid] = e.t
    return chrome_trace(spans, pid=pid, process_name=process_name)
