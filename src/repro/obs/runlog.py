"""Schema-versioned JSONL run logs (``repro.obs.runlog/v1``).

One JSON object per line.  The first record is always a ``header`` naming
the schema and the run configuration (including the theta policy, so
``tools/obs_report.py`` can plot the recorded per-round ``theta`` against
it); subsequent records are:

``step``    drained training metrics: ``{"kind": "step", "step": k,
            "wall_s": ..., "metrics": {"loss": ..., "theta": ...,
            "obs_headroom": ..., "obs_alias_count": ..., ...}}``
``span``    a host-side phase timing copied from a
            :class:`~repro.obs.trace.SpanRecorder` (name/t0_s/dur_s/tid)
``event``   a one-off structured payload (e.g. one dryrun combination's
            result row, one benchmark table row)
``result``  final summary fields (bytes_per_step, failures, ...)

Writers: ``train/trainer.py`` (replacing its ad-hoc per-step float()
drain), ``launch/dryrun.py`` (``--log-jsonl``), ``benchmarks/common.py``
(every saved benchmark result).  Readers: ``tools/obs_report.py``
(summaries), ``tools/check_obs.py`` (schema validation + the CI alias
gate), ``tests/test_obs.py``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

SCHEMA = "repro.obs.runlog/v1"
KINDS = ("header", "step", "span", "event", "result")


def _jsonable(v: Any) -> Any:
    """Coerce scalars (incl. numpy/jax 0-d arrays) to plain JSON types."""
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, int):
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class RunLogWriter:
    """Append-only JSONL writer; emits the schema header on open."""

    def __init__(self, path: str, run: Optional[Dict[str, Any]] = None,
                 tool: str = "trainer"):
        self.path = path
        self._f = open(path, "w")
        self._write({"kind": "header", "schema": SCHEMA, "tool": tool,
                     "run": {k: _jsonable(v)
                             for k, v in (run or {}).items()}})

    def _write(self, rec: Dict[str, Any]) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()

    def step(self, step: int, metrics: Dict[str, Any],
             wall_s: Optional[float] = None) -> None:
        rec: Dict[str, Any] = {"kind": "step", "step": int(step),
                               "metrics": {k: _jsonable(v)
                                           for k, v in metrics.items()}}
        if wall_s is not None:
            rec["wall_s"] = float(wall_s)
        self._write(rec)

    def span(self, name: str, t0_s: float, dur_s: float, tid: str = "host",
             args: Optional[Dict[str, Any]] = None) -> None:
        self._write({"kind": "span", "name": str(name),
                     "t0_s": float(t0_s), "dur_s": float(dur_s),
                     "tid": str(tid),
                     "args": {k: _jsonable(v)
                              for k, v in (args or {}).items()}})

    def spans_from(self, recorder) -> None:
        """Copy every span of a :class:`~repro.obs.trace.SpanRecorder`."""
        for s in recorder.events:
            self.span(s["name"], s["t0_s"], s["dur_s"], s.get("tid", "host"),
                      s.get("args"))

    def event(self, name: str, args: Optional[Dict[str, Any]] = None) -> None:
        self._write({"kind": "event", "name": str(name),
                     "args": {k: _jsonable(v)
                              for k, v in (args or {}).items()}})

    def result(self, **fields: Any) -> None:
        self._write({"kind": "result",
                     **{k: _jsonable(v) for k, v in fields.items()}})

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "RunLogWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Reading + validation.
# ---------------------------------------------------------------------------

def read_runlog(path: str) -> List[Dict[str, Any]]:
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def validate_records(records: List[Dict[str, Any]]) -> List[str]:
    """Schema check; returns human-readable error strings (empty = valid)."""
    errors: List[str] = []
    if not records:
        return ["empty run log"]
    head = records[0]
    if not isinstance(head, dict) or head.get("kind") != "header":
        errors.append("first record is not a header")
    elif head.get("schema") != SCHEMA:
        errors.append(f"unknown schema {head.get('schema')!r} "
                      f"(expected {SCHEMA})")
    for i, rec in enumerate(records):
        if not isinstance(rec, dict):
            errors.append(f"record {i}: not an object")
            continue
        kind = rec.get("kind")
        if kind not in KINDS:
            errors.append(f"record {i}: unknown kind {kind!r}")
            continue
        if kind == "header" and i != 0:
            errors.append(f"record {i}: duplicate header")
        if kind == "step":
            if not isinstance(rec.get("step"), int):
                errors.append(f"record {i}: step missing integer 'step'")
            m = rec.get("metrics")
            if not isinstance(m, dict):
                errors.append(f"record {i}: step missing 'metrics' object")
            else:
                for k, v in m.items():
                    if not isinstance(v, (int, float, str, bool,
                                          type(None))):
                        errors.append(
                            f"record {i}: metric {k!r} not JSON-scalar")
        if kind == "span":
            for fld in ("t0_s", "dur_s"):
                v = rec.get(fld)
                if not isinstance(v, (int, float)) or v < 0:
                    errors.append(f"record {i}: span {fld} invalid: {v!r}")
            if not isinstance(rec.get("name"), str):
                errors.append(f"record {i}: span missing 'name'")
        if kind == "event" and not isinstance(rec.get("name"), str):
            errors.append(f"record {i}: event missing 'name'")
    return errors


def validate_runlog(path: str) -> List[str]:
    try:
        records = read_runlog(path)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    return [f"{path}: {e}" for e in validate_records(records)]


def step_records(records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    return [r for r in records if r.get("kind") == "step"]


def alias_events(records: List[Dict[str, Any]]) -> int:
    """Total modulo-alias events recorded in a run log.

    Prefers the cumulative ``obs_alias_total`` counter (exact even when
    only every ``log_every``-th round is drained); falls back to summing
    the per-round ``obs_alias_count`` of the logged steps.
    """
    steps = step_records(records)
    totals = [r["metrics"].get("obs_alias_total") for r in steps
              if isinstance(r.get("metrics"), dict)
              and r["metrics"].get("obs_alias_total") is not None]
    if totals:
        return int(max(totals))
    return int(sum(r["metrics"].get("obs_alias_count", 0) or 0
                   for r in steps if isinstance(r.get("metrics"), dict)))
