"""repro.obs — observability for modulo-quantized decentralized SGD.

Three cooperating layers (see ``docs/observability.md``):

* :mod:`repro.obs.metrics` — jit-safe, on-device round-health counters
  (consensus inf-distance, theta headroom, the modulo **alias sentinel**,
  EF residual norms, payload bits/param).  Computed inside
  ``CommEngine.mix`` when the engine's static ``telemetry`` flag is set,
  carried in the step pytree under ``extra["health"]``, drained with the
  rest of the metrics at ``log_every``.  Purely observational: the mix
  output is bit-exact with telemetry on or off.
* :mod:`repro.obs.trace` — host-side span recorder + Chrome-trace JSON
  export (openable in Perfetto), plus the converter that renders a
  ``repro.sim`` event timeline in the same format so measured runs and
  simulator predictions line up side by side.
* :mod:`repro.obs.runlog` — schema-versioned JSONL run logs
  (``repro.obs.runlog/v1``) written by the trainer, the dryrun CLI, and
  the benchmarks; summarized by ``tools/obs_report.py`` and validated /
  CI-gated by ``tools/check_obs.py``.
"""
from repro.obs import metrics, runlog, trace  # noqa: F401
