"""Jit-safe round-health metrics for decentralized gossip.

Everything here is pure jnp on values the communication round already has
(the flat staging buffer, the packed payload, the EF residual), so the
telemetry is *observational*: it adds reductions next to the mix but never
feeds back into it — mix outputs are bit-exact with telemetry on or off,
and because the math is the same jnp graph regardless of the engine's
kernel backend or gossip path (bucketed / per-leaf), the telemetry values
themselves are backend- and path-invariant too.

The health dict (``round_health_zero`` fixes the pytree structure):

``consensus_inf``
    ``max_{o, elements} |x_i - x_{i+o}|_inf`` over the topology's neighbor
    offsets — the quantity Lemma 1's hypothesis bounds by ``theta``.
``headroom``
    ``consensus_inf / B`` with ``B = 2*theta/(1-2*delta)`` (Moniqua wire
    only; 0 otherwise).  Safe iff ``headroom < theta/B = (1-2*delta)/2``;
    ``tools/obs_report.py`` also reports ``consensus_inf / theta``, whose
    safe threshold is 1 for every wire.
``alias_count``
    the modulo **alias sentinel**: elements whose Lemma-1 recovered
    neighbor difference lands in the outer band ``|cmod(q*B - y, B)| >=
    theta`` (``kernels/moniqua_decode_reduce.py::alias_band_mask``).
    Under Lemma 1's hypothesis the recovered difference stays below
    ``theta + delta*B = B/2`` and only enters ``[theta, B/2)`` when the
    true distance is within ``delta*B`` of the bound — so a nonzero count
    means the theta budget is exhausted or already violated.  Safe runs
    are exactly zero while ``consensus_inf < theta - delta*B`` (the guard
    band — quantization alone moves the recovered difference by up to
    ``delta*B``); violations fire deterministically while crossing the
    bound and with per-element rate ``~2*delta`` per neighbor once
    grossly aliased (see ``alias_band_mask`` for the full semantics), so
    sustained violations yield large counts over a model's worth of
    elements.  Computed from the payload + local reference only, i.e.
    from exactly what a receiver has on real hardware.  Pinned to 0 for
    ``delta >= 1/4`` (1-bit nearest / 2-bit stochastic), where the guard
    band vanishes and a payload-only test carries no information.
``alias_total``
    cumulative ``alias_count`` across rounds (algorithm-level carry; see
    ``init_health`` / ``accumulate_health``).
``ef_residual_l2``
    ``||residual||_2`` of the post-round WireState (EF wires; 0 otherwise)
    — the divergence signal PR 6 could only get by hand-plotting.
``warm``
    1.0 while the onebit wire is inside its fp32 warmup phase.
``bits_per_param``
    payload bits per model parameter actually shipped per neighbor
    (trace-time constant from the engine's bytes accounting).  For tiered
    engines this is the *slow-axis* (gossip-link) number — the quantity
    quantization targets.
``bytes_fast`` / ``bytes_slow``
    per-tier bytes one worker sends per round (trace-time constants):
    ``bytes_slow`` the gossip-link payloads (all a single-tier round
    has), ``bytes_fast`` the intra-node reduce-scatter/all-gather of
    tiered rounds (0 single-tier).  Mirrors ``BytesLedger``'s split.
``participation``
    fraction of gossip-tier workers present in the round (elastic
    rounds; see ``docs/elasticity.md``).  Neutral value is **1.0** — the
    one deliberate exception to "everything at zero" in
    ``round_health_zero``: a round with no presence mask had full
    participation, and a gate like ``check_obs --min-participation``
    must not read an all-present run as a total outage.
``dropped_neighbors``
    count of directed gossip edges the round's presence mask killed
    (``sum over offsets o != 0, workers i`` of edges where ``i`` or
    ``i+o`` was absent); 0 for full presence.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.comm import gossip
from repro.core import modulo
from repro.core.quantizers import QuantSpec

HEALTH_ROUND_KEYS = ("consensus_inf", "headroom", "alias_count",
                     "ef_residual_l2", "warm", "bits_per_param",
                     "bytes_fast", "bytes_slow", "participation",
                     "dropped_neighbors")
HEALTH_KEYS = HEALTH_ROUND_KEYS + ("alias_total",)


def round_health_zero() -> Dict[str, jax.Array]:
    """Engine-level health dict with every counter at zero.

    Fixes the pytree structure so the ``extra["health"]`` carry is stable
    across jitted steps (counts are int32, everything else f32).
    ``participation`` alone starts at 1.0 — its neutral value (module
    docstring): no presence mask means everyone showed up.
    """
    z = jnp.zeros((), jnp.float32)
    return {"consensus_inf": z, "headroom": z,
            "alias_count": jnp.zeros((), jnp.int32),
            "ef_residual_l2": z, "warm": z, "bits_per_param": z,
            "bytes_fast": z, "bytes_slow": z,
            "participation": jnp.ones((), jnp.float32),
            "dropped_neighbors": jnp.zeros((), jnp.int32)}


def init_health() -> Dict[str, jax.Array]:
    """Algorithm-level carry: the round dict plus the cumulative alias
    counter (``accumulate_health`` folds each round into it)."""
    h = round_health_zero()
    h["alias_total"] = jnp.zeros((), jnp.int32)
    return h


def accumulate_health(prev: Dict[str, jax.Array],
                      round_h: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """New carry: this round's values, cumulative alias count threaded."""
    out = dict(round_h)
    out["alias_total"] = prev["alias_total"] + round_h["alias_count"]
    return out


# ---------------------------------------------------------------------------
# Consensus distance.
# ---------------------------------------------------------------------------

def consensus_inf(flat: jax.Array, offsets: Sequence[int]) -> jax.Array:
    """``max_o max_elements |x_i - x_{i+o}|`` on the stacked flat buffer."""
    x = flat.astype(jnp.float32)
    m = jnp.zeros((), jnp.float32)
    for o in offsets:
        m = jnp.maximum(m, jnp.max(jnp.abs(x - gossip._roll(x, o))))
    return m


def consensus_inf_segments(flat: jax.Array, offsets: Sequence[int],
                           segments: Sequence[int]) -> jax.Array:
    """Per-segment ``|x_i - x_j|_inf`` maxima, shape ``[num_segments]``.

    The health scalar is the max of these (per-segment maxima max out to
    the global max); the per-segment view localizes which tensor is
    eating the theta budget.
    """
    x = flat.astype(jnp.float32)
    d = jnp.zeros_like(x)
    for o in offsets:
        d = jnp.maximum(d, jnp.abs(x - gossip._roll(x, o)))
    out, off = [], 0
    for s in segments:
        out.append(jnp.max(jax.lax.slice_in_dim(d, off, off + s, axis=1)))
        off += s
    return jnp.stack(out)


# ---------------------------------------------------------------------------
# The modulo alias sentinel.
# ---------------------------------------------------------------------------

def moniqua_alias_count(packed: jax.Array, flat: jax.Array, B, theta,
                        spec: QuantSpec, offsets: Sequence[int]
                        ) -> jax.Array:
    """Alias-band elements summed over every neighbor payload of the round.

    ``packed`` is the stacked wire payload (``[n, D/vpb]`` uint8, exactly
    what the round's encode produced), ``flat`` the local references the
    receivers decode against.  Each neighbor's payload is dequantized with
    the kernel's shared math and tested against the outer-band predicate —
    see ``kernels/moniqua_decode_reduce.py::alias_band_mask``.

    The zero-false-positive guarantee has a **guard band**: quantization
    alone moves the recovered difference by up to ``delta*B``, so safe
    runs are certain to count zero only while ``consensus_inf < theta -
    delta*B``.  At ``delta = 1/4`` (1-bit nearest, 2-bit stochastic)
    ``delta*B == theta`` and the margin vanishes entirely — quantization
    error alone can land tiny distances on the band edge — so for
    ``spec.delta >= 1/4`` the sentinel is pinned to 0 (not meaningful
    from the payload alone; watch ``headroom`` instead, whose safe
    threshold ``(1-2*delta)/2`` already encodes the same budget).
    """
    from repro.kernels import moniqua_decode_reduce as _dr
    if spec.delta >= 0.25:          # no payload-only margin at this width
        return jnp.zeros((), jnp.int32)
    y = flat.astype(jnp.float32)
    count = jnp.zeros((), jnp.int32)
    for o in offsets:
        qb = _dr.unpack_values(gossip._roll(packed, o), spec.bits, B)
        mask = _dr.alias_band_mask(qb, y, B, theta)
        count = count + jnp.sum(mask, dtype=jnp.int32)
    return count


# ---------------------------------------------------------------------------
# AD-PSGD pair exchanges.
# ---------------------------------------------------------------------------

def pair_health(xi: jax.Array, xj: jax.Array, theta=None,
                spec: Optional[QuantSpec] = None,
                seed: Optional[jax.Array] = None
                ) -> Dict[str, jax.Array]:
    """Health of one edge exchange: pre-round models of the two endpoints.

    With a Moniqua ``spec`` the payloads are re-encoded under the exchange
    seed (bit-identical to what ``CommEngine.pair_average`` ships — same
    encode, same seed) and the alias band is tested in both decode
    directions; without one only the consensus distance is meaningful.
    Returns the same keys as ``round_health_zero``.
    """
    from repro.kernels import moniqua_decode_reduce as _dr
    from repro.kernels import ops as kops
    h = round_health_zero()
    fi = xi.astype(jnp.float32)
    fj = xj.astype(jnp.float32)
    h["consensus_inf"] = jnp.max(jnp.abs(fi - fj))
    if spec is None or theta is None:
        return h
    theta = jnp.asarray(theta, jnp.float32)
    B = modulo.b_theta(theta, spec.delta)
    h["headroom"] = h["consensus_inf"] / B
    if spec.delta < 0.25:   # guard band exists (see moniqua_alias_count)
        pi = kops.moniqua_encode_jnp(xi, B, spec, seed)
        pj = kops.moniqua_encode_jnp(xj, B, spec, seed)
        n_last = xi.shape[-1]
        qi = _dr.unpack_values(pi, spec.bits, B)[..., :n_last]
        qj = _dr.unpack_values(pj, spec.bits, B)[..., :n_last]
        h["alias_count"] = (
            jnp.sum(_dr.alias_band_mask(qj, fi, B, theta), dtype=jnp.int32)
            + jnp.sum(_dr.alias_band_mask(qi, fj, B, theta),
                      dtype=jnp.int32))
    h["bits_per_param"] = jnp.float32(float(spec.bits))
    return h
