"""xlstm-125m [ssm] — sLSTM + mLSTM blocks, d_ff=0 [arXiv:2405.04517]."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=192,
    ssm=SSMConfig(state_dim=64, chunk=128, slstm_every=4),  # sLSTM at 0,4,8
    gated_mlp=False, long_context_window=8192,
    dist_mode="decentralized",
    source="arXiv:2405.04517",
)
