"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242]; ssm_state=64."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, chunk=128),
    shared_attn_every=6,
    gated_mlp=True, long_context_window=8192,
    dist_mode="decentralized",
    source="arXiv:2411.15242",
)
