"""chatglm3-6b [dense] — RoPE 2d (half-dim rotary), GQA kv=2 [arXiv:2406.12793]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024, head_dim=128,
    rope_fraction=0.5,                 # chatglm applies RoPE to half the dims
    qkv_bias=True,                      # chatglm uses QKV bias
    gated_mlp=True, long_context_window=8192,
    dist_mode="decentralized",
    source="arXiv:2406.12793 (hf:THUDM/chatglm3-6b)",
)
