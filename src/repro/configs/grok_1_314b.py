"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128,
    moe=MoEConfig(num_experts=8, top_k=2),
    gated_mlp=True, long_context_window=8192,
    dist_mode="hierarchical",
    source="hf:xai-org/grok-1",
)
