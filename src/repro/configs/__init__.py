"""Assigned-architecture registry.  ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from repro.configs.base import (ArchConfig, InputShape, INPUT_SHAPES,
                                get_input_shape)


def _load(mod_name: str):
    import importlib
    return importlib.import_module(f"repro.configs.{mod_name}").CONFIG


ARCH_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "grok-1-314b": "grok_1_314b",
    "chatglm3-6b": "chatglm3_6b",
    "llama3.2-3b": "llama3_2_3b",
    "xlstm-125m": "xlstm_125m",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "internlm2-20b": "internlm2_20b",
    "qwen2-72b": "qwen2_72b",
    "whisper-base": "whisper_base",
    "zamba2-1.2b": "zamba2_1_2b",
    # the paper's own experimental model (Sec. 6, CIFAR10)
    "resnet20": "resnet20",
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCH_MODULES:
        raise ValueError(f"unknown arch {name!r}; available: "
                         f"{sorted(ARCH_MODULES)}")
    return _load(ARCH_MODULES[name])


def assigned_archs() -> list[str]:
    """The 10 assigned architectures (excludes the paper's CIFAR model)."""
    return [k for k in ARCH_MODULES if k != "resnet20"]
