"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP stub
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    vision_tokens=576, vision_embed_dim=1024,
    gated_mlp=True, long_context_window=8192,
    dist_mode="decentralized",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
