"""resnet20 — the paper's own CIFAR10 model (He et al. 2016), for the
paper-faithful decentralized-training experiments (Sec. 6).  Not part of the
assigned-architecture matrix."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="resnet20", family="dense",   # placeholder fields; built via models/resnet.py
    num_layers=20, d_model=64, num_heads=1, num_kv_heads=1,
    d_ff=64, vocab_size=10,
    dist_mode="decentralized", dtype="float32",
    source="He et al. 2016; paper Sec. 6",
)
