"""whisper-base [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

long_500k is SKIPPED for this arch: quadratic full-attention encoder-decoder
with no sub-quadratic variant (DESIGN.md §5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    rope_fraction=0.0,                  # learned/sinusoidal positions
    encoder_layers=6, encoder_downsample=2, decoder_len_cap=448,
    gated_mlp=False, tie_embeddings=True,
    dist_mode="decentralized",
    source="arXiv:2212.04356",
)
