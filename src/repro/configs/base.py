"""Architecture / run configuration schema.

``ArchConfig`` fully describes one of the assigned architectures; each
``src/repro/configs/<id>.py`` instantiates the exact published hyper-parameters
(sources cited in the file).  ``reduced()`` produces the CPU smoke-test variant
(<= 2 layers, d_model <= 512, <= 4 experts) of the same family.

``InputShape`` describes the four assigned workload shapes; ``input_specs``
in launch/dryrun.py turns (ArchConfig, InputShape) into ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 256          # GShard dispatch group length (tokens)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64            # mamba2 / xlstm recurrent state size
    conv_width: int = 4            # mamba2 local conv
    expand: int = 2                # mamba2 inner expansion
    chunk: int = 128               # chunked-scan length
    # xlstm: indices (mod pattern length) of sLSTM blocks; others mLSTM
    slstm_every: int = 0           # 0 = none (pure mLSTM); k>0 = every k-th


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # attention
    head_dim: Optional[int] = None           # default d_model // num_heads
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0               # chatglm "2d RoPE": 0.5
    qkv_bias: bool = False                   # qwen2
    sliding_window: int = 0                  # 0 = full attention (training)
    long_context_window: int = 8192          # window used for long_500k decode
    # MLP
    gated_mlp: bool = True                   # SwiGLU-style
    # subconfigs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba): shared attention block every k mamba layers
    shared_attn_every: int = 0
    # audio/vlm stubs
    encoder_layers: int = 0                  # whisper encoder depth
    encoder_downsample: int = 2              # conv frontend stub ratio
    decoder_len_cap: int = 448               # whisper decoder max positions
    vision_tokens: int = 576                 # vlm patch embeddings per image
    vision_embed_dim: int = 1024             # CLIP hidden size (stub output)
    # numerics / distribution
    dtype: str = "bfloat16"
    dist_mode: str = "decentralized"         # or "hierarchical" (see DESIGN §4)
    remat: bool = True
    # analysis-only: fully unroll the layer scan so XLA cost_analysis counts
    # every layer (it counts a while body exactly ONCE — the depth-probe
    # calibration in launch/dryrun.py lowers unrolled 1- and 2-layer probes
    # and extrapolates; see DESIGN.md §Roofline-calibration)
    unroll_layers: bool = False
    # TPU deployment: route self-attention through the Pallas flash kernel
    # (kernels/flash_attention.py). Default False: the CPU dry-run path
    # cannot SPMD-partition Pallas custom calls, so rooflines report the
    # jnp path; on real TPU the kernel removes the S^2 score bytes entirely
    # (see EXPERIMENTS.md §Perf).
    flash_attention: bool = False
    tie_embeddings: bool = False
    # citation
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, nh, nkv = self.hd, self.num_heads, self.num_kv_heads
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        if self.qkv_bias:
            attn += (nh + 2 * nkv) * hd
        if self.gated_mlp:
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn + mlp + 2 * d
        elif self.family == "moe":
            router = d * self.moe.num_experts
            per_layer = attn + self.moe.num_experts * mlp + router + 2 * d
        elif self.family == "ssm":
            # mLSTM block: up(2d) + q,k,v(d^2 each) + down  ~ 6 d^2
            per_layer = 6 * d * d + 2 * d
        elif self.family == "hybrid":
            di = self.ssm.expand * d
            ns = self.ssm.state_dim
            mamba = (d * (2 * di + 2 * ns + self.num_heads)
                     + self.ssm.conv_width * (di + 2 * ns) + di * d)
            per_layer = mamba + d
        layers = per_layer * self.num_layers
        if self.family == "hybrid" and self.shared_attn_every:
            layers += attn + mlp + 2 * d  # one shared attention block
        if self.family == "audio":
            layers += (attn + d * (nh * hd) + (nh * hd) * d + mlp + 3 * d) * self.encoder_layers
        emb = v * d + (0 if self.tie_embeddings else v * d)
        if self.family == "vlm":
            emb += self.vision_embed_dim * d  # projector
        return layers + emb + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = (3 if self.gated_mlp else 2) * d * f
        inactive = (self.moe.num_experts - self.moe.top_k) * mlp * self.num_layers
        return self.param_count() - inactive

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/wiring, tiny sizes."""
        moe = ssm = None
        if self.moe is not None:
            moe = MoEConfig(num_experts=min(self.moe.num_experts, 4),
                            top_k=min(self.moe.top_k, 2),
                            capacity_factor=self.moe.capacity_factor,
                            group_size=64)
        if self.ssm is not None:
            ssm = SSMConfig(state_dim=min(self.ssm.state_dim, 16),
                            conv_width=self.ssm.conv_width,
                            expand=self.ssm.expand, chunk=32,
                            slstm_every=self.ssm.slstm_every)
        return dataclasses.replace(
            self,
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 4),
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            encoder_layers=min(self.encoder_layers, 2),
            vision_tokens=min(self.vision_tokens, 16),
            vision_embed_dim=min(self.vision_embed_dim, 64),
            dtype="float32",
            remat=False,
            shared_attn_every=2 if self.shared_attn_every else 0,
            moe=moe,
            ssm=ssm,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def get_input_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
