"""Pallas TPU kernel: fused Moniqua decode-reduce (one gossip round's mixing).

Receiver side of Algorithm 1 lines 4-6, fused across *all* neighbors.  Given
the worker's own packed payload, the stack of its neighbors' packed payloads
(already circulated by the quantized collective-permute), and the local model
tile ``y``, produce in one VMEM pass

    out = y + sum_s  w_s * (x_hat_s - x_hat_self)

where (per element, all f32 in VREGs)

    q_s        = dequant(unpack(p_s)) * B
    x_hat_s    = (q_s - y) mod B + y          (line 5, Lemma 1 recovery)
    x_hat_self = q_self - (y mod B) + y       (line 4, bias cancellation)

HBM traffic per tile: ``(m+1) * bits/8`` bytes of packed payloads + one read
of ``y`` + one write of the mixed tile.  The unfused path (see
``comm/gossip.py::moniqua_gossip``) materialises a *full f32 model copy per
neighbor* — ``m`` extra HBM writes + reads of ``4`` bytes/elem each — so for
a ring (m=2) at 1 bit the fused kernel moves ~8/25 of the unfused bytes, and
the advantage grows with neighbor count (docs/kernels.md derives the model).

The neighbor weights are *compile-time constants* (they come from the static
``Topology``), so the reduction fully unrolls with no weight operand; only
``B`` (a function of the traced theta schedule) is a runtime scalar.

Bit-exactness contract: ``decode_reduce_values`` is the single source of the
per-element math for BOTH the kernel body and the pure-jnp backend
(``ops.moniqua_decode_reduce_jnp``).  Every *inexact* multiply is routed
through ``_shield`` — ``where(v == v, v, 0)``, a per-element NaN check no
optimizer can fold — because LLVM's FMA contraction otherwise fuses the
multiply with a downstream add/sub *through* HLO ``optimization_barrier``s
(barriers are dropped before codegen), and does so differently depending on
the surrounding fusion, leaving the two backends 1 ulp apart.  A select
between the mul and the add breaks the contractible adjacency at the
instruction level; a loop-invariant condition would be undone by loop
unswitching, hence the per-element form.  Exact multiplies (power-of-two
scalings) need no shield: contracting them is rounding-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_COLS = 1024


def _shield(v: jax.Array) -> jax.Array:
    """Identity for finite v that no compiler pass can see through (see above)."""
    return jnp.where(v == v, v, jnp.zeros_like(v))


def unpack_values(p: jax.Array, bits: int, B) -> jax.Array:
    """packed uint8 array -> dequantized f32 values scaled by B (q * B)."""
    levels = 2 ** bits
    vpb = 8 // bits
    p = p.astype(jnp.uint32)
    if vpb == 1:
        codes = p.astype(jnp.float32)
    else:
        mask = jnp.uint32(2 ** bits - 1)
        subs = [((p >> jnp.uint32(s * bits)) & mask) for s in range(vpb)]
        codes = jnp.stack(subs, axis=-1).reshape(*p.shape[:-1],
                                                 p.shape[-1] * vpb)
        codes = codes.astype(jnp.float32)
    # /levels is a power of two (exact); the *B product is not — shield it
    return _shield(((codes + 0.5) / levels - 0.5) * B)


def decode_reduce_values(qb_self: jax.Array, qb_nbrs, y: jax.Array, B,
                         weights) -> jax.Array:
    """Algorithm 1 lines 4-6 on dequantized payload values (shared math)."""
    y = y.astype(jnp.float32)
    ymod = y - _shield(B * jnp.floor(y / B + 0.5))      # cmod(y, B)
    xhat_self = qb_self - ymod + y                      # line 4
    acc = jnp.zeros_like(y)
    for qb, w in zip(qb_nbrs, weights):                 # static unroll over m
        d = qb - y
        xhat = (d - _shield(B * jnp.floor(d / B + 0.5))) + y    # line 5
        acc = acc + _shield(jnp.float32(w) * (xhat - xhat_self))
    return y + acc                                      # line 6


def alias_band_mask(qb: jax.Array, y: jax.Array, B, theta) -> jax.Array:
    """Modulo alias sentinel on one dequantized neighbor payload.

    The Lemma-1 recovered neighbor difference is ``dhat = cmod(qb - y, B)``
    (line 5 above, before adding ``y`` back).  Under the lemma's hypothesis
    ``|x_j - x_i| < theta`` the decode never wraps and
    ``|dhat| <= |x_j - x_i| + delta*B < theta + delta*B = B/2``, so the
    outer band ``|dhat| >= theta`` is unreachable except when the true
    distance is already within ``delta*B`` of the bound.  A nonzero count
    therefore means the theta budget is exhausted or violated.

    Detection semantics (aliasing is per-element undetectable from the
    payload alone — that is what aliasing *means* — so this is the
    strongest payload-only test): an element with true distance ``d``
    fires iff ``d mod B`` lands in the width-``2*delta*B`` window
    ``[theta, B - theta]`` straddling the wrap point ``B/2``.  Distances
    *crossing* the bound transit the window deterministically; a gross,
    already-wrapped violation (``d`` pseudo-uniform mod B across elements)
    fires with per-element rate ``~2*delta`` per neighbor — e.g. 1/128 at
    8 bits, 1/2 at 2 bits — so over a model's worth of elements any
    sustained violation produces counts in the thousands per round while
    a safe run stays at exactly zero.  Computable from payload + local
    reference only, i.e. from what a receiver has on real hardware
    (telemetry: see ``repro.obs.metrics.moniqua_alias_count``; pure-jnp
    twin of the recovered difference: ``ref.recovered_diff_ref``).

    Observational only — shares ``unpack_values`` with the kernel math but
    feeds nothing back into the mix, so telemetry on/off is bit-exact.
    """
    d = qb - y.astype(jnp.float32)
    dhat = d - B * jnp.floor(d / B + 0.5)               # cmod(d, B)
    return jnp.abs(dhat) >= jnp.asarray(theta, jnp.float32)


def _decode_reduce_kernel(ps_ref, pn_ref, y_ref, b_ref, o_ref, *,
                          bits: int, weights: tuple):
    B = b_ref[0]
    qb_self = unpack_values(ps_ref[...], bits, B)
    qb_nbrs = [unpack_values(pn_ref[s], bits, B) for s in range(len(weights))]
    out = decode_reduce_values(qb_self, qb_nbrs, y_ref[...], B, weights)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "weights", "block_rows",
                                             "block_cols", "interpret"))
def decode_reduce(p_self: jax.Array, p_nbrs: jax.Array, y2d: jax.Array,
                  B: jax.Array, *, bits: int, weights: tuple,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  block_cols: int = DEFAULT_BLOCK_COLS,
                  interpret: bool = False) -> jax.Array:
    """Fused mix of ``m = len(weights)`` neighbor payloads into local ``y2d``.

    Shapes: ``p_self (rows, cols*bits/8)``, ``p_nbrs (m, rows, cols*bits/8)``
    (neighbor s in topology offset order), ``y2d (rows, cols)``.
    """
    rows, cols = y2d.shape
    vpb = 8 // bits
    m = len(weights)
    if p_nbrs.shape != (m, rows, cols // vpb):
        raise ValueError(f"p_nbrs {p_nbrs.shape} != {(m, rows, cols // vpb)}")
    if cols % block_cols or rows % block_rows:
        raise ValueError(f"shape {y2d.shape} not tiled by "
                         f"({block_rows},{block_cols}); pad in ops.py")
    grid = (rows // block_rows, cols // block_cols)
    kernel = functools.partial(_decode_reduce_kernel, bits=bits,
                               weights=tuple(weights))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols // vpb), lambda i, j: (i, j)),
            pl.BlockSpec((m, block_rows, block_cols // vpb),
                         lambda i, j: (0, i, j)),
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), y2d.dtype),
        interpret=interpret,
    )(p_self, p_nbrs, y2d, jnp.asarray(B, jnp.float32).reshape(1))
