"""Pallas TPU kernel: fused Moniqua decode (unpack → dequant → mod-recover).

Receiver side of Algorithm 1: given the packed payload from a neighbor and the
receiver's own model tile ``y`` (the Lemma 1 reference), produce

    x_hat = ((q * B) - y) mod B + y           (mode="remote", line 5)
    x_hat = (q * B) - (y mod B) + y           (mode="self",   line 4)

in a single VMEM pass: one packed read (bits/8 bytes/elem) + one y read +
one f32/bf16 write.  The two modes share the unpack/dequant prologue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_COLS = 1024


def _decode_kernel(p_ref, y_ref, b_ref, o_ref, *, bits: int, mode: str):
    levels = 2 ** bits
    vpb = 8 // bits
    rows, pcols = p_ref.shape
    B = b_ref[0]
    p = p_ref[...].astype(jnp.uint32)

    if vpb == 1:
        codes = p.astype(jnp.float32)
    else:
        mask = jnp.uint32(2 ** bits - 1)
        subs = [((p >> jnp.uint32(s * bits)) & mask) for s in range(vpb)]
        # value at column (b*vpb + s) comes from byte b, slot s
        codes = jnp.stack(subs, axis=-1).reshape(rows, pcols * vpb)
        codes = codes.astype(jnp.float32)

    qb = ((codes + 0.5) / levels - 0.5) * B
    y = y_ref[...].astype(jnp.float32)
    if mode == "remote":
        d = qb - y
        out = (d - B * jnp.floor(d / B + 0.5)) + y      # cmod(q*B - y, B) + y
    elif mode == "self":
        ymod = y - B * jnp.floor(y / B + 0.5)           # cmod(y, B)
        out = qb - ymod + y
    else:
        raise ValueError(mode)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "mode", "block_rows",
                                             "block_cols", "interpret"))
def decode(packed: jax.Array, y2d: jax.Array, B: jax.Array, *, bits: int,
           mode: str = "remote",
           block_rows: int = DEFAULT_BLOCK_ROWS,
           block_cols: int = DEFAULT_BLOCK_COLS,
           interpret: bool = False) -> jax.Array:
    """Decode packed (rows, cols*bits/8) against local y (rows, cols)."""
    rows, cols = y2d.shape
    vpb = 8 // bits
    if cols % block_cols or rows % block_rows:
        raise ValueError(f"shape {y2d.shape} not tiled by "
                         f"({block_rows},{block_cols}); pad in ops.py")
    grid = (rows // block_rows, cols // block_cols)
    kernel = functools.partial(_decode_kernel, bits=bits, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols // vpb), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), y2d.dtype),
        interpret=interpret,
    )(packed, y2d, jnp.asarray(B, jnp.float32).reshape(1))
