"""Pure-jnp oracles for the Moniqua codec kernels.

These define the *exact* semantics the Pallas kernels must reproduce
(bitwise, including the in-kernel hash RNG), and are what the tests
``assert_allclose`` against.  They are also the fallback path used on
non-TPU backends.

RNG: stochastic rounding uses a counter-based murmur3-finalizer hash of
``(seed, flat_element_index)`` so that (a) the same element gets the same
uniform draw on every worker (the paper's *shared randomness*, Supp. C) and
(b) kernel and oracle agree bit-for-bit with no PRNG-state threading.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# one source of truth for the bit-exactness-critical hash (every backend
# must draw identical uniforms per (seed, element) pair)
from repro.core.quantizers import _counter_uniform as hash_uniform


def cmod(z: jax.Array, a) -> jax.Array:
    zf = z.astype(jnp.float32)
    a = jnp.float32(a) if not isinstance(a, jax.Array) else a.astype(jnp.float32)
    return zf - a * jnp.floor(zf / a + 0.5)


def codes_ref(x: jax.Array, B, bits: int, stochastic: bool,
              seed: jax.Array, idx: jax.Array) -> jax.Array:
    """Quantization codes of ``Q_delta((x/B) mod 1)`` (Algorithm 1 line 3)."""
    levels = 2 ** bits
    r = cmod(x.astype(jnp.float32) / B, 1.0)           # [-1/2, 1/2)
    lat = (r + 0.5) * levels - 0.5                      # midpoint lattice
    if stochastic:
        u = hash_uniform(seed, idx)
        c = jnp.floor(lat + u)
    else:
        c = jnp.floor(lat + 0.5)
    return jnp.clip(c, 0, levels - 1).astype(jnp.uint8)


def pack_ref(codes: jax.Array, bits: int) -> jax.Array:
    """Pack codes into uint8 along the last axis (must be divisible)."""
    if bits == 8:
        return codes.astype(jnp.uint8)
    vpb = 8 // bits
    g = codes.reshape(*codes.shape[:-1], -1, vpb).astype(jnp.uint8)
    out = jnp.zeros(g.shape[:-1], jnp.uint8)
    for j in range(vpb):
        out = out | (g[..., j] << jnp.uint8(j * bits))
    return out


def unpack_ref(packed: jax.Array, bits: int) -> jax.Array:
    if bits == 8:
        return packed
    vpb = 8 // bits
    mask = jnp.uint8(2 ** bits - 1)
    parts = [(packed >> jnp.uint8(j * bits)) & mask for j in range(vpb)]
    return jnp.stack(parts, axis=-1).reshape(*packed.shape[:-1], -1)


def encode_ref(x: jax.Array, B, bits: int, stochastic: bool, seed,
               idx_base=0) -> jax.Array:
    """Full encode: x -> packed uint8.  Last dim must divide values-per-byte.

    ``idx_base`` offsets the counter index: element ``e`` hashes
    ``(seed, idx_base + e)``, matching the kernel's global indexing when
    this array is one segment of a bucketed flat buffer.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    idx = (jnp.asarray(idx_base, jnp.uint32)
           + jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape))
    codes = codes_ref(x, B, bits, stochastic, seed, idx)
    return pack_ref(codes, bits)


def value_ref(packed: jax.Array, B, bits: int) -> jax.Array:
    """Unpack + dequantize + rescale: the transmitted value ``q * B``."""
    levels = 2 ** bits
    c = unpack_ref(packed, bits).astype(jnp.float32)
    return ((c + 0.5) / levels - 0.5) * jnp.float32(B)


def decode_ref(packed: jax.Array, y: jax.Array, B, bits: int) -> jax.Array:
    """Lemma 1 recovery against local reference ``y``."""
    qb = value_ref(packed, B, bits)
    yf = y.astype(jnp.float32)
    return cmod(qb - yf, B) + yf


def recovered_diff_ref(packed: jax.Array, y: jax.Array, B,
                       bits: int) -> jax.Array:
    """The Lemma-1 recovered neighbor difference ``cmod(q*B - y, B)``
    (``decode_ref`` minus the reference) — what the alias sentinel
    (``moniqua_decode_reduce.alias_band_mask``) thresholds at ``theta``."""
    return cmod(value_ref(packed, B, bits) - y.astype(jnp.float32), B)


def decode_self_ref(packed: jax.Array, x: jax.Array, B, bits: int) -> jax.Array:
    """Algorithm 1 line 4: sender-side biased reconstruction."""
    qb = value_ref(packed, B, bits)
    xf = x.astype(jnp.float32)
    return qb - cmod(xf, B) + xf
