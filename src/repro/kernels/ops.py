"""Jitted wrappers around the Moniqua codec kernels.

Handles arbitrary shapes/dtypes by flattening to a padded 2-D tile grid,
dispatching to the Pallas kernels (``interpret=True`` automatically off-TPU so
the same call validates on CPU), and restoring the caller's layout.

The packed layout matches ``core.quantizers.pack_codes`` (pack along the last
axis, zero-padded to the values-per-byte boundary) so payload byte accounting
is identical between the kernel and pure-jnp paths.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import QuantSpec, packed_last_dim
from repro.kernels import moniqua_decode as _dec
from repro.kernels import moniqua_encode as _enc
from repro.kernels import ref as kref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _to_tiles(x: jax.Array, block_rows: int, block_cols: int):
    """Flatten to (rows, cols) padded to the tile grid; return unpad info."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = block_cols
    rows = -(-n // cols)
    rows_p = -(-rows // block_rows) * block_rows
    pad = rows_p * cols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows_p, cols), n


def _key_to_seed(key: Optional[jax.Array]) -> jax.Array:
    if key is None:
        return jnp.uint32(0)
    return jax.random.key_data(key).reshape(-1)[-1].astype(jnp.uint32)


def moniqua_encode(x: jax.Array, B: jax.Array, spec: QuantSpec,
                   key: Optional[jax.Array], *,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Encode any-shape ``x`` -> packed uint8 with last dim ceil(n/vpb).

    Kernel-internal layout is a flat row-major tile grid; the public layout
    (matching ``pack_codes``) is recovered by unpack/repack only when the last
    dim is not already byte-aligned — the common aligned case is zero-copy.
    """
    if interpret is None:
        interpret = not _on_tpu()
    seed = _key_to_seed(key)
    vpb = spec.values_per_byte
    n_last = x.shape[-1] if x.ndim else 1
    pad = (-n_last) % vpb
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    lead_shape = xp.shape[:-1]
    x2d, n = _to_tiles(xp, _enc.DEFAULT_BLOCK_ROWS, _enc.DEFAULT_BLOCK_COLS)
    p = _enc.encode(x2d, B, seed, bits=spec.bits, stochastic=spec.stochastic,
                    interpret=interpret)
    p = p.reshape(-1)[: n // vpb]
    return p.reshape(*lead_shape, (n_last + pad) // vpb)


def _decode_common(packed: jax.Array, y: jax.Array, B, spec: QuantSpec,
                   mode: str, interpret: Optional[bool]) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    vpb = spec.values_per_byte
    n_last = y.shape[-1]
    pad = (-n_last) % vpb
    yp = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)]) if pad else y
    br = _dec.DEFAULT_BLOCK_ROWS
    bc = _dec.DEFAULT_BLOCK_COLS
    y2d, n = _to_tiles(yp, br, bc)
    pflat = packed.reshape(-1)
    p_need = y2d.size // vpb
    pfull = jnp.zeros((p_need,), jnp.uint8).at[: pflat.shape[0]].set(pflat)
    p2d = pfull.reshape(y2d.shape[0], y2d.shape[1] // vpb)
    out = _dec.decode(p2d, y2d, B, bits=spec.bits, mode=mode,
                      interpret=interpret)
    out = out.reshape(-1)[:n].reshape(yp.shape)
    if pad:
        out = out[..., :n_last]
    return out


def moniqua_decode_remote(packed, y, B, spec: QuantSpec, *,
                          interpret: Optional[bool] = None):
    return _decode_common(packed, y, B, spec, "remote", interpret)


def moniqua_decode_self(packed, x, B, spec: QuantSpec, *,
                        interpret: Optional[bool] = None):
    return _decode_common(packed, x, B, spec, "self", interpret)


# Reference-path conveniences used by MoniquaCodec(use_pallas=True)

def moniqua_unpack_value(packed, B, spec: QuantSpec, last_dim: int):
    codes = kref.unpack_ref(packed, spec.bits)[..., :last_dim]
    return ((codes.astype(jnp.float32) + 0.5) / spec.levels - 0.5) * B


def moniqua_recover(qb, y, B):
    return kref.cmod(qb - y.astype(jnp.float32), B) + y.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Flash attention: Pallas forward + reference backward (recompute).
# ---------------------------------------------------------------------------

def _sdpa_ref(q, k, v, scale, causal, window):
    """Masked-softmax oracle on [BH, S, D] layout (matches models/layers)."""
    sq, sk = q.shape[1], k.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    valid = jnp.ones((sq, sk), bool)
    if causal:
        valid &= kj <= qi
        if window:
            valid &= kj > qi - window
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bqk,bkd->bqd", w, v)


@functools.lru_cache(maxsize=None)
def _flash_sdpa_fn(scale: float, causal: bool, window: int, interpret: bool):
    from repro.kernels.flash_attention import flash_attention

    @jax.custom_vjp
    def f(q, k, v):
        return flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, interpret=interpret)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        # Recompute-based backward through the reference attention: the
        # forward never materialises scores (kernel); the backward pays the
        # jnp path once. A fused Pallas backward is the natural next step.
        q, k, v = res
        _, vjp = jax.vjp(lambda q_, k_, v_: _sdpa_ref(q_, k_, v_, scale,
                                                      causal, window),
                         q, k, v)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def flash_sdpa(q, k, v, *, scale: float, causal: bool = True,
               window: int = 0, interpret: Optional[bool] = None):
    """Differentiable flash attention on [..., S, H, D] tensors.

    Forward = Pallas kernel (scores stay in VMEM); backward = reference
    recompute.  interpret defaults to True off-TPU.
    """
    if interpret is None:
        interpret = not _on_tpu()
    *lead, S, H, D = q.shape
    Sk = k.shape[-3]
    fold = 1
    for n in lead:
        fold *= n
    qf = jnp.moveaxis(q, -2, -3).reshape(fold * H, S, D)
    kf = jnp.moveaxis(k, -2, -3).reshape(fold * H, Sk, D)
    vf = jnp.moveaxis(v, -2, -3).reshape(fold * H, Sk, D)
    o = _flash_sdpa_fn(scale, causal, window, interpret)(qf, kf, vf)
    o = o.reshape(*lead, H, S, D)
    return jnp.moveaxis(o, -3, -2)
