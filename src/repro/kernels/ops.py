"""Jitted wrappers around the Moniqua codec kernels.

Handles arbitrary shapes/dtypes by flattening to a padded 2-D tile grid,
dispatching to the Pallas kernels (``interpret=True`` automatically off-TPU so
the same call validates on CPU), and restoring the caller's layout.

The packed layout matches ``core.quantizers.pack_codes`` (pack along the last
axis, zero-padded to the values-per-byte boundary) so payload byte accounting
is identical between the kernel and pure-jnp paths.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import QuantSpec, packed_last_dim
from repro.kernels import moniqua_decode as _dec
from repro.kernels import moniqua_decode_reduce as _dr
from repro.kernels import moniqua_encode as _enc
from repro.kernels import ref as kref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _to_tiles(x: jax.Array, block_rows: int, block_cols: int):
    """Flatten to (rows, cols) padded to the tile grid; return unpad info."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = block_cols
    rows = -(-n // cols)
    rows_p = -(-rows // block_rows) * block_rows
    pad = rows_p * cols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows_p, cols), n


# Hash seed used when no PRNG key is supplied.  Only *deterministic*
# (nearest-rounding) specs may omit the key — the counter hash is never
# drawn on that path, so the constant is a documented placeholder, not a
# silent randomness source.  CommEngine._require_key rejects key=None for
# stochastic specs before this is ever reached; the legacy value 0 is kept
# so deterministic payload bits are unchanged across versions.
NO_KEY_SEED = 0


def _key_to_seed(key: Optional[jax.Array]) -> jax.Array:
    if key is None:
        return jnp.uint32(NO_KEY_SEED)
    return jax.random.key_data(key).reshape(-1)[-1].astype(jnp.uint32)


def _encode_layout(x: jax.Array, vpb: int):
    """Shared pad-to-tiles prologue for the kernel and pure-jnp encodes."""
    n_last = x.shape[-1] if x.ndim else 1
    pad = (-n_last) % vpb
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    x2d, n = _to_tiles(xp, _enc.DEFAULT_BLOCK_ROWS, _enc.DEFAULT_BLOCK_COLS)
    return x2d, n, xp.shape[:-1], n_last, pad


def moniqua_encode(x: jax.Array, B: jax.Array, spec: QuantSpec,
                   key: Optional[jax.Array], *,
                   seed: Optional[jax.Array] = None,
                   interpret: Optional[bool] = None,
                   idx_base: jax.Array | int = 0) -> jax.Array:
    """Encode any-shape ``x`` -> packed uint8 with last dim ceil(n/vpb).

    Kernel-internal layout is a flat row-major tile grid; the public layout
    (matching ``pack_codes``) is recovered by unpack/repack only when the last
    dim is not already byte-aligned — the common aligned case is zero-copy.

    ``seed`` overrides the key-derived hash seed (CommEngine passes seeds
    directly so its jnp and Pallas backends draw identical uniforms).
    ``idx_base`` offsets the stochastic counter index — the flat-buffer
    offset of this tensor when it is one segment of a bucketed layout
    (``comm/bucket.py``), 0 for a standalone encode.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if seed is None:
        seed = _key_to_seed(key)
    vpb = spec.values_per_byte
    x2d, n, lead_shape, n_last, pad = _encode_layout(x, vpb)
    p = _enc.encode(x2d, B, seed, bits=spec.bits, stochastic=spec.stochastic,
                    interpret=interpret, idx_base=idx_base)
    p = p.reshape(-1)[: n // vpb]
    return p.reshape(*lead_shape, (n_last + pad) // vpb)


def moniqua_encode_jnp(x: jax.Array, B: jax.Array, spec: QuantSpec,
                       seed: jax.Array,
                       idx_base: jax.Array | int = 0) -> jax.Array:
    """Pure-jnp encode, bit-identical to :func:`moniqua_encode`.

    Uses the same padded tile layout so the counter-based hash draws the same
    uniform per element as the kernel — the CommEngine jnp backend.
    """
    vpb = spec.values_per_byte
    x2d, n, lead_shape, n_last, pad = _encode_layout(x, vpb)
    p = kref.encode_ref(x2d, B, spec.bits, spec.stochastic, seed,
                        idx_base=idx_base)
    p = p.reshape(-1)[: n // vpb]
    return p.reshape(*lead_shape, (n_last + pad) // vpb)


def _decode_common(packed: jax.Array, y: jax.Array, B, spec: QuantSpec,
                   mode: str, interpret: Optional[bool]) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    vpb = spec.values_per_byte
    n_last = y.shape[-1]
    pad = (-n_last) % vpb
    yp = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)]) if pad else y
    br = _dec.DEFAULT_BLOCK_ROWS
    bc = _dec.DEFAULT_BLOCK_COLS
    y2d, n = _to_tiles(yp, br, bc)
    p2d = _p2d(packed, y2d.size // vpb, y2d.shape[0], y2d.shape[1] // vpb)
    out = _dec.decode(p2d, y2d, B, bits=spec.bits, mode=mode,
                      interpret=interpret)
    out = out.reshape(-1)[:n].reshape(yp.shape)
    if pad:
        out = out[..., :n_last]
    return out


def moniqua_decode_remote(packed, y, B, spec: QuantSpec, *,
                          interpret: Optional[bool] = None):
    return _decode_common(packed, y, B, spec, "remote", interpret)


def moniqua_decode_self(packed, x, B, spec: QuantSpec, *,
                        interpret: Optional[bool] = None):
    return _decode_common(packed, x, B, spec, "self", interpret)


# ---------------------------------------------------------------------------
# Fused decode-reduce: one gossip round's mixing in a single pass.
# ---------------------------------------------------------------------------

def _p2d(packed: jax.Array, p_need: int, rows: int, pcols: int) -> jax.Array:
    # jnp.pad, not zeros().at[].set(): the scatter form allocates and fills
    # a second full-size buffer on every mix; pad lowers to one concat
    pflat = packed.reshape(-1)
    return jnp.pad(pflat, (0, p_need - pflat.shape[0])).reshape(rows, pcols)


def moniqua_decode_reduce(p_self: jax.Array, p_nbrs: jax.Array, y: jax.Array,
                          B, weights, spec: QuantSpec, *,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Fused gossip mix: ``y + sum_s w_s (xhat_s - xhat_self)`` (kernel path).

    ``p_nbrs`` stacks the neighbors' packed payloads on a new leading axis in
    topology offset order; ``weights`` are the matching static gossip weights.
    Handles arbitrary ``y`` shapes via the shared pad/tile layout.
    """
    if interpret is None:
        interpret = not _on_tpu()
    vpb = spec.values_per_byte
    n_last = y.shape[-1]
    pad = (-n_last) % vpb
    yp = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, pad)]) if pad else y
    br, bc = _dr.DEFAULT_BLOCK_ROWS, _dr.DEFAULT_BLOCK_COLS
    y2d, n = _to_tiles(yp, br, bc)
    rows, pcols = y2d.shape[0], y2d.shape[1] // vpb
    p_need = rows * pcols
    ps2d = _p2d(p_self, p_need, rows, pcols)
    pn2d = jnp.stack([_p2d(p_nbrs[s], p_need, rows, pcols)
                      for s in range(p_nbrs.shape[0])])
    out = _dr.decode_reduce(ps2d, pn2d, y2d, B, bits=spec.bits,
                            weights=tuple(float(w) for w in weights),
                            interpret=interpret)
    out = out.reshape(-1)[:n].reshape(yp.shape)
    return out[..., :n_last] if pad else out


def moniqua_decode_reduce_jnp(p_self: jax.Array, p_nbrs: jax.Array,
                              y: jax.Array, B, weights,
                              spec: QuantSpec) -> jax.Array:
    """Pure-jnp twin of :func:`moniqua_decode_reduce` (bit-exact off-TPU).

    Shares ``decode_reduce_values`` with the kernel body — same per-element
    f32 op sequence, same accumulation order, same optimization-barrier
    fences — so the CommEngine parity test asserts exact equality.
    """
    Bf = jnp.asarray(B, jnp.float32)
    n_last = y.shape[-1]

    def val(p):
        return _dr.unpack_values(p, spec.bits, Bf)[..., :n_last]

    qb_nbrs = [val(p_nbrs[s]) for s in range(p_nbrs.shape[0])]
    out = _dr.decode_reduce_values(val(p_self), qb_nbrs, y, Bf, weights)
    return out.astype(y.dtype)


# ---------------------------------------------------------------------------
# Stacked-worker wrappers: per-worker tiling over the leading [n, ...] axis.
#
# The tile layout above flattens a whole array (``_to_tiles``'s
# ``reshape(-1)``); applied directly to a stacked ``[n, ...]`` leaf that
# would cross the (sharded) worker axis — XLA could insert resharding
# around the encode/decode, and the counter-hash element index would differ
# per worker, breaking Supp. C's shared randomness.  These wrappers vmap
# the layout over axis 0 instead: each worker tiles its own slice with
# element indices 0..d-1 and the SAME seed, so (a) the only cross-worker
# traffic left in a CommEngine round is the packed collective-permute and
# (b) every worker draws identical rounding uniforms per element (Supp. C).
# ---------------------------------------------------------------------------

def moniqua_encode_stacked(x: jax.Array, B, spec: QuantSpec,
                           seed: jax.Array, *, backend: str,
                           idx_base: jax.Array | int = 0) -> jax.Array:
    """Encode a stacked ``[n, ...]`` leaf with per-worker tile layout.

    ``idx_base`` is shared by every worker slice (the counter index never
    depends on the worker position — Supp. C shared randomness).
    """
    if backend == "pallas":
        return jax.vmap(lambda xi: moniqua_encode(
            xi, B, spec, None, seed=seed, idx_base=idx_base))(x)
    return jax.vmap(lambda xi: moniqua_encode_jnp(
        xi, B, spec, seed, idx_base=idx_base))(x)


def moniqua_decode_reduce_stacked(p_self: jax.Array, p_nbrs: jax.Array,
                                  y: jax.Array, B, weights, spec: QuantSpec,
                                  *, backend: str) -> jax.Array:
    """Fused decode-reduce over a stacked leaf, tiled per worker.

    ``p_self``/``y`` carry the worker axis at 0; ``p_nbrs`` stacks the
    neighbor payloads at axis 0 with the worker axis at 1 (the layout one
    ``jnp.roll`` per offset produces).
    """
    fn = (moniqua_decode_reduce if backend == "pallas"
          else moniqua_decode_reduce_jnp)
    return jax.vmap(lambda ps, pn, yi: fn(ps, pn, yi, B, weights, spec),
                    in_axes=(0, 1, 0))(p_self, p_nbrs, y)


# ---------------------------------------------------------------------------
# Chunk-windowed launches: one pipeline stage of a staged gossip round.
#
# ``CommEngine.round_plan`` splits the flat [n, D] bucket into contiguous
# chunks (``comm/bucket.py::BucketLayout.chunks``) and encodes/decodes one
# window at a time so the chunk's collective-permute can overlap its
# neighbors' codec work.  Correctness hinges on the counter index: the
# window's elements must hash the SAME (seed, global index) pairs the
# one-shot whole-buffer encode hashes, so ``idx_base`` is the window's
# element offset in the buffer — that is the whole bit-exactness argument
# (identical per-element op sequence on a slice, identical uniforms).
# ---------------------------------------------------------------------------

def moniqua_encode_chunk(flat: jax.Array, offset: int, size: int, B,
                         spec: QuantSpec, seed: jax.Array, *,
                         backend: str, idx_base: Optional[int] = None
                         ) -> jax.Array:
    """Encode the window ``flat[:, offset:offset+size]`` of a stacked flat
    buffer, with globally-indexed rounding uniforms (``idx_base=offset``).

    ``idx_base`` overrides the counter base when ``flat`` is itself a
    window of a larger buffer (a shard plan slices at shard-local offsets
    but must hash *global* element indices to stay bit-exact against the
    whole-buffer encode).
    """
    win = jax.lax.slice_in_dim(flat, offset, offset + size, axis=1)
    return moniqua_encode_stacked(win, B, spec, seed, backend=backend,
                                  idx_base=offset if idx_base is None
                                  else idx_base)


def moniqua_decode_reduce_chunk(p_self: jax.Array, p_nbrs: jax.Array,
                                flat: jax.Array, offset: int, size: int, B,
                                weights, spec: QuantSpec, *,
                                backend: str) -> jax.Array:
    """Fused decode-reduce of one chunk's payloads against the matching
    window of the local flat buffer (decode draws no randomness, so only
    the window slice matters — no idx_base needed)."""
    win = jax.lax.slice_in_dim(flat, offset, offset + size, axis=1)
    return moniqua_decode_reduce_stacked(p_self, p_nbrs, win, B, weights,
                                         spec, backend=backend)


# Reference-path conveniences used by MoniquaCodec(use_pallas=True)

def moniqua_unpack_value(packed, B, spec: QuantSpec, last_dim: int):
    codes = kref.unpack_ref(packed, spec.bits)[..., :last_dim]
    return ((codes.astype(jnp.float32) + 0.5) / spec.levels - 0.5) * B


def moniqua_recover(qb, y, B):
    return kref.cmod(qb - y.astype(jnp.float32), B) + y.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Flash attention: Pallas forward + reference backward (recompute).
# ---------------------------------------------------------------------------

def _sdpa_ref(q, k, v, scale, causal, window):
    """Masked-softmax oracle on [BH, S, D] layout (matches models/layers)."""
    sq, sk = q.shape[1], k.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    valid = jnp.ones((sq, sk), bool)
    if causal:
        valid &= kj <= qi
        if window:
            valid &= kj > qi - window
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bqk,bkd->bqd", w, v)


@functools.lru_cache(maxsize=None)
def _flash_sdpa_fn(scale: float, causal: bool, window: int, interpret: bool):
    from repro.kernels.flash_attention import flash_attention

    @jax.custom_vjp
    def f(q, k, v):
        return flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, interpret=interpret)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        # Recompute-based backward through the reference attention: the
        # forward never materialises scores (kernel); the backward pays the
        # jnp path once. A fused Pallas backward is the natural next step.
        q, k, v = res
        _, vjp = jax.vjp(lambda q_, k_, v_: _sdpa_ref(q_, k_, v_, scale,
                                                      causal, window),
                         q, k, v)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def flash_sdpa(q, k, v, *, scale: float, causal: bool = True,
               window: int = 0, interpret: Optional[bool] = None):
    """Differentiable flash attention on [..., S, H, D] tensors.

    Forward = Pallas kernel (scores stay in VMEM); backward = reference
    recompute.  interpret defaults to True off-TPU.
    """
    if interpret is None:
        interpret = not _on_tpu()
    *lead, S, H, D = q.shape
    Sk = k.shape[-3]
    fold = 1
    for n in lead:
        fold *= n
    qf = jnp.moveaxis(q, -2, -3).reshape(fold * H, S, D)
    kf = jnp.moveaxis(k, -2, -3).reshape(fold * H, Sk, D)
    vf = jnp.moveaxis(v, -2, -3).reshape(fold * H, Sk, D)
    o = _flash_sdpa_fn(scale, causal, window, interpret)(qf, kf, vf)
    o = o.reshape(*lead, H, S, D)
    return jnp.moveaxis(o, -3, -2)
