"""Pallas TPU kernel: flash-attention forward (online-softmax, windowed).

Why this kernel exists (EXPERIMENTS.md §Perf): at prefill/train shapes the
jnp attention materialises the [Sq, Sk] score matrix in f32 plus a ~5-op
softmax chain over it — the single largest HBM-bytes term of every dense
roofline (e.g. internlm2-20b train_4k: ~55% of bytes; llama3.2-3b
prefill_32k: ~70%).  The fused kernel streams K/V blocks through VMEM with
online-softmax accumulators, so HBM traffic is exactly Q+K+V+O — scores
never leave VMEM/VREGs.

TPU adaptation (vs the CUDA flash-attention):
  * grid = (batch*heads, q_blocks, k_blocks) with the k dimension marked
    "arbitrary" (sequential): accumulators (m, l, acc) live in VMEM scratch
    that persists across the k sweep — the Pallas/TPU idiom replacing CUDA
    warp-level reductions;
  * block shapes default (128, head_dim) / (128, head_dim): the QK^T and
    PV matmuls are 128x128-aligned for the MXU, and head_dim (64/128 for
    every assigned arch) is lane-aligned;
  * causal/sliding-window masks are computed from global indices via iota —
    no mask tensor is ever read from HBM (the jnp path broadcasts a
    [Sq, Sk] bool/f32 mask: measured ~100 GB/layer at 4k);
  * fully-masked k-blocks (beyond the causal frontier or the window) are
    skipped with @pl.when, so sliding-window attention does S*(w+c) work,
    matching the banded jnp fallback.

Validated bit-for-bit reasonable (allclose) against ``ref.flash_ref`` /
the model's masked-softmax oracle in ``tests/test_flash_attention.py``
(interpret mode; shapes x dtypes x window sweeps).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer jax renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, sq: int, sk: int,
               blk_q: int, blk_k: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # k block
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global positions of this tile
    iq = i * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    jk = j * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)

    # block-level skip: entirely above the causal diagonal / out of window
    q_lo = i * blk_q                       # smallest query index in tile
    q_hi = i * blk_q + blk_q - 1
    k_lo = j * blk_k
    k_hi = j * blk_k + blk_k - 1
    live = jnp.bool_(True)
    if causal:
        live = live & (k_lo <= q_hi)
        if window:
            live = live & (k_hi > q_lo - window)

    @pl.when(live)
    def _attend():
        q = q_ref[0].astype(jnp.float32)          # [blk_q, D]
        k = k_ref[0].astype(jnp.float32)          # [blk_k, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        valid = (iq < sq) & (jk < sk)
        if causal:
            valid &= jk <= iq
            if window:
                valid &= jk > iq - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                       # [blk_q, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                    # [blk_q, blk_k]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "blk_q", "blk_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True, window: int = 0,
                    blk_q: int = DEFAULT_BLOCK_Q,
                    blk_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: [BH, Sq, D]; k, v: [BH, Sk, D] -> o [BH, Sq, D].

    Sq / Sk are padded to block multiples internally; padded keys are masked,
    padded queries produce garbage rows that are sliced off.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    pq = (-sq) % blk_q
    pk = (-sk) % blk_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0))) if pk else v
    grid = (bh, (sq + pq) // blk_q, (sk + pk) // blk_k)
    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=window, sq=sq, sk=sk,
                               blk_q=blk_q, blk_k=blk_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),   # m: running max
            pltpu.VMEM((blk_q, 1), jnp.float32),   # l: running denominator
            pltpu.VMEM((blk_q, d), jnp.float32),   # acc: running numerator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :]
