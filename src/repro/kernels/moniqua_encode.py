"""Pallas TPU kernel: fused Moniqua encode (rescale → mod → round → bit-pack).

The codec is the per-parameter hot loop of the paper's system: every gossip
round touches every parameter once on the send side.  Unfused, XLA would
materialise the f32 residue, the uint8 codes and the packed bytes as separate
HBM round-trips (3 reads + 3 writes per element); the kernel does one HBM read
(x tile → VMEM) and one HBM write (packed tile), with all arithmetic in VMEM /
VREGs — the encode becomes strictly HBM-bandwidth-bound at ``(2 + bits/8)/4``
of the cost of a f32 copy.

TPU adaptation notes (vs a CUDA bit-twiddling port):
  * tiles are (block_rows × block_cols) with block_cols a multiple of
    128·values_per_byte so the *packed* output tile keeps the 128-lane layout;
  * the pack is expressed as ``vpb`` strided sub-tiles OR-ed with shifts —
    a reshape-free formulation that maps onto VREG shuffles, not scatter;
  * stochastic rounding uses a counter-based murmur3 hash of the global
    element index (shared randomness across workers, Supp. C) instead of a
    stateful PRNG, so grid blocks are independent and replayable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the shared counter-based hash: kernel and every jnp path must draw the
# same uniform per (seed, element) or bit-exactness breaks
from repro.core.quantizers import _counter_uniform as _hash_uniform

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_COLS = 1024  # multiple of 128 * max vpb (8)


def _encode_kernel(x_ref, seed_ref, b_ref, o_ref, *, bits: int,
                   stochastic: bool, ncols: int):
    """One (rows, cols) tile -> (rows, cols/vpb) packed tile.

    ``seed_ref`` carries two replicated uint32 scalars: the hash seed and
    ``idx_base``, the flat-index offset of this array inside a larger
    bucketed layout (0 for a standalone encode).  Offsetting the counter
    index — rather than perturbing the seed — is what lets a per-leaf
    encode draw the *same* uniform per element as the one-shot encode of
    the whole flat bucket (``comm/bucket.py``).
    """
    levels = 2 ** bits
    vpb = 8 // bits
    rows, cols = x_ref.shape
    i = pl.program_id(0)
    j = pl.program_id(1)

    x = x_ref[...].astype(jnp.float32)
    B = b_ref[0]
    inv_b = 1.0 / B
    r = x * inv_b
    r = r - jnp.floor(r + 0.5)                     # (x/B) mod 1 in [-1/2, 1/2)
    lat = (r + 0.5) * levels - 0.5

    if stochastic:
        # global flat element index (row-major over the full padded array)
        row_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
        col_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
        g_rows = row_ids + jnp.uint32(i * rows)
        g_cols = col_ids + jnp.uint32(j * cols)
        idx = seed_ref[1] + g_rows * jnp.uint32(ncols) + g_cols
        u = _hash_uniform(seed_ref[0], idx)
        c = jnp.floor(lat + u)
    else:
        c = jnp.floor(lat + 0.5)
    c = jnp.clip(c, 0, levels - 1).astype(jnp.uint32)

    if vpb == 1:
        o_ref[...] = c.astype(jnp.uint8)
        return
    # pack: value v at column (b*vpb + j) lands in byte b, bit-slot j.
    c3 = c.reshape(rows, cols // vpb, vpb)
    packed = c3[:, :, 0]
    for s in range(1, vpb):
        packed = packed | (c3[:, :, s] << jnp.uint32(s * bits))
    o_ref[...] = packed.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bits", "stochastic", "block_rows",
                                             "block_cols", "interpret"))
def encode(x2d: jax.Array, B: jax.Array, seed: jax.Array, *, bits: int,
           stochastic: bool = True,
           block_rows: int = DEFAULT_BLOCK_ROWS,
           block_cols: int = DEFAULT_BLOCK_COLS,
           interpret: bool = False,
           idx_base: jax.Array | int = 0) -> jax.Array:
    """Encode a 2-D array (rows, cols) with cols % block_cols == 0.

    Returns packed uint8 of shape (rows, cols * bits / 8).  ``idx_base``
    offsets the stochastic-rounding counter index (see ``_encode_kernel``).
    """
    rows, cols = x2d.shape
    if cols % block_cols or rows % block_rows:
        raise ValueError(f"shape {x2d.shape} not tiled by "
                         f"({block_rows},{block_cols}); pad in ops.py")
    vpb = 8 // bits
    grid = (rows // block_rows, cols // block_cols)
    kernel = functools.partial(_encode_kernel, bits=bits,
                               stochastic=stochastic, ncols=cols)
    seed_base = jnp.stack([jnp.asarray(seed, jnp.uint32).reshape(()),
                           jnp.asarray(idx_base, jnp.uint32).reshape(())])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j)),
            pl.BlockSpec((2,), lambda i, j: (0,)),   # [seed, idx_base] (repl.)
            pl.BlockSpec((1,), lambda i, j: (0,)),   # B    (replicated)
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols // vpb),
                               lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols // vpb), jnp.uint8),
        interpret=interpret,
    )(x2d, seed_base, jnp.asarray(B, jnp.float32).reshape(1))
