"""Shared-resource contention: NICs and switches as capacity-limited links.

The PR-2 simulator priced every transfer on an *isolated* alpha-beta link,
which overstates full-precision baselines on switched fabrics: concurrent
gossip pairs there share uplink bandwidth, so the fp32 payloads that
saturate the fabric slow each other down — exactly the regime Moniqua's
byte savings were motivated by.  This module adds the missing layer:

* a :class:`Fabric` describes the shared resources a transfer traverses —
  the sender's NIC ``tx`` queue, the receiver's NIC ``rx`` queue, and any
  number of :class:`Switch` resources (oversubscribed ToR uplinks, a
  half-duplex shared medium) between them;
* :func:`solve_rates` splits each resource's capacity over the flows
  crossing it, with either of two sharing disciplines:

  - ``"max-concurrency"`` — a flow's rate is the *most contended* resource
    on its path divided evenly: ``min_r capacity(r) / n_flows(r)``.  Cheap,
    pessimistic (not work-conserving);
  - ``"water-filling"``  — exact progressive-filling max-min fairness: all
    unfrozen flows rise together, a resource that saturates freezes its
    flows, capacity left by frozen flows is redistributed.  Saturated
    resources are used to *exactly* their capacity, and the allocation is
    independent of flow order (``tests/test_contention.py``);

* a :class:`FlowScheduler` runs the fluid model through time: flows drain
  at the solved rates, and every flow arrival/departure re-solves the
  rates (bumping ``epoch`` so stale completion predictions can be
  recognized and discarded — how ``sim/events.py`` interleaves contended
  transfers with compute events without a global barrier);
* :func:`schedule_transfers` is the batch entry point the sync-round mode
  uses: given ``(start, src, dst, nbytes)`` flow specs it returns each
  flow's completion time under the fluid model.

Everything is pure float arithmetic, deterministic, RNG-free (jitter stays
in the event layer, drawn from ``sim_uniform``).  A :class:`Fabric` with no
switches and ``nic_Bps == beta`` reproduces the isolated-link round times
on a symmetric gossip round — contention can only *add* time, a contract
``tests/test_contention.py`` enforces.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple

MAX_CONCURRENCY = "max-concurrency"
WATER_FILLING = "water-filling"
SHARING_MODES = (MAX_CONCURRENCY, WATER_FILLING)

# relative slack for "this resource is saturated" in the filling loop
_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Switch:
    """One shared switching resource between groups of workers.

    ``members`` lists the workers behind the switch.  A flow traverses the
    switch when it *crosses* the membership boundary — leaving the group
    uses the full-duplex ``up`` direction, entering uses ``down``.  An
    empty ``members`` tuple means a half-duplex shared medium (an old-
    school bus / one radio channel): *every* flow, both directions,
    contends for the single ``shared`` resource.
    """
    name: str
    capacity_Bps: float
    members: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.capacity_Bps <= 0:
            raise ValueError(
                f"capacity_Bps must be positive, got {self.capacity_Bps}")

    def resources(self, src: int, dst: int, n: int) -> Tuple[str, ...]:
        """Resource ids this flow occupies on the switch ('' = none)."""
        if not self.members:
            return (f"sw:{self.name}:shared",)
        mem = {m % n for m in self.members}
        s, d = src % n in mem, dst % n in mem
        if s and not d:
            return (f"sw:{self.name}:up",)
        if d and not s:
            return (f"sw:{self.name}:down",)
        return ()

    def capacity(self, resource: str) -> float:
        return self.capacity_Bps


@dataclasses.dataclass(frozen=True)
class Fabric:
    """The shared-resource view of a cluster network.

    Per-worker NICs (``tx:i`` / ``rx:i``, full-duplex, ``nic_Bps`` each
    way) plus the shared :class:`Switch` resources.  ``alpha_s`` and
    ``jitter_s`` price the per-message latency exactly like
    :class:`~repro.sim.network.LinkModel` — they are added by the event
    layer on top of the fluid completion time, never fed to the solver.
    """
    nic_Bps: float
    switches: Tuple[Switch, ...] = ()
    alpha_s: float = 0.0
    jitter_s: float = 0.0
    mode: str = WATER_FILLING

    def __post_init__(self):
        if self.nic_Bps <= 0:
            raise ValueError(f"nic_Bps must be positive, got {self.nic_Bps}")
        if self.mode not in SHARING_MODES:
            raise ValueError(f"unknown sharing mode {self.mode!r}; "
                             f"one of {SHARING_MODES}")

    def path(self, src: int, dst: int, n: int) -> Tuple[str, ...]:
        """Ordered resource ids a src -> dst transfer occupies."""
        mid: List[str] = []
        for sw in self.switches:
            mid.extend(sw.resources(src, dst, n))
        return (f"tx:{src % n}", *mid, f"rx:{dst % n}")

    def capacity(self, resource: str) -> float:
        if resource.startswith(("tx:", "rx:")):
            return self.nic_Bps
        for sw in self.switches:
            if resource.startswith(f"sw:{sw.name}:"):
                return sw.capacity_Bps
        raise KeyError(f"unknown resource {resource!r}")


def solve_rates(paths: Mapping[int, Tuple[str, ...]],
                capacity, mode: str = WATER_FILLING) -> Dict[int, float]:
    """Per-flow rates (bytes/s) for concurrent flows over shared resources.

    ``paths`` maps flow id -> the resource ids it occupies; ``capacity``
    is a callable ``resource_id -> bytes/s``.  Both disciplines give every
    flow a strictly positive rate, so the fluid model always makes
    progress.
    """
    if mode not in SHARING_MODES:
        raise ValueError(f"unknown sharing mode {mode!r}")
    if not paths:
        return {}
    load: Dict[str, int] = {}
    for p in paths.values():
        for r in p:
            load[r] = load.get(r, 0) + 1
    cap = {r: float(capacity(r)) for r in load}
    if mode == MAX_CONCURRENCY:
        return {f: min(cap[r] / load[r] for r in p)
                for f, p in paths.items()}
    # progressive filling: all unfrozen flows rise together; a resource
    # saturates when its residual is exhausted, freezing its flows there
    rates = {f: 0.0 for f in paths}
    residual = dict(cap)
    unfrozen = set(paths)
    while unfrozen:
        counts: Dict[str, int] = {}
        for f in unfrozen:
            for r in paths[f]:
                counts[r] = counts.get(r, 0) + 1
        inc = min(residual[r] / c for r, c in counts.items())
        for f in unfrozen:
            rates[f] += inc
        for r, c in counts.items():
            residual[r] -= inc * c
        newly = {f for f in unfrozen
                 if any(residual[r] <= _EPS * cap[r] for r in paths[f])}
        if not newly:       # numeric guard; cannot happen with exact floats
            break
        unfrozen -= newly
    return rates


@dataclasses.dataclass
class _Flow:
    path: Tuple[str, ...]
    remaining: float


class FlowScheduler:
    """Fluid-model clock for flows sharing a :class:`Fabric`.

    The scheduler owns (time, active flows, solved rates).  Callers
    :meth:`start` and :meth:`finish` flows at monotonically non-decreasing
    times; between calls, active flows drain at the current rates.  Every
    state change bumps :attr:`epoch`, so a caller that cached projected
    completion times (:meth:`eta`) can detect they went stale — the
    mechanism the async event loop uses to interleave contended transfers
    with compute events.
    """

    def __init__(self, fabric: Fabric, n: int):
        self.fabric = fabric
        self.n = n
        self.t = 0.0
        self.epoch = 0
        self._flows: Dict[int, _Flow] = {}
        self._rates: Dict[int, float] = {}

    @property
    def active(self) -> Tuple[int, ...]:
        return tuple(sorted(self._flows))

    def _advance(self, t: float) -> None:
        if t < self.t - 1e-12:
            raise ValueError(f"time moved backwards: {t} < {self.t}")
        dt = max(t - self.t, 0.0)
        if dt:
            for fid, fl in self._flows.items():
                fl.remaining = max(
                    fl.remaining - self._rates.get(fid, 0.0) * dt, 0.0)
        self.t = t

    def _resolve(self) -> None:
        self._rates = solve_rates(
            {fid: fl.path for fid, fl in self._flows.items()},
            self.fabric.capacity, self.fabric.mode)
        self.epoch += 1

    def start(self, t: float, fid: int, src: int, dst: int,
              nbytes: float) -> None:
        if fid in self._flows:
            raise ValueError(f"flow {fid} already active")
        self._advance(t)
        self._flows[fid] = _Flow(self.fabric.path(src, dst, self.n),
                                 float(nbytes))
        self._resolve()

    def finish(self, t: float, fid: int) -> None:
        """Remove ``fid`` at ``t`` (its bytes must have drained by then)."""
        self._advance(t)
        del self._flows[fid]
        self._resolve()

    def eta(self, fid: int) -> float:
        """Projected completion of ``fid`` under the *current* rates."""
        fl = self._flows[fid]
        if fl.remaining <= 0.0:
            return self.t
        return self.t + fl.remaining / self._rates[fid]


def schedule_transfers(fabric: Fabric, n: int,
                       flows: Sequence[Tuple[float, int, int, float]]
                       ) -> List[float]:
    """Fluid completion time of each ``(start, src, dst, nbytes)`` flow.

    The batch entry point for the sync-round mode: all of a round's
    transfers go in, each one's completion under shared-resource sharing
    comes out (same order).  Latency/jitter are *not* included — the event
    layer adds them, keeping one source of truth for stochastic draws.
    """
    sched = FlowScheduler(fabric, n)
    order = sorted(range(len(flows)), key=lambda i: (flows[i][0], i))
    finish = [0.0] * len(flows)
    active: List[int] = []
    qi = 0
    while qi < len(order) or active:
        t_start = flows[order[qi]][0] if qi < len(order) else float("inf")
        if active:
            t_fin, fid = min((sched.eta(f), f) for f in active)
        else:
            t_fin, fid = float("inf"), -1
        if t_start <= t_fin:
            i = order[qi]
            qi += 1
            _, src, dst, nbytes = flows[i]
            sched.start(t_start, i, src, dst, nbytes)
            active.append(i)
        else:
            sched.finish(t_fin, fid)
            active.remove(fid)
            finish[fid] = t_fin
    return finish


# ---------------------------------------------------------------------------
# Fabric factories for the scenario catalog.
# ---------------------------------------------------------------------------

def tor_groups(n: int, num_groups: int = 2,
               interleave: bool = True) -> Tuple[Tuple[int, ...], ...]:
    """Partition workers into ToR groups.

    ``interleave=True`` assigns round-robin (worker i -> group i % g), the
    adversarial placement for a ring: every neighbor edge crosses a rack
    boundary.  ``False`` gives contiguous blocks (only the seam edges
    cross).
    """
    if not 1 <= num_groups <= n:
        raise ValueError(f"need 1 <= num_groups <= {n}, got {num_groups}")
    if interleave:
        return tuple(tuple(i for i in range(n) if i % num_groups == g)
                     for g in range(num_groups))
    size = (n + num_groups - 1) // num_groups
    return tuple(tuple(range(g * size, min((g + 1) * size, n)))
                 for g in range(num_groups))


def oversubscribed_fabric(n: int, nic_Bps: float, uplink_Bps: float,
                          num_groups: int = 2, interleave: bool = True,
                          alpha_s: float = 0.0, jitter_s: float = 0.0,
                          mode: str = WATER_FILLING) -> Fabric:
    """ToR fabric: each group's cross-rack traffic shares one uplink."""
    switches = tuple(
        Switch(name=f"tor{g}", capacity_Bps=uplink_Bps, members=members)
        for g, members in enumerate(tor_groups(n, num_groups, interleave)))
    return Fabric(nic_Bps=nic_Bps, switches=switches, alpha_s=alpha_s,
                  jitter_s=jitter_s, mode=mode)


def shared_medium_fabric(nic_Bps: float, bus_Bps: float,
                         alpha_s: float = 0.0, jitter_s: float = 0.0,
                         mode: str = WATER_FILLING) -> Fabric:
    """All workers on one half-duplex shared medium of ``bus_Bps``."""
    return Fabric(nic_Bps=nic_Bps,
                  switches=(Switch("bus", bus_Bps),),
                  alpha_s=alpha_s, jitter_s=jitter_s, mode=mode)


def isolated_fabric(nic_Bps: float, alpha_s: float = 0.0,
                    jitter_s: float = 0.0,
                    mode: str = WATER_FILLING) -> Fabric:
    """No shared switches: NIC-limited only (the uncontended twin)."""
    return Fabric(nic_Bps=nic_Bps, alpha_s=alpha_s, jitter_s=jitter_s,
                  mode=mode)
