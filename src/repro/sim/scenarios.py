"""Named simulation scenarios: topology x network x cluster presets.

A :class:`Scenario` bundles everything the event engine needs — a
circulant :class:`~repro.core.topology.Topology`, a
:class:`~repro.sim.network.NetworkModel`, a
:class:`~repro.sim.cluster.ComputeModel`, and the seed all deterministic
draws key off.  The catalog covers the regimes the paper's wall-clock
claims span:

``lan-10gbe-ring``
    Homogeneous datacenter baseline: 10 GbE ring, microsecond latency.
    Compute-bound — codecs barely matter; the control scenario.
``wan-exponential``
    Geo-distributed exponential graph: 200 Mbit/s links, 20 ms base
    latency, and the long ``2^j`` hops (hop distance >= 4) at half
    bandwidth and double latency — heterogeneous links keyed by topology
    offsets.
``straggler-longtail``
    1 GbE ring with one chronically slow worker carrying a Pareto
    (shape 1.2, unbounded-variance) per-step tail: the regime where
    synchronous rounds collapse to the slowest worker and the async
    AD-PSGD loop shines.
``bandwidth-starved``
    25 Mbit/s, 5 ms links (Fig. 1's worst network, further starved):
    fp32 payloads dominate the round; Moniqua's 1-bit wire is the
    headline win here.
``oversubscribed-tor``
    Same 10 GbE NICs as the LAN control, but workers are spread
    round-robin across two racks whose ToR uplinks carry only 100 Mbit/s
    — every ring edge crosses a rack boundary, so the round's 16
    concurrent transfers share two uplinks (water-filling fair share).
    The fp32 payloads saturate the fabric and slow *each other* down;
    the 1-bit wire barely notices — contention widens the wall-clock gap
    beyond what any isolated link predicts.
``shared-uplink-ring``
    All workers behind one half-duplex 300 Mbit/s shared medium: the
    maximally contended regime (every transfer, both directions, one
    resource).
``two-tier-tor``
    The hierarchical-gossip fabric: nodes of 4 workers on ICI-fast 40
    Gbit/s NICs, contiguous placement (intra-node traffic never leaves
    the rack), each node behind a 200 Mbit/s oversubscribed uplink.  The
    scenario topology is the *slow phase* of a two-tier round: lane
    offsets ``±n_intra`` — worker ``(g, j)`` ships its owned shard to
    ``(g±1, j)`` — so every simulated flow crosses a node boundary and
    contends on the uplinks, while the full-precision intra phase is
    priced analytically on the NIC term (``bench_hierarchical``).
``calibrated-from-bench``
    Links are not datasheet constants but an alpha-beta fit
    (``sim/calibrate.py``) on measured probe times — by default synthetic
    probes of Fig. 1's worst network, or a ``NetworkModel`` JSON emitted
    by ``python -m repro.sim.calibrate`` (pass ``model_path`` or set
    ``REPRO_SIM_NETMODEL``).
``churn-ring``
    Elastic-gossip stress test: 1 GbE ring whose workers crash-restart
    (each round each worker draws a ~3-round outage with probability
    ``outage_p``) and whose messages drop with probability ``drop_p`` —
    the :mod:`repro.sim.faults` catalog exercised end to end.  Pair with
    ``Scenario.with_deadline`` to compare deadline-dropped rounds against
    wait-for-everyone (``bench_elastic``).

Factories take ``n`` so benchmarks can match the scenario to their
worker count; ``get_scenario(name, n=...)`` is the registry entry point
and forwards any extra keyword arguments to the factory (e.g. the
straggler knobs of ``straggler-longtail`` or the churn rates of
``churn-ring``).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional, Tuple

from repro.core.topology import Topology, exponential, ring
from repro.sim.cluster import (ComputeModel, crash_restart, homogeneous,
                               one_straggler)
from repro.sim.contention import (Fabric, oversubscribed_fabric,
                                  shared_medium_fabric)
from repro.sim.faults import FaultSpec
from repro.sim.network import LinkModel, NetworkModel, gbit, mbit

# default local-step cost: ResNet20-scale fwd+bwd on a P100 at batch 128
# (the paper's Fig. 1 workload; bench_walltime uses the same constant)
DEFAULT_COMPUTE_S = 0.05


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Everything one simulation run needs, as a frozen value object.

    ``fabric`` (optional) switches the event engine from isolated
    per-link pricing to shared-resource contention scheduling — see
    :mod:`repro.sim.contention`.  ``network`` stays populated either way:
    it is the isolated-link twin of the same hardware, used by code paths
    that want the uncontended baseline.
    """
    name: str
    topo: Topology
    network: NetworkModel
    compute: ComputeModel
    seed: int = 0
    description: str = ""
    fabric: Optional[Fabric] = None
    faults: Optional[FaultSpec] = None

    def with_compute(self, base_s: float) -> "Scenario":
        """Same scenario, different per-step compute cost (e.g. measured)."""
        comp = dataclasses.replace(self.compute, base_s=base_s)
        return dataclasses.replace(self, compute=comp)

    def with_seed(self, seed: int) -> "Scenario":
        return dataclasses.replace(self, seed=seed)

    def with_faults(self, faults: Optional[FaultSpec]) -> "Scenario":
        """Same scenario, different fault catalog (None clears it)."""
        return dataclasses.replace(self, faults=faults)

    def with_deadline(self, deadline_s: float) -> "Scenario":
        """Deadline-based rounds on top of whatever faults are configured.

        This is the knob ``bench_elastic`` turns: the same scenario with
        and without a deadline isolates deadline-dropping from churn.
        """
        base = self.faults if self.faults is not None else FaultSpec()
        return dataclasses.replace(
            self, faults=dataclasses.replace(base, deadline_s=deadline_s))


def lan_10gbe_ring(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                   seed: int = 0) -> Scenario:
    return Scenario(
        name="lan-10gbe-ring",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=50e-6, beta_Bps=gbit(10.0),
                                         jitter_s=10e-6),
        compute=homogeneous(compute_s),
        seed=seed,
        description="homogeneous 10 GbE datacenter ring (compute-bound)")


def wan_exponential(n: int = 16, compute_s: float = DEFAULT_COMPUTE_S,
                    seed: int = 0) -> Scenario:
    short = LinkModel(alpha_s=20e-3, beta_Bps=mbit(200.0), jitter_s=2e-3)
    long_ = LinkModel(alpha_s=40e-3, beta_Bps=mbit(100.0), jitter_s=4e-3)
    topo = exponential(n)
    # hops of distance >= 4 cross regions: half bandwidth, double latency
    far = {min(o % n, (-o) % n) for o in topo.neighbor_offsets()
           if min(o % n, (-o) % n) >= 4}
    return Scenario(
        name="wan-exponential",
        topo=topo,
        network=NetworkModel(short).with_offset_links(
            {h: long_ for h in far}),
        compute=homogeneous(compute_s),
        seed=seed,
        description="geo-distributed exponential graph; long 2^j hops "
                    "slower (heterogeneous links keyed by offset)")


def straggler_longtail(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                       seed: int = 0, worker: int = 0, slow: float = 4.0,
                       tail_scale: float = 2.0,
                       pareto_shape: float = 1.2) -> Scenario:
    return Scenario(
        name="straggler-longtail",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=0.15e-3,
                                         beta_Bps=gbit(1.0),
                                         jitter_s=30e-6),
        compute=one_straggler(compute_s, worker=worker, slow=slow,
                              tail_scale=tail_scale,
                              pareto_shape=pareto_shape),
        seed=seed,
        description=f"1 GbE ring; worker {worker} is {slow:g}x slower with "
                    "a Pareto long-tail per-step term")


def bandwidth_starved(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                      seed: int = 0) -> Scenario:
    return Scenario(
        name="bandwidth-starved",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=5e-3, beta_Bps=mbit(25.0),
                                         jitter_s=0.5e-3),
        compute=homogeneous(compute_s),
        seed=seed,
        description="25 Mbit/s, 5 ms links: fp32 payloads dominate; the "
                    "1-bit wire's headline scenario")


def oversubscribed_tor(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                       seed: int = 0) -> Scenario:
    """LAN NICs, starved rack uplinks: contention is the whole story.

    Workers sit on two racks round-robin (worker i -> rack i % 2), the
    adversarial placement for a ring: *every* gossip edge crosses a rack
    boundary, so all 2n concurrent transfers of a round squeeze through
    the two 100 Mbit/s ToR uplinks while the 10 GbE NICs sit idle.
    Compare against ``lan-10gbe-ring`` — identical NICs, alpha, jitter
    and compute; the only difference is the shared fabric.
    """
    nic = gbit(10.0)
    return Scenario(
        name="oversubscribed-tor",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=50e-6, beta_Bps=nic,
                                         jitter_s=10e-6),
        compute=homogeneous(compute_s),
        seed=seed,
        fabric=oversubscribed_fabric(n, nic_Bps=nic, uplink_Bps=mbit(100.0),
                                     num_groups=2, interleave=True,
                                     alpha_s=50e-6, jitter_s=10e-6),
        description="10 GbE NICs, two racks with 100 Mbit/s ToR uplinks, "
                    "round-robin placement: every ring edge crosses a "
                    "rack; concurrent fp32 payloads contend on the "
                    "uplinks (water-filling fair share)")


def lan_1gbe_ring(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                  seed: int = 0) -> Scenario:
    """Isolated 1 GbE ring: the uncontended twin of shared-uplink-ring.

    Identical NICs, alpha, jitter and compute — the only difference is
    that transfers do NOT share a medium, so comparing the two isolates
    contention (the pairing ``bench_network_sim``'s contention summary
    and ``tools/check_bench.py`` guard).
    """
    return Scenario(
        name="lan-1gbe-ring",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=0.15e-3,
                                         beta_Bps=gbit(1.0),
                                         jitter_s=20e-6),
        compute=homogeneous(compute_s),
        seed=seed,
        description="isolated 1 GbE ring (no shared fabric): the "
                    "uncontended twin of shared-uplink-ring")


def shared_uplink_ring(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                       seed: int = 0) -> Scenario:
    """One half-duplex shared medium carries every transfer."""
    nic = gbit(1.0)
    return Scenario(
        name="shared-uplink-ring",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=0.15e-3, beta_Bps=nic,
                                         jitter_s=20e-6),
        compute=homogeneous(compute_s),
        seed=seed,
        fabric=shared_medium_fabric(nic_Bps=nic, bus_Bps=mbit(300.0),
                                    alpha_s=0.15e-3, jitter_s=20e-6),
        description="1 GbE NICs behind one half-duplex 300 Mbit/s shared "
                    "medium: all transfers, both directions, contend for "
                    "a single resource")


def two_tier_tor(n: int = 32, compute_s: float = DEFAULT_COMPUTE_S,
                 seed: int = 0, n_intra: int = 4) -> Scenario:
    """Two-tier hierarchy fabric: ICI-fast nodes, oversubscribed uplinks.

    ``n`` workers in contiguous nodes of ``n_intra`` (worker ``w = g *
    n_intra + j``, matching ``HierarchicalTopology``'s flat index), 40
    Gbit/s NICs inside a node, one 200 Mbit/s ToR uplink per node.  The
    scenario's topology is the slow-axis *lane* graph of a tiered round:
    offsets ``±n_intra``, i.e. member ``j`` of node ``g`` exchanges with
    member ``j`` of nodes ``g±1`` — every flow crosses a node boundary,
    so a round's ``2 * n`` shard transfers contend on the ``n/n_intra``
    uplinks (water-filling).  Single-tier baselines on the same fabric
    reuse it with a flat ring topology (contiguous placement is the
    *favorable* placement for them: only seam edges cross).
    """
    if n % n_intra:
        raise ValueError(f"n_intra must divide n: {n} % {n_intra}")
    nic = gbit(40.0)
    lanes = Topology("node-lanes", n, (-n_intra, 0, n_intra),
                     (1 / 3, 1 / 3, 1 / 3))
    return Scenario(
        name="two-tier-tor",
        topo=lanes,
        network=NetworkModel.homogeneous(alpha_s=10e-6, beta_Bps=nic,
                                         jitter_s=5e-6),
        compute=homogeneous(compute_s),
        seed=seed,
        fabric=oversubscribed_fabric(n, nic_Bps=nic, uplink_Bps=mbit(200.0),
                                     num_groups=n // n_intra,
                                     interleave=False,
                                     alpha_s=10e-6, jitter_s=5e-6),
        description="nodes of 4 on 40 Gbit/s ICI behind 200 Mbit/s ToR "
                    "uplinks, contiguous placement; topology = the "
                    "slow-axis shard lanes (offsets +/- n_intra) of a "
                    "two-tier gossip round")


# synthetic calibration probes: Fig. 1's worst network (100 Mbit/s, 5 ms)
# measured at the wire sizes the codec sweep actually ships
_CAL_TRUE_ALPHA_S = 2 * 5e-3            # two messages' latency per round
_CAL_TRUE_BETA_BPS = 100e6 / 8.0
_CAL_PROBE_SIZES = (28_752, 230_016, 230_112, 575_040, 920_064, 2_300_160)


def calibrated_from_bench(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                          seed: int = 0,
                          model_path: Optional[str] = None) -> Scenario:
    """Links fitted from measurements, not quoted from a datasheet.

    If ``model_path`` (or ``$REPRO_SIM_NETMODEL``) names a ``NetworkModel``
    JSON emitted by ``python -m repro.sim.calibrate``, load it (a named
    path that does not exist raises — no silent fallback); otherwise
    self-calibrate deterministically on synthetic probes of Fig. 1's worst
    network — the fit must recover alpha/beta within 5%
    (``tests/test_contention.py``), so the scenario's behavior matches the
    closed-form constants it was probed from.
    """
    from repro.sim import calibrate as CAL

    path = model_path or os.environ.get("REPRO_SIM_NETMODEL", "")
    if path:
        # an explicitly named model must exist — a typo'd path silently
        # falling back to synthetic constants would defeat calibration
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"calibrated-from-bench: network model {path!r} not found "
                "(from model_path or $REPRO_SIM_NETMODEL)")
        net = CAL.load_network_model(path)
        source = os.path.basename(path)
    else:
        fit = CAL.fit_link(CAL.synthetic_samples(
            _CAL_TRUE_ALPHA_S, _CAL_TRUE_BETA_BPS, _CAL_PROBE_SIZES,
            seed=seed))
        net = NetworkModel(fit.link())
        source = "synthetic Fig.1 probes"
    return Scenario(
        name="calibrated-from-bench",
        topo=ring(n),
        network=net,
        compute=homogeneous(compute_s),
        seed=seed,
        description=f"alpha-beta links least-squares fitted ({source}) "
                    "via sim/calibrate.py instead of datasheet constants")


def churn_ring(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
               seed: int = 0, outage_p: float = 0.05,
               outage_rounds: int = 3, drop_p: float = 0.01) -> Scenario:
    """Crash-restart churn plus message loss on a 1 GbE ring.

    Expected unavailability per worker is about ``outage_p *
    outage_rounds`` (~15% at the defaults); layer a round deadline on top
    with :meth:`Scenario.with_deadline` to get the full elastic regime.
    """
    return Scenario(
        name="churn-ring",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=0.15e-3,
                                         beta_Bps=gbit(1.0),
                                         jitter_s=20e-6),
        compute=crash_restart(compute_s, outage_p=outage_p,
                              outage_rounds=outage_rounds),
        seed=seed,
        faults=FaultSpec(drop_p=drop_p),
        description=f"1 GbE ring under churn: {outage_rounds}-round "
                    f"crash-restart outages at p={outage_p:g} per step, "
                    f"messages lost at p={drop_p:g}")


_REGISTRY: Dict[str, Callable[..., Scenario]] = {
    "lan-10gbe-ring": lan_10gbe_ring,
    "wan-exponential": wan_exponential,
    "straggler-longtail": straggler_longtail,
    "bandwidth-starved": bandwidth_starved,
    "lan-1gbe-ring": lan_1gbe_ring,
    "oversubscribed-tor": oversubscribed_tor,
    "two-tier-tor": two_tier_tor,
    "shared-uplink-ring": shared_uplink_ring,
    "calibrated-from-bench": calibrated_from_bench,
    "churn-ring": churn_ring,
}


def list_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_scenario(name: str, n: Optional[int] = None,
                 compute_s: Optional[float] = None,
                 seed: int = 0, **kwargs) -> Scenario:
    """Build a registered scenario; extra kwargs reach the factory.

    The pass-through is what lets callers tune factory-specific knobs —
    ``get_scenario("straggler-longtail", slow=8.0)`` or
    ``get_scenario("churn-ring", outage_p=0.1)`` — without the registry
    enumerating every factory's signature.  Unknown knobs fail loudly as
    a ``TypeError`` from the factory itself.
    """
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"available: {list(list_scenarios())}") from None
    kw = dict(kwargs)
    kw["seed"] = seed
    if n is not None:
        kw["n"] = n
    if compute_s is not None:
        kw["compute_s"] = compute_s
    return factory(**kw)


def scenario_from_netconfig(name: str, bandwidth_bps: float, latency_s: float,
                            topo: Topology, compute_s: float,
                            seed: int = 0) -> Scenario:
    """Bridge from ``benchmarks.common.NetworkConfig``-style constants.

    ``bandwidth_bps`` is in bits/s (how the benchmark tables quote links);
    jitter is zero so the prediction is directly comparable with the
    closed-form analytic model it replaces.
    """
    return Scenario(
        name=name, topo=topo,
        network=NetworkModel.homogeneous(alpha_s=latency_s,
                                         beta_Bps=bandwidth_bps / 8.0),
        compute=homogeneous(compute_s), seed=seed,
        description=f"from NetworkConfig {name}")
