"""Named simulation scenarios: topology x network x cluster presets.

A :class:`Scenario` bundles everything the event engine needs — a
circulant :class:`~repro.core.topology.Topology`, a
:class:`~repro.sim.network.NetworkModel`, a
:class:`~repro.sim.cluster.ComputeModel`, and the seed all deterministic
draws key off.  The catalog covers the regimes the paper's wall-clock
claims span:

``lan-10gbe-ring``
    Homogeneous datacenter baseline: 10 GbE ring, microsecond latency.
    Compute-bound — codecs barely matter; the control scenario.
``wan-exponential``
    Geo-distributed exponential graph: 200 Mbit/s links, 20 ms base
    latency, and the long ``2^j`` hops (hop distance >= 4) at half
    bandwidth and double latency — heterogeneous links keyed by topology
    offsets.
``straggler-longtail``
    1 GbE ring with one chronically slow worker carrying a Pareto
    (shape 1.2, unbounded-variance) per-step tail: the regime where
    synchronous rounds collapse to the slowest worker and the async
    AD-PSGD loop shines.
``bandwidth-starved``
    25 Mbit/s, 5 ms links (Fig. 1's worst network, further starved):
    fp32 payloads dominate the round; Moniqua's 1-bit wire is the
    headline win here.

Factories take ``n`` so benchmarks can match the scenario to their
worker count; ``get_scenario(name, n=...)`` is the registry entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.topology import Topology, exponential, ring
from repro.sim.cluster import ComputeModel, homogeneous, one_straggler
from repro.sim.network import LinkModel, NetworkModel, gbit, mbit

# default local-step cost: ResNet20-scale fwd+bwd on a P100 at batch 128
# (the paper's Fig. 1 workload; bench_walltime uses the same constant)
DEFAULT_COMPUTE_S = 0.05


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Everything one simulation run needs, as a frozen value object."""
    name: str
    topo: Topology
    network: NetworkModel
    compute: ComputeModel
    seed: int = 0
    description: str = ""

    def with_compute(self, base_s: float) -> "Scenario":
        """Same scenario, different per-step compute cost (e.g. measured)."""
        comp = dataclasses.replace(self.compute, base_s=base_s)
        return dataclasses.replace(self, compute=comp)

    def with_seed(self, seed: int) -> "Scenario":
        return dataclasses.replace(self, seed=seed)


def lan_10gbe_ring(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                   seed: int = 0) -> Scenario:
    return Scenario(
        name="lan-10gbe-ring",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=50e-6, beta_Bps=gbit(10.0),
                                         jitter_s=10e-6),
        compute=homogeneous(compute_s),
        seed=seed,
        description="homogeneous 10 GbE datacenter ring (compute-bound)")


def wan_exponential(n: int = 16, compute_s: float = DEFAULT_COMPUTE_S,
                    seed: int = 0) -> Scenario:
    short = LinkModel(alpha_s=20e-3, beta_Bps=mbit(200.0), jitter_s=2e-3)
    long_ = LinkModel(alpha_s=40e-3, beta_Bps=mbit(100.0), jitter_s=4e-3)
    topo = exponential(n)
    # hops of distance >= 4 cross regions: half bandwidth, double latency
    far = {min(o % n, (-o) % n) for o in topo.neighbor_offsets()
           if min(o % n, (-o) % n) >= 4}
    return Scenario(
        name="wan-exponential",
        topo=topo,
        network=NetworkModel(short).with_offset_links(
            {h: long_ for h in far}),
        compute=homogeneous(compute_s),
        seed=seed,
        description="geo-distributed exponential graph; long 2^j hops "
                    "slower (heterogeneous links keyed by offset)")


def straggler_longtail(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                       seed: int = 0) -> Scenario:
    return Scenario(
        name="straggler-longtail",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=0.15e-3,
                                         beta_Bps=gbit(1.0),
                                         jitter_s=30e-6),
        compute=one_straggler(compute_s, worker=0, slow=4.0,
                              tail_scale=2.0, pareto_shape=1.2),
        seed=seed,
        description="1 GbE ring; worker 0 is 4x slower with a Pareto "
                    "long-tail per-step term")


def bandwidth_starved(n: int = 8, compute_s: float = DEFAULT_COMPUTE_S,
                      seed: int = 0) -> Scenario:
    return Scenario(
        name="bandwidth-starved",
        topo=ring(n),
        network=NetworkModel.homogeneous(alpha_s=5e-3, beta_Bps=mbit(25.0),
                                         jitter_s=0.5e-3),
        compute=homogeneous(compute_s),
        seed=seed,
        description="25 Mbit/s, 5 ms links: fp32 payloads dominate; the "
                    "1-bit wire's headline scenario")


_REGISTRY: Dict[str, Callable[..., Scenario]] = {
    "lan-10gbe-ring": lan_10gbe_ring,
    "wan-exponential": wan_exponential,
    "straggler-longtail": straggler_longtail,
    "bandwidth-starved": bandwidth_starved,
}


def list_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_scenario(name: str, n: Optional[int] = None,
                 compute_s: Optional[float] = None,
                 seed: int = 0) -> Scenario:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"available: {list(list_scenarios())}") from None
    kw = {"seed": seed}
    if n is not None:
        kw["n"] = n
    if compute_s is not None:
        kw["compute_s"] = compute_s
    return factory(**kw)


def scenario_from_netconfig(name: str, bandwidth_bps: float, latency_s: float,
                            topo: Topology, compute_s: float,
                            seed: int = 0) -> Scenario:
    """Bridge from ``benchmarks.common.NetworkConfig``-style constants.

    ``bandwidth_bps`` is in bits/s (how the benchmark tables quote links);
    jitter is zero so the prediction is directly comparable with the
    closed-form analytic model it replaces.
    """
    return Scenario(
        name=name, topo=topo,
        network=NetworkModel.homogeneous(alpha_s=latency_s,
                                         beta_Bps=bandwidth_bps / 8.0),
        compute=homogeneous(compute_s), seed=seed,
        description=f"from NetworkConfig {name}")
