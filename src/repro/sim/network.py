"""Per-edge link models: the alpha-beta cost ``T = alpha + bytes / beta``.

A :class:`LinkModel` prices one point-to-point transfer with the classic
alpha-beta (latency-bandwidth) model plus an optional jitter term:

    T(bytes) = alpha + bytes / beta + jitter * u,   u ~ U[0, 1)

``alpha`` is the per-message base latency in seconds, ``beta`` the link
bandwidth in *bytes per second* (so the formula reads literally), and the
jitter draw is deterministic — see :func:`sim_uniform` below.

A :class:`NetworkModel` maps edges of a circulant
:class:`~repro.core.topology.Topology` to links, with three levels of
specificity (most specific wins):

1. ``per_edge``   — an explicit undirected worker pair ``(i, j)``;
2. ``per_offset`` — keyed by the *hop distance* ``min(o, n - o)`` of the
   topology offset connecting the pair (how the WAN scenarios make long
   exponential-graph hops slower than ring-neighbor hops);
3. ``default``    — everything else.

Determinism contract: nothing in ``repro.sim`` owns mutable RNG state.
Every stochastic draw is :func:`sim_uniform` — a splitmix64 counter hash
of (seed, stream, counters...) — so the same (scenario, seed) always
produces the same event trace (``tests/test_sim.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple

_MASK64 = (1 << 64) - 1

# stream tags keeping independent draws independent (arbitrary constants)
STREAM_NET = 0x5E1
STREAM_COMPUTE = 0xC0
STREAM_EDGE_CHOICE = 0xED6
STREAM_GRAD = 0x64AD
STREAM_PAIR = 0xBA12
STREAM_DROP = 0xD20      # per-message loss draws (sim/faults.py)
STREAM_OUTAGE = 0x0FF    # crash-restart outage onsets (sim/cluster.py)


def _mix64(z: int) -> int:
    """splitmix64 finalizer: bijective avalanche on 64-bit ints."""
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9 & _MASK64
    z = (z ^ (z >> 27)) * 0x94D049BB133111EB & _MASK64
    return (z ^ (z >> 31)) & _MASK64


def sim_uniform(seed: int, *stream: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, stream counters).

    Pure-integer counter hash (no RNG state to thread), so any sim module
    can draw independent, reproducible randomness keyed by semantic
    counters like (worker, step) or (event index).
    """
    z = (int(seed) + 0x9E3779B97F4A7C15) & _MASK64
    for s in stream:
        z = _mix64((z + (int(s) << 1 | 1) * 0x9E3779B97F4A7C15) & _MASK64)
    return (_mix64(z) >> 11) * (1.0 / (1 << 53))


def sim_randint(seed: int, hi: int, *stream: int) -> int:
    """Deterministic integer in [0, hi) (hi >= 1) from the same hash."""
    return min(int(sim_uniform(seed, *stream) * hi), hi - 1)


def gbit(x: float) -> float:
    """x gigabit/s -> bytes/s (link specs quote bits, the model wants B/s)."""
    return x * 1e9 / 8.0


def mbit(x: float) -> float:
    return x * 1e6 / 8.0


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One point-to-point link under the alpha-beta cost model."""
    alpha_s: float              # base latency per message (s)
    beta_Bps: float             # bandwidth (bytes / s)
    jitter_s: float = 0.0       # max additional latency, uniform in [0, j)

    def __post_init__(self):
        if self.beta_Bps <= 0:
            raise ValueError(f"beta_Bps must be positive, got {self.beta_Bps}")

    def to_dict(self) -> dict:
        return {"alpha_s": self.alpha_s, "beta_Bps": self.beta_Bps,
                "jitter_s": self.jitter_s}

    @staticmethod
    def from_dict(d: Mapping) -> "LinkModel":
        return LinkModel(alpha_s=float(d["alpha_s"]),
                         beta_Bps=float(d["beta_Bps"]),
                         jitter_s=float(d.get("jitter_s", 0.0)))

    def transfer_seconds(self, nbytes: int, u: float = 0.0) -> float:
        """``alpha + bytes/beta`` plus the jitter draw ``jitter * u``."""
        return self.alpha_s + nbytes / self.beta_Bps + self.jitter_s * u

    def occupancy_seconds(self, nbytes: int) -> float:
        """Sender-side NIC occupancy: the bandwidth term alone.

        Back-to-back sends from one worker serialize on this term while
        their alpha (propagation) components overlap — how the sync round
        simulator schedules a worker's per-neighbor payloads.
        """
        return nbytes / self.beta_Bps


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Edge -> LinkModel map over an n-worker circulant topology."""
    default: LinkModel
    per_offset: Tuple[Tuple[int, LinkModel], ...] = ()
    per_edge: Tuple[Tuple[Tuple[int, int], LinkModel], ...] = ()

    @staticmethod
    def homogeneous(alpha_s: float, beta_Bps: float,
                    jitter_s: float = 0.0) -> "NetworkModel":
        return NetworkModel(LinkModel(alpha_s, beta_Bps, jitter_s))

    def with_offset_links(self, links: Mapping[int, LinkModel]
                          ) -> "NetworkModel":
        return dataclasses.replace(
            self, per_offset=tuple(sorted(links.items())))

    def link(self, src: int, dst: int, n: int) -> LinkModel:
        """Resolve the link for the (undirected) edge src—dst."""
        a, b = sorted((src % n, dst % n))
        for (i, j), lm in self.per_edge:
            if (min(i % n, j % n), max(i % n, j % n)) == (a, b):
                return lm
        hop = min((dst - src) % n, (src - dst) % n)
        for o, lm in self.per_offset:
            if o == hop:
                return lm
        return self.default

    def transfer_seconds(self, src: int, dst: int, n: int, nbytes: int,
                         u: float = 0.0) -> float:
        return self.link(src, dst, n).transfer_seconds(nbytes, u)

    def to_dict(self) -> dict:
        """JSON-friendly form (``sim/calibrate.py`` emits and loads these)."""
        return {
            "default": self.default.to_dict(),
            "per_offset": [[o, lm.to_dict()] for o, lm in self.per_offset],
            "per_edge": [[list(e), lm.to_dict()] for e, lm in self.per_edge],
        }

    @staticmethod
    def from_dict(d: Mapping) -> "NetworkModel":
        return NetworkModel(
            default=LinkModel.from_dict(d["default"]),
            per_offset=tuple((int(o), LinkModel.from_dict(lm))
                             for o, lm in d.get("per_offset", ())),
            per_edge=tuple(((int(e[0]), int(e[1])), LinkModel.from_dict(lm))
                           for e, lm in d.get("per_edge", ())))


# ---------------------------------------------------------------------------
# Reference hardware links, shared with the roofline analysis.
# ---------------------------------------------------------------------------

# TPU v5e inter-chip interconnect, per link.  analysis/roofline.py derives
# its collective term from this model (alpha ~ 0: the roofline charges pure
# bandwidth; per-message latency belongs to the event simulator).
TPU_V5E_ICI = LinkModel(alpha_s=0.0, beta_Bps=50e9)

# Datacenter ethernet ballparks used by the scenario catalog.
ETH_10G = LinkModel(alpha_s=50e-6, beta_Bps=gbit(10.0), jitter_s=10e-6)
ETH_1G = LinkModel(alpha_s=0.15e-3, beta_Bps=gbit(1.0), jitter_s=20e-6)
