"""Deterministic event-driven engine: bytes ledger -> wall clock.

Two execution modes, matching the repo's two communication regimes:

**Synchronous rounds** (D-PSGD / D2 / Moniqua — everything that calls
``CommEngine.mix``).  One round per worker ``i`` at step ``k``:

    ready(i) = max( compute(i),
                    max_{j in in-nbrs(i)}  depart(j -> i) + alpha + jitter )
    round_k  = max_i ready(i)                       (bulk-synchronous barrier)

where ``depart(j -> i)`` is when the payload for ``i`` clears ``j``'s NIC:
a sender's per-neighbor payloads serialize on the bandwidth term
(``LinkModel.occupancy_seconds``) while their latencies overlap — so on a
homogeneous ring the round time reduces to the familiar

    round = compute + m * bytes/beta + alpha

i.e. round time = max over workers of compute + slowest-neighbor transfer.
The payload size comes straight from the ``CommEngine`` bytes ledger
(``bytes_per_round / num_neighbors``), which is what makes the simulator's
wall clock composable with any codec the engine can put on the wire.

**Contended fabrics.**  A scenario may carry a
:class:`~repro.sim.contention.Fabric` — shared NIC/switch resources with a
bandwidth-sharing discipline.  Both modes then stop pricing transfers
independently: the sync round hands ALL its concurrent transfers to the
fluid solver (:func:`~repro.sim.contention.schedule_transfers`), and the
async loop drives a live :class:`~repro.sim.contention.FlowScheduler`,
re-solving rates whenever a flow starts or finishes (stale completion
predictions are detected by the scheduler epoch and discarded).  With no
fabric the PR-2 isolated-link pricing is bit-for-bit unchanged.

**Asynchronous AD-PSGD** (Algorithm 3 / the analysis model of
``core/adpsgd.py``).  Workers free-run: compute a gradient on a snapshot of
their model, gossip with one deterministic-randomly chosen neighbor (the
transfer priced by the link model), apply the now-stale gradient, repeat.
The passive peer is never blocked (AD-PSGD's wait-free design), so the
loop cannot deadlock however extreme the stragglers; staleness — how many
times a worker's model changed between gradient snapshot and gradient
application — is tracked per update.  :func:`replay_adpsgd` runs the same
event loop while *applying the actual mixing math* through
``CommEngine.pair_average`` edge by edge, so predicted wall clock and
realized convergence come from one run.

**Fault injection** (:mod:`repro.sim.faults`).  A scenario may carry a
``FaultSpec`` (or one is passed per call): worker churn removes workers
from rounds (``OFFLINE`` events, presence renormalized), per-message loss
kills individual payloads (``MSGDROP``), and a round deadline stops the
barrier from waiting for stragglers — a worker whose compute overruns it
is dropped (``DROPPED``), a payload arriving past it is dead (``LATE``),
and the barrier releases at ``t_start + deadline_s`` whenever anything
was late, else at the last *participant*'s ready time.  Per-round
participation masks land in :attr:`SimTrace.presence` /
:attr:`SimTrace.participation` — exactly the mask
``CommEngine.mix(presence=...)`` renormalizes over.  With no faults the
code path, events and fingerprint are bit-identical to the pre-elastic
engine; fault draws live on their own hash streams, so adding faults
never perturbs jitter or straggler draws either.

Determinism: every stochastic choice (jitter, straggler tails, edge
choice, outage onsets, message loss) is a counter hash of
(scenario.seed, semantic counters) — replays are event-for-event
identical, which :meth:`SimTrace.fingerprint` makes cheap to assert.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.sim.faults import presence_of
from repro.sim.network import (STREAM_EDGE_CHOICE, STREAM_NET, sim_randint,
                               sim_uniform)

# event kinds, in the order they appear inside one sync round
COMPUTE = "compute"      # worker finished local grad/update work
TRANSFER = "transfer"    # payload worker -> peer fully arrived
ROUND = "round"          # barrier: every worker finished the round
GOSSIP = "gossip"        # async: pair exchange (worker, peer) completed
UPDATE = "update"        # async: worker applied its (stale) gradient
FLOW = "_flow"           # heap-internal: contended-flow completion candidate
                         # (never appears in the trace; see fabric handling)
# elastic-round kinds (fault injection; all enter the fingerprint)
OFFLINE = "offline"      # worker absent this round (churn)
DROPPED = "dropped"      # present worker overran the round deadline
MSGDROP = "msgdrop"      # payload lost on the wire (drop_p draw)
LATE = "late"            # payload arrived after the round deadline


@dataclasses.dataclass(frozen=True)
class SimEvent:
    """One timestamped event; the trace is the ordered tuple of these."""
    t: float
    kind: str
    worker: int
    peer: int = -1
    step: int = -1
    nbytes: int = 0

    def row(self) -> Tuple[float, str, int, int, int, int]:
        return (round(self.t, 12), self.kind, self.worker, self.peer,
                self.step, self.nbytes)


@dataclasses.dataclass
class SimTrace:
    """Result of one simulation: the event list plus aggregate predictions."""
    events: List[SimEvent]
    total_seconds: float
    bytes_on_wire: int
    round_seconds: List[float] = dataclasses.field(default_factory=list)
    staleness: List[int] = dataclasses.field(default_factory=list)
    # elastic rounds only (empty on unfaulted runs): per-round fraction of
    # workers that made the round, and the exact participation masks —
    # what ``CommEngine.mix(presence=...)`` renormalizes over on replay
    participation: List[float] = dataclasses.field(default_factory=list)
    presence: List[Tuple[int, ...]] = dataclasses.field(default_factory=list)

    @property
    def mean_round_seconds(self) -> float:
        if not self.round_seconds:
            return 0.0
        return sum(self.round_seconds) / len(self.round_seconds)

    @property
    def participation_mean(self) -> float:
        """Mean per-round participation; 1.0 when no faults were injected."""
        if not self.participation:
            return 1.0
        return sum(self.participation) / len(self.participation)

    @property
    def staleness_max(self) -> int:
        return max(self.staleness) if self.staleness else 0

    @property
    def staleness_mean(self) -> float:
        if not self.staleness:
            return 0.0
        return sum(self.staleness) / len(self.staleness)

    def cumulative_seconds(self) -> List[float]:
        """Wall clock at the end of each round (sync traces)."""
        out, acc = [], 0.0
        for r in self.round_seconds:
            acc += r
            out.append(acc)
        return out

    def fingerprint(self) -> str:
        """Stable digest of the full event trace (determinism tests)."""
        h = hashlib.sha256()
        for e in self.events:
            h.update(repr(e.row()).encode())
        return h.hexdigest()

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)

    def to_chrome(self, pid: int = 1, process_name: str = "sim"
                  ) -> Dict[str, Any]:
        """Chrome-trace JSON of this timeline (one track per worker, plus a
        barrier track) — same format as the host-side
        :class:`~repro.obs.trace.SpanRecorder`, so
        :func:`~repro.obs.trace.merge_chrome_traces` puts a measured run
        and its sim prediction side by side in one Perfetto view."""
        from repro.obs.trace import sim_trace_to_chrome
        return sim_trace_to_chrome(self, pid=pid, process_name=process_name)


# ---------------------------------------------------------------------------
# Synchronous-round mode.
# ---------------------------------------------------------------------------

def simulate_sync_rounds(scenario, bytes_per_neighbor: int, num_rounds: int,
                         faults=None) -> SimTrace:
    """Wall-clock for ``num_rounds`` bulk-synchronous gossip rounds.

    ``bytes_per_neighbor`` is one worker's payload to ONE neighbor per
    round — ``CommEngine.bytes_per_round(X) / len(topo.neighbor_offsets())``.
    The trace carries per-round barrier times (``round_seconds``) so a
    loss-vs-step trajectory converts to loss-vs-wall-clock by indexing
    :meth:`SimTrace.cumulative_seconds`.

    ``faults`` (a :class:`~repro.sim.faults.FaultSpec`; defaults to
    ``scenario.faults``) turns on elastic rounds — module docstring for
    the semantics.  Presence is failure-detector knowledge: dead edges
    send nothing (no NIC occupancy, no bytes); sampled drops and late
    arrivals DO put their bytes on the wire — they were sent, then lost.
    Participation masks per round land on the trace.
    """
    topo, net, comp, seed = (scenario.topo, scenario.network,
                             scenario.compute, scenario.seed)
    fabric = getattr(scenario, "fabric", None)
    if faults is None:
        faults = getattr(scenario, "faults", None)
    deadline = faults.deadline_s if faults is not None else None
    n = topo.n
    offsets = topo.neighbor_offsets()
    events: List[SimEvent] = []
    round_seconds: List[float] = []
    participation: List[float] = []
    presence: List[Tuple[int, ...]] = []
    total_bytes = 0
    t_start = 0.0
    for k in range(num_rounds):
        pres = presence_of(faults, comp, n, k, seed)
        up = [True] * n if pres is None else [bool(b) for b in pres]
        compute = [comp.compute_seconds(i, k, seed) if up[i] else 0.0
                   for i in range(n)]
        for i in range(n):
            if up[i]:
                events.append(SimEvent(t_start + compute[i], COMPUTE, i,
                                       step=k))
            else:
                events.append(SimEvent(t_start, OFFLINE, i, step=k))
        # participants: present AND own compute met the deadline; a worker
        # still computing at the deadline is dropped from the round (its
        # model takes the identity mix), and the barrier fires at the
        # deadline because its peers waited that long for it
        part = list(up)
        late = False
        if deadline is not None:
            for i in range(n):
                if up[i] and compute[i] > deadline:
                    part[i] = False
                    late = True
                    events.append(SimEvent(t_start + deadline, DROPPED, i,
                                           step=k))
        # arrival[i] accumulates the latest in-payload; senders serialize
        # their per-neighbor payloads on the NIC bandwidth term
        ready = [t_start + compute[i] for i in range(n)]

        def _deliver(j, dst, arrive):
            """Classify one payload's arrival; returns ready-time or None."""
            nonlocal total_bytes, late
            total_bytes += bytes_per_neighbor
            if faults is not None and faults.message_dropped(k, j, dst,
                                                             seed):
                events.append(SimEvent(arrive, MSGDROP, j, peer=dst, step=k,
                                       nbytes=bytes_per_neighbor))
                return None
            if deadline is not None and arrive > t_start + deadline:
                events.append(SimEvent(arrive, LATE, j, peer=dst, step=k,
                                       nbytes=bytes_per_neighbor))
                late = True
                return None
            events.append(SimEvent(arrive, TRANSFER, j, peer=dst, step=k,
                                   nbytes=bytes_per_neighbor))
            return arrive

        if fabric is not None:
            # contended fabric: the round's transfers share NIC / switch
            # capacity; the fluid solver prices them jointly
            from repro.sim.contention import schedule_transfers
            specs = [(t_start + compute[j], j, (j - o) % n,
                      bytes_per_neighbor)
                     for j in range(n) for o in offsets
                     if part[j] and part[(j - o) % n]]
            finishes = schedule_transfers(fabric, n, specs)
            for (_, j, dst, nb), fin in zip(specs, finishes):
                u = sim_uniform(seed, STREAM_NET, k, j, dst)
                arrive = _deliver(j, dst, fin + fabric.alpha_s
                                  + fabric.jitter_s * u)
                if arrive is not None:
                    ready[dst] = max(ready[dst], arrive)
        else:
            for j in range(n):
                if not part[j]:
                    continue
                nic_free = t_start + compute[j]
                for o in offsets:
                    dst = (j - o) % n   # i = j - o receives FROM j = i + o
                    if not part[dst]:
                        continue        # dead edge: nothing enters the NIC
                    link = net.link(j, dst, n)
                    nic_free += link.occupancy_seconds(bytes_per_neighbor)
                    u = sim_uniform(seed, STREAM_NET, k, j, dst)
                    arrive = _deliver(j, dst, nic_free + link.alpha_s
                                      + link.jitter_s * u)
                    if arrive is not None:
                        ready[dst] = max(ready[dst], arrive)
        if deadline is not None and late:
            t_end = t_start + deadline
        else:
            pready = [ready[i] for i in range(n) if part[i]]
            t_end = max(pready) if pready else (
                t_start + (deadline if deadline is not None else 0.0))
        events.append(SimEvent(t_end, ROUND, -1, step=k))
        round_seconds.append(t_end - t_start)
        if faults is not None or pres is not None:
            participation.append(sum(part) / n)
            presence.append(tuple(int(b) for b in part))
        t_start = t_end
    return SimTrace(events=events, total_seconds=t_start,
                    bytes_on_wire=total_bytes, round_seconds=round_seconds,
                    participation=participation, presence=presence)


# ---------------------------------------------------------------------------
# Asynchronous AD-PSGD mode.
# ---------------------------------------------------------------------------

def simulate_async_gossip(
    scenario,
    bytes_per_exchange: int,
    num_updates: int,
    on_gossip: Optional[Callable[[int, int, int], None]] = None,
    on_update: Optional[Callable[[int, int, int], None]] = None,
    faults=None,
    on_drop: Optional[Callable[[int, int, int], None]] = None,
) -> SimTrace:
    """Event loop for AD-PSGD: one gossip + one stale gradient per update.

    Each worker cycles compute -> gossip(random incident edge) -> apply.
    Exactly ``num_updates`` update events (and exactly one gossip each) are
    processed in deterministic time order; ties break on a monotonic
    sequence number, never on worker identity.  Callbacks:

    * ``on_gossip(i, j, gossip_idx)`` — the edge exchange completed; the
      caller mutates its models here (``replay_adpsgd`` routes this to
      ``CommEngine.pair_average``).
    * ``on_update(i, local_step, staleness)`` — worker ``i`` applies the
      gradient snapshot taken ``staleness`` model-versions ago.

    ``bytes_per_exchange`` is ONE endpoint's payload; a pair exchange
    ships it in both directions (``pair_average`` encodes both models),
    so each gossip puts ``2 * bytes_per_exchange`` on the wire while the
    transfer time stays one payload's worth — the two payloads cross
    concurrently on the full-duplex link.

    The passive peer never blocks, so straggler-heavy scenarios slow the
    straggler's own update rate but cannot deadlock the loop (contract
    tested in ``tests/test_sim.py``).

    Faults: the loop is wait-free, so of the :class:`FaultSpec` catalog
    only ``drop_p`` applies (deadlines guard barriers the loop doesn't
    have; churn is a compute-model concern here).  A dropped exchange
    ships its bytes (sent, then lost), mixes nothing, and fires
    ``on_drop(i, j, idx)`` instead of ``on_gossip`` — the worker still
    applies its stale gradient.  Loss draws key on the gossip index
    (``STREAM_DROP``), so replays lose the same exchanges.
    """
    topo, net, comp, seed = (scenario.topo, scenario.network,
                             scenario.compute, scenario.seed)
    fabric = getattr(scenario, "fabric", None)
    if faults is None:
        faults = getattr(scenario, "faults", None)
    n = topo.n
    offsets = [o % n for o in topo.neighbor_offsets()]
    if not offsets:
        raise ValueError("async gossip needs a topology with neighbors")
    events: List[SimEvent] = []
    heap: List[Tuple] = []                # (time, seq, kind, worker[, extra])
    seq = 0
    # per-worker state: model version (bumped by every gossip touching the
    # worker and every applied update) and the version at gradient snapshot
    version = [0] * n
    snap_version = [0] * n
    local_step = [0] * n
    # worker -> (peer, lost?) of the in-flight gossip; the loss draw is
    # taken at launch, keyed by the gossip index
    pending_peer: Dict[int, Tuple[int, bool]] = {}
    staleness: List[int] = []
    total_bytes = 0
    gossip_idx = 0
    updates_done = 0

    # contended-fabric state: a live fluid scheduler; each gossip g is two
    # directed flows (2g: i->j, 2g+1: j->i) crossing the full-duplex fabric
    # concurrently.  Flow-completion predictions go on the heap tagged with
    # the scheduler epoch; any start/finish re-solves rates and bumps the
    # epoch, so stale predictions are recognized and dropped on pop.
    sched = None
    if fabric is not None:
        from repro.sim.contention import FlowScheduler
        sched = FlowScheduler(fabric, n)
    flows_left: Dict[int, int] = {}       # gossip -> directed flows in flight
    gossip_of: Dict[int, Tuple[int, int]] = {}    # gossip -> (initiator, peer)

    def _push_flow_etas():
        nonlocal seq
        for fid in sched.active:
            heapq.heappush(heap, (sched.eta(fid), seq, FLOW, fid,
                                  sched.epoch))
            seq += 1

    for i in range(n):
        dt = comp.compute_seconds(i, 0, seed)
        heapq.heappush(heap, (dt, seq, COMPUTE, i))
        seq += 1
        snap_version[i] = version[i]

    t_now = 0.0
    while updates_done < num_updates and heap:
        t_now, _, kind, i, *extra = heapq.heappop(heap)
        if kind == FLOW:
            if extra[0] != sched.epoch:
                continue                  # rates changed since prediction
            fid = i
            sched.finish(t_now, fid)
            _push_flow_etas()
            g = fid // 2
            flows_left[g] -= 1
            if flows_left[g] == 0:
                del flows_left[g]
                gi, gj = gossip_of.pop(g)
                u = sim_uniform(seed, STREAM_NET, g, gi, gj)
                arrive = t_now + fabric.alpha_s + fabric.jitter_s * u
                heapq.heappush(heap, (arrive, seq, GOSSIP, gi))
                seq += 1
            continue
        if kind == COMPUTE:
            # gradient ready; gossip on a deterministic-random incident edge
            o = offsets[sim_randint(seed, len(offsets), STREAM_EDGE_CHOICE,
                                    i, local_step[i])]
            j = (i + o) % n
            if sched is not None:
                # both directions enter the shared fabric now; the gossip
                # completes when the slower flow drains (+ alpha, jitter)
                sched.start(t_now, 2 * gossip_idx, i, j, bytes_per_exchange)
                sched.start(t_now, 2 * gossip_idx + 1, j, i,
                            bytes_per_exchange)
                flows_left[gossip_idx] = 2
                gossip_of[gossip_idx] = (i, j)
                _push_flow_etas()
            else:
                u = sim_uniform(seed, STREAM_NET, gossip_idx, i, j)
                dt = net.transfer_seconds(i, j, n, bytes_per_exchange, u)
                heapq.heappush(heap, (t_now + dt, seq, GOSSIP, i))
                seq += 1
            lost = (faults is not None
                    and faults.message_dropped(gossip_idx, i, j, seed))
            pending_peer[i] = (j, lost)
            events.append(SimEvent(t_now, COMPUTE, i, peer=j,
                                   step=local_step[i]))
            gossip_idx += 1
        elif kind == GOSSIP:
            j, lost = pending_peer.pop(i)
            # credited at completion: gossips still in flight when the loop
            # hits num_updates never touched models and are not counted
            total_bytes += 2 * bytes_per_exchange
            if lost:
                # exchange on the wire, then dropped: models untouched, no
                # version bumps — but the worker's cycle continues below
                if on_drop is not None:
                    on_drop(i, j, len(staleness))
                events.append(SimEvent(t_now, MSGDROP, i, peer=j,
                                       step=local_step[i],
                                       nbytes=2 * bytes_per_exchange))
            else:
                if on_gossip is not None:
                    on_gossip(i, j, len(staleness))
                version[i] += 1
                version[j] += 1
                events.append(SimEvent(t_now, GOSSIP, i, peer=j,
                                       step=local_step[i],
                                       nbytes=2 * bytes_per_exchange))
            # apply the stale gradient immediately after the exchange
            stale = version[i] - snap_version[i]
            staleness.append(stale)
            if on_update is not None:
                on_update(i, local_step[i], stale)
            version[i] += 1
            events.append(SimEvent(t_now, UPDATE, i, step=local_step[i]))
            local_step[i] += 1
            updates_done += 1
            # next compute phase; snapshot the model version it reads
            snap_version[i] = version[i]
            dt = comp.compute_seconds(i, local_step[i], seed)
            heapq.heappush(heap, (t_now + dt, seq, COMPUTE, i))
            seq += 1
    return SimTrace(events=events, total_seconds=t_now,
                    bytes_on_wire=total_bytes, staleness=staleness)


def replay_adpsgd(scenario, engine, x0, grad_fn, alpha: float,
                  num_updates: int, theta: float = 2.0,
                  faults=None) -> Dict[str, Any]:
    """Replay AD-PSGD through ``CommEngine.pair_average`` edge by edge.

    ``x0`` is the stacked ``[n, d]`` initial model, ``grad_fn(x, i, key)``
    the per-worker stochastic gradient (the :mod:`repro.core.adpsgd`
    signature).  Each simulated gossip applies the engine's pair exchange
    (quantized or exact, per its wire codec) to the live models; each
    update applies the gradient *snapshot* its worker took at compute
    start — the same staleness the wall clock prices.  Returns the final
    stacked models, the trace, and per-update mean-model distances.

    Faults (``faults`` or ``scenario.faults``): a lost exchange replays
    through ``engine.pair_average(..., presence=(1, 0))`` — the identity
    exchange, EF state untouched — so predicted wall clock and realized
    convergence under loss come from the SAME event loop and the SAME
    engine API that a fault-free replay exercises.
    """
    import jax
    import jax.numpy as jnp

    from repro.sim.network import STREAM_GRAD, STREAM_PAIR

    n = x0.shape[0]
    X = [x0[i] for i in range(n)]
    snap = [x0[i] for i in range(n)]
    grads: List[Optional[Any]] = [None] * n
    scenario_seed = scenario.seed

    def _take_grad(i: int, idx: int) -> None:
        # snapshot & gradient for the exchange initiator were taken at its
        # compute start; compute them lazily here (values equal by purity)
        if grads[i] is None:
            kg = jax.random.PRNGKey(
                sim_randint(scenario_seed, 2**31 - 1, STREAM_GRAD, i, idx))
            grads[i] = grad_fn(snap[i], i, kg)

    def _exchange(i: int, j: int, idx: int, presence) -> None:
        _take_grad(i, idx)
        kp = jax.random.PRNGKey(
            sim_randint(scenario_seed, 2**31 - 1, STREAM_PAIR, idx))
        res = engine.pair_average(X[i], X[j], theta=theta, key=kp,
                                  presence=presence)
        X[i], X[j] = res.xi, res.xj

    def on_gossip(i: int, j: int, idx: int) -> None:
        _exchange(i, j, idx, None)

    def on_drop(i: int, j: int, idx: int) -> None:
        _exchange(i, j, idx, (1, 0))    # lost payload: identity exchange

    def on_update(i: int, step: int, stale: int) -> None:
        X[i] = X[i] - alpha * grads[i]
        grads[i] = None
        snap[i] = X[i]          # next gradient reads the post-update model

    nbytes = engine.codec.payload_bytes(tuple(x0.shape[1:]))
    trace = simulate_async_gossip(scenario, bytes_per_exchange=nbytes,
                                  num_updates=num_updates,
                                  on_gossip=on_gossip, on_update=on_update,
                                  faults=faults, on_drop=on_drop)
    Xf = jnp.stack(X)
    consensus = float(jnp.mean(jnp.sum(
        (Xf - jnp.mean(Xf, axis=0, keepdims=True)) ** 2, axis=1)))
    return {"X": Xf, "trace": trace, "consensus_sq": consensus}
