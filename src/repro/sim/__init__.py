"""repro.sim: event-driven network/cluster simulator.

Turns the byte counts CommEngine already produces (``bytes_per_round`` /
``BytesLedger``) into *wall-clock predictions* under explicit link and
compute models, across heterogeneous named scenarios — the layer that lets
the repo reproduce the paper's wall-clock comparisons without a physical
cluster.  Layout:

* :mod:`repro.sim.network`   — per-edge link models (alpha-beta cost
  ``T = alpha + bytes / beta``, jitter, heterogeneous links keyed by
  topology offsets) and the deterministic counter-hash RNG every sim
  module draws from.
* :mod:`repro.sim.cluster`   — per-worker compute-time models with
  straggler distributions (static multipliers, exponential and Pareto
  tails).
* :mod:`repro.sim.events`    — the deterministic event engine: a
  synchronous-round mode (D-PSGD / D2 / Moniqua) and an asynchronous
  AD-PSGD event loop that replays ``CommEngine.pair_average`` edge by
  edge with staleness tracking.
* :mod:`repro.sim.contention` — shared-resource link scheduling: NICs and
  switches as capacity-limited resources, max-concurrency or exact
  progressive-filling (water-filling) bandwidth sharing, and the fluid
  :class:`~repro.sim.contention.FlowScheduler` both event modes use to
  serialize contended transfers.
* :mod:`repro.sim.calibrate`  — least-squares ``alpha + bytes/beta`` fits
  from measured ``bench_walltime`` probes (or synthetic traces), emitting
  a :class:`~repro.sim.network.NetworkModel` the scenario catalog loads.
* :mod:`repro.sim.scenarios` — the named scenario catalog (homogeneous
  10GbE ring, WAN exponential graph, long-tail straggler,
  bandwidth-starved 1-bit, oversubscribed ToR, shared-uplink medium,
  calibrated-from-bench) and factories for custom ones.

Everything is pure Python + numpy-free arithmetic on floats, fully
deterministic given (scenario, seed): same inputs produce an *identical*
event trace, which ``tests/test_sim.py`` enforces.
"""
from repro.sim.calibrate import LinkFit, fit_link, fit_network
from repro.sim.cluster import ComputeModel
from repro.sim.contention import (Fabric, FlowScheduler, Switch,
                                  schedule_transfers, solve_rates)
from repro.sim.events import (SimEvent, SimTrace, replay_adpsgd,
                              simulate_async_gossip, simulate_sync_rounds)
from repro.sim.network import LinkModel, NetworkModel, sim_uniform
from repro.sim.scenarios import (Scenario, get_scenario, list_scenarios,
                                 scenario_from_netconfig)

__all__ = [
    "ComputeModel", "Fabric", "FlowScheduler", "LinkFit", "LinkModel",
    "NetworkModel", "Scenario", "SimEvent", "SimTrace", "Switch",
    "fit_link", "fit_network", "get_scenario", "list_scenarios",
    "replay_adpsgd", "scenario_from_netconfig", "schedule_transfers",
    "sim_uniform", "simulate_async_gossip", "simulate_sync_rounds",
    "solve_rates",
]
