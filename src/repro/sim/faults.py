"""Fault injection for the event simulator: churn, loss, deadlines.

A :class:`FaultSpec` is a frozen catalog of everything that can go wrong
in a gossip round, attached to a :class:`~repro.sim.scenarios.Scenario`
(``scenario.faults``) or passed per-call to the event engines:

``outages``
    Scheduled churn: explicit :class:`Outage` windows (worker ``w`` is
    offline for rounds ``[start, start + rounds)``).  Stochastic
    crash-restart churn lives in the *compute model*
    (:meth:`~repro.sim.cluster.ComputeModel.offline`, the ``crash_restart``
    factory); :func:`presence_of` folds both sources into one mask.
``drop_p``
    Per-directed-message loss probability.  Each (round, src, dst) draws
    on the ``STREAM_DROP`` counter-hash stream, so a replay loses exactly
    the same messages — the sim determinism contract extends to faults.
``deadline_s``
    Deadline-based rounds: the barrier releases at ``t_start +
    deadline_s`` instead of waiting for stragglers.  A worker whose own
    compute overruns the deadline is dropped from the round (its model
    takes the identity mix — self-weight 1); a payload that arrives late
    kills just that edge.  Either way the mixing matrix is renormalized
    over who actually made it — :meth:`Topology.with_presence
    <repro.core.topology.Topology.with_presence>` semantics, executed by
    ``CommEngine.mix(presence=...)``.

The *presence mask* a fault-injected round hands to the engine is the
**participation** mask: present workers whose compute met the deadline.
Per-edge losses (sampled drops, late arrivals) are finer-grained than the
engine's worker-level mask; they shape the wall clock and the event trace
(``MSGDROP`` / ``LATE`` events) but leave worker-level participation
intact.  The async loop is wait-free, so only ``drop_p`` applies there —
a dropped pair exchange replays through ``CommEngine.pair_average(...,
presence=(1, 0))``, the identity exchange (``sim.events.replay_adpsgd``).

Everything here is a pure function of (spec, seed, semantic counters):
no simulator state, no RNG objects — :meth:`SimTrace.fingerprint
<repro.sim.events.SimTrace.fingerprint>` stays stable across reruns.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.sim.network import STREAM_DROP, sim_uniform


@dataclasses.dataclass(frozen=True)
class Outage:
    """Scheduled offline window: ``worker`` down for ``rounds`` rounds."""
    worker: int
    start: int
    rounds: int = 1

    def __post_init__(self):
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")

    def covers(self, worker: int, step: int) -> bool:
        return (worker == self.worker
                and self.start <= step < self.start + self.rounds)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What can go wrong in a round (see module docstring)."""
    deadline_s: Optional[float] = None
    drop_p: float = 0.0
    outages: Tuple[Outage, ...] = ()

    def __post_init__(self):
        if not 0.0 <= self.drop_p < 1.0:
            raise ValueError(f"drop_p must be in [0, 1), got {self.drop_p}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be positive, got {self.deadline_s}")

    def offline(self, worker: int, step: int, compute, seed: int) -> bool:
        """Down at ``step``: a scheduled window covers it, or the compute
        model's stochastic crash-restart predicate fires."""
        if any(o.covers(worker, step) for o in self.outages):
            return True
        return compute.offline(worker, step, seed)

    def message_dropped(self, step: int, src: int, dst: int,
                        seed: int) -> bool:
        """Deterministic per-directed-message loss draw (STREAM_DROP)."""
        if self.drop_p <= 0.0:
            return False
        return sim_uniform(seed, STREAM_DROP, step, src, dst) < self.drop_p


def presence_of(faults: Optional[FaultSpec], compute, n: int, step: int,
                seed: int) -> Optional[Tuple[int, ...]]:
    """Round ``step``'s presence mask, or ``None`` when everyone is up.

    ``None`` covers both "no faults configured" and "faults configured
    but nobody down this round" — callers branch to the exact unfaulted
    code path on ``None``, which is what keeps no-fault simulations
    event-identical to the pre-elastic engine.
    """
    if faults is not None:
        mask = tuple(
            0 if faults.offline(i, step, compute, seed) else 1
            for i in range(n))
    elif getattr(compute, "outage_p", 0.0) > 0.0:
        # crash-restart compute model used without a FaultSpec: churn
        # still applies (the model owns the stochastic outage draws)
        mask = tuple(0 if compute.offline(i, step, seed) else 1
                     for i in range(n))
    else:
        return None
    return None if all(mask) else mask
