"""Per-worker compute-time models with straggler distributions.

A :class:`ComputeModel` prices one local step (forward + backward + update)
for worker ``i`` at global step ``k``:

    t(i, k) = base_s * mult_i * (1 + tail(i, k))

* ``mult_i`` — static heterogeneity (mixed hardware generations); defaults
  to 1 for every worker.
* ``tail(i, k)`` — a per-step stochastic straggler term, drawn
  deterministically from :func:`~repro.sim.network.sim_uniform`:

    - ``"none"``:    0
    - ``"exp"``:     ``scale * Exp(1)``      (occasional pauses: GC, page
                     faults — light tail)
    - ``"pareto"``:  ``scale * (Pareto(shape) - 1)`` (heavy tail: the
                     long-tail straggler scenario; shape <= 2 has
                     unbounded variance, the regime where synchronous
                     rounds collapse to the slowest worker)

``tail_workers`` restricts the stochastic term to a subset (e.g. one bad
host); the static multipliers always apply.  All draws are keyed by
(seed, worker, step), so a model is a frozen value object and two runs of
the same scenario agree event-for-event.

**Crash-restart churn.**  ``outage_p`` adds a per-(worker, step) chance
that a worker goes *offline* — crashed, preempted, or partitioned — for
``outage_rounds`` consecutive steps before rejoining.  Onsets are drawn
on their own stream (``STREAM_OUTAGE``) keyed by (seed, worker, step),
so :meth:`ComputeModel.offline` is a pure predicate: whether worker ``i``
is down at step ``k`` is answerable at any time, in any order, without
simulator state — the same replay contract as the straggler tails.  The
fault-injection layer (:mod:`repro.sim.faults`) folds this predicate into
the round's presence mask; ``compute_seconds`` itself is unchanged (an
offline worker is *excluded*, not slowed).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from repro.sim.network import STREAM_COMPUTE, STREAM_OUTAGE, sim_uniform

TAILS = ("none", "exp", "pareto")


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """Per-worker local-step time model."""
    base_s: float                           # homogeneous per-step seconds
    multipliers: Tuple[float, ...] = ()     # static per-worker factors
    tail: str = "none"                      # none | exp | pareto
    tail_scale: float = 0.0                 # strength of the random term
    tail_workers: Tuple[int, ...] = ()      # affected workers; () = all
    pareto_shape: float = 1.5               # heavy-tail exponent
    outage_p: float = 0.0                   # per-step crash-restart onset
    outage_rounds: int = 1                  # steps offline per onset
    outage_workers: Tuple[int, ...] = ()    # affected workers; () = all

    def __post_init__(self):
        if self.tail not in TAILS:
            raise ValueError(f"unknown tail {self.tail!r}; one of {TAILS}")
        if self.base_s <= 0:
            raise ValueError(f"base_s must be positive, got {self.base_s}")
        if not 0.0 <= self.outage_p < 1.0:
            raise ValueError(f"outage_p must be in [0, 1), "
                             f"got {self.outage_p}")
        if self.outage_rounds < 1:
            raise ValueError(f"outage_rounds must be >= 1, "
                             f"got {self.outage_rounds}")

    def multiplier(self, worker: int) -> float:
        """Static factor for ``worker``; workers past the tuple get 1.0,
        so a prefix like ``(4.0,)`` means "worker 0 is 4x slower"."""
        if worker < len(self.multipliers):
            return self.multipliers[worker]
        return 1.0

    def compute_seconds(self, worker: int, step: int, seed: int) -> float:
        t = self.base_s * self.multiplier(worker)
        if self.tail == "none" or self.tail_scale == 0.0:
            return t
        if self.tail_workers and worker not in self.tail_workers:
            return t
        u = sim_uniform(seed, STREAM_COMPUTE, worker, step)
        # clamp away u == 1 so the inverse-CDF transforms stay finite
        u = min(u, 1.0 - 1e-12)
        if self.tail == "exp":
            extra = -math.log1p(-u)
        else:  # pareto, mean-shifted to start at 0
            extra = (1.0 - u) ** (-1.0 / self.pareto_shape) - 1.0
        return t * (1.0 + self.tail_scale * extra)

    def expected_seconds(self, worker: int) -> float:
        """Mean per-step time ignoring the stochastic tail (planning aid)."""
        return self.base_s * self.multiplier(worker)

    def offline(self, worker: int, step: int, seed: int) -> bool:
        """Is ``worker`` down at ``step``?  Pure counter-hash predicate.

        An onset drawn at step ``j`` keeps the worker offline through
        steps ``j .. j + outage_rounds - 1``, so the check scans the
        trailing onset window — stateless, so replays and out-of-order
        queries agree (the sim determinism contract).
        """
        if self.outage_p <= 0.0:
            return False
        if self.outage_workers and worker not in self.outage_workers:
            return False
        lo = max(0, step - self.outage_rounds + 1)
        return any(
            sim_uniform(seed, STREAM_OUTAGE, worker, j) < self.outage_p
            for j in range(lo, step + 1))


def homogeneous(base_s: float) -> ComputeModel:
    return ComputeModel(base_s=base_s)


def one_straggler(base_s: float, worker: int = 0, slow: float = 4.0,
                  tail_scale: float = 2.0,
                  pareto_shape: float = 1.2) -> ComputeModel:
    """One chronically slow worker with a heavy-tailed per-step term."""
    return ComputeModel(base_s=base_s, tail="pareto", tail_scale=tail_scale,
                        tail_workers=(worker,), pareto_shape=pareto_shape,
                        multipliers=tuple(slow if i == worker else 1.0
                                          for i in range(worker + 1)))


def crash_restart(base_s: float, outage_p: float = 0.05,
                  outage_rounds: int = 3,
                  workers: Tuple[int, ...] = ()) -> ComputeModel:
    """Workers crash for ``outage_rounds`` steps then rejoin (churn).

    Each step each (affected) worker independently draws a crash onset
    with probability ``outage_p`` on the ``STREAM_OUTAGE`` counter-hash
    stream; expected unavailability per worker is roughly ``outage_p *
    outage_rounds``.  Compute cost while up is homogeneous ``base_s`` —
    churn and straggling are orthogonal axes, compose them with
    ``dataclasses.replace`` if a scenario needs both.
    """
    return ComputeModel(base_s=base_s, outage_p=outage_p,
                        outage_rounds=outage_rounds,
                        outage_workers=tuple(workers))
