"""Per-worker compute-time models with straggler distributions.

A :class:`ComputeModel` prices one local step (forward + backward + update)
for worker ``i`` at global step ``k``:

    t(i, k) = base_s * mult_i * (1 + tail(i, k))

* ``mult_i`` — static heterogeneity (mixed hardware generations); defaults
  to 1 for every worker.
* ``tail(i, k)`` — a per-step stochastic straggler term, drawn
  deterministically from :func:`~repro.sim.network.sim_uniform`:

    - ``"none"``:    0
    - ``"exp"``:     ``scale * Exp(1)``      (occasional pauses: GC, page
                     faults — light tail)
    - ``"pareto"``:  ``scale * (Pareto(shape) - 1)`` (heavy tail: the
                     long-tail straggler scenario; shape <= 2 has
                     unbounded variance, the regime where synchronous
                     rounds collapse to the slowest worker)

``tail_workers`` restricts the stochastic term to a subset (e.g. one bad
host); the static multipliers always apply.  All draws are keyed by
(seed, worker, step), so a model is a frozen value object and two runs of
the same scenario agree event-for-event.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from repro.sim.network import STREAM_COMPUTE, sim_uniform

TAILS = ("none", "exp", "pareto")


@dataclasses.dataclass(frozen=True)
class ComputeModel:
    """Per-worker local-step time model."""
    base_s: float                           # homogeneous per-step seconds
    multipliers: Tuple[float, ...] = ()     # static per-worker factors
    tail: str = "none"                      # none | exp | pareto
    tail_scale: float = 0.0                 # strength of the random term
    tail_workers: Tuple[int, ...] = ()      # affected workers; () = all
    pareto_shape: float = 1.5               # heavy-tail exponent

    def __post_init__(self):
        if self.tail not in TAILS:
            raise ValueError(f"unknown tail {self.tail!r}; one of {TAILS}")
        if self.base_s <= 0:
            raise ValueError(f"base_s must be positive, got {self.base_s}")

    def multiplier(self, worker: int) -> float:
        """Static factor for ``worker``; workers past the tuple get 1.0,
        so a prefix like ``(4.0,)`` means "worker 0 is 4x slower"."""
        if worker < len(self.multipliers):
            return self.multipliers[worker]
        return 1.0

    def compute_seconds(self, worker: int, step: int, seed: int) -> float:
        t = self.base_s * self.multiplier(worker)
        if self.tail == "none" or self.tail_scale == 0.0:
            return t
        if self.tail_workers and worker not in self.tail_workers:
            return t
        u = sim_uniform(seed, STREAM_COMPUTE, worker, step)
        # clamp away u == 1 so the inverse-CDF transforms stay finite
        u = min(u, 1.0 - 1e-12)
        if self.tail == "exp":
            extra = -math.log1p(-u)
        else:  # pareto, mean-shifted to start at 0
            extra = (1.0 - u) ** (-1.0 / self.pareto_shape) - 1.0
        return t * (1.0 + self.tail_scale * extra)

    def expected_seconds(self, worker: int) -> float:
        """Mean per-step time ignoring the stochastic tail (planning aid)."""
        return self.base_s * self.multiplier(worker)


def homogeneous(base_s: float) -> ComputeModel:
    return ComputeModel(base_s=base_s)


def one_straggler(base_s: float, worker: int = 0, slow: float = 4.0,
                  tail_scale: float = 2.0,
                  pareto_shape: float = 1.2) -> ComputeModel:
    """One chronically slow worker with a heavy-tailed per-step term."""
    return ComputeModel(base_s=base_s, tail="pareto", tail_scale=tail_scale,
                        tail_workers=(worker,), pareto_shape=pareto_shape,
                        multipliers=tuple(slow if i == worker else 1.0
                                          for i in range(worker + 1)))
