"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the modality frontend (log-mel + two conv layers) is a
STUB: ``input_specs`` supplies *post-conv frame embeddings*
``[B, enc_len, d_model]`` directly (enc_len = seq_len / encoder_downsample).
Everything downstream — sinusoidal encoder positions, bidirectional encoder,
causal decoder with cross-attention, learned decoder positions, tied output
head — is implemented.

Decode shapes exercise the decoder: self-attention over a ring-buffer cache of
the requested context plus cross-attention over the (pre-filled) encoder
output.  ``long_500k`` is skipped for this arch (full-attention enc-dec; see
DESIGN.md §5).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    half = channels // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * math.log(10000.0) / max(half - 1, 1))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_enc_block(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    ka, km = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), dt),
            "ln1b": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "ln2b": jnp.zeros((cfg.d_model,), dt),
            "attn": L.init_attention(ka, cfg, dtype=dt),
            "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, False, dt)}


def init_dec_block(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    ka, kc, km = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,), dt), "ln1b": jnp.zeros((cfg.d_model,), dt),
            "lnx": jnp.ones((cfg.d_model,), dt), "lnxb": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt), "ln2b": jnp.zeros((cfg.d_model,), dt),
            "self_attn": L.init_attention(ka, cfg, dtype=dt),
            "cross_attn": L.init_attention(kc, cfg, dtype=dt),
            "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, False, dt)}


def init_whisper(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    V = T.padded_vocab(cfg)
    ke, kd, kt, kp = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_block(k, cfg))(
        jax.random.split(ke, cfg.encoder_layers))
    dec = jax.vmap(lambda k: init_dec_block(k, cfg))(
        jax.random.split(kd, cfg.num_layers))
    return {
        "enc_blocks": enc,
        "dec_blocks": dec,
        "tok_embed": L.truncated_normal(kt, (V, cfg.d_model), 0.02, dt),
        "dec_pos": L.truncated_normal(kp, (cfg.decoder_len_cap, cfg.d_model),
                                      0.01, dt),
        "ln_enc": jnp.ones((cfg.d_model,), dt),
        "ln_encb": jnp.zeros((cfg.d_model,), dt),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "ln_fb": jnp.zeros((cfg.d_model,), dt),
    }


def whisper_pspecs(cfg):
    def stk(tree):
        return jax.tree.map(lambda lg: ("stack",) + lg, tree,
                            is_leaf=lambda v: isinstance(v, tuple))
    eb = stk({"ln1": (None,), "ln1b": (None,), "ln2": (None,), "ln2b": (None,),
              "attn": L.attention_pspecs(cfg),
              "mlp": L.mlp_pspecs(False)})
    db = stk({"ln1": (None,), "ln1b": (None,), "lnx": (None,), "lnxb": (None,),
              "ln2": (None,), "ln2b": (None,),
              "self_attn": L.attention_pspecs(cfg),
              "cross_attn": L.attention_pspecs(cfg),
              "mlp": L.mlp_pspecs(False)})
    return {"enc_blocks": eb, "dec_blocks": db,
            "tok_embed": ("vocab", "embed"), "dec_pos": (None, "embed"),
            "ln_enc": (None,), "ln_encb": (None,),
            "ln_f": (None,), "ln_fb": (None,)}


def _enc_block(bp, cfg, x):
    h = L.attention(bp["attn"], cfg, L.layer_norm(x, bp["ln1"], bp["ln1b"]),
                    None, bidir=True)
    x = x + h
    return x + L.mlp(bp["mlp"], L.layer_norm(x, bp["ln2"], bp["ln2b"]), False)


def encode(p, cfg, enc_embeds):
    """enc_embeds: [B, enc_len, d] (conv-frontend stub output)."""
    x = enc_embeds + sinusoids(enc_embeds.shape[1], cfg.d_model
                               ).astype(enc_embeds.dtype)

    def fn(carry, bp):
        f = _enc_block
        if cfg.remat:
            f = jax.checkpoint(f, static_argnums=(1,))
        return f(bp, cfg, carry), None

    unroll = cfg.encoder_layers if cfg.unroll_layers else 1
    x, _ = jax.lax.scan(fn, x, p["enc_blocks"], unroll=unroll)
    return L.layer_norm(x, p["ln_enc"], p["ln_encb"])


def _dec_block(bp, cfg, x, enc_kv):
    h = L.attention(bp["self_attn"], cfg,
                    L.layer_norm(x, bp["ln1"], bp["ln1b"]), None)
    x = x + h
    h = L.attention(bp["cross_attn"], cfg,
                    L.layer_norm(x, bp["lnx"], bp["lnxb"]), None,
                    cross_kv=enc_kv)
    x = x + h
    return x + L.mlp(bp["mlp"], L.layer_norm(x, bp["ln2"], bp["ln2b"]), False)


def _cross_kv(bp, cfg, enc_out):
    k = jnp.einsum("...d,dnh->...nh", enc_out, bp["cross_attn"]["wk"])
    v = jnp.einsum("...d,dnh->...nh", enc_out, bp["cross_attn"]["wv"])
    return k, v


def decoder_hidden(p, cfg, tokens, enc_out):
    B, S = tokens.shape
    x = p["tok_embed"][tokens] + p["dec_pos"][:S][None]

    def fn(carry, bp):
        f = _dec_block
        if cfg.remat:
            f = jax.checkpoint(f, static_argnums=(1,))
        return f(bp, cfg, carry, _cross_kv(bp, cfg, enc_out)), None

    unroll = cfg.num_layers if cfg.unroll_layers else 1
    x, _ = jax.lax.scan(fn, x, p["dec_blocks"], unroll=unroll)
    return L.layer_norm(x, p["ln_f"], p["ln_fb"])


def whisper_loss(p, cfg, enc_embeds, tokens, labels):
    enc_out = encode(p, cfg, enc_embeds)
    h = decoder_hidden(p, cfg, tokens, enc_out)
    logits = (h @ p["tok_embed"].T).astype(jnp.float32)
    return T.xent(logits, labels, cfg.vocab_size)


# -- serving ----------------------------------------------------------------

def init_whisper_cache(cfg, batch, self_len, enc_len):
    dt = jnp.dtype(cfg.dtype)
    Lyr = cfg.num_layers
    selfc = jax.vmap(lambda _: L.init_attn_cache((batch,), cfg, self_len, dt))(
        jnp.arange(Lyr))
    crossc = jax.vmap(lambda _: L.init_attn_cache((batch,), cfg, enc_len, dt))(
        jnp.arange(Lyr))
    return {"self": selfc, "cross": crossc, "pos": jnp.zeros((), jnp.int32),
            "enc_len": jnp.zeros((), jnp.int32)}


def whisper_prefill_cross(p, cfg, enc_embeds, cache):
    """Run the encoder and fill the per-layer cross K/V caches."""
    enc_out = encode(p, cfg, enc_embeds)

    def fill(bp, c):
        k, v = _cross_kv(bp, cfg, enc_out)
        W = c["k"].shape[-3]
        return {"k": c["k"].at[..., :k.shape[-3], :, :].set(k.astype(c["k"].dtype)),
                "v": c["v"].at[..., :v.shape[-3], :, :].set(v.astype(c["v"].dtype))}

    cross = jax.vmap(lambda bp, c: fill(bp, c))(p["dec_blocks"], cache["cross"])
    return {**cache, "cross": cross,
            "enc_len": jnp.asarray(enc_out.shape[1], jnp.int32)}


def whisper_decode_step(p, cfg, cache, token):
    """token: [B,1] -> (logits [B,1,V], cache)."""
    pos = cache["pos"]
    pos_emb_idx = jnp.minimum(pos, cfg.decoder_len_cap - 1)
    x = p["tok_embed"][token] + jax.lax.dynamic_slice_in_dim(
        p["dec_pos"], pos_emb_idx, 1, axis=0)[None]

    def body(carry, bc):
        h = carry
        bp, sc, cc = bc
        a, sc = L.attention_decode(bp["self_attn"], cfg,
                                   L.layer_norm(h, bp["ln1"], bp["ln1b"]),
                                   sc, pos)
        h = h + a
        a, _ = L.attention_decode(bp["cross_attn"], cfg,
                                  L.layer_norm(h, bp["lnx"], bp["lnxb"]),
                                  cc, cache["enc_len"], cross=True)
        h = h + a
        h = h + L.mlp(bp["mlp"], L.layer_norm(h, bp["ln2"], bp["ln2b"]), False)
        return h, sc

    h, new_self = jax.lax.scan(body, x, (p["dec_blocks"], cache["self"],
                                         cache["cross"]),
                               unroll=cfg.num_layers if cfg.unroll_layers
                               else 1)
    h = L.layer_norm(h, p["ln_f"], p["ln_fb"])
    logits = (h @ p["tok_embed"].T).astype(jnp.float32)
    return logits, {**cache, "self": new_self, "pos": pos + 1}
