"""CIFAR ResNet (He et al. 2016) — the paper's own experimental model.

ResNet-{20,110} = 3 stages of n={3,18} basic blocks on 32x32 inputs; used by
the paper-faithful decentralized-training experiments (Sec. 6, Table 2).
Pure-jnp conv implementation (lax.conv_general_dilated), batch-norm replaced
by group norm so per-worker statistics stay local (decentralized workers must
not share BN stats — same choice the paper's PyTorch DDP-free setup implies).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) \
        * math.sqrt(2.0 / fan_in)


def conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def group_norm(x, scale, bias, groups=8, eps=1e-5):
    N, H, W, C = x.shape
    g = min(groups, C)
    xg = x.reshape(N, H, W, g, C // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(N, H, W, C) * scale + bias


def init_block(key, cin, cout, stride):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"c1": _conv_init(k1, 3, 3, cin, cout),
         "g1s": jnp.ones((cout,)), "g1b": jnp.zeros((cout,)),
         "c2": _conv_init(k2, 3, 3, cout, cout),
         "g2s": jnp.ones((cout,)), "g2b": jnp.zeros((cout,))}
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(k3, 1, 1, cin, cout)
    return p


def block(p, x, stride):
    h = conv(x, p["c1"], stride)
    h = jax.nn.relu(group_norm(h, p["g1s"], p["g1b"]))
    h = conv(h, p["c2"])
    h = group_norm(h, p["g2s"], p["g2b"])
    sc = conv(x, p["proj"], stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


def init_resnet(key, depth=20, num_classes=10, width=16):
    assert (depth - 2) % 6 == 0, depth
    n = (depth - 2) // 6
    keys = jax.random.split(key, 3 * n + 2)
    p = {"stem": _conv_init(keys[0], 3, 3, 3, width),
         "stem_s": jnp.ones((width,)), "stem_b": jnp.zeros((width,)),
         "stages": []}
    ki = 1
    cin = width
    for s, cout in enumerate([width, 2 * width, 4 * width]):
        stage = []
        for b in range(n):
            stride = 2 if (s > 0 and b == 0) else 1
            stage.append(init_block(keys[ki], cin, cout, stride))
            cin = cout
            ki += 1
        p["stages"].append(stage)
    p["fc_w"] = jax.random.normal(keys[-1], (cin, num_classes)) / math.sqrt(cin)
    p["fc_b"] = jnp.zeros((num_classes,))
    return p


def resnet_logits(p, x):
    """x: [N, 32, 32, 3] -> logits [N, classes]."""
    h = jax.nn.relu(group_norm(conv(x, p["stem"]), p["stem_s"], p["stem_b"]))
    for s, stage in enumerate(p["stages"]):
        for b, bp in enumerate(stage):
            stride = 2 if (s > 0 and b == 0) else 1
            h = block(bp, h, stride)
    h = jnp.mean(h, axis=(1, 2))
    return h @ p["fc_w"] + p["fc_b"]


def resnet_loss(p, batch):
    logits = resnet_logits(p, batch["images"])
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=1))


def resnet_accuracy(p, batch):
    logits = resnet_logits(p, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
