"""Logical-axis sharding rules (MaxText-style, reduced to what we need).

Params and activations are annotated with *logical* axis names; a
``ShardingRules`` table maps logical names to mesh axes per distribution mode:

  decentralized:  leading ``worker`` param axis -> the worker mesh axes
                  (``data`` single-pod, ``('pod','data')`` multi-pod); tensor-
                  parallel dims (heads/mlp/vocab/experts) -> ``model``;
                  embed (residual) dim replicated.
  hierarchical:   no worker param axis on single-pod (workers = pods);
                  2-D weight sharding: embed dim -> ``data`` (FSDP),
                  TP dims -> ``model``; batch -> ``data``.

``logical_to_pspec`` turns a tuple of logical names into a PartitionSpec.
Unknown / None names are unsharded.  Dims that do not divide their mesh axis
fall back to replication (checked at use site via ``safe_pspec``).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

MeshAxes = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mode: str                       # "decentralized" | "hierarchical"
    multi_pod: bool = False
    tiers: int = 1                  # >1: worker dim spans (inter, intra)
    intra_axis: str = "intra"       # fast tier (make_two_tier_mesh)
    inter_axis: str = "inter"       # slow tier

    @property
    def worker_axes(self) -> Tuple[str, ...]:
        """Mesh axes forming the decentralized-worker dimension.

        Two-tier runs (``tiers > 1``) split it into ``(inter, intra)`` —
        inter major, intra minor, matching ``HierarchicalTopology``'s
        flat worker index ``w = g * n_intra + j``.
        """
        if self.mode == "decentralized":
            if self.tiers > 1:
                return (self.inter_axis, self.intra_axis)
            return ("pod", "data") if self.multi_pod else ("data",)
        # hierarchical: workers are pods (leading replica dim only multi-pod)
        return ("pod",) if self.multi_pod else ()

    @property
    def fsdp_axis(self) -> Optional[str]:
        return "data" if self.mode == "hierarchical" else None

    def table(self) -> dict:
        fsdp = self.fsdp_axis
        return {
            "worker": self.worker_axes or None,
            # inner (per-worker) batch dim of a stacked training batch
            "batch": ("data",) if self.mode == "hierarchical" else None,
            # leading batch dim of an (unstacked) serving workload
            "global_batch": ((self.worker_axes or ("data",))
                             if self.tiers > 1
                             else (("pod", "data") if self.multi_pod
                                   else ("data",))),
            "embed": fsdp,           # residual / d_model dim
            "heads": "model",        # nh * hd flattened or nh
            "kv": "model",           # kv heads (safe_pspec guards divisibility)
            "head_dim": "model",     # per-head dim (2-D TP fallback for GQA)
            "mlp": "model",          # d_ff
            "vocab": "model",
            "experts": None,         # expert dim: replicate, shard ff inside
            "ssm_inner": "model",
            "seq": None,
            "kv_seq": "model",       # context-parallel KV (attention fallback)
            "stack": None,           # layer-stack dim (scanned)
        }

    def pspec(self, *logical: Optional[str]) -> P:
        t = self.table()
        out = []
        for name in logical:
            ax = t.get(name) if name else None
            out.append(ax)
        return P(*out)


def dim_divides(dim: int, mesh_shape: dict, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, (tuple, list)):
        total = 1
        for a in axis:
            total *= mesh_shape[a]
        return dim % total == 0
    return dim % mesh_shape[axis] == 0


def safe_pspec(shape: Sequence[int], spec: P, mesh_shape: dict) -> P:
    """Replicate any dim whose size does not divide its assigned axes."""
    out = []
    for i, ax in enumerate(spec):
        if i < len(shape) and dim_divides(shape[i], mesh_shape, ax):
            out.append(ax)
        else:
            out.append(None)
    # spec may be shorter than rank; PartitionSpec pads with None implicitly
    return P(*out)


# ---------------------------------------------------------------------------
# In-model sharding constraints (activation-level).
#
# Model code is mesh-agnostic; where SPMD's propagation picks a bad
# factorisation (measured: partitioning the *contracted* head_dim of the QK
# einsum, or fully replicating attention when heads don't divide the model
# axis), the model calls ``constrain(x, *logical_names)``.  This is a no-op
# unless a launcher has installed a constraint context (dryrun/train do,
# smoke tests don't) — requires an ambient mesh (``jax.set_mesh``).
# ---------------------------------------------------------------------------

_CONSTRAINT_CTX: Optional[Tuple["ShardingRules", dict]] = None


@contextlib.contextmanager
def constraint_context(rules: "ShardingRules", mesh_shape: dict):
    global _CONSTRAINT_CTX
    prev = _CONSTRAINT_CTX
    _CONSTRAINT_CTX = (rules, dict(mesh_shape))
    try:
        yield
    finally:
        _CONSTRAINT_CTX = prev


def mesh_axis_size(name: str, default: int = 1) -> int:
    if _CONSTRAINT_CTX is None:
        return default
    return _CONSTRAINT_CTX[1].get(name, default)


def constrain(x, *logical: Optional[str]):
    if _CONSTRAINT_CTX is None:
        return x
    rules, ms = _CONSTRAINT_CTX
    spec = safe_pspec(x.shape, rules.pspec(*logical), ms)
    return jax.lax.with_sharding_constraint(x, spec)
