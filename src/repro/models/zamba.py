"""Zamba2-style hybrid backbone (arXiv:2411.15242): Mamba2 stack + one
*shared* attention block re-entered every ``shared_attn_every`` layers.

Simplifications vs the released model (recorded in DESIGN.md §5): the shared
block consumes the current hidden state (Zamba2 concatenates the original
embedding and applies per-invocation LoRA; we omit both — parameter sharing
and the invocation schedule, which drive the distribution/roofline behaviour,
are preserved).

The layer loop is a Python loop (38 slim layers), not a scan: each shared-
attention invocation needs its own KV cache at decode time, which a scanned
stack would have to thread awkwardly.  HLO growth is modest at this depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as MB


def n_shared_invocations(cfg) -> int:
    k = cfg.shared_attn_every
    return 0 if not k else cfg.num_layers // k


def init_zamba(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    kb, ka, km = jax.random.split(key, 3)
    layer_keys = jax.random.split(kb, cfg.num_layers)
    blocks = jax.vmap(lambda k: MB.init_mamba(k, cfg))(layer_keys)
    p = {"mamba": blocks}
    if cfg.shared_attn_every:
        p["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "attn": L.init_attention(ka, cfg, dtype=dt),
            "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dt),
        }
    return p


def zamba_pspecs(cfg):
    bs = jax.tree.map(lambda lg: ("stack",) + lg, MB.mamba_pspecs(),
                      is_leaf=lambda v: isinstance(v, tuple))
    s = {"mamba": bs}
    if cfg.shared_attn_every:
        s["shared_attn"] = {"ln1": (None,), "ln2": (None,),
                            "attn": L.attention_pspecs(cfg),
                            "mlp": L.mlp_pspecs(cfg.gated_mlp)}
    return s


def _shared_block(p, cfg, x, positions, window):
    h = L.attention(p["attn"], cfg, L.rms_norm(x, p["ln1"]), positions,
                    window=window)
    x = x + h
    return x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]), cfg.gated_mlp)


def zamba_hidden(p, cfg, x, positions, *, window=0):
    """x: [B,S,d] -> hidden [B,S,d]."""
    k = cfg.shared_attn_every
    for i in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[i], p["mamba"])
        fn = MB.mamba_block
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(1,))
        x = fn(bp, cfg, x)
        if k and (i + 1) % k == 0:
            x = _shared_block(p["shared_attn"], cfg, x, positions,
                              window or cfg.long_context_window)
    return x


def init_zamba_cache(cfg, batch, attn_len):
    dt = jnp.dtype(cfg.dtype)
    mamba_states = jax.vmap(lambda _: MB.init_mamba_state(batch, cfg))(
        jnp.arange(cfg.num_layers))
    caches = {"mamba": mamba_states}
    ninv = n_shared_invocations(cfg)
    if ninv:
        caches["attn"] = jax.vmap(
            lambda _: L.init_attn_cache((batch,), cfg, attn_len, dt))(
            jnp.arange(ninv))
    return caches


def zamba_decode(p, cfg, x, caches, pos, *, window):
    """x: [B,1,d]; returns (h, new caches)."""
    k = cfg.shared_attn_every
    new_mamba = []
    new_attn = []
    inv = 0
    for i in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[i], p["mamba"])
        st = jax.tree.map(lambda a: a[i], caches["mamba"])
        x, st = MB.mamba_decode(bp, cfg, x, st)
        new_mamba.append(st)
        if k and (i + 1) % k == 0:
            sc = jax.tree.map(lambda a: a[inv], caches["attn"])
            sp = p["shared_attn"]
            h, sc = L.attention_decode(sp["attn"], cfg,
                                       L.rms_norm(x, sp["ln1"]), sc, pos,
                                       window=window)
            x = x + h
            x = x + L.mlp(sp["mlp"], L.rms_norm(x, sp["ln2"]), cfg.gated_mlp)
            new_attn.append(sc)
            inv += 1
    out = {"mamba": jax.tree.map(lambda *a: jnp.stack(a), *new_mamba)}
    if new_attn:
        out["attn"] = jax.tree.map(lambda *a: jnp.stack(a), *new_attn)
    return x, out
