"""Phi-3-vision style VLM backbone (hf:microsoft/Phi-3-vision-128k-instruct).

Per the assignment the ViT/CLIP image encoder is a STUB: ``input_specs``
supplies patch embeddings ``[B, vision_tokens, vision_embed_dim]`` (CLIP
hidden size).  The *projector* (linear vision->d_model) and the phi-3-mini
language decoder that consumes the interleaved sequence are fully implemented:

    sequence = [ projected patch tokens | text tokens ]

with loss computed on text positions only (image positions labelled -1).
Decode/serving is the plain LM path (the image lives in the prefilled cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T


def init_vlm(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    k_lm, k_proj = jax.random.split(key)
    p = T.init_lm(k_lm, cfg)
    p["projector"] = L.dense_init(k_proj, cfg.vision_embed_dim, cfg.d_model, dt)
    return p


def vlm_pspecs(cfg):
    s = T.lm_pspecs(cfg)
    s["projector"] = (None, "embed")
    return s


def vlm_hidden(p, cfg, tokens, patch_embeds, *, window=0):
    """tokens: [B, S_text]; patch_embeds: [B, Nv, vision_dim]."""
    img = (patch_embeds @ p["projector"]).astype(jnp.dtype(cfg.dtype))
    txt = T.embed_tokens(p, cfg, tokens)
    x = jnp.concatenate([img, txt], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    return T.hidden_states(p, cfg, x, positions, window=window)


def vlm_loss(p, cfg, tokens, labels, patch_embeds, *, window=0):
    """labels: [B, S_text]; image positions are excluded automatically."""
    h, aux = vlm_hidden(p, cfg, tokens, patch_embeds, window=window)
    nv = patch_embeds.shape[1]
    logits = T.logits_from_hidden(p, cfg, h[:, nv:])
    return T.xent(logits, labels, cfg.vocab_size)
