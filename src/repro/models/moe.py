"""Mixture-of-Experts layer (GShard-style grouped capacity dispatch).

Top-k softmax routing with per-group capacity: the sequence is split into
groups of ``group_size`` tokens; each expert accepts at most
``C = ceil(group_size * top_k * capacity_factor / E)`` tokens per group.
Dispatch/combine are one-hot einsums of size ``[.., g, E, C]`` — with the
default ``group_size=256`` this stays tens of MB instead of the O(seq^2)
blow-up of ungrouped dispatch (DESIGN.md §5).

Expert FFN weights are stacked ``[E, d, f]``; per DESIGN.md the expert dim is
replicated and ``f`` is tensor-parallel over ``model`` (with hierarchical 2-D
sharding adding ``d -> data``), so dispatch stays local and the expert compute
is a plain sharded einsum — the all-to-all pattern appears when XLA partitions
the combine against batch-sharded activations, and is visible to the roofline.

Router aux loss is the standard load-balancing term
``E * sum_e f_e * P_e`` (Switch/GShard).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, d, f, moe_cfg, gated, dtype):
    E = moe_cfg.num_experts
    ks = jax.random.split(key, 4)
    def ew(k, a, b):
        return (jax.random.normal(k, (E, a, b), jnp.float32) / math.sqrt(a)).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_up": ew(ks[1], d, f),
        "w_down": ew(ks[2], f, d),
    }
    if gated:
        p["w_gate"] = ew(ks[3], d, f)
    return p


def moe_pspecs(gated):
    s = {"router": ("embed", None),
         "w_up": ("experts", "embed", "mlp"),
         "w_down": ("experts", "mlp", "embed")}
    if gated:
        s["w_gate"] = ("experts", "embed", "mlp")
    return s


def capacity(group_size: int, top_k: int, cf: float, E: int) -> int:
    return max(1, int(math.ceil(group_size * top_k * cf / E)))


def moe_layer(p, x, moe_cfg, gated) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E, K = moe_cfg.num_experts, moe_cfg.top_k
    g = min(moe_cfg.group_size, S)
    assert S % g == 0, (S, g)
    C = capacity(g, K, moe_cfg.capacity_factor, E)
    xg = x.reshape(B * (S // g), g, d)                     # [G, g, d]

    logits = (xg.astype(jnp.float32) @ p["router"])        # [G, g, E]
    gates = jax.nn.softmax(logits, axis=-1)

    # -- load-balance aux (computed on full softmax) ------------------------
    me = jnp.mean(gates, axis=(0, 1))                       # mean router prob
    topg, topi = jax.lax.top_k(gates, K)                    # [G, g, K]
    assign1 = jax.nn.one_hot(topi[..., 0], E)               # primary assignment
    ce = jnp.mean(assign1, axis=(0, 1))                     # fraction routed
    aux = E * jnp.sum(me * ce)

    # -- capacity-limited dispatch ------------------------------------------
    # process the K choices in priority order, tracking per-expert fill
    dispatch = jnp.zeros((xg.shape[0], g, E, C), x.dtype)
    combine = jnp.zeros((xg.shape[0], g, E, C), jnp.float32)
    fill = jnp.zeros((xg.shape[0], E), jnp.int32)
    for kk in range(K):
        oh = jax.nn.one_hot(topi[..., kk], E)               # [G, g, E]
        pos = fill[:, None, :] + jnp.cumsum(oh, axis=1).astype(jnp.int32) - 1
        keep = (oh > 0) & (pos < C)
        posc = jnp.clip(pos, 0, C - 1)
        slot = jax.nn.one_hot(posc, C) * keep[..., None]    # [G, g, E, C]
        dispatch = dispatch + slot.astype(x.dtype)
        combine = combine + slot * topg[..., kk][..., None, None]
        fill = fill + jnp.sum(oh, axis=1).astype(jnp.int32)

    # -- expert computation ---------------------------------------------------
    xe = jnp.einsum("zgec,zgd->ezcd", dispatch, xg)          # [E, G, C, d]
    h = jnp.einsum("ezcd,edf->ezcf", xe, p["w_up"])
    if gated:
        h = jax.nn.silu(jnp.einsum("ezcd,edf->ezcf", xe, p["w_gate"])) * h
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("ezcf,efd->ezcd", h, p["w_down"])        # [E, G, C, d]
    y = jnp.einsum("zgec,ezcd->zgd", combine.astype(x.dtype), ye)
    return y.reshape(B, S, d), aux
