"""Decoder-only transformer LM (dense + MoE) with scanned layer stacks.

Layer parameters are stacked along a leading ``stack`` dim and the layer loop
is a ``lax.scan`` so the lowered HLO stays O(1) in depth — essential for the
80-layer dry-runs.  ``remat`` wraps the scan body with ``jax.checkpoint``.

Also hosts the generic LM plumbing shared by the VLM/audio wrappers:
embedding, final norm, (untied) LM head, prefill & cached decode.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M


def padded_vocab(cfg) -> int:
    return -(-cfg.vocab_size // 256) * 256


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# One decoder block
# ---------------------------------------------------------------------------

def init_block(key, cfg):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    p = {"ln1": jnp.ones((cfg.d_model,), dt),
         "ln2": jnp.ones((cfg.d_model,), dt),
         "attn": L.init_attention(ks[0], cfg, dtype=dt)}
    if cfg.family == "moe":
        p["moe"] = M.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.moe,
                              cfg.gated_mlp, dt)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dt)
    return p


def block_pspecs(cfg):
    s = {"ln1": (None,), "ln2": (None,),
         "attn": L.attention_pspecs(cfg)}
    if cfg.family == "moe":
        s["moe"] = M.moe_pspecs(cfg.gated_mlp)
    else:
        s["mlp"] = L.mlp_pspecs(cfg.gated_mlp)
    return s


def block_apply(p, cfg, x, positions, *, window=0):
    """Pre-norm block. Returns (x, aux_loss)."""
    h = L.attention(p["attn"], cfg, L.rms_norm(x, p["ln1"]), positions,
                    window=window)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        y, aux = M.moe_layer(p["moe"], L.rms_norm(x, p["ln2"]), cfg.moe,
                             cfg.gated_mlp)
    else:
        y = L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]), cfg.gated_mlp)
    return x + y, aux


def block_decode(p, cfg, x, cache, pos, *, window=0):
    h, cache = L.attention_decode(p["attn"], cfg, L.rms_norm(x, p["ln1"]),
                                  cache, pos, window=window)
    x = x + h
    if cfg.family == "moe":
        y, _ = M.moe_layer(p["moe"], L.rms_norm(x, p["ln2"]), cfg.moe,
                           cfg.gated_mlp)
    else:
        y = L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]), cfg.gated_mlp)
    return x + y, cache


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def init_lm(key, cfg):
    dt = _dtype(cfg)
    V = padded_vocab(cfg)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(block_keys)
    p = {
        "embed": L.truncated_normal(k_emb, (V, cfg.d_model), 0.02, dt),
        "blocks": blocks,
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(k_head, cfg.d_model, V, dt)
    return p


def lm_pspecs(cfg):
    bs = jax.tree.map(lambda lg: ("stack",) + lg, block_pspecs(cfg),
                      is_leaf=lambda v: isinstance(v, tuple))
    s = {"embed": ("vocab", "embed"), "blocks": bs, "ln_f": (None,)}
    if not cfg.tie_embeddings:
        s["head"] = ("embed", "vocab")
    return s


def run_stack(params_blocks, cfg, x, fn):
    """Scan ``fn(block_params, carry)`` over the stacked layer dim."""
    def body(carry, bp):
        return fn(bp, carry)
    if cfg.remat:
        body = jax.checkpoint(body)
    unroll = cfg.num_layers if cfg.unroll_layers else 1
    return jax.lax.scan(body, x, params_blocks, unroll=unroll)


def hidden_states(p, cfg, x, positions, *, window=0):
    """Run embedded inputs through the stack. x: [B, S, d]."""
    def fn(bp, carry):
        h, aux_in = carry
        h, aux = block_apply(bp, cfg, h, positions, window=window)
        return (h, aux_in + aux), None
    (x, aux), _ = run_stack(p["blocks"], cfg, (x, jnp.zeros((), jnp.float32)), fn)
    return L.rms_norm(x, p["ln_f"]), aux


def logits_from_hidden(p, cfg, h):
    w = p["embed"].T if cfg.tie_embeddings else p["head"]
    return (h @ w).astype(jnp.float32)


def embed_tokens(p, cfg, tokens):
    return p["embed"][tokens]


def lm_logits(p, cfg, tokens, *, window=0):
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, aux = hidden_states(p, cfg, embed_tokens(p, cfg, tokens), positions,
                           window=window)
    return logits_from_hidden(p, cfg, h), aux


def lm_loss(p, cfg, tokens, labels, *, window=0):
    logits, aux = lm_logits(p, cfg, tokens, window=window)
    return xent(logits, labels, cfg.vocab_size) + (
        cfg.moe.aux_loss_weight * aux if cfg.family == "moe" else 0.0)


def xent(logits, labels, vocab_size):
    """Mean token cross-entropy; positions with label < 0 are masked."""
    V = logits.shape[-1]
    mask_pad = jnp.arange(V) < vocab_size
    logits = jnp.where(mask_pad, logits, -1e30)
    lp = jax.nn.log_softmax(logits, axis=-1)
    valid = labels >= 0
    lbl = jnp.clip(labels, 0)
    ll = jnp.take_along_axis(lp, lbl[..., None], axis=-1)[..., 0]
    return -jnp.sum(jnp.where(valid, ll, 0.0)) / jnp.maximum(jnp.sum(valid), 1)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def cache_len(cfg, shape) -> int:
    """Ring-buffer length for a decode workload."""
    if cfg.sliding_window or shape.seq_len > 32_768:
        return min(cfg.long_context_window, shape.seq_len)
    return shape.seq_len


def init_cache(cfg, batch, length):
    dt = _dtype(cfg)
    def one(_):
        return L.init_attn_cache((batch,), cfg, length, dt)
    caches = jax.vmap(one)(jnp.arange(cfg.num_layers))
    return {"layers": caches, "pos": jnp.zeros((), jnp.int32)}


def cache_pspecs(cfg):
    return {"layers": {"k": ("stack", "batch", None, "kv", None),
                       "v": ("stack", "batch", None, "kv", None)},
            "pos": ()}


def decode_step(p, cfg, cache, token, *, window=0):
    """token: [B, 1] int32 -> (logits [B, 1, V], new cache)."""
    x = embed_tokens(p, cfg, token)
    pos = cache["pos"]

    def fn(bp_cache, carry):
        bp, c = bp_cache
        h, c = block_decode(bp, cfg, carry, c, pos, window=window)
        return h, c

    def body(carry, bc):
        h = carry
        h, c = fn(bc, h)
        return h, c
    if cfg.remat:
        body = jax.checkpoint(body)
    unroll = cfg.num_layers if cfg.unroll_layers else 1
    h, new_layer_caches = jax.lax.scan(body, x, (p["blocks"], cache["layers"]),
                                       unroll=unroll)
    h = L.rms_norm(h, p["ln_f"])
    return logits_from_hidden(p, cfg, h), {"layers": new_layer_caches,
                                           "pos": pos + 1}
