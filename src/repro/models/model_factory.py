"""Uniform Model interface over all assigned architecture families.

A ``Model`` bundles, for one ``ArchConfig``:

  init(key)                    -> single-replica params
  loss(params, batch)          -> scalar training loss
  param_logical()              -> tree of logical-axis tuples (see sharding.py)
  init_cache(batch, shape)     -> decode cache (concrete); shapes via eval_shape
  cache_logical()              -> logical axes for the cache
  prefill_logits(params,batch) -> forward at full length (prefill workloads)
  decode_step(params,cache,tok)-> (logits, new cache)   (decode workloads)
  batch_spec(shape, kind)      -> {name: (shape, dtype)} for the data pipeline
                                  and the dry-run ShapeDtypeStructs

Batch layouts are *global* ``[GB, ...]``; the trainer reshapes to the stacked
worker layout ``[n, GB/n, ...]``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import vlm as VLM
from repro.models import whisper as WH
from repro.models import xlstm as XL
from repro.models import zamba as ZB

PyTree = Any


def _train_window(cfg) -> int:
    return cfg.sliding_window


def _decode_window(cfg, shape) -> int:
    if shape.seq_len > 32_768:
        return cfg.long_context_window
    return cfg.sliding_window


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---------------- init / loss ----------------
    def init(self, key) -> PyTree:
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            return T.init_lm(key, cfg)
        if cfg.family == "vlm":
            return VLM.init_vlm(key, cfg)
        if cfg.family == "audio":
            return WH.init_whisper(key, cfg)
        if cfg.family == "ssm":
            return self._init_xlstm(key)
        if cfg.family == "hybrid":
            return self._init_zamba(key)
        raise ValueError(cfg.family)

    def _init_xlstm(self, key):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        V = T.padded_vocab(cfg)
        ke, kb, kh = jax.random.split(key, 3)
        blocks = []
        bkeys = jax.random.split(kb, cfg.num_layers)
        for i in range(cfg.num_layers):
            if self._is_slstm(i):
                blocks.append({"slstm": XL.init_slstm(bkeys[i], cfg)})
            else:
                blocks.append({"mlstm": XL.init_mlstm(bkeys[i], cfg)})
        return {"embed": L.truncated_normal(ke, (V, cfg.d_model), 0.02, dt),
                "layers": blocks,
                "ln_f": jnp.ones((cfg.d_model,), dt),
                "head": L.dense_init(kh, cfg.d_model, V, dt)}

    def _is_slstm(self, i: int) -> bool:
        k = self.cfg.ssm.slstm_every if self.cfg.ssm else 0
        return bool(k) and i % k == 0

    def _init_zamba(self, key):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        V = T.padded_vocab(cfg)
        ke, kb, kh = jax.random.split(key, 3)
        return {"embed": L.truncated_normal(ke, (V, cfg.d_model), 0.02, dt),
                "body": ZB.init_zamba(kb, cfg),
                "ln_f": jnp.ones((cfg.d_model,), dt),
                "head": L.dense_init(kh, cfg.d_model, V, dt)}

    # ---------------- logical specs ----------------
    def param_logical(self) -> PyTree:
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            return T.lm_pspecs(cfg)
        if cfg.family == "vlm":
            return VLM.vlm_pspecs(cfg)
        if cfg.family == "audio":
            return WH.whisper_pspecs(cfg)
        if cfg.family == "ssm":
            layers = []
            for i in range(cfg.num_layers):
                if self._is_slstm(i):
                    layers.append({"slstm": XL.slstm_pspecs()})
                else:
                    layers.append({"mlstm": XL.mlstm_pspecs()})
            return {"embed": ("vocab", "embed"), "layers": layers,
                    "ln_f": (None,), "head": ("embed", "vocab")}
        if cfg.family == "hybrid":
            return {"embed": ("vocab", "embed"), "body": ZB.zamba_pspecs(cfg),
                    "ln_f": (None,), "head": ("embed", "vocab")}
        raise ValueError(cfg.family)

    # ---------------- training loss ----------------
    def loss(self, params: PyTree, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        w = _train_window(cfg)
        if cfg.family in ("dense", "moe"):
            return T.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                             window=w)
        if cfg.family == "vlm":
            return VLM.vlm_loss(params, cfg, batch["tokens"], batch["labels"],
                                batch["patch_embeds"], window=w)
        if cfg.family == "audio":
            return WH.whisper_loss(params, cfg, batch["enc_embeds"],
                                   batch["tokens"], batch["labels"])
        if cfg.family in ("ssm", "hybrid"):
            h = self._body_hidden(params, batch["tokens"])
            logits = (h @ params["head"]).astype(jnp.float32)
            return T.xent(logits, batch["labels"], cfg.vocab_size)
        raise ValueError(cfg.family)

    def _body_hidden(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens]
        B, S = tokens.shape
        if cfg.family == "ssm":
            for i, bp in enumerate(params["layers"]):
                if self._is_slstm(i):
                    x = XL.slstm_block(bp["slstm"], cfg, x)
                else:
                    fn = XL.mlstm_block
                    if cfg.remat:
                        fn = jax.checkpoint(fn, static_argnums=(1,))
                    x = fn(bp["mlstm"], cfg, x)
        else:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            # shared-attention window: decode truncates its cache to
            # long_context_window, so prefill/train must window identically
            # once S exceeds it (also 4x cheaper via the banded path)
            window = cfg.sliding_window
            if cfg.long_context_window and S > cfg.long_context_window:
                window = cfg.long_context_window
            x = ZB.zamba_hidden(params["body"], cfg, x, positions,
                                window=window)
        return L.rms_norm(x, params["ln_f"])

    # ---------------- serving ----------------
    def prefill_logits(self, params, batch, *, last_only: bool = False
                       ) -> jax.Array:
        """Forward at full length.  ``last_only=True`` (the serve_step
        default) projects ONLY the final position through the LM head —
        serving semantics (the next-token sampler needs one row), removing
        the [B, S, V] f32 logits materialisation and its S-times-larger
        head matmul from every prefill workload (EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        w = _train_window(cfg)
        if cfg.family in ("dense", "moe"):
            B, S = batch["tokens"].shape
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            h, _ = T.hidden_states(params, cfg,
                                   T.embed_tokens(params, cfg,
                                                  batch["tokens"]),
                                   positions, window=w)
            if last_only:
                h = h[:, -1:]
            return T.logits_from_hidden(params, cfg, h)
        if cfg.family == "vlm":
            h, _ = VLM.vlm_hidden(params, cfg, batch["tokens"],
                                  batch["patch_embeds"], window=w)
            if last_only:
                h = h[:, -1:]
            return T.logits_from_hidden(params, cfg, h)
        if cfg.family == "audio":
            enc = WH.encode(params, cfg, batch["enc_embeds"])
            h = WH.decoder_hidden(params, cfg, batch["tokens"], enc)
            if last_only:
                h = h[:, -1:]
            return (h @ params["tok_embed"].T).astype(jnp.float32)
        if cfg.family in ("ssm", "hybrid"):
            h = self._body_hidden(params, batch["tokens"])
            if last_only:
                h = h[:, -1:]
            return (h @ params["head"]).astype(jnp.float32)
        raise ValueError(cfg.family)

    def init_cache(self, batch: int, shape: InputShape) -> PyTree:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return T.init_cache(cfg, batch, T.cache_len(cfg, shape))
        if cfg.family == "audio":
            enc_len = min(shape.seq_len // cfg.encoder_downsample, 8192)
            return WH.init_whisper_cache(cfg, batch, shape.seq_len, enc_len)
        if cfg.family == "ssm":
            states = []
            for i in range(cfg.num_layers):
                if self._is_slstm(i):
                    states.append({"slstm": XL.init_slstm_state(batch, cfg)})
                else:
                    states.append({"mlstm": XL.init_mlstm_state(batch, cfg)})
            return {"layers": states, "pos": jnp.zeros((), jnp.int32)}
        if cfg.family == "hybrid":
            attn_len = min(shape.seq_len, cfg.long_context_window)
            return {"body": ZB.init_zamba_cache(cfg, batch, attn_len),
                    "pos": jnp.zeros((), jnp.int32)}
        raise ValueError(cfg.family)

    def cache_logical(self, kv_div: bool = True) -> PyTree:
        """Logical-axis tree mirroring ``init_cache``'s structure.

        kv_div: whether num_kv_heads divides the model mesh axis — if not,
        KV caches fall back to head-dim (2-D TP) sharding.
        """
        cfg = self.cfg
        # kv heads divide the model axis: shard heads (matches the 3-D TP
        # weight layout). Otherwise shard the cache's SEQUENCE dim — the
        # context-parallel placement _context_parallel_kv constrains the
        # expanded K/V to, so decode reads the cache in place (head_dim
        # sharding here used to force partial-sum score all-reduces).
        kv_spec = (("stack", "global_batch", None, "kv", None) if kv_div
                   else ("stack", "global_batch", "kv_seq", None, None))
        attn_cache = {"k": kv_spec, "v": kv_spec}
        if cfg.family in ("dense", "moe", "vlm"):
            return {"layers": attn_cache, "pos": ()}
        if cfg.family == "audio":
            return {"self": dict(attn_cache), "cross": dict(attn_cache),
                    "pos": (), "enc_len": ()}
        if cfg.family == "ssm":
            layers = []
            for i in range(cfg.num_layers):
                if self._is_slstm(i):
                    v = ("global_batch", "heads", None)
                    layers.append({"slstm": {"h": v, "c": v, "n": v}})
                else:
                    layers.append({"mlstm": {
                        "C": ("global_batch", "heads", None, None),
                        "n": ("global_batch", "heads", None)}})
            return {"layers": layers, "pos": ()}
        if cfg.family == "hybrid":
            body = {"mamba": {
                "h": ("stack", "global_batch", "heads", None, None),
                "conv": ("stack", "global_batch", None, "ssm_inner")}}
            if cfg.shared_attn_every:
                body["attn"] = dict(attn_cache)
            return {"body": body, "pos": ()}
        raise ValueError(cfg.family)

    def decode_step(self, params, cache, token) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            # ring-buffer semantics: if the cache is shorter than the context
            # (long_500k), it is a sliding window of exactly its own length
            ring = cache["layers"]["k"].shape[-3]
            return T.decode_step(params, cfg, cache, token, window=ring)
        if cfg.family == "audio":
            return WH.whisper_decode_step(params, cfg, cache, token)
        if cfg.family == "ssm":
            x = params["embed"][token]
            new_states = []
            for i, (bp, st) in enumerate(zip(params["layers"],
                                             cache["layers"])):
                if self._is_slstm(i):
                    x, ns = XL.slstm_decode(bp["slstm"], cfg, x, st["slstm"])
                    new_states.append({"slstm": ns})
                else:
                    x, ns = XL.mlstm_decode(bp["mlstm"], cfg, x, st["mlstm"])
                    new_states.append({"mlstm": ns})
            h = L.rms_norm(x, params["ln_f"])
            logits = (h @ params["head"]).astype(jnp.float32)
            return logits, {"layers": new_states, "pos": cache["pos"] + 1}
        if cfg.family == "hybrid":
            x = params["embed"][token]
            attn_len = cache["body"]["attn"]["k"].shape[-3] \
                if "attn" in cache["body"] else 0
            x, body = ZB.zamba_decode(params["body"], cfg, x, cache["body"],
                                      cache["pos"], window=attn_len)
            h = L.rms_norm(x, params["ln_f"])
            logits = (h @ params["head"]).astype(jnp.float32)
            return logits, {"body": body, "pos": cache["pos"] + 1}
        raise ValueError(cfg.family)

    # ---------------- batch specs ----------------
    def batch_spec(self, shape: InputShape) -> Dict[str, Tuple[tuple, Any]]:
        cfg = self.cfg
        GB, S = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        i32 = jnp.int32
        if shape.kind == "decode":
            return {"token": ((GB, 1), i32)}
        if cfg.family == "vlm":
            s_text = max(S - cfg.vision_tokens, 8)
            spec = {"tokens": ((GB, s_text), i32),
                    "patch_embeds": ((GB, cfg.vision_tokens,
                                      cfg.vision_embed_dim), dt)}
            if shape.kind == "train":
                spec["labels"] = ((GB, s_text), i32)
            return spec
        if cfg.family == "audio":
            enc_len = S // cfg.encoder_downsample
            dec_len = min(cfg.decoder_len_cap, max(S // 8, 16))
            spec = {"enc_embeds": ((GB, enc_len, cfg.d_model), dt),
                    "tokens": ((GB, dec_len), i32)}
            if shape.kind == "train":
                spec["labels"] = ((GB, dec_len), i32)
            return spec
        spec = {"tokens": ((GB, S), i32)}
        if shape.kind == "train":
            spec["labels"] = ((GB, S), i32)
        return spec


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
