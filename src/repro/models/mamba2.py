"""Mamba2 / SSD block (arXiv:2405.21060 formulation), chunkwise on TPU.

State-space recurrence per head:

    h_t = exp(dt_t * A_h) h_{t-1} + dt_t * x_t B_t^T        h: [hd, N]
    y_t = h_t C_t + D_h x_t

computed with the standard chunked algorithm (intra-chunk quadratic +
inter-chunk scanned state), i.e. the "1-semiseparable matmul" decomposition —
this is the MXU-friendly form (length-c x length-c blocks) rather than a
sequential loop over S, the key TPU adaptation of Mamba's CUDA scan kernel
(recorded in DESIGN.md).

Block wiring (simplified Mamba2): in_proj -> (z gate, x, B, C, dt heads),
causal depthwise conv(width w) on [x,B,C], silu, SSD, RMS-norm gate with z,
out_proj.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def dims(cfg):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    nh = cfg.num_heads
    hd = di // nh
    ns = cfg.ssm.state_dim
    return d, di, nh, hd, ns


def init_mamba(key, cfg):
    d, di, nh, hd, ns = dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    conv_ch = di + 2 * ns
    return {
        "ln": jnp.ones((d,), dt),
        # three separate projections, NOT one fused w_in: slicing a fused
        # [d, 2di+2ns+nh] output at z|xBC|dt boundaries cuts across model-
        # axis shard boundaries and makes SPMD reshard each slice with
        # f32 collective-permutes (~270 GB/chip at prefill_32k; §Perf pair B
        # iteration 4) — separate weights shard independently, no reshard
        "w_z": L.dense_init(ks[0], d, di, dt),
        "w_xbc": L.dense_init(ks[3], d, di + 2 * ns, dt),
        "w_dt": L.dense_init(ks[4], d, nh, dt),
        "conv": (jax.random.normal(ks[1], (cfg.ssm.conv_width, conv_ch),
                                   jnp.float32) / math.sqrt(cfg.ssm.conv_width)
                 ).astype(dt),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "w_out": L.dense_init(ks[2], di, d, dt, scale=1.0 / math.sqrt(di)),
    }


def mamba_pspecs():
    return {"ln": (None,), "w_z": ("embed", "ssm_inner"),
            "w_xbc": ("embed", "ssm_inner"), "w_dt": ("embed", None),
            "conv": (None, None),
            "a_log": (None,), "d_skip": (None,), "dt_bias": (None,),
            "w_out": ("ssm_inner", "embed")}


def _causal_conv(u, w, state=None):
    """Depthwise causal conv.  u: [B,S,C]; w: [K,C].  state: [B,K-1,C] or None.

    Returns (out [B,S,C], new_state [B,K-1,C])."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], K - 1, u.shape[-1]), u.dtype)
    up = jnp.concatenate([state, u], axis=1)
    out = jnp.zeros_like(u)
    for k in range(K):
        out = out + up[:, k:k + u.shape[1]] * w[k]
    return out, up[:, -(K - 1):] if K > 1 else state


def _ssd_chunked(x, dtv, A, Bm, Cm, chunk):
    """x: [B,S,H,D]; dtv: [B,S,H] (>0); A: [H] (<0); Bm,Cm: [B,S,N].

    Returns (y [B,S,H,D], final_state [B,H,D,N])."""
    Bsz, S, H, D = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    xr = x.reshape(Bsz, nc, c, H, D)
    dtr = dtv.reshape(Bsz, nc, c, H)
    Br = Bm.reshape(Bsz, nc, c, N)
    Cr = Cm.reshape(Bsz, nc, c, N)

    dA = dtr * A[None, None, None, :]               # [B,nc,c,H]  (<0)
    cums = jnp.cumsum(dA, axis=2)
    tot = cums[:, :, -1, :]

    # intra-chunk: y[t] += sum_{s<=t} exp(cums_t - cums_s) dt_s (C_t.B_s) x_s
    expo = cums[:, :, :, None, :] - cums[:, :, None, :, :]     # [B,nc,t,s,H]
    tri = jnp.tril(jnp.ones((c, c), bool))
    w = jnp.where(tri[None, None, :, :, None], jnp.exp(expo), 0.0)
    cb = jnp.einsum("bntk,bnsk->bnts", Cr, Br)                  # [B,nc,t,s]
    aw = (w * cb[..., None] * dtr[:, :, None, :, :]).astype(x.dtype)
    y_intra = jnp.einsum("bntsh,bnshd->bnthd", aw, xr)

    # chunk boundary states: S_n = sum_s exp(tot - cums_s) dt_s x_s B_s^T
    wS = (jnp.exp(tot[:, :, None, :] - cums) * dtr).astype(x.dtype)
    Sn = jnp.einsum("bnsh,bnshd,bnsk->bnhdk", wS, xr, Br)

    def body(h, xs):
        Sn_i, tot_i = xs
        hprev = h
        h = h * jnp.exp(tot_i)[:, :, None, None].astype(h.dtype) + Sn_i
        return h, hprev

    h0 = jnp.zeros((Bsz, H, D, N), x.dtype)
    hT, hprevs = jax.lax.scan(body, h0, (jnp.moveaxis(Sn, 1, 0),
                                         jnp.moveaxis(tot, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)             # [B,nc,H,D,N]

    wq = jnp.exp(cums).astype(x.dtype)              # decay from chunk start
    y_inter = jnp.einsum("bnth,bntk,bnhdk->bnthd", wq, Cr, hprevs)
    y = (y_intra + y_inter).reshape(Bsz, S, H, D)
    return y, hT


def mamba_block(p, cfg, x, state=None):
    """x: [B,S,d] -> [B,S,d].  state (decode): {"h":[B,H,D,N], "conv":[B,K-1,C]}"""
    d, di, nh, hd, ns = dims(cfg)
    B, S, _ = x.shape
    xin = L.rms_norm(x, p["ln"])
    z = xin @ p["w_z"]
    xbc = xin @ p["w_xbc"]
    dtp = xin @ p["w_dt"]
    conv_out, _ = _causal_conv(xbc, p["conv"])
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :di].reshape(B, S, nh, hd)
    Bm = conv_out[..., di:di + ns]
    Cm = conv_out[..., di + ns:]
    dtv = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    y, _ = _ssd_chunked(xs, dtv, A, Bm, Cm, cfg.ssm.chunk)
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    # NOTE (§Perf pair B iteration 3, REFUTED): an optimization_barrier here
    # (hypothesis: XLA hoists the next norm's f32 upcast past the SPMD
    # all-reduce) left all three roofline terms exactly unchanged — the f32
    # residual all-reduce is intrinsic to how SPMD places this block, not a
    # convert-hoisting artifact.  Reverted.
    return x + y @ p["w_out"]


def mamba_decode(p, cfg, x, state):
    """Single-token step.  x: [B,1,d]."""
    d, di, nh, hd, ns = dims(cfg)
    B = x.shape[0]
    xin = L.rms_norm(x, p["ln"])[:, 0]
    z = xin @ p["w_z"]
    xbc = xin @ p["w_xbc"]
    dtp = xin @ p["w_dt"]
    conv_out, conv_state = _causal_conv(xbc[:, None, :], p["conv"],
                                        state["conv"])
    conv_out = jax.nn.silu(conv_out[:, 0])
    xs = conv_out[..., :di].reshape(B, nh, hd)
    Bm = conv_out[..., di:di + ns]
    Cm = conv_out[..., di + ns:]
    dtv = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])   # [B,H]
    A = -jnp.exp(p["a_log"])
    dA = jnp.exp(dtv * A)                                            # [B,H]
    h = state["h"] * dA[:, :, None, None].astype(state["h"].dtype) \
        + (dtv.astype(xs.dtype))[:, :, None, None] * xs[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhdk,bk->bhd", h, Cm) + xs * p["d_skip"][None, :, None].astype(xs.dtype)
    y = y.reshape(B, 1, di) * jax.nn.silu(z)[:, None]
    return x + y @ p["w_out"], {"h": h, "conv": conv_state}


def init_mamba_state(batch, cfg):
    d, di, nh, hd, ns = dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    return {"h": jnp.zeros((batch, nh, hd, ns), dt),
            "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, di + 2 * ns), dt)}
