"""xLSTM blocks (sLSTM + mLSTM, arXiv:2405.04517), TPU-adapted.

Structure per the paper: a stack interleaving

* **mLSTM blocks** — matrix-memory LSTM: per head, state
  ``C_t = f_t C_{t-1} + i_t v_t k_t^T``, normalizer ``n_t = f_t n_{t-1} + i_t k_t``,
  output ``h_t = C_t q_t / max(|n_t . q_t|, 1)``.  Fully parallelizable; we
  compute it **chunkwise** (intra-chunk quadratic + inter-chunk scanned state),
  which is the TPU-native formulation (MXU-friendly [c x c] blocks instead of a
  length-S sequential loop).
* **sLSTM blocks** — scalar-memory LSTM with per-head recurrent mixing
  ``R h_{t-1}``; inherently sequential, computed with ``lax.scan`` over time.

Hardware adaptation (recorded per DESIGN.md): the paper's *exponential* input
gate is replaced by a sigmoid (log-gate clipped <= 0).  This removes the
running-max stabilizer state while preserving the matrix-memory/normalizer
recurrence; on TPU it avoids f32 overflow in the chunkwise exp() terms.

Pattern: layer ``l`` is sLSTM iff ``l % slstm_every == 0`` (cfg.ssm.slstm_every
> 0), expressed as a scanned super-block of ``slstm_every`` layers.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ---------------------------------------------------------------------------
# mLSTM cell (chunkwise parallel)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d, nh = cfg.d_model, cfg.num_heads
    hd = d // nh
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.ones((d,), dt),
        "w_up": L.dense_init(ks[0], d, 2 * d, dt),
        "wq": L.dense_init(ks[1], d, d, dt),
        "wk": L.dense_init(ks[2], d, d, dt),
        "wv": L.dense_init(ks[3], d, d, dt),
        "w_if": L.dense_init(ks[4], d, 2 * nh, dt),   # input & forget pre-gates
        "w_down": L.dense_init(ks[5], d, d, dt, scale=1.0 / math.sqrt(d)),
    }


def mlstm_pspecs():
    return {"ln": (None,), "w_up": ("embed", "mlp"), "wq": ("embed", "heads"),
            "wk": ("embed", "heads"), "wv": ("embed", "heads"),
            "w_if": ("embed", None), "w_down": ("heads", "embed")}


def _mlstm_scan_chunks(q, k, v, log_f, log_i, chunk):
    """q,k,v: [B,S,H,D]; log_f/log_i: [B,S,H] (<= 0).  Returns h [B,S,H,D]."""
    B, S, H, D = q.shape
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    qc = q.reshape(B, nc, c, H, D)
    kc = k.reshape(B, nc, c, H, D)
    vc = v.reshape(B, nc, c, H, D)
    lf = log_f.reshape(B, nc, c, H)
    li = log_i.reshape(B, nc, c, H)
    F = jnp.cumsum(lf, axis=2)                      # within-chunk decay prefix
    Ftot = F[:, :, -1, :]                           # [B,nc,H]

    # intra-chunk: att[t,s] = exp(F_t - F_s + li_s) * (q_t . k_s), s <= t
    expo = F[:, :, :, None, :] - F[:, :, None, :, :] + li[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    w = jnp.where(tri[None, None, :, :, None], jnp.exp(expo), 0.0)  # [B,nc,t,s,H]
    qk = jnp.einsum("bnthd,bnshd->bntsh", qc, kc).astype(jnp.float32)
    aw = w * qk / math.sqrt(D)
    y_intra = jnp.einsum("bntsh,bnshd->bnthd", aw.astype(q.dtype), vc)
    # normalizer intra part: n_t . q_t = sum_s w[t,s] * (k_s . q_t)
    denom_intra = jnp.sum(aw, axis=3)               # [B,nc,t,H]

    # per-chunk boundary contributions: S_c = sum_s exp(Ftot - F_s + li_s) k_s v_s^T
    wS = jnp.exp(Ftot[:, :, None, :] - F + li)       # [B,nc,c,H]
    Sc = jnp.einsum("bnsh,bnshd,bnshe->bnhde", wS.astype(q.dtype), kc, vc)
    nSc = jnp.einsum("bnsh,bnshd->bnhd", wS.astype(q.dtype), kc)

    # inter-chunk recurrence over nc chunks
    def body(carry, xs):
        Cprev, nprev = carry
        Sc_i, nSc_i, Ftot_i = xs
        dec = jnp.exp(Ftot_i)[:, :, None, None].astype(Cprev.dtype)
        Cn = Cprev * dec + Sc_i
        nn = nprev * dec[:, :, :, 0] + nSc_i
        return (Cn, nn), (Cprev, nprev)

    C0 = jnp.zeros((B, H, D, D), q.dtype)
    n0 = jnp.zeros((B, H, D), q.dtype)
    xs = (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(nSc, 1, 0),
          jnp.moveaxis(Ftot, 1, 0))
    (_, _), (Cprevs, nprevs) = jax.lax.scan(body, (C0, n0), xs)
    Cprevs = jnp.moveaxis(Cprevs, 0, 1)             # [B,nc,H,D,D] state before chunk
    nprevs = jnp.moveaxis(nprevs, 0, 1)

    wq_in = jnp.exp(F)                               # decay from chunk start
    y_inter = jnp.einsum("bnth,bnthd,bnhde->bnthe",
                         wq_in.astype(q.dtype), qc, Cprevs) / math.sqrt(D)
    denom_inter = jnp.einsum("bnth,bnthd,bnhd->bnth",
                             wq_in.astype(q.dtype), qc, nprevs) / math.sqrt(D)

    y = y_intra + y_inter
    denom = jnp.maximum(jnp.abs(denom_intra + denom_inter.astype(jnp.float32)), 1.0)
    h = y / denom[..., None].astype(y.dtype)
    return h.reshape(B, S, H, D)


def mlstm_block(p, cfg, x):
    """x: [B,S,d] -> [B,S,d]."""
    B, S, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    xin = L.rms_norm(x, p["ln"])
    up = xin @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)
    q = (u @ p["wq"]).reshape(B, S, nh, hd)
    k = (u @ p["wk"]).reshape(B, S, nh, hd)
    v = (u @ p["wv"]).reshape(B, S, nh, hd)
    gates = (u @ p["w_if"]).astype(jnp.float32)
    li = jax.nn.log_sigmoid(gates[..., :nh])
    lf = jax.nn.log_sigmoid(gates[..., nh:])
    h = _mlstm_scan_chunks(q, k, v, lf, li, cfg.ssm.chunk)
    out = (h.reshape(B, S, d) * jax.nn.silu(z)) @ p["w_down"]
    return x + out


def mlstm_decode(p, cfg, x, state):
    """Single step. x: [B,1,d]; state: {"C":[B,H,D,D], "n":[B,H,D]}."""
    B, _, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    xin = L.rms_norm(x, p["ln"])
    up = xin @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)
    u1 = u[:, 0]
    q = (u1 @ p["wq"]).reshape(B, nh, hd)
    k = (u1 @ p["wk"]).reshape(B, nh, hd)
    v = (u1 @ p["wv"]).reshape(B, nh, hd)
    gates = (u1 @ p["w_if"]).astype(jnp.float32)
    i = jax.nn.sigmoid(gates[..., :nh])[..., None]
    f = jax.nn.sigmoid(gates[..., nh:])[..., None]
    C = state["C"] * f[..., None].astype(state["C"].dtype) + \
        (i.astype(v.dtype))[..., None] * v[..., :, None] * k[..., None, :]
    n = state["n"] * f.astype(state["n"].dtype) + i.astype(k.dtype) * k
    num = jnp.einsum("bhd,bhed->bhe", q, C) / math.sqrt(hd)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)) / math.sqrt(hd), 1.0)
    h = (num / den[..., None]).reshape(B, 1, d)
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    return x + out, {"C": C, "n": n}


def init_mlstm_state(batch, cfg):
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    dt = jnp.dtype(cfg.dtype)
    return {"C": jnp.zeros((batch, nh, hd, hd), dt),
            "n": jnp.zeros((batch, nh, hd), dt)}


# ---------------------------------------------------------------------------
# sLSTM cell (sequential scan)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d, nh = cfg.d_model, cfg.num_heads
    hd = d // nh
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.ones((d,), dt),
        "w": L.dense_init(ks[0], d, 4 * d, dt),          # z,i,f,o pre-acts
        "r": (jax.random.normal(ks[1], (nh, hd, 4 * hd), jnp.float32)
              / math.sqrt(hd)).astype(dt),               # recurrent per head
        "w_down": L.dense_init(ks[2], d, d, dt, scale=1.0 / math.sqrt(d)),
    }


def slstm_pspecs():
    return {"ln": (None,), "w": ("embed", None), "r": ("heads", None, None),
            "w_down": ("embed", "embed")}


def _slstm_step(p, cfg, wx_t, state):
    """wx_t: [B, 4d] precomputed input part; state h/c/n: [B,H,D]."""
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    B = wx_t.shape[0]
    h_prev = state["h"]
    rec = jnp.einsum("bhd,hde->bhe", h_prev, p["r"])     # [B,H,4hd]
    pre = wx_t.reshape(B, nh, 4 * hd) + rec
    z, i, f, o = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)
    c = f * state["c"].astype(jnp.float32) + i * z
    n = f * state["n"].astype(jnp.float32) + i
    h = o * c / jnp.maximum(n, 1.0)
    dt = state["h"].dtype
    return {"h": h.astype(dt), "c": c.astype(dt), "n": n.astype(dt)}


def slstm_block(p, cfg, x):
    B, S, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    xin = L.rms_norm(x, p["ln"])
    wx = xin @ p["w"]                                    # [B,S,4d]
    state = init_slstm_state(B, cfg)

    def body(st, wx_t):
        st = _slstm_step(p, cfg, wx_t, st)
        return st, st["h"]

    _, hs = jax.lax.scan(body, state, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d)
    return x + h @ p["w_down"]


def slstm_decode(p, cfg, x, state):
    xin = L.rms_norm(x, p["ln"])
    wx = (xin @ p["w"])[:, 0]
    st = _slstm_step(p, cfg, wx, state)
    h = st["h"].reshape(x.shape[0], 1, cfg.d_model)
    return x + h @ p["w_down"], st


def init_slstm_state(batch, cfg):
    nh = cfg.num_heads
    hd = cfg.d_model // nh
    dt = jnp.dtype(cfg.dtype)
    z = jnp.zeros((batch, nh, hd), dt)
    return {"h": z, "c": z, "n": z}
