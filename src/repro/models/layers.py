"""Shared neural-net building blocks (pure functional JAX).

Parameters are nested dicts of arrays.  Every ``init_*`` has a matching
``*_pspecs`` producing the same tree of *logical axis tuples* (resolved to
PartitionSpecs by models/sharding.py), so abstract initialisation via
``jax.eval_shape`` and sharding stay in lock-step by construction.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return truncated_normal(key, (d_in, d_out), scale, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    # NOTE (§Perf internlm2-20b iters 1-2, both REFUTED): replacing the f32
    # elementwise chain with bf16 math + f32-accumulated statistics
    # *increased* HLO bytes-accessed (23.9 -> 25.6 -> 28.6 s memory term):
    # XLA CSEs the all-f32 formulation across fwd/bwd/remat better than the
    # mixed-dtype one. Keep the numerically-stronger f32 form.
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (full and partial / "2d" chatglm style)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim, theta, fraction=1.0):
    """cos/sin tables for (possibly partial) RoPE.

    fraction < 1 (chatglm's 2-D RoPE) rotates only the first
    ``fraction * head_dim`` dims, leaving the rest unrotated.
    """
    rot = int(head_dim * fraction)
    rot -= rot % 2
    freqs = theta ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., rot/2]
    return jnp.cos(ang), jnp.sin(ang), rot


def apply_rope(x, cos, sin, rot):
    """x: [..., S, H, D]; cos/sin: [..., S, rot/2] broadcast over heads."""
    xr, xp = x[..., :rot], x[..., rot:]
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    c = cos[..., None, :]
    s = sin[..., None, :]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (full causal, sliding window, cross, cached decode)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, d_model=None, cross=False, dtype=jnp.bfloat16):
    """QKV/O weights kept 3-D ``[d, heads, head_dim]`` (O: ``[h, hd, d]``).

    The head axis is the TP axis; ``head_dim`` is NEVER sharded.  With
    flattened 2-D ``[d, nh*hd]`` weights SPMD splits the 16-way model axis
    across head boundaries and partitions the *contracted* head_dim of the
    QK einsum — producing partial-sum all-reduces of the full [B,h,S,S]
    score matrix (measured: the dominant collective for every GQA arch;
    EXPERIMENTS.md §Perf). 3-D weights shard cleanly on heads when
    divisible and fall back to replication (safe_pspec) when not.
    """
    d = d_model or cfg.d_model
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], d, nh * hd, dtype).reshape(d, nh, hd),
        "wk": dense_init(ks[1], d, nkv * hd, dtype).reshape(d, nkv, hd),
        "wv": dense_init(ks[2], d, nkv * hd, dtype).reshape(d, nkv, hd),
        "wo": dense_init(ks[3], nh * hd, d, dtype,
                         scale=1.0 / math.sqrt(nh * hd)).reshape(nh, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh, hd), dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype)
    return p


def attention_pspecs(cfg):
    s = {"wq": ("embed", "heads", None), "wk": ("embed", "kv", None),
         "wv": ("embed", "kv", None), "wo": ("heads", None, "embed")}
    if cfg.qkv_bias:
        s.update({"bq": ("heads", None), "bk": ("kv", None),
                  "bv": ("kv", None)})
    return s


def _project_qkv(p, cfg, x):
    q = jnp.einsum("...d,dnh->...nh", x, p["wq"])
    k = jnp.einsum("...d,dnh->...nh", x, p["wk"])
    v = jnp.einsum("...d,dnh->...nh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _gqa_expand(k, nh):
    nkv = k.shape[-2]
    if nkv == nh:
        return k
    return jnp.repeat(k, nh // nkv, axis=-2)


def _sdpa(q, k, v, mask, scale):
    """q [.., Sq, H, D], k/v [.., Sk, H, D], mask [.., 1|H, Sq, Sk] bool."""
    scores = jnp.einsum("...qhd,...khd->...hqk", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("...hqk,...khd->...qhd", w, v)


def _banded_sdpa(q, k, v, window, scale, q_chunk=1024):
    """Causal sliding-window attention computed band-wise.

    Full-matrix windowed attention still materialises [Sq, Sk] scores and
    masks most of them away; for query chunk [q0, q0+c) only keys in
    (q0-window, q0+c) can be attended, so per-chunk scores are
    [c, window+c] and total score bytes drop from S^2 to S*(window+c)
    (zamba2 prefill_32k: 4x; EXPERIMENTS.md §Perf).  Python loop over
    <= S/c chunks keeps the HLO flat (no while-body undercount).

    q, k, v: [.., S, H, D] self-attention at aligned positions.
    """
    S = q.shape[-3]
    c = min(q_chunk, S)
    if S % c or window <= 0 or S <= window + c:
        mask = causal_mask(S, S, window)
        return _sdpa(q, k, v, mask, scale)
    band = window + c
    pad = [(0, 0)] * (k.ndim - 3) + [(window, 0), (0, 0), (0, 0)]
    kp = jnp.pad(k, pad)
    vp = jnp.pad(v, pad)
    # per-chunk relative mask: query t = q0+ti attends key j = q0-window+ki
    # iff ki <= ti + window (causal) and ki > ti (window) and ki-window+q0>=0
    ti = jnp.arange(c)[:, None]
    ki = jnp.arange(band)[None, :]
    rel_ok = (ki <= ti + window) & (ki > ti)
    outs = []
    for i in range(S // c):
        q0 = i * c
        qc = jax.lax.slice_in_dim(q, q0, q0 + c, axis=-3)
        kc = jax.lax.slice_in_dim(kp, q0, q0 + band, axis=-3)
        vc = jax.lax.slice_in_dim(vp, q0, q0 + band, axis=-3)
        valid = rel_ok & (ki + q0 - window >= 0)       # clip left padding
        outs.append(_sdpa(qc, kc, vc, valid[None], scale))
    return jnp.concatenate(outs, axis=-3)


def causal_mask(sq, sk, window=0, offset=0):
    """bool [sq, sk]; query i attends keys j with j <= i+offset and
    (window == 0 or j > i+offset-window)."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window:
        m = m & (kj > qi - window)
    return m


def _context_parallel_kv(k, v, nh):
    """Fallback sharding when heads don't divide the model axis.

    Without this, the 3-D head-sharded weights replicate attention on every
    model rank (scores bytes x model_size).  Constraining the *KV sequence*
    dim onto the model axis makes SPMD derive context-parallel attention:
    scores sharded over the key dim, softmax with tiny stat all-reduces, one
    small all-reduce of the [.., S_q, nh, hd] output — measured on
    whisper-base x prefill_32k in EXPERIMENTS.md §Perf.
    No-op outside a launcher constraint context (see models/sharding.py).
    """
    from repro.models import sharding as SH
    if nh % max(SH.mesh_axis_size("model"), 1) == 0:
        return k, v                       # heads shard cleanly: leave it
    k = SH.constrain(k, None, "kv_seq", None, None)
    v = SH.constrain(v, None, "kv_seq", None, None)
    return k, v


def attention(p, cfg, x, positions, *, window=0, cross_kv=None, bidir=False):
    """Self (causal / windowed / bidirectional) or cross attention.

    x: [..., S, d]; positions: [..., S] absolute.  cross_kv: (k, v) already
    projected from the encoder (whisper decoder).
    """
    nh, hd = cfg.num_heads, cfg.hd
    q, k, v = _project_qkv(p, cfg, x)
    if cross_kv is not None:
        k, v = cross_kv
    elif cfg.rope_fraction > 0:
        cos, sin, rot = rope_cos_sin(positions, hd, cfg.rope_theta, cfg.rope_fraction)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)
    k = _gqa_expand(k, nh)
    v = _gqa_expand(v, nh)
    k, v = _context_parallel_kv(k, v, nh)
    sq, sk = q.shape[-3], k.shape[-3]
    scale = 1.0 / math.sqrt(hd)
    if cfg.flash_attention and cross_kv is None and not bidir:
        from repro.kernels import ops as kops
        out = kops.flash_sdpa(q, k, v, scale=scale, causal=True,
                              window=window)
    elif cross_kv is not None or bidir:
        out = _sdpa(q, k, v, jnp.ones((sq, sk), bool), scale)
    elif window and sq == sk and sq > 2 * window:
        out = _banded_sdpa(q, k, v, window, scale,
                           q_chunk=max(min(window, 1024), 128))
    else:
        out = _sdpa(q, k, v, causal_mask(sq, sk, window), scale)
    return jnp.einsum("...nh,nhd->...d", out, p["wo"])


def attention_decode(p, cfg, x, cache, pos, *, window=0, cross=False):
    """Single-token cached decode.  x: [..., 1, d]; pos: [] int32 (count of
    tokens already in the cache; the new token's absolute position).

    cache: {"k","v": [..., W, nkv, hd]} with W = ring-buffer length (the
    sliding window, or the full context for dense caches).
    cross=True: attend over a pre-filled cache without writing (whisper
    cross-attention; "pos" then is the encoder length).
    Returns (out, new_cache).
    """
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q, k, v = _project_qkv(p, cfg, x)
    if not cross and cfg.rope_fraction > 0:
        cos, sin, rot = rope_cos_sin(jnp.reshape(pos, (1,)), hd,
                                     cfg.rope_theta, cfg.rope_fraction)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)
    W = cache["k"].shape[-3]
    if cross:
        ck, cv = cache["k"], cache["v"]
        valid = jnp.arange(W) < pos
    else:
        slot = jnp.mod(pos, W)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=-3)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=-3)
        # absolute position currently stored in each slot
        slot_ids = jnp.arange(W)
        slot_pos = pos - jnp.mod(pos - slot_ids, W)
        valid = (slot_pos >= 0) & (slot_pos <= pos)
        if window:
            valid &= slot_pos > pos - window
    kk = _gqa_expand(ck, nh)
    vv = _gqa_expand(cv, nh)
    kk, vv = _context_parallel_kv(kk, vv, nh)
    mask = valid[None, None, :]                       # [1(h), 1(q), W]
    out = _sdpa(q, kk, vv, mask, 1.0 / math.sqrt(hd))
    out = jnp.einsum("...nh,nhd->...d", out, p["wo"])
    return out, {"k": ck, "v": cv}


def init_attn_cache(batch_dims, cfg, length, dtype):
    nkv, hd = cfg.num_kv_heads, cfg.hd
    shape = (*batch_dims, length, nkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d, f, gated, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, f, dtype),
         "w_down": dense_init(ks[1], f, d, dtype, scale=1.0 / math.sqrt(f))}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, f, dtype)
    return p


def mlp_pspecs(gated):
    s = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if gated:
        s["w_gate"] = ("embed", "mlp")
    return s


def mlp(p, x, gated):
    h = x @ p["w_up"]
    if gated:
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["w_down"]
