"""A-priori consensus bounds ``theta`` and quantizer settings from the theory.

The paper's convergence theorems prescribe, per algorithm:

* Theorem 2 (D-PSGD):   theta_k = 2 a_k G_inf C_a log(16 n) / (1 - eta rho)
                        delta  = (1-eta rho) / (8 C_a^2 eta log(16 n) + 2 (1-eta rho))
* Theorem 3 (1-bit):    slack matrix W_bar = gamma W + (1-gamma) I with
                        gamma = 2 / ((1-rho) + 16 d2 * 64 log(4n) log(K) / (1-rho)),
                        d2 = delta^2/(1-2 delta)^2 ;  theta = 2 a G log(16n)/(gamma (1-rho))
* Theorem 4 (D^2):      theta = (6 D1 n + 8) a G_inf ;  delta = 1/(12 n D2 + 2)
* Theorem 5 (AD-PSGD):  theta = 16 t_mix a G_inf     ;  delta = 1/(64 t_mix + 2)

plus the dimension-free bits bound (Sec. 4)

    B <= ceil(log2(4 log2(16 n) / (1 - rho) + 3)).

In practice (paper Sec. 6) a constant theta (they used 2.0) tuned once from a few
epochs of ``||g||_inf`` tracking works; ``ThetaSchedule`` supports both.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.topology import Topology


def theta_dpsgd(alpha: float, g_inf: float, n: int, rho: float,
                c_alpha: float = 1.0, eta: float = 1.0) -> float:
    """Theorem 2 theta_k (constant step size => C_a = eta = 1)."""
    return 2.0 * alpha * g_inf * c_alpha * np.log(16.0 * n) / (1.0 - eta * rho)


def delta_dpsgd(n: int, rho: float, c_alpha: float = 1.0, eta: float = 1.0) -> float:
    gap = 1.0 - eta * rho
    return gap / (8.0 * c_alpha ** 2 * eta * np.log(16.0 * n) + 2.0 * gap)


def bits_bound(n: int, rho: float) -> int:
    """Sec. 4 'Bound on the Bits' — independent of model dimension d."""
    return int(np.ceil(np.log2(4.0 * np.log2(16.0 * n) / (1.0 - rho) + 3.0)))


def gamma_slack(delta: float, n: int, K: int, rho: float) -> float:
    """Theorem 3's averaging ratio gamma for extreme bit budgets."""
    d2 = delta ** 2 / (1.0 - 2.0 * delta) ** 2
    denom = (1.0 - rho) + 16.0 * d2 * 64.0 * np.log(4.0 * n) * np.log(max(K, 2)) / (1.0 - rho)
    return min(1.0, 2.0 / denom)


def theta_slack(alpha: float, g_inf: float, n: int, rho: float, gamma: float) -> float:
    return 2.0 * alpha * g_inf * np.log(16.0 * n) / (gamma * (1.0 - rho))


def _d2_constants(topo: Topology) -> tuple[float, float]:
    """D1, D2 from Lemma 12 (depend only on eigenvalues of W)."""
    ev = np.sort(np.linalg.eigvalsh(topo.matrix))
    lam2 = float(ev[-2]) if topo.n > 1 else 0.0
    lam_n = float(ev[0])
    lam2 = min(max(lam2, 0.0), 1.0 - 1e-9)
    if lam_n <= -1.0 / 3.0 + 1e-12:
        raise ValueError(f"D^2 requires lambda_n > -1/3, got {lam_n} "
                         f"(use a lazier W, e.g. slack matrix)")
    vn = lam_n - np.sqrt(lam_n ** 2 - lam_n) if lam_n < 0 else 0.0
    avn = abs(vn)
    d1 = max(avn + 2 * abs(lam_n) / (1 - avn) if avn < 1 else np.inf,
             np.sqrt(lam2 / (1 - lam2)) + 2 * lam2 / (1 - lam2))
    d2 = max(2.0 / (1 - avn), 2.0 / np.sqrt(1 - lam2))
    return float(d1), float(d2)


def theta_d2(alpha: float, g_inf: float, topo: Topology) -> float:
    d1, _ = _d2_constants(topo)
    return (6.0 * d1 * topo.n + 8.0) * alpha * g_inf


def delta_d2(topo: Topology) -> float:
    _, d2 = _d2_constants(topo)
    return 1.0 / (12.0 * topo.n * d2 + 2.0)


def theta_adpsgd(alpha: float, g_inf: float, t_mix: float) -> float:
    return 16.0 * t_mix * alpha * g_inf


def delta_adpsgd(t_mix: float) -> float:
    return 1.0 / (64.0 * t_mix + 2.0)


@dataclasses.dataclass
class ThetaSchedule:
    """Runtime theta policy.

    mode:
      "constant" -- fixed ``value`` (paper Sec. 6 used 2.0 throughout).
      "theory"   -- Theorem-2 expression from the tracked ``g_inf`` estimate.
    The trainer tracks a running max of ``||g||_inf`` (a scalar — Moniqua's
    zero-*additional-memory* claim concerns O(d)/O(nd) state, not O(1)).
    """
    mode: str = "constant"
    value: float = 2.0
    n: int = 8
    rho: float = 0.99
    c_alpha: float = 1.0
    eta: float = 1.0

    def __call__(self, alpha: float, g_inf: float) -> float:
        if self.mode == "constant":
            return self.value
        if self.mode == "theory":
            import jax.numpy as jnp
            g = jnp.maximum(g_inf, 1e-8)   # g_inf is traced under jit
            return theta_dpsgd(alpha, g, self.n, self.rho,
                               self.c_alpha, self.eta)
        raise ValueError(f"unknown theta mode {self.mode!r}")
