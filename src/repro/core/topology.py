"""Communication topologies for decentralized SGD.

All built-in topologies are **circulant**: worker ``i`` averages from workers
``i + o (mod n)`` for a fixed offset set ``o in offsets`` with weights ``w``.
Circulant W matrices are symmetric doubly-stochastic whenever the offset set is
symmetric (``-o`` present with equal weight), which covers:

* ring            offsets {-1, 0, +1}
* torus (rows x cols)   offsets {0, ±1, ±rows} on the flattened 2-D grid
* exponential graph     offsets {0, ±1, ±2, ±4, ...}
* fully connected       all offsets, weight 1/n

Circulance is what lets the TPU mapping express gossip as a small number of
``jnp.roll``s along the (sharded) worker axis, each lowering to a single
``collective-permute`` (see comm/gossip.py).

Also provides the slack matrix ``W_bar = gamma W + (1-gamma) I`` (Theorem 3),
spectral gap ``rho``, and the Markov-chain mixing-time bound
``t_mix <= log(4n) / (1 - rho)`` (Supp. E).

Elastic rounds: ``Topology.with_presence(mask)`` renormalizes the mixing
weights over the workers that actually showed up (absent workers keep
self-weight 1, W stays symmetric doubly stochastic), and
``TimeVaryingTopology`` holds a per-round matrix schedule with a *joint*
spectral gap over one window, so ``ThetaSchedule`` consuming ``rho`` stays
honest under churn.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """A circulant gossip topology over ``n`` workers."""
    name: str
    n: int
    offsets: Tuple[int, ...]   # includes 0 (self)
    weights: Tuple[float, ...]

    def __post_init__(self):
        if len(self.offsets) != len(self.weights):
            raise ValueError("offsets/weights length mismatch")
        if abs(sum(self.weights) - 1.0) > 1e-9:
            raise ValueError(f"weights must sum to 1, got {sum(self.weights)}")
        woff: Dict[int, float] = {}
        for o, w in zip(self.offsets, self.weights):
            woff[o % self.n] = woff.get(o % self.n, 0.0) + w
        for o, w in list(woff.items()):
            if abs(woff.get((-o) % self.n, 0.0) - w) > 1e-9:
                raise ValueError("offset set must be symmetric for symmetric W")

    @property
    def matrix(self) -> np.ndarray:
        """Dense ``W`` with ``W[j, i]`` = weight worker *i* puts on worker *j*.

        Circulant: row j, col i nonzero iff ``j - i ≡ o (mod n)``.
        """
        W = np.zeros((self.n, self.n))
        for o, w in zip(self.offsets, self.weights):
            for i in range(self.n):
                W[(i + o) % self.n, i] += w
        return W

    @property
    def rho(self) -> float:
        """Spectral gap parameter: second-largest absolute eigenvalue (A2)."""
        ev = np.sort(np.abs(np.linalg.eigvalsh(self.matrix)))[::-1]
        return float(ev[1]) if self.n > 1 else 0.0

    @property
    def phi(self) -> float:
        """Smallest nonzero entry of W (Theorem 1's phi)."""
        W = self.matrix
        nz = W[W > 1e-12]
        return float(nz.min()) if nz.size else 0.0

    @property
    def t_mix_bound(self) -> float:
        """Supp. E: ``t_mix <= log(4n) / (1 - rho)`` for reversible chains."""
        gap = 1.0 - self.rho
        if gap <= 0:
            return float("inf")
        return float(np.log(4 * self.n) / gap)

    def neighbor_offsets(self) -> Tuple[int, ...]:
        return tuple(o for o in self.offsets if o % self.n != 0)

    def slack(self, gamma: float) -> "Topology":
        """``W_bar = gamma W + (1 - gamma) I`` (Theorem 3 consensus step)."""
        woff: Dict[int, float] = {}
        for o, w in zip(self.offsets, self.weights):
            woff[o % self.n] = woff.get(o % self.n, 0.0) + gamma * w
        woff[0] = woff.get(0, 0.0) + (1.0 - gamma)
        offs = tuple(sorted(woff))
        return Topology(f"{self.name}-slack{gamma:g}", self.n, offs,
                        tuple(woff[o] for o in offs))

    def with_presence(self, mask: Sequence[int]) -> "MaskedTopology":
        """Renormalize the round over the workers that showed up.

        An edge survives only if *both* endpoints are present; the weight
        a worker loses from dead edges folds back into its self-weight, so
        W' stays symmetric doubly stochastic and an absent worker's row is
        exactly the identity (self-weight 1).  Full presence reproduces
        ``self.matrix`` bit-exactly (the compensation term is exactly 0).
        """
        return MaskedTopology(base=self, presence=normalize_mask(mask,
                                                                 self.n))


def ring(n: int, self_weight: float | None = None) -> Topology:
    """Bidirectional ring. Default uniform 1/3 weights (paper's experiments)."""
    if n == 1:
        return Topology("ring", 1, (0,), (1.0,))
    if n == 2:
        sw = 0.5 if self_weight is None else self_weight
        return Topology("ring", 2, (0, 1), (sw, 1.0 - sw))
    sw = 1.0 / 3.0 if self_weight is None else self_weight
    nw = (1.0 - sw) / 2.0
    return Topology("ring", n, (-1, 0, 1), (nw, sw, nw))


def torus(rows: int, cols: int) -> Topology:
    """2-D torus on ``rows*cols`` workers flattened row-major; 1/5 weights."""
    n = rows * cols
    if rows < 3 or cols < 3:
        raise ValueError("torus needs rows, cols >= 3 for distinct offsets")
    offs = (-cols, -1, 0, 1, cols)
    w = 1.0 / len(offs)
    return Topology("torus", n, offs, tuple([w] * len(offs)))


def exponential(n: int) -> Topology:
    """Exponential graph: hops ±2^j; O(log n) degree, small rho.

    Built by an explicit dedupe-mod-n loop: for each hop ``h = 2^j`` up to
    ``n // 2`` try both ``+h`` and ``-h`` and keep an offset only if its
    residue mod n is new.  The self-inverse hop ``h = n/2`` (even n) falls
    out naturally — ``-h ≡ +h (mod n)`` so the second direction dedupes —
    as does ``n = 2`` where ``+1 ≡ -1``.  The offset set is symmetric mod
    n by construction, so W is symmetric doubly stochastic.
    """
    seen = {0}
    offsets = [0]
    h = 1
    while h <= n // 2:
        for o in (h, -h):
            if o % n not in seen:
                seen.add(o % n)
                offsets.append(o)
        h *= 2
    w = 1.0 / len(offsets)
    return Topology("exponential", n, tuple(offsets), tuple([w] * len(offsets)))


def fully_connected(n: int) -> Topology:
    offs = tuple(range(n))
    return Topology("complete", n, offs, tuple([1.0 / n] * n))


def normalize_mask(mask: Sequence[int], n: int) -> Tuple[int, ...]:
    """Validate a presence mask: length ``n``, entries coerced to {0, 1}."""
    vals = tuple(int(bool(v)) for v in mask)
    if len(vals) != n:
        raise ValueError(f"presence mask has length {len(vals)}, want {n}")
    return vals


@dataclasses.dataclass(frozen=True)
class MaskedTopology:
    """A circulant topology restricted to the workers that showed up.

    ``W'[i, j] = W[i, j] * p_i * p_j`` off-diagonal, and each worker's
    lost edge mass folds back into its self-weight:

        W'[i, i] = W[i, i] + sum_{j != i} W[i, j] * (1 - p_i * p_j)

    Properties (proofs in docs/elasticity.md):

    * symmetric doubly stochastic for any mask (the update adds
      ``W_ij (e_i - e_j)(e_i - e_j)^T`` per dead edge, which preserves
      row/column sums and symmetry);
    * an absent worker's row is exactly the identity row — it neither
      sends nor receives, its model is untouched;
    * full presence reproduces ``base.matrix`` bit-exactly (every mask
      factor is exactly 1.0 and every compensation term exactly 0.0);
    * the dead-edge update is PSD, so eigenvalues are non-decreasing in
      the number of *dropped* workers (Weyl) — less participation never
      looks like faster mixing.

    Not circulant (the mask breaks translation invariance), so this is
    the *analysis* object for theta schedules and rho regressions; the
    engine applies the same renormalization edge-wise on device.
    """
    base: Topology
    presence: Tuple[int, ...]

    @property
    def name(self) -> str:
        up = sum(self.presence)
        return f"{self.base.name}-p{up}of{self.base.n}"

    @property
    def n(self) -> int:
        return self.base.n

    @property
    def matrix(self) -> np.ndarray:
        W = self.base.matrix
        p = np.asarray(self.presence, dtype=np.float64)
        P = np.outer(p, p)
        M = W * P
        np.fill_diagonal(M, 0.0)
        # off-diagonal mass each row lost to dead edges -> self-weight
        lost = (W * (1.0 - P)).sum(axis=1) \
            - np.diag(W) * (1.0 - p * p)
        idx = np.arange(self.n)
        M[idx, idx] = np.diag(W) + lost
        return M

    @property
    def rho(self) -> float:
        ev = np.sort(np.abs(np.linalg.eigvalsh(self.matrix)))[::-1]
        return float(ev[1]) if self.n > 1 else 0.0

    @property
    def phi(self) -> float:
        W = self.matrix
        nz = W[W > 1e-12]
        return float(nz.min()) if nz.size else 0.0

    @property
    def t_mix_bound(self) -> float:
        gap = 1.0 - self.rho
        if gap <= 0:
            return float("inf")
        return float(np.log(4 * self.n) / gap)


@dataclasses.dataclass(frozen=True)
class TimeVaryingTopology:
    """A per-round schedule of mixing matrices with a *joint* spectral gap.

    Round ``k`` uses ``schedule[k % len(schedule)]`` (any object with a
    ``matrix`` — ``Topology``, ``MaskedTopology``, another schedule's
    entry).  The usual per-matrix ``rho`` is meaningless for a product of
    different W's; what Moniqua's consensus argument needs is the
    contraction of one full window:

        rho = || W_{T-1} ... W_1 W_0 - J/n ||_2 ^ (1/T)

    the per-round geometric-average contraction factor.  Because every
    entry is doubly stochastic, ``(W_t - J/n)`` telescopes through the
    product and the joint rho is at most the geometric mean of the
    per-matrix rhos — a schedule that is occasionally disconnected can
    still mix, which is exactly the B-connectivity assumption of
    time-varying-gossip analyses.  ``ThetaSchedule`` consuming this rho
    therefore stays honest under churn.
    """
    schedule: Tuple[object, ...]

    def __post_init__(self):
        if not self.schedule:
            raise ValueError("TimeVaryingTopology needs a non-empty schedule")
        ns = {t.n for t in self.schedule}
        if len(ns) != 1:
            raise ValueError(f"schedule mixes worker counts: {sorted(ns)}")

    def __len__(self) -> int:
        return len(self.schedule)

    def at(self, k: int):
        """The topology in effect at round ``k`` (periodic schedule)."""
        return self.schedule[k % len(self.schedule)]

    @property
    def name(self) -> str:
        return f"varying[{self.schedule[0].name}..x{len(self.schedule)}]"

    @property
    def n(self) -> int:
        return self.schedule[0].n

    @property
    def window_matrix(self) -> np.ndarray:
        """Product of one schedule window, ``W_{T-1} ... W_0`` (round
        order: later rounds multiply from the left)."""
        P = self.schedule[0].matrix
        for t in self.schedule[1:]:
            P = t.matrix @ P
        return P

    @property
    def rho(self) -> float:
        """Joint spectral gap: per-round contraction of one window."""
        if self.n == 1:
            return 0.0
        J = np.full((self.n, self.n), 1.0 / self.n)
        sig = np.linalg.norm(self.window_matrix - J, ord=2)
        return float(sig ** (1.0 / len(self.schedule)))

    @property
    def phi(self) -> float:
        """Most-pessimistic smallest nonzero entry across the window."""
        return min(t.phi for t in self.schedule)

    @property
    def t_mix_bound(self) -> float:
        gap = 1.0 - self.rho
        if gap <= 0:
            return float("inf")
        return float(np.log(4 * self.n) / gap)

    def slack(self, gamma: float) -> "TimeVaryingTopology":
        """Slack every round of the window (Theorem 3 entrywise)."""
        return TimeVaryingTopology(
            tuple(t.slack(gamma) for t in self.schedule))


@dataclasses.dataclass(frozen=True)
class HierarchicalTopology:
    """Two-tier gossip topology: an intra-tier graph inside each node times
    an inter-tier graph across nodes.

    Worker ``w = g * intra.n + j`` is member ``j`` of node ``g`` (the intra
    index varies fastest, matching a ``reshape(n_inter, n_intra)`` of the
    stacked worker axis).  One hierarchical round composes as

        W_hier = kron(W_inter, W_intra)

    so the spectral-gap math stays honest: the eigenvalues of the Kronecker
    product are the pairwise products of the tier eigenvalues, hence
    ``rho = max(intra.rho, inter.rho)`` for doubly-stochastic tiers (both
    factors keep the eigenvalue 1).  With ``intra = fully_connected(k)``
    the product ``kron(W_inter, J_k/k)`` is *exactly* what the executed
    two-tier round computes (intra reduce-scatter -> inter shard gossip ->
    intra all-gather, ``CommEngine`` TieredPlan); for other intra graphs
    the matrix is the analysis object (rho regressions), while the engine
    still runs reduce-scatter semantics on the intra axis.

    Theta bounds per tier: only the *inter* tier's gossip is modulo
    quantized, so Lemma 1's a-priori bound theta constrains consensus
    across node means — the intra tier is full precision and never
    aliases.  ``slack`` therefore applies to the inter tier only.
    """
    intra: Topology
    inter: Topology

    @property
    def name(self) -> str:
        return (f"{self.inter.name}{self.inter.n}"
                f"x{self.intra.name}{self.intra.n}")

    @property
    def n(self) -> int:
        return self.intra.n * self.inter.n

    @property
    def n_intra(self) -> int:
        return self.intra.n

    @property
    def n_inter(self) -> int:
        return self.inter.n

    @property
    def matrix(self) -> np.ndarray:
        """``kron(W_inter, W_intra)`` on the flat worker index
        ``w = g * n_intra + j``."""
        return np.kron(self.inter.matrix, self.intra.matrix)

    @property
    def rho(self) -> float:
        """Second-largest absolute eigenvalue of the composed W (A2).

        Equals ``max(intra.rho, inter.rho)`` for symmetric doubly-
        stochastic tiers; computed from the kron so the identity is
        checked, not assumed.
        """
        ev = np.sort(np.abs(np.linalg.eigvalsh(self.matrix)))[::-1]
        return float(ev[1]) if self.n > 1 else 0.0

    @property
    def phi(self) -> float:
        W = self.matrix
        nz = W[W > 1e-12]
        return float(nz.min()) if nz.size else 0.0

    @property
    def t_mix_bound(self) -> float:
        gap = 1.0 - self.rho
        if gap <= 0:
            return float("inf")
        return float(np.log(4 * self.n) / gap)

    def neighbor_offsets(self) -> Tuple[int, ...]:
        """Nonzero *inter*-tier offsets — the slow-axis gossip edges.

        On the flat worker index an inter offset ``o`` is the stride
        ``o * n_intra`` (node g's member j talks to node g+o's member j).
        """
        return tuple(o * self.intra.n
                     for o in self.inter.neighbor_offsets())

    def slack(self, gamma: float) -> "HierarchicalTopology":
        """Slack on the quantized (inter) tier only: the intra tier is
        full precision, so Theorem 3's consensus-step damping applies to
        the slow-axis gossip."""
        return HierarchicalTopology(intra=self.intra,
                                    inter=self.inter.slack(gamma))


def two_tier(n: int, n_intra: int, inter_name: str = "ring",
             intra: Topology | None = None, **kw) -> HierarchicalTopology:
    """Two-tier hierarchy over ``n`` workers in nodes of ``n_intra``.

    The inter tier gets the named topology over ``n // n_intra`` nodes;
    the intra tier defaults to fully connected (every node averages its
    members exactly — the reduce-scatter/all-gather the engine executes).
    ``n_intra = 1`` degenerates to the flat single-tier graph semantics
    (the engine's bit-exactness reference).
    """
    if n_intra < 1 or n % n_intra:
        raise ValueError(
            f"n_intra must divide n: got n={n}, n_intra={n_intra}")
    if intra is None:
        intra = fully_connected(n_intra)
    elif intra.n != n_intra:
        raise ValueError(f"intra topology has n={intra.n}, want {n_intra}")
    return HierarchicalTopology(intra=intra,
                                inter=get_topology(inter_name,
                                                   n // n_intra, **kw))


def get_topology(name: str, n: int, **kw) -> Topology:
    if name == "ring":
        return ring(n, **kw)
    if name == "exponential":
        return exponential(n)
    if name == "complete":
        return fully_connected(n)
    if name == "torus":
        side = int(round(np.sqrt(n)))
        if side * side != n:
            raise ValueError(f"torus needs square n, got {n}")
        return torus(side, side)
    raise ValueError(f"unknown topology {name!r}")
