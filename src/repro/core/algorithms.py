"""Decentralized training update rules (the paper's algorithm zoo).

Every algorithm operates on *stacked* worker pytrees (leaves ``[n, ...]``) and
is a pure function, so the same code runs (a) on one CPU device for the paper's
convergence experiments and (b) sharded over the production mesh where the
worker axis is a mesh axis and every neighbor exchange is a collective-permute.

Implemented rules (Table 1 of the paper + the baselines of Sec. 6):

  allreduce    exact centralized SGD (MPI AllReduce analog)
  dpsgd        Lian et al. 2017, full-precision gossip
  naive        direct quantization of exchanged models (Theorem 1: diverges)
  moniqua      Algorithm 1 (modulo-quantized gossip, zero extra memory)
  choco        ChocoSGD (Koloskova et al. 2019): local estimators x_hat, Θ(md)
  deepsqueeze  Tang et al. 2019: error-compensated compression, Θ(nd)
  dcd          DCD-PSGD (Tang et al. 2018): difference compression + replicas
  ecd          ECD-PSGD: extrapolated difference compression + replicas
  d2 / moniqua_d2   D^2 (Tang et al. 2018) variance reduction, Sec. 5

Gradient input ``g`` is the (optionally momentum-processed) local direction;
``alpha`` the current step size.  ``AlgoHyper`` carries the per-algorithm knobs.

Notes on baseline fidelity: DCD/ECD replica updates follow the difference /
extrapolated-difference schemes of Tang et al. 2018; ECD's extrapolation
weights are simplified to (1/2, 1/2) — the qualitative property the paper
tests (divergence under <= 2-bit budgets) is preserved and reproduced.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.engine import CommEngine, FullPrecisionWire, make_wire
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core import topology
from repro.core.topology import Topology
from repro.obs import metrics as obs_metrics

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AlgoHyper:
    """Static hyper-parameters shared by the update rules.

    All communication routes through :class:`~repro.comm.engine.CommEngine`:
    ``engine()`` builds the configured wire codec (``wire`` x ``codec.spec``
    x ``backend``) for the quantized-gossip algorithms, ``exact_engine()``
    the full-precision engine the baselines (and replica mixing) use.
    Swapping codec, topology, or backend is a one-field change here.

    ``telemetry`` turns on the engine's round-health observability
    (``repro.obs.metrics``): the instrumented algorithms (Moniqua family,
    DPSGD, D2) then carry the accumulated health dict under
    ``extra["health"]`` and the trainer surfaces it as ``obs_*`` metrics.
    Purely observational — params / payloads / WireState are bit-exact
    with the flag on or off.

    **Elastic rounds** (``docs/elasticity.md``): ``presence`` hands the
    instrumented algorithms a static 0/1 worker mask to pass into the
    engine's ``mix(presence=...)`` — absent workers take the identity
    mix, the rest renormalize; ``None`` / all-ones is bit-exact with
    today's gossip.  Distinct masks retrace the jitted step (the mask is
    static), so per-round time-varying masks belong in an eager loop
    (``bench_elastic``) or a schedule of pre-traced steps.  ``deadline``
    is the round deadline in seconds the *simulator* enforces when the
    run's wall clock is priced (``sim.faults.FaultSpec.deadline_s``); the
    in-step math never reads it — it rides here so one hyper object
    carries the full elastic configuration into run logs and benches.
    """
    topo: Topology
    codec: MoniquaCodec = MoniquaCodec()
    theta: float = 2.0            # Moniqua a-priori bound (paper used 2.0)
    gamma: float = 1.0            # consensus step size (Choco/DeepSqueeze/Thm 3 slack)
    naive_delta: float = 0.05     # absolute lattice pitch for the naive baseline
    wire: str = "moniqua"         # wire codec for quantized gossip (engine())
    backend: str = "auto"         # comm backend: jnp | pallas | auto
    path: str = "auto"            # gossip path: bucketed | per_leaf | auto
    chunks: int = 1               # staged-round chunk count (1 = barrier)
    overlap: str = "none"         # step-level overlap: none | stale (Moniqua)
    warmup: int = 16              # onebit wire: fp32 rounds before 1-bit+EF
    telemetry: bool = False       # round-health observability (repro.obs)
    tiers: int = 1                # 1 = flat gossip; k>1 = two-tier, nodes of k
    presence: Optional[Tuple[int, ...]] = None   # elastic 0/1 worker mask
    deadline: Optional[float] = None             # sim round deadline (s)

    def comm_topo(self):
        """The topology the engines gossip on: ``topo`` itself for flat
        (``tiers=1``) runs, or the two-tier hierarchy with ``topo`` as the
        *inter* graph over ``n // tiers`` nodes and a fully-connected intra
        tier of ``tiers`` workers.  A ``HierarchicalTopology`` passed
        directly as ``topo`` wins over ``tiers``.
        """
        if isinstance(self.topo, topology.HierarchicalTopology):
            return self.topo
        if self.tiers <= 1:
            return self.topo
        # rebuild from the base family, replaying any slack factors the
        # flat name carries ("ring-slack0.9") onto the inter tier — the
        # only quantized tier, hence the only one Theorem 3 damps
        parts = self.topo.name.split("-slack")
        hier = topology.two_tier(self.topo.n, self.tiers,
                                 inter_name=parts[0])
        for g in parts[1:]:
            hier = hier.slack(float(g))
        return hier

    def engine(self) -> CommEngine:
        return CommEngine(self.comm_topo(),
                          make_wire(self.wire, self.codec.spec,
                                    warmup=self.warmup),
                          self.backend, path=self.path, chunks=self.chunks,
                          telemetry=self.telemetry)

    def exact_engine(self, telemetry: bool = False) -> CommEngine:
        """Full-precision engine.  ``telemetry`` is opt-in per call site:
        the instrumented baselines (DPSGD, D2) pass ``self.telemetry``;
        internal replica/estimator mixing (Choco, DCD, ...) leaves it off."""
        return CommEngine(self.comm_topo(), FullPrecisionWire(),
                          self.backend, path=self.path, chunks=self.chunks,
                          telemetry=telemetry)


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def _sgd(X: PyTree, g: PyTree, alpha) -> PyTree:
    return jax.tree.map(lambda x, d: (x - alpha * d).astype(x.dtype), X, g)


def _norm_quantize(v: jax.Array, bits: int, key: Optional[jax.Array],
                   unbiased: bool = False) -> jax.Array:
    """Per-worker norm-scaled linear quantizer (used by Choco/DeepSqueeze/DCD/ECD).

    bits >= 2: scale_i = max_j |v_ij| per worker row; codes cover
    [-scale, scale] with 2**bits levels, stochastic rounding.  Payload =
    codes + one f32 scale per worker per tensor.

    bits == 1 and not unbiased: scaled sign ``sign(v) * mean|v|`` — the
    standard *biased* 1-bit compressor the contraction-based methods
    (Choco/DeepSqueeze) admit (paper Table 1 "supports biased quantizers").
    DCD/ECD's theory REQUIRES unbiased quantizers, so they must use
    1-bit stochastic rounding — whose variance at 1 bit is what makes them
    diverge there (Table 2 "diverge").
    """
    red_axes = tuple(range(1, v.ndim))
    if bits == 1 and not unbiased:
        scale = jnp.mean(jnp.abs(v), axis=red_axes, keepdims=True)
        return jnp.sign(v) * scale
    scale = jnp.max(jnp.abs(v), axis=red_axes, keepdims=True) + 1e-12
    levels = 2 ** bits
    lat = (v / (2.0 * scale) + 0.5) * (levels - 1)
    if key is None:
        codes = jnp.floor(lat + 0.5)
    else:
        codes = jnp.floor(lat + jax.random.uniform(key, v.shape))
    codes = jnp.clip(codes, 0, levels - 1)
    return (codes / (levels - 1) - 0.5) * 2.0 * scale


def _nq_tree(V: PyTree, bits: int, key: Optional[jax.Array],
             unbiased: bool = False) -> PyTree:
    leaves, td = jax.tree.flatten(V)
    keys = [None] * len(leaves) if key is None else list(jax.random.split(key, len(leaves)))
    return jax.tree.unflatten(td, [_norm_quantize(l, bits, k, unbiased)
                                   for l, k in zip(leaves, keys)])


def _zeros_like(X: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, X)


def _tree_bytes(X: PyTree) -> int:
    return sum(int(np.prod(l.shape, dtype=np.int64)) * l.dtype.itemsize
               for l in jax.tree.leaves(X))


# ---------------------------------------------------------------------------
# Algorithm definitions
# ---------------------------------------------------------------------------

class Algorithm:
    """Base: subclasses override init/step and the two accounting methods."""
    name: str = "base"
    quantized: bool = False

    def init(self, X: PyTree, hp: AlgoHyper) -> PyTree:
        return {}

    def step(self, X: PyTree, extra: PyTree, g: PyTree, alpha, k,
             key: Optional[jax.Array], hp: AlgoHyper) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def bytes_per_step(self, X: PyTree, hp: AlgoHyper) -> int:
        """Payload bytes *sent* per worker per iteration."""
        raise NotImplementedError

    def extra_memory_bytes(self, X: PyTree, hp: AlgoHyper) -> int:
        """Per-worker additional state vs full-precision D-PSGD (Table 1).

        Reported per the paper's accounting (conceptual replicas for the
        replica-based schemes, regardless of implementation sharing).
        """
        return 0

    # -- common accounting pieces ------------------------------------------
    @staticmethod
    def _model_bytes(X: PyTree) -> int:
        """Per-worker full-precision model bytes (d * itemsize)."""
        n = jax.tree.leaves(X)[0].shape[0]
        return _tree_bytes(X) // n


class AllReduce(Algorithm):
    name = "allreduce"

    def step(self, X, extra, g, alpha, k, key, hp):
        Xh = _sgd(X, g, alpha)
        Xm = jax.tree.map(lambda x: jnp.broadcast_to(
            jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True), x.shape
        ).astype(x.dtype), Xh)
        return Xm, extra

    def bytes_per_step(self, X, hp):
        return 2 * self._model_bytes(X)  # ring allreduce ~2x model bytes/worker


class DPSGD(Algorithm):
    name = "dpsgd"

    def init(self, X, hp):
        return ({"health": obs_metrics.init_health()} if hp.telemetry
                else {})

    def step(self, X, extra, g, alpha, k, key, hp):
        eng = hp.exact_engine(telemetry=hp.telemetry)
        # theta rides along as a pure diagnostic: "what bound would a
        # Moniqua wire need here" — the full wire itself ignores it
        res = eng.mix(X, theta=hp.theta, presence=hp.presence)
        if hp.telemetry:
            extra = dict(extra)
            extra["health"] = obs_metrics.accumulate_health(
                extra["health"], res.health)
        return _sgd(res.x, g, alpha), extra

    def bytes_per_step(self, X, hp):
        return hp.exact_engine().bytes_per_round(X)


class NaiveQuant(Algorithm):
    """Direct quantization of exchanged models (Eq. 4) — the Theorem 1 failure."""
    name = "naive"
    quantized = True

    def step(self, X, extra, g, alpha, k, key, hp):
        d = hp.naive_delta

        def q(v, kk):
            lat = v / d
            u = 0.5 if kk is None else jax.random.uniform(kk, v.shape)
            return d * jnp.floor(lat + u)

        leaves, td = jax.tree.flatten(X)
        keys = [None] * len(leaves) if key is None else list(jax.random.split(key, len(leaves)))
        Q = jax.tree.unflatten(td, [q(l, kk) for l, kk in zip(leaves, keys)])
        eng = hp.exact_engine()
        mixed = jax.tree.map(
            lambda x, nb: x * eng.self_weight() + nb,
            X, eng.neighbor_sum(Q, lambda v, o: v))
        return _sgd(mixed, g, alpha), extra

    def bytes_per_step(self, X, hp):
        # same code width as an 8-bit budget for comparison purposes
        return self._model_bytes(X) // 4 * len(hp.topo.neighbor_offsets())


class Moniqua(Algorithm):
    """Algorithm 1 (gossip through the engine's configured wire codec).

    With a stateful wire (``hp.wire`` in ``ef_qsgd``/``onebit``) this is the
    error-feedback gossip family: the per-worker ``WireState`` (residual +
    warmup counter) lives under ``extra["wire"]`` and is threaded through
    the engine's ``mix`` carry — which is exactly what puts EF's Θ(nd)
    buffers on the Table 1/2 memory axis while Moniqua's own wire stays at
    zero (``extra_memory_bytes``).

    ``hp.overlap == "stale"`` (stateless Moniqua wire only) switches the
    round to the engine's one-round-stale ``mix_stale``: step k applies
    the consensus delta decoded from round k-1's payloads, and the gossip
    carry (previous packed residue + its reference/B) lives under
    ``extra["gossip"]`` — the step-level overlap that lets the decode
    hide behind the next forward pass."""
    name = "moniqua"
    quantized = True

    def init(self, X, hp):
        eng = hp.engine()
        extra = {}
        if eng.stateful:
            extra["wire"] = eng.init_wire_state(X)
        elif hp.overlap == "stale":
            extra["gossip"] = eng.init_gossip_carry(X)
        if hp.telemetry:
            extra["health"] = obs_metrics.init_health()
        return extra

    def step(self, X, extra, g, alpha, k, key, hp):
        eng = hp.engine()
        new_extra = dict(extra)
        if eng.stateful:
            res = eng.mix(X, theta=hp.theta, key=key, state=extra["wire"],
                          presence=hp.presence)
            new_extra["wire"] = res.state
        elif hp.overlap == "stale":
            res = eng.mix_stale(X, extra["gossip"], theta=hp.theta, key=key,
                                presence=hp.presence)
            new_extra["gossip"] = res.state
        else:
            res = eng.mix(X, theta=hp.theta, key=key, presence=hp.presence)
        if hp.telemetry:
            new_extra["health"] = obs_metrics.accumulate_health(
                extra["health"], res.health)
        return _sgd(res.x, g, alpha), new_extra

    def bytes_per_step(self, X, hp):
        return hp.engine().bytes_per_round(X)

    def extra_memory_bytes(self, X, hp):
        # 0 for the moniqua wire (the headline claim); residual + counter
        # for the EF wires (Θ(nd) graph-wide)
        return hp.engine().wire_state_bytes(X)


class ChocoSGD(Algorithm):
    """Koloskova et al. 2019: gossip on quantized estimators x_hat."""
    name = "choco"
    quantized = True

    def init(self, X, hp):
        return {"x_hat": _zeros_like(X)}

    def step(self, X, extra, g, alpha, k, key, hp):
        x_hat = extra["x_hat"]
        Xh = _sgd(X, g, alpha)
        q = _nq_tree(jax.tree.map(lambda a, b: a - b, Xh, x_hat),
                     hp.codec.spec.bits, key)
        x_hat = jax.tree.map(lambda a, b: a + b, x_hat, q)
        mixed_hat = hp.exact_engine().mix(x_hat).x
        Xn = jax.tree.map(
            lambda x, mh, h: (x + hp.gamma * (mh - h)).astype(x.dtype),
            Xh, mixed_hat, x_hat)
        return Xn, {"x_hat": x_hat}

    def bytes_per_step(self, X, hp):
        return (self._model_bytes(X) * hp.codec.spec.bits // 32
                * len(hp.topo.neighbor_offsets()))

    def extra_memory_bytes(self, X, hp):
        # replicas of every neighbor's estimator + own: Θ(m d) graph-wide
        return self._model_bytes(X) * (len(hp.topo.neighbor_offsets()) + 1)


class DeepSqueeze(Algorithm):
    """Tang et al. 2019: error-compensated compressed gossip."""
    name = "deepsqueeze"
    quantized = True

    def init(self, X, hp):
        return {"err": _zeros_like(X)}

    def step(self, X, extra, g, alpha, k, key, hp):
        e = extra["err"]
        Xh = _sgd(X, g, alpha)
        v = jax.tree.map(lambda a, b: a + b, Xh, e)
        c = _nq_tree(v, hp.codec.spec.bits, key)
        e = jax.tree.map(lambda a, b: a - b, v, c)
        mixed_c = hp.exact_engine().mix(c).x
        Xn = jax.tree.map(
            lambda x, mc, ci: (x + hp.gamma * (mc - ci)).astype(x.dtype),
            Xh, mixed_c, c)
        return Xn, {"err": e}

    def bytes_per_step(self, X, hp):
        return (self._model_bytes(X) * hp.codec.spec.bits // 32
                * len(hp.topo.neighbor_offsets()))

    def extra_memory_bytes(self, X, hp):
        return self._model_bytes(X)  # Θ(n d) graph-wide = one buffer per worker


class DCD(Algorithm):
    """DCD-PSGD: replicas x_hat updated with quantized model differences."""
    name = "dcd"
    quantized = True

    def init(self, X, hp):
        # copy=True: an f32 astype would alias X's buffers and break donation
        return {"x_hat": jax.tree.map(
            lambda x: jnp.array(x, dtype=jnp.float32, copy=True), X)}

    def step(self, X, extra, g, alpha, k, key, hp):
        x_hat = extra["x_hat"]
        mixed_hat = hp.exact_engine().mix(x_hat).x
        Xn = _sgd(jax.tree.map(lambda x, mh, h: x + (mh - h), X, mixed_hat, x_hat),
                  g, alpha)
        z = jax.tree.map(lambda a, b: a - b, Xn, x_hat)
        q = _nq_tree(z, hp.codec.spec.bits, key, unbiased=True)
        x_hat = jax.tree.map(lambda a, b: a + b, x_hat, q)
        return Xn, {"x_hat": x_hat}

    def bytes_per_step(self, X, hp):
        return (self._model_bytes(X) * hp.codec.spec.bits // 32
                * len(hp.topo.neighbor_offsets()))

    def extra_memory_bytes(self, X, hp):
        return self._model_bytes(X) * (len(hp.topo.neighbor_offsets()) + 1)


class ECD(DCD):
    """ECD-PSGD: extrapolated difference compression."""
    name = "ecd"

    def step(self, X, extra, g, alpha, k, key, hp):
        x_hat = extra["x_hat"]
        mixed_hat = hp.exact_engine().mix(x_hat).x
        Xn = _sgd(jax.tree.map(lambda x, mh, h: x + (mh - h), X, mixed_hat, x_hat),
                  g, alpha)
        z = jax.tree.map(lambda a, b: 2.0 * a - b, Xn, x_hat)  # extrapolation
        q = _nq_tree(z, hp.codec.spec.bits, key, unbiased=True)
        x_hat = jax.tree.map(lambda a, b: 0.5 * (a + b), x_hat, q)
        return Xn, {"x_hat": x_hat}


class D2(Algorithm):
    """D^2 (Tang et al. 2018): variance-reduced decentralized SGD, Sec. 5."""
    name = "d2"

    def init(self, X, hp):
        extra = {"x_prev": jax.tree.map(
                     lambda x: jnp.array(x, dtype=jnp.float32, copy=True), X),
                 "g_prev": _zeros_like(X),
                 "alpha_prev": jnp.zeros((), jnp.float32)}
        if hp.telemetry:
            extra["health"] = obs_metrics.init_health()
        return extra

    def _half_step(self, X, extra, g, alpha):
        x_prev, g_prev, a_prev = extra["x_prev"], extra["g_prev"], extra["alpha_prev"]
        return jax.tree.map(
            lambda x, xp, gi, gp: 2.0 * x.astype(jnp.float32) - xp
            - alpha * gi + a_prev * gp,
            X, x_prev, g, g_prev)

    def step(self, X, extra, g, alpha, k, key, hp):
        Xh = self._half_step(X, extra, g, alpha)
        eng = hp.exact_engine(telemetry=hp.telemetry)
        res = eng.mix(Xh, theta=hp.theta, presence=hp.presence)
        Xn = jax.tree.map(lambda a, x: a.astype(x.dtype), res.x, X)
        new_extra = {"x_prev": jax.tree.map(lambda x: x.astype(jnp.float32),
                                            X),
                     "g_prev": g,
                     "alpha_prev": jnp.asarray(alpha, jnp.float32)}
        if hp.telemetry:
            new_extra["health"] = obs_metrics.accumulate_health(
                extra["health"], res.health)
        return Xn, new_extra

    def bytes_per_step(self, X, hp):
        return hp.exact_engine().bytes_per_round(X)

    def extra_memory_bytes(self, X, hp):
        return 2 * self._model_bytes(X)  # x_prev + g_prev (inherent to D^2)


class MoniquaD2(D2):
    """Moniqua on D^2 (Algorithm 2): quantized gossip of the half-step.

    Stateful wires ride along like in :class:`Moniqua`: the ``WireState``
    sits under ``extra["wire"]`` next to D^2's own x_prev/g_prev carry."""
    name = "moniqua_d2"
    quantized = True

    def init(self, X, hp):
        extra = super().init(X, hp)
        eng = hp.engine()
        if eng.stateful:
            extra["wire"] = eng.init_wire_state(X)
        return extra

    def step(self, X, extra, g, alpha, k, key, hp):
        Xh = self._half_step(X, extra, g, alpha)
        eng = hp.engine()
        res = eng.mix(Xh, theta=hp.theta, key=key,
                      state=extra["wire"] if eng.stateful else None,
                      presence=hp.presence)
        Xn = jax.tree.map(lambda a, x: a.astype(x.dtype), res.x, X)
        new_extra = {"x_prev": jax.tree.map(lambda x: x.astype(jnp.float32),
                                            X),
                     "g_prev": g,
                     "alpha_prev": jnp.asarray(alpha, jnp.float32)}
        if eng.stateful:
            new_extra["wire"] = res.state
        if hp.telemetry:
            new_extra["health"] = obs_metrics.accumulate_health(
                extra["health"], res.health)
        return Xn, new_extra

    def bytes_per_step(self, X, hp):
        return hp.engine().bytes_per_round(X)

    def extra_memory_bytes(self, X, hp):
        # D^2's inherent x_prev + g_prev, plus any EF wire state
        return (super().extra_memory_bytes(X, hp)
                + hp.engine().wire_state_bytes(X))


ALGORITHMS: Dict[str, Algorithm] = {a.name: a for a in [
    AllReduce(), DPSGD(), NaiveQuant(), Moniqua(), ChocoSGD(), DeepSqueeze(),
    DCD(), ECD(), D2(), MoniquaD2(),
]}


def get_algorithm(name: str) -> Algorithm:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise ValueError(f"unknown algorithm {name!r}; "
                         f"available: {sorted(ALGORITHMS)}") from None
