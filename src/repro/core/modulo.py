"""Modulo arithmetic underlying Moniqua (paper Lemma 1 & 2).

The paper defines a *centered* modulo: for ``a > 0``

    z mod a  :=  the unique element of {z + n a | n in Z}  in  [-a/2, a/2)

and proves (Lemma 1) that if ``|x - y| < theta <= a/2`` then

    x = ((x mod a) - (y mod a)) mod a + y        with a = 2 theta.

Moniqua transmits ``Q_delta((x / B) mod 1)`` with ``B = 2 theta / (1 - 2 delta)``
and recovers ``x_hat = (Q * B - y) mod B + y`` with ``|x_hat - x| <= delta * B``
(Lemma 2).  All ops are element-wise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cmod(z: jax.Array, a) -> jax.Array:
    """Centered modulo into ``[-a/2, a/2)`` (Eq. 1).

    Implemented as ``z - a * round_half_down(z / a)`` via floor to keep the
    half-open convention exact: ``cmod(a/2) == -a/2``.
    """
    z = jnp.asarray(z)
    a = jnp.asarray(a, dtype=jnp.float32)
    zf = z.astype(jnp.float32)
    out = zf - a * jnp.floor(zf / a + 0.5)
    return out


def mod_unit(z: jax.Array) -> jax.Array:
    """``z mod 1`` into [-1/2, 1/2) — the rescaled payload domain."""
    return cmod(z, 1.0)


def b_theta(theta, delta: float) -> jax.Array:
    """``B_theta = 2 theta / (1 - 2 delta)`` (requires delta < 1/2)."""
    if delta >= 0.5:
        raise ValueError(f"Moniqua requires delta < 1/2, got {delta}")
    return jnp.asarray(theta, jnp.float32) * (2.0 / (1.0 - 2.0 * delta))


def recover(q_times_b: jax.Array, y: jax.Array, B) -> jax.Array:
    """Lemma 1 recovery: ``(q*B - y) mod B + y``.

    ``q_times_b`` is the dequantized payload already scaled by ``B``; ``y`` is
    the receiver's local reference (its own model in Algorithm 1 line 5).
    """
    yf = y.astype(jnp.float32)
    return cmod(q_times_b.astype(jnp.float32) - yf, B) + yf


def local_bias(q_times_b: jax.Array, x_local: jax.Array, B) -> jax.Array:
    """Algorithm 1 line 4: ``x_hat_ii = q_i*B - (x_i mod B) + x_i``.

    The sender's *own* reconstruction under its quantizer; subtracted in the
    averaging step so quantization noise enters only as differences (the
    cancellation that removes bias from the global average).
    """
    xf = x_local.astype(jnp.float32)
    return q_times_b.astype(jnp.float32) - cmod(xf, B) + xf


def error_bound(theta, delta: float) -> float:
    """Lemma 2: ``|x_hat - x| <= theta * 2 delta / (1 - 2 delta)``."""
    return float(theta) * 2.0 * delta / (1.0 - 2.0 * delta)
