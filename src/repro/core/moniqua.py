"""The Moniqua codec (paper Algorithm 1, lines 3-5) as a composable module.

``MoniquaCodec`` turns a tensor into a *bit-packed modulo residue* payload and
back.  It is the unit that rides inside every collective (see comm/gossip.py)
and the unit the Pallas kernels accelerate (kernels/).

Pipeline (element-wise; Algorithm 1 with ``B = 2 theta / (1 - 2 delta)``):

  encode:   r = (x / B) mod 1  in [-1/2, 1/2)      (modulo.mod_unit)
            c = quant codes of Q_delta(r)           (quantizers.quantize_codes)
            p = bit-pack(c)                         (quantizers.pack_codes)
  decode:   q = unquant(unpack(p)) * B
            x_hat = (q - y) mod B + y               (modulo.recover;  y = receiver's model)
  self :    x_hat_ii = q_i - (x_i mod B) + x_i      (modulo.local_bias; line 4)

The payload is ``bits/8`` bytes per parameter + nothing else: no scales, no
error state — the zero-additional-memory property.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import modulo
from repro.core.quantizers import (QuantSpec, dequantize_codes, pack_codes,
                                   quantize_codes, unpack_codes)


@dataclasses.dataclass(frozen=True)
class MoniquaCodec:
    """Static codec config: quantizer spec + whether to use Pallas kernels."""
    spec: QuantSpec = QuantSpec()
    use_pallas: bool = False  # pure-jnp path lowers everywhere; kernels are TPU-targeted

    @property
    def delta(self) -> float:
        return self.spec.delta

    def b_theta(self, theta) -> jax.Array:
        return modulo.b_theta(theta, self.delta)

    # -- encode ------------------------------------------------------------
    def encode(self, x: jax.Array, theta, key: Optional[jax.Array] = None) -> jax.Array:
        """x -> packed uint8 payload (Algorithm 1 line 3)."""
        if self.use_pallas:
            from repro.kernels import ops as kops
            return kops.moniqua_encode(x, self.b_theta(theta), self.spec, key)
        B = self.b_theta(theta)
        r = modulo.mod_unit(x.astype(jnp.float32) / B)
        codes = quantize_codes(r, self.spec, key)
        return pack_codes(codes, self.spec.bits)

    # -- decode ------------------------------------------------------------
    def payload_value(self, packed: jax.Array, theta, last_dim: int) -> jax.Array:
        """Unpack + dequantize + rescale:  q * B  (the transmitted value)."""
        if self.use_pallas:
            from repro.kernels import ops as kops
            return kops.moniqua_unpack_value(packed, self.b_theta(theta), self.spec, last_dim)
        codes = unpack_codes(packed, self.spec.bits, last_dim)
        return dequantize_codes(codes, self.spec) * self.b_theta(theta)

    def decode(self, packed: jax.Array, y: jax.Array, theta) -> jax.Array:
        """Recover a *remote* model against local reference ``y`` (line 5)."""
        qb = self.payload_value(packed, theta, y.shape[-1])
        if self.use_pallas:
            from repro.kernels import ops as kops
            return kops.moniqua_recover(qb, y, self.b_theta(theta))
        return modulo.recover(qb, y, self.b_theta(theta))

    def decode_self(self, packed: jax.Array, x_local: jax.Array, theta) -> jax.Array:
        """Sender-side biased reconstruction ``x_hat_ii`` (line 4)."""
        qb = self.payload_value(packed, theta, x_local.shape[-1])
        return modulo.local_bias(qb, x_local, self.b_theta(theta))

    # -- accounting ----------------------------------------------------------
    def payload_bytes(self, x_shape: tuple[int, ...]) -> int:
        """Bytes on the wire for one tensor (exact packed size)."""
        import numpy as np
        from repro.core.quantizers import packed_last_dim
        if not x_shape:
            return 1
        inner = int(np.prod(x_shape[:-1], dtype=np.int64))
        return inner * packed_last_dim(x_shape[-1], self.spec.bits)

    def max_error(self, theta) -> float:
        """Lemma 2 bound on |x_hat - x| (given |x - y| < theta)."""
        return modulo.error_bound(theta, self.delta)
