"""AD-PSGD and Moniqua-on-AD-PSGD (paper Sec. 5 / Algorithm 3), simulated.

TPUs execute lock-step SPMD programs: true asynchrony (workers racing on a
network) has no TPU analogue, so — per DESIGN.md §2 — we implement the paper's
*analysis model* faithfully instead of emulating MPI races:

  * an "iteration" is ONE gradient update on ONE worker ``i_k`` (uniformly
    sampled), using a gradient computed on a model ``tau_k`` iterations stale
    (``tau_k <= T`` uniform), exactly the single-worker-update process of
    Theorem 5;
  * between updates, a random edge ``(i_k, j_k)`` of the topology gossips
    with the pair-averaging doubly-stochastic ``W_k`` (each individually has
    rho = 1; the mixing condition holds with finite t_mix);
  * Moniqua variant: the pair exchange is modulo-quantized, each endpoint
    decoding against its own model (Algorithm 3 lines 4-7).

The simulator runs under ``lax.scan`` with a staleness ring-buffer, so it jits;
it is intended for the convergence experiments (small models), not the
production mesh path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.comm.engine import CommEngine, FullPrecisionWire, make_wire
from repro.core.moniqua import MoniquaCodec
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class ADPSGDConfig:
    topo: Topology
    codec: MoniquaCodec = MoniquaCodec()
    theta: float = 2.0
    max_delay: int = 4
    quantized: bool = False     # False = plain AD-PSGD, True = Moniqua
    wire: str = "moniqua"       # wire codec when quantized (moniqua | qsgd)
    telemetry: bool = False     # per-exchange edge health (repro.obs);
                                #   run() then also returns a health trace

    def engine(self) -> CommEngine:
        """Pair-exchange engine: the quantized wire or the exact baseline."""
        codec = (make_wire(self.wire, self.codec.spec) if self.quantized
                 else FullPrecisionWire())
        return CommEngine(self.topo, codec, backend="jnp")


def _pair_average(X: jax.Array, i: jax.Array, j: jax.Array,
                  cfg: ADPSGDConfig, key: jax.Array) -> jax.Array:
    """One gossip on edge (i, j):  x_i, x_j <- (x_i + x_j)/2 (pair W_k).

    In the quantized variant each endpoint receives the packed payload of the
    other and decodes against its own model (CommEngine.pair_average,
    Algorithm 3 lines 4-7; shared randomness via one key for both encodes).
    """
    res = cfg.engine().pair_average(X[i], X[j], theta=cfg.theta, key=key)
    X = X.at[i].set(res.xi)
    X = X.at[j].set(res.xj)
    return X


def run(
    x0: jax.Array,                       # [n, d] initial (identical) models
    grad_fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    # grad_fn(x_worker [d], worker_idx, key) -> stochastic gradient [d]
    alpha: float,
    num_iters: int,
    cfg: ADPSGDConfig,
    key: jax.Array,
) -> Tuple[jax.Array, ...]:
    """Run the simulation; returns (final X [n,d], mean-model trace [K,d]).

    With ``cfg.telemetry`` a third element rides along: the per-iteration
    edge-health trace (``CommEngine.pair_health`` of the exchanged pair,
    stacked over iterations — each value a ``[K]`` array keyed like
    ``repro.obs.metrics.round_health_zero``).  Health is computed on the
    *pre-exchange* endpoints under the exchange key, so it observes exactly
    the payloads the exchange ships; the model trajectory is bit-exact with
    the flag on or off (pure observation, no feedback).
    """
    n, d = x0.shape
    T = cfg.max_delay
    hist0 = jnp.broadcast_to(x0, (T + 1, n, d))  # staleness ring buffer
    offsets = jnp.asarray([o % n for o in cfg.topo.neighbor_offsets()])
    eng = cfg.engine()

    def body(carry, k):
        X, hist, kkey = carry
        kkey, k_i, k_tau, k_nb, k_g, k_q = jax.random.split(kkey, 6)
        i = jax.random.randint(k_i, (), 0, n)
        tau = jax.random.randint(k_tau, (), 0, T + 1)
        slot = (k - tau) % (T + 1)
        x_stale = hist[slot, i]
        g = grad_fn(x_stale, i, k_g)
        # gossip on a random incident edge, then the (delayed) gradient update
        j = (i + offsets[jax.random.randint(k_nb, (), 0, offsets.shape[0])]) % n
        out = jnp.mean(X, axis=0)
        if cfg.telemetry:
            out = (out, eng.pair_health(X[i], X[j], theta=cfg.theta,
                                        key=k_q))
        X = _pair_average(X, i, j, cfg, k_q)
        X = X.at[i].add(-alpha * g)
        hist = hist.at[(k + 1) % (T + 1)].set(X)
        return (X, hist, kkey), out

    (Xf, _, _), out = jax.lax.scan(body, (x0, hist0, key),
                                   jnp.arange(num_iters))
    if cfg.telemetry:
        trace, health = out
        return Xf, trace, health
    return Xf, out
