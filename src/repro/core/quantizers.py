"""Quantizers satisfying the paper's bounded-error condition (Eq. 2).

A quantizer ``Q_delta`` must obey ``||Q(x) - x||_inf <= delta`` on
``x in [-1/2, 1/2]^d``.  Two families are provided:

* ``nearest``    -- biased linear quantizer: round to the lattice ``{2*delta*n}``.
* ``stochastic`` -- unbiased stochastic rounding on the same lattice, optionally
                    with *shared randomness* (same ``u`` on all workers; Supp. C).

Both are parameterised by a bit budget ``bits``: the lattice covers ``[-1/2, 1/2)``
with ``2**bits`` points, i.e. ``delta = 1 / (2 * (2**bits - 1))`` for nearest
rounding (``ceil(log2(1/(2 delta) + 1))`` bits suffice, Sec. 4 "Bound on the Bits").

Bit packing: quantized codes are integers in ``[0, 2**bits)`` packed into uint8
lanes (8/4/2/1 values per byte for 1/2/4/8 bits) so that the *communicated* array
is exactly ``bits/8`` bytes per parameter — the compression the roofline measures.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def delta_for_bits(bits: int, stochastic: bool = True) -> float:
    """Worst-case error of a ``bits``-wide linear quantizer on [-1/2, 1/2].

    We place the ``L = 2**bits`` representable points at the midpoints of the
    ``L`` cells tiling [-1/2, 1/2) (pitch ``1/L``).  Nearest rounding errs by
    at most half a pitch (``1/(2L)``); *stochastic* rounding moves to either
    adjacent point, erring by up to a full pitch (``1/L``).  The midpoint
    lattice is what makes 1-bit work: nearest 1-bit has ``delta = 1/4 < 1/2``
    as Theorem 3 requires (stochastic 1-bit has ``delta = 1/2`` and is
    rejected by ``modulo.b_theta``).
    """
    levels = 2 ** bits
    if levels < 2:
        raise ValueError(f"need at least 1 bit, got {bits}")
    return (1.0 / levels) if stochastic else (1.0 / (2.0 * levels))


def bits_for_delta(delta: float) -> int:
    """Paper Sec. 4: ``B <= ceil(log2(1/(2 delta) + 1))``."""
    return int(np.ceil(np.log2(1.0 / (2.0 * delta) + 1.0)))


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of a quantizer.

    Attributes:
      bits: code width per parameter (1, 2, 4 or 8 for packable widths).
      stochastic: unbiased stochastic rounding if True, nearest (biased) if False.
      shared_randomness: reuse one uniform draw across all workers (Supp. C).
    """
    bits: int = 8
    stochastic: bool = True
    shared_randomness: bool = True

    @property
    def levels(self) -> int:
        return 2 ** self.bits

    @property
    def delta(self) -> float:
        return delta_for_bits(self.bits, self.stochastic)

    @property
    def values_per_byte(self) -> int:
        if self.bits not in (1, 2, 4, 8):
            raise ValueError(f"unpackable bit width {self.bits}")
        return 8 // self.bits

    @property
    def bytes_per_param(self) -> float:
        return self.bits / 8.0


# ---------------------------------------------------------------------------
# Code <-> value maps.  Codes 0..L-1 index the midpoints of the L cells tiling
# [-1/2, 1/2):   value(c) = (c + 1/2)/L - 1/2 ;  lattice(x) = (x + 1/2)*L - 1/2
# ---------------------------------------------------------------------------

def _to_lattice(x: jax.Array, levels: int) -> jax.Array:
    return (x.astype(jnp.float32) + 0.5) * levels - 0.5


def _from_lattice(c: jax.Array, levels: int) -> jax.Array:
    return (c.astype(jnp.float32) + 0.5) / levels - 0.5


def quantize_codes(
    x: jax.Array,
    spec: QuantSpec,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Quantize ``x`` in [-1/2, 1/2] to integer codes in [0, levels).

    Stochastic mode implements ``Q(x) = delta_pitch * floor(x/pitch + u)`` with
    u ~ U[0,1) (the paper's stochastic rounding); nearest mode rounds half-up.
    Values outside [-1/2, 1/2] are clamped to the lattice ends (the theory never
    relies on behaviour outside the box).
    """
    lat = _to_lattice(x, spec.levels)
    if spec.stochastic:
        if key is None:
            raise ValueError("stochastic rounding needs a PRNG key")
        u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
        codes = jnp.floor(lat + u)
    else:
        codes = jnp.floor(lat + 0.5)
    codes = jnp.clip(codes, 0, spec.levels - 1)
    return codes.astype(jnp.uint8 if spec.bits <= 8 else jnp.uint32)


def dequantize_codes(codes: jax.Array, spec: QuantSpec) -> jax.Array:
    return _from_lattice(codes, spec.levels)


def quantize(x: jax.Array, spec: QuantSpec, key: Optional[jax.Array] = None) -> jax.Array:
    """``Q_delta(x)``: quantize-then-dequantize (value-space round trip)."""
    return dequantize_codes(quantize_codes(x, spec, key), spec)


# ---------------------------------------------------------------------------
# Bit packing along the last axis.
# ---------------------------------------------------------------------------

def packed_last_dim(n: int, bits: int) -> int:
    vpb = 8 // bits
    return -(-n // vpb)  # ceil div


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack integer codes (< 2**bits) into uint8 along the last axis.

    Pads the last axis with zeros up to a multiple of ``values_per_byte``.
    """
    if bits == 8:
        return codes.astype(jnp.uint8)
    vpb = 8 // bits
    n = codes.shape[-1]
    pad = (-n) % vpb
    if pad:
        pad_width = [(0, 0)] * (codes.ndim - 1) + [(0, pad)]
        codes = jnp.pad(codes, pad_width)
    grouped = codes.reshape(*codes.shape[:-1], -1, vpb).astype(jnp.uint8)
    shifts = (jnp.arange(vpb, dtype=jnp.uint8) * bits).astype(jnp.uint8)
    packed = jnp.zeros(grouped.shape[:-1], dtype=jnp.uint8)
    for j in range(vpb):
        packed = packed | (grouped[..., j] << shifts[j])
    return packed


def unpack_codes(packed: jax.Array, bits: int, n: int) -> jax.Array:
    """Inverse of :func:`pack_codes`; ``n`` is the original last-axis length."""
    if bits == 8:
        return packed
    vpb = 8 // bits
    mask = jnp.uint8(2 ** bits - 1)
    parts = [((packed >> jnp.uint8(j * bits)) & mask) for j in range(vpb)]
    codes = jnp.stack(parts, axis=-1).reshape(*packed.shape[:-1], -1)
    return codes[..., :n]


# ---------------------------------------------------------------------------
# QSGD-style scale + codes codec (Alistarh et al., 2017).
#
# Unlike Moniqua, QSGD transmits an explicit per-tensor scale alongside the
# codes: the sender normalizes by its own max-norm, quantizes the normalized
# value on the same midpoint lattice, and ships (packed codes, f32 scale).
# Payload = bits/8 bytes per parameter + 4 bytes per tensor per worker.  It
# needs no a-priori theta bound but pays the extra scale word and loses the
# modulo trick's reference-free exactness — the comparison CommEngine exposes.
# ---------------------------------------------------------------------------

def _counter_uniform(seed: jax.Array, idx: jax.Array) -> jax.Array:
    """murmur3-finalizer hash of (seed, idx) -> uniform f32 in [0, 1).

    Counter-based so that encode needs no PRNG-state threading and so the
    same (seed, element) pair draws the same uniform on every worker — the
    shared-randomness convention the Pallas encode kernel also uses.
    """
    h = (idx.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)) ^ seed.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def qsgd_encode(x: jax.Array, spec: QuantSpec,
                seed: Optional[jax.Array] = None,
                worker_axis: bool = True) -> tuple[jax.Array, jax.Array]:
    """Encode ``x`` -> (packed codes, per-worker scale).

    With ``worker_axis`` the leading dim of ``x`` indexes workers and each
    worker row gets its own max-norm scale (shape ``[n, 1, ..., 1]``);
    otherwise one scalar scale covers the whole tensor.
    """
    xf = x.astype(jnp.float32)
    red = tuple(range(1, x.ndim)) if (worker_axis and x.ndim > 1) else None
    scale = jnp.max(jnp.abs(xf), axis=red, keepdims=True) + 1e-12
    r = xf / (2.0 * scale)                      # in [-1/2, 1/2]
    lat = _to_lattice(r, spec.levels)
    if spec.stochastic:
        if seed is None:
            raise ValueError("stochastic QSGD rounding needs a seed")
        idx = jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape)
        codes = jnp.floor(lat + _counter_uniform(jnp.asarray(seed, jnp.uint32),
                                                 idx))
    else:
        codes = jnp.floor(lat + 0.5)
    codes = jnp.clip(codes, 0, spec.levels - 1).astype(jnp.uint8)
    return pack_codes(codes, spec.bits), scale


def qsgd_decode(packed: jax.Array, scale: jax.Array, spec: QuantSpec,
                last_dim: int) -> jax.Array:
    """Inverse of :func:`qsgd_encode`: codes -> values in [-scale, scale]."""
    codes = unpack_codes(packed, spec.bits, last_dim)
    return _from_lattice(codes, spec.levels) * (2.0 * scale)


def _segment_scale_map(scales: jax.Array, segments) -> jax.Array:
    """Broadcast per-segment scales ``[n, L]`` to element width ``[n, D]``.

    ``segments`` is the static tuple of per-segment lengths (contiguous
    ranges of the flat bucket).  Slices + broadcasts, NOT an element->id
    gather: a ``D``-sized index constant in the graph makes XLA's
    constant folder crawl for multi-million-element buckets.
    """
    n = scales.shape[0]
    return jnp.concatenate(
        [jnp.broadcast_to(scales[:, i:i + 1], (n, size))
         for i, size in enumerate(segments)], axis=1)


def qsgd_encode_segmented(x: jax.Array, spec: QuantSpec,
                          seed: Optional[jax.Array],
                          segments: tuple[int, ...],
                          idx_base: int = 0,
                          idx_stride: Optional[int] = None
                          ) -> tuple[jax.Array, jax.Array]:
    """QSGD on a flat ``[n, D]`` bucket with one scale per *segment*.

    ``segments`` gives the length of each tensor's contiguous range in
    the bucket (``BucketLayout.segment_sizes``), so the scale granularity
    matches the per-leaf path — one max-norm per tensor per worker.  A
    single whole-model scale would let a 100-scale weight matrix drown a
    0.01-scale bias in quantization noise; this keeps small tensors
    representable while the quantize/pack work stays one fused launch
    over the whole bucket.  Returns (packed codes ``[n, D*bits/8]``,
    scales ``[n, L]`` — both ride the wire).

    The rounding-uniform counter for element ``(w, e)`` is
    ``w * idx_stride + idx_base + e``.  With the defaults (``idx_base=0``,
    ``idx_stride = x.shape[-1]``) that is exactly the row-major flat index
    of the whole buffer — the historical bit stream.  A *chunked* encode
    (``CommEngine.round_plan``) passes the chunk's buffer offset and the
    FULL buffer width as the stride, so each element hashes the same
    ``(seed, global index)`` pair it would in the one-shot encode and the
    pipelined round stays bit-exact against the barrier round.
    """
    xf = x.astype(jnp.float32)
    off, parts = 0, []
    for size in segments:
        seg = jax.lax.slice_in_dim(xf, off, off + size, axis=1)
        parts.append(jnp.max(jnp.abs(seg), axis=1, keepdims=True))
        off += size
    scales = jnp.concatenate(parts, axis=1) + 1e-12     # [n, L]
    smap = _segment_scale_map(scales, segments)         # [n, D]
    lat = _to_lattice(xf / (2.0 * smap), spec.levels)
    if spec.stochastic:
        if seed is None:
            raise ValueError("stochastic QSGD rounding needs a seed")
        stride = x.shape[-1] if idx_stride is None else int(idx_stride)
        idx = (jnp.arange(x.shape[0], dtype=jnp.uint32)[:, None]
               * jnp.uint32(stride)
               + jnp.arange(x.shape[-1], dtype=jnp.uint32)[None, :]
               + jnp.uint32(idx_base))
        codes = jnp.floor(lat + _counter_uniform(jnp.asarray(seed, jnp.uint32),
                                                 idx))
    else:
        codes = jnp.floor(lat + 0.5)
    codes = jnp.clip(codes, 0, spec.levels - 1).astype(jnp.uint8)
    return pack_codes(codes, spec.bits), scales


def qsgd_decode_segmented(packed: jax.Array, scales: jax.Array,
                          spec: QuantSpec,
                          segments: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`qsgd_encode_segmented` on the flat bucket."""
    codes = unpack_codes(packed, spec.bits, sum(segments))
    smap = _segment_scale_map(scales, segments)
    return _from_lattice(codes, spec.levels) * (2.0 * smap)


def qsgd_payload_bytes(x_shape: tuple[int, ...], bits: int) -> int:
    """Wire bytes for one tensor: packed codes + one f32 scale."""
    if not x_shape:
        return 1 + 4
    inner = int(np.prod(x_shape[:-1], dtype=np.int64))
    return inner * packed_last_dim(x_shape[-1], bits) + 4


# ---------------------------------------------------------------------------
# Error-feedback codec family (Tang et al. 2019; Seide et al. 1-bit SGD;
# Tang et al. 2021 "1-bit Adam").  Unlike Moniqua, these wires carry
# *persistent per-worker state*: an f32 residual buffer accumulating what
# quantization dropped, re-injected into the next round's compressed value.
# The repo prices that Θ(nd) memory against Moniqua's zero-extra-memory
# claim in BENCH_memory_overhead.json.
#
# Randomness convention: stochastic rounding draws one uniform per flat
# *row position* (``idx_base + e``), hashed worker-free — every worker and
# both the bucketed and per-leaf gossip paths see the same uniform for a
# given element, which is what makes the paths bit-exact against each
# other (the ``tests/test_ef_codecs.py`` / ``tests/test_engine.py``
# contracts) and preserves Supp.-C shared randomness.
# ---------------------------------------------------------------------------

def _position_uniform(seed: jax.Array, idx_base, width: int) -> jax.Array:
    """``[1, width]`` uniforms hashed from the flat row position only."""
    idx = jnp.arange(width, dtype=jnp.uint32) + jnp.uint32(idx_base)
    return _counter_uniform(jnp.asarray(seed, jnp.uint32), idx)[None, :]


def ef_qsgd_encode_segmented(v: jax.Array, spec: QuantSpec,
                             seed: Optional[jax.Array],
                             segments: tuple[int, ...],
                             idx_base: int = 0
                             ) -> tuple[jax.Array, jax.Array]:
    """QSGD codes for an error-compensated flat ``[n, D]`` bucket.

    Same scale+codes wire format as :func:`qsgd_encode_segmented` (one
    max-norm f32 scale per segment, packed codes), but rounding uniforms
    come from the worker-free row-position hash so the per-leaf and
    bucketed paths (and all workers) draw identical uniforms.  ``v`` is
    the *compensated* value ``x + residual``; the caller keeps
    ``residual' = v - decode(sent)`` (see ``CommEngine._ef_flat_round``).
    """
    vf = v.astype(jnp.float32)
    off, parts = 0, []
    for size in segments:
        seg = jax.lax.slice_in_dim(vf, off, off + size, axis=1)
        parts.append(jnp.max(jnp.abs(seg), axis=1, keepdims=True))
        off += size
    scales = jnp.concatenate(parts, axis=1) + 1e-12     # [n, L]
    smap = _segment_scale_map(scales, segments)         # [n, D]
    lat = _to_lattice(vf / (2.0 * smap), spec.levels)
    if spec.stochastic:
        if seed is None:
            raise ValueError("stochastic EF-QSGD rounding needs a seed")
        codes = jnp.floor(lat + _position_uniform(seed, idx_base,
                                                  vf.shape[-1]))
    else:
        codes = jnp.floor(lat + 0.5)
    codes = jnp.clip(codes, 0, spec.levels - 1).astype(jnp.uint8)
    return pack_codes(codes, spec.bits), scales


def onebit_encode_segmented(v: jax.Array, seed: Optional[jax.Array],
                            segments: tuple[int, ...],
                            idx_base: int = 0, stochastic: bool = False
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """1-bit sign codec with per-segment cluster-mean levels (1-bit Adam
    wire; Seide et al. 2014 reconstruction).

    Each segment partitions elements by sign and ships two f32
    reconstruction levels — ``lo`` = mean of the negative cluster, ``hi``
    = mean of the non-negative cluster — plus one bit per element
    choosing a level: code 1 decodes to exactly ``hi``, code 0 to exactly
    ``lo`` (decode is a select, not arithmetic, so the shipped levels
    round-trip bitwise).  Cluster means, NOT the literal segment min/max:
    reconstructing at the cluster means makes the compression error the
    within-cluster variance, strictly below ``||v||^2`` — a contractive
    compressor, which the error-feedback loop needs.  Min/max endpoint
    levels are not contractive near consensus (every mid-range element
    pays ~span/2 error, so ``||err|| >> ||v||`` once workers agree) and
    measurably diverge under iterated gossip.

    Nearest mode codes the sign partition itself (deterministic, as in
    the 1-bit SGD/Adam literature — EF absorbs the bias); stochastic mode
    picks ``hi`` with probability ``(v - lo) / (hi - lo)`` (clipped),
    drawing from the row-position hash.  Returns
    ``(packed bits, lo [n, L], hi [n, L])``.
    """
    vf = v.astype(jnp.float32)
    pos = vf >= 0.0
    off, los, his = 0, [], []
    for size in segments:
        seg = jax.lax.slice_in_dim(vf, off, off + size, axis=1)
        m = jax.lax.slice_in_dim(pos, off, off + size, axis=1)
        n_pos = jnp.sum(m, axis=1, keepdims=True)
        pos_sum = jnp.sum(jnp.where(m, seg, 0.0), axis=1, keepdims=True)
        neg_sum = jnp.sum(jnp.where(m, 0.0, seg), axis=1, keepdims=True)
        his.append(pos_sum / jnp.maximum(n_pos, 1))
        los.append(neg_sum / jnp.maximum(size - n_pos, 1))
        off += size
    lo = jnp.concatenate(los, axis=1)                   # [n, L]
    hi = jnp.concatenate(his, axis=1)
    if stochastic:
        if seed is None:
            raise ValueError("stochastic 1-bit rounding needs a seed")
        lomap = _segment_scale_map(lo, segments)        # [n, D]
        span = _segment_scale_map(hi, segments) - lomap
        lat = jnp.clip((vf - lomap) / jnp.where(span > 0, span, 1.0),
                       0.0, 1.0)
        codes = jnp.floor(lat + _position_uniform(seed, idx_base,
                                                  vf.shape[-1]))
        codes = jnp.clip(codes, 0, 1).astype(jnp.uint8)
    else:
        codes = pos.astype(jnp.uint8)
    return pack_codes(codes, 1), lo, hi


def onebit_decode_segmented(packed: jax.Array, lo: jax.Array, hi: jax.Array,
                            segments: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`onebit_encode_segmented`: select lo/hi per bit."""
    codes = unpack_codes(packed, 1, sum(segments))
    lomap = _segment_scale_map(lo, segments)
    himap = _segment_scale_map(hi, segments)
    return jnp.where(codes.astype(bool), himap, lomap)


def onebit_payload_bytes(x_shape: tuple[int, ...]) -> int:
    """Steady-state wire bytes for one tensor: 1 bit/param + lo/hi words."""
    if not x_shape:
        return 1 + 8
    inner = int(np.prod(x_shape[:-1], dtype=np.int64))
    return inner * packed_last_dim(x_shape[-1], 1) + 8


# ---------------------------------------------------------------------------
# Worker-indexed keys for (non-)shared randomness.
# ---------------------------------------------------------------------------

def rounding_key(base: jax.Array, step: jax.Array | int, worker: int, spec: QuantSpec) -> jax.Array:
    """PRNG key for stochastic rounding at a given step/worker.

    With ``shared_randomness`` every worker derives the *same* key for a given
    step, so exchanged tensors are floored with the same ``u`` (Supp. C shows
    this bounds the pairwise error by the model distance instead of 2*delta*B).
    """
    k = jax.random.fold_in(base, jnp.asarray(step, dtype=jnp.uint32))
    if not spec.shared_randomness:
        k = jax.random.fold_in(k, worker)
    return k
