"""Host-side training loop tying pipeline, step function, and checkpoints.

Works at two scales with the same code path:
  * experiment scale: 1 CPU device, worker dim is a plain array axis;
  * production scale: mesh provided, state/batch placed with NamedShardings
    from train_step.state_pspecs / batch_pspecs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.comm import gossip
from repro.core.algorithms import AlgoHyper, get_algorithm
from repro.core.moniqua import MoniquaCodec
from repro.core.topology import get_topology
from repro.data.pipeline import SyntheticLMPipeline
from repro.models.model_factory import Model
from repro.models.sharding import ShardingRules
from repro.train import train_step as TS

PyTree = Any


def _null_ctx():
    import contextlib
    return contextlib.nullcontext()


@dataclasses.dataclass
class TrainerConfig:
    algo: str = "moniqua"
    topology: str = "ring"
    n_workers: int = 8
    bits: int = 8
    theta: float = 2.0
    gamma: float = 1.0          # Choco/DeepSqueeze consensus step size
    slack: float = 1.0          # Theorem 3 slack matrix W_bar = s W + (1-s) I
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 5e-4
    steps: int = 100
    log_every: int = 10
    seed: int = 0
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 0
    wire: str = "moniqua"       # CommEngine wire codec (moniqua | qsgd |
                                #   ef_qsgd | onebit | full)
    backend: str = "auto"       # CommEngine backend (jnp | pallas | auto)
    comm_path: str = "auto"     # gossip path: bucketed | per_leaf | auto
    chunks: int = 1             # staged-round chunk count (1 = barrier)
    overlap: str = "none"       # step-level overlap: none | stale (moniqua)
    warmup: int = 16            # onebit wire: fp32 rounds before 1-bit+EF
    tiers: int = 1              # 1 = flat gossip; k>1 = two-tier hierarchy
                                #   (nodes of k workers, tc.topology across
                                #   nodes, full-precision reduce inside)
    telemetry: bool = False     # round-health obs_* metrics (repro.obs);
                                #   static flag — off costs nothing under jit
    log_jsonl: Optional[str] = None   # schema-versioned run log (repro.obs.
                                #   runlog); drained metrics + spans + result
    trace_path: Optional[str] = None  # Chrome-trace JSON of the host-side
                                #   phase spans (Perfetto / chrome://tracing)
    presence: Optional[tuple] = None  # elastic 0/1 worker mask for every
                                #   round (AlgoHyper.presence); None = all up
    deadline: Optional[float] = None  # sim round deadline in seconds
                                #   (recorded; enforced by sim/faults.py)


def build_hyper(tc: TrainerConfig) -> AlgoHyper:
    from repro.core.quantizers import QuantSpec
    topo = get_topology(tc.topology, tc.n_workers)
    if tc.slack < 1.0:
        topo = topo.slack(tc.slack)
    spec = QuantSpec(bits=tc.bits, stochastic=tc.bits > 1)
    presence = None if tc.presence is None else tuple(tc.presence)
    return AlgoHyper(topo=topo, codec=MoniquaCodec(spec), theta=tc.theta,
                     gamma=tc.gamma, wire=tc.wire, backend=tc.backend,
                     path=tc.comm_path, chunks=tc.chunks, overlap=tc.overlap,
                     warmup=tc.warmup, telemetry=tc.telemetry,
                     tiers=tc.tiers, presence=presence,
                     deadline=tc.deadline)


class Trainer:
    def __init__(self, model: Model, shape, tc: TrainerConfig,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None):
        self.model, self.tc = model, tc
        self.hp = build_hyper(tc)
        self.algo = get_algorithm(tc.algo)
        from repro.core.theta import ThetaSchedule
        from repro.optim.sgd import SGDConfig
        self.tcfg = TS.TrainStepConfig(
            algo=tc.algo,
            sgd=SGDConfig(momentum=tc.momentum, weight_decay=tc.weight_decay),
            lr=tc.lr,
            theta=ThetaSchedule(mode="constant", value=tc.theta,
                                n=tc.n_workers,
                                rho=self.hp.comm_topo().rho))
        self.pipeline = SyntheticLMPipeline(model, shape, tc.n_workers,
                                            seed=tc.seed)
        # warm the bucket-layout cache from the abstract state so the flat
        # gossip buffer's static layout (and the auto-path crossover) is
        # built exactly once, outside jit; every traced round then hits the
        # memoized BucketLayout
        abstract = TS.abstract_state(model, self.algo, self.hp,
                                     tc.n_workers)
        self.hp.exact_engine().layout(abstract["params"])
        self.hp.engine().layout(abstract["params"])
        self.step_fn = TS.make_train_step(model, self.hp, self.tcfg)
        self.mesh = mesh
        if mesh is not None:
            assert rules is not None
            mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
            sp = TS.state_pspecs(model, self.algo, self.hp, rules, mesh_shape,
                                 tc.n_workers)
            self._state_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), sp)
            self.jstep = jax.jit(self.step_fn, donate_argnums=(0,))
        else:
            self.jstep = jax.jit(self.step_fn, donate_argnums=(0,))

    def init_state(self) -> PyTree:
        key = jax.random.PRNGKey(self.tc.seed)
        state = TS.init_state(self.model, self.algo, self.hp,
                              self.tc.n_workers, key)
        if self.mesh is not None:
            state = jax.device_put(state, self._state_sh)
        return state

    def bytes_per_step(self, state) -> int:
        return self.algo.bytes_per_step(state["params"], self.hp)

    def restore_state(self, path: Optional[str] = None) -> PyTree:
        """Rebuild FULL trainer state (params, momentum, algorithm extras
        including any ``WireState``, step, g_inf, PRNG key) from the
        ``<checkpoint_path>.state`` file ``run()`` writes.  Passing the
        result back into ``run()`` resumes bit-identically — the contract
        ``tests/test_ckpt_state.py`` pins down."""
        from repro.checkpoint import ckpt
        path = path or self.tc.checkpoint_path
        if not path:
            raise ValueError("restore_state needs a checkpoint path "
                             "(argument or TrainerConfig.checkpoint_path)")
        state = ckpt.restore(path + ".state", self.init_state())
        if self.mesh is not None:
            state = jax.device_put(state, self._state_sh)
        return state

    def run(self, state: Optional[PyTree] = None,
            callback: Optional[Callable[[int, Dict], None]] = None
            ) -> Dict[str, Any]:
        from repro.checkpoint import ckpt
        tc = self.tc
        state = state if state is not None else self.init_state()
        # resume-aware: a restored state carries its own step counter, and
        # the data pipeline is indexed by the global step, so a resumed run
        # replays exactly the batches the uninterrupted run would have seen
        k0 = int(jax.device_get(state["step"]))
        history: List[Dict] = []
        rec = writer = None
        if tc.trace_path or tc.log_jsonl:
            from repro.obs.trace import SpanRecorder
            rec = SpanRecorder()
        if tc.log_jsonl:
            from repro.obs.runlog import RunLogWriter
            run_meta = dataclasses.asdict(tc)
            run_meta["theta_mode"] = self.tcfg.theta.mode
            writer = RunLogWriter(tc.log_jsonl, run=run_meta, tool="trainer")
        t0 = time.time()
        try:
            for k in range(k0, k0 + tc.steps):
                batch = self.pipeline.worker_batch(k)
                if rec is not None:
                    with rec.span("train.step", tid="train", step=k):
                        state, metrics = self.jstep(state, batch)
                else:
                    state, metrics = self.jstep(state, batch)
                if (k - k0) % tc.log_every == 0 or k == k0 + tc.steps - 1:
                    # drain the whole metrics dict in ONE host transfer —
                    # per-scalar float() round-trips device-synced once per
                    # metric per log point
                    m = {kk: float(v)
                         for kk, v in jax.device_get(metrics).items()}
                    m["step"] = k
                    m["wall"] = time.time() - t0
                    history.append(m)
                    if writer is not None:
                        writer.step(k, {kk: v for kk, v in m.items()
                                        if kk not in ("step", "wall")},
                                    wall_s=m["wall"])
                    if callback:
                        callback(k, m)
                if (tc.checkpoint_path and tc.checkpoint_every
                        and (k + 1) % tc.checkpoint_every == 0):
                    meta = {"step": k + 1, "algo": tc.algo, "wire": tc.wire}
                    ckpt_ctx = (rec.span("train.checkpoint", tid="train",
                                         step=k + 1)
                                if rec is not None else _null_ctx())
                    with ckpt_ctx:
                        # params-only artifact (the eval/restore surface)
                        ckpt.save(tc.checkpoint_path, state["params"], meta)
                        # ... plus the FULL state (momentum, WireState,
                        # counters, PRNG key) so training resumes
                        # bit-identically
                        ckpt.save(tc.checkpoint_path + ".state", state, meta)
            bps = self.bytes_per_step(state)
            if writer is not None:
                writer.spans_from(rec)
                writer.result(bytes_per_step=bps,
                              steps=tc.steps, wall_s=time.time() - t0)
            if rec is not None and tc.trace_path:
                rec.save(tc.trace_path, process_name="trainer")
        finally:
            if writer is not None:
                writer.close()
        return {"state": state, "history": history,
                "bytes_per_step": bps}
