"""Decentralized training step: local grads -> optimizer -> gossip rule.

Layout: every training-state leaf carries a leading worker dim ``[n, ...]``
(n = 16 decentralized single-pod, 32 multi-pod; 1/2 hierarchical), sharded
over the worker mesh axes.  Per-worker gradients are ``vmap(grad(loss))`` —
XLA keeps them communication-free along the worker axis; the only cross-worker
traffic is the algorithm's gossip, which every algorithm routes through
``repro.comm.engine.CommEngine`` (quantized collective-permutes for Moniqua;
``AlgoHyper.wire`` / ``AlgoHyper.backend`` / ``AlgoHyper.path`` /
``AlgoHyper.chunks`` select codec, backend, gossip path, and the staged
round's chunk count, and the per-step wire bytes are reported in the step
metrics).  On the bucketed path the gossip inside the jitted step flattens
the whole param tree through a memoized ``comm/bucket.py`` layout — the
trainer warms that cache from the abstract state before jit, so tracing
never rebuilds it.

Stateful wires (``ef_qsgd`` / ``onebit``) need no special-casing here: their
per-worker ``WireState`` (EF residual + warmup counter) lives inside the
algorithm's ``extra`` carry, so it flows through the jitted step, the
``extra_spec`` sharding resolution (residual rows shard on the worker axis,
the counter replicates), and full-state checkpointing like any other
algorithm buffer.  The same holds for ``AlgoHyper.overlap == "stale"``:
the one-round-stale gossip carry (previous packed payload + reference)
rides under ``extra["gossip"]``.

``state_pspecs`` / ``batch_pspecs`` resolve the logical-axis annotations into
PartitionSpecs for jit shardings (trainer and launch/dryrun share them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.algorithms import AlgoHyper, Algorithm, get_algorithm
from repro.core.theta import ThetaSchedule
from repro.models.model_factory import Model
from repro.models.sharding import ShardingRules, safe_pspec
from repro.optim import sgd as optim

PyTree = Any


def n_workers_for(cfg, rules: ShardingRules, mesh_shape: Dict[str, int]) -> int:
    axes = rules.worker_axes
    n = 1
    for a in axes:
        n *= mesh_shape.get(a, 1)
    return max(n, 1)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

def init_state(model: Model, algo: Algorithm, hp: AlgoHyper, n_workers: int,
               key) -> Dict[str, PyTree]:
    """All workers start from identical weights (assumption A4)."""
    params = model.init(key)
    X = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_workers,) + a.shape),
                     params)
    return {
        "params": X,
        "mom": optim.init_momentum(X),
        "extra": algo.init(X, hp),
        "step": jnp.zeros((), jnp.int32),
        "g_inf": jnp.ones((), jnp.float32),   # running ||g||_inf for theta
        "key": jax.random.PRNGKey(0),
    }


def abstract_state(model: Model, algo: Algorithm, hp: AlgoHyper,
                   n_workers: int):
    return jax.eval_shape(
        lambda k: init_state(model, algo, hp, n_workers, k),
        jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Logical -> PartitionSpec resolution
# ---------------------------------------------------------------------------

def _lookup_logical(logical, path):
    node = logical
    for part in path:
        if isinstance(part, jax.tree_util.DictKey):
            node = node[part.key]
        elif isinstance(part, jax.tree_util.SequenceKey):
            node = node[part.idx]
        elif isinstance(part, jax.tree_util.GetAttrKey):
            node = getattr(node, part.name)
        else:
            raise TypeError(part)
    return node


def params_pspecs(model: Model, rules: ShardingRules, mesh_shape,
                  stacked: bool = True) -> PyTree:
    logical = model.param_logical()
    abstract = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def resolve(path, leaf):
        names = tuple(_lookup_logical(logical, path))
        sizes = list(leaf.shape)
        if stacked:
            names = ("worker",) + names
            wn = 1
            for a in (rules.worker_axes or ()):
                wn *= mesh_shape.get(a, 1)
            sizes = [wn] + sizes       # worker dim == product of worker axes
        return safe_pspec(sizes, rules.pspec(*names), mesh_shape)

    return jax.tree_util.tree_map_with_path(resolve, abstract)


def batch_pspecs(batch: PyTree, rules: ShardingRules, mesh_shape,
                 stacked: bool = True) -> PyTree:
    def resolve(leaf):
        if stacked:
            names = ("worker", "batch") + (None,) * (leaf.ndim - 2)
        else:
            names = ("batch",) + (None,) * (leaf.ndim - 1)
        return safe_pspec(leaf.shape, rules.pspec(*names), mesh_shape)
    return jax.tree.map(resolve, batch)


def state_pspecs(model: Model, algo: Algorithm, hp: AlgoHyper,
                 rules: ShardingRules, mesh_shape, n_workers: int) -> PyTree:
    pp = params_pspecs(model, rules, mesh_shape, stacked=True)
    ab = abstract_state(model, algo, hp, n_workers)

    def extra_spec(leaf):
        # algorithm extras mirror param shapes (replicas/error buffers) or are
        # scalars; shard like params when ranks match a leading worker dim
        if leaf.ndim >= 1 and leaf.shape[0] == n_workers:
            names = ("worker",) + (None,) * (leaf.ndim - 1)
            return safe_pspec(leaf.shape, rules.pspec(*names), mesh_shape)
        return P()

    return {
        "params": pp,
        "mom": pp,
        "extra": jax.tree.map(extra_spec, ab["extra"]),
        "step": P(),
        "g_inf": P(),
        "key": P(),
    }


# ---------------------------------------------------------------------------
# The step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    algo: str = "moniqua"
    sgd: optim.SGDConfig = dataclasses.field(default_factory=optim.SGDConfig)
    lr: float = 0.1
    lr_schedule: Optional[Callable[[jax.Array], jax.Array]] = None
    theta: ThetaSchedule = dataclasses.field(default_factory=ThetaSchedule)


def make_train_step(model: Model, hp: AlgoHyper, tcfg: TrainStepConfig
                    ) -> Callable[[PyTree, PyTree], Tuple[PyTree, PyTree]]:
    algo = get_algorithm(tcfg.algo)
    sched = tcfg.lr_schedule or optim.constant(tcfg.lr)

    def train_step(state, batch):
        X, mom, extra = state["params"], state["mom"], state["extra"]
        step, key = state["step"], state["key"]
        key, k_algo = jax.random.split(key)

        losses, grads = jax.vmap(jax.value_and_grad(model.loss))(X, batch)
        dirs, mom, g_inf_now = optim.direction(tcfg.sgd, grads, X, mom)
        g_inf = jnp.maximum(0.9 * state["g_inf"], g_inf_now)

        alpha = sched(step)
        theta = tcfg.theta(alpha, g_inf)
        hp_k = dataclasses.replace(hp, theta=theta)
        X, extra = algo.step(X, extra, dirs, alpha, step, k_algo, hp_k)

        new_state = {"params": X, "mom": mom, "extra": extra,
                     "step": step + 1, "g_inf": g_inf, "key": key}
        # bytes_per_step is shape-only bookkeeping: a trace-time constant
        metrics = {"loss": jnp.mean(losses), "alpha": alpha,
                   "theta": jnp.asarray(theta, jnp.float32), "g_inf": g_inf,
                   "wire_bytes": jnp.asarray(
                       algo.bytes_per_step(X, hp), jnp.float32)}
        if isinstance(extra, dict) and "health" in extra:
            # hp.telemetry: the algorithm's accumulated round-health carry
            # (repro.obs.metrics) surfaces as obs_* step metrics
            metrics.update({f"obs_{k}": v
                            for k, v in extra["health"].items()})
        return new_state, metrics

    return train_step
