"""Serving steps (prefill forward + cached single-token decode).

Serving carries no decentralized worker dim — the paper's technique moves
model state between *training* workers; at inference there is one model,
sharded TP/2-D over the mesh (DESIGN §4).  Decode workloads lower
``serve_step``: ONE new token against a KV cache / recurrent state of the
workload's context length.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape
from repro.models.model_factory import Model
from repro.models.sharding import ShardingRules, safe_pspec

PyTree = Any


def make_prefill_step(model: Model, *, last_only: bool = True
                      ) -> Callable[[PyTree, PyTree], jax.Array]:
    """Prefill forward.  last_only=True returns [B, 1, V] logits for the
    final position only — what a serving sampler consumes (vLLM semantics);
    the full [B, S, V] f32 logits tensor is never materialised."""
    def prefill_step(params, batch):
        # named scope: the prefill phase is attributed in XLA/profiler
        # output (same convention as CommEngine's comm.* gossip phases)
        with jax.named_scope("serve.prefill"):
            return model.prefill_logits(params, batch, last_only=last_only)
    return prefill_step


def make_serve_step(model: Model) -> Callable[..., Tuple[jax.Array, PyTree]]:
    def serve_step(params, cache, token):
        with jax.named_scope("serve.decode"):
            return model.decode_step(params, cache, token)
    return serve_step


def abstract_cache(model: Model, shape: InputShape):
    return jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape))


def cache_pspecs(model: Model, shape: InputShape, rules: ShardingRules,
                 mesh_shape) -> PyTree:
    ab = abstract_cache(model, shape)
    kv_div = model.cfg.num_kv_heads % max(mesh_shape.get("model", 1), 1) == 0
    logical = model.cache_logical(kv_div=kv_div)
    ab_leaves, treedef = jax.tree.flatten(ab)
    log_leaves, _ = jax.tree.flatten(
        logical, is_leaf=lambda x: isinstance(x, tuple))
    assert len(ab_leaves) == len(log_leaves), (len(ab_leaves), len(log_leaves))
    specs = [safe_pspec(l.shape, rules.pspec(*n), mesh_shape)
             for l, n in zip(ab_leaves, log_leaves)]
    return jax.tree.unflatten(treedef, specs)
