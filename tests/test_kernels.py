"""Pallas kernels vs pure-jnp oracle (ref.py), interpret=True on CPU.

Sweeps shapes x dtypes x bit-widths; encode must be BIT-EXACT against the
oracle (same counter-based hash RNG), decode allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import QuantSpec
from repro.kernels import moniqua_decode as DEC
from repro.kernels import moniqua_encode as ENC
from repro.kernels import ops
from repro.kernels import ref as R

BITS = [1, 2, 4, 8]


def _tile(shape=(256, 1024), dtype=jnp.float32, seed=0, scale=3.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)
    return (x * scale).astype(dtype)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stochastic", [False, True])
def test_encode_bit_exact(bits, stochastic):
    x = _tile()
    B = jnp.float32(4.0)
    p_k = ENC.encode(x, B, jnp.uint32(7), bits=bits, stochastic=stochastic,
                     interpret=True)
    p_r = R.encode_ref(x, 4.0, bits, stochastic, 7)
    assert p_k.dtype == jnp.uint8
    assert p_k.shape == (x.shape[0], x.shape[1] * bits // 8)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("mode", ["remote", "self"])
def test_decode_allclose(bits, mode):
    x = _tile(seed=1)
    B = 4.0
    p = R.encode_ref(x, B, bits, True, 3)
    y = x + 0.3 * _tile(seed=2, scale=1.0)
    d_k = DEC.decode(p, y, jnp.float32(B), bits=bits, mode=mode,
                     interpret=True)
    d_r = (R.decode_ref(p, y, B, bits) if mode == "remote"
           else R.decode_self_ref(p, y, B, bits))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r),
                               rtol=0, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    x = _tile(dtype=dtype, seed=4)
    p_k = ENC.encode(x, jnp.float32(4.0), jnp.uint32(0), bits=4,
                     stochastic=True, interpret=True)
    p_r = R.encode_ref(x, 4.0, 4, True, 0)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    d_k = DEC.decode(p_k, x, jnp.float32(4.0), bits=4, mode="remote",
                     interpret=True)
    assert d_k.dtype == dtype
    d_r = R.decode_ref(p_r, x, 4.0, 4)
    np.testing.assert_allclose(np.asarray(d_k, dtype=np.float32),
                               np.asarray(d_r), rtol=0, atol=0.05)


def test_multi_block_grid():
    """More than one grid block: global flat index must stay consistent."""
    x = _tile((512, 2048), seed=5)
    p_k = ENC.encode(x, jnp.float32(4.0), jnp.uint32(11), bits=8,
                     stochastic=True, interpret=True)
    p_r = R.encode_ref(x, 4.0, 8, True, 11)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("shape", [(7,), (3, 50), (2, 5, 33), (1000,)])
@pytest.mark.parametrize("bits", [2, 8])
def test_ops_wrapper_arbitrary_shapes(shape, bits):
    """ops.moniqua_encode/decode handle non-tile shapes via pad/unpad and the
    end-to-end roundtrip respects the Lemma 2 error bound."""
    theta = 2.0
    spec = QuantSpec(bits=bits, stochastic=True)
    B = 2.0 * theta / (1.0 - 2.0 * spec.delta)
    key = jax.random.PRNGKey(9)
    y = jax.random.normal(key, shape, dtype=jnp.float32) * 5.0
    x = y + jax.random.uniform(jax.random.PRNGKey(10), shape,
                               minval=-0.9, maxval=0.9) * theta
    p = ops.moniqua_encode(x, jnp.float32(B), spec, key, interpret=True)
    vpb = 8 // bits
    assert p.shape[-1] == -(-shape[-1] // vpb)
    out = ops.moniqua_decode_remote(p, y, jnp.float32(B), spec,
                                    interpret=True)
    assert out.shape == x.shape
    err = float(jnp.max(jnp.abs(out - x)))
    assert err <= spec.delta * B * (1 + 1e-3)


def test_ops_self_mode_matches_core():
    """decode_self wrapper agrees with the core jnp path numerically."""
    from repro.core import modulo
    spec = QuantSpec(bits=8, stochastic=False)
    theta = 2.0
    B = float(modulo.b_theta(theta, spec.delta))
    x = jax.random.normal(jax.random.PRNGKey(2), (128,), jnp.float32)
    p = ops.moniqua_encode(x, jnp.float32(B), spec, None, interpret=True)
    out = ops.moniqua_decode_self(p, x, jnp.float32(B), spec, interpret=True)
    # reconstruct with ref to compare
    ref = R.decode_self_ref(p, x, B, 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_kernel_rejects_untied_shapes():
    with pytest.raises(ValueError):
        ENC.encode(jnp.zeros((100, 100)), jnp.float32(1.0), jnp.uint32(0),
                   bits=8, interpret=True)
