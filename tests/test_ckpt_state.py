"""Full-state checkpointing: a resumed run is bit-identical to an
uninterrupted one.

``Trainer.run`` writes two artifacts per checkpoint: the params-only file
(the eval/restore surface ``tests/test_trainer.py`` covers) and a
``.state`` sidecar holding the FULL training state — momentum, algorithm
extras including the EF wires' ``WireState`` (residual + warmup counter),
step, ``g_inf``, PRNG key.  ``Trainer.restore_state`` + ``run`` must then
replay exactly the trajectory the uninterrupted run takes: the data
pipeline is indexed by the global step and every source of randomness
rides in the state, so there is nothing left to drift.

The onebit case checkpoints BEFORE the warmup switch and resumes across
it — the carried counter is what makes the warm->quantized schedule
land on the same global step either way.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig

SHAPE = InputShape("tiny", seq_len=16, global_batch=8, kind="train")


def _tiny_model():
    cfg = get_config("llama3.2-3b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=1, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=64)
    return build_model(cfg)


def _assert_trees_bitwise_equal(a, b):
    la, paths = jax.tree.leaves(a), jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(paths, lb):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(x)), np.asarray(jax.device_get(y)),
            err_msg=f"leaf {jax.tree_util.keystr(path)}")


@pytest.mark.parametrize("wire,warmup", [("ef_qsgd", 16), ("onebit", 4)])
def test_ef_wire_resume_is_bit_identical(tmp_path, wire, warmup):
    """3 steps + checkpoint + 3 resumed steps == 6 uninterrupted steps,
    bitwise, for the full state tree (params, momentum, WireState, ...).
    onebit's warmup=4 puts the checkpoint (step 3) before the switch and
    the resumed leg across it."""
    model = _tiny_model()
    common = dict(algo="moniqua", wire=wire, n_workers=2, bits=4,
                  theta=2.0, lr=0.1, log_every=10, seed=3, warmup=warmup)
    path = str(tmp_path / f"{wire}.npz")

    # interrupted: 3 steps, checkpoint, then resume for 3 more
    t1 = Trainer(model, SHAPE, TrainerConfig(steps=3, checkpoint_path=path,
                                             checkpoint_every=3, **common))
    t1.run()
    t2 = Trainer(model, SHAPE, TrainerConfig(steps=3, checkpoint_path=path,
                                             **common))
    resumed = t2.run(t2.restore_state())["state"]
    assert int(jax.device_get(resumed["step"])) == 6

    # uninterrupted reference: 6 straight steps, no checkpoint I/O
    ref = Trainer(model, SHAPE,
                  TrainerConfig(steps=6, **common)).run()["state"]
    _assert_trees_bitwise_equal(resumed, ref)
    # the WireState specifically made the trip: nonzero residual for
    # ef_qsgd (onebit is still warm at step 3 — counter does the work)
    assert int(jax.device_get(resumed["extra"]["wire"]["step"])) == 6
    if wire == "ef_qsgd":
        r = jax.device_get(resumed["extra"]["wire"]["residual"])
        assert float(np.max(np.abs(r))) > 0.0


def test_state_sidecar_written_next_to_params_artifact(tmp_path):
    model = _tiny_model()
    path = str(tmp_path / "m.npz")
    tc = TrainerConfig(algo="moniqua", wire="ef_qsgd", n_workers=2, bits=4,
                       steps=2, checkpoint_path=path, checkpoint_every=2,
                       log_every=10)
    t = Trainer(model, SHAPE, tc)
    out = t.run()
    # params-only artifact restores against a params template (the
    # pre-existing eval surface), the sidecar against the full state
    params = ckpt.restore(path, out["state"]["params"])
    _assert_trees_bitwise_equal(params, out["state"]["params"])
    full = ckpt.restore(path + ".state", t.init_state())
    _assert_trees_bitwise_equal(full, out["state"])


def test_typed_prng_key_roundtrips(tmp_path):
    """checkpoint/ckpt.py stores typed PRNG keys via key_data and rewraps
    them with the template's impl on restore — new-style keys in trainer
    state survive the npz round-trip bit-identically."""
    path = str(tmp_path / "k.npz")
    tree = {"key": jax.random.key(7), "w": jnp.arange(4.0)}
    ckpt.save(path, tree, {"step": 0})
    back = ckpt.restore(path, {"key": jax.random.key(0),
                               "w": jnp.zeros(4)})
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(back["key"])),
        np.asarray(jax.random.key_data(tree["key"])))
    assert jax.random.key_impl(back["key"]) == jax.random.key_impl(
        tree["key"])
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    # and the legacy uint32 key format keeps working unchanged
    legacy = {"key": jax.random.PRNGKey(7)}
    ckpt.save(path, legacy, {"step": 0})
    back = ckpt.restore(path, {"key": jax.random.PRNGKey(0)})
    np.testing.assert_array_equal(np.asarray(back["key"]),
                                  np.asarray(legacy["key"]))
