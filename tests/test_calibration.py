"""Depth-probe calibration math + roofline table merge logic."""
import json

import pytest

from repro.launch.calibrate import _extrapolate, SCANNED_FAMILIES
from repro.configs import assigned_archs, get_config


def test_extrapolate_linear():
    # cost(L) = a + b L with probes at L=1,2
    a, b = 5.0, 3.0
    c1, c2 = a + b, a + 2 * b
    for L in (1, 2, 6, 48, 80):
        assert _extrapolate(c1, c2, L) == pytest.approx(a + b * L)


def test_extrapolate_never_negative():
    assert _extrapolate(10.0, 4.0, 100) == 0.0


def test_scanned_family_coverage():
    fams = {get_config(a).family for a in assigned_archs()}
    # scan-undercount correction covers exactly the scanned-stack families
    assert set(SCANNED_FAMILIES) == {"dense", "moe", "vlm", "audio"}
    assert fams - set(SCANNED_FAMILIES) == {"ssm", "hybrid"}


def test_roofline_table_merge(tmp_path):
    from benchmarks import roofline_table as RT
    raw = tmp_path / "raw.jsonl"
    cal = tmp_path / "cal.jsonl"
    row = {"arch": "a", "shape": "train_4k", "mesh": "16x16", "status": "ok",
           "roofline": {"compute_s": 1.0, "memory_s": 2.0,
                        "collective_s": 0.5, "dominant": "memory",
                        "useful_ratio": 5.0, "mfu_upper_bound": 2.0,
                        "flops_per_chip": 1, "bytes_per_chip": 1,
                        "collective_bytes_per_chip": 1},
           "memory": {"peak_estimate_gb": 3.0},
           "collectives": {"summary": "none", "counts": {}, "bytes": {}}}
    raw.write_text(json.dumps(row) + "\n")
    crow = {"arch": "a", "shape": "train_4k", "mesh": "16x16",
            "status": "ok",
            "roofline_calibrated": dict(row["roofline"], compute_s=10.0,
                                        useful_ratio=0.5,
                                        mfu_upper_bound=0.1,
                                        dominant="compute"),
            "collectives_calibrated": {"counts": {}, "bytes": {}}}
    cal.write_text(json.dumps(crow) + "\n")
    rows = RT.load(str(raw), str(cal))
    assert "roofline_calibrated" in rows[0]
    table = RT.roofline_rows(rows)
    assert table[0]["status"] == "ok*"
    assert table[0]["compute_ms"] == pytest.approx(10_000.0)
    assert table[0]["useful_ratio"] == 0.5
