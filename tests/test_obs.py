"""repro.obs: round-health telemetry, phase traces, and run logs.

The observability contracts (docs/observability.md):

1. **Observational purity** — CommEngine.mix outputs (and WireState, for
   the stateful wires) are bit-exact with ``telemetry`` on or off, for
   every wire, on both gossip paths and both backend names; same at the
   algorithm level across jitted steps.
2. **Path/backend invariance** — the health values themselves are
   identical whether the engine runs bucketed or per-leaf, pallas or jnp
   (telemetry always evaluates on the canonical flat buffer with the jnp
   reference encode).
3. **Alias sentinel** — exactly zero on runs satisfying Lemma 1's
   ``|x_i - x_j|_inf < theta`` hypothesis; reliably nonzero over
   model-sized buffers once theta is undersized.
4. **Artifacts** — run logs validate against ``repro.obs.runlog/v1``,
   SpanRecorder / SimTrace exports validate as Chrome traces, and the
   ``tools/obs_report.py`` / ``tools/check_obs.py`` pipeline reads them.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.engine import CommEngine, make_wire
from repro.core import modulo
from repro.core.algorithms import AlgoHyper, get_algorithm
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.obs import metrics as M
from repro.obs import runlog as RL
from repro.obs import trace as TR

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))


def _stacked(scale=0.02, n=8, d=512, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * scale


def _tree(scale=0.02):
    return {"w": _stacked(scale=scale), "b": _stacked(scale=scale, d=33,
                                                      seed=1)}


def _engine(wire="moniqua", bits=8, backend="jnp", path="bucketed",
            telemetry=False, warmup=2, n=8):
    spec = QuantSpec(bits=bits, stochastic=bits > 1)
    return CommEngine(ring(n), make_wire(wire, spec, warmup=warmup)
                      if wire in ("ef_qsgd", "onebit")
                      else make_wire(wire, spec),
                      backend=backend, path=path,
                      telemetry=telemetry)


# ---------------------------------------------------------------------------
# 1. observational purity: outputs bit-exact with telemetry on/off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path", ["bucketed", "per_leaf"])
@pytest.mark.parametrize("wire,bits", [("full", 32), ("moniqua", 8),
                                       ("moniqua", 1), ("qsgd", 4)])
def test_stateless_mix_bit_exact_on_off(wire, bits, path):
    X = _tree()
    key = jax.random.PRNGKey(3)
    kw = dict(theta=2.0, key=key) if wire != "full" else {}
    off = _engine(wire, bits, path=path).mix(X, **kw).x
    r = _engine(wire, bits, path=path, telemetry=True).mix(X, **kw)
    on, health = r.x, r.health
    for k in X:
        np.testing.assert_array_equal(np.asarray(off[k]), np.asarray(on[k]))
    assert set(health) == set(M.HEALTH_ROUND_KEYS)
    assert health["alias_count"].dtype == jnp.int32


@pytest.mark.parametrize("path", ["bucketed", "per_leaf"])
@pytest.mark.parametrize("wire", ["ef_qsgd", "onebit"])
def test_stateful_mix_bit_exact_on_off(wire, path):
    """3 iterated rounds (crossing the onebit warmup switch): outputs AND
    the carried WireState are untouched by the telemetry flag."""
    Xa = Xb = _tree()
    a = _engine(wire, 4, path=path)
    b = _engine(wire, 4, path=path, telemetry=True)
    sa, sb = a.init_wire_state(Xa), b.init_wire_state(Xb)
    for k in range(3):
        key = jax.random.PRNGKey(40 + k)
        ra = a.mix(Xa, key=key, state=sa)
        rb = b.mix(Xb, key=key, state=sb)
        Xa, sa = ra.x, ra.state
        Xb, sb, health = rb.x, rb.state, rb.health
        for lk in Xa:
            np.testing.assert_array_equal(np.asarray(Xa[lk]),
                                          np.asarray(Xb[lk]),
                                          err_msg=f"round {k} {lk}")
        np.testing.assert_array_equal(np.asarray(sa["residual"]),
                                      np.asarray(sb["residual"]),
                                      err_msg=f"round {k} residual")
        # the warm flag reports the round just executed
        assert float(health["warm"]) == (1.0 if wire == "onebit" and k < 2
                                         else 0.0)
        assert float(health["ef_residual_l2"]) >= 0.0


@pytest.mark.parametrize("algo", ["dpsgd", "moniqua", "d2", "moniqua_d2"])
def test_algorithm_trajectory_unchanged_on_off(algo):
    """Jitted algorithm steps: the telemetry flag must not change the
    trajectory.  Eager engine mixes are bit-exact (tests above); under
    jit the extra telemetry consumers of the staging buffer may legally
    re-fuse the mix math (the repo's documented ~1-ulp FMA-contraction
    caveat), so this asserts a 1-ulp-tight bound instead of equality.
    The telemetry run also carries ``extra['health']`` with the
    cumulative alias counter threaded across steps."""
    n, d = 8, 256
    X = _stacked(n=n, d=d, scale=0.05)
    g = _stacked(n=n, d=d, seed=7, scale=0.1)
    a = get_algorithm(algo)

    def run(telemetry):
        hp = AlgoHyper(topo=ring(n),
                       codec=MoniquaCodec(QuantSpec(bits=8, stochastic=True)),
                       theta=2.0, telemetry=telemetry)
        extra = a.init(X, hp)
        step = jax.jit(lambda x, e, gg, k, kk: a.step(x, e, gg, 0.1, k, kk,
                                                      hp))
        x = X
        for k in range(3):
            x, extra = step(x, extra, g, jnp.asarray(k),
                            jax.random.PRNGKey(100 + k))
        return x, extra

    x_off, _ = run(False)
    x_on, extra_on = run(True)
    np.testing.assert_allclose(np.asarray(x_off), np.asarray(x_on),
                               rtol=0, atol=1e-6)
    h = extra_on["health"]
    assert set(h) == set(M.HEALTH_KEYS)
    assert int(h["alias_total"]) == 0          # safe theta: no alias events
    assert float(h["consensus_inf"]) > 0.0


# ---------------------------------------------------------------------------
# 2. path/backend invariance of the health values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [1, 4, 8])
def test_health_invariant_across_paths_and_backends(bits):
    X = _tree()
    key = jax.random.PRNGKey(11)
    ref = None
    for backend in ("jnp", "pallas"):
        for path in ("bucketed", "per_leaf"):
            h = _engine("moniqua", bits, backend=backend,
                        path=path, telemetry=True).mix(
                            X, theta=2.0, key=key).health
            h = {k: np.asarray(v) for k, v in h.items()}
            if ref is None:
                ref = h
                continue
            for k in M.HEALTH_ROUND_KEYS:
                np.testing.assert_array_equal(
                    h[k], ref[k], err_msg=f"{k} @ {backend}/{path}")


# ---------------------------------------------------------------------------
# 3. the alias sentinel
# ---------------------------------------------------------------------------

def test_alias_zero_when_theta_bound_holds():
    """Lemma 1 hypothesis satisfied (with guard-band margin) -> exactly
    zero for every width whose sentinel is live (delta < 1/4)."""
    X = _tree(scale=0.01)   # consensus_inf << theta - delta*B
    for bits in (4, 8):
        h = _engine("moniqua", bits, telemetry=True).mix(
            X, theta=2.0, key=jax.random.PRNGKey(0)).health
        assert int(h["alias_count"]) == 0, bits
        assert float(h["headroom"]) < 0.5


def test_alias_pinned_to_zero_without_guard_band():
    """delta >= 1/4 (1-bit nearest, 2-bit stochastic): quantization error
    alone spans the whole band, so the sentinel is pinned to 0 even under
    gross violation — headroom is the live signal at these widths."""
    X = {"w": _stacked(scale=3.0, d=2048, seed=5)}
    for bits in (1, 2):
        h = _engine("moniqua", bits, telemetry=True).mix(
            X, theta=0.05, key=jax.random.PRNGKey(2)).health
        assert int(h["alias_count"]) == 0, bits
        assert float(h["headroom"]) > 0.5   # ...but headroom screams


@pytest.mark.parametrize("bits", [4, 8])
def test_alias_fires_when_theta_undersized(bits):
    """Gross theta violation over a model-sized buffer: neighbor distances
    are many multiples of B, so wrapped decodes land in the outer band at
    per-element rate ~2*delta per neighbor (1/8 @4-bit, 1/128 @8-bit
    stochastic) — thousands of hits at 4 bits, dozens at 8, never zero."""
    X = {"w": _stacked(scale=3.0, d=4096, seed=5)}   # >> theta=0.05
    h = _engine("moniqua", bits, telemetry=True).mix(
        X, theta=0.05, key=jax.random.PRNGKey(2)).health
    count = int(h["alias_count"])
    assert count > 0, f"undersized theta must trip the sentinel ({bits}b)"
    # calibration sanity: within a loose factor of the ~2*delta rate
    delta = QuantSpec(bits=bits, stochastic=True).delta
    expect = 2 * delta * 2 * 8 * 4096    # 2 neighbors x n x d
    assert count > expect / 8
    assert float(h["headroom"]) > 0.5


def test_alias_band_mask_semantics():
    """The band predicate on hand-built payload values (B=1, theta=0.4):
    fires iff ``|cmod(qb - y, B)| >= theta``, i.e. iff the payload's
    recovered difference lands in ``[theta, B - theta]`` mod B.  The
    d=0.61 case is the instructive one: a true violation whose wrap
    lands back inside (-theta, theta) — aliasing is per-element
    undetectable from the payload alone, which is exactly why the
    sentinel aggregates counts over model-sized buffers."""
    from repro.kernels import moniqua_decode_reduce as dr
    B, theta = 1.0, 0.4
    y = jnp.zeros((1, 6))
    qb = jnp.asarray([[0.00,    # in consensus: no fire
                       0.39,    # just under theta: no fire
                       0.45,    # budget exhausted: fire
                       0.55,    # cmod -> -0.45: fire
                       0.61,    # cmod -> -0.39: silent alias, no fire
                       1.00]])  # full period, cmod -> 0: no fire
    mask = np.asarray(dr.alias_band_mask(qb, y, B, theta))[0]
    np.testing.assert_array_equal(
        mask, [False, False, True, True, False, False])
    # shifting the reference shifts the band with it
    mask2 = np.asarray(dr.alias_band_mask(qb + 3.2, y + 3.2, B, theta))[0]
    np.testing.assert_array_equal(mask, mask2)


# ---------------------------------------------------------------------------
# 4. AD-PSGD edge telemetry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantized", [False, True])
def test_adpsgd_telemetry_pure_and_traced(quantized):
    from repro.core import adpsgd as A
    n, d = 8, 64
    x0 = _stacked(n=n, d=d, scale=0.01)
    grad = lambda x, i, k: x + 0.05 * jax.random.normal(k, x.shape)  # noqa
    kw = dict(topo=ring(n),
              codec=MoniquaCodec(QuantSpec(bits=8, stochastic=True)),
              theta=2.0, quantized=quantized)
    key = jax.random.PRNGKey(0)
    Xf0, tr0 = A.run(x0, grad, 0.05, 20, A.ADPSGDConfig(**kw), key)
    Xf1, tr1, health = A.run(x0, grad, 0.05, 20,
                             A.ADPSGDConfig(telemetry=True, **kw), key)
    np.testing.assert_array_equal(np.asarray(Xf0), np.asarray(Xf1))
    np.testing.assert_array_equal(np.asarray(tr0), np.asarray(tr1))
    assert set(health) == set(M.HEALTH_ROUND_KEYS)
    assert health["consensus_inf"].shape == (20,)
    assert int(jnp.sum(health["alias_count"])) == 0
    bpp = float(health["bits_per_param"][0])
    assert bpp == (8.0 if quantized else 32.0)


# ---------------------------------------------------------------------------
# 5. run logs + Chrome traces
# ---------------------------------------------------------------------------

def test_runlog_roundtrip_and_validation(tmp_path):
    path = str(tmp_path / "run.jsonl")
    rec = TR.SpanRecorder()
    with rec.span("phase.a", tid="t0", step=1):
        pass
    with RL.RunLogWriter(path, run={"algo": "moniqua", "bits": 8,
                                    "theta": jnp.float32(2.0)}) as w:
        w.step(0, {"loss": jnp.float32(1.5), "obs_alias_count": 0,
                   "obs_alias_total": 0})
        w.step(5, {"loss": 1.2, "obs_alias_count": 2, "obs_alias_total": 3})
        w.spans_from(rec)
        w.event("checkpoint", {"step": 5})
        w.result(steps=6, bytes_per_step=1234)
    assert RL.validate_runlog(path) == []
    records = RL.read_runlog(path)
    assert records[0]["kind"] == "header"
    assert records[0]["schema"] == RL.SCHEMA
    assert records[0]["run"]["theta"] == 2.0       # jax scalar -> JSON float
    assert len(RL.step_records(records)) == 2
    # alias_events prefers the cumulative counter over the per-step sum
    assert RL.alias_events(records) == 3


def test_runlog_validation_catches_malformed(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"kind": "step", "step": 0, "metrics": {}}) + "\n")
        f.write(json.dumps({"kind": "wat"}) + "\n")
        f.write(json.dumps({"kind": "span", "name": "x", "t0_s": -1.0,
                            "dur_s": 0.1}) + "\n")
    errors = RL.validate_runlog(path)
    assert any("header" in e for e in errors)
    assert any("unknown kind" in e for e in errors)
    assert any("t0_s" in e for e in errors)


def test_span_recorder_chrome_export_validates(tmp_path):
    rec = TR.SpanRecorder()
    with rec.span("outer", tid="train", step=0):
        with rec.span("inner", tid="train"):
            pass
    rec.instant("marker", tid="train")
    obj = rec.to_chrome(process_name="test")
    assert TR.validate_chrome(obj) == []
    names = {e["name"] for e in obj["traceEvents"]}
    assert {"outer", "inner", "marker"} <= names
    phases = {e["name"]: e["ph"] for e in obj["traceEvents"]
              if e["ph"] in ("X", "i")}
    assert phases["marker"] == "i" and phases["outer"] == "X"
    path = str(tmp_path / "t.json")
    rec.save(path)
    with open(path) as f:
        assert TR.validate_chrome(json.load(f)) == []


def test_sim_trace_to_chrome_and_merge():
    from repro.sim import events as SE
    from repro.sim import scenarios as SC
    sc = SC.get_scenario("lan-10gbe-ring", n=4)
    trace = SE.simulate_sync_rounds(sc, 10_000, num_rounds=3)
    sim_obj = trace.to_chrome()
    assert TR.validate_chrome(sim_obj) == []
    assert any(e.get("pid") == 1 for e in sim_obj["traceEvents"])
    rec = TR.SpanRecorder()
    with rec.span("train.step", tid="train"):
        pass
    merged = TR.merge_chrome_traces([rec.to_chrome(), sim_obj])
    assert TR.validate_chrome(merged) == []
    pids = {e.get("pid") for e in merged["traceEvents"]}
    assert {0, 1} <= pids            # measured + sim side by side


def test_trainer_end_to_end_runlog_and_trace(tmp_path):
    """Trainer with telemetry + log_jsonl + trace_path: obs_* metrics in
    the history, a schema-valid run log the CI gate passes, and a valid
    Chrome trace with train.step spans — the whole satellite pipeline."""
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.models.model_factory import build_model
    from repro.train.trainer import Trainer, TrainerConfig
    import dataclasses
    cfg = get_config("llama3.2-3b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=1, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=64)
    model = build_model(cfg)
    shape = InputShape("tiny", seq_len=16, global_batch=8, kind="train")
    log = str(tmp_path / "run.jsonl")
    tr = str(tmp_path / "trace.json")
    tc = TrainerConfig(algo="moniqua", n_workers=4, bits=8, theta=2.0,
                       lr=0.3, steps=4, log_every=2, momentum=0.0,
                       weight_decay=0.0, telemetry=True, log_jsonl=log,
                       trace_path=tr)
    out = Trainer(model, shape, tc).run()
    h = out["history"][-1]
    assert "obs_headroom" in h and "obs_alias_total" in h
    assert h["obs_alias_total"] == 0        # theta=2 is safe on this run
    assert 0.0 < h["obs_headroom"] < 0.5
    assert h["obs_bits_per_param"] == pytest.approx(8.0, abs=0.5)
    assert RL.validate_runlog(log) == []
    records = RL.read_runlog(log)
    assert RL.alias_events(records) == 0
    steps = RL.step_records(records)
    assert steps and "obs_headroom" in steps[-1]["metrics"]
    assert any(r.get("kind") == "span" and r["name"] == "train.step"
               for r in records)
    assert any(r.get("kind") == "result" for r in records)
    with open(tr) as f:
        obj = json.load(f)
    assert TR.validate_chrome(obj) == []
    assert any(e.get("name") == "train.step" and e.get("ph") == "X"
               for e in obj["traceEvents"])


# ---------------------------------------------------------------------------
# 6. the tools (report + CI gate)
# ---------------------------------------------------------------------------

def _write_alias_log(path):
    with RL.RunLogWriter(str(path), run={"algo": "moniqua"}) as w:
        w.step(0, {"loss": 1.0, "obs_alias_count": 7, "obs_alias_total": 7,
                   "obs_headroom": 0.9, "theta": 0.05})
        w.result(steps=1)


def test_check_obs_gates_alias_and_telemetry(tmp_path, capsys):
    import check_obs
    bad = tmp_path / "alias.jsonl"
    _write_alias_log(bad)
    assert check_obs.main([str(bad)]) == 1
    assert "alias" in capsys.readouterr().out
    assert check_obs.main([str(bad), "--allow-alias"]) == 0
    # --require-telemetry fails a log whose steps carry no obs_* metrics
    plain = tmp_path / "plain.jsonl"
    with RL.RunLogWriter(str(plain)) as w:
        w.step(0, {"loss": 1.0})
    assert check_obs.main([str(plain)]) == 0
    assert check_obs.main([str(plain), "--require-telemetry"]) == 1


def test_obs_report_renders_and_warns(tmp_path, capsys):
    import obs_report
    log = tmp_path / "alias.jsonl"
    _write_alias_log(log)
    assert obs_report.main([str(log)]) == 0
    out = capsys.readouterr().out
    assert "ALIAS WARNING" in out and "Lemma 1" in out
    rec = TR.SpanRecorder()
    with rec.span("comm.encode", tid="t"):
        pass
    tr = tmp_path / "t.json"
    rec.save(str(tr))
    assert obs_report.main(["--trace", str(tr)]) == 0
    assert "comm.encode" in capsys.readouterr().out


def test_committed_sample_runlog_is_valid_and_alias_free():
    """RUNLOG_sample.jsonl (rendered in docs/observability.md) must stay
    schema-valid, telemetry-bearing, and alias-free — the obs-smoke CI
    job gates on exactly this."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "RUNLOG_sample.jsonl")
    assert RL.validate_runlog(path) == []
    records = RL.read_runlog(path)
    steps = RL.step_records(records)
    assert steps and any(k.startswith("obs_")
                         for k in steps[0].get("metrics", {}))
    assert RL.alias_events(records) == 0


# ---------------------------------------------------------------------------
# 7. property test: safe configurations never trip the sentinel
# ---------------------------------------------------------------------------

try:         # deterministic tests above must run even without hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # not in the baked image
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(bits=st.sampled_from([4, 8]),
           seed=st.integers(0, 2**31 - 1),
           scale=st.floats(1e-3, 0.4))
    def test_property_safe_runs_are_alias_free(bits, seed, scale):
        """For any seed/scale with ``consensus_inf < theta - delta*B``
        (Lemma 1's hypothesis plus the guard band), the sentinel is
        exactly zero: scale < 0.4 keeps the worst pairwise distance of
        the +-1-bounded rows under 0.8, and theta=1 leaves a 0.857
        guard-band threshold even at 4 bits."""
        x = jnp.tanh(_stacked(scale=1.0, d=128, seed=seed % 1000)) * scale
        h = _engine("moniqua", bits, telemetry=True).mix(
            {"w": x}, theta=1.0, key=jax.random.PRNGKey(seed % 65536)).health
        assert float(h["consensus_inf"]) < 1.0
        assert int(h["alias_count"]) == 0
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_safe_runs_are_alias_free():
        pass
