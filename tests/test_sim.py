"""repro.sim contracts: determinism, alpha-beta pricing, async liveness.

The three contracts the subsystem design promises (docs/simulator.md):

1. **Determinism** — same (scenario, seed) => identical event trace and
   identical wall-clock totals, for both the sync-round and async modes.
2. **alpha-beta semantics** — sync round time on a homogeneous network
   reduces to ``compute + m * bytes/beta + alpha (+ jitter)``; bytes are
   priced exactly once per edge per round.
3. **Async liveness** — the AD-PSGD event loop processes every scheduled
   gossip exactly once (one gossip per update, every edge in the
   topology) and never deadlocks, however heavy the straggler tail.
"""
import math

import pytest

from repro.core.topology import exponential, ring
from repro.sim import cluster as SCL
from repro.sim import events as SE
from repro.sim import network as SN
from repro.sim import scenarios as SC


# ---------------------------------------------------------------------------
# network: the alpha-beta link model
# ---------------------------------------------------------------------------

def test_alpha_beta_cost():
    lm = SN.LinkModel(alpha_s=1e-3, beta_Bps=1e6)
    assert lm.transfer_seconds(0) == pytest.approx(1e-3)
    assert lm.transfer_seconds(1_000_000) == pytest.approx(1e-3 + 1.0)
    assert lm.occupancy_seconds(500_000) == pytest.approx(0.5)


def test_jitter_is_deterministic_and_bounded():
    lm = SN.LinkModel(alpha_s=0.0, beta_Bps=1e9, jitter_s=1e-3)
    u = SN.sim_uniform(7, 1, 2, 3)
    assert 0.0 <= u < 1.0
    assert SN.sim_uniform(7, 1, 2, 3) == u          # pure counter hash
    assert SN.sim_uniform(8, 1, 2, 3) != u          # seed matters
    assert lm.transfer_seconds(0, u) <= 1e-3


def test_heterogeneous_links_keyed_by_offset():
    slow = SN.LinkModel(alpha_s=0.0, beta_Bps=1e6)
    fast = SN.LinkModel(alpha_s=0.0, beta_Bps=1e9)
    net = SN.NetworkModel(fast).with_offset_links({4: slow})
    n = 16
    assert net.link(0, 1, n) is fast
    assert net.link(0, 4, n) is slow           # hop distance 4
    assert net.link(4, 0, n) is slow           # symmetric
    assert net.link(0, 12, n) is slow          # (0-12) % 16 = 4 the short way
    assert net.link(0, 8, n) is fast           # hop 8 not overridden


def test_per_edge_beats_per_offset():
    a = SN.LinkModel(alpha_s=0.0, beta_Bps=1.0)
    b = SN.LinkModel(alpha_s=0.0, beta_Bps=2.0)
    c = SN.LinkModel(alpha_s=0.0, beta_Bps=3.0)
    net = SN.NetworkModel(a, per_offset=((1, b),), per_edge=(((2, 3), c),))
    assert net.link(2, 3, 8) is c
    assert net.link(3, 2, 8) is c
    assert net.link(0, 1, 8) is b


# ---------------------------------------------------------------------------
# cluster: straggler distributions
# ---------------------------------------------------------------------------

def test_compute_model_static_multipliers():
    cm = SCL.ComputeModel(base_s=0.1, multipliers=(4.0,))
    assert cm.compute_seconds(0, 0, seed=0) == pytest.approx(0.4)
    assert cm.compute_seconds(1, 0, seed=0) == pytest.approx(0.1)


@pytest.mark.parametrize("tail", ["exp", "pareto"])
def test_compute_model_tails_deterministic_and_positive(tail):
    cm = SCL.ComputeModel(base_s=0.1, tail=tail, tail_scale=1.0)
    ts = [cm.compute_seconds(2, k, seed=5) for k in range(50)]
    assert ts == [cm.compute_seconds(2, k, seed=5) for k in range(50)]
    assert all(t >= 0.1 for t in ts)
    assert len(set(ts)) > 1                    # actually stochastic
    assert all(math.isfinite(t) for t in ts)


def test_tail_workers_scopes_the_tail():
    cm = SCL.ComputeModel(base_s=0.1, tail="pareto", tail_scale=2.0,
                          tail_workers=(0,))
    assert cm.compute_seconds(1, 3, seed=0) == pytest.approx(0.1)
    assert cm.compute_seconds(0, 3, seed=0) > 0.1


def test_unknown_tail_rejected():
    with pytest.raises(ValueError):
        SCL.ComputeModel(base_s=0.1, tail="weibull")


# ---------------------------------------------------------------------------
# determinism: same scenario + seed => identical trace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SC.list_scenarios())
def test_sync_trace_deterministic(name):
    sc = SC.get_scenario(name, n=8)
    a = SE.simulate_sync_rounds(sc, bytes_per_neighbor=10_000, num_rounds=5)
    b = SE.simulate_sync_rounds(sc, bytes_per_neighbor=10_000, num_rounds=5)
    assert a.fingerprint() == b.fingerprint()
    assert a.total_seconds == b.total_seconds
    assert a.round_seconds == b.round_seconds
    assert [e.row() for e in a.events] == [e.row() for e in b.events]


def test_sync_trace_seed_sensitivity():
    sc = SC.get_scenario("straggler-longtail", n=8)
    a = SE.simulate_sync_rounds(sc, 10_000, 5)
    b = SE.simulate_sync_rounds(sc.with_seed(1), 10_000, 5)
    assert a.fingerprint() != b.fingerprint()


@pytest.mark.parametrize("name", SC.list_scenarios())
def test_async_trace_deterministic(name):
    sc = SC.get_scenario(name, n=8)
    a = SE.simulate_async_gossip(sc, bytes_per_exchange=1000, num_updates=60)
    b = SE.simulate_async_gossip(sc, bytes_per_exchange=1000, num_updates=60)
    assert a.fingerprint() == b.fingerprint()
    assert a.total_seconds == b.total_seconds
    assert a.staleness == b.staleness


# ---------------------------------------------------------------------------
# sync-round semantics
# ---------------------------------------------------------------------------

def test_sync_round_closed_form_homogeneous():
    """No jitter, homogeneous: round = compute + m*bytes/beta + alpha."""
    n, nbytes = 8, 100_000
    sc = SC.Scenario(
        name="t", topo=ring(n),
        network=SN.NetworkModel.homogeneous(alpha_s=1e-3, beta_Bps=1e7),
        compute=SCL.homogeneous(0.05))
    tr = SE.simulate_sync_rounds(sc, nbytes, num_rounds=3)
    expect = 0.05 + 2 * nbytes / 1e7 + 1e-3
    for r in tr.round_seconds:
        assert r == pytest.approx(expect, rel=1e-9)


def test_sync_bytes_accounting():
    n, m, nbytes, rounds = 8, 2, 12_345, 4
    sc = SC.get_scenario("lan-10gbe-ring", n=n)
    tr = SE.simulate_sync_rounds(sc, nbytes, rounds)
    assert tr.bytes_on_wire == n * m * nbytes * rounds
    assert tr.count(SE.TRANSFER) == n * m * rounds
    assert tr.count(SE.ROUND) == rounds


def test_sync_straggler_dominates_round():
    base = SC.Scenario("t", ring(8),
                       SN.NetworkModel.homogeneous(1e-4, 1e9),
                       SCL.homogeneous(0.05))
    slow = SC.Scenario("t", ring(8),
                       SN.NetworkModel.homogeneous(1e-4, 1e9),
                       SCL.ComputeModel(base_s=0.05, multipliers=(10.0,)))
    t_fast = SE.simulate_sync_rounds(base, 1000, 3).total_seconds
    t_slow = SE.simulate_sync_rounds(slow, 1000, 3).total_seconds
    assert t_slow > 9 * t_fast          # barrier collapses to the straggler


def test_bandwidth_starved_one_bit_beats_fp32():
    """The headline: on starved links 1-bit wall clock << fp32 wall clock."""
    sc = SC.get_scenario("bandwidth-starved", n=8)
    d = 272_474                          # ResNet20 params
    fp32 = SE.simulate_sync_rounds(sc, d * 4, 5)
    onebit = SE.simulate_sync_rounds(sc, d // 8, 5)
    assert onebit.total_seconds < 0.25 * fp32.total_seconds


def test_cumulative_seconds_monotone():
    sc = SC.get_scenario("wan-exponential", n=16)
    tr = SE.simulate_sync_rounds(sc, 50_000, 6)
    cum = tr.cumulative_seconds()
    assert len(cum) == 6
    assert all(b > a for a, b in zip(cum, cum[1:]))
    assert cum[-1] == pytest.approx(tr.total_seconds)


def test_sync_contended_bytes_accounting_unchanged():
    """Contention reprices time, never bytes: the wire ledger is identical
    between a contended scenario and the isolated formula."""
    n, m, nbytes, rounds = 8, 2, 12_345, 4
    sc = SC.get_scenario("oversubscribed-tor", n=n)
    assert sc.fabric is not None
    tr = SE.simulate_sync_rounds(sc, nbytes, rounds)
    assert tr.bytes_on_wire == n * m * nbytes * rounds
    assert tr.count(SE.TRANSFER) == n * m * rounds


def test_sync_contended_round_not_faster_than_isolated_twin():
    """oversubscribed-tor shares NIC/alpha/compute with lan-10gbe-ring;
    the shared uplinks can only add time (repro.sim.contention)."""
    for nbytes in (1_000, 100_000, 1_000_000):
        t_iso = SE.simulate_sync_rounds(
            SC.get_scenario("lan-10gbe-ring", n=8), nbytes, 3).total_seconds
        t_con = SE.simulate_sync_rounds(
            SC.get_scenario("oversubscribed-tor", n=8), nbytes,
            3).total_seconds
        assert t_con >= t_iso - 1e-12


@pytest.mark.parametrize("name", ["oversubscribed-tor", "shared-uplink-ring"])
def test_contended_seed_sensitivity(name):
    """Determinism contract extends to fabric scenarios: jitter draws key
    off the seed, identical otherwise."""
    sc = SC.get_scenario(name, n=8)
    a = SE.simulate_sync_rounds(sc, 50_000, 4)
    b = SE.simulate_sync_rounds(sc.with_seed(1), 50_000, 4)
    c = SE.simulate_sync_rounds(sc, 50_000, 4)
    assert a.fingerprint() == c.fingerprint()
    assert a.fingerprint() != b.fingerprint()


# ---------------------------------------------------------------------------
# async AD-PSGD loop: exactly-once gossip, no deadlock
# ---------------------------------------------------------------------------

def test_async_every_gossip_processed_exactly_once():
    sc = SC.get_scenario("lan-10gbe-ring", n=8)
    seen = []
    tr = SE.simulate_async_gossip(
        sc, 1000, num_updates=120,
        on_gossip=lambda i, j, idx: seen.append((idx, i, j)))
    assert tr.count(SE.GOSSIP) == 120
    assert tr.count(SE.UPDATE) == 120
    # one callback per gossip, indices dense 0..119, edges in the topology
    assert [s[0] for s in seen] == list(range(120))
    offsets = {o % 8 for o in ring(8).neighbor_offsets()}
    for _, i, j in seen:
        assert (j - i) % 8 in offsets


@pytest.mark.parametrize("n", [4, 8])
def test_async_no_deadlock_under_heavy_stragglers(n):
    """Pareto-tailed straggler 100x slower: the loop still completes and
    every worker keeps making progress (wait-free passive peers)."""
    sc = SC.Scenario(
        "heavy", ring(n),
        SN.NetworkModel.homogeneous(1e-3, 1e6),
        SCL.ComputeModel(base_s=0.01, multipliers=(100.0,),
                         tail="pareto", tail_scale=5.0, pareto_shape=1.05,
                         tail_workers=(0,)))
    tr = SE.simulate_async_gossip(sc, 5000, num_updates=150)
    assert tr.count(SE.UPDATE) == 150      # the loop completed: no deadlock
    by_worker = {e.worker for e in tr.events if e.kind == SE.UPDATE}
    assert by_worker >= set(range(1, n))   # all healthy workers progress
    # the straggler still participates passively (peers gossip with it)
    peers = {e.peer for e in tr.events if e.kind == SE.GOSSIP}
    assert 0 in peers
    assert math.isfinite(tr.total_seconds)


def test_async_staleness_tracked_and_bounded():
    sc = SC.get_scenario("straggler-longtail", n=8)
    tr = SE.simulate_async_gossip(sc, 1000, num_updates=200)
    assert len(tr.staleness) == 200
    assert tr.staleness_max >= 1            # own gossip always intervenes
    assert tr.staleness_mean >= 1.0
    # staleness counts model-version bumps, bounded by total events
    assert tr.staleness_max < 2 * 200


def test_async_bytes_credited_at_completion_only():
    """Slow links leave gossips in flight when the loop hits num_updates;
    only COMPLETED exchanges may be on the bytes ledger."""
    sc = SC.Scenario("slownet", ring(8),
                     SN.NetworkModel.homogeneous(alpha_s=1e-3, beta_Bps=1e6),
                     SCL.homogeneous(0.001))
    tr = SE.simulate_async_gossip(sc, bytes_per_exchange=5000,
                                  num_updates=100)
    assert tr.bytes_on_wire == 2 * 5000 * tr.count(SE.GOSSIP)
    # and some computes really were left in flight (the interesting case)
    assert tr.count(SE.COMPUTE) > tr.count(SE.GOSSIP)


def test_async_needs_neighbors():
    sc = SC.Scenario("solo", ring(1),
                     SN.NetworkModel.homogeneous(1e-3, 1e9),
                     SCL.homogeneous(0.01))
    with pytest.raises(ValueError):
        SE.simulate_async_gossip(sc, 100, num_updates=5)


# ---------------------------------------------------------------------------
# replay: CommEngine.pair_average edge by edge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire_bits", [("full", 8), ("moniqua", 8)])
def test_replay_adpsgd_converges_and_prices_bytes(wire_bits):
    import jax
    import jax.numpy as jnp

    from repro.comm.engine import CommEngine, make_wire, FullPrecisionWire
    from repro.core.quantizers import QuantSpec

    wire, bits = wire_bits
    codec = (FullPrecisionWire() if wire == "full"
             else make_wire(wire, QuantSpec(bits=bits)))
    eng = CommEngine(ring(8), codec, backend="jnp")
    sc = SC.get_scenario("lan-10gbe-ring", n=8, compute_s=0.01)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (8, 32)) * 0.2
    out = SE.replay_adpsgd(sc, eng, x0, lambda x, i, k: x, alpha=0.05,
                           num_updates=200, theta=2.0)
    # gradient flow on ||x||^2/2 contracts toward 0; gossip keeps consensus
    assert float(jnp.mean(jnp.abs(out["X"]))) < 0.5 * float(
        jnp.mean(jnp.abs(x0)))
    assert out["consensus_sq"] < 0.05
    tr = out["trace"]
    # each pair exchange ships one payload in each direction
    expected = 2 * codec.payload_bytes((32,))
    assert tr.bytes_on_wire == expected * tr.count(SE.GOSSIP)
    assert tr.total_seconds > 0


def test_replay_deterministic():
    import jax

    from repro.comm.engine import CommEngine, FullPrecisionWire

    eng = CommEngine(ring(8), FullPrecisionWire(), backend="jnp")
    sc = SC.get_scenario("straggler-longtail", n=8, compute_s=0.01)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    a = SE.replay_adpsgd(sc, eng, x0, lambda x, i, k: x, 0.05, 100)
    b = SE.replay_adpsgd(sc, eng, x0, lambda x, i, k: x, 0.05, 100)
    assert a["trace"].fingerprint() == b["trace"].fingerprint()
    assert a["consensus_sq"] == b["consensus_sq"]


# ---------------------------------------------------------------------------
# scenarios registry
# ---------------------------------------------------------------------------

def test_scenario_registry_roundtrip():
    assert set(SC.list_scenarios()) >= {
        "lan-10gbe-ring", "wan-exponential", "straggler-longtail",
        "bandwidth-starved"}
    for name in SC.list_scenarios():
        sc = SC.get_scenario(name, n=8)
        assert sc.topo.n == 8
        assert sc.compute.base_s > 0
    with pytest.raises(ValueError):
        SC.get_scenario("localhost")


def test_wan_exponential_long_hops_slower():
    sc = SC.get_scenario("wan-exponential", n=16)
    short = sc.network.link(0, 1, 16)
    long_ = sc.network.link(0, 4, 16)
    assert long_.beta_Bps < short.beta_Bps
    assert long_.alpha_s > short.alpha_s


def test_scenario_with_compute_override():
    sc = SC.get_scenario("lan-10gbe-ring", n=8).with_compute(0.123)
    assert sc.compute.base_s == 0.123
    assert sc.name == "lan-10gbe-ring"
