"""MoE routing invariants (GShard-style dispatch used by dbrx/grok)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models import moe as M


def _cfg(E=4, K=2, cf=2.0, g=64):
    return MoEConfig(num_experts=E, top_k=K, capacity_factor=cf, group_size=g)


def test_capacity_formula():
    assert M.capacity(256, 4, 1.25, 16) == 80
    assert M.capacity(64, 2, 2.0, 4) == 64
    assert M.capacity(1, 1, 0.1, 64) == 1          # floor at 1


def test_moe_layer_shapes_and_aux():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, 32, 64, cfg, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 32))
    y, aux = M.moe_layer(p, x, cfg, True)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0                       # load-balance aux loss


def test_moe_uniform_router_balanced_aux():
    """With near-uniform routing the aux loss approaches its minimum (1.0
    for the standard GShard fraction-product form scaled by E)."""
    cfg = _cfg(E=4, K=1, cf=4.0)
    p = M.init_moe(jax.random.PRNGKey(0), 16, 32, cfg, True, jnp.float32)
    # zero router weights -> uniform gates -> perfectly balanced
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 16))
    _, aux_uniform = M.moe_layer(p, x, cfg, True)
    # heavily skewed router: all mass on expert 0
    p_skew = dict(p)
    p_skew["router"] = p_skew["router"].at[:, 0].set(100.0)
    _, aux_skew = M.moe_layer(p_skew, x, cfg, True)
    assert float(aux_skew) > float(aux_uniform)


def test_moe_capacity_drops_tokens_gracefully():
    """capacity_factor << 1 forces drops; output stays finite and bounded."""
    cfg = _cfg(E=4, K=2, cf=0.1)
    p = M.init_moe(jax.random.PRNGKey(0), 16, 32, cfg, True, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 16))
    y, _ = M.moe_layer(p, x, cfg, True)
    assert np.isfinite(np.asarray(y)).all()
    # dropped tokens contribute ~0; overall norm smaller than full dispatch
    cfg_full = _cfg(E=4, K=2, cf=4.0)
    y_full, _ = M.moe_layer(p, x, cfg_full, True)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) + 1e-3


def test_dbrx_reduced_is_fine_grained():
    cfg = get_config("dbrx-132b")
    assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 4
    r = cfg.reduced()
    assert r.moe.num_experts <= 4
