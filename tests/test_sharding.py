"""Sharding rules and PartitionSpec resolution."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.model_factory import build_model
from repro.models.sharding import ShardingRules, dim_divides, safe_pspec
from repro.train import train_step as TS

MESH_1POD = {"data": 16, "model": 16}
MESH_2POD = {"pod": 2, "data": 16, "model": 16}


def test_worker_axes_by_mode():
    assert ShardingRules("decentralized").worker_axes == ("data",)
    assert ShardingRules("decentralized", multi_pod=True).worker_axes \
        == ("pod", "data")
    assert ShardingRules("hierarchical").worker_axes == ()
    assert ShardingRules("hierarchical", multi_pod=True).worker_axes \
        == ("pod",)


def test_safe_pspec_fallback():
    # 48 kv heads /16 ok; 8 kv heads / 16 -> replicate that dim
    assert safe_pspec((48, 128), P("model", None), MESH_1POD) \
        == P("model", None)
    assert safe_pspec((8, 128), P("model", None), MESH_1POD) == P(None, None)
    assert dim_divides(32, MESH_2POD, ("pod", "data"))
    assert not dim_divides(24, MESH_2POD, ("pod", "data"))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "dbrx-132b", "xlstm-125m",
                                  "zamba2-1.2b", "whisper-base"])
def test_params_pspecs_align_with_param_tree(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rules = ShardingRules(cfg.dist_mode)
    specs = TS.params_pspecs(model, rules, MESH_1POD, stacked=True)
    ab = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    a_leaves = jax.tree.leaves(ab)
    assert len(s_leaves) == len(a_leaves)
    for sp, leaf in zip(s_leaves, a_leaves):
        assert isinstance(sp, P)
        # stacked: rank is leaf rank + 1 (worker dim), spec never longer
        assert len(sp) <= leaf.ndim + 1


def test_n_workers_for():
    assert TS.n_workers_for(None, ShardingRules("decentralized"),
                            MESH_1POD) == 16
    assert TS.n_workers_for(None, ShardingRules("decentralized", True),
                            MESH_2POD) == 32
    assert TS.n_workers_for(None, ShardingRules("hierarchical"),
                            MESH_1POD) == 1
    assert TS.n_workers_for(None, ShardingRules("hierarchical", True),
                            MESH_2POD) == 2


def test_hierarchical_fsdp_axis():
    r = ShardingRules("hierarchical")
    assert r.fsdp_axis == "data"
    assert r.pspec("embed", "mlp") == P("data", "model")
    r2 = ShardingRules("decentralized")
    assert r2.pspec("embed", "mlp") == P(None, "model")


def test_constraint_context_noop_without_launcher():
    """constrain() is a no-op outside a launcher context (smoke tests run
    un-meshed); inside a context it resolves logical names to specs."""
    from repro.models import sharding as SH
    x = jnp.zeros((4, 8))
    assert SH.constrain(x, None, "kv_seq") is x       # no context: identity
    assert SH.mesh_axis_size("model") == 1
    with SH.constraint_context(ShardingRules("decentralized"), MESH_1POD):
        assert SH.mesh_axis_size("model") == 16
        # outside jit, with_sharding_constraint needs a mesh; just verify the
        # spec resolution path by checking divisibility fallback
        spec = SH.safe_pspec((4, 8), ShardingRules("decentralized")
                             .pspec(None, "kv_seq"), MESH_1POD)
        assert spec == P(None, None)                  # 8 % 16 -> replicate
    assert SH.mesh_axis_size("model") == 1            # context restored


def test_kv_seq_rule():
    assert ShardingRules("decentralized").pspec("kv_seq") == P("model")
