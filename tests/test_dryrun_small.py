"""Dry-run path on a small forced-host-device mesh (subprocess).

Validates the full lower+compile+roofline pipeline (deliverable e) without
needing 512 devices: 8 host devices, (4 data x 2 model) and (2 pod x 2 data
x 2 model) meshes, reduced configs.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, dataclasses
import jax
from repro.launch import dryrun
from repro.launch.mesh import make_host_mesh

results = {}

# decentralized train on (4 data x 2 model)
mesh = make_host_mesh(data=4, model=2)
r = dryrun.dryrun_one("llama3.2-3b", "train_4k", mesh=mesh,
                      override=dict(num_layers=2, d_model=256, num_heads=4,
                                    num_kv_heads=2, head_dim=64, d_ff=512,
                                    vocab_size=512, remat=False))
results["train_1pod"] = r.row()

# multi-pod (2 pod x 2 data x 2 model): the pod axis must shard
mesh_mp = make_host_mesh(data=2, model=2, pod=2)
r2 = dryrun.dryrun_one("llama3.2-3b", "train_4k", mesh=mesh_mp,
                       multi_pod=True,
                       override=dict(num_layers=2, d_model=256, num_heads=4,
                                     num_kv_heads=2, head_dim=64, d_ff=512,
                                     vocab_size=512, remat=False))
results["train_2pod"] = r2.row()

# decode path
r3 = dryrun.dryrun_one("llama3.2-3b", "decode_32k", mesh=mesh,
                       override=dict(num_layers=2, d_model=256, num_heads=4,
                                     num_kv_heads=2, head_dim=64, d_ff=512,
                                     vocab_size=512, remat=False))
results["decode_1pod"] = r3.row()

print("RESULTS_JSON=" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dryrun_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULTS_JSON=")][0]
    return json.loads(line[len("RESULTS_JSON="):])


def test_single_pod_train_compiles(dryrun_results):
    r = dryrun_results["train_1pod"]
    assert r["status"] == "ok", r["error"]
    assert r["roofline"]["flops_per_chip"] > 0
    assert r["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_multi_pod_train_compiles(dryrun_results):
    r = dryrun_results["train_2pod"]
    assert r["status"] == "ok", r["error"]


def test_quantized_collectives_present(dryrun_results):
    """The Moniqua gossip must appear as collective traffic in the HLO."""
    r = dryrun_results["train_1pod"]
    counts = r["collectives"]["counts"]
    assert sum(counts.values()) > 0
    assert "collective-permute" in counts or "all-to-all" in counts


def test_decode_compiles_and_is_lighter(dryrun_results):
    r = dryrun_results["decode_1pod"]
    assert r["status"] == "ok", r["error"]
    assert (r["roofline"]["flops_per_chip"]
            < dryrun_results["train_1pod"]["roofline"]["flops_per_chip"])
