"""Error-feedback codec family contracts (EF-QSGD, the 1-bit Adam wire).

Four contracts (docs/codecs.md):

1. **Conservation** — the post-round residual is EXACTLY ``v - decode(sent)``
   in f32, recomputable from nothing but the wire artifacts (payload +
   sideband): the WireState is fully determined by what was communicated,
   and ``decode(sent) + residual`` rebuilds the compensated value ``v`` to
   ~1 ulp of its magnitude (bitwise telescoping of the *subtraction* is the
   invariant; the rearranged sum re-rounds, hence the ulp bound).
2. **Boundedness** — 100 iterated compression rounds keep the residual at
   the EF fixpoint ``e* = q * max|x| / (1 - q)`` for the q-contractive
   qsgd lattice, and at the model scale for the 1-bit sign/cluster-mean
   compressor (the property the min/max-endpoint construction FAILS:
   its residual grows linearly — see docs/codecs.md).
3. **Switching determinism** — the onebit wire's warmup rounds are exactly
   the full-precision gossip and leave the residual untouched; the switch
   fires precisely at ``step == warmup`` and replays bit-identically.
4. **Level exactness** — onebit decode is a select, so every decoded
   element equals a shipped level bitwise, and a two-valued segment is
   lossless.

The deterministic subset always runs; the property-based variants need
``hypothesis`` (pinned in requirements-ci.txt — tests/conftest.py fails CI
loudly if it is missing, so the skip can only happen locally).
"""
import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import gossip
from repro.comm.engine import CommEngine, make_wire
from repro.core.quantizers import (QuantSpec, ef_qsgd_encode_segmented,
                                   onebit_decode_segmented,
                                   onebit_encode_segmented,
                                   qsgd_decode_segmented)
from repro.core.topology import ring
from repro.kernels import ops as kops

HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _tree(n=8, seed=0, scale=0.3):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (n, 37)) * scale,
            "b": jax.random.normal(k2, (n, 5)) * scale}


def _engine(wire, bits=4, stochastic=False, warmup=16, path="bucketed"):
    return CommEngine(ring(8),
                      make_wire(wire, QuantSpec(bits=bits,
                                                stochastic=stochastic),
                                warmup=warmup),
                      backend="jnp", path=path)


def _seeded_state(eng, X, seed=42, scale=0.1):
    st = eng.init_wire_state(X)
    r = jax.random.normal(jax.random.PRNGKey(seed),
                          st["residual"].shape) * scale
    return {"residual": r, "step": st["step"]}


# ---------------------------------------------------------------------------
# 1. conservation: residual == v - decode(wire artifacts), bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("stochastic", [False, True])
def test_ef_qsgd_residual_is_wire_determined(bits, stochastic):
    eng = _engine("ef_qsgd", bits, stochastic)
    X = _tree()
    layout = eng.layout(X)
    st = _seeded_state(eng, X)
    key = jax.random.PRNGKey(7)
    st1 = eng.mix(X, key=key, state=st).state
    # replay the wire from scratch: encode v = x + r, decode own payload
    v = layout.flatten(X).astype(jnp.float32) + st["residual"]
    spec = eng.codec.spec
    packed, scales = ef_qsgd_encode_segmented(v, spec,
                                              kops._key_to_seed(key),
                                              layout.segment_sizes)
    d = qsgd_decode_segmented(packed, scales, spec, layout.segment_sizes)
    np.testing.assert_array_equal(np.asarray(st1["residual"]),
                                  np.asarray(v - d))
    # telescoping: payload + residual rebuild v to ~1 ulp of its scale
    tol = float(jnp.max(jnp.abs(v))) * 2.0**-22
    np.testing.assert_allclose(np.asarray(d + st1["residual"]),
                               np.asarray(v), rtol=0, atol=tol)


@pytest.mark.parametrize("stochastic", [False, True])
def test_onebit_residual_is_wire_determined(stochastic):
    eng = _engine("onebit", 1, stochastic, warmup=0)
    X = _tree()
    layout = eng.layout(X)
    st = _seeded_state(eng, X)
    key = jax.random.PRNGKey(9)
    st1 = eng.mix(X, key=key, state=st).state
    v = layout.flatten(X).astype(jnp.float32) + st["residual"]
    packed, lo, hi = onebit_encode_segmented(v, kops._key_to_seed(key),
                                             layout.segment_sizes, 0,
                                             stochastic)
    d = onebit_decode_segmented(packed, lo, hi, layout.segment_sizes)
    np.testing.assert_array_equal(np.asarray(st1["residual"]),
                                  np.asarray(v - d))
    tol = float(jnp.max(jnp.abs(v))) * 2.0**-22
    np.testing.assert_allclose(np.asarray(d + st1["residual"]),
                               np.asarray(v), rtol=0, atol=tol)


def test_onebit_warm_round_is_exact_gossip_and_keeps_residual():
    """Warmup rounds ARE the full-precision round: output == gossip.mix
    bitwise, residual untouched bitwise, only the counter advances."""
    eng = _engine("onebit", warmup=16)
    X = _tree()
    st = _seeded_state(eng, X)
    res = eng.mix(X, key=jax.random.PRNGKey(0), state=st)
    out, st1 = res.x, res.state
    ref = gossip.mix(X, ring(8))
    for k in X:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[k]))
    np.testing.assert_array_equal(np.asarray(st1["residual"]),
                                  np.asarray(st["residual"]))
    assert int(st1["step"]) == 1


# ---------------------------------------------------------------------------
# 2. boundedness: 100 iterated rounds at 1/2/4/8 bits
# ---------------------------------------------------------------------------

def _iterate_residual(eng, X, rounds=100):
    st = eng.init_wire_state(X)
    step = jax.jit(lambda s, k: eng.mix(X, key=k, state=s).state)
    sups = []
    for k in range(rounds):
        st = step(st, jax.random.PRNGKey(1000 + k))
        sups.append(float(jnp.max(jnp.abs(st["residual"]))))
    return sups


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("stochastic", [False, True])
def test_ef_qsgd_residual_bounded_100_rounds(bits, stochastic):
    """Iterated compression of a fixed model sits under the EF fixpoint:
    the per-segment max-norm scale bounds one round's quantization error
    by q * max|v| with q = 1/(levels-1) (nearest) or 2/(levels-1)
    (stochastic), and v = x + r gives e* = q * max|x| / (1 - q)."""
    eng = _engine("ef_qsgd", bits, stochastic)
    X = _tree()
    sups = _iterate_residual(eng, X)
    xmax = max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(X))
    q = (2.0 if stochastic else 1.0) / (QuantSpec(bits=bits).levels - 1)
    assert max(sups) <= 1.5 * q * xmax / (1.0 - q)


@pytest.mark.parametrize("stochastic", [False, True])
def test_onebit_residual_bounded_100_rounds(stochastic):
    """The sign/cluster-mean compressor is contractive (reconstruction at
    the cluster means makes the error the within-cluster variance), so the
    1-bit residual plateaus (by ~round 50, at a disagreement-dependent
    multiple of the model scale — fixed X keeps workers permanently apart,
    so the fixpoint constant is larger than the lattice wires') instead of
    growing without bound.  A linearly-growing residual — what the
    min/max-endpoint construction produces — fails the late/early ratio
    check at ~2x regardless of its growth rate, and the absolute bound as
    well within a few hundred rounds."""
    eng = _engine("onebit", 1, stochastic, warmup=0)
    X = _tree()
    sups = _iterate_residual(eng, X)
    xmax = max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(X))
    assert max(sups) <= 16.0 * xmax
    assert max(sups[80:]) <= 1.2 * max(sups[:50])


# ---------------------------------------------------------------------------
# 3. warmup -> quantized switching determinism (the need_reset-style hook)
# ---------------------------------------------------------------------------

def test_onebit_warmup_switch_fires_at_warmup_and_replays_bitwise():
    W = 3
    eng1, eng2 = _engine("onebit", warmup=W), _engine("onebit", warmup=W)
    X1 = X2 = _tree(seed=5)
    st1, st2 = eng1.init_wire_state(X1), eng2.init_wire_state(X2)
    for k in range(2 * W):
        key = jax.random.PRNGKey(500 + k)
        ref = gossip.mix(X1, ring(8))
        r1 = eng1.mix(X1, key=key, state=st1)
        r2 = eng2.mix(X2, key=key, state=st2)
        X1, st1 = r1.x, r1.state
        X2, st2 = r2.x, r2.state
        # two independent engines replay the schedule bit-identically
        for lk in X1:
            np.testing.assert_array_equal(np.asarray(X1[lk]),
                                          np.asarray(X2[lk]))
        np.testing.assert_array_equal(np.asarray(st1["residual"]),
                                      np.asarray(st2["residual"]))
        assert int(st1["step"]) == k + 1
        if k < W:   # warm round: exactly the full-precision gossip
            for lk in X1:
                np.testing.assert_array_equal(np.asarray(X1[lk]),
                                              np.asarray(ref[lk]))
            assert float(jnp.max(jnp.abs(st1["residual"]))) == 0.0
        else:       # quantized round: visibly not the f32 round
            assert any(not np.array_equal(np.asarray(X1[lk]),
                                          np.asarray(ref[lk])) for lk in X1)
            assert float(jnp.max(jnp.abs(st1["residual"]))) > 0.0


# ---------------------------------------------------------------------------
# 4. onebit level exactness
# ---------------------------------------------------------------------------

def test_onebit_two_valued_segment_is_lossless():
    """A segment holding one negative and one non-negative value has those
    values as its cluster means — encode/decode round-trips bitwise
    (powers of two and power-of-two cluster counts keep the means exact)."""
    v = jnp.array([[-0.5] * 4 + [0.25] * 4], jnp.float32)
    packed, lo, hi = onebit_encode_segmented(v, None, (8,))
    assert float(lo[0, 0]) == -0.5 and float(hi[0, 0]) == 0.25
    d = onebit_decode_segmented(packed, lo, hi, (8,))
    np.testing.assert_array_equal(np.asarray(d), np.asarray(v))


def test_onebit_decoded_values_are_shipped_levels():
    v = jax.random.normal(jax.random.PRNGKey(3), (2, 24), jnp.float32)
    seg = (16, 8)
    packed, lo, hi = onebit_encode_segmented(v, None, seg)
    d = np.asarray(onebit_decode_segmented(packed, lo, hi, seg))
    off = 0
    for si, size in enumerate(seg):
        block = d[:, off:off + size]
        levels = np.stack([np.asarray(lo)[:, si], np.asarray(hi)[:, si]], 1)
        for row in range(v.shape[0]):
            assert set(block[row].tolist()) <= set(levels[row].tolist())
        off += size


def test_stochastic_modes_require_seed():
    v = jnp.ones((1, 8), jnp.float32)
    with pytest.raises(ValueError, match="seed"):
        onebit_encode_segmented(v, None, (8,), stochastic=True)
    with pytest.raises(ValueError, match="seed"):
        ef_qsgd_encode_segmented(v, QuantSpec(bits=4, stochastic=True),
                                 None, (8,))


# ---------------------------------------------------------------------------
# property-based variants (hypothesis; see module docstring for the gate)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    def _v_from(seed, n, d8):
        return jax.random.normal(jax.random.PRNGKey(seed), (n, 8 * d8),
                                 jnp.float32) * 0.5

    def _segments(d8, split8):
        d = 8 * d8
        cut = 8 * min(split8, d8)
        return (cut, d - cut) if 0 < cut < d else (d,)

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4, 8]),
           stochastic=st.booleans(), n=st.integers(2, 6),
           d8=st.integers(1, 5), split8=st.integers(0, 5),
           hash_seed=st.integers(0, 2**32 - 1))
    def test_ef_qsgd_error_bounded_by_lattice_pitch(seed, bits, stochastic,
                                                    n, d8, split8,
                                                    hash_seed):
        """Eq.-2 analog for the segmented EF wire: per segment, the
        compression error is under one lattice step of ITS max-norm scale
        (half a step for nearest rounding)."""
        spec = QuantSpec(bits=bits, stochastic=stochastic)
        v = _v_from(seed, n, d8)
        seg = _segments(d8, split8)
        packed, scales = ef_qsgd_encode_segmented(
            v, spec, jnp.uint32(hash_seed), seg)
        err = np.abs(np.asarray(
            v - qsgd_decode_segmented(packed, scales, spec, seg)))
        q = (2.0 if stochastic else 1.0) / (spec.levels - 1)
        off = 0
        for si, size in enumerate(seg):
            smax = np.max(np.abs(np.asarray(v)[:, off:off + size]),
                          axis=1) + 1e-12
            assert np.all(np.max(err[:, off:off + size], axis=1)
                          <= q * smax * (1 + 1e-6))
            off += size

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 6),
           d8=st.integers(1, 5), split8=st.integers(0, 5))
    def test_onebit_nearest_is_contractive(seed, n, d8, split8):
        """||v - decode(encode(v))||^2 <= ||v||^2 per segment row: the
        compression error is the within-cluster variance of the sign
        partition — the delta-contraction the EF loop's stability needs."""
        v = _v_from(seed, n, d8)
        seg = _segments(d8, split8)
        packed, lo, hi = onebit_encode_segmented(v, None, seg)
        err = np.asarray(v - onebit_decode_segmented(packed, lo, hi, seg))
        va = np.asarray(v)
        off = 0
        for size in seg:
            e2 = np.sum(err[:, off:off + size] ** 2, axis=1)
            v2 = np.sum(va[:, off:off + size] ** 2, axis=1)
            assert np.all(e2 <= v2 * (1 + 1e-5) + 1e-12)
            off += size

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 6),
           d8=st.integers(1, 5), stochastic=st.booleans(),
           hash_seed=st.integers(0, 2**32 - 1))
    def test_onebit_error_under_segment_spread(seed, n, d8, stochastic,
                                               hash_seed):
        """Both rounding modes decode to a shipped level, so the pointwise
        error never exceeds the segment's spread ``max(span, hi - lo)``
        (the ``hi - lo`` term covers one-sided segments, where the empty
        cluster's level is 0 and can sit outside the value range)."""
        v = _v_from(seed, n, d8)
        seg = (8 * d8,)
        packed, lo, hi = onebit_encode_segmented(
            v, jnp.uint32(hash_seed), seg, 0, stochastic)
        err = np.abs(np.asarray(
            v - onebit_decode_segmented(packed, lo, hi, seg)))
        span = (np.max(np.asarray(v), axis=1)
                - np.min(np.asarray(v), axis=1))
        spread = np.maximum(span, np.asarray(hi)[:, 0] - np.asarray(lo)[:, 0])
        assert np.all(np.max(err, axis=1) <= spread * (1 + 1e-6) + 1e-12)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 6),
           d8=st.integers(1, 4), bits=st.sampled_from([2, 4, 8]),
           hash_seed=st.integers(0, 2**32 - 1))
    def test_identical_rows_emit_identical_payloads(seed, n, d8, bits,
                                                    hash_seed):
        """Shared randomness (Supp. C analog): the row-position uniform
        hash is worker-free, so workers holding the same model broadcast
        the same bytes — for both EF wires, in stochastic mode."""
        row = jax.random.normal(jax.random.PRNGKey(seed), (1, 8 * d8),
                                jnp.float32)
        v = jnp.broadcast_to(row, (n, 8 * d8))
        seg = (8 * d8,)
        spec = QuantSpec(bits=bits, stochastic=True)
        packed, scales = ef_qsgd_encode_segmented(
            v, spec, jnp.uint32(hash_seed), seg)
        pb, lo, hi = onebit_encode_segmented(v, jnp.uint32(hash_seed), seg,
                                             0, True)
        for arr in (packed, scales, pb, lo, hi):
            a = np.asarray(arr)
            for i in range(1, n):
                np.testing.assert_array_equal(a[i], a[0])
