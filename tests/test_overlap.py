"""Staged RoundPlan pipeline: chunked gossip == barrier round, bitwise.

The tentpole contract of the chunk-pipelined round (comm/engine.py
``RoundPlan``): splitting the flat bucket into K slot-aligned chunks and
running encode(t) / permute(t-1) / decode-reduce(t-2) in the skewed
software pipeline changes NOTHING observable —

1. mixed outputs are bit-exact vs the barrier round (``chunks=1``) for
   every wire, on both backend names;
2. the concatenated per-chunk payload bytes ARE the whole-round payload
   (global hash indices + segment-aligned chunk boundaries);
3. the post-round ``WireState`` of the EF wires carries identically;
4. the round-health telemetry is chunk-count invariant;
5. ``BucketLayout.chunks(K)`` partitions are contiguous, slot-aligned,
   and cover the padded buffer exactly;
6. the one-round-stale trainer (``overlap="stale"``) is deterministic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import bucket, gossip
from repro.comm.engine import CommEngine, make_wire
from repro.core.quantizers import QuantSpec
from repro.core.topology import exponential, ring

# (wire, bits): the full codec matrix the pipeline must preserve
WIRES = [("full", 32), ("moniqua", 8), ("moniqua", 1), ("qsgd", 8),
         ("ef_qsgd", 4), ("onebit", 1)]
KS = [2, 5]


def _stacked(scale=0.3, n=8, d=300, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * scale


def _tree():
    """Several leaves with unaligned last dims so K=5 splits mid-tree."""
    return {
        "w": _stacked(),                                   # (8, 300)
        "b": _stacked(d=17, seed=7),                       # (8, 17)
        "c": _stacked(d=21, seed=5).reshape(8, 3, 7),      # (8, 3, 7)
        "d": _stacked(d=65, seed=9),                       # (8, 65)
        "e": _stacked(d=129, seed=11),                     # (8, 129)
    }


def _engine(wire, bits, backend="jnp", chunks=1, telemetry=False,
            topo=None):
    spec = QuantSpec(bits=min(bits, 8), stochastic=1 < bits <= 8)
    codec = (make_wire(wire, spec, warmup=2)
             if wire in ("ef_qsgd", "onebit") else make_wire(wire, spec))
    return CommEngine(topo or ring(8), codec, backend=backend,
                      path="bucketed", chunks=chunks, telemetry=telemetry)


def _mix_kw(wire, key):
    if wire == "full":
        return {}
    if wire == "moniqua":
        return dict(theta=2.0, key=key)
    return dict(key=key)


# ---------------------------------------------------------------------------
# 1+3. pipelined mixed outputs and WireState == barrier, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("wire,bits", WIRES,
                         ids=[f"{w}{b}" for w, b in WIRES])
def test_pipelined_matches_barrier_bit_exact(wire, bits, backend, K):
    """3 iterated rounds (crossing the onebit warmup switch): outputs and
    the carried WireState are bitwise identical whether the round runs as
    one barrier chunk or K pipelined chunks."""
    Xa = Xb = _tree()
    a = _engine(wire, bits, backend, chunks=1)
    b = _engine(wire, bits, backend, chunks=K)
    sa = a.init_wire_state(Xa) if a.stateful else None
    sb = sa
    for k in range(3):
        key = jax.random.PRNGKey(70 + k)
        ra = a.mix(Xa, state=sa, **_mix_kw(wire, key))
        rb = b.mix(Xb, state=sb, **_mix_kw(wire, key))
        Xa, Xb = ra.x, rb.x
        for lk in Xa:
            np.testing.assert_array_equal(
                np.asarray(Xa[lk], np.float32),
                np.asarray(Xb[lk], np.float32),
                err_msg=f"round {k} leaf {lk} K={K}")
        if a.stateful:
            sa, sb = ra.state, rb.state
            np.testing.assert_array_equal(
                np.asarray(sa["residual"]), np.asarray(sb["residual"]),
                err_msg=f"round {k} residual K={K}")
            assert int(sa["step"]) == int(sb["step"]) == k + 1


@pytest.mark.parametrize("K", KS)
def test_pipelined_matches_barrier_on_exponential_topology(K):
    """Multi-offset reduction order survives chunking (4 neighbors)."""
    X = _tree()
    key = jax.random.PRNGKey(3)
    topo = exponential(8)
    a = _engine("moniqua", 4, topo=topo, chunks=1).mix(X, theta=2.0,
                                                       key=key).x
    b = _engine("moniqua", 4, topo=topo, chunks=K).mix(X, theta=2.0,
                                                       key=key).x
    for lk in X:
        np.testing.assert_array_equal(np.asarray(a[lk]), np.asarray(b[lk]))


@pytest.mark.parametrize("K", KS)
def test_pipelined_under_jit_close(K):
    """Re-jitting may legally FMA-contract: the documented ~1-ulp bound."""
    eng = _engine("moniqua", 8, chunks=K)
    ref = _engine("moniqua", 8, chunks=1)
    X = _tree()
    key = jax.random.PRNGKey(1)
    jo = jax.jit(lambda x, k: eng.mix(x, theta=2.0, key=k).x)(X, key)
    ro = jax.jit(lambda x, k: ref.mix(x, theta=2.0, key=k).x)(X, key)
    for lk in X:
        np.testing.assert_allclose(np.asarray(jo[lk]), np.asarray(ro[lk]),
                                   rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# 2. payload bits: concatenated chunk payloads == the whole-round payload
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", KS)
@pytest.mark.parametrize("wire,bits", WIRES,
                         ids=[f"{w}{b}" for w, b in WIRES])
def test_chunk_payload_bits_match_whole_round(wire, bits, K):
    """What rides the wire is identical: chunk c's payload is the
    [offset, offset+size) window of the barrier payload, for every array
    in the payload tuple (codes AND sideband scales/levels)."""
    X = _tree()
    eng = _engine(wire, bits)
    key = jax.random.PRNGKey(13)
    st = eng.init_wire_state(X) if eng.stateful else None
    kw = _mix_kw(wire, key)
    whole = eng.round_plan(X, state=st, chunks=1, **kw).encode_chunk(0)
    plan = eng.round_plan(X, state=st, chunks=K, **kw)
    assert plan.num_chunks == K
    # EF wires append the local compensated value v — not a wire payload
    n_payload = {"full": 1, "moniqua": 1, "qsgd": 2, "ef_qsgd": 2,
                 "onebit": 3}[wire]
    parts = [plan.encode_chunk(i) for i in range(K)]
    for j in range(n_payload):
        cat = jnp.concatenate([p[j].reshape(8, -1) for p in parts], axis=1)
        np.testing.assert_array_equal(
            np.asarray(whole[j].reshape(8, -1)), np.asarray(cat),
            err_msg=f"payload array {j}")


# ---------------------------------------------------------------------------
# 4. telemetry is chunk-count invariant (canonical flat-buffer health)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", KS)
def test_health_invariant_across_chunk_counts(K):
    from repro.obs import metrics as M
    X = _tree()
    key = jax.random.PRNGKey(17)
    h1 = _engine("moniqua", 4, chunks=1, telemetry=True).mix(
        X, theta=2.0, key=key).health
    hk = _engine("moniqua", 4, chunks=K, telemetry=True).mix(
        X, theta=2.0, key=key).health
    for k in M.HEALTH_ROUND_KEYS:
        np.testing.assert_array_equal(np.asarray(h1[k]), np.asarray(hk[k]),
                                      err_msg=f"{k} @ K={K}")


# ---------------------------------------------------------------------------
# 5. BucketLayout.chunks(K): the alignment contracts
# ---------------------------------------------------------------------------

def _layout(vpb=2):
    return bucket.layout_of(_tree(), vpb)


@pytest.mark.parametrize("K", [1, 2, 3, 5, 100])
def test_chunks_cover_contiguously_and_slot_aligned(K):
    layout = _layout()
    chunks = layout.chunks(K)
    assert 1 <= len(chunks) <= min(max(K, 1), len(layout.slots))
    # contiguous exact cover of the padded buffer
    pos = 0
    for i, c in enumerate(chunks):
        assert c.index == i
        assert c.offset == pos
        assert c.size > 0
        pos += c.size
    assert pos == layout.padded_elems
    # every chunk holds whole slots, in order — per-tensor scales and the
    # vpb byte alignment can never straddle a chunk boundary
    all_slots = [s for c in chunks for s in c.slots]
    assert all_slots == list(layout.slots)
    for c in chunks:
        assert c.size == sum(s.padded_size for s in c.slots)
        assert c.segment_sizes == tuple(s.padded_size for s in c.slots)
        assert c.offset == c.slots[0].offset


def test_chunks_clamp_to_slot_count():
    layout = _layout()
    n_slots = len(layout.slots)
    assert len(layout.chunks(n_slots + 50)) == n_slots
    assert len(layout.chunks(0)) == 1
    assert len(layout.chunks(-3)) == 1


def test_chunks_memoized():
    layout = _layout()
    assert layout.chunks(3) is layout.chunks(3)


def test_chunk_offsets_stay_on_vpb_boundaries():
    """Payload-byte windows: every chunk offset divides values-per-byte
    for every packing width (slots are vpb-row aligned by construction)."""
    for vpb in (2, 4, 8):
        layout = bucket.layout_of(_tree(), vpb)
        for c in layout.chunks(5):
            assert c.offset % vpb == 0
            assert c.size % vpb == 0


# ---------------------------------------------------------------------------
# 6. one-round-stale overlap: identity first round, deterministic trainer
# ---------------------------------------------------------------------------

def test_mix_stale_first_round_is_identity_then_moves():
    eng = _engine("moniqua", 8)
    X = _tree()
    carry = eng.init_gossip_carry(X)
    assert not bool(carry["valid"])
    r1 = eng.mix_stale(X, carry, theta=2.0, key=jax.random.PRNGKey(0))
    for lk in X:   # nothing to decode yet: the model is untouched
        np.testing.assert_array_equal(np.asarray(r1.x[lk]),
                                      np.asarray(X[lk]))
    assert bool(r1.state["valid"])
    r2 = eng.mix_stale(r1.x, r1.state, theta=2.0,
                       key=jax.random.PRNGKey(1))
    moved = max(float(jnp.max(jnp.abs(r2.x[lk] - r1.x[lk]))) for lk in X)
    assert moved > 0.0


def test_mix_stale_deterministic_replay():
    eng = _engine("moniqua", 8)

    def run():
        X = _tree()
        carry = eng.init_gossip_carry(X)
        for k in range(4):
            r = eng.mix_stale(X, carry, theta=2.0,
                              key=jax.random.PRNGKey(200 + k))
            X, carry = r.x, r.state
        return X

    Xa, Xb = run(), run()
    for lk in Xa:
        np.testing.assert_array_equal(np.asarray(Xa[lk]),
                                      np.asarray(Xb[lk]))


def test_stale_trainer_step_deterministic():
    """TrainerConfig(overlap='stale', chunks=2) end-to-end: the gossip
    carry rides extra['gossip'], training stays finite, and two identical
    runs replay bit-identically."""
    import dataclasses as dc

    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.models.model_factory import build_model
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = dc.replace(get_config("llama3.2-3b").reduced(), num_layers=1,
                     d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
                     d_ff=128, vocab_size=64)
    model = build_model(cfg)
    shape = InputShape("tiny", seq_len=16, global_batch=8, kind="train")
    tc = TrainerConfig(algo="moniqua", n_workers=4, bits=8, theta=2.0,
                       lr=0.3, steps=6, log_every=2, momentum=0.0,
                       weight_decay=0.0, overlap="stale", chunks=2)

    def run():
        out = Trainer(model, shape, tc).run()
        assert "gossip" in out["state"]["extra"]
        assert np.isfinite(out["history"][-1]["loss"])
        return out

    a, b = run(), run()
    assert [h["loss"] for h in a["history"]] == \
        [h["loss"] for h in b["history"]]
    for la, lb in zip(jax.tree.leaves(a["state"]["params"]),
                      jax.tree.leaves(b["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(la, np.float32),
                                      np.asarray(lb, np.float32))
