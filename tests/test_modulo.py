"""Property tests for the modulo arithmetic (paper Lemma 1 & 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in the baked image
from hypothesis import given, settings, strategies as st

from repro.core import modulo
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec

F = st.floats(min_value=-100.0, max_value=100.0,
              allow_nan=False, allow_infinity=False)


@settings(max_examples=200, deadline=None)
@given(z=F, a=st.floats(min_value=0.01, max_value=50.0))
def test_cmod_range(z, a):
    out = float(modulo.cmod(jnp.float32(z), a))
    assert -a / 2 - 1e-4 * a <= out < a / 2 + 1e-4 * a


def test_cmod_half_open_convention():
    # cmod(a/2) == -a/2 (half-open interval [-a/2, a/2))
    assert float(modulo.cmod(jnp.float32(1.0), 2.0)) == -1.0
    assert float(modulo.cmod(jnp.float32(-1.0), 2.0)) == -1.0
    assert float(modulo.cmod(jnp.float32(0.999), 2.0)) == pytest.approx(0.999)


@settings(max_examples=200, deadline=None)
@given(y=F, d=st.floats(min_value=-0.999, max_value=0.999),
       theta=st.floats(min_value=0.05, max_value=10.0))
def test_lemma1_recovery_identity(y, d, theta):
    """Lemma 1: |x-y| < theta => x == cmod(cmod(x,2θ)-cmod(y,2θ), 2θ) + y."""
    x = y + d * theta            # guarantees |x - y| < theta
    a = 2.0 * theta
    lhs = float(modulo.cmod(
        modulo.cmod(jnp.float32(x), a) - modulo.cmod(jnp.float32(y), a), a)
        + jnp.float32(y))
    assert lhs == pytest.approx(x, abs=1e-3 * max(1.0, abs(x), a))


@settings(max_examples=150, deadline=None)
@given(y=F, d=st.floats(min_value=-0.98, max_value=0.98),
       theta=st.floats(min_value=0.1, max_value=8.0),
       bits=st.sampled_from([1, 2, 4, 8]),
       stochastic=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_lemma2_error_bound(y, d, theta, bits, stochastic, seed):
    """Lemma 2: |x_hat - x| <= delta * B given |x - y| < theta, delta < 1/2."""
    spec = QuantSpec(bits=bits, stochastic=stochastic)
    if spec.delta >= 0.5:        # stochastic 1-bit: rejected by the theory
        return
    codec = MoniquaCodec(spec)
    x = jnp.full((8,), y + d * theta, jnp.float32)
    yv = jnp.full((8,), y, jnp.float32)
    key = jax.random.PRNGKey(seed) if stochastic else None
    packed = codec.encode(x, theta, key)
    x_hat = codec.decode(packed, yv, theta)
    bound = codec.max_error(theta)
    err = float(jnp.max(jnp.abs(x_hat - x)))
    # f32 wrap arithmetic: allow a few ulp of slack relative to B
    B = float(codec.b_theta(theta))
    assert err <= bound + 1e-4 * B


def test_b_theta_rejects_half():
    with pytest.raises(ValueError):
        modulo.b_theta(1.0, 0.5)


def test_error_bound_formula():
    # theta * 2 delta / (1 - 2 delta)
    assert modulo.error_bound(2.0, 0.25) == pytest.approx(2.0)
    assert modulo.error_bound(1.0, 1.0 / 512.0) == pytest.approx(
        (2.0 / 512.0) / (1.0 - 2.0 / 512.0))


def test_local_bias_cancellation():
    """Line 4/5 structure: for the sender, decode_self - x == q*B - (x mod B).

    Averaging subtracts x_hat_self so the *difference* of reconstructions is
    what enters the update — verify decode(self payload against own model)
    equals decode_self exactly when x is within the principal window.
    """
    codec = MoniquaCodec(QuantSpec(bits=8, stochastic=False))
    theta = 2.0
    x = jnp.linspace(-0.9, 0.9, 16, dtype=jnp.float32)
    p = codec.encode(x, theta, None)
    self_rec = codec.decode_self(p, x, theta)
    remote_rec = codec.decode(p, x, theta)   # y == x (zero distance)
    np.testing.assert_allclose(np.asarray(self_rec), np.asarray(remote_rec),
                               atol=1e-5)
