"""Attention layer equivalences: banded == full masked, GQA, decode cache."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _qkv(key, S, H=2, D=16, B=2):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("S,window,chunk", [(512, 128, 128), (512, 96, 128),
                                            (1024, 256, 128), (384, 64, 192)])
def test_banded_equals_full_windowed(S, window, chunk):
    q, k, v = _qkv(jax.random.PRNGKey(0), S)
    scale = 1.0 / math.sqrt(q.shape[-1])
    full = L._sdpa(q, k, v, L.causal_mask(S, S, window), scale)
    banded = L._banded_sdpa(q, k, v, window, scale, q_chunk=chunk)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_banded_fallback_small_seq():
    # S <= window + chunk: must fall back to the full path, same result
    q, k, v = _qkv(jax.random.PRNGKey(1), 128)
    scale = 0.125
    full = L._sdpa(q, k, v, L.causal_mask(128, 128, 64), scale)
    banded = L._banded_sdpa(q, k, v, 64, scale, q_chunk=512)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=1e-6, atol=1e-6)


def test_causal_mask_window_semantics():
    m = np.asarray(L.causal_mask(6, 6, 3))
    for i in range(6):
        for j in range(6):
            assert m[i, j] == (j <= i and j > i - 3)


def test_decode_matches_prefill_last_token():
    """Cached decode of token t must equal the full forward's position t."""
    import dataclasses
    from repro.configs import get_config
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              num_layers=1, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=64, dtype="float32")
    from repro.models.model_factory import build_model
    from repro.configs.base import InputShape
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 64)
    from repro.models import transformer as T
    full_logits, _ = T.lm_logits(params, cfg, toks)
    shape = InputShape("x", seq_len=8, global_batch=1, kind="decode")
    cache = model.init_cache(1, shape)
    for t in range(8):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(full_logits[0, -1]),
                               rtol=2e-4, atol=2e-4)
