"""BucketLayout: the flat-buffer contract behind bucketed gossip.

Covers the static layout invariants (offsets, vpb row alignment, staging
dtype), the flatten/unflatten round trip, and the memoization that lets a
trainer warm the cache from abstract shapes before jit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import bucket


def _tree(n=8):
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 4)
    return {
        "w": jax.random.normal(ks[0], (n, 300)),
        "b": jax.random.normal(ks[1], (n, 17)),
        "c": jax.random.normal(ks[2], (n, 3, 7)).astype(jnp.bfloat16),
        "s": jax.random.normal(ks[3], (n,)),
    }


@pytest.mark.parametrize("align", [1, 2, 4, 8])
def test_flatten_unflatten_round_trip(align):
    X = _tree()
    layout = bucket.layout_of(X, align)
    flat = layout.flatten(X)
    assert flat.shape == (8, layout.padded_elems)
    out = layout.unflatten(flat)
    for k in X:
        assert out[k].dtype == X[k].dtype
        np.testing.assert_array_equal(
            np.asarray(out[k], np.float32), np.asarray(X[k], np.float32))


def test_offsets_are_row_padded_and_aligned():
    X = _tree()
    layout = bucket.layout_of(X, 4)
    off = 0
    for s in layout.slots:
        assert s.offset == off
        assert s.last_padded % 4 == 0
        assert s.padded_size == s.rows * s.last_padded
        off += s.padded_size
    assert layout.padded_elems == off
    assert layout.total_elems == sum(s.size for s in layout.slots)
    # unaligned last dims pick up row padding, aligned ones don't
    by_shape = {s.shape: s for s in layout.slots}
    assert by_shape[(300,)].last_padded == 300
    assert by_shape[(17,)].last_padded == 20
    assert by_shape[(3, 7)].last_padded == 8
    assert by_shape[()].last_padded == 4     # scalar-per-worker leaf


def test_padding_is_zero_and_segments_match_leaves():
    X = _tree()
    layout = bucket.layout_of(X, 8)
    flat = np.asarray(layout.flatten(X))
    for leaf, s in zip(jax.tree.leaves(X), layout.slots):
        seg = flat[:, s.offset:s.offset + s.padded_size]
        seg = seg.reshape(8, s.rows, s.last_padded)
        np.testing.assert_array_equal(
            seg[..., :s.last],
            np.asarray(leaf, np.float32).reshape(8, s.rows, s.last))
        np.testing.assert_array_equal(seg[..., s.last:], 0.0)


def test_stage_dtype_rules():
    n = 4
    uniform = {"a": jnp.zeros((n, 8), jnp.bfloat16),
               "b": jnp.zeros((n, 3), jnp.bfloat16)}
    assert bucket.layout_of(uniform, 1).stage_dtype == jnp.bfloat16
    mixed = {"a": jnp.zeros((n, 8), jnp.bfloat16),
             "b": jnp.zeros((n, 3), jnp.float32)}
    assert bucket.layout_of(mixed, 1).stage_dtype == jnp.float32


def test_layout_memoized_and_abstract_safe():
    X = _tree()
    l1 = bucket.layout_of(X, 8)
    l2 = bucket.layout_of(jax.eval_shape(lambda: X), 8)
    assert l1 is l2
    assert bucket.layout_of(X, 4) is not l1      # alignment is part of key


def test_flatten_inside_jit():
    X = _tree()
    layout = bucket.layout_of(X, 8)
    eager = layout.flatten(X)
    jitted = jax.jit(layout.flatten)(X)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))
    back = jax.jit(layout.unflatten)(jitted)
    for k in X:
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(X[k], np.float32))


def test_rejects_mismatched_worker_axes_and_empty_trees():
    with pytest.raises(ValueError):
        bucket.layout_of({"a": jnp.zeros((8, 3)), "b": jnp.zeros((4, 3))}, 1)
    with pytest.raises(ValueError):
        bucket.layout_of({}, 1)


# -- shard windows (two-tier owned shards) ----------------------------------

def test_shards_partition_buffer_in_order_and_slot_aligned():
    X = _tree()
    layout = bucket.layout_of(X, 4)
    whole = layout.shard(1, 0)
    assert (whole.offset, whole.size) == (0, layout.padded_elems)
    assert whole.slots == layout.slots
    for k in (2, 3, 4):
        shards = [layout.shard(k, i) for i in range(k)]
        off = 0
        for s in shards:
            assert s.offset == off
            assert s.size == sum(sl.padded_size for sl in s.slots)
            off += s.size
        assert off == layout.padded_elems
        # slot-aligned: shard slots concatenate back to the layout's
        assert tuple(sl for s in shards for sl in s.slots) == layout.slots


def test_shards_pad_with_empty_windows_beyond_leaf_count():
    X = _tree()   # 4 leaves
    layout = bucket.layout_of(X, 1)
    shards = [layout.shard(6, i) for i in range(6)]
    assert sum(s.size for s in shards) == layout.padded_elems
    for s in shards[4:]:
        assert (s.size, s.slots) == (0, ())
        assert s.offset == layout.padded_elems


def test_shard_memoized_and_validated():
    X = _tree()
    layout = bucket.layout_of(X, 1)
    assert layout.shard(2, 1) is layout.shard(2, 1)
    with pytest.raises(ValueError):
        layout.shard(0, 0)
    with pytest.raises(ValueError):
        layout.shard(2, 2)
    with pytest.raises(ValueError):
        layout.shard(2, -1)
