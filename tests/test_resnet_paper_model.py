"""The paper's own experimental model (Sec. 6): CIFAR ResNet under Moniqua."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import AlgoHyper, get_algorithm
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.data.synthetic import cifar_like
from repro.models import resnet as R


def test_resnet20_shapes_and_param_count():
    p = R.init_resnet(jax.random.PRNGKey(0), depth=20)
    n = sum(int(l.size) for l in jax.tree.leaves(p))
    assert 0.2e6 < n < 0.4e6          # ~0.27M published
    x = jnp.zeros((4, 32, 32, 3))
    logits = R.resnet_logits(p, x)
    assert logits.shape == (4, 10)


def test_resnet_moniqua_training_step_decreases_loss():
    """Paper Sec. 6 setup in miniature: 4 workers, ring, 8-bit Moniqua."""
    n = 4
    algo = get_algorithm("moniqua")
    hp = AlgoHyper(topo=ring(n), codec=MoniquaCodec(QuantSpec(bits=8)),
                   theta=2.0)
    p0 = R.init_resnet(jax.random.PRNGKey(0), depth=20, width=8)
    X = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), p0)
    extra = algo.init(X, hp)

    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[cifar_like(0, 16, worker=w, seed=1) for w in range(n)])

    @jax.jit
    def step(X, extra, k, key):
        losses, grads = jax.vmap(jax.value_and_grad(R.resnet_loss))(X, batches)
        Xn, en = algo.step(X, extra, grads, 0.1, k, key, hp)
        return Xn, en, jnp.mean(losses)

    key = jax.random.PRNGKey(2)
    losses = []
    for k in range(6):
        key, kk = jax.random.split(key)
        X, extra, l = step(X, extra, jnp.asarray(k), kk)
        losses.append(float(l))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_resnet_accuracy_learnable_signal():
    p = R.init_resnet(jax.random.PRNGKey(0), depth=20, width=8)
    batch = cifar_like(0, 64, seed=0)
    acc = float(R.resnet_accuracy(p, batch))
    assert 0.0 <= acc <= 1.0
