"""Docs stay truthful: every repo path/symbol referenced in README.md and
docs/*.md exists (tools/check_docs.py), and the README quickstart's imports
resolve."""
import importlib.util
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(REPO, "tools", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_no_dangling_doc_references():
    checker = _load_checker()
    errors = []
    for md in checker.DOC_FILES:
        if os.path.exists(os.path.join(REPO, md)):
            errors.extend(checker.check_file(md))
    assert not errors, "\n".join(errors)


def test_readme_quickstart_runs():
    """Execute the README's first python code block verbatim."""
    import re
    text = open(os.path.join(REPO, "README.md")).read()
    blocks = re.findall(r"```python\n(.*?)```", text, flags=re.S)
    assert blocks, "README.md lost its python quickstart block"
    exec(compile(blocks[0], "README.md:quickstart", "exec"), {})
