"""Two-tier hierarchical gossip: the TieredPlan round contract.

The load-bearing invariant is *trivial-tier bit-exactness*: with an intra
tier of size 1 the tiered round (intra reduce -> owned-shard gossip ->
all-gather) must be bitwise identical to the single-tier bucketed round on
the inter topology — outputs AND WireState carries, every wire, both
backends.  That equality is what lets the tiered engine inherit the
single-tier theory (theta bounds, EF residual analysis) unchanged.

Also covered: the executed nontrivial round equals the composed
``kron(W_inter, J_k/k)`` matrix, owned-shard byte accounting (slow-axis
payloads shrink ``n_intra``-fold, the ledger splits tiers), ``path="auto"``
resolving on the *shard's* leaf census, ``AlgoHyper.tiers`` plumbing, and
the guards on single-tier-only entry points.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.engine import CommEngine, make_wire
from repro.comm.gossip import BytesLedger
from repro.core.quantizers import QuantSpec
from repro.core.topology import fully_connected, ring, two_tier
from repro.core.algorithms import AlgoHyper
from repro.core.moniqua import MoniquaCodec

THETA = 2.0
WIRES = [("full", 32), ("moniqua", 2), ("qsgd", 4), ("ef_qsgd", 4),
         ("onebit", 1)]
BACKENDS = ("jnp", "pallas")


def _wire(name, bits):
    return make_wire(name, QuantSpec(bits=min(bits, 8),
                                     stochastic=1 < bits <= 8))


def _tree(n=8, scale=0.5):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    return {
        "a": scale * jax.random.normal(ks[0], (n, 37), jnp.float32),
        "b": scale * jax.random.normal(ks[1], (n, 5, 11), jnp.float32),
        "c": scale * jax.random.normal(ks[2], (n, 3), jnp.float32),
    }


def _assert_trees_equal(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("wire_name,bits", WIRES,
                         ids=[f"{w}-{b}b" for w, b in WIRES])
def test_trivial_tier_bitexact_vs_single_tier(wire_name, bits, backend):
    """two_tier(n, 1) rounds == single-tier bucketed rounds, bitwise,
    iterated so WireState carries propagate through the comparison."""
    n, rounds = 8, 3
    X0 = _tree(n)
    single = CommEngine(ring(n), _wire(wire_name, bits), backend,
                        path="bucketed")
    tiered = CommEngine(two_tier(n, 1), _wire(wire_name, bits), backend)
    assert tiered.tiered and not single.tiered
    Xs = Xt = X0
    ss = single.init_wire_state(X0) if single.stateful else None
    st = tiered.init_wire_state(X0) if tiered.stateful else None
    keys = jax.random.split(jax.random.PRNGKey(11), rounds)
    for t in range(rounds):
        rs = single.mix(Xs, theta=THETA, key=keys[t], state=ss)
        rt = tiered.mix(Xt, theta=THETA, key=keys[t], state=st)
        _assert_trees_equal(rs.x, rt.x)
        if single.stateful:
            _assert_trees_equal(rs.state, rt.state)
        Xs, Xt, ss, st = rs.x, rt.x, rs.state, rt.state


@pytest.mark.parametrize("n,n_intra", [(8, 2), (8, 4), (12, 3)])
def test_full_wire_round_equals_kron_matrix(n, n_intra):
    """The executed round (intra mean -> shard gossip -> all-gather) with
    the full-precision wire IS multiplication by kron(W_inter, J_k/k)."""
    hier = two_tier(n, n_intra)
    eng = CommEngine(hier, _wire("full", 32), "jnp")
    X = _tree(n)
    out = eng.mix(X, key=jax.random.PRNGKey(0)).x
    W = hier.matrix
    for k in X:
        flat = np.asarray(X[k], np.float64).reshape(n, -1)
        want = (W @ flat).reshape(X[k].shape)
        np.testing.assert_allclose(np.asarray(out[k], np.float64), want,
                                   atol=1e-5)


def test_tiered_slow_axis_bytes_shrink_n_intra_fold():
    X = _tree(32)
    wire = _wire("moniqua", 1)
    single = CommEngine(ring(32), wire, "jnp", path="bucketed")
    tiered = CommEngine(two_tier(32, 4), wire, "jnp")
    ps = single.payload_bytes_per_broadcast(X)
    pt = tiered.payload_bytes_per_broadcast(X)
    assert pt == -(-ps // 4)
    # abstract trees must give identical accounting (dryrun/bench contract)
    Xa = jax.eval_shape(lambda: X)
    assert tiered.payload_bytes_per_broadcast(Xa) == pt
    assert tiered.fast_bytes_per_round(Xa) == tiered.fast_bytes_per_round(X)
    # fast phase ships 2*(k-1)/k of the staging buffer at stage dtype
    padded = tiered.layout(X).padded_elems
    assert tiered.fast_bytes_per_round(X) == 2 * 4 * padded * 3 // 4
    assert single.fast_bytes_per_round(X) == 0


def test_ledger_splits_fast_and_slow_tiers():
    X = _tree(8)
    tiered = CommEngine(two_tier(8, 2), _wire("moniqua", 2), "jnp")
    led = BytesLedger()
    tiered.mix(X, theta=THETA, key=jax.random.PRNGKey(0), ledger=led)
    m = len(tiered.gossip_topo.neighbor_offsets())
    assert led.bytes_slow == tiered.payload_bytes_per_broadcast(X) * m
    assert led.bytes_fast == tiered.fast_bytes_per_round(X)
    assert led.bytes_per_worker == led.bytes_slow + led.bytes_fast
    # single-tier rounds account everything as slow-axis (totals unchanged)
    led1 = BytesLedger()
    single = CommEngine(ring(8), _wire("moniqua", 2), "jnp", path="bucketed")
    single.mix(X, theta=THETA, key=jax.random.PRNGKey(0), ledger=led1)
    assert led1.bytes_fast == 0 and led1.bytes_slow == led1.bytes_per_worker


def test_tiered_wire_state_is_owned_shard_sized():
    X = _tree(8)
    single = CommEngine(ring(8), _wire("onebit", 1), "jnp", path="bucketed")
    tiered = CommEngine(two_tier(8, 2), _wire("onebit", 1), "jnp")
    assert tiered.wire_state_bytes(X) < single.wire_state_bytes(X)
    padded = tiered.layout(X).padded_elems
    assert tiered.wire_state_bytes(X) == -(-padded // 2) * 4 + 4


def test_auto_path_resolves_on_shard_census():
    """``path="auto"`` with a shard window must resolve on the shard's own
    leaf census — bitwise the same verdict as a standalone model holding
    exactly those leaves — not inherit the whole model's."""
    n = 8
    X = {
        "big": jnp.zeros((n, 4096), jnp.float32),
        **{f"t{i}": jnp.zeros((n, 3), jnp.float32) for i in range(12)},
    }
    wire = _wire("moniqua", 2)
    eng = CommEngine(two_tier(n, 2), wire, "jnp", path="auto")
    layout = eng.layout(X)
    flat_eng = CommEngine(ring(n), wire, "jnp", path="auto")
    for i in range(2):
        sh = layout.shard(2, i)
        sub = {f"l{j}": jnp.zeros((n,) + s.shape, s.dtype)
               for j, s in enumerate(sh.slots)}
        want = flat_eng.resolved_path(sub)
        assert eng.resolved_path(None, shard=sh) == want
    # whole-buffer shard == whole-model resolution (the degenerate window)
    whole = layout.shard(1, 0)
    assert eng.resolved_path(None, shard=whole) == flat_eng.resolved_path(X)


def test_single_tier_only_entry_points_raise():
    X = _tree(8)
    eng = CommEngine(two_tier(8, 2), _wire("moniqua", 2), "jnp")
    with pytest.raises(ValueError):
        eng.round_plan(X, theta=THETA, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        eng.init_gossip_carry(X)
    with pytest.raises(ValueError):
        eng.neighbor_sum(X, lambda x: x)
    with pytest.raises(ValueError):
        eng.self_weight()
    with pytest.raises(ValueError):   # moniqua tiered round needs theta
        eng.mix(X, key=jax.random.PRNGKey(0))


def test_slack_applies_to_inter_tier_only():
    hier = two_tier(8, 2)
    slacked = hier.slack(0.5)
    np.testing.assert_allclose(slacked.intra.matrix, hier.intra.matrix)
    np.testing.assert_allclose(
        slacked.inter.matrix,
        0.5 * hier.inter.matrix + 0.5 * np.eye(4), atol=1e-12)
    # neighbor offsets stride by n_intra on the flat index
    assert two_tier(32, 4).neighbor_offsets() == (-4, 4)


def test_algo_hyper_tiers_builds_hierarchy():
    hp = AlgoHyper(topo=ring(8), codec=MoniquaCodec(QuantSpec(bits=2)),
                   theta=THETA, tiers=4)
    hier = hp.comm_topo()
    assert hier.n == 8 and hier.n_intra == 4
    assert hier.inter.name == "ring" and hier.inter.n == 2
    assert hier.intra.matrix == pytest.approx(fully_connected(4).matrix)
    # tiers=1 stays flat; slack on the flat topo is replayed on the inter
    assert AlgoHyper(topo=ring(8), codec=MoniquaCodec(QuantSpec(bits=2)),
                     theta=THETA).comm_topo() is not None
    hp_s = dataclasses.replace(hp, topo=ring(8).slack(0.5))
    assert hp_s.comm_topo().inter.name.endswith("slack0.5")
