"""Data pipeline: determinism, worker layout, heterogeneous (D^2) split."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.pipeline import SyntheticLMPipeline
from repro.data.synthetic import TokenTask, cifar_like, quadratic_grad
from repro.models.model_factory import build_model

SHAPE = InputShape("t", seq_len=16, global_batch=8, kind="train")


def _pipe(n=4, seed=0):
    model = build_model(get_config("llama3.2-3b").reduced())
    return SyntheticLMPipeline(model, SHAPE, n, seed=seed)


def test_pipeline_deterministic_in_seed_step():
    a = _pipe().worker_batch(3)
    b = _pipe().worker_batch(3)
    c = _pipe().worker_batch(4)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_worker_layout():
    wb = _pipe(n=4).worker_batch(0)
    assert wb["tokens"].shape == (4, 2, 16)
    assert wb["labels"].shape == (4, 2, 16)


def test_vlm_batch_has_patch_embeddings():
    model = build_model(get_config("phi-3-vision-4.2b").reduced())
    pipe = SyntheticLMPipeline(model, SHAPE, 2)
    gb = pipe.global_batch(0)
    assert "patch_embeds" in gb
    assert gb["patch_embeds"].shape[1] == model.cfg.vision_tokens


def test_token_task_learnable():
    """Bigram teacher: next-token dist is non-uniform (learnable signal)."""
    task = TokenTask(vocab_size=16)
    b = task.batch(0, batch=32, seq=64)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    assert toks.shape == (32, 64)
    # teacher determinism: same step -> identical batch
    b2 = task.batch(0, batch=32, seq=64)
    np.testing.assert_array_equal(toks, np.asarray(b2["tokens"]))
    # labels are the next-token shift of the stream
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_cifar_like_heterogeneous_split():
    """D^2 setting (Fig. 2a): worker i sees only class i."""
    for w in range(4):
        b = cifar_like(0, 16, worker=w, heterogeneous=True)
        assert (np.asarray(b["labels"]) == w).all()
    hom = cifar_like(0, 256, worker=1, heterogeneous=False)
    assert len(np.unique(np.asarray(hom["labels"]))) > 1


def test_quadratic_grad_unbiased():
    x = jnp.zeros((10_000,))
    keys = jax.random.split(jax.random.PRNGKey(0), 1)
    g = quadratic_grad(x, 0.2, keys[0], sigma=0.1)
    # E[g] = x - 0.1
    assert abs(float(g.mean()) + 0.1) < 5e-3
