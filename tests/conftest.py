"""Shared fixtures. Tests run on the single CPU device (no forced host
devices here — the dry-run subprocess test sets its own XLA_FLAGS)."""
import jax
import pytest

# Determinism + float32 default for numeric assertions.
jax.config.update("jax_default_matmul_precision", "float32")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
