"""Shared fixtures. Tests run on the single CPU device (no forced host
devices here — the dry-run subprocess test sets its own XLA_FLAGS)."""
import importlib.util
import os

import jax
import pytest

# Determinism + float32 default for numeric assertions.
jax.config.update("jax_default_matmul_precision", "float32")

# The property-based suites (test_modulo / test_quantizers / test_ef_codecs)
# importorskip hypothesis so local environments without it still run the
# deterministic tests.  In CI that skip would be SILENT — the suites pin the
# codec contracts, and requirements-ci.txt installs hypothesis precisely so
# they execute in the tier-1 matrix — so a CI environment missing it is a
# broken install and must fail loudly, not shed coverage.
if os.environ.get("CI") and importlib.util.find_spec("hypothesis") is None:
    raise pytest.UsageError(
        "hypothesis is not importable in CI: the property-based codec "
        "suites would be skipped silently. It is pinned in "
        "requirements-ci.txt — fix the install instead of skipping.")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
