"""Algorithm-level validation of the paper's core claims (C1, C2, C8).

C1 (Theorem 1): naive quantization stalls at the gradient-norm floor
    phi^2 delta^2 / (8 (1 + phi^2)) on the quadratic f(x)=||x-delta 1/2||^2/2.
C2 (Theorem 2/Corollary 1): Moniqua tracks full-precision D-PSGD.
C8 (Table 1): memory accounting — Moniqua adds zero bytes, Choco/DCD/ECD
    Theta(m d), DeepSqueeze Theta(n d).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import ALGORITHMS, AlgoHyper, get_algorithm
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.data.synthetic import quadratic_grad

N, D = 8, 32
DELTA_NAIVE = 0.2      # the lattice pitch of Theorem 1's quantizer


def _hyper(bits=8, theta=2.0, naive_delta=DELTA_NAIVE, gamma=1.0):
    return AlgoHyper(topo=ring(N), codec=MoniquaCodec(QuantSpec(bits=bits)),
                     theta=theta, gamma=gamma, naive_delta=naive_delta)


def _run_quadratic(algo_name, hp, steps=800, alpha0=0.05, sigma=0.05, seed=0):
    """Run an update rule on the Theorem-1 quadratic; return final mean
    squared gradient norm per worker (averaged over workers)."""
    algo = get_algorithm(algo_name)
    opt = hp.naive_delta / 2.0
    X = jnp.zeros((N, D))
    extra = algo.init(X, hp)
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def step(X, extra, k, key):
        key, kg, ka = jax.random.split(key, 3)
        gkeys = jax.random.split(kg, N)
        g = jax.vmap(lambda x, kk: quadratic_grad(x, hp.naive_delta, kk,
                                                  sigma))(X, gkeys)
        alpha = alpha0 / (1.0 + 0.01 * k)       # non-constant step size
        Xn, extran = algo.step(X, extra, g, alpha, k, ka, hp)
        return Xn, extran, key

    for k in range(steps):
        X, extra, key = step(X, extra, jnp.asarray(k), key)
    grad_sq = jnp.mean(jnp.sum((X - opt) ** 2, axis=1))
    return float(grad_sq), np.asarray(X)


def test_theorem1_naive_floor():
    """C1: naive quantization cannot beat the Theorem-1 floor; Moniqua can."""
    topo = ring(N)
    phi = topo.phi
    floor = phi ** 2 * DELTA_NAIVE ** 2 / (8.0 * (1.0 + phi ** 2)) * D

    naive_g2, _ = _run_quadratic("naive", _hyper())
    moni_g2, _ = _run_quadratic("moniqua", _hyper(theta=0.5))

    assert naive_g2 >= floor, (naive_g2, floor)
    assert moni_g2 < floor / 4.0, (moni_g2, floor)
    assert moni_g2 < naive_g2 / 10.0


def test_moniqua_matches_dpsgd():
    """C2: same asymptotic behaviour as full-precision D-PSGD."""
    d_g2, Xd = _run_quadratic("dpsgd", _hyper())
    m_g2, Xm = _run_quadratic("moniqua", _hyper(theta=0.5))
    # both reach the noise floor; Moniqua within 3x of full precision
    assert m_g2 <= max(3.0 * d_g2, 1e-3)


def test_all_algorithms_step_and_stay_finite():
    for name in ALGORITHMS:
        hp = _hyper(theta=1.0)
        g2, X = _run_quadratic(name, hp, steps=50)
        assert np.isfinite(X).all(), name
        assert np.isfinite(g2), name


def test_consensus_contraction():
    """Workers approach consensus under Moniqua gossip (basis of Lemma 7)."""
    _, X = _run_quadratic("moniqua", _hyper(theta=0.5), steps=600)
    spread = np.abs(X - X.mean(0, keepdims=True)).max()
    assert spread < 0.05


def test_1bit_moniqua_with_slack_matrix():
    """C4/Theorem 3: 1-bit (nearest, delta=1/4 < 1/2) with slack matrix."""
    # Theorem 3 prescribes a small averaging ratio gamma for coarse
    # quantizers (the paper's experiments used gamma = 5e-3); gamma = 0.1
    # suffices at this scale, gamma = 0.4 is too aggressive (1-bit noise
    # delta*B = theta enters scaled by gamma each round).
    hp = AlgoHyper(topo=ring(N).slack(0.1),
                   codec=MoniquaCodec(QuantSpec(bits=1, stochastic=False)),
                   theta=0.5, naive_delta=DELTA_NAIVE)
    g2, X = _run_quadratic("moniqua", hp, steps=1200)
    topo = ring(N)
    floor = topo.phi ** 2 * DELTA_NAIVE ** 2 / (8 * (1 + topo.phi ** 2)) * D
    assert np.isfinite(X).all()
    assert g2 < floor            # beats what naive can ever do


def test_d2_and_moniqua_d2_converge():
    for name in ("d2", "moniqua_d2"):
        g2, X = _run_quadratic(name, _hyper(theta=0.5), steps=600,
                               alpha0=0.03)
        assert np.isfinite(X).all()
        assert g2 < 0.05 * D


def test_memory_accounting_table1():
    """C8: extra memory — Moniqua 0, Choco/DCD Theta(md), DeepSqueeze Theta(nd)."""
    hp = _hyper()
    X = {"w": jnp.zeros((N, 1000))}
    model_bytes = 1000 * 4
    assert get_algorithm("moniqua").extra_memory_bytes(X, hp) == 0
    assert get_algorithm("dpsgd").extra_memory_bytes(X, hp) == 0
    # replica-based schemes pay neighbors+self replicas
    assert (get_algorithm("choco").extra_memory_bytes(X, hp)
            == model_bytes * 3)
    assert get_algorithm("dcd").extra_memory_bytes(X, hp) == model_bytes * 3
    assert (get_algorithm("deepsqueeze").extra_memory_bytes(X, hp)
            == model_bytes)


def test_bytes_per_step_ordering():
    """Quantized payloads shrink wire bytes by exactly bits/32 vs f32."""
    X = {"w": jnp.zeros((N, 1024))}
    hp8 = _hyper(bits=8)
    hp1 = AlgoHyper(topo=ring(N),
                    codec=MoniquaCodec(QuantSpec(bits=1, stochastic=False)),
                    theta=2.0)
    full = get_algorithm("dpsgd").bytes_per_step(X, hp8)
    b8 = get_algorithm("moniqua").bytes_per_step(X, hp8)
    b1 = get_algorithm("moniqua").bytes_per_step(X, hp1)
    assert b8 == full // 4       # 8 bits vs 32
    assert b1 == full // 32      # 1 bit vs 32


def test_unknown_algorithm_raises():
    with pytest.raises(ValueError):
        get_algorithm("sgdmagic")
