"""End-to-end trainer integration: loss decreases, checkpoints round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig

SHAPE = InputShape("tiny", seq_len=16, global_batch=8, kind="train")


def _tiny_model():
    cfg = get_config("llama3.2-3b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, num_layers=1, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=64)
    return build_model(cfg)


def test_trainer_loss_decreases():
    model = _tiny_model()
    tc = TrainerConfig(algo="moniqua", n_workers=4, bits=8, theta=2.0,
                       lr=0.3, steps=30, log_every=5, momentum=0.0,
                       weight_decay=0.0)
    out = Trainer(model, SHAPE, tc).run()
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert np.isfinite(hist[-1]["loss"])
    assert out["bytes_per_step"] > 0


def test_trainer_quantized_tracks_full_precision():
    model = _tiny_model()
    common = dict(n_workers=4, lr=0.3, steps=25, log_every=25,
                  momentum=0.0, weight_decay=0.0, seed=1)
    fp = Trainer(model, SHAPE, TrainerConfig(algo="dpsgd", **common)).run()
    mq = Trainer(model, SHAPE, TrainerConfig(algo="moniqua", bits=8,
                                             theta=2.0, **common)).run()
    l_fp = fp["history"][-1]["loss"]
    l_mq = mq["history"][-1]["loss"]
    assert abs(l_mq - l_fp) < 0.25 * l_fp
    # and the quantized run ships 4x fewer bytes (8 vs 32)
    assert mq["bytes_per_step"] * 4 <= fp["bytes_per_step"] * 1.01


def test_checkpoint_roundtrip(tmp_path):
    model = _tiny_model()
    tc = TrainerConfig(algo="moniqua", n_workers=2, steps=3, log_every=1,
                       checkpoint_path=str(tmp_path / "ck"),
                       checkpoint_every=2)
    out = Trainer(model, SHAPE, tc).run()
    params = out["state"]["params"]
    restored = ckpt.restore(str(tmp_path / "ck"), params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.shape == b.shape
        assert a.dtype == b.dtype
    meta = ckpt.load_meta(str(tmp_path / "ck"))
    assert meta["algo"] == "moniqua"


def test_checkpoint_exact_values(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.int32)]}
    ckpt.save(str(tmp_path / "t"), tree, {"k": 1})
    back = ckpt.restore(str(tmp_path / "t"), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_trainer_theory_theta_mode():
    """ThetaSchedule(mode='theory') end-to-end: theta tracks alpha * g_inf
    via the Theorem-2 expression and training stays finite."""
    from repro.core.theta import ThetaSchedule, theta_dpsgd
    from repro.core.topology import ring
    from repro.train import train_step as TS
    from repro.core.algorithms import AlgoHyper, get_algorithm
    from repro.core.moniqua import MoniquaCodec
    from repro.core.quantizers import QuantSpec
    from repro.optim.sgd import SGDConfig
    from repro.data.pipeline import SyntheticLMPipeline

    model = _tiny_model()
    n = 4
    topo = ring(n)
    hp = AlgoHyper(topo=topo, codec=MoniquaCodec(QuantSpec(bits=8)))
    tcfg = TS.TrainStepConfig(
        algo="moniqua", sgd=SGDConfig(momentum=0.0, weight_decay=0.0),
        lr=0.2, theta=ThetaSchedule(mode="theory", n=n, rho=topo.rho))
    algo = get_algorithm("moniqua")
    step = jax.jit(TS.make_train_step(model, hp, tcfg))
    state = TS.init_state(model, algo, hp, n, jax.random.PRNGKey(0))
    pipe = SyntheticLMPipeline(model, SHAPE, n)
    for k in range(5):
        state, metrics = step(state, pipe.worker_batch(k))
    th = float(metrics["theta"])
    expect = theta_dpsgd(0.2, float(metrics["g_inf"]), n, topo.rho)
    assert th == pytest.approx(expect, rel=1e-4)
    assert np.isfinite(float(metrics["loss"]))
