"""Theory-prescribed theta/delta/bits (Theorems 2-5, Sec. 4 bits bound)."""
import numpy as np
import pytest

from repro.core import theta as TH
from repro.core.topology import exponential, ring


def test_bits_bound_dimension_free_loglog():
    """Sec. 4: B <= ceil(log2(4 log2(16n)/(1-rho) + 3)), O(log log n)."""
    rho = 0.5
    bs = [TH.bits_bound(n, rho) for n in (8, 64, 512, 4096, 2 ** 20)]
    assert bs == sorted(bs)                       # non-decreasing
    assert bs[-1] - bs[0] <= 2                    # log log growth: tiny
    assert TH.bits_bound(8, rho) <= 6             # single-digit bits suffice


def test_delta_dpsgd_below_half():
    for n in (2, 8, 256):
        for rho in (0.1, 0.9, 0.99):
            d = TH.delta_dpsgd(n, rho)
            assert 0.0 < d < 0.5


def test_theta_dpsgd_scales_with_alpha_and_ginf():
    t1 = TH.theta_dpsgd(0.1, 1.0, 8, 0.5)
    assert TH.theta_dpsgd(0.2, 1.0, 8, 0.5) == pytest.approx(2 * t1)
    assert TH.theta_dpsgd(0.1, 3.0, 8, 0.5) == pytest.approx(3 * t1)


def test_gamma_slack_in_unit_interval():
    for bits_delta in (0.25, 0.1):
        g = TH.gamma_slack(bits_delta, n=8, K=10_000, rho=2 / 3)
        assert 0.0 < g <= 1.0
    # finer quantizer (smaller delta) allows larger gamma (closer to plain W)
    assert (TH.gamma_slack(0.01, 8, 10_000, 2 / 3)
            >= TH.gamma_slack(0.25, 8, 10_000, 2 / 3))


def test_d2_constants_and_schedules():
    # the uniform-1/3 ring has lambda_n = -1/3 exactly (the assumption
    # boundary); a lazier ring satisfies lambda_n > -1/3
    topo = ring(8, self_weight=0.5)
    d1, d2 = TH._d2_constants(topo)
    assert d1 > 0 and d2 > 0
    th = TH.theta_d2(0.1, 1.0, topo)
    assert th == pytest.approx((6 * d1 * 8 + 8) * 0.1 * 1.0)
    dd = TH.delta_d2(topo)
    assert 0 < dd < 0.5
    assert dd == pytest.approx(1.0 / (12 * 8 * d2 + 2))


def test_d2_lambda_n_guard():
    """D^2 requires lambda_n > -1/3; a ring with tiny self-weight violates it."""
    bad = ring(8, self_weight=0.01)
    lam_n = np.linalg.eigvalsh(bad.matrix).min()
    if lam_n <= -1 / 3:
        with pytest.raises(ValueError):
            TH._d2_constants(bad)
    # slack matrix repairs it
    lazy = bad.slack(0.5)
    TH._d2_constants(lazy)   # must not raise


def test_adpsgd_schedules():
    t_mix = ring(8).t_mix_bound
    assert TH.theta_adpsgd(0.1, 2.0, t_mix) == pytest.approx(16 * t_mix * 0.2)
    d = TH.delta_adpsgd(t_mix)
    assert 0 < d < 0.5


def test_theta_schedule_modes():
    s = TH.ThetaSchedule(mode="constant", value=2.0)
    assert s(0.1, 5.0) == 2.0
    s = TH.ThetaSchedule(mode="theory", n=8, rho=ring(8).rho)
    assert s(0.1, 1.0) == pytest.approx(
        TH.theta_dpsgd(0.1, 1.0, 8, ring(8).rho))
    with pytest.raises(ValueError):
        TH.ThetaSchedule(mode="bogus")(0.1, 1.0)
