"""Flash-attention Pallas kernel vs masked-softmax oracle (interpret mode).

Sweeps shapes x dtypes x (causal, window) and checks the custom-vjp wrapper
(forward = kernel, backward = reference recompute) against full jnp autodiff.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.models import layers as L


def _qkv(S, H=2, D=64, B=2, dtype=jnp.float32, seed=0, Sk=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk or S, H, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk or S, H, D), jnp.float32).astype(dtype)
    return q, k, v


def _ref(q, k, v, window, scale):
    return L._sdpa(q, k, v, L.causal_mask(q.shape[-3], k.shape[-3], window),
                   scale)


@pytest.mark.parametrize("S,window", [(256, 0), (384, 100), (128, 32),
                                      (130, 0)])   # 130: padding path
def test_flash_matches_oracle(S, window):
    q, k, v = _qkv(S)
    scale = 1.0 / math.sqrt(q.shape[-1])
    out = ops.flash_sdpa(q, k, v, scale=scale, window=window, interpret=True)
    ref = _ref(q, k, v, window, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    q, k, v = _qkv(256, dtype=dtype)
    scale = 0.125
    out = ops.flash_sdpa(q, k, v, scale=scale, interpret=True)
    assert out.dtype == dtype
    ref = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
               v.astype(jnp.float32), 0, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=0, atol=0.03)


def test_flash_kernel_block_shapes():
    """Non-default blocks exercise the grid/index maps."""
    B, H, S, D = 1, 1, 512, 64
    q, k, v = _qkv(S, H=H, B=B)
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, D)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * H, S, D)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * H, S, D)
    scale = 0.125
    o1 = flash_attention(qf, kf, vf, scale=scale, blk_q=256, blk_k=64,
                         interpret=True)
    o2 = flash_attention(qf, kf, vf, scale=scale, blk_q=64, blk_k=256,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_flash_gradient_matches_reference():
    q, k, v = _qkv(192, H=1, B=1)
    scale = 1.0 / math.sqrt(q.shape[-1])

    def loss_flash(q, k, v):
        return jnp.sum(ops.flash_sdpa(q, k, v, scale=scale, window=64,
                                      interpret=True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(_ref(q, k, v, 64, scale) ** 2)

    g_f = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_model_attention_flash_flag():
    """cfg.flash_attention=True routes L.attention through the kernel with
    numerically equivalent results."""
    import dataclasses
    from repro.configs import get_config
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              dtype="float32")
    key = jax.random.PRNGKey(0)
    p = L.init_attention(key, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(128), (2, 128))
    ref = L.attention(p, cfg, x, pos)
    cfg_f = dataclasses.replace(cfg, flash_attention=True)
    out = L.attention(p, cfg_f, x, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
