"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED variant (2 layers,
d_model <= 256, <= 4 experts) and runs:
  * one decentralized Moniqua train step (vmap-grad + quantized gossip),
    asserting finite loss/params and correct shapes;
  * one cached decode step (serve path), asserting logits shape + finiteness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import assigned_archs, get_config
from repro.configs.base import InputShape
from repro.core.algorithms import AlgoHyper, get_algorithm
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.theta import ThetaSchedule
from repro.core.topology import ring
from repro.models.model_factory import build_model
from repro.optim.sgd import SGDConfig
from repro.train import serve_step as SS
from repro.train import train_step as TS

SMOKE_TRAIN = InputShape("smoke_train", seq_len=32, global_batch=4,
                         kind="train")
SMOKE_DECODE = InputShape("smoke_decode", seq_len=64, global_batch=2,
                          kind="decode")
N_WORKERS = 2

ARCHS = assigned_archs()


def _batch(model, shape, key):
    spec = model.batch_spec(shape)
    out = {}
    for name, (shp, dt) in spec.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(dt, jnp.integer):
            out[name] = jax.random.randint(k, shp, 0, model.cfg.vocab_size,
                                           dtype=jnp.int32)
        else:
            out[name] = jax.random.normal(k, shp, jnp.float32).astype(dt)
    return out


def _stack(batch, n):
    return {k: v.reshape(n, v.shape[0] // n, *v.shape[1:])
            for k, v in batch.items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    algo = get_algorithm("moniqua")
    hp = AlgoHyper(topo=ring(N_WORKERS), codec=MoniquaCodec(QuantSpec(bits=8)),
                   theta=2.0)
    tcfg = TS.TrainStepConfig(algo="moniqua", sgd=SGDConfig(), lr=0.05,
                              theta=ThetaSchedule(mode="constant", value=2.0))
    step = TS.make_train_step(model, hp, tcfg)
    state = TS.init_state(model, algo, hp, N_WORKERS, jax.random.PRNGKey(0))
    batch = _stack(_batch(model, SMOKE_TRAIN, jax.random.PRNGKey(1)),
                   N_WORKERS)
    new_state, metrics = jax.jit(step)(state, batch)

    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch
    assert loss > 0.0
    for leaf in jax.tree.leaves(new_state["params"]):
        assert leaf.shape[0] == N_WORKERS
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch
    assert int(new_state["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(SMOKE_DECODE.global_batch, SMOKE_DECODE)
    tok = jnp.ones((SMOKE_DECODE.global_batch, 1), jnp.int32)
    sstep = jax.jit(SS.make_serve_step(model))
    logits, cache2 = sstep(params, cache, tok)
    logits, cache3 = sstep(params, cache2, tok)   # second token re-uses cache
    assert logits.shape[0] == SMOKE_DECODE.global_batch
    assert logits.shape[-1] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), arch
    # cache positions advance
    pos = cache3["pos"] if "pos" in cache3 else None
    if pos is not None:
        assert int(pos) == 2


@pytest.mark.parametrize("arch", ["llama3.2-3b", "dbrx-132b", "zamba2-1.2b",
                                  "whisper-base", "phi-3-vision-4.2b"])
def test_prefill_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = InputShape("smoke_prefill", seq_len=32, global_batch=2,
                       kind="prefill")
    batch = _batch(model, shape, jax.random.PRNGKey(5))
    logits = jax.jit(SS.make_prefill_step(model))(params, batch)
    assert bool(jnp.isfinite(logits).all()), arch


def test_param_counts_match_published_scale():
    """Analytic parameter counts land near the published sizes (names)."""
    expected = {
        "dbrx-132b": 132e9, "grok-1-314b": 314e9, "chatglm3-6b": 6e9,
        "llama3.2-3b": 3e9, "xlstm-125m": 125e6, "internlm2-20b": 20e9,
        "qwen2-72b": 72e9, "zamba2-1.2b": 1.2e9,
    }
    for arch, target in expected.items():
        n = get_config(arch).param_count()
        assert 0.5 * target <= n <= 1.7 * target, (arch, n, target)


def test_reduced_configs_are_small():
    for arch in ARCHS:
        r = get_config(arch).reduced()
        assert r.num_layers <= 2
        assert r.d_model <= 512
        if r.moe:
            assert r.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ["llama3.2-3b", "zamba2-1.2b"])
def test_prefill_last_only_serving_semantics(arch):
    """serve_step prefill returns [B, 1, V] (last position only) and matches
    the full forward's final row."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = InputShape("p", seq_len=32, global_batch=2, kind="prefill")
    batch = _batch(model, shape, jax.random.PRNGKey(3))
    last = jax.jit(SS.make_prefill_step(model))(params, batch)
    assert last.shape[1] == 1
    full = model.prefill_logits(params, batch, last_only=False)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)
