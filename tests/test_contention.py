"""Contention + calibration contracts (repro.sim.contention / .calibrate).

The promises the contended, self-calibrating simulator makes:

1. **Water-filling exactness** — saturated resources are used to exactly
   their capacity, every flow gets a positive rate, and the allocation is
   independent of flow insertion order.
2. **Contention only hurts** — a contended round is never faster than the
   same round on isolated links; a fabric with no shared switches
   reproduces the isolated closed form on a symmetric gossip round.
3. **Calibration round-trips** — least-squares fitting on times generated
   by ``alpha + n/beta`` recovers both parameters within 5%, and the
   emitted ``NetworkModel`` JSON loads back identically.
"""
import math

import pytest

from repro.core.topology import ring
from repro.sim import calibrate as CAL
from repro.sim import cluster as SCL
from repro.sim import contention as CT
from repro.sim import events as SE
from repro.sim import network as SN
from repro.sim import scenarios as SC


# ---------------------------------------------------------------------------
# rate solving: water-filling and max-concurrency
# ---------------------------------------------------------------------------

def _cap(table):
    return lambda r: table[r]


def test_water_filling_sums_to_capacity():
    # three flows through one 90 B/s bottleneck, fat NICs
    caps = {"tx:0": 1e3, "tx:1": 1e3, "tx:2": 1e3, "sw:b:shared": 90.0,
            "rx:3": 1e3}
    paths = {i: (f"tx:{i}", "sw:b:shared", "rx:3") for i in range(3)}
    rates = CT.solve_rates(paths, _cap(caps))
    assert sum(rates.values()) == pytest.approx(90.0)
    for r in rates.values():
        assert r == pytest.approx(30.0)


def test_water_filling_max_min_fairness():
    # flow 0 is also bottlenecked on its own slow NIC: it freezes early
    # and the shared-switch capacity it cannot use goes to flow 1
    caps = {"tx:0": 10.0, "tx:1": 1e3, "sw:b:shared": 100.0,
            "rx:2": 1e3, "rx:3": 1e3}
    paths = {0: ("tx:0", "sw:b:shared", "rx:2"),
             1: ("tx:1", "sw:b:shared", "rx:3")}
    rates = CT.solve_rates(paths, _cap(caps))
    assert rates[0] == pytest.approx(10.0)
    assert rates[1] == pytest.approx(90.0)      # work-conserving
    assert sum(rates.values()) == pytest.approx(100.0)


def test_water_filling_order_invariant():
    caps = {f"tx:{i}": 50.0 + 7 * i for i in range(6)}
    caps["sw:u:shared"] = 120.0
    caps.update({f"rx:{i}": 1e3 for i in range(6)})
    paths = {i: (f"tx:{i}", "sw:u:shared", f"rx:{i}") for i in range(6)}
    fwd = CT.solve_rates(paths, _cap(caps))
    rev = CT.solve_rates(dict(reversed(list(paths.items()))), _cap(caps))
    for i in range(6):
        assert fwd[i] == pytest.approx(rev[i])


def test_max_concurrency_pessimistic_but_positive():
    caps = {"tx:0": 10.0, "tx:1": 1e3, "sw:b:shared": 100.0,
            "rx:2": 1e3, "rx:3": 1e3}
    paths = {0: ("tx:0", "sw:b:shared", "rx:2"),
             1: ("tx:1", "sw:b:shared", "rx:3")}
    rates = CT.solve_rates(paths, _cap(caps), CT.MAX_CONCURRENCY)
    # equal split of the most contended resource: no work conservation
    assert rates[0] == pytest.approx(10.0)
    assert rates[1] == pytest.approx(50.0)
    wf = CT.solve_rates(paths, _cap(caps), CT.WATER_FILLING)
    for f in paths:
        assert 0.0 < rates[f] <= wf[f] + 1e-12


def test_solve_rates_rejects_unknown_mode_and_empty():
    with pytest.raises(ValueError):
        CT.solve_rates({0: ("tx:0",)}, _cap({"tx:0": 1.0}), "tcp-reno")
    assert CT.solve_rates({}, _cap({})) == {}


# ---------------------------------------------------------------------------
# fabric topology: which resources a flow traverses
# ---------------------------------------------------------------------------

def test_switch_crossing_semantics():
    sw = CT.Switch("tor0", 1e6, members=(0, 2, 4, 6))
    assert sw.resources(0, 1, 8) == ("sw:tor0:up",)      # leaving the rack
    assert sw.resources(1, 0, 8) == ("sw:tor0:down",)    # entering
    assert sw.resources(0, 2, 8) == ()                   # intra-rack
    assert sw.resources(1, 3, 8) == ()                   # both outside
    bus = CT.Switch("bus", 1e6)
    assert bus.resources(0, 1, 8) == ("sw:bus:shared",)
    assert bus.resources(5, 2, 8) == ("sw:bus:shared",)


def test_fabric_path_and_capacity():
    fab = CT.oversubscribed_fabric(8, nic_Bps=1e9, uplink_Bps=1e8)
    p = fab.path(0, 1, 8)
    assert p[0] == "tx:0" and p[-1] == "rx:1"
    assert "sw:tor0:up" in p and "sw:tor1:down" in p
    assert fab.capacity("tx:5") == 1e9
    assert fab.capacity("sw:tor1:down") == 1e8
    with pytest.raises(KeyError):
        fab.capacity("sw:nope:up")
    with pytest.raises(ValueError):
        CT.Fabric(nic_Bps=1e9, mode="tcp-reno")
    with pytest.raises(ValueError):
        CT.Switch("s", 0.0)


def test_tor_groups_partition():
    inter = CT.tor_groups(8, 2, interleave=True)
    assert inter == ((0, 2, 4, 6), (1, 3, 5, 7))
    block = CT.tor_groups(8, 2, interleave=False)
    assert block == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert sorted(sum(CT.tor_groups(7, 3), ())) == list(range(7))


# ---------------------------------------------------------------------------
# the fluid scheduler
# ---------------------------------------------------------------------------

def test_flow_scheduler_serializes_on_shared_medium():
    fab = CT.shared_medium_fabric(nic_Bps=1e3, bus_Bps=100.0)
    sched = CT.FlowScheduler(fab, 4)
    sched.start(0.0, 0, 0, 1, 100.0)
    assert sched.eta(0) == pytest.approx(1.0)           # alone: full rate
    e0 = sched.epoch
    sched.start(0.5, 1, 2, 3, 100.0)
    assert sched.epoch > e0                             # stale predictions
    # flow 0 drained 50 B alone, then shares: 50 B left at 50 B/s
    assert sched.eta(0) == pytest.approx(1.5)
    assert sched.eta(1) == pytest.approx(2.5)
    sched.finish(1.5, 0)
    assert sched.eta(1) == pytest.approx(2.0)           # back to full rate


def test_schedule_transfers_matches_hand_computation():
    fab = CT.shared_medium_fabric(nic_Bps=1e3, bus_Bps=100.0)
    fin = CT.schedule_transfers(
        fab, 4, [(0.0, 0, 1, 100.0), (0.5, 2, 3, 100.0)])
    assert fin[0] == pytest.approx(1.5)
    assert fin[1] == pytest.approx(2.0)


def test_contended_round_never_faster_than_isolated():
    """Core contract: adding shared switches can only add time."""
    n, nbytes = 8, 250_000
    base = SC.Scenario(
        "iso", ring(n),
        SN.NetworkModel.homogeneous(alpha_s=1e-4, beta_Bps=1e8),
        SCL.homogeneous(0.01),
        fabric=CT.isolated_fabric(1e8, alpha_s=1e-4))
    for uplink in (1e8, 1e7, 1e6):
        cont = SC.Scenario(
            "tor", ring(n),
            SN.NetworkModel.homogeneous(alpha_s=1e-4, beta_Bps=1e8),
            SCL.homogeneous(0.01),
            fabric=CT.oversubscribed_fabric(n, nic_Bps=1e8,
                                            uplink_Bps=uplink,
                                            alpha_s=1e-4))
        t_iso = SE.simulate_sync_rounds(base, nbytes, 3).total_seconds
        t_con = SE.simulate_sync_rounds(cont, nbytes, 3).total_seconds
        assert t_con >= t_iso - 1e-12


def test_fabric_without_switches_matches_isolated_closed_form():
    """Symmetric ring round: fluid sharing == serialized NIC sends."""
    n, nbytes = 8, 100_000
    iso = SC.Scenario(
        "iso", ring(n),
        SN.NetworkModel.homogeneous(alpha_s=1e-3, beta_Bps=1e7),
        SCL.homogeneous(0.05))
    fab = SC.Scenario(
        "fab", ring(n),
        SN.NetworkModel.homogeneous(alpha_s=1e-3, beta_Bps=1e7),
        SCL.homogeneous(0.05),
        fabric=CT.isolated_fabric(1e7, alpha_s=1e-3))
    t_iso = SE.simulate_sync_rounds(iso, nbytes, 3)
    t_fab = SE.simulate_sync_rounds(fab, nbytes, 3)
    expect = 0.05 + 2 * nbytes / 1e7 + 1e-3
    for r in t_fab.round_seconds:
        assert r == pytest.approx(expect, rel=1e-9)
    assert t_fab.total_seconds == pytest.approx(t_iso.total_seconds,
                                                rel=1e-9)
    assert t_fab.bytes_on_wire == t_iso.bytes_on_wire


@pytest.mark.parametrize("mode", CT.SHARING_MODES)
def test_async_contended_liveness_and_determinism(mode):
    n = 8
    sc = SC.Scenario(
        "cont-async", ring(n),
        SN.NetworkModel.homogeneous(alpha_s=1e-4, beta_Bps=1e8),
        SCL.homogeneous(0.005),
        fabric=CT.shared_medium_fabric(nic_Bps=1e8, bus_Bps=1e6,
                                       alpha_s=1e-4, mode=mode))
    a = SE.simulate_async_gossip(sc, bytes_per_exchange=20_000,
                                 num_updates=80)
    b = SE.simulate_async_gossip(sc, bytes_per_exchange=20_000,
                                 num_updates=80)
    assert a.count(SE.UPDATE) == 80                     # no deadlock
    assert a.fingerprint() == b.fingerprint()
    assert a.bytes_on_wire == 2 * 20_000 * a.count(SE.GOSSIP)
    assert math.isfinite(a.total_seconds)
    # the shared medium really throttles: slower than the isolated twin
    iso = SC.Scenario("iso-async", ring(n), sc.network, sc.compute)
    t_iso = SE.simulate_async_gossip(iso, 20_000, 80).total_seconds
    assert a.total_seconds > t_iso


# ---------------------------------------------------------------------------
# calibration: least-squares alpha-beta fits
# ---------------------------------------------------------------------------

def test_fit_recovers_exact_line():
    fit = CAL.fit_link(CAL.synthetic_samples(2e-3, 12.5e6,
                                             (10_000, 10**5, 10**6, 10**7)))
    assert fit.alpha_s == pytest.approx(2e-3, rel=1e-9)
    assert fit.beta_Bps == pytest.approx(12.5e6, rel=1e-9)
    assert fit.r2 == pytest.approx(1.0)


@pytest.mark.parametrize("alpha,beta", [(5e-3, 100e6 / 8),
                                        (0.15e-3, 1e9 / 8),
                                        (20e-3, 25e6 / 8)])
def test_fit_round_trips_within_5pct_under_jitter(alpha, beta):
    """The acceptance contract: noisy synthetic traces, <= 5% error."""
    sizes = tuple(int(x) for x in (2e4, 1e5, 3e5, 1e6, 3e6, 1e7))
    samples = CAL.synthetic_samples(alpha, beta, sizes,
                                    jitter_s=0.1 * alpha, seed=3)
    fit = CAL.fit_link(samples)
    assert abs(fit.alpha_s - alpha) / alpha < 0.05
    assert abs(fit.beta_Bps - beta) / beta < 0.05
    assert fit.n_samples == len(sizes)


def test_fit_network_per_offset():
    short = CAL.synthetic_samples(1e-3, 1e8, (10**4, 10**5, 10**6))
    long_ = CAL.synthetic_samples(40e-3, 1e7, (10**4, 10**5, 10**6))
    net = CAL.fit_network({1: short, 4: long_})
    assert net.link(0, 1, 16).beta_Bps == pytest.approx(1e8, rel=1e-6)
    assert net.link(0, 4, 16).alpha_s == pytest.approx(40e-3, rel=1e-6)
    # unmatched hops fall back to the pooled default
    assert net.link(0, 8, 16) is net.default


def test_fit_rejects_degenerate_samples():
    with pytest.raises(ValueError):
        CAL.fit_link([(1000.0, 0.1)])
    with pytest.raises(ValueError):
        CAL.fit_link([(1000.0, 0.1), (1000.0, 0.2)])    # one payload size
    with pytest.raises(ValueError):
        CAL.fit_link([(1000.0, 0.5), (10_000.0, 0.1)])  # shrinking times


def test_network_model_json_round_trip(tmp_path):
    net = SN.NetworkModel(
        SN.LinkModel(1e-3, 1e8, 1e-5)).with_offset_links(
        {4: SN.LinkModel(2e-3, 5e7)})
    path = tmp_path / "net.json"
    CAL.save_network_model(net, str(path), meta={"source": "test"})
    loaded = CAL.load_network_model(str(path))
    assert loaded == net


def test_calibrate_from_walltime_rows():
    # synthetic codec_table in the bench_walltime shape: the fit must see
    # through the per-row measured mix time to the pure network term
    lat, bw = 5e-3, 100e6
    rows = []
    for nbytes, mix_ms in [(2e4, 0.8), (2e5, 1.1), (1e6, 2.3), (2e6, 4.0)]:
        comm = nbytes * 8.0 / bw + 2 * lat
        rows.append({"wire_bytes_per_step": nbytes,
                     "mix_ms_measured": mix_ms,
                     "s/step 100Mbps-5ms": 0.05 + mix_ms / 1e3 + comm})
    fit = CAL.calibrate_from_walltime({"codec_table": rows}, "100Mbps-5ms",
                                      compute_s=0.05)
    assert fit.alpha_s == pytest.approx(2 * lat, rel=1e-6)
    assert fit.beta_Bps == pytest.approx(bw / 8.0, rel=1e-6)


# ---------------------------------------------------------------------------
# scenario catalog + acceptance claims
# ---------------------------------------------------------------------------

def test_new_scenarios_registered():
    names = set(SC.list_scenarios())
    assert {"oversubscribed-tor", "shared-uplink-ring",
            "calibrated-from-bench"} <= names
    for name in ("oversubscribed-tor", "shared-uplink-ring"):
        sc = SC.get_scenario(name, n=8)
        assert sc.fabric is not None
        assert sc.fabric.mode == CT.WATER_FILLING


def test_oversubscribed_tor_widens_fp32_gap():
    """Acceptance: same NICs, shared uplinks => the fp32-vs-1bit round
    ratio grows well beyond the isolated-link scenario's."""
    fp32_b, onebit_b = 460_032, 14_376      # tiny-LM bytes/neighbor
    ratios = {}
    for name in ("lan-10gbe-ring", "oversubscribed-tor"):
        sc = SC.get_scenario(name, n=8)
        t32 = SE.simulate_sync_rounds(sc, fp32_b, 3).mean_round_seconds
        t1 = SE.simulate_sync_rounds(sc, onebit_b, 3).mean_round_seconds
        ratios[name] = t32 / t1
    assert ratios["oversubscribed-tor"] > 2 * ratios["lan-10gbe-ring"]
    assert ratios["oversubscribed-tor"] > 3.0


def test_calibrated_scenario_matches_probed_constants():
    sc = SC.get_scenario("calibrated-from-bench", n=8)
    lm = sc.network.default
    assert abs(lm.alpha_s - SC._CAL_TRUE_ALPHA_S) / SC._CAL_TRUE_ALPHA_S \
        < 0.05
    assert abs(lm.beta_Bps - SC._CAL_TRUE_BETA_BPS) / SC._CAL_TRUE_BETA_BPS \
        < 0.05


def test_calibrated_scenario_loads_model_file(tmp_path):
    net = SN.NetworkModel(SN.LinkModel(7e-3, 9e6))
    path = tmp_path / "model.json"
    CAL.save_network_model(net, str(path))
    sc = SC.calibrated_from_bench(n=8, model_path=str(path))
    assert sc.network == net


def test_calibrated_scenario_rejects_missing_model_path(tmp_path):
    """An explicitly named model must exist — no silent synthetic
    fallback that would defeat calibration."""
    with pytest.raises(FileNotFoundError):
        SC.calibrated_from_bench(n=8,
                                 model_path=str(tmp_path / "typo.json"))


def test_shared_uplink_isolated_twin_matches():
    """lan-1gbe-ring shares NIC/alpha/jitter/compute with
    shared-uplink-ring so their comparison isolates contention."""
    iso = SC.get_scenario("lan-1gbe-ring", n=8)
    con = SC.get_scenario("shared-uplink-ring", n=8)
    assert iso.fabric is None and con.fabric is not None
    assert iso.network == con.network
    assert iso.compute == con.compute


def test_roofline_ici_calibratable():
    from repro.analysis import roofline as RL
    hw = RL.hw_with_ici(SN.LinkModel(alpha_s=0.0, beta_Bps=42e9))
    assert hw["ici_bw"] == 42e9
    assert hw["peak_flops"] == RL.HW["peak_flops"]
    assert RL.hw_with_ici(13e9)["ici_bw"] == 13e9
    assert RL.HW["ici_bw"] == SN.TPU_V5E_ICI.beta_Bps   # default untouched
    with pytest.raises(ValueError):
        RL.hw_with_ici(0.0)
