"""Roofline extraction: HLO collective parsing + term arithmetic."""
import pytest

from repro.analysis import roofline as RL
from repro.configs import get_config
from repro.configs.base import get_input_shape

HLO = """
HloModule test
ENTRY %main {
  %p0 = f32[16,256]{1,0} parameter(0)
  %ag = f32[16,4096]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={1}
  %ar = bf16[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%y), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = u8[1000]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = f32[32,32]{1,0} all-to-all(%w), replica_groups={{0,1}}, dimensions={0}
  %ags = f32[8,128]{1,0} all-gather-start(%q), replica_groups=[4,2]<=[8], dimensions={1}
  %agd = f32[8,128]{1,0} all-gather-done(%ags)
}
"""


def test_parse_collectives_counts_and_bytes():
    st = RL.parse_collectives(HLO)
    assert st.counts == {"all-gather": 2, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1,
                         "all-to-all": 1}
    # all-gather operand = result / group (16,4096)*4/16 ; start variant /2
    ag = 16 * 4096 * 4 // 16 + 8 * 128 * 4 // 2
    assert st.bytes_by_op["all-gather"] == ag
    assert st.bytes_by_op["all-reduce"] == 1024 * 2
    assert st.bytes_by_op["reduce-scatter"] == 64 * 4 * 8
    assert st.bytes_by_op["collective-permute"] == 1000    # u8
    assert st.bytes_by_op["all-to-all"] == 32 * 32 * 4
    assert st.total_bytes == sum(st.bytes_by_op.values())


def test_quantized_payload_visible_to_parser():
    """A u8 collective-permute is 1/4 the bytes of the f32 one — the paper's
    bandwidth saving must be measurable at the HLO level."""
    full = RL.parse_collectives(
        "%cp = f32[1000]{0} collective-permute(%z), source_target_pairs={{0,1}}")
    quant = RL.parse_collectives(
        "%cp = u8[1000]{0} collective-permute(%z), source_target_pairs={{0,1}}")
    assert full.total_bytes == 4 * quant.total_bytes


def test_roofline_terms():
    r = RL.Roofline(flops=197e12, bytes_accessed=819e9,
                    collective_bytes=50e9, compute_s=1.0, memory_s=1.0,
                    collective_s=1.0, model_flops=197e12 * 256, chips=256)
    assert r.bound_s == 1.0
    assert r.useful_ratio == pytest.approx(1.0)
    assert r.mfu_upper_bound == pytest.approx(1.0)


def test_model_flops_for():
    cfg = get_config("llama3.2-3b")
    n = cfg.param_count()
    tr = RL.model_flops_for(cfg, get_input_shape("train_4k"))
    assert tr == pytest.approx(6.0 * n * 256 * 4096)
    de = RL.model_flops_for(cfg, get_input_shape("decode_32k"))
    assert de == pytest.approx(2.0 * n * 128)
    # MoE uses active params only
    moe = get_config("dbrx-132b")
    assert (RL.model_flops_for(moe, get_input_shape("train_4k"))
            == pytest.approx(6.0 * moe.active_param_count() * 256 * 4096))
    assert moe.active_param_count() < 0.5 * moe.param_count()
