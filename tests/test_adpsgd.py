"""AD-PSGD simulation (paper Sec. 5 / Theorem 5; DESIGN §2 asynchrony note)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adpsgd import ADPSGDConfig, run
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.data.synthetic import quadratic_grad

N, D = 6, 16
DELTA = 0.2
OPT = DELTA / 2.0


def _grad(x, i, key):
    return quadratic_grad(x, DELTA, key, sigma=0.05)


def _final_err(quantized: bool, iters=1500, alpha=0.05):
    cfg = ADPSGDConfig(topo=ring(N), codec=MoniquaCodec(QuantSpec(bits=8)),
                       theta=0.5, max_delay=4, quantized=quantized)
    x0 = jnp.zeros((N, D))
    Xf, trace = run(x0, _grad, alpha, iters, cfg, jax.random.PRNGKey(0))
    assert np.isfinite(np.asarray(Xf)).all()
    mean_final = np.asarray(trace[-1])
    return float(np.mean((mean_final - OPT) ** 2)), np.asarray(Xf)


def test_adpsgd_converges_under_staleness():
    err, _ = _final_err(quantized=False)
    assert err < 1e-2


def test_moniqua_adpsgd_matches_full_precision():
    err_fp, _ = _final_err(quantized=False)
    err_q, Xf = _final_err(quantized=True)
    assert err_q < max(3.0 * err_fp, 1e-2)
    # workers stay near consensus despite pairwise-only quantized gossip
    spread = np.abs(Xf - Xf.mean(0, keepdims=True)).max()
    assert spread < 0.25


def test_pairwise_gossip_mixing_condition():
    """Supp. E condition: products of the pairwise W_k mix — for the ring's
    random-edge pair-averaging chain, ||prod W_k mu - 1/n||_1 <= 1/2 within
    a finite t_mix, even though each individual W_k has rho = 1."""
    rng = np.random.RandomState(0)
    n = 6
    offsets = [1, n - 1]
    mu = np.zeros(n)
    mu[0] = 1.0                       # worst-case point mass
    P = np.eye(n)
    t = 0
    while np.abs(P @ mu - 1.0 / n).sum() > 0.5:
        i = rng.randint(n)
        j = (i + offsets[rng.randint(2)]) % n
        W = np.eye(n)
        W[i, i] = W[j, j] = 0.5
        W[i, j] = W[j, i] = 0.5
        P = W @ P
        t += 1
        assert t < 500, "pair-averaging chain failed to mix"
    # t_mix is finite and modest for n=6
    assert t < 200


def test_theorem5_schedule_positive():
    from repro.core import theta as TH
    t_mix = 60
    assert TH.theta_adpsgd(0.05, 1.0, t_mix) == 16 * t_mix * 0.05
    assert 0 < TH.delta_adpsgd(t_mix) < 0.5
