"""Elastic gossip: presence masks, fault injection, deadline rounds.

Engine contracts:
  * ``mix``/``mix_stale``/``pair_average`` with presence=None or all-ones
    are BIT-exact against the pre-elastic round — all five wires, both
    backends, both gossip paths, and the two-tier engine, EF WireState
    carries included;
  * absent workers pass through a round as exact identity (parameters
    AND residuals), and round-health telemetry reports participation /
    dropped gossip edges.

Simulator contracts:
  * faulted traces are replay-deterministic (stable ``fingerprint``,
    participation masks recorded) and a no-fault run is event-identical
    to one with the fault layer absent;
  * deadline-based rounds beat wait-for-stragglers on wall clock;
  * the async loop replays sampled message drops through
    ``pair_average(..., presence=(1, 0))`` deterministically.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.engine import CommEngine, make_wire
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring, two_tier
from repro.sim import events as SE
from repro.sim.cluster import ComputeModel, crash_restart
from repro.sim.faults import FaultSpec, Outage, presence_of
from repro.sim.scenarios import get_scenario

N = 8
THETA = 4.0
WIRES = [("full", 32), ("moniqua", 2), ("qsgd", 4),
         ("ef_qsgd", 4), ("onebit", 1)]
BACKENDS = ("jnp", "pallas")
PATHS = ("bucketed", "per_leaf")


def _engine(wname, bits, backend="jnp", path="bucketed", topo=None,
            telemetry=False):
    spec = QuantSpec(bits=min(bits, 8), stochastic=1 < bits <= 8)
    return CommEngine(topo if topo is not None else ring(N),
                      make_wire(wname, spec, warmup=1), backend, path=path,
                      telemetry=telemetry)


def _tree(n, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": 0.1 * jax.random.normal(k1, (n, 4, 3)),
            "b": 0.1 * jax.random.normal(k2, (n, 5)),
            "s": {"m": 0.1 * jax.random.normal(k3, (n, 2, 2, 2))}}


def _eq(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _rounds(eng, X0, presence, rounds=3):
    X = X0
    state = eng.init_wire_state(X0) if eng.stateful else None
    for r in range(rounds):
        res = eng.mix(X, theta=THETA, key=jax.random.PRNGKey(100 + r),
                      state=state, presence=presence)
        X = res.x
        if eng.stateful:
            state = res.state
    return X, (state if state is not None else {})


# ---------------------------------------------------------------------------
# Full presence is bit-exact (the elastic layer costs nothing when unused).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("path", PATHS)
@pytest.mark.parametrize("wname,bits", WIRES, ids=[w for w, _ in WIRES])
def test_all_ones_presence_bitexact(wname, bits, backend, path):
    eng = _engine(wname, bits, backend, path)
    X0 = _tree(N, jax.random.PRNGKey(7))
    xa, sa = _rounds(eng, X0, None)
    xb, sb = _rounds(eng, X0, (1,) * N)
    _eq(xa, xb)
    _eq(sa, sb)


@pytest.mark.parametrize("wname,bits", WIRES, ids=[w for w, _ in WIRES])
def test_all_ones_presence_bitexact_tiered(wname, bits):
    eng = _engine(wname, bits, topo=two_tier(N, 2))
    X0 = _tree(N, jax.random.PRNGKey(7))
    xa, sa = _rounds(eng, X0, None)
    xb, sb = _rounds(eng, X0, (1,) * (N // 2))  # per-NODE mask
    _eq(xa, xb)
    _eq(sa, sb)


def test_mix_stale_all_ones_presence_bitexact():
    eng = _engine("moniqua", 4)
    X0 = _tree(N, jax.random.PRNGKey(3))
    outs = []
    for presence in (None, (1,) * N):
        X, carry = X0, eng.init_gossip_carry(X0)
        for r in range(3):
            res = eng.mix_stale(X, carry, theta=THETA,
                                key=jax.random.PRNGKey(50 + r),
                                presence=presence)
            X, carry = res.x, res.state
        outs.append(X)
    _eq(outs[0], outs[1])


# ---------------------------------------------------------------------------
# Absent workers are exact identity — parameters AND EF residuals.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wname,bits", WIRES, ids=[w for w, _ in WIRES])
def test_absent_workers_exact_identity(wname, bits):
    eng = _engine(wname, bits)
    X0 = _tree(N, jax.random.PRNGKey(11))
    absent = (2, 5)
    presence = tuple(0 if i in absent else 1 for i in range(N))
    state0 = eng.init_wire_state(X0) if eng.stateful else None
    res = eng.mix(X0, theta=THETA, key=jax.random.PRNGKey(0),
                  state=state0, presence=presence)
    for a, b in zip(jax.tree.leaves(res.x), jax.tree.leaves(X0)):
        for i in absent:
            np.testing.assert_array_equal(np.asarray(a)[i],
                                          np.asarray(b)[i])
    if eng.stateful:
        # an absent worker's residual (worker axis 0) must not advance;
        # present workers' residuals must have moved off the zero init
        for a, b in zip(jax.tree.leaves(res.state),
                        jax.tree.leaves(state0)):
            a, b = np.asarray(a), np.asarray(b)
            if a.ndim and a.shape[0] == N:
                for i in absent:
                    np.testing.assert_array_equal(a[i], b[i])


def test_mixed_mean_conserved_under_mask():
    """W' doubly stochastic => the stacked mean is conserved exactly for
    the full-precision wire, whoever is absent."""
    eng = _engine("full", 32)
    X0 = _tree(N, jax.random.PRNGKey(13))
    res = eng.mix(X0, presence=(1, 0, 1, 1, 0, 1, 1, 1))
    for a, b in zip(jax.tree.leaves(res.x), jax.tree.leaves(X0)):
        np.testing.assert_allclose(np.asarray(a).mean(axis=0),
                                   np.asarray(b).mean(axis=0),
                                   rtol=0, atol=1e-6)


def test_health_reports_participation_and_dropped_edges():
    eng = _engine("moniqua", 4, telemetry=True)
    X0 = _tree(N, jax.random.PRNGKey(17))
    res = eng.mix(X0, theta=THETA, key=jax.random.PRNGKey(1),
                  presence=(1, 1, 1, 0, 1, 1, 1, 1))
    assert res.health is not None
    assert float(res.health["participation"]) == pytest.approx(7.0 / 8.0)
    assert float(res.health["dropped_neighbors"]) > 0
    full = eng.mix(X0, theta=THETA, key=jax.random.PRNGKey(1))
    assert float(full.health["participation"]) == 1.0
    assert float(full.health["dropped_neighbors"]) == 0


@pytest.mark.parametrize("wname,bits",
                         [("full", 32), ("moniqua", 2), ("ef_qsgd", 4)],
                         ids=["full", "moniqua", "ef_qsgd"])
def test_pair_average_presence_identity(wname, bits):
    eng = _engine(wname, bits)
    key = jax.random.PRNGKey(5)
    ki, kj = jax.random.split(key)
    xi = jax.random.normal(ki, (6,))
    xj = xi + 0.5 + 0.5 * jax.random.normal(kj, (6,))
    kw = {}
    if eng.stateful:
        kw = dict(state_i=eng.init_edge_state(xi),
                  state_j=eng.init_edge_state(xj))
    for presence in ((1, 0), (0, 1), (0, 0)):
        res = eng.pair_average(xi, xj, theta=THETA,
                               key=jax.random.PRNGKey(2),
                               presence=presence, **kw)
        np.testing.assert_array_equal(np.asarray(res.xi), np.asarray(xi))
        np.testing.assert_array_equal(np.asarray(res.xj), np.asarray(xj))
        if eng.stateful:
            _eq(res.state_i, kw["state_i"])
            _eq(res.state_j, kw["state_j"])
    # all-present exchanges DO move the endpoints
    res = eng.pair_average(xi, xj, theta=THETA, key=jax.random.PRNGKey(2),
                           presence=(1, 1), **kw)
    assert not np.array_equal(np.asarray(res.xi), np.asarray(xi))


# ---------------------------------------------------------------------------
# Fault layer: pure predicates, deterministic traces.
# ---------------------------------------------------------------------------

def test_crash_restart_offline_window():
    comp = crash_restart(0.05, outage_p=0.2, outage_rounds=3)
    seed = 9
    for w in range(4):
        onsets = [k for k in range(40)
                  if dc.replace(comp, outage_rounds=1).offline(w, k, seed)]
        for k in range(40):
            expect = any(k - 2 <= j <= k for j in onsets)
            assert comp.offline(w, k, seed) == expect
    assert not ComputeModel(base_s=0.05).offline(0, 0, seed)


def test_scheduled_outage_covers_exact_rounds():
    faults = FaultSpec(outages=(Outage(worker=2, start=5, rounds=3),))
    comp = ComputeModel(base_s=0.05)
    down = [k for k in range(12) if faults.offline(2, k, comp, seed=0)]
    assert down == [5, 6, 7]
    assert not any(faults.offline(1, k, comp, seed=0) for k in range(12))


def test_presence_of_none_when_everyone_up():
    comp = ComputeModel(base_s=0.05)
    assert presence_of(None, comp, N, 0, seed=0) is None
    assert presence_of(FaultSpec(drop_p=0.5), comp, N, 0, seed=0) is None
    faults = FaultSpec(outages=(Outage(worker=1, start=0, rounds=1),))
    assert presence_of(faults, comp, N, 0, seed=0) == \
        (1, 0, 1, 1, 1, 1, 1, 1)


def test_message_drop_is_deterministic_and_validated():
    f = FaultSpec(drop_p=0.3)
    draws = [f.message_dropped(k, 0, 1, seed=4) for k in range(200)]
    assert draws == [f.message_dropped(k, 0, 1, seed=4) for k in range(200)]
    assert 20 < sum(draws) < 100  # ~60 expected
    with pytest.raises(ValueError):
        FaultSpec(drop_p=1.5)
    with pytest.raises(ValueError):
        FaultSpec(deadline_s=0.0)
    with pytest.raises(ValueError):
        Outage(worker=0, start=0, rounds=0)


def test_no_fault_sim_is_event_identical():
    sc = get_scenario("lan-10gbe-ring", n=N)
    a = SE.simulate_sync_rounds(sc, 1024, 12)
    b = SE.simulate_sync_rounds(sc.with_faults(None), 1024, 12)
    assert a.fingerprint() == b.fingerprint()
    assert a.participation == [] and a.presence == []
    assert a.participation_mean == 1.0


def test_churn_ring_trace_deterministic_with_participation():
    sc = get_scenario("churn-ring", n=N, outage_p=0.1, outage_rounds=2,
                      drop_p=0.05)
    a = SE.simulate_sync_rounds(sc, 2048, 30)
    b = SE.simulate_sync_rounds(sc, 2048, 30)
    assert a.fingerprint() == b.fingerprint()
    assert len(a.presence) == 30 and len(a.participation) == 30
    assert 0.5 < a.participation_mean < 1.0
    kinds = {e.kind for e in a.events}
    assert SE.OFFLINE in kinds
    # presence masks match the offline events round for round
    for k, mask in enumerate(a.presence):
        off = {e.worker for e in a.events
               if e.kind == SE.OFFLINE and e.step == k}
        assert off == {i for i in range(N) if not mask[i]}


def test_deadline_rounds_beat_waiting_for_stragglers():
    sc = get_scenario("straggler-longtail", n=N)
    rounds = 40
    wait = SE.simulate_sync_rounds(sc, 1024, rounds)
    dl = SE.simulate_sync_rounds(sc.with_deadline(0.25), 1024, rounds)
    assert dl.total_seconds < wait.total_seconds
    # the deadline caps every barrier the straggler would have stalled
    assert max(dl.round_seconds) < max(wait.round_seconds)
    assert any(e.kind == SE.DROPPED for e in dl.events)
    assert 0.0 < dl.participation_mean < 1.0
    assert wait.fingerprint() != dl.fingerprint()


def test_straggler_kwargs_passthrough_and_unknown_rejected():
    sc = get_scenario("straggler-longtail", n=N, worker=3, slow=8.0)
    assert sc.compute.multiplier(3) == 8.0
    with pytest.raises(TypeError):
        get_scenario("straggler-longtail", n=N, nope=1)


def test_async_replay_with_drops_is_deterministic():
    sc = get_scenario("lan-10gbe-ring", n=4).with_faults(
        FaultSpec(drop_p=0.4))
    eng = _engine("moniqua", 4, topo=ring(4))

    def grad(x, i, key):
        return 0.1 * x

    outs = []
    for _ in range(2):
        X0 = jnp.stack([jnp.full((6,), float(i)) for i in range(4)])
        out = SE.replay_adpsgd(sc, eng, X0, grad, alpha=0.05,
                               num_updates=25, theta=THETA)
        outs.append((out["trace"].fingerprint(), np.asarray(out["X"])))
    assert outs[0][0] == outs[1][0]
    np.testing.assert_array_equal(outs[0][1], outs[1][1])
    kinds = {e.kind for e in SE.replay_adpsgd(
        sc, eng, jnp.zeros((4, 6)), grad, alpha=0.05, num_updates=25,
        theta=THETA)["trace"].events}
    # sampled losses fire the identity exchange, the rest gossip for real
    assert SE.MSGDROP in kinds and SE.GOSSIP in kinds
