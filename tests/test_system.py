"""System-level invariants spanning layers (paper claims C2/C8 end-to-end).

Train a tiny LM under four update rules with identical data/seeds and check
(1) all converge except naive stalls, (2) the bytes ledger matches Table 1's
bandwidth ordering, (3) quantized kernel path == jnp path semantics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig

SHAPE = InputShape("sys", seq_len=16, global_batch=8, kind="train")


def _model():
    cfg = get_config("llama3.2-3b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=1, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=64)
    return build_model(cfg)


@pytest.fixture(scope="module")
def runs():
    model = _model()
    out = {}
    for algo, bits in [("dpsgd", 8), ("moniqua", 8), ("moniqua", 2),
                       ("choco", 8)]:
        coarse = (algo, bits) == ("moniqua", 2)
        tc = TrainerConfig(algo=algo, n_workers=4, bits=bits,
                           # Theorem 3: coarse budgets need the slack matrix
                           # and a theta tight to the actual consensus gap
                           theta=0.25 if coarse else 2.0,
                           slack=0.2 if coarse else 1.0,
                           lr=0.3, steps=25, log_every=25, momentum=0.0,
                           weight_decay=0.0, seed=7,
                           gamma=0.3 if algo == "choco" else 1.0)
        out[f"{algo}-{bits}"] = Trainer(model, SHAPE, tc).run()
    return out


def test_all_rules_learn(runs):
    for name, r in runs.items():
        first, last = r["history"][0]["loss"], r["history"][-1]["loss"]
        assert np.isfinite(last), name
        assert last < first, name


def test_moniqua_tracks_dpsgd_loss(runs):
    l_fp = runs["dpsgd-8"]["history"][-1]["loss"]
    l_q8 = runs["moniqua-8"]["history"][-1]["loss"]
    assert abs(l_q8 - l_fp) < 0.3 * l_fp


def test_bandwidth_ordering(runs):
    """Wire bytes: moniqua-2 < moniqua-8 < dpsgd (full precision)."""
    b_fp = runs["dpsgd-8"]["bytes_per_step"]
    b_8 = runs["moniqua-8"]["bytes_per_step"]
    b_2 = runs["moniqua-2"]["bytes_per_step"]
    assert b_2 < b_8 < b_fp
    assert b_8 == b_fp // 4
    assert b_2 == b_fp // 16


def test_pallas_codec_path_equivalent_semantics():
    """MoniquaCodec(use_pallas=True) obeys the same Lemma-2 bound as the
    jnp path (RNG differs, bound must hold for both)."""
    theta = 2.0
    for use_pallas in (False, True):
        codec = MoniquaCodec(QuantSpec(bits=4, stochastic=True),
                             use_pallas=use_pallas)
        y = jax.random.normal(jax.random.PRNGKey(0), (33, 65)) * 4.0
        x = y + jax.random.uniform(jax.random.PRNGKey(1), y.shape,
                                   minval=-0.95, maxval=0.95) * theta
        p = codec.encode(x, theta, jax.random.PRNGKey(2))
        assert p.dtype == jnp.uint8
        xh = codec.decode(p, y, theta)
        err = float(jnp.max(jnp.abs(xh - x)))
        assert err <= codec.max_error(theta) * (1 + 1e-3), use_pallas
