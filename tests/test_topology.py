"""Topology invariants: W symmetric doubly stochastic, spectral gap, slack."""
import numpy as np
import pytest

from repro.core.topology import (Topology, exponential, fully_connected,
                                 get_topology, ring, torus)

ALL = [ring(8), ring(16), ring(2), ring(1), torus(4, 4), exponential(8),
       exponential(16), exponential(10), fully_connected(6)]


@pytest.mark.parametrize("topo", ALL, ids=lambda t: f"{t.name}-{t.n}")
def test_doubly_stochastic_symmetric(topo):
    W = topo.matrix
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-9)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-9)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    assert (W >= -1e-12).all()


@pytest.mark.parametrize("topo", [t for t in ALL if t.n > 1],
                         ids=lambda t: f"{t.name}-{t.n}")
def test_spectral_gap(topo):
    """Assumption A2: rho < 1 for connected circulant graphs."""
    assert 0.0 <= topo.rho < 1.0
    assert topo.t_mix_bound < np.inf


def test_exponential_beats_ring_rho():
    # exponential graph mixes faster (smaller rho) at the same n
    assert exponential(16).rho < ring(16).rho


# --- exponential() offset-construction regressions (explicit dedupe loop) ---

EXP_NS = [2, 3, 4, 6, 8, 16]


@pytest.mark.parametrize("n", EXP_NS)
def test_exponential_doubly_stochastic_symmetric(n):
    W = exponential(n).matrix
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-9)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-9)
    np.testing.assert_allclose(W, W.T, atol=1e-12)


@pytest.mark.parametrize("n", EXP_NS)
def test_exponential_offsets_symmetric_and_deduped(n):
    topo = exponential(n)
    residues = [o % n for o in topo.offsets]
    # no offset appears twice mod n (the n/2 self-inverse hop in particular)
    assert len(residues) == len(set(residues))
    # symmetric: -o present (mod n) for every o
    assert {(-r) % n for r in residues} == set(residues)


@pytest.mark.parametrize("n", EXP_NS)
def test_exponential_expected_offsets(n):
    """Hops are exactly {0, ±2^j : 2^j <= n/2} deduped mod n."""
    expected = {0}
    h = 1
    while h <= n // 2:
        expected |= {h % n, (-h) % n}
        h *= 2
    assert {o % n for o in exponential(n).offsets} == expected


@pytest.mark.parametrize("n", EXP_NS)
def test_exponential_rho_no_worse_than_ring(n):
    """Denser 2^j hops must not mix slower than the ring; strictly faster
    once the graphs actually differ (n >= 6)."""
    e, r = exponential(n).rho, ring(n).rho
    assert e <= r + 1e-12
    if n >= 6:
        assert e < r - 1e-9


def test_slack_matrix():
    """Theorem 3: W_bar = gamma W + (1-gamma) I stays doubly stochastic and
    its spectral gap scales as 1 - gamma (1 - rho)."""
    topo = ring(8)
    gamma = 0.25
    s = topo.slack(gamma)
    np.testing.assert_allclose(s.matrix, gamma * topo.matrix
                               + (1 - gamma) * np.eye(8), atol=1e-12)
    assert s.rho == pytest.approx(1.0 - gamma * (1.0 - topo.rho), abs=1e-9)


def test_phi_smallest_entry():
    assert ring(8).phi == pytest.approx(1.0 / 3.0)
    assert fully_connected(6).phi == pytest.approx(1.0 / 6.0)


def test_asymmetric_rejected():
    with pytest.raises(ValueError):
        Topology("bad", 4, (0, 1), (0.5, 0.5))   # +1 without -1


def test_get_topology_dispatch():
    assert get_topology("ring", 8).name == "ring"
    assert get_topology("torus", 16).n == 16
    assert get_topology("exponential", 8).name == "exponential"
    with pytest.raises(ValueError):
        get_topology("nope", 4)
    with pytest.raises(ValueError):
        get_topology("torus", 15)


# -- two-tier hierarchy (kron composition) ----------------------------------

def test_two_tier_kron_doubly_stochastic_and_rho_identity():
    """kron(W_inter, W_intra) stays doubly stochastic and its rho is the
    max of the tier rhos (eigenvalues of a kron are pairwise products)."""
    from repro.core.topology import two_tier
    hier = two_tier(32, 4)
    W = hier.matrix
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-9)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-9)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    assert hier.rho == pytest.approx(
        max(hier.intra.rho, hier.inter.rho), abs=1e-9)


@pytest.mark.parametrize("n_inter,n_intra,expected", [
    # ring-of-rings: rho = max(rho(ring(n_inter)), rho(ring(n_intra)))
    (4, 2, 1.0 / 3.0),
    (4, 4, 1.0 / 3.0),
    (8, 4, 1.0 / 3.0 + (2.0 / 3.0) * np.cos(np.pi / 4.0)),
], ids=["4x2", "4x4", "8x4"])
def test_two_tier_ring_of_rings_rho_regression(n_inter, n_intra, expected):
    """Closed-form ring eigenvalues 1/3 + (2/3) cos(2 pi k / n) pin the
    numerically computed kron rho — a regression against the spectral-gap
    math the theta schedule and t_mix bounds consume."""
    from repro.core.topology import ring, two_tier
    hier = two_tier(n_inter * n_intra, n_intra, intra=ring(n_intra))
    assert hier.name == f"ring{n_inter}xring{n_intra}"
    assert hier.rho == pytest.approx(expected, abs=1e-9)
    assert hier.t_mix_bound == pytest.approx(
        np.log(4 * n_inter * n_intra) / (1.0 - expected), rel=1e-9)


# -- elastic rounds: presence renormalization + time-varying schedules ------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # not in the baked image; deterministic twins below
    HAVE_HYPOTHESIS = False

MASKS = [(1,) * 8, (0,) * 8, (1, 0, 1, 0, 1, 0, 1, 0),
         (0, 1, 1, 1, 1, 1, 1, 1), (1, 1, 1, 1, 0, 0, 0, 0),
         (0, 0, 0, 0, 0, 0, 0, 1)]


def _check_masked(topo, mask):
    W = topo.with_presence(mask).matrix
    np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-9)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-9)
    np.testing.assert_allclose(W, W.T, atol=1e-12)
    assert (W >= -1e-12).all()
    for i, p in enumerate(mask):
        if not p:
            # absent worker: row is EXACTLY the identity, not approximately
            expect = np.zeros(topo.n)
            expect[i] = 1.0
            np.testing.assert_array_equal(W[i], expect)


@pytest.mark.parametrize("topo", [ring(8), exponential(8),
                                  fully_connected(8)],
                         ids=lambda t: t.name)
@pytest.mark.parametrize("mask", MASKS, ids=lambda m: "".join(map(str, m)))
def test_with_presence_doubly_stochastic_any_mask(topo, mask):
    _check_masked(topo, mask)


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="hypothesis not installed in this image")
def test_with_presence_doubly_stochastic_property():
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.integers(0, 1), min_size=8, max_size=8),
           st.sampled_from(["ring", "exponential", "fully"]))
    def prop(mask, name):
        topo = {"ring": ring(8), "exponential": exponential(8),
                "fully": fully_connected(8)}[name]
        _check_masked(topo, tuple(mask))

    prop()


@pytest.mark.parametrize("topo", [ring(8), exponential(8), torus(4, 4)],
                         ids=lambda t: t.name)
def test_full_presence_is_original_matrix_exact(topo):
    np.testing.assert_array_equal(
        topo.with_presence((1,) * topo.n).matrix, topo.matrix)


def test_with_presence_rho_monotone_in_participation():
    """Dropping one more worker from a ring never improves the spectral
    gap (PSD interlacing on W' - J/n): rho is monotone non-decreasing as
    participation falls along a nested chain of masks."""
    topo = ring(8)
    rhos = []
    mask = [1] * 8
    for drop in (None, 6, 3, 1):
        if drop is not None:
            mask[drop] = 0
        rhos.append(topo.with_presence(tuple(mask)).rho)
    assert rhos[0] == pytest.approx(topo.rho, abs=1e-12)
    for a, b in zip(rhos, rhos[1:]):
        assert b >= a - 1e-9
    assert rhos[-1] > rhos[0]


def test_time_varying_topology_joint_rho():
    from repro.core.topology import TimeVaryingTopology
    topo = ring(8)
    # alternating complementary half-participation rounds: each matrix
    # alone has rho = 1 (disconnected), the WINDOW still contracts
    a = topo.with_presence((1, 1, 1, 1, 1, 1, 0, 1))
    b = topo.with_presence((1, 0, 1, 1, 1, 1, 1, 1))
    tv = TimeVaryingTopology((a, b))
    assert tv.n == 8
    assert tv.at(0) is a and tv.at(1) is b and tv.at(2) is a
    assert 0.0 < tv.rho < 1.0
    # full-presence schedule degenerates to the static topology's rho
    full = TimeVaryingTopology((topo, topo))
    assert full.rho == pytest.approx(topo.rho, abs=1e-9)
