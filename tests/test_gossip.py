"""Gossip layer: circulant mix == dense X W, Moniqua gossip error bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import gossip
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import exponential, ring, torus


@pytest.mark.parametrize("topo", [ring(8), torus(3, 3), exponential(8)],
                         ids=lambda t: t.name)
def test_mix_equals_dense_matmul(topo):
    """roll-gossip must equal the dense W X product (W symmetric)."""
    X = jax.random.normal(jax.random.PRNGKey(0), (topo.n, 13))
    mixed = gossip.mix({"w": X}, topo)["w"]
    dense = jnp.asarray(topo.matrix, jnp.float32) @ X
    np.testing.assert_allclose(np.asarray(mixed), np.asarray(dense),
                               rtol=1e-5, atol=1e-6)


def test_mix_preserves_mean():
    """Doubly stochastic W preserves the worker average exactly."""
    topo = ring(8)
    X = jax.random.normal(jax.random.PRNGKey(1), (8, 31))
    mixed = gossip.mix({"w": X}, topo)["w"]
    np.testing.assert_allclose(np.asarray(mixed.mean(0)),
                               np.asarray(X.mean(0)), atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_moniqua_gossip_close_to_exact_mix(bits):
    """One Moniqua round deviates from full-precision mixing by at most
    O(delta * B) per coordinate (each of the <= 2 neighbor terms and the self
    term contributes <= delta*B, weighted)."""
    topo = ring(8)
    theta = 1.0
    spec = QuantSpec(bits=bits, stochastic=True)
    codec = MoniquaCodec(spec)
    key = jax.random.PRNGKey(0)
    base = jax.random.normal(key, (1, 64)) * 10.0
    X = base + jax.random.uniform(jax.random.PRNGKey(1), (8, 64),
                                  minval=-0.45, maxval=0.45) * theta
    out = gossip.moniqua_gossip(X, topo, codec, theta, jax.random.PRNGKey(2))
    exact = gossip.mix(X, topo)
    B = float(codec.b_theta(theta))
    tol = 2.0 * spec.delta * B + 1e-4    # 2 terms of delta*B worst case
    assert float(jnp.max(jnp.abs(out - exact))) <= tol


def test_moniqua_gossip_mean_shift_is_noise_only():
    """Line-4 bias cancellation: the gossip perturbs the global mean only by
    the *difference* of reconstruction errors, not their sum."""
    topo = ring(8)
    theta = 1.0
    codec = MoniquaCodec(QuantSpec(bits=8, stochastic=True))
    X = jax.random.uniform(jax.random.PRNGKey(3), (8, 256),
                           minval=-0.4, maxval=0.4)
    out = gossip.moniqua_gossip(X, topo, codec, theta, jax.random.PRNGKey(4))
    drift = float(jnp.max(jnp.abs(out.mean(0) - X.mean(0))))
    B = float(codec.b_theta(theta))
    assert drift <= 2 * codec.delta * B   # individual-error scale, not n x


def test_single_worker_gossip_is_identity():
    topo = ring(1)
    codec = MoniquaCodec(QuantSpec(bits=8))
    X = jnp.ones((1, 8))
    out = gossip.moniqua_gossip(X, topo, codec, 1.0, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(X))


def test_payload_accounting():
    codec = MoniquaCodec(QuantSpec(bits=2))
    X = {"a": jnp.zeros((4, 10, 100)), "b": jnp.zeros((4, 7))}
    per_worker = gossip.payload_bytes_tree(X, codec)
    assert per_worker == 10 * 25 + 2          # ceil(100/4)=25, ceil(7/4)=2
    assert gossip.dtype_bytes_tree(X) == (10 * 100 + 7) * 4


def test_ledger():
    topo = ring(4)
    codec = MoniquaCodec(QuantSpec(bits=8))
    led = gossip.BytesLedger()
    X = jnp.zeros((4, 16))
    gossip.moniqua_gossip(X, topo, codec, 1.0, jax.random.PRNGKey(0),
                          ledger=led)
    assert led.bytes_per_worker == 16 * 2     # 16 bytes payload x 2 neighbors
