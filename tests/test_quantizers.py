"""Quantizer properties: Eq. 2 bound, packing, shared randomness (Supp. C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in the baked image
from hypothesis import given, settings, strategies as st

from repro.core.quantizers import (QuantSpec, bits_for_delta, delta_for_bits,
                                   dequantize_codes, pack_codes, quantize,
                                   quantize_codes, unpack_codes)


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("stochastic", [False, True])
def test_eq2_bounded_error(bits, stochastic):
    """||Q(x) - x||_inf <= delta on [-1/2, 1/2] (the paper's Eq. 2)."""
    spec = QuantSpec(bits=bits, stochastic=stochastic)
    x = jnp.linspace(-0.5, 0.5, 4097, dtype=jnp.float32)
    key = jax.random.PRNGKey(3) if stochastic else None
    q = quantize(x, spec, key)
    err = float(jnp.max(jnp.abs(q - x)))
    assert err <= spec.delta + 1e-6


def test_delta_for_bits_values():
    assert delta_for_bits(1, stochastic=False) == pytest.approx(0.25)
    assert delta_for_bits(1, stochastic=True) == pytest.approx(0.5)
    assert delta_for_bits(8, stochastic=False) == pytest.approx(1 / 512)
    # 1-bit nearest satisfies Theorem 3's delta < 1/2 requirement
    assert delta_for_bits(1, stochastic=False) < 0.5


def test_bits_for_delta_roundtrip():
    # Sec. 4: B <= ceil(log2(1/(2 delta) + 1)) is an UPPER bound (it covers
    # the endpoint lattice {2 delta n}); our midpoint lattice achieves the
    # same delta with at most one bit less.
    for bits in (1, 2, 4, 8):
        b = bits_for_delta(delta_for_bits(bits, stochastic=False))
        assert bits <= b <= bits + 1
    # monotone: finer delta needs more bits
    assert bits_for_delta(0.25) <= bits_for_delta(0.01)


def test_stochastic_unbiased():
    spec = QuantSpec(bits=2, stochastic=True)
    x = jnp.full((200_000,), 0.1234, jnp.float32)
    q = quantize(x, spec, jax.random.PRNGKey(0))
    assert float(jnp.mean(q) - 0.1234) == pytest.approx(0.0, abs=2e-3)


@settings(max_examples=60, deadline=None)
@given(bits=st.sampled_from([1, 2, 4, 8]),
       lead=st.integers(min_value=1, max_value=5),
       last=st.integers(min_value=1, max_value=97))
def test_pack_unpack_roundtrip(bits, lead, last):
    rng = np.random.RandomState(bits * 1000 + lead * 100 + last)
    codes = jnp.asarray(rng.randint(0, 2 ** bits, size=(lead, last)),
                        dtype=jnp.uint8)
    packed = pack_codes(codes, bits)
    assert packed.dtype == jnp.uint8
    vpb = 8 // bits
    assert packed.shape[-1] == -(-last // vpb)   # exact wire size
    out = unpack_codes(packed, bits, last)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_payload_compression_ratio():
    """bits/8 bytes per parameter — the bandwidth saving the paper claims."""
    from repro.core.moniqua import MoniquaCodec
    shape = (1024, 1024)
    full = int(np.prod(shape)) * 4       # f32 wire bytes
    for bits in (1, 2, 4, 8):
        codec = MoniquaCodec(QuantSpec(bits=bits))
        assert codec.payload_bytes(shape) == full * bits // 32


def test_shared_randomness_reduces_pair_error():
    """Supp. C: with the same u on both workers,
    E|(Q(x)-x)-(Q(y)-y)|^2 == E|Q(y-x)-(y-x)|^2  <= sqrt(d) delta E||x-y||,
    which vanishes as x -> y; with independent u it stays ~2 Var[Q].
    """
    spec = QuantSpec(bits=4, stochastic=True, shared_randomness=True)
    d = 50_000
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (d,), minval=-0.45, maxval=0.45)
    y = x + 1e-4 * jax.random.normal(jax.random.PRNGKey(1), (d,))  # near consensus

    k_shared = jax.random.PRNGKey(42)
    qx_s = quantize(x, spec, k_shared)
    qy_s = quantize(y, spec, k_shared)          # same u
    qy_i = quantize(y, spec, jax.random.PRNGKey(43))  # independent u

    err_shared = float(jnp.mean(((qx_s - x) - (qy_s - y)) ** 2))
    err_indep = float(jnp.mean(((qx_s - x) - (qy_i - y)) ** 2))
    assert err_shared < err_indep / 20.0

    # quantitative Supp. C scale: E r^2 ~ delta * E|y - x| element-wise
    # (bound is per-element E r_h^2 <= delta |Delta_h|; sampling noise over a
    # finite mean warrants modest slack)
    assert err_shared <= spec.delta * float(jnp.mean(jnp.abs(y - x))) * 1.5


def test_rounding_key_shared_vs_private():
    from repro.core.quantizers import rounding_key
    base = jax.random.PRNGKey(0)
    shared = QuantSpec(shared_randomness=True)
    private = QuantSpec(shared_randomness=False)
    k0 = rounding_key(base, 3, worker=0, spec=shared)
    k1 = rounding_key(base, 3, worker=1, spec=shared)
    assert (jax.random.key_data(k0) == jax.random.key_data(k1)).all()
    p0 = rounding_key(base, 3, worker=0, spec=private)
    p1 = rounding_key(base, 3, worker=1, spec=private)
    assert not (jax.random.key_data(p0) == jax.random.key_data(p1)).all()
