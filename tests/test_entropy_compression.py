"""Paper Sec. 6 'More efficient Moniqua': the modulo wrap leaves redundancy
in the higher-order bits of near-consensus payloads that a standard entropy
coder (the paper suggests bzip; zlib here) removes — verified empirically.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec


def _ratio(payload: bytes) -> float:
    return len(zlib.compress(payload, 6)) / max(len(payload), 1)


def test_near_consensus_payload_is_compressible():
    """Workers near consensus: residues cluster -> low entropy -> zlib wins.
    theta is an upper bound, so actual |x - y| << theta concentrates the
    quantized residues on few code values."""
    codec = MoniquaCodec(QuantSpec(bits=8, stochastic=True))
    theta = 2.0
    base = jax.random.normal(jax.random.PRNGKey(0), (64, 1024)) * 5.0
    x = base + 0.02 * jax.random.normal(jax.random.PRNGKey(1), base.shape)
    packed = codec.encode(x - base, theta, jax.random.PRNGKey(2))
    ratio = _ratio(np.asarray(packed).tobytes())
    assert ratio < 0.75, ratio           # clearly compressible

    # far from consensus (residues ~ uniform over the lattice): incompressible
    y = jax.random.uniform(jax.random.PRNGKey(3), base.shape,
                           minval=-50.0, maxval=50.0)
    packed_u = codec.encode(y, theta, jax.random.PRNGKey(4))
    ratio_u = _ratio(np.asarray(packed_u).tobytes())
    assert ratio_u > 0.95, ratio_u


def test_compression_stacks_with_bit_packing():
    """Entropy coding composes with the wire format: total bytes =
    ratio * bits/32 of f32 — strictly better than either alone."""
    codec = MoniquaCodec(QuantSpec(bits=4, stochastic=True))
    theta = 1.0
    x = 0.01 * jax.random.normal(jax.random.PRNGKey(0), (32, 4096))
    packed = np.asarray(codec.encode(x, theta, jax.random.PRNGKey(1)))
    f32_bytes = x.size * 4
    wire = len(zlib.compress(packed.tobytes(), 6))
    assert wire < packed.nbytes <= f32_bytes // 8
