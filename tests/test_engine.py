"""CommEngine: codec/backend parity, fused decode-reduce, bytes accounting.

The four contracts from the engine design (docs/architecture.md):

1. ``CommEngine(full_precision).mix == gossip.mix`` exactly (the engine's
   full-precision round IS the circulant ``X W``).
2. ``CommEngine(moniqua, pallas)`` (interpret off-TPU) is **bit-exact** with
   ``CommEngine(moniqua, jnp)`` — same counter-hash randomness, same fenced
   per-element math (kernels/moniqua_decode_reduce.py documents why the jnp
   path is compared as written, i.e. eagerly; under re-jit XLA may legally
   FMA-contract and drift by 1 ulp, checked separately with a tight bound).
3. BytesLedger: 1-bit Moniqua payloads are exactly 1/32 of f32 bytes.
4. ``CommEngine(path="bucketed")`` (the default flat-buffer round,
   comm/bucket.py) is **bit-exact** with ``path="per_leaf"`` for the
   Moniqua wire — same payload bits, same mixed output — on both
   backends, and its bytes accounting (bytes_per_round == ledger == the
   bytes the simulator prices) matches the per-leaf sum.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import gossip
from repro.comm.engine import (CommEngine, FullPrecisionWire, MoniquaWire,
                               QSGDWire, make_wire)
from repro.core import modulo
from repro.core.quantizers import QuantSpec
from repro.core.topology import exponential, ring

BITS = [1, 2, 4, 8]


def _stacked(scale=0.3, n=8, d=300, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * scale


# ---------------------------------------------------------------------------
# 1. full-precision parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", [ring(8), exponential(8)],
                         ids=lambda t: t.name)
def test_full_precision_equals_gossip_mix(topo):
    X = {"w": _stacked(), "b": _stacked(d=17, seed=1)}
    eng = CommEngine(topo, FullPrecisionWire())
    out = eng.mix(X).x
    ref = gossip.mix(X, topo)
    for k in X:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[k]))


# ---------------------------------------------------------------------------
# 2. moniqua backend parity (pallas interpret vs pure jnp)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("topo", [ring(8), exponential(8)],
                         ids=lambda t: t.name)
def test_moniqua_pallas_vs_jnp_bit_exact(bits, topo):
    spec = QuantSpec(bits=bits, stochastic=bits > 1)
    X = _stacked()
    key = jax.random.PRNGKey(3)
    a = CommEngine(topo, MoniquaWire(spec), backend="jnp").mix(
        X, theta=2.0, key=key).x
    b = CommEngine(topo, MoniquaWire(spec), backend="pallas").mix(
        X, theta=2.0, key=key).x
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("bits", [1, 4])
def test_moniqua_parity_on_pytrees(bits):
    spec = QuantSpec(bits=bits, stochastic=bits > 1)
    X = {"w": _stacked(), "b": _stacked(d=17, seed=7).reshape(8, 17)}
    key = jax.random.PRNGKey(1)
    a = CommEngine(ring(8), MoniquaWire(spec), backend="jnp").mix(
        X, theta=2.0, key=key).x
    b = CommEngine(ring(8), MoniquaWire(spec), backend="pallas").mix(
        X, theta=2.0, key=key).x
    for k in X:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_moniqua_parity_under_jit_close():
    """Re-jitting the jnp path lets XLA contract FMAs: bounded by ~1 ulp."""
    spec = QuantSpec(bits=4)
    X = _stacked()
    key = jax.random.PRNGKey(3)
    ej = CommEngine(ring(8), MoniquaWire(spec), backend="jnp")
    b = CommEngine(ring(8), MoniquaWire(spec), backend="pallas").mix(
        X, theta=2.0, key=key).x
    aj = jax.jit(lambda x, k: ej.mix(x, theta=2.0, key=k).x)(X, key)
    np.testing.assert_allclose(np.asarray(aj), np.asarray(b),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_moniqua_engine_close_to_exact_mix(bits):
    """One engine round deviates from full-precision mixing by O(delta*B).

    Valid only under the a-priori bound |x_i - x_j| < theta, so workers are
    bounded perturbations of a common base model (as in test_gossip).
    """
    topo = ring(8)
    theta = 1.0
    spec = QuantSpec(bits=bits, stochastic=True)
    base = jax.random.normal(jax.random.PRNGKey(0), (1, 300)) * 10.0
    X = base + jax.random.uniform(jax.random.PRNGKey(1), (8, 300),
                                  minval=-0.45, maxval=0.45) * theta
    out = CommEngine(topo, MoniquaWire(spec), backend="jnp").mix(
        X, theta=theta, key=jax.random.PRNGKey(2)).x
    exact = gossip.mix(X, topo)
    B = float(modulo.b_theta(theta, spec.delta))
    assert float(jnp.max(jnp.abs(out - exact))) <= 2.0 * spec.delta * B + 1e-4


def test_single_worker_is_identity():
    eng = CommEngine(ring(1), MoniquaWire(QuantSpec(bits=8)))
    X = jnp.ones((1, 16))
    np.testing.assert_array_equal(
        np.asarray(eng.mix(X, theta=1.0, key=jax.random.PRNGKey(0)).x),
        np.asarray(X))


# ---------------------------------------------------------------------------
# per-worker tiling (stacked wrappers in kernels/ops.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_stacked_encode_is_per_worker(backend):
    """Each worker's payload equals its solo encode: the tile layout (and
    hence the counter-hash element index) must not depend on the worker's
    position in the stack or on n."""
    from repro.core import modulo
    from repro.kernels import ops as kops
    spec = QuantSpec(bits=4)
    B = modulo.b_theta(2.0, spec.delta)
    seed = jnp.uint32(77)
    X = _stacked(n=6, d=37)
    stacked = kops.moniqua_encode_stacked(X, B, spec, seed, backend=backend)
    for i in range(6):
        solo = (kops.moniqua_encode(X[i], B, spec, None, seed=seed)
                if backend == "pallas"
                else kops.moniqua_encode_jnp(X[i], B, spec, seed))
        np.testing.assert_array_equal(np.asarray(stacked[i]),
                                      np.asarray(solo))


def test_shared_randomness_identical_rows_identical_payloads():
    """Supp. C: workers holding the same model must emit the same payload
    (same uniforms per element), which per-worker tiling guarantees."""
    from repro.core import modulo
    from repro.kernels import ops as kops
    spec = QuantSpec(bits=8, stochastic=True)
    B = modulo.b_theta(2.0, spec.delta)
    row = jax.random.normal(jax.random.PRNGKey(9), (123,)) * 0.3
    X = jnp.broadcast_to(row, (5, 123))
    packed = kops.moniqua_encode_stacked(X, B, spec, jnp.uint32(3),
                                         backend="jnp")
    for i in range(1, 5):
        np.testing.assert_array_equal(np.asarray(packed[i]),
                                      np.asarray(packed[0]))


# ---------------------------------------------------------------------------
# bucketed flat-buffer gossip (comm/bucket.py)
# ---------------------------------------------------------------------------

def _mixed_tree():
    """Mixed shapes AND dtypes: unaligned last dims, a 3-D leaf, a
    scalar-per-worker leaf, and a bf16 leaf."""
    return {
        "w": _stacked(),                                       # (8, 300) f32
        "b": _stacked(d=17, seed=7),                           # (8, 17)  f32
        "c": _stacked(d=21, seed=5,
                      ).reshape(8, 3, 7).astype(jnp.bfloat16),  # (8,3,7) bf16
        "s": _stacked(d=1, seed=3).reshape(8),                 # (8,) scalar
    }


@pytest.mark.parametrize("bits", [1, 4])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bucketed_matches_per_leaf_bit_exact(bits, backend):
    """The tentpole contract: one flat-buffer round == the per-leaf round,
    bitwise, on a mixed-shape/mixed-dtype pytree — same stochastic uniforms
    per element (global counter indices), same decode math, same casts."""
    spec = QuantSpec(bits=bits, stochastic=bits > 1)
    X = _mixed_tree()
    key = jax.random.PRNGKey(11)
    per_leaf = CommEngine(ring(8), MoniquaWire(spec), backend=backend,
                          path="per_leaf").mix(X, theta=2.0, key=key).x
    bucketed = CommEngine(ring(8), MoniquaWire(spec), backend=backend,
                          path="bucketed").mix(X, theta=2.0, key=key).x
    for k in X:
        np.testing.assert_array_equal(np.asarray(per_leaf[k]),
                                      np.asarray(bucketed[k]))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bucketed_stochastic_payload_bits_match_per_leaf(backend):
    """Concatenated per-leaf payload bytes ARE the bucketed payload: the
    vpb row alignment lines byte boundaries up and the global idx_base
    makes both paths hash identical (seed, element) pairs."""
    from repro.comm import bucket
    from repro.core import modulo
    from repro.kernels import ops as kops
    spec = QuantSpec(bits=4, stochastic=True)
    X = {"a": _stacked(d=37), "b": _stacked(d=300, seed=2)}
    layout = bucket.layout_of(X, spec.values_per_byte)
    B = modulo.b_theta(2.0, spec.delta)
    seed = jnp.uint32(5)
    flat = layout.flatten(X)
    p_bucket = kops.moniqua_encode_stacked(flat, B, spec, seed,
                                           backend=backend)
    leaves = jax.tree.leaves(X)
    p_leaves = [kops.moniqua_encode_stacked(l, B, spec, seed,
                                            backend=backend, idx_base=off)
                .reshape(8, -1)
                for l, off in zip(leaves, layout.offsets)]
    np.testing.assert_array_equal(
        np.asarray(p_bucket), np.asarray(jnp.concatenate(p_leaves, axis=1)))


def test_bucketed_full_precision_is_exact_mix():
    X = {"w": _stacked(), "b": _stacked(d=17, seed=1)}
    out = CommEngine(ring(8), FullPrecisionWire(), path="bucketed").mix(X).x
    ref = gossip.mix(X, ring(8))
    for k in X:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[k]))


def test_bucketed_full_precision_mixed_dtype_is_exact_mix():
    """Contract 1 survives bucketing on mixed-dtype trees: the full wire
    falls back to the per-leaf circulant mix there, because f32 staging
    would accumulate bf16 rolls in f32 and drift from gossip.mix."""
    X = {"w": _stacked(), "c": _stacked(d=24, seed=5).astype(jnp.bfloat16)}
    eng = CommEngine(ring(8), FullPrecisionWire(), path="bucketed")
    out = eng.mix(X).x
    ref = gossip.mix(X, ring(8))
    for k in X:
        np.testing.assert_array_equal(np.asarray(out[k], np.float32),
                                      np.asarray(ref[k], np.float32))
    # and the bytes account the per-leaf payloads (bf16 ships 2 bytes)
    per_leaf = CommEngine(ring(8), FullPrecisionWire(), path="per_leaf")
    assert eng.bytes_per_round(X) == per_leaf.bytes_per_round(X)
    assert eng.bytes_per_round(X) == (300 * 4 + 24 * 2) * 2


def test_bucketed_qsgd_close_to_exact():
    X = {"w": _stacked(scale=0.25), "b": _stacked(d=17, seed=1, scale=0.25)}
    out = CommEngine(ring(8), QSGDWire(QuantSpec(bits=8)), backend="jnp",
                     path="bucketed").mix(X, key=jax.random.PRNGKey(2)).x
    ref = gossip.mix(X, ring(8))
    mx = max(float(jnp.max(jnp.abs(X[k]))) for k in X)
    tol = 2.0 * mx * (2.0 / 256.0) + 1e-4
    for k in X:
        assert float(jnp.max(jnp.abs(out[k] - ref[k]))) <= tol


def test_bucketed_mix_under_jit():
    spec = QuantSpec(bits=4)
    eng = CommEngine(ring(8), MoniquaWire(spec), backend="jnp",
                     path="bucketed")
    X = _mixed_tree()
    key = jax.random.PRNGKey(0)
    eager = eng.mix(X, theta=2.0, key=key).x
    jitted = jax.jit(lambda x, k: eng.mix(x, theta=2.0, key=k).x)(X, key)
    for k in X:
        np.testing.assert_allclose(
            np.asarray(eager[k], np.float32),
            np.asarray(jitted[k], np.float32), rtol=0, atol=1e-6)


def test_bucketed_bytes_ledger_and_sim_agree():
    """bytes_per_round == BytesLedger == the bytes the simulator prices:
    one consistent accounting for the bucketed layout (and for Moniqua it
    equals the per-leaf sum — tile padding never rides the wire)."""
    from repro.sim import events as SE
    from repro.sim import scenarios as SC
    topo = ring(8)
    X = {"a": jnp.zeros((8, 100)), "b": jnp.zeros((8, 3, 7))}
    eng = CommEngine(topo, MoniquaWire(QuantSpec(bits=2)), backend="jnp",
                     path="bucketed")
    led = gossip.BytesLedger()
    eng.mix(X, theta=2.0, key=jax.random.PRNGKey(0), ledger=led)
    m = len(topo.neighbor_offsets())
    assert led.bytes_per_worker == eng.bytes_per_round(X)
    # identical to the per-leaf accounting: (25 + 6) bytes x 2 neighbors
    assert eng.bytes_per_round(X) == (25 + 6) * 2
    per_leaf = CommEngine(topo, MoniquaWire(QuantSpec(bits=2)),
                          backend="jnp", path="per_leaf")
    assert eng.bytes_per_round(X) == per_leaf.bytes_per_round(X)
    sc = SC.get_scenario("lan-10gbe-ring", n=8)
    trace = SE.simulate_sync_rounds(sc, eng.bytes_per_round(X) // m,
                                    num_rounds=1)
    assert trace.bytes_on_wire == 8 * eng.bytes_per_round(X)


def test_bucketed_qsgd_keeps_per_tensor_scales():
    """Bucketed qsgd quantizes each tensor under its own max-norm scale
    (segment_max over the flat buffer), so a tiny-magnitude leaf next to
    a huge one is not drowned in the big leaf's quantization noise —
    and the wire bytes (4 per tensor) match the per-leaf sum."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = {"w": jax.random.normal(k1, (8, 100)) * 100.0,
         "b": jax.random.normal(k2, (8, 32)) * 0.01}
    eng = CommEngine(ring(8), QSGDWire(QuantSpec(bits=8)), backend="jnp",
                     path="bucketed")
    out = eng.mix(X, key=jax.random.PRNGKey(3)).x
    ref = gossip.mix(X, ring(8))
    # error on the small leaf is bounded by ITS scale, not the big one's
    err_b = float(jnp.max(jnp.abs(out["b"] - ref["b"])))
    assert err_b <= 2.0 * 0.01 * 8.0 * (2.0 / 256.0) + 1e-5
    per_leaf = CommEngine(ring(8), QSGDWire(QuantSpec(bits=8)),
                          backend="jnp", path="per_leaf")
    assert eng.bytes_per_round(X) == per_leaf.bytes_per_round(X)
    assert eng.bytes_per_round(X) == (100 + 4 + 32 + 4) * 2


def test_bucketed_layout_cache_reused_across_abstract_and_concrete():
    from repro.comm import bucket
    X = {"a": jnp.zeros((8, 100)), "b": jnp.zeros((8, 3, 7))}
    abstract = jax.eval_shape(lambda: X)
    assert bucket.layout_of(X, 4) is bucket.layout_of(abstract, 4)


# ---------------------------------------------------------------------------
# seed derivation: deterministic specs with key=None are explicit
# ---------------------------------------------------------------------------

def test_deterministic_spec_key_none_is_explicit_constant():
    """key=None is only legal for nearest-rounding specs, where the hash
    seed is never drawn: the mix must equal a keyed mix bit-for-bit, and
    the placeholder seed is the documented NO_KEY_SEED constant."""
    from repro.kernels import ops as kops
    assert int(kops._key_to_seed(None)) == kops.NO_KEY_SEED
    spec = QuantSpec(bits=4, stochastic=False)
    X = _stacked()
    for path in ("per_leaf", "bucketed"):
        eng = CommEngine(ring(8), MoniquaWire(spec), backend="jnp",
                         path=path)
        a = eng.mix(X, theta=2.0, key=None).x
        b = eng.mix(X, theta=2.0, key=jax.random.PRNGKey(123)).x
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("path", ["per_leaf", "bucketed"])
@pytest.mark.parametrize("wire", ["moniqua", "qsgd"])
def test_stochastic_spec_key_none_raises(path, wire):
    eng = CommEngine(ring(8), make_wire(wire, QuantSpec(bits=4,
                                                        stochastic=True)),
                     backend="jnp", path=path)
    with pytest.raises(ValueError, match="PRNG key"):
        eng.mix(_stacked(), theta=2.0, key=None)


# ---------------------------------------------------------------------------
# QSGD wire
# ---------------------------------------------------------------------------

def test_qsgd_mix_close_to_exact():
    topo = ring(8)
    X = _stacked(scale=0.25)
    out = CommEngine(topo, QSGDWire(QuantSpec(bits=8)), backend="jnp").mix(
        X, key=jax.random.PRNGKey(2)).x
    exact = gossip.mix(X, topo)
    # per-worker scale <= max|x|; 8-bit lattice pitch = 2*scale/256
    tol = 2.0 * float(jnp.max(jnp.abs(X))) * (2.0 / 256.0) + 1e-4
    assert float(jnp.max(jnp.abs(out - exact))) <= tol


def test_qsgd_preserves_mean_roughly():
    topo = ring(8)
    X = _stacked(scale=0.25)
    out = CommEngine(topo, QSGDWire(QuantSpec(bits=8)), backend="jnp").mix(
        X, key=jax.random.PRNGKey(4)).x
    drift = float(jnp.max(jnp.abs(out.mean(0) - X.mean(0))))
    assert drift <= 2.0 * float(jnp.max(jnp.abs(X))) * (2.0 / 256.0) + 1e-4


# ---------------------------------------------------------------------------
# 3. bytes accounting
# ---------------------------------------------------------------------------

def test_ledger_one_bit_is_one_thirtysecond_of_f32():
    topo = ring(8)
    X = jnp.zeros((8, 256))
    led_1bit, led_f32 = gossip.BytesLedger(), gossip.BytesLedger()
    CommEngine(topo, MoniquaWire(QuantSpec(bits=1, stochastic=False)),
               backend="jnp").mix(X, theta=2.0, ledger=led_1bit)
    CommEngine(topo, FullPrecisionWire()).mix(X, ledger=led_f32)
    assert led_1bit.bytes_per_worker > 0
    assert led_1bit.bytes_per_worker * 32 == led_f32.bytes_per_worker


def test_bytes_per_round_matches_ledger():
    topo = ring(8)
    X = {"a": jnp.zeros((8, 100)), "b": jnp.zeros((8, 3, 7))}
    eng = CommEngine(topo, MoniquaWire(QuantSpec(bits=2)), backend="jnp")
    led = gossip.BytesLedger()
    eng.mix(X, theta=2.0, key=jax.random.PRNGKey(0), ledger=led)
    assert led.bytes_per_worker == eng.bytes_per_round(X)
    # 2 bits -> ceil(100/4)=25 and 3*ceil(7/4)=6 bytes per leaf, 2 neighbors
    assert eng.bytes_per_round(X) == (25 + 6) * 2


def test_qsgd_bytes_include_scale():
    eng = CommEngine(ring(8), QSGDWire(QuantSpec(bits=8)), backend="jnp")
    X = jnp.zeros((8, 100))
    # 100 code bytes + 4 scale bytes, 2 neighbors
    assert eng.bytes_per_round(X) == (100 + 4) * 2


# ---------------------------------------------------------------------------
# pair_average (AD-PSGD primitive)
# ---------------------------------------------------------------------------

def test_pair_average_full_is_exact_average():
    eng = CommEngine(ring(8), FullPrecisionWire())
    xi, xj = jnp.arange(4.0), jnp.arange(4.0) + 1.0
    res = eng.pair_average(xi, xj)
    ni, nj = res.xi, res.xj
    np.testing.assert_allclose(np.asarray(ni), np.asarray(0.5 * (xi + xj)))
    np.testing.assert_allclose(np.asarray(ni), np.asarray(nj))


@pytest.mark.parametrize("wire", ["moniqua", "qsgd"])
def test_pair_average_quantized_close(wire):
    theta = 1.0
    spec = QuantSpec(bits=8)
    eng = CommEngine(ring(8), make_wire(wire, spec), backend="jnp")
    xi = jax.random.normal(jax.random.PRNGKey(5), (64,)) * 0.2
    xj = xi + jax.random.uniform(jax.random.PRNGKey(6), (64,),
                                 minval=-0.4, maxval=0.4) * theta
    res = eng.pair_average(xi, xj, theta=theta, key=jax.random.PRNGKey(7))
    ni, nj = res.xi, res.xj
    avg = 0.5 * (xi + xj)
    B = float(modulo.b_theta(theta, spec.delta))
    tol = (2.0 * spec.delta * B if wire == "moniqua"
           else 2.0 * float(jnp.max(jnp.abs(xj))) * (2.0 / 256.0)) + 1e-4
    assert float(jnp.max(jnp.abs(ni - avg))) <= tol
    assert float(jnp.max(jnp.abs(nj - avg))) <= tol


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_unknown_wire_and_backend_raise():
    with pytest.raises(ValueError):
        make_wire("zstd")
    with pytest.raises(ValueError):
        CommEngine(ring(8), MoniquaWire(), backend="cuda").mix(
            jnp.zeros((8, 8)), theta=1.0)


def test_moniqua_requires_theta():
    eng = CommEngine(ring(8), MoniquaWire())
    with pytest.raises(ValueError):
        eng.mix(jnp.zeros((8, 8)))


# ---------------------------------------------------------------------------
# stateful EF wires (ef_qsgd / onebit): the WireState contracts
# ---------------------------------------------------------------------------

EF_CASES = [("ef_qsgd", False), ("ef_qsgd", True),
            ("onebit", False), ("onebit", True)]


def _ef_engine(wire, stochastic, backend="jnp", path="bucketed", warmup=2):
    spec = QuantSpec(bits=4 if wire == "ef_qsgd" else 1,
                     stochastic=stochastic)
    return CommEngine(ring(8), make_wire(wire, spec, warmup=warmup),
                      backend=backend, path=path)


@pytest.mark.parametrize("wire,stochastic", EF_CASES)
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ef_bucketed_matches_per_leaf_bit_exact(wire, stochastic, backend):
    """The stateful tentpole contract: 4 iterated rounds bucketed vs
    per-leaf agree bitwise — mixed outputs AND the post-round WireState —
    on a mixed-shape/mixed-dtype pytree, on both backend names (warmup=2
    exercises rounds on both sides of the onebit switch).  The residual
    living in the canonical flat bucket domain is what makes this hold."""
    Xa = Xb = _mixed_tree()
    a = _ef_engine(wire, stochastic, backend, path="bucketed")
    b = _ef_engine(wire, stochastic, backend, path="per_leaf")
    sa, sb = a.init_wire_state(Xa), b.init_wire_state(Xb)
    for k in range(4):
        key = jax.random.PRNGKey(90 + k)
        ra = a.mix(Xa, key=key, state=sa)
        rb = b.mix(Xb, key=key, state=sb)
        Xa, sa = ra.x, ra.state
        Xb, sb = rb.x, rb.state
        for lk in Xa:
            np.testing.assert_array_equal(
                np.asarray(Xa[lk], np.float32),
                np.asarray(Xb[lk], np.float32), err_msg=f"round {k} {lk}")
        np.testing.assert_array_equal(np.asarray(sa["residual"]),
                                      np.asarray(sb["residual"]),
                                      err_msg=f"round {k} residual")
        assert int(sa["step"]) == int(sb["step"]) == k + 1


@pytest.mark.parametrize("wire,stochastic", EF_CASES)
def test_ef_payload_bits_match_per_leaf(wire, stochastic):
    """Concatenated per-slot payloads (what the per-leaf round rolls) ARE
    the bucketed payload — codes and sideband words both — because both
    paths encode the same canonical flat segments under the same
    row-position uniforms (idx_base = the segment's bucket offset)."""
    from repro.core.quantizers import (ef_qsgd_encode_segmented,
                                       onebit_encode_segmented)
    eng = _ef_engine(wire, stochastic)
    X = {"a": _stacked(d=37), "b": _stacked(d=300, seed=2)}
    layout = eng.layout(X)
    flat = layout.flatten(X).astype(jnp.float32)
    seed = jnp.uint32(5)
    spec = eng.codec.spec

    def enc(buf, segments, idx_base):
        if wire == "ef_qsgd":
            return ef_qsgd_encode_segmented(buf, spec, seed, segments,
                                            idx_base)
        return onebit_encode_segmented(buf, seed, segments, idx_base,
                                       stochastic)

    whole = enc(flat, layout.segment_sizes, 0)
    parts = [enc(jax.lax.slice_in_dim(flat, s.offset,
                                      s.offset + s.padded_size, axis=1),
                 (s.padded_size,), s.offset)
             for s in layout.slots]
    for j, arrs in enumerate(zip(*parts)):
        np.testing.assert_array_equal(
            np.asarray(whole[j]),
            np.asarray(jnp.concatenate(arrs, axis=1)))


@pytest.mark.parametrize("wire,nbytes", [("ef_qsgd", 70), ("onebit", 32)])
def test_ef_bytes_ledger_and_sim_agree(wire, nbytes):
    """One consistent accounting for the EF wires: BytesLedger ==
    payload_bytes_per_broadcast (x neighbors) == the bytes the simulator
    prices, identical for the bucketed and per-leaf paths (both ship the
    same packed flat segments).  Exact numbers for {a: 100, b: 3x7} f32
    (each of b's 3 rows pads its last dim to the byte boundary):
    ef_qsgd-4bit packs 100+24=124 elems at 2/byte + 4B scale x 2 leaves =
    70; onebit packs 104+24=128 elems at 8/byte + 8B levels x 2 = 32."""
    from repro.sim import events as SE
    from repro.sim import scenarios as SC
    topo = ring(8)
    X = {"a": jnp.zeros((8, 100)), "b": jnp.zeros((8, 3, 7))}
    bits = 4 if wire == "ef_qsgd" else 1
    eng = CommEngine(topo, make_wire(wire, QuantSpec(bits=bits)),
                     backend="jnp", path="bucketed")
    led = gossip.BytesLedger()
    st = eng.init_wire_state(X)
    eng.mix(X, key=jax.random.PRNGKey(0), ledger=led, state=st)
    m = len(topo.neighbor_offsets())
    assert eng.payload_bytes_per_broadcast(X) == nbytes
    assert led.bytes_per_worker == eng.bytes_per_round(X) == nbytes * m
    per_leaf = CommEngine(topo, make_wire(wire, QuantSpec(bits=bits)),
                          backend="jnp", path="per_leaf")
    assert per_leaf.bytes_per_round(X) == eng.bytes_per_round(X)
    sc = SC.get_scenario("lan-10gbe-ring", n=8)
    trace = SE.simulate_sync_rounds(sc, eng.bytes_per_round(X) // m,
                                    num_rounds=1)
    assert trace.bytes_on_wire == 8 * eng.bytes_per_round(X)


def test_onebit_warmup_payload_is_f32():
    wire = make_wire("onebit", QuantSpec(bits=1))
    assert wire.warmup_payload_bytes((100,)) == 400
    assert wire.payload_bytes((100,)) == 13 + 8   # ceil(100/8) + lo/hi


@pytest.mark.parametrize("wire", ["ef_qsgd", "onebit"])
@pytest.mark.parametrize("path", ["per_leaf", "bucketed"])
def test_stateful_mix_without_state_raises(wire, path):
    eng = CommEngine(ring(8), make_wire(wire, QuantSpec(bits=4)),
                     backend="jnp", path=path)
    with pytest.raises(ValueError, match="stateful"):
        eng.mix(_stacked(), key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="stateful"):
        eng.mix(_stacked(), key=jax.random.PRNGKey(0), state={})


def test_stateful_pair_average_without_state_raises():
    eng = CommEngine(ring(8), make_wire("ef_qsgd", QuantSpec(bits=4)),
                     backend="jnp")
    xi = jnp.zeros((16,))
    with pytest.raises(ValueError, match="stateful"):
        eng.pair_average(xi, xi, key=jax.random.PRNGKey(0))


@pytest.mark.parametrize("wire,stochastic", EF_CASES)
def test_ef_mix_under_jit_close(wire, stochastic):
    """Re-jitting may legally FMA-contract the EF math: ~1 ulp, like the
    Moniqua wire's jit bound."""
    eng = _ef_engine(wire, stochastic, warmup=0)
    X = _mixed_tree()
    st = eng.init_wire_state(X)
    key = jax.random.PRNGKey(4)
    er = eng.mix(X, key=key, state=st)
    jr = jax.jit(lambda x, s, k: eng.mix(x, key=k, state=s))(X, st, key)
    eo, es = er.x, er.state
    jo, js = jr.x, jr.state
    for k in X:
        np.testing.assert_allclose(np.asarray(eo[k], np.float32),
                                   np.asarray(jo[k], np.float32),
                                   rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(es["residual"]),
                               np.asarray(js["residual"]),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("wire", ["ef_qsgd", "onebit"])
def test_ef_pair_average_stateful(wire):
    """AD-PSGD edges: per-endpoint WireState carries; the warmup exchange
    is the exact average; iterated compressed exchanges keep shrinking
    the pair gap (EF makes the biased 1-bit exchange converge too) down
    to the codec's noise floor — 8-bit qsgd's pitch keeps it well under
    a tenth of the initial gap."""
    eng = CommEngine(ring(8), make_wire(wire, QuantSpec(bits=8), warmup=1),
                     backend="jnp")
    xi = jax.random.normal(jax.random.PRNGKey(5), (3, 5)) * 0.2
    xj = xi + 0.3
    si, sj = eng.init_edge_state(xi), eng.init_edge_state(xj)
    gap0 = float(jnp.max(jnp.abs(xi - xj)))
    res = eng.pair_average(xi, xj, key=jax.random.PRNGKey(0),
                           state_i=si, state_j=sj)
    ni, nj, si, sj = res.xi, res.xj, res.state_i, res.state_j
    avg = 0.5 * (xi + xj)
    if wire == "onebit":   # warm exchange: exactly the f32 average
        np.testing.assert_array_equal(np.asarray(ni), np.asarray(avg))
        np.testing.assert_array_equal(np.asarray(nj), np.asarray(avg))
    xi, xj = ni, nj
    for k in range(40):
        r = eng.pair_average(
            xi, xj, key=jax.random.PRNGKey(10 + k), state_i=si, state_j=sj)
        xi, xj, si, sj = r.xi, r.xj, r.state_i, r.state_j
    assert int(si["step"]) == int(sj["step"]) == 41
    assert float(jnp.max(jnp.abs(xi - xj))) < 0.1 * gap0


@pytest.mark.parametrize("wire,extra", [("moniqua", 0), ("qsgd", 0),
                                        ("full", 0), ("ef_qsgd", 4 * 124 + 4),
                                        ("onebit", 4 * 128 + 4)])
def test_wire_state_bytes_accounting(wire, extra):
    """Tables 1-2 memory column: stateless wires report exactly 0; EF
    wires one f32 per padded bucket element plus the counter word."""
    X = {"a": jnp.zeros((8, 100)), "b": jnp.zeros((8, 3, 7))}
    bits = 1 if wire == "onebit" else 4
    eng = CommEngine(ring(8), make_wire(wire, QuantSpec(bits=bits)),
                     backend="jnp")
    assert eng.wire_state_bytes(X) == extra
    assert eng.stateful == (extra > 0)


def test_init_wire_state_from_abstract_shapes():
    """Trainers build the WireState under jax.eval_shape — shapes only."""
    X = {"a": jnp.zeros((8, 100)), "b": jnp.zeros((8, 3, 7))}
    eng = CommEngine(ring(8), make_wire("ef_qsgd", QuantSpec(bits=4)),
                     backend="jnp")
    concrete = eng.init_wire_state(X)
    abstract = jax.eval_shape(lambda: X)
    shaped = eng.init_wire_state(abstract)
    assert shaped["residual"].shape == concrete["residual"].shape
    assert shaped["residual"].dtype == concrete["residual"].dtype
    assert shaped["step"].dtype == jnp.int32
    stateless = CommEngine(ring(8), MoniquaWire())
    assert stateless.init_wire_state(X) == {}
