"""CommEngine: codec/backend parity, fused decode-reduce, bytes accounting.

The three contracts from the engine design (docs/architecture.md):

1. ``CommEngine(full_precision).mix == gossip.mix`` exactly (the engine's
   full-precision round IS the circulant ``X W``).
2. ``CommEngine(moniqua, pallas)`` (interpret off-TPU) is **bit-exact** with
   ``CommEngine(moniqua, jnp)`` — same counter-hash randomness, same fenced
   per-element math (kernels/moniqua_decode_reduce.py documents why the jnp
   path is compared as written, i.e. eagerly; under re-jit XLA may legally
   FMA-contract and drift by 1 ulp, checked separately with a tight bound).
3. BytesLedger: 1-bit Moniqua payloads are exactly 1/32 of f32 bytes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import gossip
from repro.comm.engine import (CommEngine, FullPrecisionWire, MoniquaWire,
                               QSGDWire, make_wire)
from repro.core import modulo
from repro.core.quantizers import QuantSpec
from repro.core.topology import exponential, ring

BITS = [1, 2, 4, 8]


def _stacked(scale=0.3, n=8, d=300, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * scale


# ---------------------------------------------------------------------------
# 1. full-precision parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", [ring(8), exponential(8)],
                         ids=lambda t: t.name)
def test_full_precision_equals_gossip_mix(topo):
    X = {"w": _stacked(), "b": _stacked(d=17, seed=1)}
    eng = CommEngine(topo, FullPrecisionWire())
    out = eng.mix(X)
    ref = gossip.mix(X, topo)
    for k in X:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[k]))


# ---------------------------------------------------------------------------
# 2. moniqua backend parity (pallas interpret vs pure jnp)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("topo", [ring(8), exponential(8)],
                         ids=lambda t: t.name)
def test_moniqua_pallas_vs_jnp_bit_exact(bits, topo):
    spec = QuantSpec(bits=bits, stochastic=bits > 1)
    X = _stacked()
    key = jax.random.PRNGKey(3)
    a = CommEngine(topo, MoniquaWire(spec), backend="jnp").mix(
        X, theta=2.0, key=key)
    b = CommEngine(topo, MoniquaWire(spec), backend="pallas").mix(
        X, theta=2.0, key=key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("bits", [1, 4])
def test_moniqua_parity_on_pytrees(bits):
    spec = QuantSpec(bits=bits, stochastic=bits > 1)
    X = {"w": _stacked(), "b": _stacked(d=17, seed=7).reshape(8, 17)}
    key = jax.random.PRNGKey(1)
    a = CommEngine(ring(8), MoniquaWire(spec), backend="jnp").mix(
        X, theta=2.0, key=key)
    b = CommEngine(ring(8), MoniquaWire(spec), backend="pallas").mix(
        X, theta=2.0, key=key)
    for k in X:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_moniqua_parity_under_jit_close():
    """Re-jitting the jnp path lets XLA contract FMAs: bounded by ~1 ulp."""
    spec = QuantSpec(bits=4)
    X = _stacked()
    key = jax.random.PRNGKey(3)
    ej = CommEngine(ring(8), MoniquaWire(spec), backend="jnp")
    b = CommEngine(ring(8), MoniquaWire(spec), backend="pallas").mix(
        X, theta=2.0, key=key)
    aj = jax.jit(lambda x, k: ej.mix(x, theta=2.0, key=k))(X, key)
    np.testing.assert_allclose(np.asarray(aj), np.asarray(b),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_moniqua_engine_close_to_exact_mix(bits):
    """One engine round deviates from full-precision mixing by O(delta*B).

    Valid only under the a-priori bound |x_i - x_j| < theta, so workers are
    bounded perturbations of a common base model (as in test_gossip).
    """
    topo = ring(8)
    theta = 1.0
    spec = QuantSpec(bits=bits, stochastic=True)
    base = jax.random.normal(jax.random.PRNGKey(0), (1, 300)) * 10.0
    X = base + jax.random.uniform(jax.random.PRNGKey(1), (8, 300),
                                  minval=-0.45, maxval=0.45) * theta
    out = CommEngine(topo, MoniquaWire(spec), backend="jnp").mix(
        X, theta=theta, key=jax.random.PRNGKey(2))
    exact = gossip.mix(X, topo)
    B = float(modulo.b_theta(theta, spec.delta))
    assert float(jnp.max(jnp.abs(out - exact))) <= 2.0 * spec.delta * B + 1e-4


def test_single_worker_is_identity():
    eng = CommEngine(ring(1), MoniquaWire(QuantSpec(bits=8)))
    X = jnp.ones((1, 16))
    np.testing.assert_array_equal(
        np.asarray(eng.mix(X, theta=1.0, key=jax.random.PRNGKey(0))),
        np.asarray(X))


# ---------------------------------------------------------------------------
# per-worker tiling (stacked wrappers in kernels/ops.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_stacked_encode_is_per_worker(backend):
    """Each worker's payload equals its solo encode: the tile layout (and
    hence the counter-hash element index) must not depend on the worker's
    position in the stack or on n."""
    from repro.core import modulo
    from repro.kernels import ops as kops
    spec = QuantSpec(bits=4)
    B = modulo.b_theta(2.0, spec.delta)
    seed = jnp.uint32(77)
    X = _stacked(n=6, d=37)
    stacked = kops.moniqua_encode_stacked(X, B, spec, seed, backend=backend)
    for i in range(6):
        solo = (kops.moniqua_encode(X[i], B, spec, None, seed=seed)
                if backend == "pallas"
                else kops.moniqua_encode_jnp(X[i], B, spec, seed))
        np.testing.assert_array_equal(np.asarray(stacked[i]),
                                      np.asarray(solo))


def test_shared_randomness_identical_rows_identical_payloads():
    """Supp. C: workers holding the same model must emit the same payload
    (same uniforms per element), which per-worker tiling guarantees."""
    from repro.core import modulo
    from repro.kernels import ops as kops
    spec = QuantSpec(bits=8, stochastic=True)
    B = modulo.b_theta(2.0, spec.delta)
    row = jax.random.normal(jax.random.PRNGKey(9), (123,)) * 0.3
    X = jnp.broadcast_to(row, (5, 123))
    packed = kops.moniqua_encode_stacked(X, B, spec, jnp.uint32(3),
                                         backend="jnp")
    for i in range(1, 5):
        np.testing.assert_array_equal(np.asarray(packed[i]),
                                      np.asarray(packed[0]))


# ---------------------------------------------------------------------------
# QSGD wire
# ---------------------------------------------------------------------------

def test_qsgd_mix_close_to_exact():
    topo = ring(8)
    X = _stacked(scale=0.25)
    out = CommEngine(topo, QSGDWire(QuantSpec(bits=8)), backend="jnp").mix(
        X, key=jax.random.PRNGKey(2))
    exact = gossip.mix(X, topo)
    # per-worker scale <= max|x|; 8-bit lattice pitch = 2*scale/256
    tol = 2.0 * float(jnp.max(jnp.abs(X))) * (2.0 / 256.0) + 1e-4
    assert float(jnp.max(jnp.abs(out - exact))) <= tol


def test_qsgd_preserves_mean_roughly():
    topo = ring(8)
    X = _stacked(scale=0.25)
    out = CommEngine(topo, QSGDWire(QuantSpec(bits=8)), backend="jnp").mix(
        X, key=jax.random.PRNGKey(4))
    drift = float(jnp.max(jnp.abs(out.mean(0) - X.mean(0))))
    assert drift <= 2.0 * float(jnp.max(jnp.abs(X))) * (2.0 / 256.0) + 1e-4


# ---------------------------------------------------------------------------
# 3. bytes accounting
# ---------------------------------------------------------------------------

def test_ledger_one_bit_is_one_thirtysecond_of_f32():
    topo = ring(8)
    X = jnp.zeros((8, 256))
    led_1bit, led_f32 = gossip.BytesLedger(), gossip.BytesLedger()
    CommEngine(topo, MoniquaWire(QuantSpec(bits=1, stochastic=False)),
               backend="jnp").mix(X, theta=2.0, ledger=led_1bit)
    CommEngine(topo, FullPrecisionWire()).mix(X, ledger=led_f32)
    assert led_1bit.bytes_per_worker > 0
    assert led_1bit.bytes_per_worker * 32 == led_f32.bytes_per_worker


def test_bytes_per_round_matches_ledger():
    topo = ring(8)
    X = {"a": jnp.zeros((8, 100)), "b": jnp.zeros((8, 3, 7))}
    eng = CommEngine(topo, MoniquaWire(QuantSpec(bits=2)), backend="jnp")
    led = gossip.BytesLedger()
    eng.mix(X, theta=2.0, key=jax.random.PRNGKey(0), ledger=led)
    assert led.bytes_per_worker == eng.bytes_per_round(X)
    # 2 bits -> ceil(100/4)=25 and 3*ceil(7/4)=6 bytes per leaf, 2 neighbors
    assert eng.bytes_per_round(X) == (25 + 6) * 2


def test_qsgd_bytes_include_scale():
    eng = CommEngine(ring(8), QSGDWire(QuantSpec(bits=8)), backend="jnp")
    X = jnp.zeros((8, 100))
    # 100 code bytes + 4 scale bytes, 2 neighbors
    assert eng.bytes_per_round(X) == (100 + 4) * 2


# ---------------------------------------------------------------------------
# pair_average (AD-PSGD primitive)
# ---------------------------------------------------------------------------

def test_pair_average_full_is_exact_average():
    eng = CommEngine(ring(8), FullPrecisionWire())
    xi, xj = jnp.arange(4.0), jnp.arange(4.0) + 1.0
    ni, nj = eng.pair_average(xi, xj)
    np.testing.assert_allclose(np.asarray(ni), np.asarray(0.5 * (xi + xj)))
    np.testing.assert_allclose(np.asarray(ni), np.asarray(nj))


@pytest.mark.parametrize("wire", ["moniqua", "qsgd"])
def test_pair_average_quantized_close(wire):
    theta = 1.0
    spec = QuantSpec(bits=8)
    eng = CommEngine(ring(8), make_wire(wire, spec), backend="jnp")
    xi = jax.random.normal(jax.random.PRNGKey(5), (64,)) * 0.2
    xj = xi + jax.random.uniform(jax.random.PRNGKey(6), (64,),
                                 minval=-0.4, maxval=0.4) * theta
    ni, nj = eng.pair_average(xi, xj, theta=theta, key=jax.random.PRNGKey(7))
    avg = 0.5 * (xi + xj)
    B = float(modulo.b_theta(theta, spec.delta))
    tol = (2.0 * spec.delta * B if wire == "moniqua"
           else 2.0 * float(jnp.max(jnp.abs(xj))) * (2.0 / 256.0)) + 1e-4
    assert float(jnp.max(jnp.abs(ni - avg))) <= tol
    assert float(jnp.max(jnp.abs(nj - avg))) <= tol


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_unknown_wire_and_backend_raise():
    with pytest.raises(ValueError):
        make_wire("zstd")
    with pytest.raises(ValueError):
        CommEngine(ring(8), MoniquaWire(), backend="cuda").mix(
            jnp.zeros((8, 8)), theta=1.0)


def test_moniqua_requires_theta():
    eng = CommEngine(ring(8), MoniquaWire())
    with pytest.raises(ValueError):
        eng.mix(jnp.zeros((8, 8)))
