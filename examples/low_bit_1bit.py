"""1-bit-per-parameter decentralized training (paper Theorem 3 / Table 2).

    PYTHONPATH=src python examples/low_bit_1bit.py

Uses the *nearest* (biased!) 1-bit quantizer — delta = 1/4 < 1/2 as Theorem 3
requires — and the slack communication matrix W_bar = s W + (1-s) I.
Compares against naive 1-bit quantization (diverges / stalls) and full
precision, reporting final loss and wire bytes.
"""
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig

SHAPE = InputShape("lb", seq_len=32, global_batch=16, kind="train")


def main():
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              num_layers=2, d_model=128, num_heads=2,
                              num_kv_heads=2, head_dim=64, d_ff=256,
                              vocab_size=128)
    model = build_model(cfg)
    n_params = sum(int(p.size) for p in
                   jax.tree.leaves(model.init(jax.random.PRNGKey(0))))
    print(f"model: {n_params/1e3:.0f}k params, 8 workers on a ring\n")

    runs = [
        ("d-psgd f32", dict(algo="dpsgd", bits=8)),
        ("moniqua 1-bit + slack", dict(algo="moniqua", bits=1, theta=0.25,
                                       slack=0.2)),
        ("naive 1-bit (Thm 1)", dict(algo="naive", bits=1)),
    ]
    for name, kw in runs:
        tc = TrainerConfig(n_workers=8, lr=0.3, steps=60, log_every=60,
                           momentum=0.0, weight_decay=0.0, seed=3, **kw)
        out = Trainer(model, SHAPE, tc).run()
        h = out["history"]
        bits_per_param = (8 * out["bytes_per_step"]
                          / (n_params * 2))          # 2 ring neighbors
        print(f"{name:24s} loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}  "
              f"wire {bits_per_param:.1f} bits/param/neighbor")
    print("\n1-bit Moniqua matches full precision at 1/32 the bandwidth "
          "and ZERO extra memory (Table 2's headline result).")


if __name__ == "__main__":
    main()
