"""End-to-end decentralized training driver (deliverable b).

    # CPU-sized run (default): ~1M-param LM, 200 steps, 8 workers
    PYTHONPATH=src python examples/train_decentralized.py

    # the paper-scale run for real hardware: xlstm-125m, 300 steps
    PYTHONPATH=src python examples/train_decentralized.py --preset 100m

    # any assigned architecture's reduced variant, any algorithm
    PYTHONPATH=src python examples/train_decentralized.py \
        --arch dbrx-132b --algo choco --bits 4 --steps 50

Demonstrates the full stack: config -> model factory -> synthetic pipeline ->
vmap-per-worker gradients -> Moniqua gossip -> checkpointing, with a bytes-
on-wire ledger per algorithm.
"""
import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig


def build(preset: str, arch: str):
    if preset == "100m":
        # the real xlstm-125m config (paper-scale example; needs accelerator
        # to be fast, but runs on CPU too — just slowly)
        cfg = get_config("xlstm-125m")
        shape = InputShape("train_1k", seq_len=1024, global_batch=16,
                           kind="train")
    elif preset == "cpu":
        cfg = get_config(arch).reduced()
        cfg = dataclasses.replace(cfg, d_model=min(cfg.d_model, 128))
        shape = InputShape("train_tiny", seq_len=64, global_batch=16,
                           kind="train")
    else:
        raise SystemExit(f"unknown preset {preset}")
    return build_model(cfg), shape


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="cpu", choices=["cpu", "100m"])
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--algo", default="moniqua")
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--theta", type=float, default=2.0)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "exponential", "torus", "complete"])
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    model, shape = build(args.preset, args.arch)
    n_params = sum(int(p.size) for p in
                   __import__("jax").tree.leaves(
                       model.init(__import__("jax").random.PRNGKey(0))))
    print(f"arch={model.cfg.name} ({n_params/1e6:.1f}M params/worker) "
          f"algo={args.algo} bits={args.bits} workers={args.workers} "
          f"topology={args.topology}")

    tc = TrainerConfig(algo=args.algo, topology=args.topology,
                       n_workers=args.workers, bits=args.bits,
                       theta=args.theta, lr=args.lr, steps=args.steps,
                       log_every=max(args.steps // 20, 1),
                       checkpoint_path=args.checkpoint,
                       checkpoint_every=50 if args.checkpoint else 0)
    t0 = time.time()
    out = Trainer(model, shape, tc).run(
        callback=lambda k, m: print(
            f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
            f"alpha {m['alpha']:.3f}  theta {m['theta']:.3f}  "
            f"({m['wall']:.1f}s)"))
    print(f"done in {time.time()-t0:.1f}s; "
          f"wire bytes/step/worker = {out['bytes_per_step']:,} "
          f"({8*out['bytes_per_step']/n_params:.2f} bits/param incl. "
          f"neighbor fan-out)")


if __name__ == "__main__":
    main()
