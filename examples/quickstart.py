"""Quickstart: the Moniqua codec in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Round-trip one tensor through the modulo-quantized codec (Lemmas 1-2).
2. Gossip 8 decentralized workers one 1-bit round through CommEngine: the
   global mean is preserved exactly (line-4 bias cancellation), the spread
   stays inside the Lemma-2 ball, and the ledger counts 1/32 of the f32
   bytes.  (At 1 bit the quantization floor is ~theta, so a single round
   cannot *shrink* spread — convergence comes from cancellation across
   steps, which part 3 shows end-to-end.)
3. Train a tiny LM with Moniqua vs full-precision D-PSGD and compare both
   the loss and the bytes on the wire.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.comm import gossip
from repro.comm.engine import CommEngine, make_wire
from repro.core import modulo
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig


def demo_codec():
    print("=== 1. codec round-trip (Lemma 1/2) ===")
    theta = 2.0                      # a-priori bound on |x - y|
    codec = MoniquaCodec(QuantSpec(bits=4, stochastic=True))
    y = jax.random.normal(jax.random.PRNGKey(0), (8,)) * 10.0   # receiver's model
    x = y + jax.random.uniform(jax.random.PRNGKey(1), (8,),
                               minval=-0.9, maxval=0.9) * theta  # sender's
    packed = codec.encode(x, theta, jax.random.PRNGKey(2))
    x_hat = codec.decode(packed, y, theta)
    print(f"payload: {packed.nbytes} bytes for {x.nbytes} bytes of f32 "
          f"({8 * packed.nbytes / x.size:.0f} bits/param)")
    print(f"max |x_hat - x| = {float(jnp.max(jnp.abs(x_hat - x))):.4f}"
          f"  (Lemma-2 bound {codec.max_error(theta):.4f})")


def demo_gossip():
    print("\n=== 2. one 1-bit gossip round through CommEngine ===")
    engine = CommEngine(topo=ring(8),
                        codec=make_wire("moniqua",
                                        QuantSpec(bits=1, stochastic=False)),
                        backend="auto")   # Pallas on TPU, pure jnp elsewhere
    # Moniqua's regime: workers are theta-close perturbations of one model
    # (during training theta tracks alpha * ||g||_inf, see core/theta.py)
    theta = 0.5
    base = jax.random.normal(jax.random.PRNGKey(0), (1, 128)) * 10.0
    X = base + jax.random.uniform(jax.random.PRNGKey(1), (8, 128),
                                  minval=-0.45, maxval=0.45) * theta
    ledger = gossip.BytesLedger()
    X1 = engine.mix(X, theta=theta, key=jax.random.PRNGKey(2),
                    ledger=ledger).x
    spread = lambda A: float(jnp.abs(A - A.mean(0)).max())
    drift = float(jnp.abs(X1.mean(0) - X.mean(0)).max())
    f32 = gossip.dtype_bytes_tree(X) * len(engine.topo.neighbor_offsets())
    ball = modulo.error_bound(theta, engine.codec.spec.delta)
    print(f"worker spread {spread(X):.4f} -> {spread(X1):.4f} "
          f"(grows at most the Lemma-2 error {ball:.2f}), "
          f"global-mean drift {drift:.4f} (line-4 bias cancellation)")
    print(f"bytes on wire per worker: {ledger.bytes_per_worker} "
          f"(vs {f32} for f32 D-PSGD = 1/{f32 // ledger.bytes_per_worker})")


def demo_training():
    print("\n=== 3. tiny decentralized training run ===")
    import dataclasses
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              num_layers=1, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=64)
    model = build_model(cfg)
    shape = InputShape("qs", seq_len=16, global_batch=8, kind="train")
    for algo, bits in [("dpsgd", 32), ("moniqua", 8)]:
        tc = TrainerConfig(algo=algo, n_workers=4, bits=min(bits, 8),
                           theta=2.0, lr=0.3, steps=20, log_every=10,
                           momentum=0.0, weight_decay=0.0)
        out = Trainer(model, shape, tc).run()
        h = out["history"]
        print(f"{algo:8s} ({bits:2d}-bit wire): loss {h[0]['loss']:.3f} -> "
              f"{h[-1]['loss']:.3f}   bytes/step/worker "
              f"{out['bytes_per_step']:,}")


if __name__ == "__main__":
    demo_codec()
    demo_gossip()
    demo_training()
